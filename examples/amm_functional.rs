//! Functional demonstration of the algorithmic multi-port schemes
//! (paper §II), cross-checked three ways:
//!
//! 1. Rust bit-accurate simulators vs a flat-memory oracle under a
//!    conflict-heavy access storm;
//! 2. the H-NTX-Rd read path vs the AOT **Pallas** `xor_recon` kernel
//!    executed through PJRT (L1 ↔ L3 agreement on real data);
//! 3. parity-invariant checks after every cycle.
//!
//! ```bash
//! make artifacts && cargo run --release --example amm_functional
//! ```

use amm_dse::mem::functional::{BNtxWr, HNtxRd, HbNtxRdWr, LvtAmm, MultiPortMem};
use amm_dse::runtime::{names, Runtime};
use amm_dse::util::rng::Rng;

fn main() -> amm_dse::Result<()> {
    let mut rng = Rng::new(2020);

    // --- 1. conflict storm vs flat oracle ------------------------------
    println!("== conflict storm: schemes vs flat memory oracle ==");
    storm(&mut rng, "H-NTX-Rd   (2R1W)", HNtxRd::new(256));
    storm(&mut rng, "B-NTX-Wr   (1R2W)", BNtxWr::new(256));
    storm(&mut rng, "LVT        (4R2W)", LvtAmm::new(512, 4, 2));
    storm(&mut rng, "HB-NTX     (2R2W)", HbNtxRdWr::new(512, 2, 2));

    // --- 2. H-NTX-Rd vs the Pallas kernel through PJRT -----------------
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            println!("\n({e}; skipping the PJRT cross-check)");
            return Ok(());
        }
    };
    if !rt.has_artifact(names::XOR_RECON) {
        println!("\n(xor_recon artifact missing; run `make artifacts` for the PJRT cross-check)");
        return Ok(());
    }
    println!("\n== H-NTX-Rd rust simulator vs AOT Pallas xor_recon (PJRT) ==");
    let exe = rt.load(names::XOR_RECON)?;
    let d = 1024usize; // words per bank (artifact shape)
    let nq = 512usize;
    let mut hntx = HNtxRd::new(d);
    // fill with random data through the write port
    for a in 0..2 * d {
        hntx.cycle(&[], &[(a, (rng.next_u32() & 0x7fffffff) as u64)]);
    }
    // extract the banks for the kernel (bank0 = even addrs, bank1 = odd)
    let mut bank0 = vec![0i32; d];
    let mut bank1 = vec![0i32; d];
    for off in 0..d {
        bank0[off] = hntx.read_direct(off * 2) as i32;
        bank1[off] = hntx.read_direct(off * 2 + 1) as i32;
    }
    let parity: Vec<i32> = bank0.iter().zip(&bank1).map(|(a, b)| a ^ b).collect();
    // conflicted read batch: all queries forced down the parity path
    let idx: Vec<i32> = (0..nq).map(|_| rng.below(d as u64) as i32).collect();
    let sel: Vec<i32> = (0..nq).map(|_| rng.below(2) as i32).collect();
    let conflict = vec![1i32; nq];
    let out = exe.run_i32(&[
        (&bank0, &[d]),
        (&bank1, &[d]),
        (&parity, &[d]),
        (&idx, &[nq]),
        (&sel, &[nq]),
        (&conflict, &[nq]),
    ])?;
    let mut mismatches = 0;
    for q in 0..nq {
        let addr = idx[q] as usize * 2 + sel[q] as usize;
        let want = hntx.read_via_parity(addr) as i32;
        if out[0][q] != want {
            mismatches += 1;
        }
    }
    println!(
        "  {} parity-path reads through PJRT, {} mismatches vs rust simulator",
        nq, mismatches
    );
    assert_eq!(mismatches, 0);

    // --- 3. parity invariant ------------------------------------------
    println!("\n== parity invariant after 10k random writes ==");
    let mut m = HNtxRd::new(128);
    for _ in 0..10_000 {
        m.cycle(&[], &[(rng.below_usize(256), rng.next_u64())]);
    }
    let ok = (0..256).all(|a| m.read_direct(a) == m.read_via_parity(a));
    println!("  Ref == Bank0 ^ Bank1 everywhere: {ok}");
    assert!(ok);
    println!("\nall functional checks passed");
    Ok(())
}

/// Hammer a scheme with same-bank conflicts and compare against flat.
fn storm<M: MultiPortMem>(rng: &mut Rng, name: &str, mut mem: M) {
    let cap = mem.capacity();
    let (r, w) = (mem.read_ports(), mem.write_ports());
    let mut flat = vec![0u64; cap];
    let mut checked = 0u64;
    for _ in 0..2_000 {
        // bias addresses into a small window to force conflicts
        let window = 1 + rng.below_usize(cap / 4);
        let reads: Vec<usize> = (0..r).map(|_| rng.below_usize(window)).collect();
        let writes: Vec<(usize, u64)> =
            (0..w).map(|_| (rng.below_usize(window), rng.next_u64() & 0xFFFF)).collect();
        let got = mem.cycle(&reads, &writes);
        for (i, &a) in reads.iter().enumerate() {
            assert_eq!(got[i], flat[a], "{name}: read {a}");
            checked += 1;
        }
        for &(a, v) in &writes {
            flat[a] = v;
        }
    }
    println!("  {name}: {checked} conflicted reads verified");
}
