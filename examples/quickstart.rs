//! Quickstart: explore GEMM with the `Explorer` facade — one run covers
//! the banked baseline, the HB-NTX XOR AMM, the LVT AMM and a
//! circuit-level multiport comparator (added by registry id).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use amm_dse::dse::Sweep;
use amm_dse::suite::Scale;
use amm_dse::Explorer;

fn main() -> amm_dse::Result<()> {
    // A focused sweep: banked 1/8, XOR + LVT 4R2W, three unroll factors.
    let sweep = Sweep {
        unrolls: vec![1, 4, 8],
        word_bytes: vec![8],
        alus: vec![8],
        bank_counts: vec![1, 8],
        amm_ports: vec![(4, 2)],
        include_multipump: false,
        include_lvt: true,
        ..Sweep::default()
    };
    let ex = Explorer::new()
        .workload("gemm", Scale::Paper)
        .sweep(sweep)
        .model("cmp4r2w") // any registry id composes into the sweep
        .run()?;
    println!(
        "workload: GEMM-NCUBED ({} trace nodes, checksum {:.4})",
        ex.trace_nodes, ex.checksum
    );
    println!("spatial locality (Weinberg, byte strides): {:.3}", ex.locality);
    println!(
        "sweep: {} design points, cost backend {}",
        ex.points().len(),
        ex.backend_label()
    );

    // The head-to-head table at unroll 8 (one row per organization).
    println!(
        "\n{:<28} {:>10} {:>10} {:>12} {:>10} {:>10}",
        "design (u8/w8/a8)", "cycles", "time(ns)", "area(um2)", "power(mW)", "stalls"
    );
    for p in ex.points().iter().filter(|p| p.unroll == 8) {
        println!(
            "{:<28} {:>10} {:>10.0} {:>12.0} {:>10.3} {:>10}",
            p.mem_id, p.out.cycles, p.out.time_ns, p.out.area_um2, p.out.power_mw, p.out.port_stalls
        );
    }

    println!("\n(time, area) Pareto frontier across the whole sweep:");
    for p in ex.pareto_area() {
        println!(
            "  {:<22} {:>10} cycles {:>12.0} um^2 {:>8.3} mW",
            p.id, p.out.cycles, p.area(), p.power()
        );
    }
    println!("\nAMM true ports remove the bank conflicts the static banked schedule");
    println!("stalls on — at the cost of parity/replica capacity. Run the full");
    println!("sweep with `cargo run --release --example full_dse`.");
    Ok(())
}
