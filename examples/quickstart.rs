//! Quickstart: trace one benchmark, compare a banked baseline against an
//! XOR-based AMM on the same workload.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use amm_dse::mem::MemKind;
use amm_dse::sched::{simulate, DesignConfig};
use amm_dse::suite::{self, Scale};
use amm_dse::locality;

fn main() {
    let wl = suite::generate("gemm", Scale::Paper);
    println!("workload: GEMM-NCUBED ({} trace nodes, checksum {:.4})", wl.trace.len(), wl.checksum);
    let rep = locality::analyze(&wl.trace);
    println!("spatial locality (Weinberg, byte strides): {:.3}\n", rep.spatial_locality());

    let configs = [
        ("banked x8 (array partitioning)", DesignConfig {
            mem: MemKind::Banked { banks: 8 },
            unroll: 8,
            word_bytes: 8,
            alus: 8,
        }),
        ("HB-NTX XOR AMM 4R2W", DesignConfig {
            mem: MemKind::XorAmm { read_ports: 4, write_ports: 2 },
            unroll: 8,
            word_bytes: 8,
            alus: 8,
        }),
        ("LVT AMM 4R2W", DesignConfig {
            mem: MemKind::LvtAmm { read_ports: 4, write_ports: 2 },
            unroll: 8,
            word_bytes: 8,
            alus: 8,
        }),
    ];

    println!(
        "{:<34} {:>10} {:>10} {:>12} {:>10} {:>10}",
        "design", "cycles", "time(ns)", "area(um2)", "power(mW)", "stalls"
    );
    for (name, cfg) in configs {
        let out = simulate(&wl.trace, &cfg);
        println!(
            "{:<34} {:>10} {:>10.0} {:>12.0} {:>10.3} {:>10}",
            name, out.cycles, out.time_ns, out.area_um2, out.power_mw, out.port_stalls
        );
    }
    println!("\nAMM true ports remove the bank conflicts the static banked schedule");
    println!("stalls on — at the cost of parity/replica capacity. Run the full");
    println!("sweep with `cargo run --release --example full_dse`.");
}
