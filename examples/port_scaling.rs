//! Fig 2 reproduction: the HB-NTX-RdWr port-scaling flow — how bank
//! count, capacity overhead, glue logic, and access time grow as read
//! and write ports are added, compared against LVT and circuit-level
//! multiport designs.
//!
//! ```bash
//! cargo run --release --example port_scaling
//! ```

use amm_dse::mem::MemKind;

fn main() {
    let depth = 4096u32;
    let width = 32u32;
    let base = MemKind::Banked { banks: 1 }.build(depth, width);
    println!("logical memory: {depth} x {width}b; baseline 1RW macro = {:.0} um^2\n", base.area_um2());
    println!(
        "{:<8} {:<10} {:>7} {:>9} {:>11} {:>11} {:>8} {:>9}",
        "ports", "design", "macros", "capacity", "sram_um2", "logic_um2", "t_ns", "area_x"
    );
    for (r, w) in [(1u32, 1u32), (2, 1), (4, 1), (2, 2), (4, 2), (4, 4), (8, 4)] {
        for kind in [
            MemKind::XorAmm { read_ports: r, write_ports: w },
            MemKind::LvtAmm { read_ports: r, write_ports: w },
            MemKind::CircuitMp { read_ports: r, write_ports: w },
        ] {
            let d = kind.build(depth, width);
            println!(
                "{:<8} {:<10} {:>7} {:>8.2}x {:>11.0} {:>11.0} {:>8.3} {:>8.2}x",
                format!("{r}R{w}W"),
                match kind {
                    MemKind::XorAmm { .. } => "hb-ntx",
                    MemKind::LvtAmm { .. } => "lvt",
                    _ => "circuit",
                },
                d.macros,
                d.macros as f32 * d.macro_depth as f32 / depth as f32,
                d.sram.area_um2,
                d.logic.area_um2,
                d.t_access_ns(),
                d.area_um2() / base.area_um2()
            );
        }
        println!();
    }
    println!("HB-NTX grows capacity linearly per port doubling (the Fig-2 flow);");
    println!("LVT replicates r*w full copies; circuit-level multiport pays the");
    println!("quadratic cell-pitch penalty the paper cites as having no EDA support.");
}
