//! END-TO-END driver (the repository's headline experiment).
//!
//! Runs the complete Mem-Aladdin pipeline on the paper's four DSE
//! benchmarks at paper scale:
//!
//!   trace → spatial locality → design-space sweep (design points scored
//!   through the AOT Pallas cost model via PJRT) → Pareto frontiers →
//!   performance ratios → locality correlation,
//!
//! writing `results/fig4_<bench>.csv` and `results/fig5.csv`, printing
//! the figures as ASCII, and checking the paper's §IV-C claim. Also
//! functionally validates the workload datapath artifacts (GEMM tile)
//! against the Rust traced execution — proving all three layers compose.
//!
//! ```bash
//! make artifacts && cargo run --release --example full_dse
//! ```

use amm_dse::coordinator::{Coordinator, CostBackend};
use amm_dse::dse::{self, Sweep};
use amm_dse::runtime::{names, Runtime};
use amm_dse::suite::{self, Scale};
use amm_dse::util::stats;
use amm_dse::{locality, report};
use std::path::Path;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let t_start = Instant::now();
    let coord = Coordinator::new();
    println!("cost backend: {:?} (Pjrt = AOT Pallas kernel through PJRT)", coord.backend);
    if coord.backend != CostBackend::Pjrt {
        eprintln!("warning: run `make artifacts` first to exercise the PJRT path");
    }

    // --- layer-composition check: run the GEMM datapath artifact ------
    if coord.backend == CostBackend::Pjrt {
        verify_gemm_artifact()?;
    }

    // --- the four-panel Fig 4 sweep ------------------------------------
    let sweep = Sweep::default();
    println!("\nsweep: {} design points per benchmark", sweep.configs().len());
    let mut summaries = Vec::new();
    for name in suite::DSE_BENCHMARKS {
        let t0 = Instant::now();
        let wl = suite::generate(name, Scale::Paper);
        let loc = locality::analyze(&wl.trace).spatial_locality();
        let points = coord.run_sweep(&wl.trace, &sweep)?;
        let ratio = dse::performance_ratio(&points, 0.10);
        let csv = format!("results/fig4_{name}.csv");
        report::write_file(Path::new(&csv), &report::fig4_csv(&points))?;
        println!(
            "\n=== {name}: {} nodes, L_spatial {:.3}, {} points in {:.1?} -> {csv}",
            wl.trace.len(),
            loc,
            points.len(),
            t0.elapsed()
        );
        println!("{}", report::ascii_scatter(&points, |p| p.area(), &format!("Fig4 {name}: area vs time"), 72, 16));
        summaries.push(dse::BenchSummary {
            name: name.to_string(),
            locality: loc,
            perf_ratio: ratio,
            best_banking_ns: dse::best_time(&points, |p| !p.is_amm),
            best_amm_ns: dse::best_time(&points, |p| p.is_amm),
            n_points: points.len(),
        });
    }

    // --- Fig 5: locality for the whole suite + ratios -----------------
    for name in suite::ALL_BENCHMARKS {
        if suite::DSE_BENCHMARKS.contains(&name) {
            continue;
        }
        let wl = suite::generate(name, Scale::Paper);
        summaries.push(dse::BenchSummary {
            name: name.to_string(),
            locality: locality::analyze(&wl.trace).spatial_locality(),
            perf_ratio: None,
            best_banking_ns: f64::NAN,
            best_amm_ns: f64::NAN,
            n_points: 0,
        });
    }
    summaries.sort_by(|a, b| a.name.cmp(&b.name));
    report::write_file(Path::new("results/fig5.csv"), &report::fig5_csv(&summaries))?;
    println!("\n{}", report::fig5_ascii(&summaries));

    // --- the paper's §IV-C claim ---------------------------------------
    let with_ratio: Vec<&dse::BenchSummary> =
        summaries.iter().filter(|s| s.perf_ratio.is_some()).collect();
    let xs: Vec<f64> = with_ratio.iter().map(|s| s.locality).collect();
    let ys: Vec<f64> = with_ratio.iter().map(|s| s.perf_ratio.unwrap()).collect();
    println!(
        "locality vs perf-ratio: pearson {:.3}, spearman {:.3}",
        stats::pearson(&xs, &ys),
        stats::spearman(&xs, &ys)
    );
    // The paper's win criterion for "high-performance design": AMMs
    // *extend the design space* (Fig 4's blue-shaded region — AMM points
    // at cycle counts banking cannot reach) exactly when spatial
    // locality is low (< 0.3); the area ratio separates KMP (AMM pays)
    // from the rest (nearly equal / better).
    let mut consistent = 0;
    for s in &with_ratio {
        let low = s.locality < 0.3;
        let extends = s.best_amm_ns < s.best_banking_ns;
        println!(
            "  {:<10} L={:.3} ratio={:.3} amm-extends-frontier={} -> {}",
            s.name,
            s.locality,
            s.perf_ratio.unwrap(),
            extends,
            if low == extends { "consistent with paper (low locality <=> AMM wins)" } else { "inconsistent" }
        );
        if low == extends {
            consistent += 1;
        }
    }
    println!(
        "\n{} of {} benchmarks consistent with the paper's threshold claim; total {:.1?}",
        consistent,
        with_ratio.len(),
        t_start.elapsed()
    );
    Ok(())
}

/// Run the AOT GEMM tile datapath through PJRT and compare with a Rust
/// matmul — the L1→L2→L3 composition proof on real data.
fn verify_gemm_artifact() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let exe = rt.load(names::GEMM)?;
    let n = 64usize;
    let mut rng = amm_dse::util::rng::Rng::new(77);
    let a: Vec<f32> = (0..n * n).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let b: Vec<f32> = (0..n * n).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let out = exe.run_f32(&[(&a, &[n, n]), (&b, &[n, n])])?;
    let mut max_err = 0f32;
    for i in 0..n {
        for j in 0..n {
            let mut want = 0f32;
            for k in 0..n {
                want += a[i * n + k] * b[k * n + j];
            }
            max_err = max_err.max((out[0][i * n + j] - want).abs());
        }
    }
    anyhow::ensure!(max_err < 1e-3, "gemm artifact mismatch: {max_err}");
    println!("layer-composition check: PJRT GEMM datapath matches Rust matmul (max err {max_err:.2e})");
    Ok(())
}
