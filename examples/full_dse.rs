//! END-TO-END driver (the repository's headline experiment).
//!
//! Runs the complete Mem-Aladdin pipeline on the paper's four DSE
//! benchmarks at paper scale through the `Explorer` facade:
//!
//!   trace → spatial locality → design-space sweep (design points scored
//!   through the coordinator's batched cost service) → Pareto frontiers
//!   → performance ratios → locality correlation,
//!
//! writing `results/fig4_<bench>.csv` and `results/fig5.csv`, printing
//! the figures as ASCII, and checking the paper's §IV-C claim. Also
//! functionally validates the workload datapath artifacts (GEMM tile)
//! against the Rust traced execution when the PJRT backend is live —
//! proving all three layers compose.
//!
//! ```bash
//! make artifacts && cargo run --release --example full_dse
//! ```

use amm_dse::coordinator::{Coordinator, CostBackend};
use amm_dse::dse::{self, Sweep};
use amm_dse::runtime::{names, Runtime};
use amm_dse::suite::{self, Scale};
use amm_dse::util::stats;
use amm_dse::{locality, Explorer};
use std::time::Instant;

fn main() -> amm_dse::Result<()> {
    let t_start = Instant::now();

    // One coordinator for the whole run: the PJRT cost model compiles
    // once and every benchmark's sweep batches through it.
    let coord = Coordinator::new();
    println!("cost backend: {:?} (Pjrt = AOT Pallas kernel through PJRT)", coord.backend);
    if coord.backend != CostBackend::Pjrt {
        eprintln!("warning: run `make artifacts` first to exercise the PJRT path");
    } else {
        // layer-composition check: run the GEMM datapath artifact
        verify_gemm_artifact()?;
    }

    // --- the four-panel Fig 4 sweep ------------------------------------
    let sweep = Sweep::default();
    println!("sweep: {} design points per benchmark", sweep.points().len());
    let mut summaries = Vec::new();
    for name in suite::DSE_BENCHMARKS {
        let t0 = Instant::now();
        let ex =
            Explorer::new().workload(name, Scale::Paper).sweep(sweep.clone()).run_with(&coord)?;
        let csv = format!("results/fig4_{name}.csv");
        ex.write_csv(&csv)?;
        println!(
            "\n=== {name}: {} nodes, L_spatial {:.3}, {} points in {:.1?} -> {csv}",
            ex.trace_nodes,
            ex.locality,
            ex.points().len(),
            t0.elapsed()
        );
        println!("{}", ex.scatter_area(72, 16));
        summaries.push(ex.summary());
    }

    // --- Fig 5: locality for the whole suite + ratios -----------------
    for name in suite::ALL_BENCHMARKS {
        if suite::DSE_BENCHMARKS.contains(&name) {
            continue;
        }
        let wl = suite::generate(name, Scale::Paper);
        summaries.push(dse::BenchSummary {
            name: name.to_string(),
            locality: locality::analyze(&wl.trace).spatial_locality(),
            perf_ratio: None,
            best_banking_ns: f64::NAN,
            best_amm_ns: f64::NAN,
            n_points: 0,
        });
    }
    summaries.sort_by(|a, b| a.name.cmp(&b.name));
    amm_dse::report::write_file(
        std::path::Path::new("results/fig5.csv"),
        &amm_dse::report::fig5_csv(&summaries),
    )
    .map_err(|e| amm_dse::Error::io("write results/fig5.csv", e))?;
    println!("\n{}", amm_dse::report::fig5_ascii(&summaries));

    // --- the paper's §IV-C claim ---------------------------------------
    let with_ratio: Vec<&dse::BenchSummary> =
        summaries.iter().filter(|s| s.perf_ratio.is_some()).collect();
    let xs: Vec<f64> = with_ratio.iter().map(|s| s.locality).collect();
    let ys: Vec<f64> = with_ratio.iter().map(|s| s.perf_ratio.unwrap()).collect();
    println!(
        "locality vs perf-ratio: pearson {:.3}, spearman {:.3}",
        stats::pearson(&xs, &ys),
        stats::spearman(&xs, &ys)
    );
    // The paper's win criterion for "high-performance design": AMMs
    // *extend the design space* (Fig 4's blue-shaded region — AMM points
    // at cycle counts banking cannot reach) exactly when spatial
    // locality is low (< 0.3); the area ratio separates KMP (AMM pays)
    // from the rest (nearly equal / better).
    let mut consistent = 0;
    for s in &with_ratio {
        let low = s.locality < 0.3;
        let extends = s.best_amm_ns < s.best_banking_ns;
        println!(
            "  {:<10} L={:.3} ratio={:.3} amm-extends-frontier={} -> {}",
            s.name,
            s.locality,
            s.perf_ratio.unwrap(),
            extends,
            if low == extends { "consistent with paper (low locality <=> AMM wins)" } else { "inconsistent" }
        );
        if low == extends {
            consistent += 1;
        }
    }
    println!(
        "\n{} of {} benchmarks consistent with the paper's threshold claim; total {:.1?}",
        consistent,
        with_ratio.len(),
        t_start.elapsed()
    );
    Ok(())
}

/// Run the AOT GEMM tile datapath through PJRT and compare with a Rust
/// matmul — the L1→L2→L3 composition proof on real data.
fn verify_gemm_artifact() -> amm_dse::Result<()> {
    let rt = Runtime::cpu()?;
    let exe = rt.load(names::GEMM)?;
    let n = 64usize;
    let mut rng = amm_dse::util::rng::Rng::new(77);
    let a: Vec<f32> = (0..n * n).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let b: Vec<f32> = (0..n * n).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let out = exe.run_f32(&[(&a, &[n, n]), (&b, &[n, n])])?;
    let mut max_err = 0f32;
    for i in 0..n {
        for j in 0..n {
            let mut want = 0f32;
            for k in 0..n {
                want += a[i * n + k] * b[k * n + j];
            }
            max_err = max_err.max((out[0][i * n + j] - want).abs());
        }
    }
    if max_err >= 1e-3 {
        return Err(amm_dse::Error::runtime(format!("gemm artifact mismatch: {max_err}")));
    }
    println!("layer-composition check: PJRT GEMM datapath matches Rust matmul (max err {max_err:.2e})");
    Ok(())
}
