//! Campaign specs as data: build a plan, serialize it, run it as two
//! deterministic shards (as two hosts would), and merge the shard
//! sinks back into the exact unsharded result.
//!
//! ```bash
//! cargo run --release --example campaign_spec
//! ```
//!
//! Everything here is offline (pure-Rust cost model) and tiny-scale so
//! the example runs in seconds; swap `run_offline` for `run` and the
//! scale for `Paper` to reproduce the real figure.

use amm_dse::campaign::merge;
use amm_dse::dse::Sweep;
use amm_dse::suite::Scale;
use amm_dse::CampaignSpec;

fn main() -> amm_dse::Result<()> {
    let dir = std::env::temp_dir().join("amm_dse_campaign_spec_example");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).map_err(|e| amm_dse::Error::io("create tmp dir", e))?;

    // ---- 1. the plan, as a value --------------------------------------
    let mut spec = CampaignSpec::new()
        .benchmark("gemm")
        .benchmark("fft")
        .benchmark("stencil2d")
        .locality_only("kmp");
    spec.scale = Scale::Tiny;
    spec.sweep = Sweep::quick();

    // ---- 2. ... and as a shippable artifact ---------------------------
    let toml = spec.to_toml();
    println!("--- campaign spec (send this file to every host) ---\n{toml}");
    assert_eq!(CampaignSpec::parse(&toml)?, spec, "specs round-trip through TOML");

    // ---- 3. the reference: one unsharded run --------------------------
    let full = spec.run_offline()?;
    println!(
        "unsharded: {} points across {} benchmarks",
        full.total_points(),
        full.explorations().len()
    );

    // ---- 4. two shards, each with its own sink ------------------------
    // `--shard i/n` filters the planned units by a stable hash of
    // (benchmark, point id): the two runs below touch disjoint work and
    // together cover the plan exactly.
    let mut sinks = Vec::new();
    for i in 0..2u32 {
        let mut shard = spec.clone().with_shard(i, 2);
        let path = dir.join(format!("s{i}.jsonl"));
        shard.sink = Some(path.clone());
        let outcome = shard.run_offline()?;
        println!("shard {i}/2: {} points -> {}", outcome.total_points(), path.display());
        sinks.push(path);
    }

    // ---- 5. merge the sinks against the plan --------------------------
    let merged = merge::merge(&spec, &sinks)?;
    assert!(merged.missing.is_empty(), "shards partition the plan: nothing is missing");
    assert_eq!(merged.duplicates + merged.conflicts, 0, "...and nothing overlaps");
    assert_eq!(
        merged.outcome.fig5_csv(),
        full.fig5_csv(),
        "merged shards reproduce the unsharded fig5 CSV byte-for-byte"
    );
    println!("\n--- fig5 from the merged shard sinks ---");
    print!("{}", merged.outcome.fig5_ascii());
    println!("merge == unsharded campaign, byte-for-byte. specs are just data.");
    Ok(())
}
