//! Spatial-locality survey across the MachSuite ports (Fig 5's x-axis):
//! the Weinberg metric, stride histograms, and the byte-stride argument
//! from the paper's §IV-B (stride-one byte code vs 8-byte doubles).
//!
//! ```bash
//! cargo run --release --example locality_report
//! ```

use amm_dse::locality;
use amm_dse::suite::{self, Scale};

fn main() {
    println!(
        "{:<12} {:>10} {:>10} {:>12}   dominant byte-strides",
        "benchmark", "L_spatial", "stride1", "accesses"
    );
    let mut rows: Vec<(String, f64)> = Vec::new();
    for name in suite::ALL_BENCHMARKS {
        let wl = suite::generate(name, Scale::Paper);
        let rep = locality::analyze(&wl.trace);
        // aggregate stride histogram over sites
        let mut hist: std::collections::HashMap<u64, u64> = std::collections::HashMap::new();
        for s in rep.sites.values() {
            for (&k, &v) in &s.strides {
                *hist.entry(k).or_insert(0) += v;
            }
        }
        let mut top: Vec<(u64, u64)> = hist.into_iter().collect();
        top.sort_by_key(|&(_, c)| std::cmp::Reverse(c));
        let tops: Vec<String> =
            top.iter().take(3).map(|(s, c)| format!("{s}B x{c}")).collect();
        println!(
            "{:<12} {:>10.4} {:>10.4} {:>12}   {}",
            name,
            rep.spatial_locality(),
            rep.stride1_fraction(),
            rep.total_accesses,
            tops.join(", ")
        );
        rows.push((name.to_string(), rep.spatial_locality()));
    }
    rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("\npaper §IV-B check:");
    println!("  highest locality: {} ({:.3}) — expected byte-oriented (kmp/aes)", rows[0].0, rows[0].1);
    let low: Vec<&str> = rows
        .iter()
        .filter(|r| r.1 < 0.3)
        .map(|r| r.0.as_str())
        .collect();
    println!("  below the paper's 0.3 threshold: {low:?}");
    for want in ["fft", "gemm", "md-knn"] {
        assert!(low.contains(&want), "{want} should be below 0.3");
    }
    println!("  (fft, gemm, md-knn all < 0.3 — consistent with the paper)");
}
