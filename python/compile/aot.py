"""AOT lowering: JAX/Pallas → HLO text artifacts for the Rust runtime.

Usage: ``python -m compile.aot [--out-dir ../artifacts]`` (the Makefile's
`artifacts` target). Each model entry point is jitted, lowered to
stablehlo, converted to an XlaComputation and dumped as HLO **text** —
the only interchange format xla_extension 0.5.1 accepts from jax ≥ 0.5
(64-bit instruction ids in serialized protos are rejected; the text
parser reassigns ids). See /opt/xla-example/README.md.

Before writing anything, every kernel is validated against its pure-jnp
oracle (kernels/ref.py); a disagreement aborts the build.
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

# Artifact shapes — keep in lockstep with rust/src/cost/service.rs (COST_BATCH)
# and the examples.
COST_N = 1024
XOR_D = 1024
XOR_N = 512
GEMM_N = 64
STENCIL_ROWS = 32
FFT_N = 512


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def specs():
    """(name, fn, example_args) for every artifact."""
    return [
        ("cost_model", model.cost_model, (_f32(COST_N, 4),)),
        (
            "xor_recon",
            model.xor_recon,
            (_i32(XOR_D), _i32(XOR_D), _i32(XOR_D), _i32(XOR_N), _i32(XOR_N), _i32(XOR_N)),
        ),
        ("gemm", model.gemm, (_f32(GEMM_N, GEMM_N), _f32(GEMM_N, GEMM_N))),
        (
            "stencil2d",
            model.stencil2d,
            (_f32(STENCIL_ROWS, STENCIL_ROWS), _f32(3, 3)),
        ),
        (
            "fft_stage",
            model.fft_stage,
            (_f32(FFT_N), _f32(FFT_N), _f32(FFT_N // 2), _f32(FFT_N // 2)),
        ),
    ]


def validate() -> None:
    """Kernels must match their oracles before we emit artifacts."""
    rng = np.random.default_rng(0)

    x = np.stack(
        [
            rng.choice([64, 256, 1024, 4096, 16384], COST_N).astype(np.float32),
            rng.choice([8, 16, 32, 64], COST_N).astype(np.float32),
            rng.choice([1, 2, 4], COST_N).astype(np.float32),
            rng.choice([1, 2, 4], COST_N).astype(np.float32),
        ],
        axis=-1,
    )
    got = model.cost_model(jnp.asarray(x))[0]
    want = ref.cost_ref(jnp.asarray(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    b0 = rng.integers(0, 2**31, XOR_D, dtype=np.int32)
    b1 = rng.integers(0, 2**31, XOR_D, dtype=np.int32)
    par = np.bitwise_xor(b0, b1)
    idx = rng.integers(0, XOR_D, XOR_N, dtype=np.int32)
    sel = rng.integers(0, 2, XOR_N, dtype=np.int32)
    conflict = rng.integers(0, 2, XOR_N, dtype=np.int32)
    got = model.xor_recon(*map(jnp.asarray, (b0, b1, par, idx, sel, conflict)))[0]
    want = ref.xor_recon_ref(*map(jnp.asarray, (b0, b1, par, idx, sel, conflict)))
    np.testing.assert_array_equal(got, want)

    a = rng.standard_normal((GEMM_N, GEMM_N), dtype=np.float32)
    b = rng.standard_normal((GEMM_N, GEMM_N), dtype=np.float32)
    np.testing.assert_allclose(
        model.gemm(jnp.asarray(a), jnp.asarray(b))[0],
        ref.gemm_ref(jnp.asarray(a), jnp.asarray(b)),
        rtol=1e-4,
        atol=1e-4,
    )

    g = rng.standard_normal((STENCIL_ROWS, STENCIL_ROWS), dtype=np.float32)
    f = rng.standard_normal((3, 3), dtype=np.float32)
    np.testing.assert_allclose(
        model.stencil2d(jnp.asarray(g), jnp.asarray(f))[0],
        ref.stencil2d_ref(jnp.asarray(g), jnp.asarray(f)),
        rtol=1e-4,
        atol=1e-4,
    )
    print("aot: kernel-vs-oracle validation OK", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--skip-validate", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    if not args.skip_validate:
        validate()

    for name, fn, example_args in specs():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        print(f"aot: wrote {path} ({len(text)} chars)", file=sys.stderr)


if __name__ == "__main__":
    main()
