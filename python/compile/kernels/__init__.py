"""Layer-1 Pallas kernels (interpret=True for CPU-PJRT execution)."""
