"""H-NTX-Rd XOR read-path as a Pallas kernel (paper §II-A).

Given the two data banks and the reference (parity) bank of an H-NTX-Rd
memory, service a batch of reads: port-conflicted reads take the recovery
path ``sibling[i] ⊕ Ref[i]``, direct reads take their own bank. This is
the datapath the `mem::functional::HNtxRd` Rust simulator models
bit-accurately; `examples/amm_functional.rs` cross-checks the two through
PJRT.

TPU mapping: the banks live fully in VMEM (three [D] i32 vectors); the
read batch is tiled; the gather becomes a VMEM-local `jnp.take`, and the
XOR tree is a single VPU op per lane.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128


def _kernel(bank0_ref, bank1_ref, parity_ref, idx_ref, sel_ref, conflict_ref, o_ref):
    b0 = bank0_ref[...]
    b1 = bank1_ref[...]
    par = parity_ref[...]
    idx = idx_ref[...]
    sel = sel_ref[...]
    conflict = conflict_ref[...]
    own = jnp.where(sel == 0, jnp.take(b0, idx), jnp.take(b1, idx))
    sib = jnp.where(sel == 0, jnp.take(b1, idx), jnp.take(b0, idx))
    recon = jax.lax.bitwise_xor(sib, jnp.take(par, idx))
    o_ref[...] = jnp.where(conflict != 0, recon, own)


@functools.partial(jax.jit, static_argnames=())
def xor_recon(bank0, bank1, parity, idx, sel, conflict):
    """Reconstruct a batch of reads.

    Args:
      bank0, bank1, parity: [D] i32 bank contents (parity = bank0^bank1).
      idx: [N] i32 in-bank offsets.
      sel: [N] i32 bank selector (0/1).
      conflict: [N] i32 — nonzero forces the parity recovery path.
    Returns:
      [N] i32 read values.
    """
    n = idx.shape[0]
    assert n % TILE == 0, f"batch {n} not a multiple of {TILE}"
    d = bank0.shape[0]
    return pl.pallas_call(
        _kernel,
        grid=(n // TILE,),
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(
        bank0.astype(jnp.int32),
        bank1.astype(jnp.int32),
        parity.astype(jnp.int32),
        idx.astype(jnp.int32),
        sel.astype(jnp.int32),
        conflict.astype(jnp.int32),
    )
