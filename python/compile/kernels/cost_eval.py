"""CACTI-lite SRAM macro cost model as a Pallas kernel — the DSE hot path.

Mirrors ``rust/src/sram/mod.rs`` **exactly** (same f32 formulas, same
constants). The Rust coordinator batches `[depth, width, read_ports,
write_ports]` queries through the AOT-compiled version of this kernel via
PJRT; `rust/tests/pjrt_cost.rs` asserts Rust-mirror/PJRT agreement.

TPU mapping (DESIGN.md §Hardware-Adaptation): a pure elementwise pipeline
(sqrt, log2, polynomials) over the design-point axis — VPU-friendly; the
batch axis is tiled into VMEM-resident blocks by the BlockSpec below.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# --- calibration constants: keep in lockstep with rust/src/sram/mod.rs ---
CELL_UM2 = 0.65
PORT_PITCH = 0.5
PERIPH_A = 1.9
PERIPH_B = 520.0
E_READ_0 = 0.45
E_READ_BIT = 0.0021
WRITE_FACTOR = 1.18
LEAK_BIT = 0.00082
LEAK_0 = 3.1
T_0 = 0.28
T_DEC = 0.042
T_BL = 0.0095
T_PORT = 0.06

# Rows per grid step: 2 tiles double-buffer comfortably in ~16 MB VMEM
# (tile bytes = 128 x 5 x 4 B ≈ 2.5 KB — tiny; the tile size is chosen to
# keep the 8x128 VPU lanes full, not by VMEM pressure).
TILE = 128


def _cost_block(x):
    """The shared elementwise pipeline over a [tile, 4] block."""
    depth = jnp.maximum(x[:, 0], 1.0)
    width = jnp.maximum(x[:, 1], 1.0)
    ports = x[:, 2] + x[:, 3]
    extra = jnp.maximum(ports - 2.0, 0.0)
    pitch = 1.0 + PORT_PITCH * extra
    sqrt_d = jnp.sqrt(depth)
    area = depth * width * CELL_UM2 * pitch * pitch \
        + PERIPH_A * width * sqrt_d * pitch + PERIPH_B
    e_read = E_READ_0 + E_READ_BIT * width * sqrt_d * pitch
    e_write = e_read * WRITE_FACTOR
    leak = LEAK_0 + LEAK_BIT * depth * width * pitch * pitch
    t = T_0 + T_DEC * jnp.log2(depth) + T_BL * sqrt_d * pitch + T_PORT * extra
    return jnp.stack([area, e_read, e_write, leak, t], axis=-1)


def _kernel(x_ref, o_ref):
    o_ref[...] = _cost_block(x_ref[...])


@functools.partial(jax.jit, static_argnames=())
def cost_eval(x):
    """Evaluate the macro model for a [N, 4] f32 design matrix → [N, 5].

    N must be a multiple of TILE (the AOT artifact uses N=1024; the Rust
    side pads its final chunk).
    """
    n = x.shape[0]
    assert n % TILE == 0, f"batch {n} not a multiple of {TILE}"
    return pl.pallas_call(
        _kernel,
        grid=(n // TILE,),
        in_specs=[pl.BlockSpec((TILE, 4), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TILE, 5), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 5), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))
