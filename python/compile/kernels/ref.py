"""Pure-jnp oracles for every Pallas kernel — the build-time correctness
signal. pytest (python/tests/) asserts kernel == ref across shapes/dtypes
(hypothesis sweeps), and `aot.py` refuses to emit artifacts if any kernel
disagrees with its oracle.
"""

import jax
import jax.numpy as jnp

from . import cost_eval as ce


def cost_ref(x):
    """[N,4] → [N,5] macro cost, no Pallas (plain jnp)."""
    x = x.astype(jnp.float32)
    depth = jnp.maximum(x[:, 0], 1.0)
    width = jnp.maximum(x[:, 1], 1.0)
    ports = x[:, 2] + x[:, 3]
    extra = jnp.maximum(ports - 2.0, 0.0)
    pitch = 1.0 + ce.PORT_PITCH * extra
    sqrt_d = jnp.sqrt(depth)
    area = depth * width * ce.CELL_UM2 * pitch * pitch \
        + ce.PERIPH_A * width * sqrt_d * pitch + ce.PERIPH_B
    e_read = ce.E_READ_0 + ce.E_READ_BIT * width * sqrt_d * pitch
    e_write = e_read * ce.WRITE_FACTOR
    leak = ce.LEAK_0 + ce.LEAK_BIT * depth * width * pitch * pitch
    t = ce.T_0 + ce.T_DEC * jnp.log2(depth) + ce.T_BL * sqrt_d * pitch \
        + ce.T_PORT * extra
    return jnp.stack([area, e_read, e_write, leak, t], axis=-1)


def xor_recon_ref(bank0, bank1, parity, idx, sel, conflict):
    """Reference H-NTX-Rd read path."""
    bank0 = bank0.astype(jnp.int32)
    bank1 = bank1.astype(jnp.int32)
    parity = parity.astype(jnp.int32)
    own = jnp.where(sel == 0, bank0[idx], bank1[idx])
    sib = jnp.where(sel == 0, bank1[idx], bank0[idx])
    recon = jax.lax.bitwise_xor(sib, parity[idx])
    return jnp.where(conflict != 0, recon, own)


def gemm_ref(a, b):
    """Plain matmul."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))


def stencil2d_ref(grid, filt):
    """MachSuite stencil2d semantics (interior only, borders zero)."""
    grid = grid.astype(jnp.float32)
    filt = filt.astype(jnp.float32)
    rows, cols = grid.shape
    acc = jnp.zeros((rows - 2, cols - 2), jnp.float32)
    for k1 in range(3):
        for k2 in range(3):
            acc = acc + filt[k1, k2] * grid[k1 : k1 + rows - 2, k2 : k2 + cols - 2]
    out = jnp.zeros_like(grid)
    return out.at[: rows - 2, : cols - 2].set(acc)


def fft_stage_ref(re, im, tw_re, tw_im):
    """One strided-FFT butterfly stage (span = N/2, log = 0), vectorized.

    Mirrors MachSuite's first stage: for odd in [span, N): even = odd-span;
    butterflies then twiddle where rootindex = even != 0.
    """
    re = re.astype(jnp.float32)
    im = im.astype(jnp.float32)
    n = re.shape[0]
    span = n // 2
    re_e, re_o = re[:span], re[span:]
    im_e, im_o = im[:span], im[span:]
    new_re_e = re_e + re_o
    new_re_o = re_e - re_o
    new_im_e = im_e + im_o
    new_im_o = im_e - im_o
    # twiddle for rootindex = even index (0..span-1); index 0 untouched
    tr = tw_re.astype(jnp.float32)
    ti = tw_im.astype(jnp.float32)
    tw_applied_re = tr * new_re_o - ti * new_im_o
    tw_applied_im = tr * new_im_o + ti * new_re_o
    rooted = jnp.arange(span) != 0
    out_re_o = jnp.where(rooted, tw_applied_re, new_re_o)
    out_im_o = jnp.where(rooted, tw_applied_im, new_im_o)
    return (
        jnp.concatenate([new_re_e, out_re_o]),
        jnp.concatenate([new_im_e, out_im_o]),
    )
