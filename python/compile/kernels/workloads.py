"""Workload datapath kernels: the compute side of the accelerator whose
memory system the DSE explores (GEMM-NCUBED and Stencil-2D tiles).

TPU mapping (DESIGN.md §Hardware-Adaptation): `gemm_tile` is shaped for
the MXU — (TM, TK) x (TK, TN) f32 tiles accumulated over the K grid axis;
`stencil2d` is a VPU kernel over shifted slices (the 3x3 taps become 9
shifted adds, no gather).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped tiles. 64 keeps the interpret-mode tests fast while the
# BlockSpec structure (K innermost, accumulate-in-place) is exactly what
# a real Mosaic lowering wants.
TM = TN = TK = 32


def _gemm_kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ b_ref[...]


@functools.partial(jax.jit, static_argnames=())
def gemm(a, b):
    """Tiled matmul C = A @ B for [N, N] f32 (N multiple of 32)."""
    n, k = a.shape
    k2, m = b.shape
    assert k == k2 and n % TM == 0 and m % TN == 0 and k % TK == 0
    return pl.pallas_call(
        _gemm_kernel,
        grid=(n // TM, m // TN, k // TK),
        in_specs=[
            pl.BlockSpec((TM, TK), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((TK, TN), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((TM, TN), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(a.astype(jnp.float32), b.astype(jnp.float32))


def _stencil_kernel(grid_ref, filt_ref, o_ref):
    g = grid_ref[...]
    f = filt_ref[...]
    rows, cols = g.shape
    acc = jnp.zeros((rows - 2, cols - 2), jnp.float32)
    for k1 in range(3):
        for k2 in range(3):
            acc = acc + f[k1, k2] * g[k1 : k1 + rows - 2, k2 : k2 + cols - 2]
    out = jnp.zeros_like(g)
    out = out.at[: rows - 2, : cols - 2].set(acc)
    o_ref[...] = out


@functools.partial(jax.jit, static_argnames=())
def stencil2d(grid, filt):
    """MachSuite stencil2d: 3x3 filter; sol[r][c] for r,c < n-2, rest 0."""
    rows, cols = grid.shape
    return pl.pallas_call(
        _stencil_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((rows, cols), lambda i: (0, 0)),
            pl.BlockSpec((3, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, cols), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(grid.astype(jnp.float32), filt.astype(jnp.float32))
