"""Layer-2 JAX graphs: what actually gets AOT-lowered for the Rust side.

Each exported entry point returns a *tuple* (the Rust loader unwraps with
``decompose_tuple``) and calls the Layer-1 Pallas kernels so they lower
into the same HLO module.
"""

import jax.numpy as jnp

from .kernels import cost_eval as ce
from .kernels import ref
from .kernels import workloads as wk
from .kernels import xor_recon as xr


def cost_model(x):
    """Batched SRAM macro cost: [N,4] → ([N,5],). The DSE hot path."""
    return (ce.cost_eval(x),)


def xor_recon(bank0, bank1, parity, idx, sel, conflict):
    """H-NTX-Rd read reconstruction: → ([N] i32,)."""
    return (xr.xor_recon(bank0, bank1, parity, idx, sel, conflict),)


def gemm(a, b):
    """Tiled GEMM datapath: → ([N,N] f32,)."""
    return (wk.gemm(a, b),)


def stencil2d(grid, filt):
    """Stencil datapath: → ([R,C] f32,)."""
    return (wk.stencil2d(grid, filt),)


def fft_stage(re, im, tw_re, tw_im):
    """One strided-FFT butterfly stage (plain jnp — the memory behaviour
    of FFT is what the trace generator models; this is the compute
    datapath used by the end-to-end example): → (re', im')."""
    out_re, out_im = ref.fft_stage_ref(re, im, tw_re, tw_im)
    return (out_re.astype(jnp.float32), out_im.astype(jnp.float32))
