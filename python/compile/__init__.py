"""Build-time compile package: JAX/Pallas kernels AOT-lowered to HLO text.

Nothing in here runs at request time — `make artifacts` invokes
`compile.aot` once and the Rust binary self-contains afterwards.
"""
