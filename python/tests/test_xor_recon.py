"""XOR reconstruction kernel vs oracle + the H-NTX-Rd algebraic laws."""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (offline image); CI runs these"
)
import hypothesis.strategies as st

import jax.numpy as jnp
import numpy as np
from compile.kernels import ref
from compile.kernels import xor_recon as xr


def _setup(rng, d, n):
    b0 = rng.integers(0, 2**31, d, dtype=np.int32)
    b1 = rng.integers(0, 2**31, d, dtype=np.int32)
    par = np.bitwise_xor(b0, b1)
    idx = rng.integers(0, d, n, dtype=np.int32)
    sel = rng.integers(0, 2, n, dtype=np.int32)
    conflict = rng.integers(0, 2, n, dtype=np.int32)
    return b0, b1, par, idx, sel, conflict


def test_matches_ref():
    rng = np.random.default_rng(7)
    args = tuple(map(jnp.asarray, _setup(rng, 1024, 512)))
    np.testing.assert_array_equal(xr.xor_recon(*args), ref.xor_recon_ref(*args))


@hypothesis.given(
    d_log=st.integers(min_value=4, max_value=12),
    tiles=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@hypothesis.settings(max_examples=20, deadline=None)
def test_matches_ref_across_shapes(d_log, tiles, seed):
    rng = np.random.default_rng(seed)
    args = tuple(map(jnp.asarray, _setup(rng, 1 << d_log, tiles * xr.TILE)))
    np.testing.assert_array_equal(xr.xor_recon(*args), ref.xor_recon_ref(*args))


def test_parity_path_equals_direct_path():
    """With parity = b0 ^ b1, recovery must reproduce the direct read —
    the algebraic identity the whole H-NTX scheme rests on."""
    rng = np.random.default_rng(11)
    b0, b1, par, idx, sel, _ = _setup(rng, 512, 256)
    direct = xr.xor_recon(*map(jnp.asarray, (b0, b1, par, idx, sel, np.zeros(256, np.int32))))
    recovered = xr.xor_recon(*map(jnp.asarray, (b0, b1, par, idx, sel, np.ones(256, np.int32))))
    np.testing.assert_array_equal(direct, recovered)


def test_stale_parity_breaks_recovery():
    """Negative control: corrupt one parity word → exactly the conflicted
    reads of that offset break."""
    rng = np.random.default_rng(13)
    b0, b1, par, idx, sel, _ = _setup(rng, 512, 256)
    par_bad = par.copy()
    par_bad[idx[0]] ^= 0x5A5A
    ok = np.asarray(
        xr.xor_recon(*map(jnp.asarray, (b0, b1, par, idx, sel, np.ones(256, np.int32))))
    )
    bad = np.asarray(
        xr.xor_recon(*map(jnp.asarray, (b0, b1, par_bad, idx, sel, np.ones(256, np.int32))))
    )
    broken = ok != bad
    assert broken[0]
    assert np.array_equal(broken, idx == idx[0])
