"""Pallas cost kernel vs pure-jnp oracle — incl. hypothesis shape sweeps."""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (offline image); CI runs these"
)
import hypothesis.strategies as st

import jax.numpy as jnp
import numpy as np
from compile.kernels import cost_eval as ce
from compile.kernels import ref


def _random_designs(rng, n):
    return np.stack(
        [
            rng.choice([4, 64, 256, 1024, 4096, 16384, 65536], n).astype(np.float32),
            rng.choice([1, 8, 16, 32, 64, 128], n).astype(np.float32),
            rng.choice([1, 2, 4, 8], n).astype(np.float32),
            rng.choice([1, 2, 4, 8], n).astype(np.float32),
        ],
        axis=-1,
    )


def test_matches_ref_fixed_batch():
    rng = np.random.default_rng(42)
    x = jnp.asarray(_random_designs(rng, 1024))
    np.testing.assert_allclose(ce.cost_eval(x), ref.cost_ref(x), rtol=1e-5, atol=1e-5)


@hypothesis.given(
    tiles=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@hypothesis.settings(max_examples=20, deadline=None)
def test_matches_ref_across_batch_sizes(tiles, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(_random_designs(rng, tiles * ce.TILE))
    np.testing.assert_allclose(ce.cost_eval(x), ref.cost_ref(x), rtol=1e-5, atol=1e-5)


@hypothesis.given(
    depth=st.sampled_from([4.0, 256.0, 4096.0, 262144.0]),
    width=st.sampled_from([1.0, 32.0, 256.0]),
    r=st.sampled_from([1.0, 2.0, 8.0]),
    w=st.sampled_from([1.0, 2.0, 8.0]),
)
@hypothesis.settings(max_examples=40, deadline=None)
def test_extreme_corners_finite_and_exact(depth, width, r, w):
    x = jnp.asarray(np.tile([depth, width, r, w], (ce.TILE, 1)).astype(np.float32))
    got = np.asarray(ce.cost_eval(x))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, ref.cost_ref(x), rtol=1e-5, atol=1e-5)


def test_monotone_in_depth():
    cols = lambda d: [d, 32.0, 1.0, 1.0]
    rows = ([cols(256), cols(1024), cols(4096)] * 43 + [cols(256.0)] * 3)[: ce.TILE]
    x = jnp.asarray(np.array(rows, np.float32))
    out = np.asarray(ce.cost_eval(x))
    assert out[0, 0] < out[1, 0] < out[2, 0]  # area
    assert out[0, 4] < out[1, 4] < out[2, 4]  # access time


def test_rejects_non_tile_multiple():
    with pytest.raises(AssertionError):
        ce.cost_eval(jnp.zeros((100, 4), jnp.float32))


def test_port_pitch_quadratic_blowup():
    """The paper's premise: circuit-level multiport cells blow up."""
    base = jnp.asarray(np.tile([1024.0, 32.0, 1.0, 1.0], (ce.TILE, 1)).astype(np.float32))
    multi = jnp.asarray(np.tile([1024.0, 32.0, 4.0, 2.0], (ce.TILE, 1)).astype(np.float32))
    a0 = float(np.asarray(ce.cost_eval(base))[0, 0])
    a1 = float(np.asarray(ce.cost_eval(multi))[0, 0])
    assert a1 > 4.0 * a0
