"""GEMM / stencil Pallas kernels vs oracles; fft_stage vs numpy FFT math."""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (offline image); CI runs these"
)
import hypothesis.strategies as st

import jax.numpy as jnp
import numpy as np
from compile import model
from compile.kernels import ref
from compile.kernels import workloads as wk


def test_gemm_matches_ref():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((64, 64), dtype=np.float32))
    b = jnp.asarray(rng.standard_normal((64, 64), dtype=np.float32))
    np.testing.assert_allclose(wk.gemm(a, b), ref.gemm_ref(a, b), rtol=1e-4, atol=1e-4)


@hypothesis.given(
    n=st.sampled_from([32, 64, 96, 128]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@hypothesis.settings(max_examples=10, deadline=None)
def test_gemm_across_sizes(n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((n, n), dtype=np.float32))
    b = jnp.asarray(rng.standard_normal((n, n), dtype=np.float32))
    np.testing.assert_allclose(wk.gemm(a, b), ref.gemm_ref(a, b), rtol=1e-3, atol=1e-3)


def test_gemm_rectangular():
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.standard_normal((32, 96), dtype=np.float32))
    b = jnp.asarray(rng.standard_normal((96, 64), dtype=np.float32))
    np.testing.assert_allclose(wk.gemm(a, b), ref.gemm_ref(a, b), rtol=1e-3, atol=1e-3)


def test_stencil_matches_ref():
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.standard_normal((32, 32), dtype=np.float32))
    f = jnp.asarray(rng.standard_normal((3, 3), dtype=np.float32))
    np.testing.assert_allclose(
        wk.stencil2d(g, f), ref.stencil2d_ref(g, f), rtol=1e-4, atol=1e-4
    )


def test_stencil_identity_filter():
    g = jnp.asarray(np.arange(64, dtype=np.float32).reshape(8, 8))
    f = jnp.zeros((3, 3), jnp.float32).at[0, 0].set(1.0)
    out = np.asarray(wk.stencil2d(g, f))
    np.testing.assert_allclose(out[:6, :6], np.asarray(g)[:6, :6])
    assert (out[6:, :] == 0).all() and (out[:, 6:] == 0).all()


def test_fft_stage_is_a_valid_butterfly():
    """Applying the stage then undoing it recovers the input (the
    butterfly is invertible: e' = e+o, o' = (e-o)·tw)."""
    rng = np.random.default_rng(4)
    n = 512
    re = rng.standard_normal(n).astype(np.float32)
    im = rng.standard_normal(n).astype(np.float32)
    k = np.arange(n // 2)
    tw_re = np.cos(-2 * np.pi * k / n).astype(np.float32)
    tw_im = np.sin(-2 * np.pi * k / n).astype(np.float32)
    out_re, out_im = model.fft_stage(*map(jnp.asarray, (re, im, tw_re, tw_im)))
    out_re, out_im = np.asarray(out_re), np.asarray(out_im)
    # undo twiddle on the odd half (skip index 0, untouched)
    tw = tw_re + 1j * tw_im
    odd = out_re[n // 2 :] + 1j * out_im[n // 2 :]
    odd[1:] = odd[1:] / tw[1:]
    even = out_re[: n // 2] + 1j * out_im[: n // 2]
    # invert butterfly
    e = (even + odd) / 2
    o = (even - odd) / 2
    np.testing.assert_allclose(e.real, re[: n // 2], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(o.real, re[n // 2 :], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(e.imag, im[: n // 2], rtol=1e-4, atol=1e-4)
