"""AOT pipeline tests: every artifact lowers to parseable HLO text with
the expected entry signature, and validation catches corruption."""

import os
import tempfile

import numpy as np
import jax
import pytest
from compile import aot, model


def test_specs_cover_all_artifacts():
    names = [s[0] for s in aot.specs()]
    assert names == ["cost_model", "xor_recon", "gemm", "stencil2d", "fft_stage"]


@pytest.mark.parametrize("name,fn,args", aot.specs())
def test_each_spec_lowers_to_hlo_text(name, fn, args):
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text
    # tuple return (the rust loader decomposes tuples)
    assert "tuple" in text.lower()


def test_main_writes_all_files(tmp_path=None):
    out = tempfile.mkdtemp(prefix="amm_aot_test")
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", out, "--skip-validate"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    for name, _, _ in aot.specs():
        p = os.path.join(out, f"{name}.hlo.txt")
        assert os.path.exists(p), p
        assert os.path.getsize(p) > 500


def test_validate_passes_on_healthy_kernels():
    aot.validate()


def test_cost_model_batch_matches_coordinator_constant():
    # rust/src/cost/service.rs::COST_BATCH must equal aot.COST_N.
    rs = open(
        os.path.join(os.path.dirname(__file__), "..", "..", "rust", "src", "cost", "service.rs")
    ).read()
    assert f"COST_BATCH: usize = {aot.COST_N};" in rs


def test_sram_constants_match_rust_mirror():
    """The f32 constants in kernels/cost_eval.py must equal the ones in
    rust/src/sram/mod.rs — this test parses the Rust source."""
    from compile.kernels import cost_eval as ce

    rs = open(
        os.path.join(os.path.dirname(__file__), "..", "..", "rust", "src", "sram", "mod.rs")
    ).read()

    def rust_const(name):
        for line in rs.splitlines():
            line = line.strip()
            if line.startswith(f"pub const {name}: f32 ="):
                return float(line.split("=")[1].strip().rstrip(";"))
        raise AssertionError(f"constant {name} not found in rust source")

    pairs = {
        "CELL_UM2": ce.CELL_UM2,
        "PORT_PITCH": ce.PORT_PITCH,
        "PERIPH_A": ce.PERIPH_A,
        "PERIPH_B": ce.PERIPH_B,
        "E_READ_0": ce.E_READ_0,
        "E_READ_BIT": ce.E_READ_BIT,
        "WRITE_FACTOR": ce.WRITE_FACTOR,
        "LEAK_BIT": ce.LEAK_BIT,
        "LEAK_0": ce.LEAK_0,
        "T_0": ce.T_0,
        "T_DEC": ce.T_DEC,
        "T_BL": ce.T_BL,
        "T_PORT": ce.T_PORT,
    }
    for name, pyval in pairs.items():
        np.testing.assert_allclose(rust_const(name), pyval, rtol=0, atol=0, err_msg=name)
