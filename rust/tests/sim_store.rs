//! The persistent simulation-store contract, end to end.
//!
//! Core claim (ROADMAP "Cross-campaign simulation reuse"): a scheduled
//! design point is a reusable artifact. A campaign run against a store
//! holding a *subset* of its units (here: one of two benchmarks) must
//! simulate only the delta while producing a sink and fig5 CSV
//! byte-identical to a cold run, at both the scalar engine (`lanes=1`)
//! and a wide lane width (`lanes=32`); a fully warm re-run against a
//! fresh sink must simulate **zero** points. Plus: engine-version
//! quarantine on the row key, and a key-hash collision property over
//! synthetic (`synth:`) trace configs.

use amm_dse::campaign::{self, Campaign, ExecOptions};
use amm_dse::coordinator::Coordinator;
use amm_dse::dse::Sweep;
use amm_dse::sched::{CompiledTrace, ENGINE_VERSION};
use amm_dse::sim::{key_hash, Key, SimStore};
use amm_dse::suite::{self, Scale};
use amm_dse::util::propkit::{check, Config};
use amm_dse::util::rng::Rng;
use std::path::{Path, PathBuf};

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A RustFallback coordinator rooted at an empty artifacts dir.
fn coordinator(dir: &Path) -> Coordinator {
    let artifacts = dir.join("artifacts");
    let _ = std::fs::create_dir_all(&artifacts);
    Coordinator::with_artifacts(artifacts)
}

#[test]
fn half_warm_campaign_simulates_only_the_delta_and_matches_cold_bytes() {
    for lanes in [1usize, 32] {
        let dir = tmp_dir(&format!("amm_dse_sim_store_half_warm_{lanes}"));
        let store_path = dir.join("suite.sim.jsonl");
        let mut sweep = Sweep::quick();
        sweep.lanes = lanes;
        let n_points = sweep.points().len();
        assert!(n_points > 0);

        // ---- seed: a gemm-only run fills the store with HALF the
        // units the two-benchmark campaign below will probe for
        let seed_coord = coordinator(&dir);
        let seeded = Campaign::new()
            .benchmark("gemm")
            .scale(Scale::Tiny)
            .sweep(sweep.clone())
            .sim_store(&store_path)
            .run_with(&seed_coord)
            .unwrap();
        assert_eq!(seeded.simulated, n_points, "lanes={lanes}: empty store seeds cold");
        assert_eq!(seeded.memoized, 0);

        let spec_for = |sink: &Path| {
            Campaign::new()
                .benchmarks(["gemm", "fft"])
                .scale(Scale::Tiny)
                .sweep(sweep.clone())
                .sink(sink)
                .sim_store(&store_path)
                .into_spec()
        };

        // ---- cold control: the sim stack is disabled outright, so
        // every point goes through the scheduler
        let cold_sink = dir.join("cold.jsonl");
        let cold_opts = ExecOptions { sim_memo: false, ..ExecOptions::default() };
        let cold_coord = coordinator(&dir);
        let cold = campaign::run_with(&spec_for(&cold_sink), &cold_coord, &cold_opts).unwrap();
        assert_eq!(cold.simulated, 2 * n_points, "lanes={lanes}: cold control simulates all");
        assert_eq!(cold.memoized, 0);

        // ---- half-warm: gemm units hit the store, only fft simulates
        let warm_sink = dir.join("warm.jsonl");
        let warm_coord = coordinator(&dir);
        let warm = campaign::run_with(
            &spec_for(&warm_sink),
            &warm_coord,
            &ExecOptions::default(),
        )
        .unwrap();
        assert_eq!(warm.memoized, n_points, "lanes={lanes}: the seeded half memoizes");
        assert_eq!(warm.simulated, n_points, "lanes={lanes}: only the delta simulates");
        assert_eq!(warm.sim.store_hits, n_points, "fresh coordinator: hits come from disk");
        assert_eq!(warm.sim.misses, n_points);
        assert_eq!(warm.fig5_csv(), cold.fig5_csv(), "lanes={lanes}: fig5 byte-identical");
        let cold_bytes = std::fs::read(&cold_sink).unwrap();
        let warm_bytes = std::fs::read(&warm_sink).unwrap();
        assert_eq!(cold_bytes, warm_bytes, "lanes={lanes}: sinks byte-identical");

        // ---- fully warm: a fresh sink + fresh coordinator re-runs the
        // campaign without simulating a single point
        let warm2_sink = dir.join("warm2.jsonl");
        let warm2_coord = coordinator(&dir);
        let warm2 = campaign::run_with(
            &spec_for(&warm2_sink),
            &warm2_coord,
            &ExecOptions::default(),
        )
        .unwrap();
        assert_eq!(warm2.simulated, 0, "lanes={lanes}: a warm store absorbs the whole run");
        assert_eq!(warm2.memoized, 2 * n_points);
        assert_eq!(std::fs::read(&warm2_sink).unwrap(), cold_bytes);
        assert_eq!(warm2.fig5_csv(), cold.fig5_csv());

        // the store holds each unit exactly once (seed + delta; the
        // warm passes appended nothing)
        let store = SimStore::open(&store_path).unwrap();
        assert_eq!(store.len(), 2 * n_points, "lanes={lanes}: one row per unit");
        let rep = store.report();
        assert_eq!((rep.malformed, rep.duplicates, rep.conflicts), (0, 0, 0));
    }
}

#[test]
fn engine_version_quarantines_rows_from_older_kernels() {
    let dir = tmp_dir("amm_dse_sim_store_engine_ver");
    let path = dir.join("ver.sim.jsonl");
    let current = Key {
        trace_hash: 0xabad_cafe,
        nodes: 256,
        unroll: 4,
        word_bytes: 8,
        alus: 4,
        mem: "xor4r2w".into(),
        engine: ENGINE_VERSION,
    };
    let stale = Key { engine: ENGINE_VERSION - 1, ..current.clone() };
    let out = amm_dse::sched::SimOutput { cycles: 4242, ..Default::default() };
    {
        let mut store = SimStore::open(&path).unwrap();
        store.append("fp", &[(stale.clone(), out.clone())]).unwrap();
        store.append("fp", &[(current.clone(), out.clone())]).unwrap();
    }
    // a reopened store serves each engine version only its own rows
    let store = SimStore::open(&path).unwrap();
    assert_eq!(store.len(), 2);
    assert_eq!(store.get("fp", &current), Some(out.clone()));
    assert_eq!(store.get("fp", &stale), Some(out));
    let future = Key { engine: ENGINE_VERSION + 1, ..current.clone() };
    assert_eq!(store.get("fp", &future), None, "a bumped kernel must start cold");
    // and the hashes themselves never alias across versions
    assert_ne!(key_hash("fp", &current), key_hash("fp", &stale));
    assert_ne!(key_hash("fp", &current), key_hash("fp", &future));
}

#[test]
fn key_hashes_never_collide_across_synth_configs() {
    // A pool of synthetic traces with different generator dials: each
    // must compile to a distinct content hash...
    let dials = [
        "synth:stride=1,rw=0.5,reuse=64,seed=1,n=256",
        "synth:stride=4,rw=0.5,reuse=64,seed=1,n=256",
        "synth:stride=rand,rw=0.7,reuse=32,seed=2,n=256",
        "synth:stride=rand,rw=0.3,reuse=128,seed=3,n=384",
        "synth:stride=2,rw=0.9,reuse=16,seed=4,n=512",
    ];
    let traces: Vec<(u64, u64)> = dials
        .iter()
        .map(|d| {
            let wl = suite::generate(d, Scale::Tiny);
            let compiled = CompiledTrace::new(&wl.trace, 8);
            (compiled.content_hash(), wl.trace.len() as u64)
        })
        .collect();
    for (i, a) in traces.iter().enumerate() {
        for b in &traces[i + 1..] {
            assert_ne!(a.0, b.0, "synth dials must separate trace content");
        }
    }
    // ...and over the whole (trace, knobs, mem, fingerprint) domain,
    // two draws hash equal iff they ARE equal.
    let mems = ["bank1", "bank4", "xor2r1w", "xor4r2w", "lvt2r2w", "mp2x"];
    let fps = ["stub-v1", "pjrt-0123abcd"];
    type Draw = (usize, u32, u32, u32, usize, usize);
    let draw = |rng: &mut Rng| -> Draw {
        (
            rng.below_usize(traces.len()),
            *rng.pick(&[1u32, 2, 4, 8, 16]),
            *rng.pick(&[1u32, 2, 4, 8]),
            *rng.pick(&[2u32, 4, 8, 16]),
            rng.below_usize(mems.len()),
            rng.below_usize(fps.len()),
        )
    };
    let realize = |d: &Draw| -> (String, Key) {
        let (t, unroll, word_bytes, alus, m, f) = *d;
        let key = Key {
            trace_hash: traces[t].0,
            nodes: traces[t].1,
            unroll,
            word_bytes,
            alus,
            mem: mems[m].to_string(),
            engine: ENGINE_VERSION,
        };
        (fps[f].to_string(), key)
    };
    check(
        Config::default().cases(512),
        |rng| (draw(rng), draw(rng)),
        |(a, b)| {
            let (fp_a, key_a) = realize(a);
            let (fp_b, key_b) = realize(b);
            let same_input = fp_a == fp_b && key_a == key_b;
            let same_hash = key_hash(&fp_a, &key_a) == key_hash(&fp_b, &key_b);
            same_input == same_hash
        },
        |_| vec![],
    );
}
