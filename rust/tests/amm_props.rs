//! Property tests: every algorithmic multi-port scheme must be
//! indistinguishable from a flat memory with the same port count, under
//! arbitrary (conflicting) access sequences. This is the correctness
//! foundation under the paper's cost models — if the schemes didn't
//! work, their area/power numbers would be meaningless.

use amm_dse::mem::functional::{BNtxWr, HNtxRd, HbNtxRdWr, LvtAmm, MultiPortMem};
use amm_dse::util::propkit::{check, shrink_vec, Config};
use amm_dse::util::rng::Rng;

/// One cycle of a random access pattern.
#[derive(Clone, Debug)]
struct Cycle {
    reads: Vec<usize>,
    writes: Vec<(usize, u64)>,
}

/// Generate `len` cycles for a memory with r reads / w writes / cap words.
fn gen_cycles(rng: &mut Rng, len: usize, r: usize, w: usize, cap: usize) -> Vec<Cycle> {
    (0..len)
        .map(|_| Cycle {
            reads: (0..rng.below_usize(r + 1)).map(|_| rng.below_usize(cap)).collect(),
            writes: (0..rng.below_usize(w + 1))
                .map(|_| (rng.below_usize(cap), rng.next_u64() & 0xFFFF))
                .collect(),
        })
        .collect()
}

/// Reference: flat memory, read-first semantics, port-order write priority.
struct FlatMem {
    data: Vec<u64>,
}

impl FlatMem {
    fn new(cap: usize) -> Self {
        FlatMem { data: vec![0; cap] }
    }
    fn cycle(&mut self, reads: &[usize], writes: &[(usize, u64)]) -> Vec<u64> {
        let out = reads.iter().map(|&a| self.data[a]).collect();
        for &(a, v) in writes {
            self.data[a] = v;
        }
        out
    }
}

/// Drive `mem` and the flat reference with the same cycles; report the
/// first divergence, if any.
fn equivalent<M: MultiPortMem>(mut mem: M, cycles: &[Cycle]) -> bool {
    let mut flat = FlatMem::new(mem.capacity());
    for (t, c) in cycles.iter().enumerate() {
        let got = mem.cycle(&c.reads, &c.writes);
        let want = flat.cycle(&c.reads, &c.writes);
        if got != want {
            eprintln!("cycle {t}: {c:?}: got {got:?} want {want:?}");
            return false;
        }
    }
    true
}

#[test]
fn prop_hntx_rd_equals_flat_memory() {
    check(
        Config::default().cases(200),
        |rng| {
            let half = 1 << (2 + rng.below_usize(4)); // 4..32
            let cycles = gen_cycles(rng, 40, 2, 1, half * 2);
            (half, cycles)
        },
        |(half, cycles)| equivalent(HNtxRd::new(*half), cycles),
        |(half, cycles)| shrink_vec(cycles).into_iter().map(|c| (*half, c)).collect(),
    );
}

#[test]
fn prop_bntx_wr_equals_flat_memory() {
    check(
        Config::default().cases(200),
        |rng| {
            let half = 1 << (2 + rng.below_usize(4));
            let cycles = gen_cycles(rng, 40, 1, 2, half * 2);
            (half, cycles)
        },
        |(half, cycles)| equivalent(BNtxWr::new(*half), cycles),
        |(half, cycles)| shrink_vec(cycles).into_iter().map(|c| (*half, c)).collect(),
    );
}

#[test]
fn prop_lvt_equals_flat_memory() {
    check(
        Config::default().cases(150),
        |rng| {
            let cap = 8 << rng.below_usize(4);
            let r = 1 + rng.below_usize(4);
            let w = 1 + rng.below_usize(4);
            let cycles = gen_cycles(rng, 30, r, w, cap);
            (cap, r, w, cycles)
        },
        |(cap, r, w, cycles)| equivalent(LvtAmm::new(*cap, *r, *w), cycles),
        |(cap, r, w, cycles)| {
            shrink_vec(cycles).into_iter().map(|c| (*cap, *r, *w, c)).collect()
        },
    );
}

#[test]
fn prop_hbntx_equals_flat_memory_2r2w() {
    // Single-lane (w=2) configuration exercises the full generality of
    // the B-NTX write-parity protocol under any conflict pattern.
    check(
        Config::default().cases(200),
        |rng| {
            let cap = 16 << rng.below_usize(3);
            let cycles = gen_cycles(rng, 40, 2, 2, cap);
            (cap, cycles)
        },
        |(cap, cycles)| equivalent(HbNtxRdWr::new(*cap, 2, 2), cycles),
        |(cap, cycles)| shrink_vec(cycles).into_iter().map(|c| (*cap, c)).collect(),
    );
}

#[test]
fn prop_hntx_parity_invariant_holds() {
    // After ANY write sequence, Ref[i] == Bank0[i] ^ Bank1[i] — checked
    // through the public recovery path: parity read == direct read.
    check(
        Config::default().cases(200),
        |rng| {
            let writes: Vec<(usize, u64)> =
                (0..rng.below_usize(60)).map(|_| (rng.below_usize(16), rng.next_u64())).collect();
            writes
        },
        |writes| {
            let mut m = HNtxRd::new(8);
            for &w in writes.iter() {
                m.cycle(&[], &[w]);
            }
            (0..16).all(|a| m.read_direct(a) == m.read_via_parity(a))
        },
        |writes| shrink_vec(writes),
    );
}

#[test]
fn prop_lvt_write_priority_is_port_order() {
    // Same-address simultaneous writes: the highest port index wins.
    check(
        Config::default().cases(100),
        |rng| {
            let addr = rng.below_usize(16);
            let vals: Vec<u64> = (0..3).map(|_| rng.next_u64()).collect();
            (addr, vals)
        },
        |(addr, vals)| {
            let mut m = LvtAmm::new(16, 1, 3);
            let writes: Vec<(usize, u64)> = vals.iter().map(|&v| (*addr, v)).collect();
            m.cycle(&[], &writes);
            m.cycle(&[*addr], &[])[0] == vals[2]
        },
        |_| vec![],
    );
}
