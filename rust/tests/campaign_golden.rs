//! Campaign-vs-sequential golden equivalence, global cost batching, and
//! the streaming + resume contract.
//!
//! The campaign engine restructures *how* the suite × sweep
//! cross-product executes (one flat unit stream, one pool, one cost
//! batch, streaming sink) but must not change a single result bit:
//! every exploration must equal the sequential per-benchmark
//! [`Explorer`] run point-for-point, a fresh campaign's JSONL sink must
//! be byte-stable, and a killed campaign must resume to identical
//! results without re-simulating any already-scored point.

use amm_dse::campaign::{sink, Campaign};
use amm_dse::coordinator::Coordinator;
use amm_dse::dse::Sweep;
use amm_dse::suite::{self, Scale};
use amm_dse::{CampaignSpec, Explorer};

#[test]
fn campaign_matches_sequential_explorer_runs_point_for_point() {
    // All 13 benchmarks × the quick sweep, offline on both sides.
    let outcome = Campaign::new()
        .benchmarks(suite::ALL_BENCHMARKS)
        .scale(Scale::Tiny)
        .sweep(Sweep::quick())
        .offline()
        .run()
        .unwrap();
    assert_eq!(outcome.explorations().len(), suite::ALL_BENCHMARKS.len());
    assert_eq!(outcome.resumed, 0);
    assert_eq!(outcome.simulated, outcome.total_points());
    for (name, ex) in suite::ALL_BENCHMARKS.iter().zip(outcome.explorations()) {
        let seq = Explorer::new()
            .workload(*name, Scale::Tiny)
            .sweep(Sweep::quick())
            .offline()
            .run()
            .unwrap();
        assert_eq!(ex.benchmark, *name);
        assert_eq!(ex.locality.to_bits(), seq.locality.to_bits(), "{name}: locality");
        assert_eq!(ex.trace_nodes, seq.trace_nodes, "{name}");
        assert_eq!(ex.points().len(), seq.points().len(), "{name}");
        for (a, b) in ex.points().iter().zip(seq.points()) {
            assert_eq!(a.id, b.id, "{name}: enumeration order");
            assert_eq!(a.out, b.out, "{name}/{}", a.id);
        }
        // summaries (the fig-5 rows) agree too
        let (cs, ss) = (ex.summary(), seq.summary());
        assert_eq!(cs.perf_ratio, ss.perf_ratio, "{name}");
        assert_eq!(cs.best_banking_ns, ss.best_banking_ns, "{name}");
        assert_eq!(cs.best_amm_ns, ss.best_amm_ns, "{name}");
    }
}

#[test]
fn builder_and_serialized_spec_paths_produce_identical_results() {
    // The builders are thin front-ends over the spec: running the spec
    // they lower to — even after a TOML round trip — must reproduce the
    // builder path bit for bit.
    let builder = || {
        Campaign::new()
            .benchmarks(["gemm", "stencil2d"])
            .locality_only("kmp")
            .scale(Scale::Tiny)
            .sweep(Sweep::quick())
    };
    let via_builder = builder().offline().run().unwrap();
    let spec = builder().into_spec();
    let reparsed = CampaignSpec::parse(&spec.to_toml()).unwrap();
    assert_eq!(reparsed, spec);
    let via_spec = reparsed.run_offline().unwrap();
    assert_eq!(via_builder.explorations().len(), via_spec.explorations().len());
    for (a, b) in via_builder.explorations().iter().zip(via_spec.explorations()) {
        assert_eq!(a.benchmark, b.benchmark);
        assert_eq!(a.locality.to_bits(), b.locality.to_bits(), "{}", a.benchmark);
        for (x, y) in a.points().iter().zip(b.points()) {
            assert_eq!(x, y, "{}/{}", a.benchmark, x.id);
        }
    }
}

#[test]
fn campaign_issues_one_deduplicated_cost_batch_for_the_whole_suite() {
    let tmp = std::env::temp_dir().join("amm_dse_campaign_batch");
    let _ = std::fs::create_dir_all(&tmp);
    let coord = Coordinator::with_artifacts(tmp);
    let benches = ["gemm", "fft", "stencil2d", "kmp"];
    let outcome = Campaign::new()
        .benchmarks(benches)
        .scale(Scale::Tiny)
        .sweep(Sweep::quick())
        .run_with(&coord)
        .unwrap();
    assert_eq!(coord.batches_issued(), 1, "whole campaign must score in ONE batch");
    assert_eq!(outcome.cost_batches, 1);
    assert!(outcome.cost.misses > 0);
    assert!(outcome.backend.is_some());
    // and the globally-batched costs reproduce the per-benchmark
    // coordinator path exactly (same queries, same service)
    for (name, ex) in benches.iter().zip(outcome.explorations()) {
        let seq = Explorer::new()
            .workload(*name, Scale::Tiny)
            .sweep(Sweep::quick())
            .run_with(&coord)
            .unwrap();
        assert_eq!(ex.points().len(), seq.points().len(), "{name}");
        for (a, b) in ex.points().iter().zip(seq.points()) {
            assert_eq!(a.id, b.id, "{name}");
            assert_eq!(a.out, b.out, "{name}/{}", a.id);
        }
    }
    // the sequential comparison runs re-dispatched only units the
    // campaign already simulated: the coordinator's sim memo answered
    // every one of them (so nothing was even re-scored), and the
    // backend batch count never moved
    assert_eq!(
        coord.batches_issued(),
        1,
        "memo-warm re-scoring must not reach the runtime backend"
    );
    assert!(coord.sim_counters().hits() > 0, "re-runs answer from the sim memo");
    assert_eq!(coord.sim_counters().misses, outcome.simulated);
}

#[test]
fn campaign_sink_streams_byte_stable_and_resumes_without_resimulating() {
    let dir = std::env::temp_dir().join("amm_dse_campaign_resume");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let benches = ["gemm", "stencil2d", "fft"];
    let campaign = |sink_path: &std::path::Path| {
        Campaign::new()
            .benchmarks(benches)
            .scale(Scale::Tiny)
            .sweep(Sweep::quick())
            .threads(4)
            .offline()
            .sink(sink_path)
    };

    // ---- fresh run: every point lands in the sink, in enumeration
    // order, despite the multi-threaded work-stealing pool ------------
    let sink_a = dir.join("a.jsonl");
    let full = campaign(&sink_a).run().unwrap();
    assert_eq!(full.resumed, 0);
    assert_eq!(full.simulated, full.total_points());
    let text = std::fs::read_to_string(&sink_a).unwrap();
    assert_eq!(text.lines().count(), full.total_points());
    let (records, torn) = sink::load(&sink_a).unwrap();
    assert_eq!(records.len(), full.total_points());
    assert!(!torn);
    let flat: Vec<&amm_dse::dse::DesignPoint> =
        full.explorations().iter().flat_map(|e| e.points()).collect();
    for ((rec_bench, rec_scale, rec), p) in records.iter().zip(&flat) {
        assert_eq!(*rec_scale, Scale::Tiny);
        assert_eq!(rec.id, p.id, "sink order must be enumeration order");
        assert_eq!(rec.out, p.out, "{rec_bench}/{}", rec.id);
    }

    // ---- byte stability: an identical fresh run writes the identical
    // file (ordered maps + reorder-buffer writer) ---------------------
    let sink_b = dir.join("b.jsonl");
    let _ = campaign(&sink_b).run().unwrap();
    assert_eq!(
        std::fs::read_to_string(&sink_b).unwrap(),
        text,
        "fresh campaign JSONL must be byte-stable"
    );

    // ---- kill + resume: keep the first k lines plus a torn fragment,
    // as a mid-write kill would leave them ----------------------------
    let k = full.total_points() / 2;
    let prefix: String = text.lines().take(k).map(|l| format!("{l}\n")).collect();
    let torn_line = &text.lines().nth(k).unwrap()[..24];
    let sink_c = dir.join("c.jsonl");
    std::fs::write(&sink_c, format!("{prefix}{torn_line}")).unwrap();
    let resumed = campaign(&sink_c).run().unwrap();
    assert_eq!(resumed.resumed, k, "every intact line must be restored");
    assert_eq!(
        resumed.simulated,
        full.total_points() - k,
        "a resumed campaign re-simulates only the missing points"
    );
    assert_eq!(resumed.cost_batches, 0, "offline campaigns never batch");
    // results identical to the uninterrupted run, bit for bit
    for (a, b) in full.explorations().iter().zip(resumed.explorations()) {
        assert_eq!(a.benchmark, b.benchmark);
        assert_eq!(a.points().len(), b.points().len());
        for (x, y) in a.points().iter().zip(b.points()) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.out, y.out, "{}/{}", a.benchmark, x.id);
        }
    }
    // the repaired sink now parses to exactly one record per point
    // (the torn fragment was newline-terminated and is skipped)
    let (records, torn) = sink::load(&sink_c).unwrap();
    assert!(!torn);
    assert_eq!(records.len(), full.total_points());

    // ---- a fully-scored sink resumes everything and simulates nothing
    let complete = campaign(&sink_a).run().unwrap();
    assert_eq!(complete.simulated, 0, "complete sink ⇒ zero re-simulation");
    assert_eq!(complete.resumed, full.total_points());
    assert_eq!(complete.restored(), complete.resumed, "restored() is the resume count");
    assert_eq!(
        complete.points_per_s, 0.0,
        "points_per_s counts fresh simulation only; a warm resume reports zero"
    );
    for (a, b) in full.explorations().iter().zip(complete.explorations()) {
        for (x, y) in a.points().iter().zip(b.points()) {
            assert_eq!(x.out, y.out, "{}/{}", a.benchmark, x.id);
        }
    }
}

#[test]
fn lane_batched_campaign_sink_is_byte_identical_to_sequential() {
    // The lane-batched simulate stage must not change a single sink
    // byte: a campaign forced onto the scalar engine (lanes = 1) and
    // one running the batch kernel at full width (lanes = 32) must
    // write identical JSONL and produce identical results, point for
    // point.
    let dir = std::env::temp_dir().join("amm_dse_campaign_lanes");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let run = |lanes: usize, sink_path: &std::path::Path| {
        let mut sweep = Sweep::quick();
        sweep.lanes = lanes;
        Campaign::new()
            .benchmarks(["gemm", "stencil2d", "fft"])
            .scale(Scale::Tiny)
            .sweep(sweep)
            .threads(4)
            .offline()
            .sink(sink_path)
            .run()
            .unwrap()
    };
    let scalar_sink = dir.join("scalar.jsonl");
    let batched_sink = dir.join("batched.jsonl");
    let scalar = run(1, &scalar_sink);
    let batched = run(32, &batched_sink);
    assert_eq!(scalar.simulated, batched.simulated);
    assert!(batched.points_per_s > 0.0, "fresh campaigns report sustained throughput");
    assert_eq!(
        std::fs::read_to_string(&scalar_sink).unwrap(),
        std::fs::read_to_string(&batched_sink).unwrap(),
        "lane-batched campaign sink must be byte-identical to the scalar one"
    );
    for (a, b) in scalar.explorations().iter().zip(batched.explorations()) {
        for (x, y) in a.points().iter().zip(b.points()) {
            assert_eq!(x, y, "{}/{}", a.benchmark, x.id);
        }
    }
}

#[test]
fn coordinator_backed_campaign_resumes_identically() {
    // Resume is backend-agnostic at the record level: a sink written by
    // one run is trusted verbatim by the next. Here both runs use the
    // RustFallback-scored coordinator path, interrupted after 5 points.
    let dir = std::env::temp_dir().join("amm_dse_campaign_resume_coord");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let tmp = dir.join("artifacts");
    let _ = std::fs::create_dir_all(&tmp);
    let coord = Coordinator::with_artifacts(tmp);
    let sink_path = dir.join("coord.jsonl");
    let full = Campaign::new()
        .benchmarks(["gemm", "kmp"])
        .scale(Scale::Tiny)
        .sweep(Sweep::quick())
        .sink(&sink_path)
        .run_with(&coord)
        .unwrap();
    let text = std::fs::read_to_string(&sink_path).unwrap();
    let keep: String = text.lines().take(5).map(|l| format!("{l}\n")).collect();
    std::fs::write(&sink_path, keep).unwrap();
    let resumed = Campaign::new()
        .benchmarks(["gemm", "kmp"])
        .scale(Scale::Tiny)
        .sweep(Sweep::quick())
        .sink(&sink_path)
        .run_with(&coord)
        .unwrap();
    assert_eq!(resumed.resumed, 5);
    // the pending points need no re-simulation either: the shared
    // coordinator's sim memo (and the `<sink>.sim.jsonl` store the
    // first run flushed) already hold every scheduled unit, so they
    // skip the scheduler — and with zero fresh units there is nothing
    // to score, so the backend batch count never moves
    assert_eq!(resumed.simulated, 0, "warmed resume re-simulates nothing");
    assert_eq!(resumed.memoized, full.total_points() - 5);
    assert!(resumed.sim.hits() == resumed.memoized);
    assert_eq!(resumed.cost_batches, 0, "warmed resume must issue zero cost batches");
    for (a, b) in full.explorations().iter().zip(resumed.explorations()) {
        for (x, y) in a.points().iter().zip(b.points()) {
            assert_eq!(x.out, y.out, "{}/{}", a.benchmark, x.id);
        }
    }
}
