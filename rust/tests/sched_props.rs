//! Property tests on the scheduler and DSE invariants: port-capacity
//! compliance, dependence safety, monotonicity, and Pareto/ratio laws.

use amm_dse::dse::{self, Sweep};
use amm_dse::mem::MemKind;
use amm_dse::sched::{self, BatchArena, CompiledTrace, DesignConfig, Knobs, SimArena};
use amm_dse::suite::{self, Scale};
use amm_dse::trace::{AluKind, Trace, TraceBuilder};
use amm_dse::util::propkit::{check, Config};
use amm_dse::util::rng::Rng;

/// A random but valid traced program: interleaved loads/stores/alus over
/// a couple of arrays with random (true) value dependences.
fn random_trace(rng: &mut Rng, n_ops: usize) -> Trace {
    let mut b = TraceBuilder::new();
    let a0 = b.array("a0", 4, 64);
    let a1 = b.array("a1", 8, 32);
    let mut produced: Vec<u32> = Vec::new();
    for i in 0..n_ops {
        if i % 7 == 0 {
            b.next_iter();
        }
        b.site((i % 5) as u32);
        let pick_deps = |rng: &mut Rng, produced: &[u32]| -> Vec<u32> {
            if produced.is_empty() {
                return vec![];
            }
            (0..rng.below_usize(3)).map(|_| produced[rng.below_usize(produced.len())]).collect()
        };
        match rng.below(4) {
            0 => {
                let id = b.load(a0, rng.below(64) as u32);
                produced.push(id);
            }
            1 => {
                let id = b.load(a1, rng.below(32) as u32);
                produced.push(id);
            }
            2 => {
                let deps = pick_deps(rng, &produced);
                let id = b.alu(AluKind::FAdd, &deps);
                produced.push(id);
            }
            _ => {
                let deps = pick_deps(rng, &produced);
                b.store(a0, rng.below(64) as u32, &deps);
            }
        }
    }
    b.finish()
}

#[test]
fn prop_random_traces_validate_and_schedule() {
    check(
        Config::default().cases(60),
        |rng| {
            let n = 20 + rng.below_usize(200);
            let seed = rng.next_u64();
            (n, seed)
        },
        |(n, seed)| {
            let mut rng = Rng::new(*seed);
            let t = random_trace(&mut rng, *n);
            if t.validate().is_err() {
                return false;
            }
            let out = sched::simulate(&t, &DesignConfig::baseline());
            // every mem op issued exactly once, cycles bounded below by
            // both the critical path and the port bound
            out.mem_accesses == t.mem_ops() as u64
                && out.cycles >= (t.mem_ops() as u64) // 1 shared port
                && out.cycles as u64 >= t.critical_path_len() as u64 / 20
        },
        |_| vec![],
    );
}

#[test]
fn prop_cycles_lower_bounded_by_port_capacity() {
    // cycles >= mem_ops / total_ports for ANY true-port design.
    check(
        Config::default().cases(40),
        |rng| {
            let seed = rng.next_u64();
            let r = 1 << rng.below_usize(3);
            let w = 1 << rng.below_usize(2);
            (seed, r, w)
        },
        |(seed, r, w)| {
            let mut rng = Rng::new(*seed);
            let t = random_trace(&mut rng, 150);
            let cfg = DesignConfig {
                mem: MemKind::XorAmm { read_ports: *r, write_ports: *w },
                unroll: 64,
                word_bytes: 8,
                alus: 64,
            };
            let out = sched::simulate(&t, &cfg);
            let bound = (t.mem_ops() as u64).div_ceil((*r + *w) as u64);
            out.cycles >= bound
        },
        |_| vec![],
    );
}

#[test]
fn prop_batch_bit_identical_to_scalar_on_random_lane_mixes() {
    // The lane-batched kernel's contract, fuzzed: random traces ×
    // random lane mixes (1–32 lanes drawn from all four port-model
    // families with random port counts, the full v2 width) × random
    // knobs must equal the scalar oracle lane-for-lane, `SimOutput`
    // bit-for-bit. The batch arena is reused dirty across the two knob
    // sets within a case.
    check(
        Config::default().cases(40),
        |rng| rng.next_u64(),
        |seed| {
            let mut rng = Rng::new(*seed);
            let t = random_trace(&mut rng, 40 + rng.below_usize(120));
            if t.validate().is_err() {
                return false;
            }
            let knobs_of = |rng: &mut Rng| Knobs {
                unroll: 1u32 << rng.below(4),
                word_bytes: 1u32 << rng.below(4),
                alus: 1 + rng.below(8) as u32,
            };
            let knob_sets = [knobs_of(&mut rng), knobs_of(&mut rng)];
            let mut batch = BatchArena::new();
            let mut arena = SimArena::new();
            for knobs in &knob_sets {
                let designs: Vec<_> = (0..1 + rng.below_usize(32))
                    .map(|_| {
                        let kind = match rng.below(4) {
                            0 => MemKind::Banked { banks: 1u32 << rng.below(3) },
                            1 => MemKind::XorAmm {
                                read_ports: 1u32 << rng.below(3),
                                write_ports: 1u32 << rng.below(2),
                            },
                            2 => MemKind::LvtAmm {
                                read_ports: 1u32 << rng.below(3),
                                write_ports: 1u32 << rng.below(2),
                            },
                            _ => MemKind::MultiPump { factor: 2u32 << rng.below(2) },
                        };
                        sched::build_memory_model(&t, &*kind.model(), knobs.word_bytes)
                    })
                    .collect();
                let ct = CompiledTrace::new(&t, knobs.word_bytes);
                let lanes = ct.simulate_batch(&mut batch, knobs, &designs);
                let ok = lanes
                    .iter()
                    .zip(&designs)
                    .all(|(lane, d)| *lane == ct.simulate(&mut arena, knobs, d));
                if !ok {
                    return false;
                }
            }
            true
        },
        |_| vec![],
    );
}

#[test]
fn batch_matches_scalar_on_degenerate_traces() {
    // Zero-mem-op and single-node traces exercise the v2 kernel's empty
    // paths: lanes that never queue a memory completion (the ring-occ
    // mask stays 0) and lanes that finish on their first visit (the
    // event wheel drains immediately).
    let mut pure_alu = TraceBuilder::new();
    let mut prev: Vec<u32> = Vec::new();
    for _ in 0..10 {
        let id = pure_alu.alu(AluKind::FAdd, &prev);
        prev = vec![id];
    }
    let pure_alu = pure_alu.finish();
    let mut single = TraceBuilder::new();
    single.alu(AluKind::FAdd, &[]);
    let single = single.finish();
    let knobs = Knobs { unroll: 1, word_bytes: 8, alus: 2 };
    let mut batch = BatchArena::new();
    let mut arena = SimArena::new();
    for t in [&pure_alu, &single] {
        t.validate().unwrap();
        let designs: Vec<_> = [1u32, 2, 4, 8]
            .iter()
            .map(|&b| {
                let kind = MemKind::Banked { banks: b };
                sched::build_memory_model(t, &*kind.model(), knobs.word_bytes)
            })
            .collect();
        let ct = CompiledTrace::new(t, knobs.word_bytes);
        let lanes = ct.simulate_batch(&mut batch, &knobs, &designs);
        for (lane, d) in lanes.iter().zip(&designs) {
            assert_eq!(*lane, ct.simulate(&mut arena, &knobs, d));
        }
    }
}

#[test]
fn prop_readyq_pop_order_matches_binary_heap_under_tie_storms() {
    // The ReadyQ bucket queue must be order-equivalent to a plain
    // BinaryHeap over (cycle, node-id) even when whole bursts of pushes
    // land on one cycle: the batch kernel relies on this to keep every
    // lane bit-identical to the scalar engine.
    check(
        Config::default().cases(60),
        |rng| rng.next_u64(),
        |seed| {
            let (q, h) = sched::readyq_heap_pop_orders(*seed, 40);
            q == h
        },
        |_| vec![],
    );
}

#[test]
fn prop_unroll_monotone_nonincreasing_cycles() {
    // Greedy list scheduling admits small Graham-style anomalies (more
    // parallelism can occasionally delay a critical chain by a few
    // cycles), so the property allows a 10% + 4-cycle slack while still
    // catching any systematic inversion.
    check(
        Config::default().cases(30),
        |rng| rng.next_u64(),
        |seed| {
            let mut rng = Rng::new(*seed);
            let t = random_trace(&mut rng, 120);
            let mut prev = u64::MAX;
            for u in [1u32, 2, 4, 8, 16] {
                let cfg = DesignConfig {
                    mem: MemKind::LvtAmm { read_ports: 4, write_ports: 2 },
                    unroll: u,
                    word_bytes: 8,
                    alus: 8,
                };
                let c = sched::simulate(&t, &cfg).cycles;
                if prev != u64::MAX && c > prev + prev / 10 + 4 {
                    eprintln!("unroll {u}: {c} >> {prev}");
                    return false;
                }
                prev = c.min(prev);
            }
            true
        },
        |_| vec![],
    );
}

#[test]
fn prop_pareto_front_minimal_and_complete() {
    check(
        Config::default().cases(10),
        |rng| rng.next_u64(),
        |seed| {
            let mut rng = Rng::new(*seed);
            let t = random_trace(&mut rng, 150);
            let points = Sweep::quick().run(&t);
            let front = dse::pareto_front(&points, |p| p.time_ns(), |p| p.area());
            // minimality
            for (k, &i) in front.iter().enumerate() {
                for &j in &front[k + 1..] {
                    let a = &points[i];
                    let b = &points[j];
                    if a.time_ns() <= b.time_ns() && a.area() <= b.area() {
                        return false;
                    }
                }
            }
            // completeness
            points.iter().enumerate().all(|(i, p)| {
                front.contains(&i)
                    || front
                        .iter()
                        .any(|&f| points[f].time_ns() <= p.time_ns() && points[f].area() <= p.area())
            })
        },
        |_| vec![],
    );
}

#[test]
fn prop_banked_never_faster_than_true_ports_same_count() {
    // A true-R+W-port memory dominates a banked design whose per-bank
    // ports sum to the same count, for the same trace/unroll/alus.
    check(
        Config::default().cases(30),
        |rng| rng.next_u64(),
        |seed| {
            let mut rng = Rng::new(*seed);
            let t = random_trace(&mut rng, 120);
            let banked = DesignConfig {
                mem: MemKind::Banked { banks: 4 },
                unroll: 8,
                word_bytes: 8,
                alus: 8,
            };
            // the AMM must offer at least as many ports of each type as
            // the banked design can ever use in one cycle (4 banks ⇒ ≤4
            // reads and ≤4 writes) for domination to be guaranteed.
            let amm = DesignConfig { mem: MemKind::LvtAmm { read_ports: 4, write_ports: 4 }, ..banked };
            sched::simulate(&t, &amm).cycles <= sched::simulate(&t, &banked).cycles
        },
        |_| vec![],
    );
}

#[test]
fn prop_benchmark_checksums_stable() {
    // Workload generation is deterministic: same name+scale → same trace
    // shape and checksum (the DSE depends on this for reproducibility).
    for name in suite::ALL_BENCHMARKS {
        let a = suite::generate(name, Scale::Tiny);
        let b = suite::generate(name, Scale::Tiny);
        assert_eq!(a.checksum, b.checksum, "{name}");
        assert_eq!(a.trace.len(), b.trace.len(), "{name}");
    }
}
