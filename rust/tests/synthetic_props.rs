//! Property tests for the `suite::synthetic` locality-dial generator.
//!
//! The contracts: every dial combination yields a trace that passes
//! `Trace::validate()`; generation is bit-identical for identical
//! `(params, seed, scale)` and diverges across seeds; and each dial
//! moves the *measured* Weinberg locality metric monotonically in its
//! designed direction — the property that makes the locality-sweep
//! figure's x-axis trustworthy.

use amm_dse::locality;
use amm_dse::suite::{self, synthetic, Scale};
use amm_dse::trace::{OpKind, Trace};

/// Structural digest of a trace: every node (kind, site, iter) and the
/// full CSR successor structure folded FNV-style into one u64. Two
/// traces with equal digests are the same DDG for the scheduler.
fn digest(t: &Trace) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |x: u64| h = (h ^ x).wrapping_mul(0x1_0000_0000_01b3);
    for n in &t.nodes {
        let kind = match n.kind {
            OpKind::Load { array, index } => 1u64 << 40 | (array as u64) << 32 | index as u64,
            OpKind::Store { array, index } => 2u64 << 40 | (array as u64) << 32 | index as u64,
            OpKind::Alu(k) => 3u64 << 40 | k.index() as u64,
        };
        mix(kind);
        mix((n.site as u64) << 32 | n.iter as u64);
    }
    for &o in &t.succ_off {
        mix(o as u64);
    }
    for &s in &t.succ {
        mix(s as u64);
    }
    h
}

fn spatial(name: &str) -> f64 {
    locality::analyze(&suite::generate(name, Scale::Tiny).trace).spatial_locality()
}

#[test]
fn every_dial_combination_validates() {
    // A grid over the generator's regimes: each axis at its extremes
    // plus the defaults, including the awkward corners (all-writes,
    // all-random, saturated conflict pressure, minimum window).
    let names = [
        "synth:",
        "synth:stride=unit,rw=1,reuse=32,n=256",
        "synth:stride=unit,rw=0,reuse=32,n=256",
        "synth:stride=rand,mix=1,conflict=1,seed=42,n=256",
        "synth:stride=s4096,reuse=1024,n=256",
        "synth:stride=s3,mix=0.5,rw=0.3,reuse=100,conflict=0.5,seed=5,n=500",
    ];
    for name in names {
        let wl = suite::generate(name, Scale::Tiny);
        wl.trace.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(wl.checksum.is_finite(), "{name}");
        assert!(wl.trace.mem_ops() > 0, "{name}");
        let p = synthetic::parse(name).unwrap();
        assert_eq!(wl.trace.len() as u64, p.node_count(Scale::Tiny), "{name}");
    }
}

#[test]
fn identical_params_are_bit_identical_across_generations() {
    let name = "synth:stride=rand,mix=0.3,rw=0.6,reuse=128,conflict=0.2,seed=77";
    let a = suite::generate(name, Scale::Tiny);
    let b = suite::generate(name, Scale::Tiny);
    assert_eq!(a.checksum, b.checksum);
    assert_eq!(digest(&a.trace), digest(&b.trace), "same (params, seed, scale) must be bit-identical");
    // dial order in the name must not matter either
    let c = suite::generate(
        "synth:seed=77,conflict=0.2,reuse=128,rw=0.6,mix=0.3,stride=rand",
        Scale::Tiny,
    );
    assert_eq!(a.checksum, c.checksum);
    assert_eq!(digest(&a.trace), digest(&c.trace), "dial order is not part of the identity");
}

#[test]
fn different_seeds_differ_and_scales_nest() {
    let a = suite::generate("synth:stride=rand,seed=1", Scale::Tiny);
    let b = suite::generate("synth:stride=rand,seed=2", Scale::Tiny);
    assert_ne!(a.checksum, b.checksum, "seeds must give different streams");
    assert_ne!(digest(&a.trace), digest(&b.trace));
    // scale moves only the access count, not the validity
    let p = suite::generate("synth:stride=rand,seed=1", Scale::Paper);
    p.trace.validate().unwrap();
    assert!(a.trace.len() < p.trace.len());
}

#[test]
fn stride_dial_moves_locality_down_through_the_ladder() {
    let unit = spatial("synth:stride=unit,seed=7");
    let s4 = spatial("synth:stride=s4,seed=7");
    let s16 = spatial("synth:stride=s16,seed=7");
    let rand = spatial("synth:stride=rand,seed=7");
    assert!(
        unit > s4 && s4 > s16 && s16 > rand,
        "stride ladder must descend: unit={unit:.4} s4={s4:.4} s16={s16:.4} rand={rand:.4}"
    );
    assert!(unit > 0.15, "unit-stride 4-byte stream should be high-locality: {unit:.4}");
    assert!(rand < 0.05, "random stream should be low-locality: {rand:.4}");
}

#[test]
fn mix_dial_moves_locality_down() {
    let m0 = spatial("synth:stride=unit,mix=0,seed=7");
    let m4 = spatial("synth:stride=unit,mix=0.4,seed=7");
    let m9 = spatial("synth:stride=unit,mix=0.9,seed=7");
    assert!(
        m0 > m4 && m4 > m9,
        "mix must degrade locality monotonically: {m0:.4} > {m4:.4} > {m9:.4}"
    );
}

#[test]
fn conflict_dial_moves_locality_down() {
    let c0 = spatial("synth:stride=unit,conflict=0,seed=7");
    let c5 = spatial("synth:stride=unit,conflict=0.5,seed=7");
    let c9 = spatial("synth:stride=unit,conflict=0.9,seed=7");
    assert!(
        c0 > c5 && c5 > c9,
        "conflict pressure must degrade locality monotonically: {c0:.4} > {c5:.4} > {c9:.4}"
    );
}

#[test]
fn reuse_dial_moves_locality_up() {
    // Pure deterministic stream (no RNG draws at mix=0, conflict=0,
    // stride=unit): a larger window wraps less often, so fewer
    // non-forward transitions and strictly higher measured locality.
    let r64 = spatial("synth:stride=unit,reuse=64,seed=7");
    let r256 = spatial("synth:stride=unit,reuse=256,seed=7");
    let r1024 = spatial("synth:stride=unit,reuse=1024,seed=7");
    assert!(
        r64 < r256 && r256 < r1024,
        "reuse window must raise locality monotonically: {r64:.6} < {r256:.6} < {r1024:.6}"
    );
}

#[test]
fn rw_dial_moves_the_read_fraction_not_the_address_stream() {
    // Reads and writes share one address stream, so `rw` is not a
    // locality dial; its monotone effect is the read fraction of the
    // trace's memory ops, exact under the Bresenham interleave.
    let mut fractions = Vec::new();
    for rw in ["0.2", "0.5", "0.8"] {
        let wl = suite::generate(&format!("synth:rw={rw},n=1000,seed=7"), Scale::Tiny);
        let loads = wl
            .trace
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, OpKind::Load { .. }))
            .count();
        assert_eq!(wl.trace.mem_ops(), 1000, "one mem op per access");
        fractions.push(loads as f64 / 1000.0);
    }
    assert!(
        fractions[0] < fractions[1] && fractions[1] < fractions[2],
        "read fraction must follow the rw dial: {fractions:?}"
    );
    // and exactly: rw=0.5 over 1000 accesses = 500 writes
    assert!((fractions[1] - 0.5).abs() < 1e-9, "{fractions:?}");
}

#[test]
fn unknown_and_malformed_names_error_with_the_dial_listing() {
    // The CLI bugfix contract, at the library gate all front-ends use.
    let e = suite::validate_name("synth:stride=spiral").unwrap_err().to_string();
    assert!(e.contains("known dials"), "{e}");
    let e = suite::validate_name("sinth:stride=unit").unwrap_err().to_string();
    assert!(e.contains("synth:"), "a typo'd prefix should advertise the namespace: {e}");
    assert!(e.contains("known dials"), "{e}");
    suite::validate_name("synth:stride=unit").unwrap();
}
