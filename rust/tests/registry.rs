//! The trait-based memory-model API, tested from outside the crate:
//!
//! 1. property: every registered model round-trips `id()` ↔ registry
//!    parse under arbitrary parameters;
//! 2. golden: the `Explorer` facade reproduces the free-function path's
//!    cycle counts exactly;
//! 3. extensibility (the API's acceptance criterion): a brand-new
//!    memory organization defined *in this test* — no edits to `sched`,
//!    `dse`, `config` or `coordinator` — registers, parses, sweeps,
//!    schedules and lands in CSV output like any built-in.

use amm_dse::dse::Sweep;
use amm_dse::mem::{self, MemDesign, MemModel, ModelEntry, PortModel};
use amm_dse::sched::Knobs;
use amm_dse::suite::{self, Scale};
use amm_dse::util::propkit::{check, Config};
use amm_dse::Explorer;

// ---------------------------------------------------------------------
// 1. registry round-trip property
// ---------------------------------------------------------------------

#[test]
fn prop_builtin_models_round_trip_through_registry() {
    check(
        Config::default().cases(300),
        |rng| {
            let banks = 1 + rng.below(64) as u32;
            let factor = 2 + rng.below(3) as u32;
            let r = 1 + rng.below(8) as u32;
            let w = 1 + rng.below(8) as u32;
            let kind = match rng.below(8) {
                0 => mem::MemKind::Banked { banks },
                1 => mem::MemKind::BankedDualPort { banks },
                2 => mem::MemKind::BankedBlock { banks },
                3 => mem::MemKind::MultiPump { factor },
                4 => mem::MemKind::LvtAmm { read_ports: r, write_ports: w },
                5 => mem::MemKind::XorAmm { read_ports: r, write_ports: w },
                6 => mem::MemKind::XorFlat { read_ports: r, write_ports: w },
                _ => mem::MemKind::CircuitMp { read_ports: r, write_ports: w },
            };
            kind.model().id()
        },
        |id| {
            // parse(id).id() == id, and parse agrees with the model on
            // classification + port semantics
            match mem::parse_model(id) {
                None => false,
                Some(m) => {
                    m.id() == *id
                        && mem::parse_model(&m.id()).map(|m2| m2.is_amm()) == Some(m.is_amm())
                        && mem::parse_model(&m.id()).map(|m2| m2.port_model())
                            == Some(m.port_model())
                }
            }
        },
        |_| vec![],
    );
}

#[test]
fn prop_built_designs_describe_their_model() {
    // For arbitrary geometry, build() must label the design with the
    // model's own id/is_amm and advertised port model.
    check(
        Config::default().cases(120),
        |rng| {
            let ids = [
                "banked4", "banked2p2", "bankedblk4", "pump2", "lvt2r2w", "xor2r2w",
                "xorflat2r2w", "cmp2r1w",
            ];
            let id = ids[rng.below(ids.len() as u64) as usize];
            let depth = 4 + rng.below(65536) as u32;
            let width = 8u32 << (rng.below(4) as u32);
            (id.to_string(), depth, width)
        },
        |(id, depth, width)| {
            let m = mem::parse_model(id).unwrap();
            let d = m.build(*depth, *width);
            d.id == m.id()
                && d.is_amm == m.is_amm()
                && d.ports == m.port_model()
                && d.area_um2() > 0.0
                && d.t_access_ns() > 0.0
        },
        |_| vec![],
    );
}

// ---------------------------------------------------------------------
// 2. golden: facade == free functions
// ---------------------------------------------------------------------

#[test]
fn explorer_reproduces_free_function_cycle_counts() {
    let wl = suite::generate("gemm", Scale::Tiny);
    let sweep = Sweep::quick();
    let direct = sweep.run(&wl.trace);

    // coordinator-backed facade (pure-Rust cost backend: no artifacts in
    // the test cwd) and offline facade must both match exactly
    for ex in [
        Explorer::new().workload("gemm", Scale::Tiny).sweep(sweep.clone()).run().unwrap(),
        Explorer::new().workload("gemm", Scale::Tiny).sweep(sweep.clone()).offline().run().unwrap(),
    ] {
        assert_eq!(ex.points().len(), direct.len());
        for (a, b) in ex.points().iter().zip(&direct) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.out.cycles, b.out.cycles, "{}", a.id);
            let rel = (a.out.area_um2 - b.out.area_um2).abs() / b.out.area_um2;
            assert!(rel < 1e-5, "{}: {} vs {}", a.id, a.out.area_um2, b.out.area_um2);
        }
    }
}

// ---------------------------------------------------------------------
// 3. extensibility: a new model, defined here, runs end to end
// ---------------------------------------------------------------------

/// A hypothetical organization the crate has never heard of: an
/// `N`-copy replicated-read memory (every read port gets a private
/// full-depth copy; the single write updates all copies). This is the
/// kind of scheme PAPERS.md's coding-based designs would add.
#[derive(Clone, Copy, Debug)]
struct ReplicatedRead {
    copies: u32,
}

impl MemModel for ReplicatedRead {
    fn id(&self) -> String {
        format!("repl{}r", self.copies)
    }
    fn describe(&self) -> String {
        format!("{}-copy replicated-read memory (test extension)", self.copies)
    }
    fn is_amm(&self) -> bool {
        true
    }
    fn port_model(&self) -> PortModel {
        PortModel::TruePorts { reads: self.copies.max(1), writes: 1 }
    }
    fn build(&self, depth: u32, width: u32) -> MemDesign {
        let copies = self.copies.max(1);
        // Compose via an existing design, then override the metadata —
        // an extension only needs public mem/ APIs.
        let mut d = mem::MemKind::Banked { banks: 1 }.build(depth, width);
        let one = d.sram;
        d.id = self.id();
        d.is_amm = true;
        d.ports = self.port_model();
        d.macros = copies;
        d.sram.area_um2 = one.area_um2 * copies as f32;
        d.sram.leak_uw = one.leak_uw * copies as f32;
        d.sram.e_write_pj = one.e_write_pj * copies as f32;
        d.write_energy_scale = copies as f32;
        d
    }
    fn boxed_clone(&self) -> Box<dyn MemModel> {
        Box::new(*self)
    }
}

fn parse_repl(s: &str) -> Option<Box<dyn MemModel>> {
    let copies = s.strip_prefix("repl")?.strip_suffix('r')?.parse().ok()?;
    Some(Box::new(ReplicatedRead { copies }))
}

#[test]
fn registered_extension_model_explores_end_to_end() {
    mem::register_model(ModelEntry {
        prefix: "repl",
        synopsis: "replicated-read memory (test extension)",
        example: "repl4r",
        parse: parse_repl,
    });

    // parses through the registry…
    let m = mem::parse_model("repl4r").expect("extension must parse");
    assert_eq!(m.id(), "repl4r");
    assert!(m.is_amm());

    // …schedules like any built-in…
    let wl = suite::generate("gemm", Scale::Tiny);
    let knobs = Knobs { unroll: 8, word_bytes: 8, alus: 8 };
    let point = amm_dse::dse::evaluate_model(&wl.trace, &*m, &knobs);
    assert_eq!(point.mem_id, "repl4r");
    assert!(point.is_amm);
    assert!(point.out.cycles > 0);
    // 4 read ports must beat the single-ported baseline on cycles
    let base = amm_dse::dse::evaluate_model(
        &wl.trace,
        &*mem::parse_model("banked1").unwrap(),
        &knobs,
    );
    assert!(point.out.cycles < base.out.cycles, "{} !< {}", point.out.cycles, base.out.cycles);

    // …and sweeps through the Explorer facade + coordinator cost batch
    // + CSV report, with zero edits outside mem/ (or this test).
    let ex = Explorer::new()
        .workload("gemm", Scale::Tiny)
        .sweep(Sweep::quick())
        .model("repl4r")
        .run()
        .unwrap();
    let repl_points: Vec<_> = ex.points().iter().filter(|p| p.mem_id == "repl4r").collect();
    assert_eq!(repl_points.len(), Sweep::quick().unrolls.len());
    assert!(ex.to_csv().contains("repl4r"), "extension must land in the CSV report");
}
