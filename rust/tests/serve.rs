//! End-to-end loopback test of the serve daemon.
//!
//! One daemon, one real TCP socket, raw `std::net` clients: a tiny
//! gemm spec is POSTed as TOML, polled to completion, tailed
//! incrementally, and its `/query/pareto` CSV must equal the offline
//! sequential [`Explorer`] path byte for byte (valid because the
//! daemon's coordinator is rooted at an empty artifacts dir, i.e. the
//! RustFallback backend, which is pinned bit-identical to direct
//! evaluation). A warm re-submission of the same spec must report
//! zero backend batches through the shared cost store.
//!
//! HTTP/1.1 parser unit tests (torn reads, bad methods, oversized
//! bodies, keep-alive) live next to the parser in `serve::http`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use amm_dse::campaign::Campaign;
use amm_dse::dse::Sweep;
use amm_dse::report;
use amm_dse::serve::{ServeOptions, Server};
use amm_dse::suite::Scale;
use amm_dse::Explorer;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("amm_dse_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// One raw `Connection: close` HTTP exchange; returns (status,
/// headers, body).
fn exchange(addr: SocketAddr, request: &[u8]) -> (u16, Vec<(String, String)>, Vec<u8>) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    stream.write_all(request).unwrap();
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).unwrap();
    let head_end = raw.windows(4).position(|w| w == b"\r\n\r\n").expect("response head");
    let head = std::str::from_utf8(&raw[..head_end]).unwrap();
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap();
    let status: u16 = status_line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let headers: Vec<(String, String)> = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(n, v)| (n.trim().to_ascii_lowercase(), v.trim().to_string()))
        .collect();
    (status, headers, raw[head_end + 4..].to_vec())
}

fn get(addr: SocketAddr, path: &str) -> (u16, Vec<(String, String)>, String) {
    let req = format!("GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
    let (status, headers, body) = exchange(addr, req.as_bytes());
    (status, headers, String::from_utf8(body).unwrap())
}

fn post(addr: SocketAddr, path: &str, body: &str) -> (u16, String) {
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    let (status, _, resp) = exchange(addr, req.as_bytes());
    (status, String::from_utf8(resp).unwrap())
}

fn header<'a>(headers: &'a [(String, String)], name: &str) -> &'a str {
    headers
        .iter()
        .find(|(n, _)| n == name)
        .map(|(_, v)| v.as_str())
        .unwrap_or_else(|| panic!("missing header {name}"))
}

/// Pull `"field":"value"` out of a flat JSON body.
fn json_str(body: &str, field: &str) -> String {
    let tag = format!("\"{field}\":\"");
    let at = body.find(&tag).unwrap_or_else(|| panic!("no {field} in {body}"));
    let rest = &body[at + tag.len()..];
    rest[..rest.find('"').unwrap()].to_string()
}

fn poll_done(addr: SocketAddr, id: &str) -> String {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (status, _, body) = get(addr, &format!("/campaigns/{id}"));
        assert_eq!(status, 200, "{body}");
        if body.contains("\"state\":\"done\"") {
            return body;
        }
        assert!(
            !body.contains("\"state\":\"failed\"") && !body.contains("\"state\":\"cancelled\""),
            "job {id} did not complete: {body}"
        );
        assert!(Instant::now() < deadline, "job {id} timed out: {body}");
        std::thread::sleep(Duration::from_millis(25));
    }
}

#[test]
fn daemon_runs_submitted_specs_and_serves_results_and_pareto_queries() {
    let dir = tmp("serve_e2e");
    // empty artifacts dir → RustFallback backend (bit-identical to the
    // offline path), regardless of what the host env has installed
    let artifacts = dir.join("artifacts");
    std::fs::create_dir_all(&artifacts).unwrap();
    let opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        data_dir: dir.join("data"),
        artifacts: Some(artifacts),
        status_history: 8,
    };
    let server = Server::bind(&opts).unwrap();
    let addr = server.addr();
    let daemon = std::thread::spawn(move || server.run());

    let (status, _, body) = get(addr, "/healthz");
    assert_eq!(status, 200);
    assert!(body.contains("\"schema\":\"serve/v1\"") && body.contains("\"ok\":true"), "{body}");
    assert!(body.contains("\"workers\":2"), "{body}");

    // bad inputs first: they must not wedge the daemon
    let (status, body) = post(addr, "/campaigns", "benchmark = ");
    assert_eq!(status, 400, "{body}");
    let (status, _, _) = get(addr, "/no/such/endpoint");
    assert_eq!(status, 404);
    let req = b"DELETE /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n";
    let (status, _, _) = exchange(addr, req);
    assert_eq!(status, 405);
    let (status, _, _) = get(addr, "/campaigns/c9999");
    assert_eq!(status, 404);

    // submit the tiny spec exactly as a remote host would: TOML text
    let spec_toml = Campaign::new()
        .benchmarks(["gemm"])
        .scale(Scale::Tiny)
        .sweep(Sweep::quick())
        .into_spec()
        .to_toml();
    let (status, body) = post(addr, "/campaigns", &spec_toml);
    assert_eq!(status, 202, "{body}");
    let id = json_str(&body, "id");
    assert_eq!(id, "c0001");

    let done = poll_done(addr, &id);
    assert!(done.contains("\"points\":"), "{done}");

    // status: the raw campaign-status/v1 sidecar, served verbatim
    let (status, _, body) = get(addr, &format!("/campaigns/{id}/status"));
    assert_eq!(status, 200);
    assert!(body.contains("campaign-status/v1") && body.contains("\"complete\":true"), "{body}");

    // the throttled history ring arrived and is valid JSONL
    let (status, _, hist) = get(addr, &format!("/campaigns/{id}/status?history=1"));
    assert_eq!(status, 200);
    assert!(!hist.is_empty(), "history ring is empty");
    assert!(hist.lines().all(|l| l.contains("campaign-status/v1")), "{hist}");

    // incremental tail: after=0 yields everything, then resume from
    // the X-After cursor like a fleet poller would
    let (status, headers, all) = get(addr, &format!("/campaigns/{id}/results?after=0"));
    assert_eq!(status, 200);
    let total: usize = header(&headers, "x-after").parse().unwrap();
    assert_eq!(all.lines().count(), total);
    assert!(total > 0 && all.lines().all(|l| l.contains("campaign/v1")), "{all}");
    let (_, headers, tail) = get(addr, &format!("/campaigns/{id}/results?after={}", total - 1));
    assert_eq!(tail.lines().count(), 1);
    assert_eq!(header(&headers, "x-after"), total.to_string());
    let (_, _, empty) = get(addr, &format!("/campaigns/{id}/results?after={total}"));
    assert!(empty.is_empty());

    // the HTTP Pareto answer == the offline sequential Explorer, byte
    // for byte
    let (status, _, served) = get(addr, "/query/pareto?benchmark=gemm&scale=tiny");
    assert_eq!(status, 200, "{served}");
    let seq = Explorer::new()
        .workload("gemm", Scale::Tiny)
        .sweep(Sweep::quick())
        .offline()
        .run()
        .unwrap();
    assert_eq!(served, report::pareto_csv(seq.points()));
    let (status, _, _) = get(addr, "/query/pareto?benchmark=nosuch");
    assert_eq!(status, 404);

    // warm re-submission: same spec, shared store → zero backend
    // batches (the cross-campaign warm-start contract, over HTTP)
    let (status, body) = post(addr, "/campaigns", &spec_toml);
    assert_eq!(status, 202, "{body}");
    let id2 = json_str(&body, "id");
    let done2 = poll_done(addr, &id2);
    assert!(done2.contains("\"cost_batches\":0"), "warm job hit the backend: {done2}");

    let (status, _, body) = get(addr, "/cost-store/stat");
    assert_eq!(status, 200);
    assert!(body.contains("\"schema\":\"serve/v1\"") && body.contains("\"rows\":"), "{body}");
    assert!(!body.contains("\"rows\":0,"), "shared store stayed empty: {body}");

    // cancelling a finished job is a conflict, not a state change
    let req = format!("DELETE /campaigns/{id} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
    let (status, _, _) = exchange(addr, req.as_bytes());
    assert_eq!(status, 409);

    // the job list shows both runs
    let (_, _, list) = get(addr, "/campaigns");
    assert!(list.contains("c0001") && list.contains(&id2), "{list}");

    let (status, body) = post(addr, "/shutdown", "");
    assert_eq!(status, 200);
    assert!(body.contains("\"stopping\":true"), "{body}");
    daemon.join().unwrap().unwrap();

    // the data dir holds everything a cold restart needs
    let data = dir.join("data");
    assert!(data.join("cost-store.jsonl").exists());
    assert!(data.join("campaigns/c0001/spec.toml").exists());
    assert!(data.join("campaigns/c0001/results.jsonl").exists());
}

#[test]
fn daemon_recovers_registered_jobs_after_restart() {
    let dir = tmp("serve_restart");
    let artifacts = dir.join("artifacts");
    std::fs::create_dir_all(&artifacts).unwrap();
    let opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: 1,
        data_dir: dir.join("data"),
        artifacts: Some(artifacts),
        status_history: 0,
    };

    let first = Server::bind(&opts).unwrap();
    let addr = first.addr();
    let daemon = std::thread::spawn(move || first.run());
    let spec_toml = Campaign::new()
        .benchmarks(["kmp"])
        .scale(Scale::Tiny)
        .sweep(Sweep::quick())
        .into_spec()
        .to_toml();
    let (status, body) = post(addr, "/campaigns", &spec_toml);
    assert_eq!(status, 202, "{body}");
    let id = json_str(&body, "id");
    poll_done(addr, &id);
    let (status, _) = post(addr, "/shutdown", "");
    assert_eq!(status, 200);
    daemon.join().unwrap().unwrap();

    // a fresh daemon over the same data dir re-registers the job and
    // keeps numbering past it; history=0 → no ring file was written
    let second = Server::bind(&opts).unwrap();
    let addr = second.addr();
    let daemon = std::thread::spawn(move || second.run());
    let (status, _, body) = get(addr, &format!("/campaigns/{id}"));
    assert_eq!(status, 200);
    assert!(body.contains("\"state\":\"done\""), "{body}");
    let (status, _, hist) = get(addr, &format!("/campaigns/{id}/status?history=1"));
    assert_eq!(status, 200);
    assert!(hist.is_empty(), "unexpected ring with history=0: {hist}");
    let (status, body) = post(addr, "/campaigns", &spec_toml);
    assert_eq!(status, 202, "{body}");
    assert_eq!(json_str(&body, "id"), "c0002");
    poll_done(addr, "c0002");
    let (_, body) = post(addr, "/shutdown", "");
    assert!(body.contains("stopping"));
    daemon.join().unwrap().unwrap();
}
