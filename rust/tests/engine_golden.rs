//! Engine-vs-compat golden equivalence.
//!
//! The sweep paths of the engine (`CompiledTrace` shared across a word
//! group, `SimArena` reused dirty across runs, grouped parallel
//! dispatch) must reproduce the compat `simulate_design` wrapper's
//! `SimOutput` **bit-for-bit** — cycles, stalls, energies, areas — on
//! every suite benchmark across the paper's design families.
//!
//! Scope note: `simulate_design` is itself a thin wrapper over the same
//! engine (compile + fresh arena per call), so what these tests pin is
//! that *state reuse and grouping* never change a result — not that the
//! engine matches the pre-refactor scheduler. Fidelity to the seed
//! scheduler's behavior is pinned separately by the fixture unit tests
//! in `sched` (exact cycle counts for serial chains, port
//! serialization, banking conflicts, unroll gating, multipumping) and
//! the `sched_props`/`end_to_end` invariants, all of which now execute
//! through this engine.

use amm_dse::dse::{self, Sweep};
use amm_dse::mem::MemKind;
use amm_dse::sched::{self, CompiledTrace, Knobs, SimArena};
use amm_dse::suite::{self, Scale};

/// One design per port-model family the scheduler distinguishes:
/// banked (per-bank, shared 1RW), XOR AMM + LVT AMM (true ports),
/// multipump (true ports + frequency penalty).
fn design_families() -> Vec<MemKind> {
    vec![
        MemKind::Banked { banks: 4 },
        MemKind::XorAmm { read_ports: 4, write_ports: 2 },
        MemKind::LvtAmm { read_ports: 2, write_ports: 2 },
        MemKind::MultiPump { factor: 2 },
    ]
}

#[test]
fn engine_matches_compat_on_all_suite_benchmarks() {
    let knob_sets = [
        Knobs { unroll: 4, word_bytes: 8, alus: 4 },
        Knobs { unroll: 8, word_bytes: 1, alus: 8 },
    ];
    // One arena shared (and dirtied) across every benchmark × design ×
    // knob combination — the harshest reuse pattern.
    let mut arena = SimArena::new();
    for name in suite::ALL_BENCHMARKS {
        let wl = suite::generate(name, Scale::Tiny);
        for kind in design_families() {
            for knobs in &knob_sets {
                let design =
                    sched::build_memory_model(&wl.trace, &*kind.model(), knobs.word_bytes);
                let compat = sched::simulate_design(&wl.trace, knobs, &design);
                let engine =
                    CompiledTrace::new(&wl.trace, knobs.word_bytes).simulate(&mut arena, knobs, &design);
                assert_eq!(engine, compat, "{name}/{} {knobs:?}", design.id);
            }
        }
    }
}

#[test]
fn dirty_arena_resets_cleanly_between_different_traces() {
    // gemm and kmp differ in node count, array count and op mix; ping-
    // ponging one arena between them must reproduce fresh-arena outputs
    // exactly, every round.
    let gemm = suite::generate("gemm", Scale::Tiny);
    let kmp = suite::generate("kmp", Scale::Tiny);
    let knobs = Knobs::default();
    let kind = MemKind::XorAmm { read_ports: 2, write_ports: 2 };
    let d_gemm = sched::build_memory_model(&gemm.trace, &*kind.model(), knobs.word_bytes);
    let d_kmp = sched::build_memory_model(&kmp.trace, &*kind.model(), knobs.word_bytes);
    let fresh_gemm = CompiledTrace::new(&gemm.trace, knobs.word_bytes)
        .simulate(&mut SimArena::new(), &knobs, &d_gemm);
    let fresh_kmp = CompiledTrace::new(&kmp.trace, knobs.word_bytes)
        .simulate(&mut SimArena::new(), &knobs, &d_kmp);
    let mut arena = SimArena::new();
    for round in 0..3 {
        let g = CompiledTrace::new(&gemm.trace, knobs.word_bytes)
            .simulate(&mut arena, &knobs, &d_gemm);
        assert_eq!(g, fresh_gemm, "gemm round {round}");
        let k = CompiledTrace::new(&kmp.trace, knobs.word_bytes)
            .simulate(&mut arena, &knobs, &d_kmp);
        assert_eq!(k, fresh_kmp, "kmp round {round}");
    }
}

#[test]
fn grouped_sweep_engine_matches_compat_per_point() {
    // The full stack: Sweep::run (word-grouped CompiledTrace + per-
    // worker arenas) vs the per-point compat path, multi word size so
    // grouping actually kicks in, multi-threaded so arena reuse crosses
    // work-stealing boundaries.
    for name in ["gemm", "stencil2d"] {
        let wl = suite::generate(name, Scale::Tiny);
        let mut sweep = Sweep::quick();
        sweep.word_bytes = vec![1, 4, 8];
        sweep.threads = 4;
        let run = sweep.run(&wl.trace);
        let points = sweep.points();
        assert_eq!(run.len(), points.len(), "{name}");
        for (a, p) in run.iter().zip(&points) {
            let b = dse::evaluate_model(&wl.trace, &*p.model, &p.knobs);
            assert_eq!(a.id, b.id, "{name}: enumeration order must be preserved");
            assert_eq!(a.out, b.out, "{name}/{}", a.id);
        }
    }
}
