//! Engine-vs-compat golden equivalence.
//!
//! The sweep paths of the engine (`CompiledTrace` shared across a word
//! group, `SimArena` reused dirty across runs, grouped parallel
//! dispatch) must reproduce the compat `simulate_design` wrapper's
//! `SimOutput` **bit-for-bit** — cycles, stalls, energies, areas — on
//! every suite benchmark across the paper's design families. The
//! lane-batched kernel (`simulate_batch`) carries the same contract
//! against the scalar engine: every lane of a batch — mixed port
//! models, dirty `BatchArena` reuse, L=1 through wider-than-auto
//! groups — must equal the scalar `SimOutput` bit-for-bit.
//!
//! Scope note: `simulate_design` is itself a thin wrapper over the same
//! engine (compile + fresh arena per call), so what these tests pin is
//! that *state reuse and grouping* never change a result — not that the
//! engine matches the pre-refactor scheduler. Fidelity to the seed
//! scheduler's behavior is pinned separately by the fixture unit tests
//! in `sched` (exact cycle counts for serial chains, port
//! serialization, banking conflicts, unroll gating, multipumping) and
//! the `sched_props`/`end_to_end` invariants, all of which now execute
//! through this engine.

use amm_dse::dse::{self, Sweep};
use amm_dse::mem::MemKind;
use amm_dse::sched::{self, BatchArena, CompiledTrace, Knobs, SimArena};
use amm_dse::suite::{self, Scale};

/// One design per port-model family the scheduler distinguishes:
/// banked (per-bank, shared 1RW), XOR AMM + LVT AMM (true ports),
/// multipump (true ports + frequency penalty).
fn design_families() -> Vec<MemKind> {
    vec![
        MemKind::Banked { banks: 4 },
        MemKind::XorAmm { read_ports: 4, write_ports: 2 },
        MemKind::LvtAmm { read_ports: 2, write_ports: 2 },
        MemKind::MultiPump { factor: 2 },
    ]
}

#[test]
fn engine_matches_compat_on_all_suite_benchmarks() {
    let knob_sets = [
        Knobs { unroll: 4, word_bytes: 8, alus: 4 },
        Knobs { unroll: 8, word_bytes: 1, alus: 8 },
    ];
    // One arena shared (and dirtied) across every benchmark × design ×
    // knob combination — the harshest reuse pattern.
    let mut arena = SimArena::new();
    for name in suite::ALL_BENCHMARKS {
        let wl = suite::generate(name, Scale::Tiny);
        for kind in design_families() {
            for knobs in &knob_sets {
                let design =
                    sched::build_memory_model(&wl.trace, &*kind.model(), knobs.word_bytes);
                let compat = sched::simulate_design(&wl.trace, knobs, &design);
                let engine =
                    CompiledTrace::new(&wl.trace, knobs.word_bytes).simulate(&mut arena, knobs, &design);
                assert_eq!(engine, compat, "{name}/{} {knobs:?}", design.id);
            }
        }
    }
}

#[test]
fn dirty_arena_resets_cleanly_between_different_traces() {
    // gemm and kmp differ in node count, array count and op mix; ping-
    // ponging one arena between them must reproduce fresh-arena outputs
    // exactly, every round.
    let gemm = suite::generate("gemm", Scale::Tiny);
    let kmp = suite::generate("kmp", Scale::Tiny);
    let knobs = Knobs::default();
    let kind = MemKind::XorAmm { read_ports: 2, write_ports: 2 };
    let d_gemm = sched::build_memory_model(&gemm.trace, &*kind.model(), knobs.word_bytes);
    let d_kmp = sched::build_memory_model(&kmp.trace, &*kind.model(), knobs.word_bytes);
    let fresh_gemm = CompiledTrace::new(&gemm.trace, knobs.word_bytes)
        .simulate(&mut SimArena::new(), &knobs, &d_gemm);
    let fresh_kmp = CompiledTrace::new(&kmp.trace, knobs.word_bytes)
        .simulate(&mut SimArena::new(), &knobs, &d_kmp);
    let mut arena = SimArena::new();
    for round in 0..3 {
        let g = CompiledTrace::new(&gemm.trace, knobs.word_bytes)
            .simulate(&mut arena, &knobs, &d_gemm);
        assert_eq!(g, fresh_gemm, "gemm round {round}");
        let k = CompiledTrace::new(&kmp.trace, knobs.word_bytes)
            .simulate(&mut arena, &knobs, &d_kmp);
        assert_eq!(k, fresh_kmp, "kmp round {round}");
    }
}

#[test]
fn batch_matches_scalar_on_all_suite_benchmarks() {
    // The lane-batched kernel's bit-identity contract: a mixed-model
    // lane group (one lane per port-model family — banked, XOR, LVT,
    // multipump — all scored in a SINGLE `simulate_batch` pass) must
    // reproduce the scalar oracle's `SimOutput` exactly on every suite
    // benchmark. One `BatchArena` shared (and dirtied) across every
    // benchmark × knob combination, plus an L=1 singleton group per
    // combination so the narrowest lane count is pinned too.
    let knob_sets = [
        Knobs { unroll: 4, word_bytes: 8, alus: 4 },
        Knobs { unroll: 8, word_bytes: 1, alus: 8 },
    ];
    let mut arena = SimArena::new();
    let mut batch = BatchArena::new();
    for name in suite::ALL_BENCHMARKS {
        let wl = suite::generate(name, Scale::Tiny);
        for knobs in &knob_sets {
            let ct = CompiledTrace::new(&wl.trace, knobs.word_bytes);
            let designs: Vec<_> = design_families()
                .into_iter()
                .map(|k| sched::build_memory_model(&wl.trace, &*k.model(), knobs.word_bytes))
                .collect();
            let lanes = ct.simulate_batch(&mut batch, knobs, &designs);
            assert_eq!(lanes.len(), designs.len(), "{name} {knobs:?}");
            for (lane, design) in lanes.iter().zip(&designs) {
                let scalar = ct.simulate(&mut arena, knobs, design);
                assert_eq!(*lane, scalar, "{name}/{} {knobs:?}", design.id);
            }
            let solo = ct.simulate_batch(&mut batch, knobs, &designs[..1]);
            assert_eq!(solo[0], ct.simulate(&mut arena, knobs, &designs[0]), "{name} L=1");
        }
    }
}

#[test]
fn batch_matches_scalar_on_synthetic_configurations() {
    // The synthetic namespace rides the same bit-identity contract as
    // MachSuite: dial configurations spanning the generator's regimes —
    // streaming, bank-conflict-saturated, random, write-heavy — scored
    // by a mixed-model lane group in one `simulate_batch` pass must
    // equal the scalar oracle lane-for-lane, dirty arenas throughout.
    let synth_names = [
        "synth:stride=unit,conflict=0,seed=7",
        "synth:stride=unit,conflict=0.9,seed=7",
        "synth:stride=rand,rw=0.4,reuse=64,seed=3",
        "synth:stride=s16,mix=0.3,rw=0.2,seed=11,n=1024",
    ];
    let knob_sets = [
        Knobs { unroll: 4, word_bytes: 4, alus: 4 },
        Knobs { unroll: 8, word_bytes: 8, alus: 8 },
    ];
    let mut arena = SimArena::new();
    let mut batch = BatchArena::new();
    for name in synth_names {
        let wl = suite::generate(name, Scale::Tiny);
        wl.trace.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        for knobs in &knob_sets {
            let ct = CompiledTrace::new(&wl.trace, knobs.word_bytes);
            let designs: Vec<_> = design_families()
                .into_iter()
                .map(|k| sched::build_memory_model(&wl.trace, &*k.model(), knobs.word_bytes))
                .collect();
            let lanes = ct.simulate_batch(&mut batch, knobs, &designs);
            for (lane, design) in lanes.iter().zip(&designs) {
                let scalar = ct.simulate(&mut arena, knobs, design);
                assert_eq!(*lane, scalar, "{name}/{} {knobs:?}", design.id);
            }
        }
    }
}

#[test]
fn conflict_dial_stalls_banked_not_true_ports() {
    // The causal mechanism behind the locality curve, pinned at the
    // engine level: ramping the conflict dial (64-element-aligned jumps
    // that all land in one bank) must strictly increase port stalls on
    // a banked design while a true-port AMM of the same width stays
    // conflict-immune by construction.
    let knobs = Knobs { unroll: 4, word_bytes: 4, alus: 4 };
    let mut arena = SimArena::new();
    let mut banked_stalls = Vec::new();
    let mut amm_stalls = Vec::new();
    for conflict in ["0", "0.5", "0.9"] {
        let name = format!("synth:stride=unit,conflict={conflict},seed=7,n=2048");
        let wl = suite::generate(&name, Scale::Tiny);
        let ct = CompiledTrace::new(&wl.trace, knobs.word_bytes);
        let banked = sched::build_memory_model(
            &wl.trace,
            &*MemKind::Banked { banks: 8 }.model(),
            knobs.word_bytes,
        );
        let amm = sched::build_memory_model(
            &wl.trace,
            &*MemKind::XorAmm { read_ports: 4, write_ports: 2 }.model(),
            knobs.word_bytes,
        );
        banked_stalls.push(ct.simulate(&mut arena, &knobs, &banked).port_stalls);
        amm_stalls.push(ct.simulate(&mut arena, &knobs, &amm).port_stalls);
    }
    assert!(
        banked_stalls[0] < banked_stalls[1] && banked_stalls[1] < banked_stalls[2],
        "banked stalls must ramp with the conflict dial: {banked_stalls:?}"
    );
    // The AMM issues by port count alone, never by address, so the dial
    // must not open a stall gap on the true-port side the way it does on
    // the banked side.
    let banked_ramp = banked_stalls[2] - banked_stalls[0];
    let amm_ramp = amm_stalls[2].saturating_sub(amm_stalls[0]);
    assert!(
        amm_ramp * 10 < banked_ramp.max(10),
        "true ports must not inherit bank conflicts: amm {amm_stalls:?} vs banked {banked_stalls:?}"
    );
}

#[test]
fn dirty_batch_arena_resets_cleanly_between_different_traces() {
    // gemm and kmp differ in node count, array count and op mix; ping-
    // ponging one `BatchArena` between them must reproduce fresh-arena
    // lane outputs exactly, every round.
    let gemm = suite::generate("gemm", Scale::Tiny);
    let kmp = suite::generate("kmp", Scale::Tiny);
    let knobs = Knobs::default();
    let d_gemm: Vec<_> = design_families()
        .into_iter()
        .map(|k| sched::build_memory_model(&gemm.trace, &*k.model(), knobs.word_bytes))
        .collect();
    let d_kmp: Vec<_> = design_families()
        .into_iter()
        .map(|k| sched::build_memory_model(&kmp.trace, &*k.model(), knobs.word_bytes))
        .collect();
    let ct_gemm = CompiledTrace::new(&gemm.trace, knobs.word_bytes);
    let ct_kmp = CompiledTrace::new(&kmp.trace, knobs.word_bytes);
    let fresh_gemm = ct_gemm.simulate_batch(&mut BatchArena::new(), &knobs, &d_gemm);
    let fresh_kmp = ct_kmp.simulate_batch(&mut BatchArena::new(), &knobs, &d_kmp);
    let mut arena = BatchArena::new();
    for round in 0..3 {
        let g = ct_gemm.simulate_batch(&mut arena, &knobs, &d_gemm);
        assert_eq!(g, fresh_gemm, "gemm round {round}");
        let k = ct_kmp.simulate_batch(&mut arena, &knobs, &d_kmp);
        assert_eq!(k, fresh_kmp, "kmp round {round}");
    }
}

#[test]
fn batch_matches_scalar_at_32_lanes_on_all_suite_benchmarks() {
    // The v2 kernel's acceptance width: a full 32-wide lane group
    // mixing every port-model family (banked/block/dual-port, XOR and
    // LVT and flat AMMs, multipump, circuit multiport) in one
    // `simulate_batch` pass must equal the scalar oracle lane-for-lane
    // on every suite benchmark, with one dirty `BatchArena` throughout.
    let mut kinds: Vec<MemKind> = Vec::new();
    for b in [1u32, 2, 4, 8, 16, 32] {
        kinds.push(MemKind::Banked { banks: b });
    }
    for b in [2u32, 4, 8, 16] {
        kinds.push(MemKind::BankedBlock { banks: b });
    }
    for b in [2u32, 4] {
        kinds.push(MemKind::BankedDualPort { banks: b });
    }
    for f in [2u32, 4] {
        kinds.push(MemKind::MultiPump { factor: f });
    }
    for (r, w) in [(2u32, 1u32), (2, 2), (4, 2), (4, 4), (8, 4), (8, 8)] {
        kinds.push(MemKind::XorAmm { read_ports: r, write_ports: w });
        kinds.push(MemKind::LvtAmm { read_ports: r, write_ports: w });
    }
    for (r, w) in [(2u32, 1u32), (2, 2), (4, 2), (4, 4)] {
        kinds.push(MemKind::XorFlat { read_ports: r, write_ports: w });
    }
    for (r, w) in [(4u32, 2u32), (8, 4)] {
        kinds.push(MemKind::CircuitMp { read_ports: r, write_ports: w });
    }
    assert_eq!(kinds.len(), 32);
    let knobs = Knobs { unroll: 4, word_bytes: 8, alus: 4 };
    let mut batch = BatchArena::new();
    let mut arena = SimArena::new();
    for name in suite::ALL_BENCHMARKS {
        let wl = suite::generate(name, Scale::Tiny);
        let ct = CompiledTrace::new(&wl.trace, knobs.word_bytes);
        let designs: Vec<_> = kinds
            .iter()
            .map(|k| sched::build_memory_model(&wl.trace, &*k.model(), knobs.word_bytes))
            .collect();
        let lanes = ct.simulate_batch(&mut batch, &knobs, &designs);
        for (lane, design) in lanes.iter().zip(&designs) {
            assert_eq!(*lane, ct.simulate(&mut arena, &knobs, design), "{name}/{}", design.id);
        }
    }
}

#[test]
fn batch_handles_max_width_lane_groups() {
    // L = every model the default sweep enumerates — wider than the
    // auto lane count the dispatcher would ever form — all sharing one
    // trace pass; each lane must still match the oracle.
    let wl = suite::generate("stencil2d", Scale::Tiny);
    let knobs = Knobs { unroll: 4, word_bytes: 4, alus: 4 };
    let ct = CompiledTrace::new(&wl.trace, knobs.word_bytes);
    let designs: Vec<_> = Sweep::default()
        .models()
        .into_iter()
        .map(|m| sched::build_memory_model(&wl.trace, &*m, knobs.word_bytes))
        .collect();
    assert!(designs.len() > 8, "expected a wide lane group, got {}", designs.len());
    let lanes = ct.simulate_batch(&mut BatchArena::new(), &knobs, &designs);
    let mut arena = SimArena::new();
    for (lane, design) in lanes.iter().zip(&designs) {
        assert_eq!(*lane, ct.simulate(&mut arena, &knobs, design), "{}", design.id);
    }
}

#[test]
fn grouped_sweep_engine_matches_compat_per_point() {
    // The full stack: Sweep::run (word-grouped CompiledTrace + per-
    // worker arenas) vs the per-point compat path, multi word size so
    // grouping actually kicks in, multi-threaded so arena reuse crosses
    // work-stealing boundaries.
    for name in ["gemm", "stencil2d"] {
        let wl = suite::generate(name, Scale::Tiny);
        let mut sweep = Sweep::quick();
        sweep.word_bytes = vec![1, 4, 8];
        sweep.threads = 4;
        let run = sweep.run(&wl.trace);
        let points = sweep.points();
        assert_eq!(run.len(), points.len(), "{name}");
        for (a, p) in run.iter().zip(&points) {
            let b = dse::evaluate_model(&wl.trace, &*p.model, &p.knobs);
            assert_eq!(a.id, b.id, "{name}: enumeration order must be preserved");
            assert_eq!(a.out, b.out, "{name}/{}", a.id);
        }
    }
}
