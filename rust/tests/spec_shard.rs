//! The declarative-spec contract: TOML round-trip, exact shard
//! partitioning, merge-equivalence, spec-vs-builder lowering, and the
//! scale-keyed resume rule.

use amm_dse::campaign::{merge, sink, Campaign};
use amm_dse::dse::Sweep;
use amm_dse::spec::{self, shard_of, CampaignSpec, Shard, ShardStrategy};
use amm_dse::suite::Scale;
use std::collections::HashSet;
use std::path::PathBuf;

/// A small canonical spec exercising every serialized field.
fn sample_spec() -> CampaignSpec {
    let mut sweep = Sweep::quick();
    sweep.extra_models = vec!["cmp2r2w".into()];
    sweep.threads = 2;
    let mut spec = CampaignSpec::new()
        .benchmark("gemm")
        .benchmark("fft")
        .locality_only("kmp")
        .with_shard(0, 2)
        .with_shard_strategy(ShardStrategy::Weighted)
        .with_cost_store("results/suite.cost.jsonl")
        .with_sim_store("results/suite.sim.jsonl");
    spec.scale = Scale::Tiny;
    spec.sweep = sweep;
    spec.sink = Some(PathBuf::from("results/suite.jsonl"));
    spec.threads = 4;
    spec
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn spec_round_trips_through_toml_byte_for_byte() {
    let spec = sample_spec();
    let toml1 = spec.to_toml();
    assert!(
        toml1.contains(&format!("schema = \"{}\"\n", spec::SCHEMA)),
        "canonical documents carry the schema tag: {toml1}"
    );
    let parsed = CampaignSpec::parse(&toml1).expect("canonical TOML must parse");
    assert_eq!(parsed, spec, "TOML -> spec must reproduce every field");
    let toml2 = parsed.to_toml();
    assert_eq!(toml1, toml2, "spec -> TOML must be canonical (byte-stable)");

    // defaults are restored when omitted: a minimal (untagged = v1)
    // document fills in the default sweep, no sink/store, hash shards
    let minimal = CampaignSpec::parse("[campaign]\nbenchmarks = [\"gemm\"]\n").unwrap();
    assert_eq!(minimal.sweep, Sweep::default());
    assert_eq!(minimal.scale, Scale::Paper);
    assert!(minimal.sink.is_none() && minimal.shard.is_none());
    assert!(minimal.cost_store.is_none());
    assert!(minimal.sim_store.is_none());
    assert_eq!(minimal.shard_strategy, ShardStrategy::Hash);
    assert_eq!(minimal.threads, 0);
    // and a default-heavy spec also round-trips
    let toml3 = minimal.to_toml();
    assert_eq!(CampaignSpec::parse(&toml3).unwrap(), minimal);

    // spec evolution: an unknown schema version is rejected up front,
    // not silently mis-read
    let future = toml1.replace(spec::SCHEMA, "campaign-spec/v2");
    assert_ne!(future, toml1);
    let err = CampaignSpec::parse(&future).unwrap_err();
    assert!(err.to_string().contains("campaign-spec/v2"), "{err}");
}

#[test]
fn config_files_and_builders_lower_to_the_same_spec() {
    // the single-benchmark config form is a one-entry plan
    let rc = amm_dse::config::parse("benchmark = \"gemm\"\nscale = \"tiny\"\n").unwrap();
    let built = Campaign::new().benchmark("gemm").scale(Scale::Tiny).into_spec();
    assert_eq!(rc.campaign, built);
    // and the spec's own serialization closes the loop
    assert_eq!(CampaignSpec::parse(&built.to_toml()).unwrap(), built);
}

#[test]
fn shards_partition_the_planned_unit_stream_exactly() {
    let mut spec = CampaignSpec::new().benchmark("gemm").benchmark("fft").benchmark("kmp");
    spec.scale = Scale::Tiny;
    spec.sweep = Sweep::quick();
    let keys = spec.plan_keys();
    assert!(!keys.is_empty());
    let all: HashSet<&(String, String)> = keys.iter().collect();
    assert_eq!(all.len(), keys.len(), "plan keys are unique");
    for n in [2u32, 3, 7] {
        let mut seen: HashSet<&(String, String)> = HashSet::new();
        for i in 0..n {
            let sh = Shard { index: i, count: n };
            for k in keys.iter().filter(|(b, id)| sh.contains(b, id)) {
                assert!(seen.insert(k), "{k:?} landed in two shards (n={n})");
            }
        }
        assert_eq!(seen, all, "the union of {n} shards must be the full plan");
    }
    // shard_of agrees with Shard::contains (the engine uses the latter)
    for (b, id) in &keys {
        let bucket = shard_of(b, id, 3);
        assert!(Shard { index: bucket, count: 3 }.contains(b, id));
    }
    // with 2 shards over dozens of units, both sides get work
    let sh0 = Shard { index: 0, count: 2 };
    let owned = keys.iter().filter(|(b, id)| sh0.contains(b, id)).count();
    assert!(owned > 0 && owned < keys.len(), "{owned}/{} is a degenerate split", keys.len());
}

#[test]
fn sharded_runs_merge_back_to_the_unsharded_campaign() {
    let dir = tmp_dir("amm_dse_spec_shard_merge");
    let mut spec = CampaignSpec::new()
        .benchmark("gemm")
        .benchmark("stencil2d")
        .benchmark("fft")
        .locality_only("kmp");
    spec.scale = Scale::Tiny;
    spec.sweep = Sweep::quick();

    // ---- the reference: one unsharded offline campaign ---------------
    let full = spec.run_offline().unwrap();
    let full_csv = full.fig5_csv();

    // ---- n=2 sharded runs, each to its own sink ----------------------
    let n = 2u32;
    let mut sinks = Vec::new();
    let mut shard_points = 0usize;
    for i in 0..n {
        let mut shard_spec = spec.clone().with_shard(i, n);
        let path = dir.join(format!("s{i}.jsonl"));
        shard_spec.sink = Some(path.clone());
        let outcome = shard_spec.run_offline().unwrap();
        assert_eq!(outcome.shard, Some(Shard { index: i, count: n }));
        assert_eq!(outcome.resumed, 0);
        // a shard never traces benchmarks it owns no units of: the
        // locality-only row stays unmaterialized (merge recomputes it)
        let kmp = outcome.get("kmp").unwrap();
        assert!(kmp.locality.is_nan() && kmp.trace_nodes == 0, "kmp traced on a shard host");
        shard_points += outcome.total_points();
        sinks.push(path);
    }
    assert_eq!(shard_points, full.total_points(), "shards partition the plan");
    // the two sinks are disjoint record sets
    let (r0, _) = sink::load(&sinks[0]).unwrap();
    let (r1, _) = sink::load(&sinks[1]).unwrap();
    let k0: HashSet<(String, String)> =
        r0.iter().map(|(b, _, p)| (b.clone(), p.id.clone())).collect();
    let k1: HashSet<(String, String)> =
        r1.iter().map(|(b, _, p)| (b.clone(), p.id.clone())).collect();
    assert!(k0.is_disjoint(&k1), "shard sinks must not overlap");
    assert_eq!(k0.len() + k1.len(), full.total_points());

    // ---- merge: byte-for-byte the unsharded fig5, zero missing -------
    let merged = merge::merge(&spec, &sinks).unwrap();
    assert!(merged.missing.is_empty(), "{:?}", merged.missing);
    assert_eq!(merged.duplicates, 0);
    assert_eq!(merged.conflicts, 0);
    assert_eq!(merged.foreign, 0);
    assert_eq!(merged.outcome.fig5_csv(), full_csv, "merged fig5 CSV must match byte-for-byte");
    // point-for-point equality, in enumeration order
    for (a, b) in full.explorations().iter().zip(merged.outcome.explorations()) {
        assert_eq!(a.benchmark, b.benchmark);
        assert_eq!(a.locality.to_bits(), b.locality.to_bits(), "{}", a.benchmark);
        assert_eq!(a.points().len(), b.points().len(), "{}", a.benchmark);
        for (x, y) in a.points().iter().zip(b.points()) {
            assert_eq!(x, y, "{}/{}", a.benchmark, x.id);
        }
    }

    // ---- a sharded run resumes from its own sink ---------------------
    let mut shard0 = spec.clone().with_shard(0, n);
    shard0.sink = Some(sinks[0].clone());
    let resumed = shard0.run_offline().unwrap();
    assert_eq!(resumed.simulated, 0, "a complete shard sink resumes everything");
    assert_eq!(resumed.resumed, k0.len());
}

#[test]
fn weighted_shards_partition_exactly_and_merge_back() {
    // The weighted (LPT-over-trace-size) strategy must keep the hash
    // strategy's correctness contract: n shard runs partition the
    // cross-product exactly and merge back to the unsharded campaign
    // byte-for-byte — only the *placement* of units changes.
    let dir = tmp_dir("amm_dse_weighted_shard_merge");
    let mut spec = CampaignSpec::new().benchmark("gemm").benchmark("kmp");
    spec.scale = Scale::Tiny;
    spec.sweep = Sweep::quick();
    let full = spec.run_offline().unwrap();

    let n = 2u32;
    let mut sinks = Vec::new();
    let mut shard_points = 0usize;
    for i in 0..n {
        let mut shard_spec =
            spec.clone().with_shard(i, n).with_shard_strategy(ShardStrategy::Weighted);
        let path = dir.join(format!("w{i}.jsonl"));
        shard_spec.sink = Some(path.clone());
        let outcome = shard_spec.run_offline().unwrap();
        assert!(outcome.total_points() > 0, "LPT must give shard {i} work");
        shard_points += outcome.total_points();
        sinks.push(path);
    }
    assert_eq!(shard_points, full.total_points(), "weighted shards partition the plan");
    let (r0, _) = sink::load(&sinks[0]).unwrap();
    let (r1, _) = sink::load(&sinks[1]).unwrap();
    let k0: HashSet<(String, String)> =
        r0.iter().map(|(b, _, p)| (b.clone(), p.id.clone())).collect();
    let k1: HashSet<(String, String)> =
        r1.iter().map(|(b, _, p)| (b.clone(), p.id.clone())).collect();
    assert!(k0.is_disjoint(&k1), "weighted shard sinks must not overlap");
    assert_eq!(k0.len() + k1.len(), full.total_points());

    let merged = merge::merge(&spec, &sinks).unwrap();
    assert!(merged.missing.is_empty(), "{:?}", merged.missing);
    assert_eq!(merged.outcome.fig5_csv(), full.fig5_csv(), "merged fig5 matches byte-for-byte");

    // and a weighted shard resumes from its own sink like any other
    let mut again =
        spec.clone().with_shard(0, n).with_shard_strategy(ShardStrategy::Weighted);
    again.sink = Some(sinks[0].clone());
    let resumed = again.run_offline().unwrap();
    assert_eq!(resumed.simulated, 0, "deterministic ownership: the sink satisfies resume");
    assert_eq!(resumed.resumed, k0.len());
}

#[test]
fn resume_is_scale_keyed() {
    let dir = tmp_dir("amm_dse_spec_scale_key");
    let path = dir.join("tiny.jsonl");
    let mut spec = CampaignSpec::new().benchmark("gemm");
    spec.scale = Scale::Tiny;
    spec.sweep = Sweep::quick();
    spec.sink = Some(path.clone());
    let full = spec.run_offline().unwrap();
    assert_eq!(full.resumed, 0);

    // same records, but claiming another scale: must not satisfy resume
    let text = std::fs::read_to_string(&path).unwrap();
    let forged = text.replace("\"scale\":\"tiny\"", "\"scale\":\"paper\"");
    assert_ne!(text, forged, "the forgery must actually rewrite the records");
    std::fs::write(&path, forged).unwrap();
    let rerun = spec.run_offline().unwrap();
    assert_eq!(rerun.resumed, 0, "a paper-labelled sink must not satisfy a tiny resume");
    assert_eq!(rerun.simulated, full.total_points());

    // restore the genuine scale: everything resumes again
    std::fs::write(&path, &text).unwrap();
    let resumed = spec.run_offline().unwrap();
    assert_eq!(resumed.simulated, 0);
    assert_eq!(resumed.resumed, full.total_points());
}
