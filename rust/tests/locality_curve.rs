//! The locality-dial campaign contract: a synthetic conflict-pressure
//! ramp swept against banked and true-multi-port organizations must
//! produce a *monotone* AMM-benefit-vs-measured-locality curve, its
//! JSONL sink must stay byte-stable and resumable with zero
//! re-simulation (synthetic names regenerate deterministically, so a
//! resumed campaign trusts the sink exactly like a MachSuite one), and
//! the checked-in `configs/locality.toml` preset must keep parsing —
//! dial commas inside quoted names and all.

use amm_dse::campaign::{sink, Campaign};
use amm_dse::dse::Sweep;
use amm_dse::suite::Scale;
use amm_dse::util::stats;
use amm_dse::{config, report};

/// The conflict-pressure ramp at unit stride: locality is degraded by
/// one dial only, and every jump lands 64-element-aligned — the same
/// bank on any power-of-two banking at 4-byte words — so the banked
/// baseline stalls harder at each step while the true-port AMM stays
/// port-limited. Fixed seed: the whole campaign is a pure function.
const RAMP: [&str; 4] = [
    "synth:stride=unit,conflict=0,seed=7",
    "synth:stride=unit,conflict=0.3,seed=7",
    "synth:stride=unit,conflict=0.6,seed=7",
    "synth:stride=unit,conflict=0.9,seed=7",
];

/// Tiny-scale mirror of the `configs/locality.toml` sweep axes.
fn ramp_sweep() -> Sweep {
    Sweep {
        unrolls: vec![4],
        word_bytes: vec![4],
        alus: vec![4],
        bank_counts: vec![2, 8],
        amm_ports: vec![(4, 2)],
        include_multipump: false,
        include_lvt: false,
        ..Sweep::default()
    }
}

fn ramp_campaign() -> Campaign {
    Campaign::new().benchmarks(RAMP).scale(Scale::Tiny).sweep(ramp_sweep()).offline()
}

#[test]
fn conflict_ramp_produces_a_monotone_amm_benefit_curve() {
    let outcome = ramp_campaign().run().unwrap();
    let summaries = outcome.summaries();
    assert_eq!(summaries.len(), RAMP.len());

    // Every ramp point prices both families, so every row has a benefit.
    let benefits: Vec<f64> = summaries
        .iter()
        .map(|s| report::amm_benefit(s).unwrap_or_else(|| panic!("{}: no benefit", s.name)))
        .collect();
    let localities: Vec<f64> = summaries.iter().map(|s| s.locality).collect();

    // The dial direction: more conflict pressure ⇒ strictly lower
    // measured locality AND strictly more AMM benefit.
    for i in 1..RAMP.len() {
        assert!(
            localities[i] < localities[i - 1],
            "locality must fall along the ramp: {localities:?}"
        );
        assert!(
            benefits[i] > benefits[i - 1],
            "AMM benefit must rise along the ramp: {benefits:?}"
        );
    }
    assert!(
        benefits[RAMP.len() - 1] > 1.05 * benefits[0],
        "the ramp should move the benefit materially: {benefits:?}"
    );

    // The figure itself: a perfectly anticorrelated four-point curve.
    let rho = stats::spearman(&localities, &benefits);
    assert!(rho <= -0.99, "benefit-vs-locality Spearman must be -1 on the ramp, got {rho}");
    assert_eq!(report::locality_benefit_spearman(&summaries), Some(rho));

    // Golden pin: the CSV is a pure function of (dials, seed, sweep) —
    // an independent second campaign reproduces it byte for byte, rows
    // sorted by ascending locality with a populated benefit column.
    let csv = report::locality_csv(&summaries);
    let again = ramp_campaign().run().unwrap();
    assert_eq!(
        report::locality_csv(&again.summaries()),
        csv,
        "locality CSV must be byte-stable across fresh runs"
    );
    let mut lines = csv.lines();
    assert_eq!(
        lines.next().unwrap(),
        "benchmark,spatial_locality,amm_benefit,best_banking_ns,best_amm_ns,n_points"
    );
    let mut prev_loc = f64::NEG_INFINITY;
    for line in lines {
        let cols: Vec<&str> = line.split(',').collect();
        // synthetic names carry commas; locality is column -5 from the end
        let loc: f64 = cols[cols.len() - 5].parse().unwrap();
        assert!(loc >= prev_loc, "CSV rows must sort by ascending locality:\n{csv}");
        prev_loc = loc;
        assert!(!cols[cols.len() - 4].is_empty(), "amm_benefit must be populated:\n{csv}");
    }
}

#[test]
fn synthetic_campaign_sink_is_byte_stable_and_resumes_without_resimulating() {
    let dir = std::env::temp_dir().join("amm_dse_locality_resume");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // ---- fresh run: one sink line per point, enumeration order -------
    let sink_a = dir.join("a.jsonl");
    let full = ramp_campaign().threads(4).sink(&sink_a).run().unwrap();
    assert_eq!(full.resumed, 0);
    assert_eq!(full.simulated, full.total_points());
    let text = std::fs::read_to_string(&sink_a).unwrap();
    assert_eq!(text.lines().count(), full.total_points());
    let (records, torn) = sink::load(&sink_a).unwrap();
    assert_eq!(records.len(), full.total_points());
    assert!(!torn);
    // the parametric names round-trip the sink verbatim
    for (bench, _, _) in &records {
        assert!(RAMP.contains(&bench.as_str()), "sink carried a mangled name: {bench:?}");
    }

    // ---- byte stability across identical fresh runs ------------------
    let sink_b = dir.join("b.jsonl");
    let _ = ramp_campaign().threads(4).sink(&sink_b).run().unwrap();
    assert_eq!(
        std::fs::read_to_string(&sink_b).unwrap(),
        text,
        "synthetic campaign JSONL must be byte-stable"
    );

    // ---- kill + resume: intact prefix plus a torn fragment -----------
    let k = full.total_points() / 2;
    let prefix: String = text.lines().take(k).map(|l| format!("{l}\n")).collect();
    let torn_line = &text.lines().nth(k).unwrap()[..24];
    let sink_c = dir.join("c.jsonl");
    std::fs::write(&sink_c, format!("{prefix}{torn_line}")).unwrap();
    let resumed = ramp_campaign().threads(4).sink(&sink_c).run().unwrap();
    assert_eq!(resumed.resumed, k, "every intact line must be restored");
    assert_eq!(
        resumed.simulated,
        full.total_points() - k,
        "a resumed synthetic campaign re-simulates only the missing points"
    );
    for (a, b) in full.explorations().iter().zip(resumed.explorations()) {
        assert_eq!(a.benchmark, b.benchmark);
        for (x, y) in a.points().iter().zip(b.points()) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.out, y.out, "{}/{}", a.benchmark, x.id);
        }
    }

    // ---- a complete sink resumes everything, simulates nothing, and
    // still yields the identical figure --------------------------------
    let complete = ramp_campaign().threads(4).sink(&sink_a).run().unwrap();
    assert_eq!(complete.simulated, 0, "complete sink ⇒ zero re-simulation");
    assert_eq!(complete.resumed, full.total_points());
    assert_eq!(
        report::locality_csv(&complete.summaries()),
        report::locality_csv(&full.summaries()),
        "a warm resume must reproduce the locality figure byte for byte"
    );
}

#[test]
fn the_checked_in_locality_preset_parses_and_round_trips() {
    // The preset's names carry `=` and `,` inside quoted strings — the
    // exact shape the line-based TOML subset must keep handling.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../configs/locality.toml");
    let cfg = config::load(path.as_ref()).unwrap();
    assert_eq!(cfg.scale, Scale::Paper);
    assert_eq!(cfg.campaign.plan.len(), 8);
    assert!(cfg.campaign.plan.iter().all(|e| e.name.starts_with("synth:")));
    assert!(cfg.campaign.plan.iter().any(|e| e.name.contains("conflict=0.9")));
    assert_eq!(cfg.sweep.word_bytes, vec![4], "preset must match the generator's element size");
    assert_eq!(cfg.sweep.amm_ports, vec![(4, 2)]);
    // and the lowered spec survives a TOML round trip, commas intact
    let reparsed = amm_dse::CampaignSpec::parse(&cfg.campaign.to_toml()).unwrap();
    assert_eq!(reparsed, cfg.campaign);
}
