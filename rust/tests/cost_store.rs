//! The persistent cost-store contract, end to end.
//!
//! Core claim (ROADMAP "Cross-campaign cost-batch reuse"): macro-cost
//! characterization is a reusable artifact. A campaign re-run against a
//! warm store — a *fresh* coordinator, as a new process/host would have
//! — must issue **zero** runtime cost batches (`batches_issued == 0`)
//! while producing a byte-identical fig5 CSV, across ≥ 3 benchmarks.
//! Plus: the `<sink>.status.json` health sidecar, and warm-start
//! through the `Explorer` facade.

use amm_dse::campaign::{self, sink, Campaign};
use amm_dse::coordinator::Coordinator;
use amm_dse::cost::CostStore;
use amm_dse::dse::Sweep;
use amm_dse::suite::Scale;
use amm_dse::Explorer;
use std::path::{Path, PathBuf};

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A RustFallback coordinator rooted at an empty artifacts dir.
fn coordinator(dir: &Path) -> Coordinator {
    let artifacts = dir.join("artifacts");
    let _ = std::fs::create_dir_all(&artifacts);
    Coordinator::with_artifacts(artifacts)
}

fn campaign_with_store(store: &Path) -> Campaign {
    Campaign::new()
        .benchmarks(["gemm", "fft", "stencil2d"])
        .scale(Scale::Tiny)
        .sweep(Sweep::quick())
        .cost_store(store)
}

#[test]
fn warm_store_rerun_issues_zero_batches_and_reproduces_fig5_byte_for_byte() {
    let dir = tmp_dir("amm_dse_cost_store_golden");
    let store_path = dir.join("suite.cost.jsonl");

    // ---- cold run: scores through the runtime backend, fills the store
    let cold_coord = coordinator(&dir);
    let cold = campaign_with_store(&store_path).run_with(&cold_coord).unwrap();
    assert_eq!(cold_coord.batches_issued(), 1, "cold campaign scores in ONE batch");
    assert_eq!(cold.cost_batches, 1);
    assert_eq!(cold.cost.store_hits, 0);
    assert!(cold.cost.misses > 0);
    let cold_fig5 = cold.fig5_csv();
    let rows = CostStore::open(&store_path).unwrap();
    assert_eq!(rows.len(), cold.cost.misses, "every scored shape persisted");
    assert!(!rows.is_empty());

    // ---- warm run: a FRESH coordinator (new process) over the same
    // store must re-simulate everything but batch NOTHING
    let warm_coord = coordinator(&dir);
    assert_eq!(warm_coord.batches_issued(), 0);
    let warm = campaign_with_store(&store_path).run_with(&warm_coord).unwrap();
    assert_eq!(
        warm_coord.batches_issued(),
        0,
        "a warm cost store must absorb every macro-cost query"
    );
    assert_eq!(warm.cost_batches, 0);
    assert_eq!(warm.cost.misses, 0);
    assert_eq!(warm.cost.store_hits, cold.cost.misses + cold.cost.hits());
    assert_eq!(warm.simulated, cold.simulated, "no sink: simulation still runs");
    assert_eq!(warm.fig5_csv(), cold_fig5, "warm fig5 CSV must match byte-for-byte");
    // point-for-point bit equality, not just the summary
    for (a, b) in cold.explorations().iter().zip(warm.explorations()) {
        assert_eq!(a.benchmark, b.benchmark);
        for (x, y) in a.points().iter().zip(b.points()) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.out, y.out, "{}/{}", a.benchmark, x.id);
        }
    }
    // the warm pass appended nothing
    assert_eq!(CostStore::open(&store_path).unwrap().len(), rows.len());
}

#[test]
fn sink_plus_store_makes_a_resume_fully_free() {
    // The tentpole's headline: sink resume skips re-SIMULATION, the
    // store skips re-SCORING — together a restarted campaign does
    // neither, which is what makes shard fleets cheap to restart.
    let dir = tmp_dir("amm_dse_cost_store_resume");
    let sink_path = dir.join("suite.jsonl");
    // no explicit cost_store: the default `<sink>.cost.jsonl` applies
    let run = |coord: &Coordinator| {
        Campaign::new()
            .benchmarks(["gemm", "kmp"])
            .scale(Scale::Tiny)
            .sweep(Sweep::quick())
            .sink(&sink_path)
            .run_with(coord)
            .unwrap()
    };
    let coord_a = coordinator(&dir);
    let full = run(&coord_a);
    assert_eq!(full.cost_batches, 1);
    let derived = campaign::default_cost_store(&sink_path);
    assert!(derived.exists(), "store must derive next to the sink: {}", derived.display());

    // fresh coordinator + intact sink: zero simulation AND zero batches
    let coord_b = coordinator(&dir);
    let resumed = run(&coord_b);
    assert_eq!(resumed.simulated, 0);
    assert_eq!(resumed.resumed, full.total_points());
    assert_eq!(coord_b.batches_issued(), 0, "warmed resume must issue zero cost batches");

    // fresh coordinator + LOST sink, kept stores: nothing re-batches —
    // and since the default `<sink>.sim.jsonl` simulation store also
    // outlives the sink, nothing re-simulates either: every point
    // rebuilds straight from the two stores
    std::fs::remove_file(&sink_path).unwrap();
    let derived_sim = campaign::default_sim_store(&sink_path);
    assert!(derived_sim.exists(), "sim store derives next to the sink: {}", derived_sim.display());
    let coord_c = coordinator(&dir);
    let rebuilt = run(&coord_c);
    assert_eq!(rebuilt.simulated, 0, "the sim store outlives the sink");
    assert_eq!(rebuilt.memoized, full.total_points());
    assert_eq!(coord_c.batches_issued(), 0, "store outlives the sink");
    assert_eq!(rebuilt.fig5_csv(), full.fig5_csv(), "byte-identical rebuild");
}

#[test]
fn torn_store_tail_is_repaired_and_only_costs_the_lost_rows() {
    let dir = tmp_dir("amm_dse_cost_store_torn");
    let store_path = dir.join("torn.cost.jsonl");
    let cold = coordinator(&dir);
    campaign_with_store(&store_path).run_with(&cold).unwrap();
    let text = std::fs::read_to_string(&store_path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert!(lines.len() >= 3, "need rows to tear: {}", lines.len());
    // keep all but the last line, plus a torn fragment of it (what a
    // kill mid-append leaves behind)
    let kept = lines.len() - 1;
    let mut torn: String = lines[..kept].iter().map(|l| format!("{l}\n")).collect();
    torn.push_str(&lines[kept][..25]);
    std::fs::write(&store_path, torn).unwrap();

    let warm = coordinator(&dir);
    let outcome = campaign_with_store(&store_path).run_with(&warm).unwrap();
    assert_eq!(outcome.cost.store_hits, kept, "intact rows still serve");
    assert_eq!(outcome.cost.misses, 1, "only the torn row re-scores");
    assert_eq!(outcome.cost_batches, 1);
    // the repaired store is whole again: a third run is fully warm
    let reloaded = CostStore::open(&store_path).unwrap();
    assert_eq!(reloaded.len(), lines.len());
    assert!(!reloaded.report().torn_tail);
    assert_eq!(reloaded.report().malformed, 1, "the terminated fragment is skipped");
    let third = coordinator(&dir);
    campaign_with_store(&store_path).run_with(&third).unwrap();
    assert_eq!(third.batches_issued(), 0);
}

#[test]
fn campaign_writes_a_status_sidecar_next_to_the_sink() {
    let dir = tmp_dir("amm_dse_status_sidecar");
    let sink_path = dir.join("s.jsonl");
    let outcome = Campaign::new()
        .benchmarks(["gemm"])
        .scale(Scale::Tiny)
        .sweep(Sweep::quick())
        .offline()
        .sink(&sink_path)
        .run()
        .unwrap();
    let status_path = sink::status_path(&sink_path);
    let text = std::fs::read_to_string(&status_path)
        .unwrap_or_else(|e| panic!("{} missing: {e}", status_path.display()));
    assert!(text.contains("\"schema\":\"campaign-status/v1\""), "{text}");
    assert!(text.contains("\"complete\":true"), "final status must be complete: {text}");
    assert!(
        text.contains(&format!("\"done\":{}", outcome.total_points())),
        "done must equal the persisted point count: {text}"
    );
    assert!(text.contains("\"shard\":null"), "{text}");
    assert!(text.contains("\"scale\":\"tiny\""), "{text}");
    // offline: no scoring happened
    assert!(text.contains("\"cost_batches\":0"), "{text}");
}

#[test]
fn explorer_inherits_warm_start_through_the_campaign_engine() {
    let dir = tmp_dir("amm_dse_explorer_warm");
    let store_path = dir.join("gemm.cost.jsonl");
    let explore = |coord: &Coordinator| {
        Explorer::new()
            .workload("gemm", Scale::Tiny)
            .sweep(Sweep::quick())
            .cost_store(&store_path)
            .run_with(coord)
            .unwrap()
    };
    let coord_a = coordinator(&dir);
    let cold = explore(&coord_a);
    assert_eq!(coord_a.batches_issued(), 1);
    let coord_b = coordinator(&dir);
    let warm = explore(&coord_b);
    assert_eq!(coord_b.batches_issued(), 0, "facade rides the same warm-start");
    for (a, b) in cold.points().iter().zip(warm.points()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.out, b.out, "{}", a.id);
    }
}
