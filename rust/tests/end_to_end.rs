//! End-to-end integration: the full pipeline (trace → locality → sweep →
//! Pareto → performance ratio) reproduces the paper's qualitative claims
//! at test scale, and the config/report layers round-trip.

use amm_dse::dse::{self, Sweep};
use amm_dse::locality;
use amm_dse::report;
use amm_dse::suite::{self, Scale};

/// A sweep large enough to exhibit the Fig-4 shapes but fast enough for CI.
fn test_sweep() -> Sweep {
    Sweep {
        unrolls: vec![1, 4, 16],
        word_bytes: vec![1, 4, 8],
        alus: vec![8],
        bank_counts: vec![1, 2, 4, 8, 16, 32],
        amm_ports: vec![(2, 1), (2, 2), (4, 2), (8, 4)],
        include_multipump: true,
        include_lvt: true,
        ..Sweep::default()
    }
}

#[test]
fn fig4_shape_amm_extends_design_space_for_low_locality_benchmarks() {
    // The paper's headline: for FFT/GEMM/MD-KNN (low locality) the AMM
    // points reach execution times banking cannot; the design space is
    // *extended* (blue-shaded region of Fig 4).
    for name in ["gemm", "md-knn"] {
        let wl = suite::generate(name, Scale::Tiny);
        let points = test_sweep().run(&wl.trace);
        let best_bank = dse::best_time(&points, |p| !p.is_amm);
        let best_amm = dse::best_time(&points, |p| p.is_amm);
        assert!(
            best_amm < best_bank,
            "{name}: AMM best {best_amm} !< banking best {best_bank}"
        );
    }
}

#[test]
fn fig4_shape_kmp_amm_pays_area() {
    // For KMP (stride-1 bytes, locality ≈ 1) banking partitions are
    // conflict-free, so the AMM area premium buys little: the banking
    // frontier must contain points at-or-near AMM times with less area
    // (performance ratio < 1 or barely above).
    let wl = suite::generate("kmp", Scale::Tiny);
    let points = test_sweep().run(&wl.trace);
    let ratio = dse::performance_ratio(&points, 0.10);
    if let Some(r) = ratio {
        assert!(r < 1.15, "kmp perf ratio should not favour AMM strongly, got {r}");
    }
}

#[test]
fn fig5_shape_ratio_tracks_locality() {
    // Across the four DSE benchmarks, low locality ⇒ higher ratio.
    let mut rows: Vec<(f64, f64)> = Vec::new();
    for name in suite::DSE_BENCHMARKS {
        let wl = suite::generate(name, Scale::Tiny);
        let loc = locality::analyze(&wl.trace).spatial_locality();
        let points = test_sweep().run(&wl.trace);
        if let Some(r) = dse::performance_ratio(&points, 0.10) {
            rows.push((loc, r));
        }
    }
    assert!(rows.len() >= 3, "need ratios for most benchmarks, got {rows:?}");
    let xs: Vec<f64> = rows.iter().map(|r| r.0).collect();
    let ys: Vec<f64> = rows.iter().map(|r| r.1).collect();
    let rho = amm_dse::util::stats::pearson(&xs, &ys);
    assert!(rho < 0.0, "locality and AMM benefit must anti-correlate, rho={rho} rows={rows:?}");
    // and KMP (the high-locality benchmark) must have the lowest ratio
    let kmp = rows.iter().zip(suite::DSE_BENCHMARKS).find(|(_, n)| *n == "kmp");
    if let Some(((_, kmp_ratio), _)) = kmp {
        assert!(
            rows.iter().filter(|(_, r)| r < kmp_ratio).count() <= 1,
            "kmp should have (nearly) the lowest AMM benefit: {rows:?}"
        );
    }
}

#[test]
fn csv_reports_roundtrip_through_filesystem() {
    let wl = suite::generate("stencil2d", Scale::Tiny);
    let points = Sweep::quick().run(&wl.trace);
    let dir = std::env::temp_dir().join("amm_dse_e2e_csv");
    let path = dir.join("fig4_test.csv");
    report::write_file(&path, &report::fig4_csv(&points)).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.lines().count(), points.len() + 1);
    assert!(text.lines().next().unwrap().starts_with("id,mem,is_amm"));
}

#[test]
fn config_file_drives_a_sweep() {
    let toml = r#"
        benchmark = "stencil2d"
        scale = "tiny"
        [sweep]
        unrolls = [1, 4]
        word_bytes = [4]
        alus = [4]
        bank_counts = [1, 4]
        multipump = false
        lvt = false
        [[amm]]
        read_ports = 2
        write_ports = 1
    "#;
    let rc = amm_dse::config::parse(toml).unwrap();
    let wl = suite::generate(&rc.benchmark, rc.scale);
    let points = rc.sweep.run(&wl.trace);
    // mem kinds: banked1, banked4, xor2r1w = 3; ×2 unrolls
    assert_eq!(points.len(), 6);
    assert!(points.iter().any(|p| p.is_amm));
}

#[test]
fn explorer_facade_runs_the_full_pipeline() {
    // The facade path: workload → coordinator-batched sweep → Pareto →
    // ratio → CSV, in one chain.
    let ex = amm_dse::Explorer::new()
        .workload("gemm", Scale::Tiny)
        .sweep(test_sweep())
        .threads(2)
        .run()
        .unwrap();
    assert_eq!(ex.points().len(), test_sweep().points().len());
    assert!(ex.locality > 0.0);
    assert!(!ex.pareto_area().is_empty());
    assert!(ex.best_amm_ns() < ex.best_banking_ns(), "gemm AMM must extend the frontier");
    let dir = std::env::temp_dir().join("amm_dse_e2e_explorer");
    let path = dir.join("gemm.csv");
    ex.write_csv(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.lines().count(), ex.points().len() + 1);
}

#[test]
fn simulate_is_deterministic_across_thread_counts() {
    let wl = suite::generate("fft", Scale::Tiny);
    let mut s1 = test_sweep();
    s1.threads = 1;
    let mut s8 = test_sweep();
    s8.threads = 8;
    let a = s1.run(&wl.trace);
    let b = s8.run(&wl.trace);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.out.cycles, y.out.cycles);
        assert_eq!(x.out.area_um2, y.out.area_um2);
    }
}
