//! Integration: the AOT Pallas cost model (via PJRT) must agree with the
//! pure-Rust CACTI-lite mirror to float precision, and the coordinator
//! must produce identical sweeps through either backend.
//!
//! Skips (with a loud message) when `make artifacts` has not run.

use amm_dse::coordinator::{CostBackend, CostService, Coordinator, COST_BATCH};
use amm_dse::runtime::{names, Runtime};
use amm_dse::sram;
use amm_dse::suite::{self, Scale};
use amm_dse::util::rng::Rng;

fn artifacts_ready() -> bool {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("SKIP: pjrt feature not enabled (stub runtime)");
        return false;
    }
    let dir = amm_dse::runtime::artifacts_dir();
    let missing = amm_dse::runtime::missing_artifacts(&dir);
    if !missing.is_empty() {
        eprintln!("SKIP: artifacts missing {missing:?}; run `make artifacts`");
        return false;
    }
    true
}

#[test]
fn pjrt_cost_model_matches_rust_mirror() {
    if !artifacts_ready() {
        return;
    }
    let (svc, _guard, backend) = CostService::spawn(amm_dse::runtime::artifacts_dir());
    assert_eq!(backend, CostBackend::Pjrt, "artifact exists but PJRT backend not live");
    let mut rng = Rng::new(42);
    let depths = [4.0f32, 64.0, 256.0, 1024.0, 4096.0, 16384.0, 65536.0];
    let widths = [1.0f32, 8.0, 32.0, 64.0, 128.0];
    let ports = [1.0f32, 2.0, 4.0, 8.0];
    let queries: Vec<[f32; 4]> = (0..3000)
        .map(|_| {
            [
                *rng.pick(&depths),
                *rng.pick(&widths),
                *rng.pick(&ports),
                *rng.pick(&ports),
            ]
        })
        .collect();
    let got = svc.cost_batch(queries.clone()).expect("pjrt batch");
    let want = sram::macro_cost_batch(&queries);
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        for k in 0..5 {
            let rel = (g[k] - w[k]).abs() / w[k].abs().max(1e-6);
            assert!(
                rel < 1e-4,
                "row {i} field {k}: pjrt {} vs rust {} (query {:?})",
                g[k],
                w[k],
                queries[i]
            );
        }
    }
    svc.stop();
}

#[test]
fn pjrt_handles_partial_batches() {
    if !artifacts_ready() {
        return;
    }
    let (svc, _guard, _) = CostService::spawn(amm_dse::runtime::artifacts_dir());
    // 1 query, COST_BATCH+1 queries: padding must be invisible.
    let q = [1024.0f32, 32.0, 2.0, 1.0];
    let one = svc.cost_batch(vec![q]).unwrap();
    assert_eq!(one.len(), 1);
    let many = svc.cost_batch(vec![q; COST_BATCH + 1]).unwrap();
    assert_eq!(many.len(), COST_BATCH + 1);
    for row in &many {
        assert_eq!(row, &one[0]);
    }
    svc.stop();
}

#[test]
fn coordinator_sweep_identical_on_both_backends() {
    if !artifacts_ready() {
        return;
    }
    let wl = suite::generate("stencil2d", Scale::Tiny);
    let sweep = amm_dse::dse::Sweep::quick();

    let pjrt = Coordinator::with_artifacts(amm_dse::runtime::artifacts_dir());
    assert_eq!(pjrt.backend, CostBackend::Pjrt);
    let a = pjrt.run_sweep(&wl.trace, &sweep).unwrap();

    let empty = std::env::temp_dir().join("amm_dse_empty_artifacts");
    let _ = std::fs::create_dir_all(&empty);
    let rust = Coordinator::with_artifacts(empty);
    assert_eq!(rust.backend, CostBackend::RustFallback);
    let b = rust.run_sweep(&wl.trace, &sweep).unwrap();

    assert_eq!(a.len(), b.len());
    for (pa, pb) in a.iter().zip(&b) {
        assert_eq!(pa.id, pb.id);
        assert_eq!(pa.out.cycles, pb.out.cycles, "{}", pa.id);
        let rel = (pa.out.area_um2 - pb.out.area_um2).abs() / pb.out.area_um2;
        assert!(rel < 1e-4, "{}: {} vs {}", pa.id, pa.out.area_um2, pb.out.area_um2);
        let relp = (pa.out.power_mw - pb.out.power_mw).abs() / pb.out.power_mw;
        assert!(relp < 1e-3, "{}: power {} vs {}", pa.id, pa.out.power_mw, pb.out.power_mw);
    }
}

#[test]
fn workload_artifacts_execute() {
    if !artifacts_ready() {
        return;
    }
    let rt = Runtime::cpu().expect("runtime");
    // gemm: identity x identity = identity
    let exe = rt.load(names::GEMM).expect("load gemm");
    let n = 64usize;
    let mut eye = vec![0f32; n * n];
    for i in 0..n {
        eye[i * n + i] = 1.0;
    }
    let out = exe.run_f32(&[(&eye, &[n, n]), (&eye, &[n, n])]).expect("run gemm");
    assert_eq!(out.len(), 1);
    assert_eq!(out[0], eye);

    // xor_recon: parity recovery equals direct read
    let exe = rt.load(names::XOR_RECON).expect("load xor");
    let d = 1024usize;
    let nq = 512usize;
    let mut rng = Rng::new(3);
    let b0: Vec<i32> = (0..d).map(|_| rng.next_u32() as i32 & 0x7fffffff).collect();
    let b1: Vec<i32> = (0..d).map(|_| rng.next_u32() as i32 & 0x7fffffff).collect();
    let par: Vec<i32> = b0.iter().zip(&b1).map(|(a, b)| a ^ b).collect();
    let idx: Vec<i32> = (0..nq).map(|_| rng.below(d as u64) as i32).collect();
    let sel: Vec<i32> = (0..nq).map(|_| (rng.below(2)) as i32).collect();
    let dims: &[usize] = &[d];
    let qdims: &[usize] = &[nq];
    let zeros = vec![0i32; nq];
    let ones = vec![1i32; nq];
    let direct = exe
        .run_i32(&[(&b0, dims), (&b1, dims), (&par, dims), (&idx, qdims), (&sel, qdims), (&zeros, qdims)])
        .expect("xor direct");
    let recovered = exe
        .run_i32(&[(&b0, dims), (&b1, dims), (&par, dims), (&idx, qdims), (&sel, qdims), (&ones, qdims)])
        .expect("xor recovered");
    assert_eq!(direct[0], recovered[0], "parity recovery must equal direct reads");
}
