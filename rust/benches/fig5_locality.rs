//! Bench + regeneration harness for **Fig 5**: spatial locality across
//! the MachSuite ports and the AMM performance ratio for the DSE set.
//! Writes `results/fig5.csv` and prints the locality/ratio correlation
//! behind the paper's §IV-C threshold claim.
//!
//! `cargo bench --bench fig5_locality [-- --quick]`

use amm_dse::dse::{self, Sweep};
use amm_dse::suite::{self, Scale};
use amm_dse::util::benchkit::Bench;
use amm_dse::util::stats;
use amm_dse::{locality, report};
use std::path::Path;

fn main() {
    let mut bench = Bench::from_args();

    // locality for all 13 benchmarks (timed as one unit: the analyzer
    // is part of the paper's methodology)
    let locs = bench.run("fig5/locality/all13", Some(13), || {
        suite::ALL_BENCHMARKS
            .iter()
            .map(|name| {
                let wl = suite::generate(name, Scale::Paper);
                (name.to_string(), locality::analyze(&wl.trace).spatial_locality())
            })
            .collect::<Vec<_>>()
    });

    // ratios for the four DSE benchmarks
    let sweep = Sweep::default();
    let mut summaries = Vec::new();
    for name in suite::DSE_BENCHMARKS {
        let wl = suite::generate(name, Scale::Paper);
        let points = bench.run(&format!("fig5/ratio/{name}"), None, || sweep.run(&wl.trace));
        if let Some(points) = points {
            summaries.push(dse::BenchSummary {
                name: name.to_string(),
                locality: locality::analyze(&wl.trace).spatial_locality(),
                perf_ratio: dse::performance_ratio(&points, 0.10),
                best_banking_ns: dse::best_time(&points, |p| !p.is_amm),
                best_amm_ns: dse::best_time(&points, |p| p.is_amm),
                n_points: points.len(),
            });
        }
    }

    if let Some(locs) = locs {
        for (name, l) in &locs {
            if !summaries.iter().any(|s| &s.name == name) {
                summaries.push(dse::BenchSummary {
                    name: name.clone(),
                    locality: *l,
                    perf_ratio: None,
                    best_banking_ns: f64::NAN,
                    best_amm_ns: f64::NAN,
                    n_points: 0,
                });
            }
        }
    }
    summaries.sort_by(|a, b| a.name.cmp(&b.name));
    report::write_file(Path::new("results/fig5.csv"), &report::fig5_csv(&summaries)).unwrap();
    println!("{}", report::fig5_ascii(&summaries));
    let with: Vec<_> = summaries.iter().filter(|s| s.perf_ratio.is_some()).collect();
    if with.len() >= 3 {
        let xs: Vec<f64> = with.iter().map(|s| s.locality).collect();
        let ys: Vec<f64> = with.iter().map(|s| s.perf_ratio.unwrap()).collect();
        println!(
            "locality/ratio correlation: pearson {:.3} spearman {:.3} (paper: negative)",
            stats::pearson(&xs, &ys),
            stats::spearman(&xs, &ys)
        );
    }
    bench.finish();
}
