//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **Hierarchical vs flat XOR** (HB-NTX vs LaForest) — area across
//!    port configs; the reason the paper builds on the hierarchical flow.
//! 2. **Cyclic vs block partitioning** (§IV-A) — cycles on stride-1 vs
//!    strided benchmarks.
//! 3. **Word size** — the §IV-B lever: byte words for KMP vs 8-byte
//!    words for GEMM.
//! 4. **EDP objective** — best energy-delay-product design per benchmark
//!    (the paper's §I EDP-maximization objective), AMM vs banking.
//!
//! Writes `results/ablation.csv`. `cargo bench --bench ablation [-- --quick]`

use amm_dse::dse::{DesignPoint, Sweep};
use amm_dse::mem::MemKind;
use amm_dse::report;
use amm_dse::sched::{simulate, DesignConfig};
use amm_dse::suite::{self, Scale};
use amm_dse::util::benchkit::Bench;
use std::fmt::Write as _;
use std::path::Path;

fn main() {
    let mut bench = Bench::from_args();
    let mut csv = String::from("ablation,case,metric,value\n");

    // 1. hierarchical vs flat XOR area
    bench.run("ablation/xor-hier-vs-flat", None, || {
        for (r, w) in [(2u32, 1u32), (2, 2), (4, 2), (4, 4), (8, 4)] {
            let hb = MemKind::XorAmm { read_ports: r, write_ports: w }.build(8192, 64);
            let flat = MemKind::XorFlat { read_ports: r, write_ports: w }.build(8192, 64);
            let save = flat.area_um2() / hb.area_um2();
            let _ = writeln!(csv, "xor-hier-vs-flat,{r}R{w}W,area_saving_x,{save:.3}");
        }
        0u8
    });

    // 2. cyclic vs block partitioning
    for name in ["kmp", "fft"] {
        let wl = suite::generate(name, Scale::Paper);
        bench.run(&format!("ablation/cyclic-vs-block/{name}"), None, || {
            for banks in [4u32, 16] {
                let cyc = simulate(
                    &wl.trace,
                    &DesignConfig { mem: MemKind::Banked { banks }, unroll: 8, word_bytes: 4, alus: 8 },
                );
                let blk = simulate(
                    &wl.trace,
                    &DesignConfig { mem: MemKind::BankedBlock { banks }, unroll: 8, word_bytes: 4, alus: 8 },
                );
                let _ = writeln!(
                    csv,
                    "cyclic-vs-block,{name}/b{banks},block_slowdown_x,{:.3}",
                    blk.cycles as f64 / cyc.cycles as f64
                );
            }
            0u8
        });
    }

    // 3. word size on KMP vs GEMM (banked 8)
    for name in ["kmp", "gemm"] {
        let wl = suite::generate(name, Scale::Paper);
        bench.run(&format!("ablation/word-size/{name}"), None, || {
            for wb in [1u32, 8] {
                let out = simulate(
                    &wl.trace,
                    &DesignConfig { mem: MemKind::Banked { banks: 8 }, unroll: 8, word_bytes: wb, alus: 8 },
                );
                let _ = writeln!(csv, "word-size,{name}/w{wb},cycles,{}", out.cycles);
                let _ = writeln!(csv, "word-size,{name}/w{wb},area_um2,{:.1}", out.area_um2);
            }
            0u8
        });
    }

    // 4. EDP-optimal designs, AMM vs banking
    let sweep = Sweep { alus: vec![4, 8], word_bytes: vec![4, 8], ..Sweep::default() };
    for name in suite::DSE_BENCHMARKS {
        let wl = suite::generate(name, Scale::Paper);
        bench.run(&format!("ablation/edp/{name}"), None, || {
            let points = sweep.run(&wl.trace);
            let best = |amm: bool| -> Option<&DesignPoint> {
                points
                    .iter()
                    .filter(|p| p.is_amm == amm)
                    .min_by(|a, b| a.edp().partial_cmp(&b.edp()).unwrap())
            };
            if let (Some(b), Some(a)) = (best(false), best(true)) {
                let _ = writeln!(csv, "edp,{name}/banking,best_edp,{:.4e}", b.edp());
                let _ = writeln!(csv, "edp,{name}/amm,best_edp,{:.4e}", a.edp());
                let _ = writeln!(csv, "edp,{name},banking_over_amm_x,{:.3}", b.edp() / a.edp());
            }
            points.len()
        });
    }

    // The harness runs each closure warmup+iters times; dedupe the
    // accumulated rows (they are identical across iterations).
    let mut seen = std::collections::HashSet::new();
    let deduped: String = csv
        .lines()
        .filter(|l| seen.insert(l.to_string()))
        .map(|l| format!("{l}\n"))
        .collect();
    report::write_file(Path::new("results/ablation.csv"), &deduped).unwrap();
    println!("wrote results/ablation.csv ({} rows)", deduped.lines().count() - 1);
    bench.finish();
}
