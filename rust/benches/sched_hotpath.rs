//! Scheduler hot-path microbenchmarks (the §Perf target: ≥1M scheduled
//! DDG nodes/s/core). Not a paper figure — this is the knob the whole
//! DSE's wall-clock hangs off, tracked in EXPERIMENTS.md §Perf.
//!
//! `cargo bench --bench sched_hotpath [-- --quick]`

use amm_dse::mem::MemKind;
use amm_dse::sched::{self, simulate, CompiledTrace, DesignConfig, SimArena};
use amm_dse::suite::{self, Scale};
use amm_dse::util::benchkit::Bench;

fn main() {
    let mut bench = Bench::from_args();
    for (name, scale) in [("gemm", Scale::Paper), ("fft", Scale::Paper), ("gemm", Scale::Large)] {
        let wl = suite::generate(name, scale);
        let nodes = wl.trace.len() as u64;
        let banked8 = MemKind::Banked { banks: 8 };
        let xor4r2w = MemKind::XorAmm { read_ports: 4, write_ports: 2 };
        for (label, cfg) in [
            ("banked8", DesignConfig { mem: banked8, unroll: 8, word_bytes: 8, alus: 8 }),
            ("xor4r2w", DesignConfig { mem: xor4r2w, unroll: 8, word_bytes: 8, alus: 8 }),
            ("banked8/w1", DesignConfig { mem: banked8, unroll: 8, word_bytes: 1, alus: 8 }),
        ] {
            bench.run(
                &format!("sched/{name}-{scale:?}/{label}"),
                Some(nodes),
                || simulate(&wl.trace, &cfg).cycles,
            );
        }
    }

    // engine vs compat: the same design point through a pre-compiled
    // trace + reused arena (the sweep path) vs compile-per-call
    for (name, scale) in [("gemm", Scale::Paper), ("fft", Scale::Paper)] {
        let wl = suite::generate(name, scale);
        let nodes = wl.trace.len() as u64;
        let cfg = DesignConfig {
            mem: MemKind::XorAmm { read_ports: 4, write_ports: 2 },
            unroll: 8,
            word_bytes: 8,
            alus: 8,
        };
        let design = sched::build_memory(&wl.trace, &cfg);
        let compiled = CompiledTrace::new(&wl.trace, cfg.word_bytes);
        let mut arena = SimArena::new();
        bench.run(&format!("sched-engine/{name}-{scale:?}/xor4r2w"), Some(nodes), || {
            compiled.simulate(&mut arena, &cfg.knobs(), &design).cycles
        });
        bench.run(&format!("sched-compat/{name}-{scale:?}/xor4r2w"), Some(nodes), || {
            sched::simulate_design(&wl.trace, &cfg.knobs(), &design).cycles
        });
    }

    // trace generation itself (the Aladdin front end)
    for name in ["gemm", "fft", "md-knn"] {
        bench.run(&format!("tracegen/{name}"), None, || suite::generate(name, Scale::Paper).trace.len());
    }
    bench.finish();
}
