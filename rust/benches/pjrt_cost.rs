//! PJRT cost-model dispatch benchmarks: per-point cost of scoring design
//! batches through the AOT Pallas kernel vs the pure-Rust mirror — the
//! coordinator's batching policy is sized from these numbers
//! (EXPERIMENTS.md §Perf).
//!
//! `cargo bench --bench pjrt_cost [-- --quick]`

use amm_dse::coordinator::{CostBackend, CostService, COST_BATCH};
use amm_dse::sram;
use amm_dse::util::benchkit::Bench;
use amm_dse::util::rng::Rng;

fn queries(n: usize) -> Vec<[f32; 4]> {
    let mut rng = Rng::new(99);
    let depths = [256.0f32, 1024.0, 4096.0, 16384.0];
    (0..n)
        .map(|_| [*rng.pick(&depths), 32.0, 1.0 + rng.below(4) as f32, 1.0 + rng.below(2) as f32])
        .collect()
}

fn main() {
    let mut bench = Bench::from_args();

    // pure-Rust mirror
    for n in [64usize, 1024, 8192] {
        let q = queries(n);
        bench.run(&format!("cost/rust-mirror/{n}"), Some(n as u64), || sram::macro_cost_batch(&q));
    }

    // PJRT path (skips if artifacts are missing)
    let (svc, _guard, backend) = CostService::spawn(amm_dse::runtime::artifacts_dir());
    if backend == CostBackend::Pjrt {
        for n in [1usize, 64, COST_BATCH, 4 * COST_BATCH] {
            let q = queries(n);
            bench.run(&format!("cost/pjrt/{n}"), Some(n as u64), || {
                svc.cost_batch(q.clone()).unwrap().len()
            });
        }
    } else {
        println!("(artifacts missing; PJRT benches skipped — run `make artifacts`)");
    }
    svc.stop();
    bench.finish();
}
