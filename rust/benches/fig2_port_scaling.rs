//! Bench + regeneration harness for **Fig 2**: the HB-NTX-RdWr port-
//! scaling flow — bank counts, capacity overhead and glue logic as read
//! and write ports double, vs LVT and circuit-level multiport. Writes
//! `results/fig2_port_scaling.csv`.
//!
//! `cargo bench --bench fig2_port_scaling [-- --quick]`

use amm_dse::mem::MemKind;
use amm_dse::report;
use amm_dse::util::benchkit::Bench;
use std::fmt::Write as _;
use std::path::Path;

fn main() {
    let mut bench = Bench::from_args();
    let configs: Vec<(u32, u32)> = vec![(1, 1), (2, 1), (4, 1), (8, 1), (2, 2), (4, 2), (4, 4), (8, 4)];
    let depths = [1024u32, 4096, 16384];

    let rows = bench.run("fig2/port_scaling/build_all", Some((configs.len() * depths.len() * 3) as u64), || {
        let mut rows = Vec::new();
        for &depth in &depths {
            let base = MemKind::Banked { banks: 1 }.build(depth, 32);
            for &(r, w) in &configs {
                for kind in [
                    MemKind::XorAmm { read_ports: r, write_ports: w },
                    MemKind::LvtAmm { read_ports: r, write_ports: w },
                    MemKind::CircuitMp { read_ports: r, write_ports: w },
                ] {
                    let d = kind.build(depth, 32);
                    rows.push((
                        depth,
                        format!("{r}R{w}W"),
                        kind.id(),
                        d.macros,
                        d.macros as f32 * d.macro_depth as f32 / depth as f32,
                        d.sram.area_um2,
                        d.logic.area_um2,
                        d.t_access_ns(),
                        d.area_um2() / base.area_um2(),
                    ));
                }
            }
        }
        rows
    });

    if let Some(rows) = rows {
        let mut csv = String::from(
            "depth,ports,design,macros,capacity_factor,sram_um2,logic_um2,t_access_ns,area_vs_1rw\n",
        );
        for r in &rows {
            let _ = writeln!(
                csv,
                "{},{},{},{},{:.3},{:.1},{:.1},{:.4},{:.3}",
                r.0, r.1, r.2, r.3, r.4, r.5, r.6, r.7, r.8
            );
        }
        report::write_file(Path::new("results/fig2_port_scaling.csv"), &csv).unwrap();
        println!("wrote results/fig2_port_scaling.csv ({} rows)", rows.len());
        // shape check: XOR capacity grows linearly, LVT multiplicatively
        let xor8r4w = rows.iter().find(|r| r.0 == 4096 && r.2 == "xor8r4w").unwrap();
        let lvt8r4w = rows.iter().find(|r| r.0 == 4096 && r.2 == "lvt8r4w").unwrap();
        println!(
            "  4096-deep 8R4W capacity: hb-ntx {:.2}x vs lvt {:.2}x (paper Fig 2: hierarchical flow scales linearly)",
            xor8r4w.4, lvt8r4w.4
        );
    }
    bench.finish();
}
