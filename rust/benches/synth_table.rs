//! Bench + regeneration harness for the **§III-A synthesis table**: cost
//! of every AMM organization across memory depth × port configuration
//! (the numbers the paper folds into Mem-Aladdin). Writes
//! `results/synth_table.csv`.
//!
//! `cargo bench --bench synth_table [-- --quick]`

use amm_dse::mem::MemKind;
use amm_dse::report;
use amm_dse::util::benchkit::Bench;
use std::fmt::Write as _;
use std::path::Path;

fn main() {
    let mut bench = Bench::from_args();
    let depths = [256u32, 1024, 4096, 16384, 65536];
    let widths = [8u32, 32, 64];
    let kinds: Vec<MemKind> = vec![
        MemKind::Banked { banks: 1 },
        MemKind::Banked { banks: 8 },
        MemKind::Banked { banks: 32 },
        MemKind::BankedDualPort { banks: 8 },
        MemKind::MultiPump { factor: 2 },
        MemKind::LvtAmm { read_ports: 2, write_ports: 1 },
        MemKind::LvtAmm { read_ports: 2, write_ports: 2 },
        MemKind::LvtAmm { read_ports: 4, write_ports: 2 },
        MemKind::XorAmm { read_ports: 2, write_ports: 1 },
        MemKind::XorAmm { read_ports: 2, write_ports: 2 },
        MemKind::XorAmm { read_ports: 4, write_ports: 2 },
        MemKind::XorAmm { read_ports: 8, write_ports: 4 },
        MemKind::CircuitMp { read_ports: 2, write_ports: 2 },
        MemKind::CircuitMp { read_ports: 4, write_ports: 2 },
    ];

    let n = (depths.len() * widths.len() * kinds.len()) as u64;
    let rows = bench.run("synth_table/build_all", Some(n), || {
        let mut rows = Vec::new();
        for &depth in &depths {
            for &width in &widths {
                for kind in &kinds {
                    let d = kind.build(depth, width);
                    rows.push((
                        kind.id(),
                        depth,
                        width,
                        d.area_um2(),
                        d.e_read_pj(),
                        d.e_write_pj(),
                        d.leak_uw(),
                        d.t_access_ns(),
                        d.macros,
                    ));
                }
            }
        }
        rows
    });

    if let Some(rows) = rows {
        let mut csv =
            String::from("design,depth,width,area_um2,e_read_pj,e_write_pj,leak_uw,t_access_ns,macros\n");
        for r in &rows {
            let _ = writeln!(
                csv,
                "{},{},{},{:.1},{:.4},{:.4},{:.2},{:.4},{}",
                r.0, r.1, r.2, r.3, r.4, r.5, r.6, r.7, r.8
            );
        }
        report::write_file(Path::new("results/synth_table.csv"), &csv).unwrap();
        println!("wrote results/synth_table.csv ({} rows)", rows.len());
    }
    bench.finish();
}
