//! Bench + regeneration harness for **Fig 4** (a–d): the area/power vs
//! cycles design-space exploration on FFT-Strided, GEMM-NCUBED, KMP and
//! MD-KNN. Timing measures the full sweep; the CSV series the paper
//! plots land in `results/fig4_<bench>.csv`.
//!
//! `cargo bench --bench fig4_dse [-- --quick] [-- <filter>]`

use amm_dse::dse::{self, Sweep};
use amm_dse::report;
use amm_dse::suite::{self, Scale};
use amm_dse::util::benchkit::Bench;
use std::path::Path;

fn main() {
    let mut bench = Bench::from_args();
    let sweep = Sweep::default();
    println!("fig4 sweep: {} design points per benchmark", sweep.configs().len());
    for name in suite::DSE_BENCHMARKS {
        let wl = suite::generate(name, Scale::Paper);
        let points = bench.run(
            &format!("fig4/{name}/sweep"),
            Some(sweep.configs().len() as u64),
            || sweep.run(&wl.trace),
        );
        if let Some(points) = points {
            let csv = format!("results/fig4_{name}.csv");
            report::write_file(Path::new(&csv), &report::fig4_csv(&points)).unwrap();
            let ratio = dse::performance_ratio(&points, 0.10);
            println!(
                "  {name}: best banking {:.0} ns, best AMM {:.0} ns, perf-ratio {:?} -> {csv}",
                dse::best_time(&points, |p| !p.is_amm),
                dse::best_time(&points, |p| p.is_amm),
                ratio
            );
        }
    }
    bench.finish();
}
