//! DSE coordinator: the L3 orchestration layer.
//!
//! Owns the process topology of a sweep run:
//!
//! * a **PJRT service thread** hosting the (non-`Send`) runtime, which
//!   receives batched SRAM-macro cost queries over a channel and answers
//!   with the AOT cost-model's outputs — design points are scored by the
//!   *same compiled artifact* the Python build produced, never by ad-hoc
//!   reimplementation (the pure-Rust mirror in [`crate::sram`] exists
//!   only as a fallback and cross-check);
//! * a pool of **scheduler workers** ([`crate::util::pool`]) that run the
//!   cycle-accurate simulation per design point;
//! * result aggregation into [`crate::dse::DesignPoint`]s.
//!
//! The coordinator is memory-model-agnostic: designs describe their own
//! macro shape ([`MemDesign::macro_ports`]) and cost composition
//! ([`MemDesign::restack`]), so registry-extension models batch through
//! the cost service exactly like the built-ins — no per-organization
//! `match` anywhere in this module.
//!
//! Batching policy: macro-cost queries are deduplicated through a
//! [`CostBatcher`] (many design points — and, across a campaign, many
//! *benchmarks* — share macro configurations) and evaluated in one PJRT
//! execute per scope: [`Coordinator::run_sweep`] batches one benchmark's
//! sweep, [`Coordinator::score_designs`] batches an arbitrary design
//! set, which is how [`crate::campaign`] scores an entire suite×sweep
//! campaign in a single batch. The measured dispatch overhead is
//! amortized to <1 µs per design point (see EXPERIMENTS.md §Perf).

use crate::dse::{self, DesignPoint, Sweep, SweepPoint};
use crate::error::{Error, Result};
use crate::mem::MemDesign;
use crate::runtime::{names, Runtime};
use crate::sram::MacroCost;
use crate::trace::Trace;
use crate::util::{log, pool};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// A macro-cost query: `[depth, width, read_ports, write_ports]`.
pub type MacroQuery = [f32; 4];

/// Requests accepted by the PJRT service thread.
enum Request {
    /// Evaluate a batch of macro queries; respond with one
    /// `[area, e_read, e_write, leak, t_access]` row per query.
    CostBatch(Vec<MacroQuery>, mpsc::Sender<Result<Vec<[f32; 5]>>>),
    /// Shut the service down.
    Stop,
}

/// Handle to the PJRT cost service. Clone-able across worker threads.
#[derive(Clone)]
pub struct CostService {
    tx: mpsc::Sender<Request>,
}

/// Where the cost numbers came from (reported in run summaries).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostBackend {
    /// AOT Pallas/JAX cost model via PJRT (the production path).
    Pjrt,
    /// Pure-Rust mirror (artifacts not built).
    RustFallback,
}

impl CostService {
    /// Spawn the service thread. Returns the handle, a join guard, and
    /// which backend is live. Falls back to the Rust mirror when the
    /// artifact is missing or PJRT fails to initialize.
    pub fn spawn(artifacts_dir: std::path::PathBuf) -> (CostService, ServiceGuard, CostBackend) {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<CostBackend>();
        let join = std::thread::Builder::new()
            .name("pjrt-cost-service".into())
            .spawn(move || service_main(artifacts_dir, rx, ready_tx))
            .expect("spawn pjrt service thread");
        let backend = ready_rx.recv().unwrap_or(CostBackend::RustFallback);
        (CostService { tx }, ServiceGuard { tx2: None, join: Some(join) }, backend)
    }

    /// Evaluate a batch of macro queries (blocking).
    pub fn cost_batch(&self, queries: Vec<MacroQuery>) -> Result<Vec<[f32; 5]>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Request::CostBatch(queries, rtx))
            .map_err(|_| Error::runtime("cost service stopped"))?;
        rrx.recv().map_err(|_| Error::runtime("cost service dropped reply"))?
    }

    /// Ask the service to stop (the guard also does this on drop).
    pub fn stop(&self) {
        let _ = self.tx.send(Request::Stop);
    }
}

/// Joins the service thread on drop.
pub struct ServiceGuard {
    tx2: Option<mpsc::Sender<Request>>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Drop for ServiceGuard {
    fn drop(&mut self) {
        if let Some(tx) = self.tx2.take() {
            let _ = tx.send(Request::Stop);
        }
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn service_main(
    dir: std::path::PathBuf,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<CostBackend>,
) {
    // Try to bring up PJRT + the cost artifact; otherwise run the mirror.
    let exe = match Runtime::with_dir(&dir) {
        Ok(rt) if rt.has_artifact(names::COST_MODEL) => match rt.load(names::COST_MODEL) {
            Ok(exe) => Some((rt, exe)),
            Err(e) => {
                log::warn(format!("cost model failed to compile ({e}); using Rust mirror"));
                None
            }
        },
        Ok(_) => {
            log::info("artifacts not built; cost service using Rust mirror");
            None
        }
        Err(e) => {
            // With the pjrt feature on, a client that fails to come up
            // is a real problem worth a warning; the stub build errors
            // here by design, so only whisper.
            let msg = format!("PJRT unavailable ({e}); cost service using Rust mirror");
            if cfg!(feature = "pjrt") {
                log::warn(msg);
            } else {
                log::info(msg);
            }
            None
        }
    };
    let backend = if exe.is_some() { CostBackend::Pjrt } else { CostBackend::RustFallback };
    let _ = ready.send(backend);

    while let Ok(req) = rx.recv() {
        match req {
            Request::Stop => break,
            Request::CostBatch(queries, reply) => {
                let result = match &exe {
                    Some((_rt, exe)) => pjrt_cost_batch(exe, &queries),
                    None => Ok(crate::sram::macro_cost_batch(&queries)),
                };
                let _ = reply.send(result);
            }
        }
    }
}

/// The artifact's batch size (must match `python/compile/aot.py`).
pub const COST_BATCH: usize = 1024;

fn pjrt_cost_batch(
    exe: &crate::runtime::Executable,
    queries: &[MacroQuery],
) -> Result<Vec<[f32; 5]>> {
    let mut out = Vec::with_capacity(queries.len());
    // Pad to the fixed batch the artifact was lowered for.
    for chunk in queries.chunks(COST_BATCH) {
        let mut flat = vec![0f32; COST_BATCH * 4];
        for (i, q) in chunk.iter().enumerate() {
            flat[i * 4..i * 4 + 4].copy_from_slice(q);
        }
        // Padding rows use a benign config (depth 4, width 1, 1R1W).
        for i in chunk.len()..COST_BATCH {
            flat[i * 4..i * 4 + 4].copy_from_slice(&[4.0, 1.0, 1.0, 1.0]);
        }
        let results = exe.run_f32(&[(&flat, &[COST_BATCH, 4])])?;
        let rows = &results[0]; // [COST_BATCH, 5] flattened
        if rows.len() != COST_BATCH * 5 {
            return Err(Error::runtime(format!("unexpected cost output size {}", rows.len())));
        }
        for i in 0..chunk.len() {
            out.push([
                rows[i * 5],
                rows[i * 5 + 1],
                rows[i * 5 + 2],
                rows[i * 5 + 3],
                rows[i * 5 + 4],
            ]);
        }
    }
    Ok(out)
}

/// Deduplicating accumulator for macro-cost queries.
///
/// Designs register their macro shape with [`CostBatcher::add`] and get
/// back a slot into the batch; identical shapes share a slot. The batch
/// is laid out in **first-seen order** and the key index is a
/// `BTreeMap`, so the layout is identical run to run — campaign JSONL
/// sinks and the resume golden test depend on byte-stable batches, and
/// hash-seeded layouts would also defeat PJRT input caching.
#[derive(Debug, Default)]
pub struct CostBatcher {
    unique: Vec<MacroQuery>,
    index: BTreeMap<[u32; 4], usize>,
}

impl CostBatcher {
    /// An empty batch.
    pub fn new() -> Self {
        CostBatcher::default()
    }

    /// Register a design's macro query; returns its slot in the batch.
    pub fn add(&mut self, d: &MemDesign) -> usize {
        let key = macro_key(d);
        match self.index.get(&key) {
            Some(&slot) => slot,
            None => {
                let slot = self.unique.len();
                self.unique
                    .push([key[0] as f32, key[1] as f32, key[2] as f32, key[3] as f32]);
                self.index.insert(key, slot);
                slot
            }
        }
    }

    /// Number of distinct macro configurations batched so far.
    pub fn len(&self) -> usize {
        self.unique.len()
    }

    /// True if nothing has been batched.
    pub fn is_empty(&self) -> bool {
        self.unique.is_empty()
    }

    /// The deduplicated queries, in first-seen order.
    pub fn into_queries(self) -> Vec<MacroQuery> {
        self.unique
    }
}

/// Coordinator for sweep runs.
pub struct Coordinator {
    cost: CostService,
    _guard: ServiceGuard,
    /// Which backend scored the designs.
    pub backend: CostBackend,
    threads: usize,
    /// Cost batches issued so far (observability: lets tests pin the
    /// "one batch per campaign" contract).
    batches: AtomicUsize,
}

impl Coordinator {
    /// Bring up the coordinator (PJRT service + worker pool sizing).
    pub fn new() -> Self {
        Self::with_artifacts(crate::runtime::artifacts_dir())
    }

    /// Coordinator rooted at a specific artifacts directory.
    pub fn with_artifacts(dir: std::path::PathBuf) -> Self {
        let (cost, guard, backend) = CostService::spawn(dir);
        Coordinator {
            cost,
            _guard: guard,
            backend,
            threads: pool::default_threads(),
            batches: AtomicUsize::new(0),
        }
    }

    /// Override the scheduler worker-thread count (0 = auto).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = if n == 0 { pool::default_threads() } else { n };
        self
    }

    /// Handle to the cost service (for benches/tests).
    pub fn cost_service(&self) -> &CostService {
        &self.cost
    }

    /// The configured scheduler worker-thread count (what sweeps and
    /// campaigns fall back to when neither they nor their sweep set an
    /// explicit count).
    pub fn worker_threads(&self) -> usize {
        self.threads
    }

    /// Cost batches issued by this coordinator so far. A well-batched
    /// caller issues one per scope: `run_sweep` one per benchmark sweep,
    /// a [`crate::campaign::Campaign`] one for its whole suite.
    pub fn batches_issued(&self) -> usize {
        self.batches.load(Ordering::Relaxed)
    }

    /// Campaign-scoped cost batching: deduplicate the macro queries of
    /// an arbitrary design set (any mix of benchmarks, models and word
    /// sizes), evaluate them in **one** batch through the cost service,
    /// and patch each design via [`MemDesign::restack`]. Scoring an
    /// empty set issues no batch.
    pub fn score_designs<'a>(
        &self,
        designs: impl IntoIterator<Item = &'a mut MemDesign>,
    ) -> Result<()> {
        let mut designs: Vec<&'a mut MemDesign> = designs.into_iter().collect();
        if designs.is_empty() {
            return Ok(());
        }
        let mut batcher = CostBatcher::new();
        let slots: Vec<usize> = designs.iter().map(|d| batcher.add(&**d)).collect();
        let costs = self.cost.cost_batch(batcher.into_queries())?;
        self.batches.fetch_add(1, Ordering::Relaxed);
        for (d, slot) in designs.into_iter().zip(slots) {
            d.restack(macro_cost_row(costs[slot]));
        }
        Ok(())
    }

    /// Run a sweep over one trace, scoring every design's memory system
    /// through the cost service in one deduplicated batch, then
    /// scheduling in parallel on the worker pool.
    pub fn run_sweep(&self, trace: &Trace, sweep: &Sweep) -> Result<Vec<DesignPoint>> {
        let points = sweep.points();

        // 1. Build every design's macro plan in Rust (one build per
        //    distinct (model, word-size) run, cloned across knob
        //    variants; the builder memoizes the footprint depth).
        let mut designs = dse::build_designs(trace, &points);

        // 2. One deduplicated cost batch, patched into each design —
        //    the design itself knows how to re-stack the numbers.
        self.score_designs(designs.iter_mut())?;

        // 3. Schedule in parallel. The sweep's explicit thread request
        //    wins over the coordinator's default (lets Explorer::threads
        //    / config `threads = N` work through a shared coordinator
        //    too). Scheduling runs on the compiled-trace engine: one
        //    `CompiledTrace` per word-size group, one reusable
        //    `SimArena` per worker thread.
        let patched: Vec<(SweepPoint, MemDesign)> = points.into_iter().zip(designs).collect();
        let threads = if sweep.threads != 0 { sweep.threads } else { self.threads };
        Ok(dse::evaluate_designs(trace, &patched, threads))
    }
}

/// Unpack one cost-service row into a [`MacroCost`].
fn macro_cost_row(row: [f32; 5]) -> MacroCost {
    MacroCost {
        area_um2: row[0],
        e_read_pj: row[1],
        e_write_pj: row[2],
        leak_uw: row[3],
        t_access_ns: row[4],
    }
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

/// The (depth, width, rports, wports) of the design's base macro — what
/// the memory compiler (and the AOT cost model) is asked for.
fn macro_key(d: &MemDesign) -> [u32; 4] {
    [d.macro_depth, d.width, d.macro_ports.0, d.macro_ports.1]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suite::{self, Scale};

    #[test]
    fn fallback_backend_matches_direct_evaluation() {
        // Point the coordinator at an empty dir → Rust mirror; sweep
        // results must equal dse::Sweep::run exactly.
        let tmp = std::env::temp_dir().join("amm_dse_coord_test");
        let _ = std::fs::create_dir_all(&tmp);
        let coord = Coordinator::with_artifacts(tmp);
        assert_eq!(coord.backend, CostBackend::RustFallback);
        let wl = suite::generate("stencil2d", Scale::Tiny);
        let sweep = Sweep::quick();
        let via_coord = coord.run_sweep(&wl.trace, &sweep).unwrap();
        let direct = sweep.run(&wl.trace);
        assert_eq!(via_coord.len(), direct.len());
        for (a, b) in via_coord.iter().zip(&direct) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.out.cycles, b.out.cycles, "{}", a.id);
            let rel = (a.out.area_um2 - b.out.area_um2).abs() / b.out.area_um2;
            assert!(rel < 1e-5, "{}: {} vs {}", a.id, a.out.area_um2, b.out.area_um2);
            let relp = (a.out.power_mw - b.out.power_mw).abs() / b.out.power_mw;
            assert!(relp < 1e-4, "{}: power {} vs {}", a.id, a.out.power_mw, b.out.power_mw);
        }
    }

    #[test]
    fn cost_service_survives_multiple_batches() {
        let tmp = std::env::temp_dir().join("amm_dse_coord_test2");
        let _ = std::fs::create_dir_all(&tmp);
        let (svc, _guard, backend) = CostService::spawn(tmp);
        assert_eq!(backend, CostBackend::RustFallback);
        for _ in 0..3 {
            let out = svc.cost_batch(vec![[1024.0, 32.0, 1.0, 1.0]; 10]).unwrap();
            assert_eq!(out.len(), 10);
            assert!(out[0][0] > 0.0);
        }
        svc.stop();
    }

    #[test]
    fn worker_threads_reflect_the_builder_setting() {
        let tmp = std::env::temp_dir().join("amm_dse_coord_threads");
        let _ = std::fs::create_dir_all(&tmp);
        let coord = Coordinator::with_artifacts(tmp.clone()).threads(3);
        assert_eq!(coord.worker_threads(), 3);
        let auto = Coordinator::with_artifacts(tmp).threads(0);
        assert_eq!(auto.worker_threads(), pool::default_threads());
    }

    #[test]
    fn cost_batcher_dedupes_and_keeps_first_seen_order() {
        let d1 = crate::mem::MemKind::Banked { banks: 1 }.build(1024, 32);
        let d2 = crate::mem::MemKind::Banked { banks: 4 }.build(1024, 32);
        let mut b = CostBatcher::new();
        assert!(b.is_empty());
        let s1 = b.add(&d1);
        let s2 = b.add(&d2);
        let s1_again = b.add(&d1);
        assert_eq!(s1, 0);
        assert_eq!(s2, 1);
        assert_eq!(s1_again, s1, "identical macro shapes share a slot");
        assert_eq!(b.len(), 2);
        let q = b.into_queries();
        assert_eq!(q.len(), 2);
        assert_eq!(q[0][0], d1.macro_depth as f32, "first-seen order is preserved");
    }

    #[test]
    fn score_designs_counts_one_batch_and_matches_run_sweep_restack() {
        let tmp = std::env::temp_dir().join("amm_dse_coord_score");
        let _ = std::fs::create_dir_all(&tmp);
        let coord = Coordinator::with_artifacts(tmp);
        assert_eq!(coord.batches_issued(), 0);
        coord.score_designs(std::iter::empty()).unwrap();
        assert_eq!(coord.batches_issued(), 0, "empty sets issue no batch");
        let mut designs = vec![
            crate::mem::MemKind::Banked { banks: 4 }.build(2048, 64),
            crate::mem::MemKind::XorAmm { read_ports: 2, write_ports: 1 }.build(2048, 64),
        ];
        let before = designs.clone();
        coord.score_designs(designs.iter_mut()).unwrap();
        assert_eq!(coord.batches_issued(), 1);
        // RustFallback scoring re-derives the same macro cost the build
        // composed, so restack is (numerically) an identity here.
        for (d, b) in designs.iter().zip(&before) {
            let rel = (d.sram.area_um2 - b.sram.area_um2).abs() / b.sram.area_um2;
            assert!(rel < 1e-5, "{}: {} vs {}", d.id, d.sram.area_um2, b.sram.area_um2);
        }
    }

    #[test]
    fn extension_models_flow_through_the_batched_cost_path() {
        // extra_models resolve via the registry and batch through the
        // cost service like any built-in — no coordinator edits needed.
        let tmp = std::env::temp_dir().join("amm_dse_coord_test3");
        let _ = std::fs::create_dir_all(&tmp);
        let coord = Coordinator::with_artifacts(tmp);
        let wl = suite::generate("stencil2d", Scale::Tiny);
        let mut sweep = Sweep::quick();
        sweep.extra_models = vec!["cmp2r2w".into()];
        let points = coord.run_sweep(&wl.trace, &sweep).unwrap();
        assert!(points.iter().any(|p| p.mem_id == "cmp2r2w"));
    }
}
