//! DSE coordinator: the L3 orchestration layer.
//!
//! Owns the process topology of a sweep run:
//!
//! * a tiered [`CostStack`] (see [`crate::cost`]): an in-process memo
//!   and an optional persistent cost store in front of the **PJRT
//!   service thread** hosting the (non-`Send`) runtime, which receives
//!   batched SRAM-macro cost queries over a channel and answers with
//!   the AOT cost-model's outputs — design points are scored by the
//!   *same compiled artifact* the Python build produced, never by
//!   ad-hoc reimplementation (the pure-Rust mirror in [`crate::sram`]
//!   exists only as a fallback and cross-check);
//! * a pool of **scheduler workers** ([`crate::util::pool`]) that run the
//!   cycle-accurate simulation per design point;
//! * result aggregation into [`crate::dse::DesignPoint`]s.
//!
//! The coordinator is memory-model-agnostic: designs describe their own
//! macro shape ([`MemDesign::macro_ports`]) and cost composition
//! ([`MemDesign::restack`]), so registry-extension models batch through
//! the cost service exactly like the built-ins — no per-organization
//! `match` anywhere in this module.
//!
//! Batching policy: macro-cost queries are deduplicated through a
//! [`CostBatcher`] (many design points — and, across a campaign, many
//! *benchmarks* — share macro configurations) and resolved through the
//! stack in one call per scope: [`Coordinator::run_sweep`] batches one
//! benchmark's sweep, [`Coordinator::score_designs`] batches an
//! arbitrary design set, which is how [`crate::campaign`] scores an
//! entire suite×sweep campaign. Only the stack's *misses* reach the
//! runtime backend — a shape seen earlier in the process (memo) or
//! persisted by any previous run (store) costs a map lookup, and
//! [`Coordinator::batches_issued`] counts **backend** batches, so a
//! fully warm scope issues zero. The measured dispatch overhead is
//! amortized to <1 µs per design point (see EXPERIMENTS.md §Perf).

use crate::cost::{self, CostCounters, CostStack};
use crate::dse::{self, DesignPoint, Sweep, SweepPoint};
use crate::error::Result;
use crate::mem::MemDesign;
use crate::sim::{SimCounters, SimStack};
use crate::trace::Trace;
use crate::util::pool;
use std::path::Path;

// Compat re-exports: these types lived here before the cost subsystem
// was extracted (tests, benches and the python build reference them
// under both paths).
pub use crate::cost::{
    macro_cost_row, CostBackend, CostBatcher, CostService, MacroQuery, ServiceGuard, COST_BATCH,
};

/// Coordinator for sweep runs.
pub struct Coordinator {
    cost: CostService,
    stack: CostStack,
    sim: SimStack,
    _guard: ServiceGuard,
    /// Which backend scored the designs.
    pub backend: CostBackend,
    threads: usize,
}

impl Coordinator {
    /// Bring up the coordinator (PJRT service + worker pool sizing).
    pub fn new() -> Self {
        Self::with_artifacts(crate::runtime::artifacts_dir())
    }

    /// Coordinator rooted at a specific artifacts directory.
    pub fn with_artifacts(dir: std::path::PathBuf) -> Self {
        let (cost, guard, backend) = CostService::spawn(dir.clone());
        let fingerprint = cost::backend_fingerprint(backend, &dir);
        // The sim stack shares the cost fingerprint: every SimOutput
        // folds cost-patched numbers in, so simulation rows are only
        // reusable within the scoring context that produced them.
        let sim = SimStack::new(fingerprint.clone());
        let stack = CostStack::new(Box::new(cost.clone()), fingerprint);
        Coordinator {
            cost,
            stack,
            sim,
            _guard: guard,
            backend,
            threads: pool::default_threads(),
        }
    }

    /// Override the scheduler worker-thread count (0 = auto).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = if n == 0 { pool::default_threads() } else { n };
        self
    }

    /// Handle to the cost service (for benches/tests).
    pub fn cost_service(&self) -> &CostService {
        &self.cost
    }

    /// The tiered cost stack every scoring call resolves through.
    pub fn cost_stack(&self) -> &CostStack {
        &self.stack
    }

    /// Attach (open or create) the persistent cost store at `path` —
    /// the warm-start tier between the in-process memo and the runtime
    /// backend. See [`CostStack::open_store`] for replacement rules.
    pub fn open_cost_store(&self, path: &Path) -> Result<()> {
        self.stack.open_store(path)
    }

    /// Hit/miss/batch accounting for every scoring call so far.
    pub fn cost_counters(&self) -> CostCounters {
        self.stack.counters()
    }

    /// The tiered simulation-result stack campaigns probe before lane
    /// packing (see [`crate::sim`]).
    pub fn sim_stack(&self) -> &SimStack {
        &self.sim
    }

    /// Attach (open or create) the persistent simulation store at
    /// `path` — the warm-start tier that lets a campaign skip the
    /// scheduler itself. See [`SimStack::open_store`] for replacement
    /// rules.
    pub fn open_sim_store(&self, path: &Path) -> Result<()> {
        self.sim.open_store(path)
    }

    /// Hit/miss accounting for every simulation probe so far.
    pub fn sim_counters(&self) -> SimCounters {
        self.sim.counters()
    }

    /// The configured scheduler worker-thread count (what sweeps and
    /// campaigns fall back to when neither they nor their sweep set an
    /// explicit count).
    pub fn worker_threads(&self) -> usize {
        self.threads
    }

    /// Runtime-backend cost batches issued by this coordinator so far.
    /// A well-batched caller triggers at most one per scope (`run_sweep`
    /// per benchmark sweep, a [`crate::campaign::Campaign`] per suite) —
    /// and **zero** when the memo or a warmed cost store absorbs every
    /// query (tests pin both contracts).
    pub fn batches_issued(&self) -> usize {
        self.stack.counters().batches
    }

    /// Campaign-scoped cost batching: deduplicate the macro queries of
    /// an arbitrary design set (any mix of benchmarks, models and word
    /// sizes), resolve them through the tiered stack — misses are
    /// evaluated in **one** batch through the cost service — and patch
    /// each design via [`MemDesign::restack`]. Scoring an empty set
    /// touches nothing.
    pub fn score_designs<'a>(
        &self,
        designs: impl IntoIterator<Item = &'a mut MemDesign>,
    ) -> Result<()> {
        let mut designs: Vec<&'a mut MemDesign> = designs.into_iter().collect();
        if designs.is_empty() {
            return Ok(());
        }
        let mut batcher = CostBatcher::new();
        let slots: Vec<usize> = designs.iter().map(|d| batcher.add(&**d)).collect();
        let costs = cost::CostProvider::cost_batch(&self.stack, &batcher.into_queries())?;
        for (d, slot) in designs.into_iter().zip(slots) {
            d.restack(macro_cost_row(costs[slot]));
        }
        Ok(())
    }

    /// Run a sweep over one trace, scoring every design's memory system
    /// through the cost stack in one deduplicated batch, then
    /// scheduling in parallel on the worker pool.
    pub fn run_sweep(&self, trace: &Trace, sweep: &Sweep) -> Result<Vec<DesignPoint>> {
        let points = sweep.points();

        // 1. Build every design's macro plan in Rust (one build per
        //    distinct (model, word-size) run, cloned across knob
        //    variants; the builder memoizes the footprint depth).
        let mut designs = dse::build_designs(trace, &points);

        // 2. One deduplicated cost batch, patched into each design —
        //    the design itself knows how to re-stack the numbers.
        self.score_designs(designs.iter_mut())?;

        // 3. Schedule in parallel. The sweep's explicit thread request
        //    wins over the coordinator's default (lets Explorer::threads
        //    / config `threads = N` work through a shared coordinator
        //    too). Scheduling runs on the compiled-trace engines: one
        //    `CompiledTrace` per word-size group, compatible points
        //    lane-batched per the sweep's `lanes` knob, one reusable
        //    arena pair per worker thread.
        let patched: Vec<(SweepPoint, MemDesign)> = points.into_iter().zip(designs).collect();
        let threads = if sweep.threads != 0 { sweep.threads } else { self.threads };
        Ok(dse::evaluate_designs(trace, &patched, threads, sweep.lanes))
    }
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suite::{self, Scale};

    #[test]
    fn fallback_backend_matches_direct_evaluation() {
        // Point the coordinator at an empty dir → Rust mirror; sweep
        // results must equal dse::Sweep::run exactly.
        let tmp = std::env::temp_dir().join("amm_dse_coord_test");
        let _ = std::fs::create_dir_all(&tmp);
        let coord = Coordinator::with_artifacts(tmp);
        assert_eq!(coord.backend, CostBackend::RustFallback);
        let wl = suite::generate("stencil2d", Scale::Tiny);
        let sweep = Sweep::quick();
        let via_coord = coord.run_sweep(&wl.trace, &sweep).unwrap();
        let direct = sweep.run(&wl.trace);
        assert_eq!(via_coord.len(), direct.len());
        for (a, b) in via_coord.iter().zip(&direct) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.out.cycles, b.out.cycles, "{}", a.id);
            let rel = (a.out.area_um2 - b.out.area_um2).abs() / b.out.area_um2;
            assert!(rel < 1e-5, "{}: {} vs {}", a.id, a.out.area_um2, b.out.area_um2);
            let relp = (a.out.power_mw - b.out.power_mw).abs() / b.out.power_mw;
            assert!(relp < 1e-4, "{}: power {} vs {}", a.id, a.out.power_mw, b.out.power_mw);
        }
    }

    #[test]
    fn worker_threads_reflect_the_builder_setting() {
        let tmp = std::env::temp_dir().join("amm_dse_coord_threads");
        let _ = std::fs::create_dir_all(&tmp);
        let coord = Coordinator::with_artifacts(tmp.clone()).threads(3);
        assert_eq!(coord.worker_threads(), 3);
        let auto = Coordinator::with_artifacts(tmp).threads(0);
        assert_eq!(auto.worker_threads(), pool::default_threads());
    }

    #[test]
    fn cost_batcher_dedupes_and_keeps_first_seen_order() {
        let d1 = crate::mem::MemKind::Banked { banks: 1 }.build(1024, 32);
        let d2 = crate::mem::MemKind::Banked { banks: 4 }.build(1024, 32);
        let mut b = CostBatcher::new();
        assert!(b.is_empty());
        let s1 = b.add(&d1);
        let s2 = b.add(&d2);
        let s1_again = b.add(&d1);
        assert_eq!(s1, 0);
        assert_eq!(s2, 1);
        assert_eq!(s1_again, s1, "identical macro shapes share a slot");
        assert_eq!(b.len(), 2);
        let q = b.into_queries();
        assert_eq!(q.len(), 2);
        assert_eq!(q[0][0], d1.macro_depth as f32, "first-seen order is preserved");
    }

    #[test]
    fn score_designs_counts_one_batch_and_matches_run_sweep_restack() {
        let tmp = std::env::temp_dir().join("amm_dse_coord_score");
        let _ = std::fs::create_dir_all(&tmp);
        let coord = Coordinator::with_artifacts(tmp);
        assert_eq!(coord.batches_issued(), 0);
        coord.score_designs(std::iter::empty()).unwrap();
        assert_eq!(coord.batches_issued(), 0, "empty sets issue no batch");
        let mut designs = vec![
            crate::mem::MemKind::Banked { banks: 4 }.build(2048, 64),
            crate::mem::MemKind::XorAmm { read_ports: 2, write_ports: 1 }.build(2048, 64),
        ];
        let before = designs.clone();
        coord.score_designs(designs.iter_mut()).unwrap();
        assert_eq!(coord.batches_issued(), 1);
        // RustFallback scoring re-derives the same macro cost the build
        // composed, so restack is (numerically) an identity here.
        for (d, b) in designs.iter().zip(&before) {
            let rel = (d.sram.area_um2 - b.sram.area_um2).abs() / b.sram.area_um2;
            assert!(rel < 1e-5, "{}: {} vs {}", d.id, d.sram.area_um2, b.sram.area_um2);
        }
        // the memo tier absorbs a repeat of the same shapes: still one
        // backend batch, and the restacked numbers are identical
        let mut again = before.clone();
        coord.score_designs(again.iter_mut()).unwrap();
        assert_eq!(coord.batches_issued(), 1, "memo-warm repeat must not re-batch");
        let c = coord.cost_counters();
        assert_eq!(c.memo_hits, 2, "{c:?}");
        for (d, b) in again.iter().zip(&designs) {
            assert_eq!(d.sram.area_um2.to_bits(), b.sram.area_um2.to_bits(), "{}", d.id);
        }
    }

    #[test]
    fn extension_models_flow_through_the_batched_cost_path() {
        // extra_models resolve via the registry and batch through the
        // cost service like any built-in — no coordinator edits needed.
        let tmp = std::env::temp_dir().join("amm_dse_coord_test3");
        let _ = std::fs::create_dir_all(&tmp);
        let coord = Coordinator::with_artifacts(tmp);
        let wl = suite::generate("stencil2d", Scale::Tiny);
        let mut sweep = Sweep::quick();
        sweep.extra_models = vec!["cmp2r2w".into()];
        let points = coord.run_sweep(&wl.trace, &sweep).unwrap();
        assert!(points.iter().any(|p| p.mem_id == "cmp2r2w"));
    }

    #[test]
    fn coordinator_fingerprint_is_the_mirror_on_fallback() {
        let tmp = std::env::temp_dir().join("amm_dse_coord_fp");
        let _ = std::fs::create_dir_all(&tmp);
        let coord = Coordinator::with_artifacts(tmp);
        assert_eq!(coord.backend, CostBackend::RustFallback);
        assert!(
            coord.cost_stack().fingerprint().starts_with("rust-mirror/"),
            "{}",
            coord.cost_stack().fingerprint()
        );
    }
}
