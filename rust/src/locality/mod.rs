//! Weinberg spatial-locality metric (paper §IV-B, eq. 1).
//!
//! `L_spatial = Σ_{stride=1..∞} P(stride) / stride`
//!
//! where strides are the *byte* differences between consecutive dynamic
//! addresses issued by the same static load/store instruction. Byte
//! granularity is what makes the paper's observation work: byte-oriented
//! stride-one code (KMP, AES) scores ≈1, while double-precision kernels
//! have a minimum stride of 8 bytes and score ≤ 1/8 (§IV-B).

use crate::trace::{OpKind, Trace};
use std::collections::{BTreeMap, HashMap};

/// Stride histogram for one static instruction site.
///
/// Maps are `BTreeMap`, not `HashMap`, on purpose: locality is a sum of
/// floats over these maps, and summation order changes the low bits of
/// the result. Ordered maps make every locality figure — and therefore
/// campaign JSONL sinks and fig-5 CSV goldens — byte-stable run to run.
#[derive(Clone, Debug, Default)]
pub struct SiteStats {
    /// Dynamic accesses observed.
    pub accesses: u64,
    /// stride(bytes) → count; only positive strides accumulate locality
    /// (Weinberg's definition ignores non-forward reuse).
    pub strides: BTreeMap<u64, u64>,
    /// Transitions with zero or negative stride (counted in the
    /// probability denominator, contributing 0 locality).
    pub non_forward: u64,
}

impl SiteStats {
    /// Weinberg locality of this site.
    pub fn locality(&self) -> f64 {
        let total: u64 = self.strides.values().sum::<u64>() + self.non_forward;
        if total == 0 {
            return 0.0;
        }
        self.strides
            .iter()
            .map(|(&stride, &count)| (count as f64 / total as f64) / stride as f64)
            .sum()
    }
}

/// Whole-trace locality report.
#[derive(Clone, Debug, Default)]
pub struct LocalityReport {
    /// Per-site statistics (site id → stats), ordered by site id so
    /// iteration (and the float sums built from it) is deterministic.
    pub sites: BTreeMap<u32, SiteStats>,
    /// Total dynamic memory accesses.
    pub total_accesses: u64,
}

impl LocalityReport {
    /// Access-weighted mean of per-site localities — the benchmark's
    /// `L_spatial` as plotted in Fig 5.
    pub fn spatial_locality(&self) -> f64 {
        if self.total_accesses == 0 {
            return 0.0;
        }
        self.sites
            .values()
            .map(|s| s.locality() * s.accesses as f64)
            .sum::<f64>()
            / self.total_accesses as f64
    }

    /// Fraction of forward transitions that are exactly stride-1 bytes
    /// (diagnostic for the KMP/AES "stride-one code" claim).
    pub fn stride1_fraction(&self) -> f64 {
        let mut s1 = 0u64;
        let mut total = 0u64;
        for site in self.sites.values() {
            s1 += site.strides.get(&1).copied().unwrap_or(0);
            total += site.strides.values().sum::<u64>() + site.non_forward;
        }
        if total == 0 {
            0.0
        } else {
            s1 as f64 / total as f64
        }
    }
}

/// Analyze a trace: group dynamic accesses by static site (in program
/// order) and histogram consecutive byte strides.
pub fn analyze(trace: &Trace) -> LocalityReport {
    let mut sites: BTreeMap<u32, SiteStats> = BTreeMap::new();
    let mut last_addr: HashMap<u32, u64> = HashMap::new();
    let mut total = 0u64;
    for node in &trace.nodes {
        let (array, index) = match node.kind {
            OpKind::Load { array, index } | OpKind::Store { array, index } => (array, index),
            OpKind::Alu(_) => continue,
        };
        let addr = trace.arrays[array as usize].byte_addr(index);
        total += 1;
        let stats = sites.entry(node.site).or_default();
        stats.accesses += 1;
        if let Some(&prev) = last_addr.get(&node.site) {
            if addr > prev {
                *stats.strides.entry(addr - prev).or_insert(0) += 1;
            } else {
                stats.non_forward += 1;
            }
        }
        last_addr.insert(node.site, addr);
    }
    LocalityReport { sites, total_accesses: total }
}

/// Convenience: analyze a named benchmark at a scale.
pub fn benchmark_locality(name: &str, scale: crate::suite::Scale) -> f64 {
    analyze(&crate::suite::generate(name, scale).trace).spatial_locality()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suite::{self, Scale};
    use crate::trace::TraceBuilder;

    #[test]
    fn stride1_bytes_scores_one() {
        let mut b = TraceBuilder::new();
        let a = b.array("t", 1, 128);
        b.site(0);
        for i in 0..128 {
            b.load(a, i);
        }
        let rep = analyze(&b.finish());
        let l = rep.spatial_locality();
        assert!((l - 1.0).abs() < 0.02, "l={l}");
        assert!(rep.stride1_fraction() > 0.98);
    }

    #[test]
    fn stride8_bytes_scores_eighth() {
        let mut b = TraceBuilder::new();
        let a = b.array("d", 8, 128);
        b.site(0);
        for i in 0..128 {
            b.load(a, i);
        }
        let l = analyze(&b.finish()).spatial_locality();
        assert!((l - 0.125).abs() < 0.01, "l={l}");
    }

    #[test]
    fn random_access_scores_near_zero() {
        let mut b = TraceBuilder::new();
        let a = b.array("d", 8, 4096);
        b.site(0);
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..512 {
            b.load(a, rng.below(4096) as u32);
        }
        let l = analyze(&b.finish()).spatial_locality();
        assert!(l < 0.05, "l={l}");
    }

    #[test]
    fn per_site_separation() {
        // One stride-1 site + one random site: weighted mean in between.
        let mut b = TraceBuilder::new();
        let a = b.array("t", 1, 4096);
        let mut rng = crate::util::rng::Rng::new(9);
        for i in 0..256 {
            b.site(0);
            b.load(a, i);
            b.site(1);
            b.load(a, rng.below(4096) as u32);
        }
        let rep = analyze(&b.finish());
        let l = rep.spatial_locality();
        assert!(l > 0.4 && l < 0.6, "l={l}");
    }

    #[test]
    fn paper_ordering_kmp_high_fft_low() {
        // The paper's core empirical fact (§IV-B / Fig 5).
        let kmp = benchmark_locality("kmp", Scale::Tiny);
        let aes = benchmark_locality("aes", Scale::Tiny);
        let fft = benchmark_locality("fft", Scale::Tiny);
        let gemm = benchmark_locality("gemm", Scale::Tiny);
        let md = benchmark_locality("md-knn", Scale::Tiny);
        assert!(kmp > 0.5, "kmp={kmp}");
        assert!(aes > 0.3, "aes={aes}");
        assert!(fft < 0.3, "fft={fft}");
        assert!(gemm < 0.3, "gemm={gemm}");
        assert!(md < 0.3, "md={md}");
        assert!(kmp > fft && kmp > gemm && kmp > md);
    }

    #[test]
    fn locality_is_bit_deterministic_across_analyses() {
        // Ordered maps make the float summation order fixed, so two
        // independent analyses of the same trace agree to the last bit
        // (campaign sinks and fig-5 goldens rely on this).
        let wl = suite::generate("spmv", Scale::Tiny);
        let a = analyze(&wl.trace);
        let b = analyze(&wl.trace);
        assert_eq!(a.spatial_locality().to_bits(), b.spatial_locality().to_bits());
        assert_eq!(a.stride1_fraction().to_bits(), b.stride1_fraction().to_bits());
        let sites_a: Vec<u32> = a.sites.keys().copied().collect();
        let sites_b: Vec<u32> = b.sites.keys().copied().collect();
        assert_eq!(sites_a, sites_b, "site order must be stable");
    }

    #[test]
    fn empty_trace_is_zero() {
        let t = TraceBuilder::new().finish();
        assert_eq!(analyze(&t).spatial_locality(), 0.0);
    }

    #[test]
    fn all_benchmarks_in_unit_interval() {
        for name in suite::ALL_BENCHMARKS {
            let l = benchmark_locality(name, Scale::Tiny);
            assert!((0.0..=1.0).contains(&l), "{name}: {l}");
        }
    }
}
