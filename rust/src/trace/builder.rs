//! Trace construction with automatic dependence tracking.
//!
//! Benchmarks drive this builder while *actually executing* their
//! algorithm; the builder records the DDG. Register (value) dependences
//! are explicit — `alu(kind, deps)` names the producing nodes — and
//! memory dependences (RAW, WAR, WAW) are inferred per exact address,
//! exactly as Aladdin's dynamic-trace analysis does.

use super::{AluKind, ArrayInfo, Node, NodeId, OpKind, Trace};
use std::collections::HashMap;

/// Per-address dependence state.
#[derive(Default)]
struct Cell {
    last_store: Option<NodeId>,
    /// Loads since the last store (WAR sources for the next store).
    readers: Vec<NodeId>,
}

/// Incrementally builds a [`Trace`].
pub struct TraceBuilder {
    arrays: Vec<ArrayInfo>,
    nodes: Vec<Node>,
    /// Edge list (from, to); deduplicated on finish.
    edges: Vec<(NodeId, NodeId)>,
    cells: HashMap<(u16, u32), Cell>,
    site: u32,
    iter: u32,
    next_base: u64,
}

impl Default for TraceBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceBuilder {
    /// New empty builder.
    pub fn new() -> Self {
        TraceBuilder {
            arrays: Vec::new(),
            nodes: Vec::new(),
            edges: Vec::new(),
            cells: HashMap::new(),
            site: 0,
            iter: 0,
            next_base: 0,
        }
    }

    /// Declare an array; returns its id. Arrays are laid out back-to-back
    /// (64-byte aligned) in a flat address space for the locality metric.
    pub fn array(&mut self, name: &str, elem_bytes: u32, length: u32) -> u16 {
        let id = self.arrays.len() as u16;
        let base = self.next_base;
        self.next_base += ((length as u64 * elem_bytes as u64) + 63) & !63;
        self.arrays.push(ArrayInfo { name: name.to_string(), elem_bytes, length, base });
        id
    }

    /// Set the static-site id for subsequently recorded ops. Each distinct
    /// load/store instruction in the source should use a distinct site.
    pub fn site(&mut self, site: u32) {
        self.site = site;
    }

    /// Advance the innermost-loop iteration counter (drives the unroll
    /// constraint). Call once per innermost iteration, monotonically.
    pub fn next_iter(&mut self) {
        self.iter += 1;
    }

    /// Current iteration counter.
    pub fn cur_iter(&self) -> u32 {
        self.iter
    }

    fn push(&mut self, kind: OpKind, deps: &[NodeId]) -> NodeId {
        let id = self.nodes.len() as NodeId;
        self.nodes.push(Node { kind, site: self.site, iter: self.iter });
        for &d in deps {
            debug_assert!(d < id, "dependence must reference an earlier node");
            self.edges.push((d, id));
        }
        id
    }

    /// Record a load of `array[index]`; `deps` are address-computation
    /// producers (may be empty — scratchpad address generation is free in
    /// Aladdin when indices are affine).
    pub fn load(&mut self, array: u16, index: u32) -> NodeId {
        self.load_dep(array, index, &[])
    }

    /// Load with explicit extra dependences (e.g. indirect index value).
    pub fn load_dep(&mut self, array: u16, index: u32, deps: &[NodeId]) -> NodeId {
        debug_assert!(
            index < self.arrays[array as usize].length,
            "load OOB: {}[{}]",
            self.arrays[array as usize].name,
            index
        );
        let id = self.push(OpKind::Load { array, index }, deps);
        let cell = self.cells.entry((array, index)).or_default();
        if let Some(st) = cell.last_store {
            self.edges.push((st, id)); // RAW
        }
        cell.readers.push(id);
        id
    }

    /// Record a store of `array[index]` whose value depends on `deps`.
    pub fn store(&mut self, array: u16, index: u32, deps: &[NodeId]) -> NodeId {
        debug_assert!(
            index < self.arrays[array as usize].length,
            "store OOB: {}[{}]",
            self.arrays[array as usize].name,
            index
        );
        let id = self.push(OpKind::Store { array, index }, deps);
        let cell = self.cells.entry((array, index)).or_default();
        if let Some(st) = cell.last_store {
            self.edges.push((st, id)); // WAW
        }
        for &r in &cell.readers {
            self.edges.push((r, id)); // WAR
        }
        cell.readers.clear();
        cell.last_store = Some(id);
        id
    }

    /// Record an ALU op depending on `deps`.
    pub fn alu(&mut self, kind: AluKind, deps: &[NodeId]) -> NodeId {
        self.push(OpKind::Alu(kind), deps)
    }

    /// Finalize into a [`Trace`] (CSR successor lists + pred counts).
    pub fn finish(mut self) -> Trace {
        // Dedup edges (a store may be both value-dep and WAW target, etc.)
        self.edges.sort_unstable();
        self.edges.dedup();

        let n = self.nodes.len();
        let mut succ_off = vec![0u32; n + 1];
        for &(from, _) in &self.edges {
            succ_off[from as usize + 1] += 1;
        }
        for i in 0..n {
            succ_off[i + 1] += succ_off[i];
        }
        let mut cursor = succ_off.clone();
        let mut succ = vec![0u32; self.edges.len()];
        let mut pred_count = vec![0u32; n];
        for &(from, to) in &self.edges {
            succ[cursor[from as usize] as usize] = to;
            cursor[from as usize] += 1;
            pred_count[to as usize] += 1;
        }
        let mut mem_op_count = 0u32;
        let mut alu_kind_counts = [0u64; 8];
        for nd in &self.nodes {
            match nd.kind {
                OpKind::Alu(k) => alu_kind_counts[k.index()] += 1,
                _ => mem_op_count += 1,
            }
        }
        let t = Trace {
            arrays: self.arrays,
            nodes: self.nodes,
            succ_off,
            succ,
            pred_count,
            mem_op_count,
            alu_kind_counts,
        };
        debug_assert!(t.validate().is_ok(), "{:?}", t.validate());
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::OpKind;

    #[test]
    fn raw_war_waw_edges() {
        let mut b = TraceBuilder::new();
        let a = b.array("a", 4, 8);
        let s0 = b.store(a, 3, &[]); // first store
        let l0 = b.load(a, 3); //        RAW from s0
        let s1 = b.store(a, 3, &[]); //  WAW from s0, WAR from l0
        let t = b.finish();
        t.validate().unwrap();
        assert!(t.successors(s0).contains(&l0));
        assert!(t.successors(s0).contains(&s1));
        assert!(t.successors(l0).contains(&s1));
    }

    #[test]
    fn independent_addresses_have_no_edges() {
        let mut b = TraceBuilder::new();
        let a = b.array("a", 4, 8);
        let s0 = b.store(a, 0, &[]);
        let _l1 = b.load(a, 1);
        let t = b.finish();
        assert!(t.successors(s0).is_empty());
        assert_eq!(t.pred_count, vec![0, 0]);
    }

    #[test]
    fn value_deps_recorded() {
        let mut b = TraceBuilder::new();
        let a = b.array("a", 8, 4);
        let l0 = b.load(a, 0);
        let l1 = b.load(a, 1);
        let m = b.alu(AluKind::FMul, &[l0, l1]);
        let t = b.finish();
        assert!(t.successors(l0).contains(&m));
        assert!(t.successors(l1).contains(&m));
        assert_eq!(t.pred_count[m as usize], 2);
    }

    #[test]
    fn duplicate_edges_collapse() {
        let mut b = TraceBuilder::new();
        let a = b.array("a", 4, 4);
        let s = b.store(a, 0, &[]);
        // load value-depends on the store AND has a RAW edge to it.
        let l = b.load_dep(a, 0, &[s]);
        let t = b.finish();
        assert_eq!(t.successors(s), &[l]);
        assert_eq!(t.pred_count[l as usize], 1);
    }

    #[test]
    fn arrays_are_disjoint_and_aligned() {
        let mut b = TraceBuilder::new();
        let x = b.array("x", 8, 5); // 40 bytes -> 64
        let y = b.array("y", 4, 3);
        let t = {
            b.load(x, 0);
            b.load(y, 0);
            b.finish()
        };
        assert_eq!(t.arrays[0].base, 0);
        assert_eq!(t.arrays[1].base, 64);
        assert_eq!(t.arrays[1].base % 64, 0);
    }

    #[test]
    fn sites_and_iters_stamp_nodes() {
        let mut b = TraceBuilder::new();
        let a = b.array("a", 4, 16);
        b.site(7);
        for i in 0..4 {
            b.load(a, i);
            b.next_iter();
        }
        let t = b.finish();
        assert!(t.nodes.iter().all(|n| n.site == 7));
        assert_eq!(t.nodes[2].iter, 2);
        assert!(matches!(t.nodes[0].kind, OpKind::Load { .. }));
    }
}
