//! Dynamic trace / data-dependence-graph substrate (the Aladdin front end).
//!
//! Aladdin instruments LLVM IR to record a *dynamic* trace of every
//! executed operation, then builds a data-dependence graph (DDG) whose
//! only edges are true dependences — exposing all of the algorithm's
//! instruction- and memory-level parallelism. Our benchmark ports
//! (see [`crate::suite`]) do the same thing directly: they execute the
//! algorithm in Rust and record each load/store/ALU op through
//! [`TraceBuilder`], which tracks RAW/WAR/WAW memory dependences by
//! exact address and true register dependences by value handles.
//!
//! Loop iteration numbers are recorded per node so the scheduler can
//! model Aladdin's *unrolling factor*: with unroll `U`, the index-
//! increment chain serializes iteration groups `g = iter / U` (group `g`
//! cannot begin before cycle `g`) — see [`crate::sched`].

pub mod builder;

pub use builder::TraceBuilder;

/// Node handle inside one trace.
pub type NodeId = u32;

/// ALU operation classes with distinct latency/energy (Aladdin's FU mix).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AluKind {
    /// Integer add/sub.
    IntAdd,
    /// Integer multiply.
    IntMul,
    /// Integer compare / select.
    Cmp,
    /// Bitwise logic.
    Logic,
    /// Shift.
    Shift,
    /// FP add/sub (double).
    FAdd,
    /// FP multiply.
    FMul,
    /// FP divide / sqrt.
    FDiv,
}

impl AluKind {
    /// Latency in cycles at the 1 GHz base clock (Aladdin defaults).
    pub fn latency(self) -> u32 {
        match self {
            AluKind::IntAdd | AluKind::Cmp | AluKind::Logic | AluKind::Shift => 1,
            AluKind::IntMul => 3,
            AluKind::FAdd => 3,
            AluKind::FMul => 4,
            AluKind::FDiv => 16,
        }
    }

    /// Dynamic energy per op, pJ (45 nm, Aladdin-like FU characterization).
    pub fn energy_pj(self) -> f32 {
        match self {
            AluKind::IntAdd => 0.10,
            AluKind::Cmp | AluKind::Logic | AluKind::Shift => 0.06,
            AluKind::IntMul => 1.1,
            AluKind::FAdd => 1.5,
            AluKind::FMul => 2.9,
            AluKind::FDiv => 8.4,
        }
    }

    /// FU area, µm² (one functional unit able to execute this class).
    pub fn fu_area_um2(self) -> f32 {
        match self {
            AluKind::IntAdd => 280.0,
            AluKind::Cmp | AluKind::Logic | AluKind::Shift => 150.0,
            AluKind::IntMul => 1650.0,
            AluKind::FAdd => 3100.0,
            AluKind::FMul => 5200.0,
            AluKind::FDiv => 6900.0,
        }
    }

    /// Index of this kind in [`AluKind::ALL`] — O(1), so hot paths can
    /// bucket per-kind counts without a linear `position()` scan.
    pub const fn index(self) -> usize {
        match self {
            AluKind::IntAdd => 0,
            AluKind::IntMul => 1,
            AluKind::Cmp => 2,
            AluKind::Logic => 3,
            AluKind::Shift => 4,
            AluKind::FAdd => 5,
            AluKind::FMul => 6,
            AluKind::FDiv => 7,
        }
    }

    /// All kinds (for FU-mix sizing).
    pub const ALL: [AluKind; 8] = [
        AluKind::IntAdd,
        AluKind::IntMul,
        AluKind::Cmp,
        AluKind::Logic,
        AluKind::Shift,
        AluKind::FAdd,
        AluKind::FMul,
        AluKind::FDiv,
    ];
}

/// Operation performed by a trace node.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OpKind {
    /// Memory read of `array[index]`.
    Load {
        /// Array id (index into [`Trace::arrays`]).
        array: u16,
        /// Element index within the array.
        index: u32,
    },
    /// Memory write of `array[index]`.
    Store {
        /// Array id.
        array: u16,
        /// Element index.
        index: u32,
    },
    /// Functional-unit operation.
    Alu(AluKind),
}

impl OpKind {
    /// Is this a load or store?
    pub fn is_mem(&self) -> bool {
        matches!(self, OpKind::Load { .. } | OpKind::Store { .. })
    }
    /// (array, index) if a memory op.
    pub fn mem_ref(&self) -> Option<(u16, u32)> {
        match *self {
            OpKind::Load { array, index } | OpKind::Store { array, index } => Some((array, index)),
            OpKind::Alu(_) => None,
        }
    }
}

/// One dynamic operation.
#[derive(Clone, Copy, Debug)]
pub struct Node {
    /// What it does.
    pub kind: OpKind,
    /// Static instruction site (source location surrogate) — groups the
    /// dynamic instances of one program instruction for the Weinberg
    /// locality metric.
    pub site: u32,
    /// Innermost-loop iteration number (flattened, monotone) — drives the
    /// unrolling constraint in the scheduler.
    pub iter: u32,
}

/// A program array traced into the accelerator's scratchpad space.
#[derive(Clone, Debug)]
pub struct ArrayInfo {
    /// Name (for reports/config).
    pub name: String,
    /// Element size in bytes (1 for KMP text, 8 for double arrays, …).
    pub elem_bytes: u32,
    /// Length in elements.
    pub length: u32,
    /// Base byte address in the flat trace address space.
    pub base: u64,
}

impl ArrayInfo {
    /// Byte address of element `index`.
    pub fn byte_addr(&self, index: u32) -> u64 {
        self.base + index as u64 * self.elem_bytes as u64
    }
    /// Footprint in bytes.
    pub fn bytes(&self) -> u64 {
        self.length as u64 * self.elem_bytes as u64
    }
}

/// A complete dynamic trace with its dependence graph in CSR form.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    /// Traced arrays.
    pub arrays: Vec<ArrayInfo>,
    /// Dynamic ops in program order.
    pub nodes: Vec<Node>,
    /// CSR row offsets into `succ`: successors of node `i` are
    /// `succ[succ_off[i] .. succ_off[i+1]]`.
    pub succ_off: Vec<u32>,
    /// Flattened successor lists.
    pub succ: Vec<NodeId>,
    /// In-degree (number of predecessors) per node.
    pub pred_count: Vec<u32>,
    /// Cached number of memory (load/store) nodes, filled by
    /// [`TraceBuilder::finish`] so per-design-point consumers never
    /// re-scan the node list.
    pub mem_op_count: u32,
    /// Cached node count per [`AluKind`], indexed by [`AluKind::index`]
    /// (the FU-mix table), filled by [`TraceBuilder::finish`].
    pub alu_kind_counts: [u64; 8],
}

impl Trace {
    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }
    /// True if no nodes.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
    /// Successors of `n`.
    pub fn successors(&self, n: NodeId) -> &[NodeId] {
        let a = self.succ_off[n as usize] as usize;
        let b = self.succ_off[n as usize + 1] as usize;
        &self.succ[a..b]
    }
    /// Count of memory nodes (cached at build time).
    pub fn mem_ops(&self) -> usize {
        self.mem_op_count as usize
    }
    /// Count of ALU nodes.
    pub fn alu_ops(&self) -> usize {
        self.len() - self.mem_ops()
    }
    /// Total scratchpad footprint in bytes.
    pub fn footprint_bytes(&self) -> u64 {
        self.arrays.iter().map(|a| a.bytes()).sum()
    }
    /// Largest single array in elements (sizes the memory depth).
    pub fn max_array_len(&self) -> u32 {
        self.arrays.iter().map(|a| a.length).max().unwrap_or(0)
    }

    /// Verify the DDG is a DAG consistent with program order (every edge
    /// goes forward) and that CSR bookkeeping matches `pred_count`.
    /// Used by tests and debug assertions.
    pub fn validate(&self) -> Result<(), String> {
        if self.succ_off.len() != self.len() + 1 {
            return Err("succ_off length mismatch".into());
        }
        let mut preds = vec![0u32; self.len()];
        for i in 0..self.len() {
            for &s in self.successors(i as NodeId) {
                if s as usize <= i {
                    return Err(format!("edge {} -> {} not forward", i, s));
                }
                if s as usize >= self.len() {
                    return Err(format!("edge to out-of-range node {}", s));
                }
                preds[s as usize] += 1;
            }
        }
        if preds != self.pred_count {
            return Err("pred_count inconsistent with successor lists".into());
        }
        let mut mem_count = 0u32;
        let mut alu_counts = [0u64; 8];
        for n in &self.nodes {
            match n.kind {
                OpKind::Alu(k) => alu_counts[k.index()] += 1,
                _ => mem_count += 1,
            }
            if let Some((a, idx)) = n.kind.mem_ref() {
                let arr =
                    self.arrays.get(a as usize).ok_or_else(|| format!("bad array id {a}"))?;
                if idx >= arr.length {
                    return Err(format!("index {idx} out of bounds for array {}", arr.name));
                }
            }
        }
        if mem_count != self.mem_op_count {
            return Err(format!(
                "cached mem_op_count {} != actual {}",
                self.mem_op_count, mem_count
            ));
        }
        if alu_counts != self.alu_kind_counts {
            return Err("cached alu_kind_counts inconsistent with nodes".into());
        }
        Ok(())
    }

    /// Length of the critical path through the DDG in *dependence levels*
    /// (unit latencies) — a lower bound on schedulable cycles, used by
    /// tests as a sanity reference.
    pub fn critical_path_len(&self) -> u32 {
        let mut level = vec![0u32; self.len()];
        let mut maxl = 0;
        for i in 0..self.len() {
            let l = level[i] + 1;
            maxl = maxl.max(l);
            for &s in self.successors(i as NodeId) {
                level[s as usize] = level[s as usize].max(l);
            }
        }
        maxl
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Trace {
        // load a[0] -> alu -> store a[1]
        let mut b = TraceBuilder::new();
        let a = b.array("a", 8, 4);
        let l = b.load(a, 0);
        let x = b.alu(AluKind::FAdd, &[l]);
        b.store(a, 1, &[x]);
        b.finish()
    }

    #[test]
    fn tiny_trace_validates() {
        let t = tiny();
        assert_eq!(t.len(), 3);
        t.validate().unwrap();
        assert_eq!(t.mem_ops(), 2);
        assert_eq!(t.alu_ops(), 1);
        assert_eq!(t.critical_path_len(), 3);
    }

    #[test]
    fn byte_addresses() {
        let a = ArrayInfo { name: "x".into(), elem_bytes: 8, length: 10, base: 0x100 };
        assert_eq!(a.byte_addr(3), 0x100 + 24);
        assert_eq!(a.bytes(), 80);
    }

    #[test]
    fn alu_index_matches_all_order() {
        for (i, k) in AluKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i, "{k:?}");
        }
    }

    #[test]
    fn op_mix_counts_cached_at_build() {
        let t = tiny();
        assert_eq!(t.mem_op_count, 2);
        assert_eq!(t.alu_kind_counts[AluKind::FAdd.index()], 1);
        assert_eq!(t.alu_kind_counts.iter().sum::<u64>() as usize, t.alu_ops());
    }

    #[test]
    fn alu_latencies_positive() {
        for k in AluKind::ALL {
            assert!(k.latency() >= 1);
            assert!(k.energy_pj() > 0.0);
            assert!(k.fu_area_um2() > 0.0);
        }
    }
}
