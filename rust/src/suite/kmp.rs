//! KMP (MachSuite `kmp/kmp`): Knuth–Morris–Pratt string matching.
//! Byte-oriented, stride-1 text scan ⇒ the highest spatial locality in
//! the suite (paper §IV-B: "stride-one code is available in byte-oriented
//! programs like KMP") — the benchmark where AMMs do *not* pay off.

use super::Workload;
use crate::trace::{AluKind, TraceBuilder};
use crate::util::rng::Rng;

const SITE_PAT_FAIL: u32 = 0;
const SITE_FAIL_RD: u32 = 1;
const SITE_FAIL_WR: u32 = 2;
const SITE_TEXT: u32 = 3;
const SITE_PAT: u32 = 4;
const SITE_FAIL_M: u32 = 5;

const PATTERN: &[u8] = b"bull";

/// Generate a KMP trace over an `n`-byte text. Checksum = match count.
pub fn generate(n: usize) -> Workload {
    let m = PATTERN.len();
    assert!(n >= m * 2);
    // Text with planted pattern occurrences (MachSuite uses a news corpus;
    // we synthesize one with the same alphabet footprint).
    let mut rng = Rng::new(0x6B6D70);
    let alphabet = b"abcdefghijklmnopqrstuvwxyz ";
    let mut text: Vec<u8> = (0..n).map(|_| *rng.pick(alphabet)).collect();
    for _ in 0..(n / 64).max(1) {
        let pos = rng.below_usize(n - m);
        text[pos..pos + m].copy_from_slice(PATTERN);
    }

    let mut b = TraceBuilder::new();
    let a_pat = b.array("pattern", 1, m as u32);
    let a_text = b.array("input", 1, n as u32);
    let a_fail = b.array("kmp_failure", 4, m as u32);

    // --- CPF: compute failure table (kmp_failure) ---
    let mut fail = vec![0i32; m];
    let mut k_node = b.alu(AluKind::IntAdd, &[]); // k = 0
    let mut k = 0usize;
    b.site(SITE_FAIL_WR);
    b.store(a_fail, 0, &[k_node]);
    for q in 1..m {
        loop {
            b.site(SITE_PAT_FAIL);
            let lq = b.load(a_pat, q as u32);
            let lk = b.load(a_pat, k as u32);
            let cmp = b.alu(AluKind::Cmp, &[lq, lk, k_node]);
            if k > 0 && PATTERN[k] != PATTERN[q] {
                b.site(SITE_FAIL_RD);
                let lf = b.load(a_fail, (k - 1) as u32);
                k_node = b.alu(AluKind::IntAdd, &[lf, cmp]);
                k = fail[k - 1] as usize;
            } else {
                k_node = cmp;
                break;
            }
        }
        if PATTERN[k] == PATTERN[q] {
            k += 1;
            k_node = b.alu(AluKind::IntAdd, &[k_node]);
        }
        fail[q] = k as i32;
        b.site(SITE_FAIL_WR);
        b.store(a_fail, q as u32, &[k_node]);
        b.next_iter();
    }

    // --- KMP: match over the text ---
    let mut matches = 0u32;
    let mut q = 0usize;
    let mut q_node = b.alu(AluKind::IntAdd, &[]);
    for i in 0..n {
        b.site(SITE_TEXT);
        let lt = b.load(a_text, i as u32);
        loop {
            b.site(SITE_PAT);
            let lp = b.load(a_pat, q as u32);
            let cmp = b.alu(AluKind::Cmp, &[lt, lp, q_node]);
            if q > 0 && PATTERN[q] != text[i] {
                b.site(SITE_FAIL_M);
                let lf = b.load(a_fail, (q - 1) as u32);
                q_node = b.alu(AluKind::IntAdd, &[lf, cmp]);
                q = fail[q - 1] as usize;
            } else {
                q_node = cmp;
                break;
            }
        }
        if PATTERN[q] == text[i] {
            q += 1;
            q_node = b.alu(AluKind::IntAdd, &[q_node]);
        }
        if q == m {
            matches += 1;
            b.site(SITE_FAIL_M);
            let lf = b.load(a_fail, (q - 1) as u32);
            q_node = b.alu(AluKind::IntAdd, &[lf, q_node]);
            q = fail[q - 1] as usize;
        }
        b.next_iter();
    }

    Workload { name: "kmp", trace: b.finish(), checksum: matches as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_planted_matches() {
        let wl = generate(512);
        // We plant n/64 = 8 occurrences; random collisions can add more,
        // overlaps can merge — but at least one must be found.
        assert!(wl.checksum >= 1.0, "checksum {}", wl.checksum);
    }

    #[test]
    fn checksum_matches_std_matcher() {
        let n = 512;
        // Rebuild the same text and count with a naive matcher.
        let m = PATTERN.len();
        let mut rng = Rng::new(0x6B6D70);
        let alphabet = b"abcdefghijklmnopqrstuvwxyz ";
        let mut text: Vec<u8> = (0..n).map(|_| *rng.pick(alphabet)).collect();
        for _ in 0..(n / 64).max(1) {
            let pos = rng.below_usize(n - m);
            text[pos..pos + m].copy_from_slice(PATTERN);
        }
        let want = text.windows(m).filter(|w| *w == PATTERN).count() as f64;
        assert_eq!(generate(n).checksum, want);
    }

    #[test]
    fn text_scan_is_byte_stride_one() {
        let wl = generate(256);
        let text_id = wl.trace.arrays.iter().position(|a| a.name == "input").unwrap() as u16;
        assert_eq!(wl.trace.arrays[text_id as usize].elem_bytes, 1);
        // consecutive SITE_TEXT loads advance by exactly 1 element
        let idxs: Vec<u32> = wl
            .trace
            .nodes
            .iter()
            .filter_map(|n| match n.kind.mem_ref() {
                Some((a, i)) if a == text_id && n.site == SITE_TEXT => Some(i),
                _ => None,
            })
            .collect();
        assert!(idxs.windows(2).all(|w| w[1] == w[0] + 1));
    }
}
