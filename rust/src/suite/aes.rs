//! AES-256 ECB encryption (MachSuite `aes/aes`): byte-oriented
//! table-driven rounds — S-box gathers plus stride-1 state walks give the
//! suite's other high-locality benchmark alongside KMP (paper §IV-B).

use super::Workload;
use crate::trace::{AluKind, TraceBuilder};
use crate::util::rng::Rng;

const SITE_SBOX: u32 = 0;
const SITE_STATE_RD: u32 = 1;
const SITE_STATE_WR: u32 = 2;
const SITE_KEY: u32 = 3;

/// Rijndael S-box.
const SBOX: [u8; 256] = [
    0x63, 0x7c, 0x77, 0x7b, 0xf2, 0x6b, 0x6f, 0xc5, 0x30, 0x01, 0x67, 0x2b, 0xfe, 0xd7, 0xab, 0x76,
    0xca, 0x82, 0xc9, 0x7d, 0xfa, 0x59, 0x47, 0xf0, 0xad, 0xd4, 0xa2, 0xaf, 0x9c, 0xa4, 0x72, 0xc0,
    0xb7, 0xfd, 0x93, 0x26, 0x36, 0x3f, 0xf7, 0xcc, 0x34, 0xa5, 0xe5, 0xf1, 0x71, 0xd8, 0x31, 0x15,
    0x04, 0xc7, 0x23, 0xc3, 0x18, 0x96, 0x05, 0x9a, 0x07, 0x12, 0x80, 0xe2, 0xeb, 0x27, 0xb2, 0x75,
    0x09, 0x83, 0x2c, 0x1a, 0x1b, 0x6e, 0x5a, 0xa0, 0x52, 0x3b, 0xd6, 0xb3, 0x29, 0xe3, 0x2f, 0x84,
    0x53, 0xd1, 0x00, 0xed, 0x20, 0xfc, 0xb1, 0x5b, 0x6a, 0xcb, 0xbe, 0x39, 0x4a, 0x4c, 0x58, 0xcf,
    0xd0, 0xef, 0xaa, 0xfb, 0x43, 0x4d, 0x33, 0x85, 0x45, 0xf9, 0x02, 0x7f, 0x50, 0x3c, 0x9f, 0xa8,
    0x51, 0xa3, 0x40, 0x8f, 0x92, 0x9d, 0x38, 0xf5, 0xbc, 0xb6, 0xda, 0x21, 0x10, 0xff, 0xf3, 0xd2,
    0xcd, 0x0c, 0x13, 0xec, 0x5f, 0x97, 0x44, 0x17, 0xc4, 0xa7, 0x7e, 0x3d, 0x64, 0x5d, 0x19, 0x73,
    0x60, 0x81, 0x4f, 0xdc, 0x22, 0x2a, 0x90, 0x88, 0x46, 0xee, 0xb8, 0x14, 0xde, 0x5e, 0x0b, 0xdb,
    0xe0, 0x32, 0x3a, 0x0a, 0x49, 0x06, 0x24, 0x5c, 0xc2, 0xd3, 0xac, 0x62, 0x91, 0x95, 0xe4, 0x79,
    0xe7, 0xc8, 0x37, 0x6d, 0x8d, 0xd5, 0x4e, 0xa9, 0x6c, 0x56, 0xf4, 0xea, 0x65, 0x7a, 0xae, 0x08,
    0xba, 0x78, 0x25, 0x2e, 0x1c, 0xa6, 0xb4, 0xc6, 0xe8, 0xdd, 0x74, 0x1f, 0x4b, 0xbd, 0x8b, 0x8a,
    0x70, 0x3e, 0xb5, 0x66, 0x48, 0x03, 0xf6, 0x0e, 0x61, 0x35, 0x57, 0xb9, 0x86, 0xc1, 0x1d, 0x9e,
    0xe1, 0xf8, 0x98, 0x11, 0x69, 0xd9, 0x8e, 0x94, 0x9b, 0x1e, 0x87, 0xe9, 0xce, 0x55, 0x28, 0xdf,
    0x8c, 0xa1, 0x89, 0x0d, 0xbf, 0xe6, 0x42, 0x68, 0x41, 0x99, 0x2d, 0x0f, 0xb0, 0x54, 0xbb, 0x16,
];

fn xtime(x: u8) -> u8 {
    (x << 1) ^ (((x >> 7) & 1) * 0x1b)
}

/// Generate an AES trace encrypting `blocks` 16-byte blocks.
/// Checksum = Σ ciphertext bytes.
pub fn generate(blocks: usize) -> Workload {
    let mut rng = Rng::new(0xAE5);
    let key: [u8; 32] = std::array::from_fn(|_| rng.next_u32() as u8);
    let mut state_all: Vec<u8> = (0..blocks * 16).map(|_| rng.next_u32() as u8).collect();

    let mut b = TraceBuilder::new();
    let a_sbox = b.array("sbox", 1, 256);
    let a_state = b.array("buf", 1, (blocks * 16) as u32);
    let a_key = b.array("key", 1, 32);

    const ROUNDS: usize = 14;
    for blk in 0..blocks {
        let base = blk * 16;
        for round in 0..ROUNDS {
            // AddRoundKey (simplified schedule: cycle the master key) +
            // SubBytes + ShiftRows; MixColumns on non-final rounds.
            let mut st: [u8; 16] = state_all[base..base + 16].try_into().unwrap();
            // SubBytes + AddRoundKey, traced per byte.
            for i in 0..16 {
                b.site(SITE_STATE_RD);
                let ls = b.load(a_state, (base + i) as u32);
                b.site(SITE_KEY);
                let lk = b.load(a_key, ((round * 16 + i) % 32) as u32);
                let x = b.alu(AluKind::Logic, &[ls, lk]);
                b.site(SITE_SBOX);
                let sub = SBOX[(st[i] ^ key[(round * 16 + i) % 32]) as usize] as u32;
                let lsb = b.load_dep(a_sbox, sub, &[x]);
                b.site(SITE_STATE_WR);
                b.store(a_state, (base + i) as u32, &[lsb]);
                st[i] = SBOX[(st[i] ^ key[(round * 16 + i) % 32]) as usize];
            }
            // ShiftRows (index shuffle, no memory traffic in-register)
            let mut sr = st;
            for r in 1..4 {
                for c in 0..4 {
                    sr[r + 4 * c] = st[r + 4 * ((c + r) % 4)];
                }
            }
            st = sr;
            // MixColumns: per column, 4 loads + xtime logic + 4 stores.
            if round != ROUNDS - 1 {
                for c in 0..4 {
                    let col = [st[4 * c], st[4 * c + 1], st[4 * c + 2], st[4 * c + 3]];
                    let mut loads = Vec::with_capacity(4);
                    for r in 0..4 {
                        b.site(SITE_STATE_RD);
                        loads.push(b.load(a_state, (base + 4 * c + r) as u32));
                    }
                    let t = col[0] ^ col[1] ^ col[2] ^ col[3];
                    let mut out = [0u8; 4];
                    for r in 0..4 {
                        out[r] = col[r] ^ t ^ xtime(col[r] ^ col[(r + 1) % 4]);
                        let x1 = b.alu(AluKind::Logic, &loads);
                        let x2 = b.alu(AluKind::Shift, &[x1]);
                        b.site(SITE_STATE_WR);
                        b.store(a_state, (base + 4 * c + r) as u32, &[x2]);
                        st[4 * c + r] = out[r];
                    }
                }
            }
            state_all[base..base + 16].copy_from_slice(&st);
            b.next_iter();
        }
    }

    let checksum = state_all.iter().map(|&x| x as f64).sum();
    Workload { name: "aes", trace: b.finish(), checksum }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_diffused() {
        let a = generate(2);
        let b = generate(2);
        assert_eq!(a.checksum, b.checksum);
        // Mean byte value should be near 127.5 after 14 rounds of sbox.
        let mean = a.checksum / (2.0 * 16.0);
        assert!(mean > 80.0 && mean < 175.0, "mean {mean}");
    }

    #[test]
    fn byte_arrays_only() {
        let wl = generate(1);
        assert!(wl.trace.arrays.iter().all(|a| a.elem_bytes == 1));
    }

    #[test]
    fn sbox_is_a_permutation() {
        let mut seen = [false; 256];
        for &v in SBOX.iter() {
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
