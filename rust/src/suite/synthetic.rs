//! `suite::synthetic` — locality-dial streaming workload generator.
//!
//! MachSuite samples the spatial-locality axis only incidentally; this
//! module turns it into a dial. A parametric benchmark name such as
//! `synth:stride=rand,rw=0.7,reuse=64` describes a seeded, deterministic
//! streaming access pattern that flows through the registry everywhere a
//! MachSuite name does (generate, campaign specs, weighted sharding, the
//! batch kernel's compatible groups, serve).
//!
//! # Name grammar
//!
//! `synth:` followed by zero or more comma-separated `dial=value` pairs
//! (any order, no duplicates). Dials and defaults:
//!
//! | dial       | values                  | default | effect |
//! |------------|-------------------------|---------|--------|
//! | `stride`   | `unit` \| `s<K>` \| `rand` | `unit` | base address pattern: unit-stride stream, fixed K-element stride, or uniform-random within the window |
//! | `mix`      | `0..=1`                 | `0`     | probability an access abandons the pattern for a uniform-random index (smoothly degrades spatial locality) |
//! | `rw`       | `0..=1`                 | `0.7`   | read fraction; writes are interleaved deterministically (Bresenham over per-mille), so node counts stay closed-form |
//! | `reuse`    | `32..=1048576`          | `256`   | working-set window in 4-byte elements the stream wraps within (reuse distance); ≥ 32 keeps the array past register promotion |
//! | `conflict` | `0..=1`                 | `0`     | probability an access is forced to a 64-element-aligned index — one bank on every power-of-two banking, harmless to true multi-port |
//! | `seed`     | any `u64`               | `1`     | RNG seed (xoshiro256** via SplitMix64) |
//! | `n`        | `64..=16777216`         | per scale | access count override; otherwise Tiny/Paper/Large pick 2048/32768/524288 |
//!
//! Every access contributes exactly **2 trace nodes** (a memory op plus
//! one ALU op), so `node_count = 2 × accesses` is computable in closed
//! form without tracing — that is what `weight-table/v1` records for
//! synthetic entries and what the `generate_cached` bypass checks.
//!
//! The generator streams: each access is produced on demand straight into
//! [`TraceBuilder`], no intermediate workload buffer, so peak footprint is
//! the trace itself plus the O(`reuse`) dependence cells.

use super::{Scale, Workload};
use crate::error::{Error, Result};
use crate::trace::{AluKind, NodeId, TraceBuilder};
use crate::util::rng::Rng;

/// Name prefix that marks a parametric synthetic benchmark.
pub const PREFIX: &str = "synth:";

/// Element size of the synthetic data array (bytes).
pub const ELEM_BYTES: u32 = 4;

/// Alignment (in elements) of conflict-dial target indices. 64 elements ×
/// 4 bytes = 256 bytes, a multiple of every swept `banks × word_bytes`
/// (pow2 banks ≤ 32, word_bytes ≤ 8), so all conflict targets land in one
/// bank under cyclic interleaving no matter the banked design point.
pub const CONFLICT_ALIGN: u32 = 64;

/// Lower bound on `reuse`: 32 elements × 4 bytes = 128 bytes, safely past
/// the scheduler's 64-byte register-promotion threshold — a smaller window
/// would bypass memory ports entirely and dissolve the experiment.
pub const MIN_REUSE: u32 = 32;

/// Upper bound on `reuse` (1 Mi elements = 4 MiB window).
pub const MAX_REUSE: u32 = 1 << 20;

/// Bounds on the `n` access-count override dial.
pub const MIN_ACCESSES: u64 = 64;
/// See [`MIN_ACCESSES`].
pub const MAX_ACCESSES: u64 = 1 << 24;

/// Independent accumulator lanes (bounds the value-dependence chain so
/// ILP is limited by ports, not by one serial accumulator).
const ILP_LANES: usize = 8;

/// One-line dial reference, embedded in every parse error (the CLI
/// "clear error listing the known dials" contract).
pub const DIAL_HELP: &str = "known dials: stride=unit|s<K>|rand, mix=0..1, rw=0..1, \
     reuse=32..1048576, conflict=0..1, seed=<u64>, n=64..16777216";

/// Base address pattern selected by the `stride` dial.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StridePattern {
    /// Unit-stride stream (stride 1 element).
    Unit,
    /// Fixed stride of K elements.
    Fixed(u32),
    /// Uniform-random index per access.
    Rand,
}

/// Parsed dial settings of one `synth:` name.
#[derive(Clone, Debug, PartialEq)]
pub struct SynthParams {
    /// `stride` dial.
    pub stride: StridePattern,
    /// `mix` dial: probability of a random jump.
    pub mix: f64,
    /// `rw` dial: read fraction.
    pub rw: f64,
    /// `reuse` dial: window length in elements.
    pub reuse: u32,
    /// `conflict` dial: probability of a bank-aligned forced index.
    pub conflict: f64,
    /// `seed` dial.
    pub seed: u64,
    /// `n` dial: access-count override (else scale decides).
    pub n: Option<u64>,
}

impl Default for SynthParams {
    fn default() -> Self {
        SynthParams {
            stride: StridePattern::Unit,
            mix: 0.0,
            rw: 0.7,
            reuse: 256,
            conflict: 0.0,
            seed: 1,
            n: None,
        }
    }
}

impl SynthParams {
    /// Dynamic access count at `scale` (the `n` dial overrides).
    pub fn accesses(&self, scale: Scale) -> u64 {
        self.n.unwrap_or(match scale {
            Scale::Tiny => 2_048,
            Scale::Paper => 32_768,
            Scale::Large => 524_288,
        })
    }

    /// Closed-form trace node count: exactly 2 nodes per access (one
    /// memory op + one ALU op), independent of every RNG draw.
    pub fn node_count(&self, scale: Scale) -> u64 {
        2 * self.accesses(scale)
    }

    /// Writes among the first `n` accesses under the deterministic
    /// Bresenham interleave (`floor(n * wpm / 1000)`).
    pub fn writes_among(&self, n: u64) -> u64 {
        n * self.writes_per_mille() / 1000
    }

    fn writes_per_mille(&self) -> u64 {
        ((1.0 - self.rw) * 1000.0).round() as u64
    }

    /// Canonical name (every dial spelled out, fixed order). Display /
    /// debugging aid only — registry keys keep the user's spelling.
    pub fn canonical_name(&self) -> String {
        let stride = match self.stride {
            StridePattern::Unit => "unit".to_string(),
            StridePattern::Fixed(k) => format!("s{k}"),
            StridePattern::Rand => "rand".to_string(),
        };
        let mut s = format!(
            "{PREFIX}stride={stride},mix={},rw={},reuse={},conflict={},seed={}",
            self.mix, self.rw, self.reuse, self.conflict, self.seed
        );
        if let Some(n) = self.n {
            s.push_str(&format!(",n={n}"));
        }
        s
    }
}

/// True if `name` is in the parametric `synth:` namespace (it may still
/// fail to [`parse`]).
pub fn is_synthetic(name: &str) -> bool {
    name.starts_with(PREFIX)
}

fn bad(name: &str, detail: &str) -> Error {
    Error::config(format!("bad synthetic benchmark {name:?}: {detail}; {DIAL_HELP}"))
}

fn unit_range(name: &str, key: &str, raw: &str) -> Result<f64> {
    let v: f64 = raw
        .parse()
        .map_err(|_| bad(name, &format!("dial {key}={raw:?} is not a number")))?;
    if !(0.0..=1.0).contains(&v) {
        return Err(bad(name, &format!("dial {key}={raw} outside 0..=1")));
    }
    Ok(v)
}

/// Parse a `synth:` name into dial settings.
///
/// Dials may appear in any order; unknown or duplicate dials and
/// out-of-range values are [`Error::Config`] listing the known dials.
/// `synth:` alone selects all defaults.
pub fn parse(name: &str) -> Result<SynthParams> {
    let body = name
        .strip_prefix(PREFIX)
        .ok_or_else(|| bad(name, "missing synth: prefix"))?;
    let mut p = SynthParams::default();
    let mut seen: Vec<&str> = Vec::new();
    for part in body.split(',') {
        let part = part.trim();
        if part.is_empty() {
            if body.trim().is_empty() {
                continue; // bare "synth:" = all defaults
            }
            return Err(bad(name, "empty dial"));
        }
        let (key, raw) = part
            .split_once('=')
            .ok_or_else(|| bad(name, &format!("dial {part:?} is not key=value")))?;
        let (key, raw) = (key.trim(), raw.trim());
        if seen.contains(&key) {
            return Err(bad(name, &format!("duplicate dial {key:?}")));
        }
        seen.push(key);
        match key {
            "stride" => {
                p.stride = match raw {
                    "unit" => StridePattern::Unit,
                    "rand" => StridePattern::Rand,
                    _ => {
                        let k: u32 = raw
                            .strip_prefix('s')
                            .and_then(|d| d.parse().ok())
                            .ok_or_else(|| {
                                bad(name, &format!("dial stride={raw:?} is not unit, s<K> or rand"))
                            })?;
                        if !(1..=4096).contains(&k) {
                            return Err(bad(name, &format!("stride s{k} outside s1..=s4096")));
                        }
                        StridePattern::Fixed(k)
                    }
                };
            }
            "mix" => p.mix = unit_range(name, "mix", raw)?,
            "rw" => p.rw = unit_range(name, "rw", raw)?,
            "conflict" => p.conflict = unit_range(name, "conflict", raw)?,
            "reuse" => {
                let v: u32 = raw
                    .parse()
                    .map_err(|_| bad(name, &format!("dial reuse={raw:?} is not an integer")))?;
                if !(MIN_REUSE..=MAX_REUSE).contains(&v) {
                    return Err(bad(
                        name,
                        &format!("reuse={v} outside {MIN_REUSE}..={MAX_REUSE}"),
                    ));
                }
                p.reuse = v;
            }
            "seed" => {
                p.seed = raw
                    .parse()
                    .map_err(|_| bad(name, &format!("dial seed={raw:?} is not a u64")))?;
            }
            "n" => {
                let v: u64 = raw
                    .parse()
                    .map_err(|_| bad(name, &format!("dial n={raw:?} is not an integer")))?;
                if !(MIN_ACCESSES..=MAX_ACCESSES).contains(&v) {
                    return Err(bad(
                        name,
                        &format!("n={v} outside {MIN_ACCESSES}..={MAX_ACCESSES}"),
                    ));
                }
                p.n = Some(v);
            }
            other => return Err(bad(name, &format!("unknown dial {other:?}"))),
        }
    }
    Ok(p)
}

/// Closed-form node count for a `synth:` name, `None` if `name` is not a
/// valid synthetic spec. Lets weighted sharding answer without tracing.
pub fn try_node_count(name: &str, scale: Scale) -> Option<u64> {
    if !is_synthetic(name) {
        return None;
    }
    parse(name).ok().map(|p| p.node_count(scale))
}

/// Generate a synthetic workload from its parametric name.
///
/// # Panics
/// On an invalid `synth:` spec — callers validate via
/// [`crate::suite::validate_name`] first (mirrors the MachSuite
/// `generate` contract).
pub fn generate(name: &str, scale: Scale) -> Workload {
    let params = parse(name).unwrap_or_else(|e| panic!("{e}"));
    let (trace, checksum) = build(&params, scale);
    Workload { name: super::intern_name(name), trace, checksum }
}

/// Stream the access pattern into a trace. Returns the trace plus a
/// deterministic digest of the (address, read/write) stream — synthetic
/// workloads compute nothing real, so the checksum certifies the *access
/// stream*, not an algorithm result.
pub fn build(params: &SynthParams, scale: Scale) -> (crate::trace::Trace, f64) {
    let n = params.accesses(scale);
    let window = params.reuse;
    let mut b = TraceBuilder::new();
    let data = b.array("synth_data", ELEM_BYTES, window);
    let mut rng = Rng::new(params.seed);
    let mut acc: [Option<NodeId>; ILP_LANES] = [None; ILP_LANES];
    let mut pos: u32 = 0;
    let wpm = params.writes_per_mille();
    // Conflict targets: 64-element-aligned indices inside the window.
    let aligned_slots = (window / CONFLICT_ALIGN).max(1) as u64;
    let mut digest: u64 = 0xcbf2_9ce4_8422_2325; // FNV offset basis
    for i in 0..n {
        // Per-access decision order is part of the determinism contract:
        // conflict draw, then mix draw, then the stride pattern.
        let idx = if params.conflict > 0.0 && rng.chance(params.conflict) {
            (rng.below(aligned_slots) as u32 * CONFLICT_ALIGN).min(window - 1)
        } else if params.mix > 0.0 && rng.chance(params.mix) {
            rng.below(window as u64) as u32
        } else {
            match params.stride {
                StridePattern::Rand => rng.below(window as u64) as u32,
                StridePattern::Unit => {
                    let p = pos;
                    pos = (pos + 1) % window;
                    p
                }
                StridePattern::Fixed(k) => {
                    let p = pos;
                    pos = (pos + k) % window;
                    p
                }
            }
        };
        // Deterministic read/write interleave: access i is a write iff
        // the Bresenham accumulator crosses a per-mille boundary.
        let write = (i + 1) * wpm / 1000 > i * wpm / 1000;
        let lane = (i as usize) % ILP_LANES;
        if write {
            b.site(1);
            let v = match acc[lane] {
                Some(a) => b.alu(AluKind::FMul, &[a]),
                None => b.alu(AluKind::FMul, &[]),
            };
            b.store(data, idx, &[v]);
            acc[lane] = Some(v);
        } else {
            b.site(0);
            let l = b.load(data, idx);
            let f = match acc[lane] {
                Some(a) => b.alu(AluKind::FAdd, &[l, a]),
                None => b.alu(AluKind::FAdd, &[l]),
            };
            acc[lane] = Some(f);
        }
        b.next_iter();
        digest = (digest ^ (idx as u64 | (write as u64) << 32)).wrapping_mul(0x1_0000_0000_01b3);
    }
    // Keep the digest exactly representable as f64 (< 2^52).
    let checksum = (digest & ((1u64 << 52) - 1)) as f64;
    (b.finish(), checksum)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_bare_prefix() {
        assert_eq!(parse("synth:").unwrap(), SynthParams::default());
        let p = parse("synth:stride=rand,rw=0.7,reuse=64").unwrap();
        assert_eq!(p.stride, StridePattern::Rand);
        assert_eq!(p.rw, 0.7);
        assert_eq!(p.reuse, 64);
        assert_eq!(p.seed, 1);
    }

    #[test]
    fn dial_order_is_irrelevant() {
        let a = parse("synth:rw=0.5,stride=s4,seed=9").unwrap();
        let b = parse("synth:seed=9,stride=s4,rw=0.5").unwrap();
        assert_eq!(a, b);
        assert_eq!(a.canonical_name(), b.canonical_name());
    }

    #[test]
    fn parse_errors_list_the_dials() {
        for bad_name in [
            "synth:warp=2",              // unknown dial
            "synth:rw=1.5",              // out of range
            "synth:reuse=8",             // below register-promotion floor
            "synth:stride=diag",         // unknown pattern
            "synth:stride",              // not key=value
            "synth:rw=0.5,rw=0.5",       // duplicate
            "synth:n=1",                 // below floor
            "synth:mix=nope",            // not a number
            "synth:rw=0.5,,seed=1",      // empty dial
        ] {
            let e = parse(bad_name).unwrap_err().to_string();
            assert!(e.contains("known dials"), "{bad_name}: {e}");
            assert!(e.contains("stride=unit|s<K>|rand"), "{bad_name}: {e}");
        }
    }

    #[test]
    fn node_count_is_closed_form_and_matches_generation() {
        for name in
            ["synth:", "synth:stride=rand,rw=0.4,reuse=64,seed=3", "synth:conflict=0.8,n=512"]
        {
            let p = parse(name).unwrap();
            let (t, _) = build(&p, Scale::Tiny);
            assert_eq!(t.len() as u64, p.node_count(Scale::Tiny), "{name}");
            assert_eq!(try_node_count(name, Scale::Tiny), Some(p.node_count(Scale::Tiny)));
        }
        assert_eq!(try_node_count("gemm", Scale::Tiny), None);
        assert_eq!(try_node_count("synth:warp=1", Scale::Tiny), None);
    }

    #[test]
    fn rw_dial_sets_exact_write_count() {
        for (rw, n) in [(1.0, 1000u64), (0.7, 1000), (0.5, 640), (0.0, 128)] {
            let p = parse(&format!("synth:rw={rw},n={n}")).unwrap();
            let (t, _) = build(&p, Scale::Tiny);
            let stores = t
                .nodes
                .iter()
                .filter(|nd| matches!(nd.kind, crate::trace::OpKind::Store { .. }))
                .count() as u64;
            assert_eq!(stores, p.writes_among(n), "rw={rw}");
            assert_eq!(t.mem_ops() as u64, n, "rw={rw}: one mem op per access");
        }
    }

    #[test]
    fn window_fits_the_reuse_dial() {
        let p = parse("synth:reuse=128,n=256").unwrap();
        let (t, _) = build(&p, Scale::Tiny);
        assert_eq!(t.arrays.len(), 1);
        assert_eq!(t.arrays[0].length, 128);
        assert_eq!(t.arrays[0].elem_bytes, ELEM_BYTES);
        // Past the 64-byte register-promotion threshold by construction.
        assert!(t.arrays[0].length as u64 * ELEM_BYTES as u64 > 64);
    }

    #[test]
    fn scales_are_ordered() {
        let p = SynthParams::default();
        assert!(p.node_count(Scale::Tiny) < p.node_count(Scale::Paper));
        assert!(p.node_count(Scale::Paper) < p.node_count(Scale::Large));
    }
}
