//! MachSuite benchmark ports with dynamic-trace generation.
//!
//! Each benchmark *executes its real algorithm* in Rust while recording
//! every load, store and ALU op through [`crate::trace::TraceBuilder`],
//! producing the same dynamic DDG Aladdin extracts from instrumented
//! LLVM IR. A `checksum` of the computed result is returned so tests can
//! assert the traced execution is functionally correct, not just
//! structurally plausible.
//!
//! The four DSE benchmarks of the paper's Fig 4 are `fft` (FFT-Strided),
//! `gemm` (GEMM-NCUBED), `kmp` and `md_knn`; the remaining nine cover the
//! spatial-locality sweep of Fig 5.
//!
//! Beyond MachSuite, the parametric `synth:` namespace ([`synthetic`])
//! generates locality-dial streaming workloads; [`validate_name`] accepts
//! both families and is the single name gate every front-end should use.

pub mod aes;
pub mod bfs;
pub mod fft;
pub mod gemm;
pub mod kmp;
pub mod md_knn;
pub mod nw;
pub mod sort_merge;
pub mod sort_radix;
pub mod spmv;
pub mod stencil2d;
pub mod stencil3d;
pub mod synthetic;
pub mod viterbi;

use crate::error::{Error, Result};
use crate::trace::Trace;
use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex, OnceLock};

/// A traced benchmark run.
pub struct Workload {
    /// Benchmark name (`gemm`, `fft`, …).
    pub name: &'static str,
    /// The dynamic trace + DDG.
    pub trace: Trace,
    /// Functional checksum of the computed output (see each module for
    /// its definition); tests compare it against an independently
    /// computed reference.
    pub checksum: f64,
}

/// Scale selector: `Tiny` keeps unit tests fast, `Paper` is the size used
/// for the figure reproductions, `Large` stresses the scheduler benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Scale {
    /// Smallest functional size (unit tests).
    Tiny,
    /// Figure-reproduction size (default).
    Paper,
    /// Scheduler-stress size.
    Large,
}

impl Scale {
    /// Stable lowercase name (CLI flags, campaign JSONL records).
    pub fn as_str(&self) -> &'static str {
        match self {
            Scale::Tiny => "tiny",
            Scale::Paper => "paper",
            Scale::Large => "large",
        }
    }

    /// Parse the name produced by [`Scale::as_str`].
    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "tiny" => Some(Scale::Tiny),
            "paper" => Some(Scale::Paper),
            "large" => Some(Scale::Large),
            _ => None,
        }
    }
}

/// Names of the four benchmarks swept in the paper's Fig 4.
pub const DSE_BENCHMARKS: [&str; 4] = ["fft", "gemm", "kmp", "md-knn"];

/// All benchmark names, in Fig-5 display order.
pub const ALL_BENCHMARKS: [&str; 13] = [
    "aes",
    "bfs",
    "fft",
    "gemm",
    "kmp",
    "md-knn",
    "nw",
    "sort-merge",
    "sort-radix",
    "spmv",
    "stencil2d",
    "stencil3d",
    "viterbi",
];

/// Validate a benchmark name: either a MachSuite name from
/// [`ALL_BENCHMARKS`] or a parametric `synth:` spec. This is the single
/// gate every front-end (CLI one-shots, campaign specs, serve) lowers
/// through; synthetic dial errors surface as [`Error::Config`] listing
/// the known dials, anything else as [`Error::UnknownBenchmark`].
pub fn validate_name(name: &str) -> Result<()> {
    if ALL_BENCHMARKS.contains(&name) {
        return Ok(());
    }
    if synthetic::is_synthetic(name) {
        synthetic::parse(name)?;
        return Ok(());
    }
    Err(Error::UnknownBenchmark { name: name.to_string() })
}

/// Intern a dynamically-built benchmark name as `&'static str` so
/// [`Workload::name`] stays a static str across both name families. Each
/// distinct synthetic spec leaks its name once per process — bounded by
/// the number of distinct configurations a run touches.
pub(crate) fn intern_name(name: &str) -> &'static str {
    static NAMES: OnceLock<Mutex<HashSet<&'static str>>> = OnceLock::new();
    let mut set = NAMES.get_or_init(|| Mutex::new(HashSet::new())).lock().expect("name intern");
    match set.get(name) {
        Some(&s) => s,
        None => {
            let s: &'static str = Box::leak(name.to_string().into_boxed_str());
            set.insert(s);
            s
        }
    }
}

/// Generate a benchmark by name at the given scale.
///
/// # Panics
/// On an unknown name — callers validate via [`validate_name`].
pub fn generate(name: &str, scale: Scale) -> Workload {
    if synthetic::is_synthetic(name) {
        return synthetic::generate(name, scale);
    }
    match name {
        "aes" => aes::generate(match scale {
            Scale::Tiny => 1,
            Scale::Paper => 8,
            Scale::Large => 32,
        }),
        "bfs" => bfs::generate(match scale {
            Scale::Tiny => 32,
            Scale::Paper => 256,
            Scale::Large => 1024,
        }),
        "fft" => fft::generate(match scale {
            Scale::Tiny => 64,
            Scale::Paper => 512,
            Scale::Large => 2048,
        }),
        // MachSuite GEMM is 64x64 (power-of-two): the column walk of B
        // strides n words, which conflicts on every power-of-two bank
        // count — the access pattern the paper's GEMM panel hinges on.
        "gemm" => gemm::generate(match scale {
            Scale::Tiny => 8,
            Scale::Paper => 32,
            Scale::Large => 64,
        }),
        "kmp" => kmp::generate(match scale {
            Scale::Tiny => 128,
            Scale::Paper => 1700,
            Scale::Large => 8192,
        }),
        "md-knn" => md_knn::generate(match scale {
            Scale::Tiny => 24,
            Scale::Paper => 128,
            Scale::Large => 512,
        }),
        "nw" => nw::generate(match scale {
            Scale::Tiny => 16,
            Scale::Paper => 64,
            Scale::Large => 160,
        }),
        "sort-merge" => sort_merge::generate(match scale {
            Scale::Tiny => 64,
            Scale::Paper => 512,
            Scale::Large => 4096,
        }),
        "sort-radix" => sort_radix::generate(match scale {
            Scale::Tiny => 64,
            Scale::Paper => 512,
            Scale::Large => 4096,
        }),
        "spmv" => spmv::generate(match scale {
            Scale::Tiny => 32,
            Scale::Paper => 128,
            Scale::Large => 512,
        }),
        "stencil2d" => stencil2d::generate(match scale {
            Scale::Tiny => 8,
            Scale::Paper => 30,
            Scale::Large => 64,
        }),
        "stencil3d" => stencil3d::generate(match scale {
            Scale::Tiny => 6,
            Scale::Paper => 14,
            Scale::Large => 24,
        }),
        "viterbi" => viterbi::generate(match scale {
            Scale::Tiny => 8,
            Scale::Paper => 24,
            Scale::Large => 48,
        }),
        other => panic!("unknown benchmark: {other}"),
    }
}

/// The process-wide memoized workload store behind [`generate_cached`].
fn workload_cache() -> &'static Mutex<HashMap<(String, Scale), Arc<Workload>>> {
    static CACHE: OnceLock<Mutex<HashMap<(String, Scale), Arc<Workload>>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Cache admission ceiling for synthetic traces, in closed-form node
/// count: at or below (≤ Paper-scale synthetic, 2 × 32768 nodes) the
/// workload is memoized like MachSuite; above it `generate_cached`
/// bypasses the cache so a single `synth:...,n=<huge>` point can't pin
/// hundreds of MB for the process lifetime (mirrors the PR 3 decision to
/// keep Large traces out of long-lived state).
pub const SYNTH_CACHE_MAX_NODES: u64 = 65_536;

/// Memoized [`generate`]: each `(name, scale)` workload is generated at
/// most once per process and shared by `Arc` afterwards. Benchmark
/// generation is deterministic, so every caller sees the identical
/// trace. Meant for the paths that genuinely regenerate — campaign /
/// `Explorer` planning and the repeated `perf-smoke` / bench iterations
/// used to re-trace the same workload several times per process; now
/// only the first caller pays. Cached workloads live for the process
/// lifetime (a full `Paper`-scale suite is tens of MB), so one-shot
/// paths should keep calling plain [`generate`]. Synthetic workloads
/// whose closed-form node count exceeds [`SYNTH_CACHE_MAX_NODES`] are
/// generated fresh on every call instead of being pinned.
pub fn generate_cached(name: &str, scale: Scale) -> Arc<Workload> {
    if let Some(nodes) = synthetic::try_node_count(name, scale) {
        if nodes > SYNTH_CACHE_MAX_NODES {
            return Arc::new(generate(name, scale));
        }
    }
    if let Some(wl) =
        workload_cache().lock().expect("workload cache poisoned").get(&(name.to_string(), scale))
    {
        return Arc::clone(wl);
    }
    // Generate outside the lock: Paper/Large traces take a while and
    // generation is deterministic, so a rare duplicate race costs one
    // extra generation, never a divergent result.
    let wl = Arc::new(generate(name, scale));
    let mut cache = workload_cache().lock().expect("workload cache poisoned");
    Arc::clone(cache.entry((name.to_string(), scale)).or_insert(wl))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_benchmarks_generate_valid_traces() {
        for name in ALL_BENCHMARKS {
            let wl = generate(name, Scale::Tiny);
            assert_eq!(wl.name, name);
            wl.trace.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(wl.trace.len() > 0, "{name}: empty trace");
            assert!(wl.trace.mem_ops() > 0, "{name}: no memory ops");
            assert!(wl.checksum.is_finite(), "{name}: bad checksum");
        }
    }

    #[test]
    fn dse_benchmarks_are_a_subset() {
        for name in DSE_BENCHMARKS {
            assert!(ALL_BENCHMARKS.contains(&name));
        }
    }

    #[test]
    fn generate_cached_shares_one_workload_per_key() {
        let a = generate_cached("stencil2d", Scale::Tiny);
        let b = generate_cached("stencil2d", Scale::Tiny);
        assert!(Arc::ptr_eq(&a, &b), "same (name, scale) must hit the cache");
        let other = generate_cached("stencil2d", Scale::Paper);
        assert!(!Arc::ptr_eq(&a, &other), "scales are distinct cache keys");
        // the cached workload is the same deterministic generation
        assert_eq!(a.checksum, generate("stencil2d", Scale::Tiny).checksum);
        assert_eq!(a.trace.len(), generate("stencil2d", Scale::Tiny).trace.len());
    }

    #[test]
    fn validate_name_accepts_both_families() {
        validate_name("gemm").unwrap();
        validate_name("synth:").unwrap();
        validate_name("synth:stride=rand,rw=0.7,reuse=64").unwrap();
        assert!(matches!(
            validate_name("gemmm").unwrap_err(),
            Error::UnknownBenchmark { .. }
        ));
        // a malformed synth spec is a Config error listing the dials
        let e = validate_name("synth:warp=2").unwrap_err().to_string();
        assert!(e.contains("known dials"), "{e}");
    }

    #[test]
    fn synthetic_names_generate_and_intern() {
        let name = "synth:stride=s4,rw=0.5,reuse=64,n=256";
        let wl = generate(name, Scale::Tiny);
        assert_eq!(wl.name, name);
        wl.trace.validate().unwrap();
        assert_eq!(wl.trace.len() as u64, 512);
        // interning is stable across generations
        let again = generate(name, Scale::Tiny);
        assert!(std::ptr::eq(wl.name, again.name));
    }

    #[test]
    fn synthetic_cache_bypass_boundary() {
        // 2 nodes per access: n=32768 sits exactly at the ceiling
        // (cached), n=32769 is one access above it (bypassed).
        let at = "synth:stride=unit,n=32768";
        assert_eq!(
            synthetic::try_node_count(at, Scale::Tiny),
            Some(SYNTH_CACHE_MAX_NODES)
        );
        let a = generate_cached(at, Scale::Tiny);
        let b = generate_cached(at, Scale::Tiny);
        assert!(Arc::ptr_eq(&a, &b), "at the ceiling must still cache");

        let above = "synth:stride=unit,n=32769";
        let c = generate_cached(above, Scale::Tiny);
        let d = generate_cached(above, Scale::Tiny);
        assert!(!Arc::ptr_eq(&c, &d), "above the ceiling must bypass the cache");
        // bypass returns the same deterministic trace, just un-pinned
        assert_eq!(c.checksum, d.checksum);
        assert_eq!(c.trace.len(), d.trace.len());
    }

    #[test]
    fn scale_names_round_trip() {
        for s in [Scale::Tiny, Scale::Paper, Scale::Large] {
            assert_eq!(Scale::parse(s.as_str()), Some(s));
        }
        assert_eq!(Scale::parse("huge"), None);
    }

    #[test]
    fn scales_are_ordered() {
        for name in ["gemm", "fft", "kmp"] {
            let t = generate(name, Scale::Tiny).trace.len();
            let p = generate(name, Scale::Paper).trace.len();
            assert!(t < p, "{name}: tiny {t} !< paper {p}");
        }
    }
}
