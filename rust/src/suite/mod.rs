//! MachSuite benchmark ports with dynamic-trace generation.
//!
//! Each benchmark *executes its real algorithm* in Rust while recording
//! every load, store and ALU op through [`crate::trace::TraceBuilder`],
//! producing the same dynamic DDG Aladdin extracts from instrumented
//! LLVM IR. A `checksum` of the computed result is returned so tests can
//! assert the traced execution is functionally correct, not just
//! structurally plausible.
//!
//! The four DSE benchmarks of the paper's Fig 4 are `fft` (FFT-Strided),
//! `gemm` (GEMM-NCUBED), `kmp` and `md_knn`; the remaining nine cover the
//! spatial-locality sweep of Fig 5.

pub mod aes;
pub mod bfs;
pub mod fft;
pub mod gemm;
pub mod kmp;
pub mod md_knn;
pub mod nw;
pub mod sort_merge;
pub mod sort_radix;
pub mod spmv;
pub mod stencil2d;
pub mod stencil3d;
pub mod viterbi;

use crate::trace::Trace;

/// A traced benchmark run.
pub struct Workload {
    /// Benchmark name (`gemm`, `fft`, …).
    pub name: &'static str,
    /// The dynamic trace + DDG.
    pub trace: Trace,
    /// Functional checksum of the computed output (see each module for
    /// its definition); tests compare it against an independently
    /// computed reference.
    pub checksum: f64,
}

/// Scale selector: `Tiny` keeps unit tests fast, `Paper` is the size used
/// for the figure reproductions, `Large` stresses the scheduler benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Smallest functional size (unit tests).
    Tiny,
    /// Figure-reproduction size (default).
    Paper,
    /// Scheduler-stress size.
    Large,
}

/// Names of the four benchmarks swept in the paper's Fig 4.
pub const DSE_BENCHMARKS: [&str; 4] = ["fft", "gemm", "kmp", "md-knn"];

/// All benchmark names, in Fig-5 display order.
pub const ALL_BENCHMARKS: [&str; 13] = [
    "aes",
    "bfs",
    "fft",
    "gemm",
    "kmp",
    "md-knn",
    "nw",
    "sort-merge",
    "sort-radix",
    "spmv",
    "stencil2d",
    "stencil3d",
    "viterbi",
];

/// Generate a benchmark by name at the given scale.
///
/// # Panics
/// On an unknown name — callers validate against [`ALL_BENCHMARKS`].
pub fn generate(name: &str, scale: Scale) -> Workload {
    match name {
        "aes" => aes::generate(match scale {
            Scale::Tiny => 1,
            Scale::Paper => 8,
            Scale::Large => 32,
        }),
        "bfs" => bfs::generate(match scale {
            Scale::Tiny => 32,
            Scale::Paper => 256,
            Scale::Large => 1024,
        }),
        "fft" => fft::generate(match scale {
            Scale::Tiny => 64,
            Scale::Paper => 512,
            Scale::Large => 2048,
        }),
        // MachSuite GEMM is 64x64 (power-of-two): the column walk of B
        // strides n words, which conflicts on every power-of-two bank
        // count — the access pattern the paper's GEMM panel hinges on.
        "gemm" => gemm::generate(match scale {
            Scale::Tiny => 8,
            Scale::Paper => 32,
            Scale::Large => 64,
        }),
        "kmp" => kmp::generate(match scale {
            Scale::Tiny => 128,
            Scale::Paper => 1700,
            Scale::Large => 8192,
        }),
        "md-knn" => md_knn::generate(match scale {
            Scale::Tiny => 24,
            Scale::Paper => 128,
            Scale::Large => 512,
        }),
        "nw" => nw::generate(match scale {
            Scale::Tiny => 16,
            Scale::Paper => 64,
            Scale::Large => 160,
        }),
        "sort-merge" => sort_merge::generate(match scale {
            Scale::Tiny => 64,
            Scale::Paper => 512,
            Scale::Large => 4096,
        }),
        "sort-radix" => sort_radix::generate(match scale {
            Scale::Tiny => 64,
            Scale::Paper => 512,
            Scale::Large => 4096,
        }),
        "spmv" => spmv::generate(match scale {
            Scale::Tiny => 32,
            Scale::Paper => 128,
            Scale::Large => 512,
        }),
        "stencil2d" => stencil2d::generate(match scale {
            Scale::Tiny => 8,
            Scale::Paper => 30,
            Scale::Large => 64,
        }),
        "stencil3d" => stencil3d::generate(match scale {
            Scale::Tiny => 6,
            Scale::Paper => 14,
            Scale::Large => 24,
        }),
        "viterbi" => viterbi::generate(match scale {
            Scale::Tiny => 8,
            Scale::Paper => 24,
            Scale::Large => 48,
        }),
        other => panic!("unknown benchmark: {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_benchmarks_generate_valid_traces() {
        for name in ALL_BENCHMARKS {
            let wl = generate(name, Scale::Tiny);
            assert_eq!(wl.name, name);
            wl.trace.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(wl.trace.len() > 0, "{name}: empty trace");
            assert!(wl.trace.mem_ops() > 0, "{name}: no memory ops");
            assert!(wl.checksum.is_finite(), "{name}: bad checksum");
        }
    }

    #[test]
    fn dse_benchmarks_are_a_subset() {
        for name in DSE_BENCHMARKS {
            assert!(ALL_BENCHMARKS.contains(&name));
        }
    }

    #[test]
    fn scales_are_ordered() {
        for name in ["gemm", "fft", "kmp"] {
            let t = generate(name, Scale::Tiny).trace.len();
            let p = generate(name, Scale::Paper).trace.len();
            assert!(t < p, "{name}: tiny {t} !< paper {p}");
        }
    }
}
