//! BFS-Queue (MachSuite `bfs/queue`): breadth-first search over a CSR
//! graph with an explicit work queue. Edge-list walks are sequential but
//! node-level gathers are scattered ⇒ low-to-mid locality.

use super::Workload;
use crate::trace::{AluKind, TraceBuilder};
use crate::util::rng::Rng;

const SITE_QUEUE_RD: u32 = 0;
const SITE_EDGE_BEGIN: u32 = 1;
const SITE_EDGE_DST: u32 = 2;
const SITE_LEVEL_RD: u32 = 3;
const SITE_LEVEL_WR: u32 = 4;
const SITE_QUEUE_WR: u32 = 5;

const DEGREE: usize = 6;

/// Generate a BFS trace over an `n`-node random graph.
/// Checksum = Σ level[v] over reached nodes.
pub fn generate(n: usize) -> Workload {
    let mut rng = Rng::new(0xBF5 ^ n as u64);
    // CSR random graph with fixed out-degree; ring edges guarantee
    // connectivity so BFS reaches every node.
    let mut edge_begin = vec![0u32; n + 1];
    let mut edge_dst = Vec::with_capacity(n * DEGREE);
    for v in 0..n {
        edge_begin[v + 1] = edge_begin[v] + DEGREE as u32;
        edge_dst.push(((v + 1) % n) as u32);
        for _ in 1..DEGREE {
            edge_dst.push(rng.below_usize(n) as u32);
        }
    }

    let mut b = TraceBuilder::new();
    let a_begin = b.array("edge_begin", 4, (n + 1) as u32);
    let a_dst = b.array("edge_dst", 4, (n * DEGREE) as u32);
    let a_level = b.array("level", 1, n as u32);
    let a_queue = b.array("queue", 4, n as u32);

    const UNVISITED: u8 = u8::MAX;
    let mut level = vec![UNVISITED; n];
    let mut queue = vec![0u32; n];
    let (mut head, mut tail) = (0usize, 0usize);
    level[0] = 0;
    queue[tail] = 0;
    tail += 1;
    let mut level_store = vec![None; n];
    let mut queue_store: Vec<Option<crate::trace::NodeId>> = vec![None; n];
    let s0 = b.store(a_level, 0, &[]);
    level_store[0] = Some(s0);
    let q0 = b.store(a_queue, 0, &[]);
    queue_store[0] = Some(q0);

    while head < tail {
        b.site(SITE_QUEUE_RD);
        let lq = b.load_dep(a_queue, head as u32, &[queue_store[head].unwrap()]);
        let v = queue[head] as usize;
        head += 1;
        b.site(SITE_EDGE_BEGIN);
        let lb0 = b.load_dep(a_begin, v as u32, &[lq]);
        let lb1 = b.load_dep(a_begin, (v + 1) as u32, &[lq]);
        let bound = b.alu(AluKind::Cmp, &[lb0, lb1]);
        for e in edge_begin[v]..edge_begin[v + 1] {
            b.site(SITE_EDGE_DST);
            let ld = b.load_dep(a_dst, e, &[bound]);
            let w = edge_dst[e as usize] as usize;
            b.site(SITE_LEVEL_RD);
            let mut deps = vec![ld];
            if let Some(s) = level_store[w] {
                deps.push(s);
            }
            let ll = b.load_dep(a_level, w as u32, &deps);
            let cmp = b.alu(AluKind::Cmp, &[ll]);
            if level[w] == UNVISITED {
                level[w] = level[v] + 1;
                b.site(SITE_LEVEL_WR);
                let sw = b.store(a_level, w as u32, &[cmp]);
                level_store[w] = Some(sw);
                b.site(SITE_QUEUE_WR);
                let qw = b.store(a_queue, tail as u32, &[cmp]);
                queue_store[tail] = Some(qw);
                queue[tail] = w as u32;
                tail += 1;
            }
            b.next_iter();
        }
    }

    let checksum = level.iter().filter(|&&l| l != UNVISITED).map(|&l| l as f64).sum();
    Workload { name: "bfs", trace: b.finish(), checksum }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reaches_all_nodes() {
        // The ring edge guarantees full reachability: levels all set.
        let n = 64;
        let wl = generate(n);
        // checksum = sum of levels; with a ring + random edges diameter is
        // small, so sum < n * n but > 0.
        assert!(wl.checksum > 0.0);
        assert!(wl.checksum < (n * n) as f64);
    }

    #[test]
    fn visits_each_node_once() {
        let n = 32;
        let wl = generate(n);
        //每 node exactly one queue store + one level store (plus source).
        let q_id = wl.trace.arrays.iter().position(|a| a.name == "queue").unwrap() as u16;
        let q_stores = wl
            .trace
            .nodes
            .iter()
            .filter(|nd| matches!(nd.kind, crate::trace::OpKind::Store { array, .. } if array == q_id))
            .count();
        assert_eq!(q_stores, n);
    }
}
