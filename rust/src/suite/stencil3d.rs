//! Stencil-3D (MachSuite `stencil/stencil3d`): 7-point von-Neumann
//! stencil over a 3-D grid. Plane strides of `dim²·4` bytes pull the
//! locality well below the 2-D case.

use super::Workload;
use crate::trace::{AluKind, TraceBuilder};
use crate::util::rng::Rng;

const SITE_IN: u32 = 0;
const SITE_OUT: u32 = 1;

/// Generate a `dim³` 7-point stencil trace. Checksum = Σ output.
pub fn generate(dim: usize) -> Workload {
    assert!(dim >= 3);
    let mut rng = Rng::new(0x57E4C3D);
    let grid: Vec<i64> = (0..dim * dim * dim).map(|_| rng.below(100) as i64).collect();
    let mut out = grid.clone();
    let (c0, c1) = (2i64, 1i64);
    let idx = |i: usize, j: usize, k: usize| (i * dim + j) * dim + k;

    let mut b = TraceBuilder::new();
    let a_in = b.array("orig", 4, (dim * dim * dim) as u32);
    let a_out = b.array("sol", 4, (dim * dim * dim) as u32);

    for i in 1..dim - 1 {
        for j in 1..dim - 1 {
            for k in 1..dim - 1 {
                let offs = [
                    idx(i, j, k),
                    idx(i - 1, j, k),
                    idx(i + 1, j, k),
                    idx(i, j - 1, k),
                    idx(i, j + 1, k),
                    idx(i, j, k - 1),
                    idx(i, j, k + 1),
                ];
                let mut loads = Vec::with_capacity(7);
                for &o in &offs {
                    b.site(SITE_IN);
                    loads.push(b.load(a_in, o as u32));
                }
                let m0 = b.alu(AluKind::IntMul, &[loads[0]]);
                let sum1 = b.alu(AluKind::IntAdd, &loads[1..]);
                let m1 = b.alu(AluKind::IntMul, &[sum1]);
                let total = b.alu(AluKind::IntAdd, &[m0, m1]);
                b.site(SITE_OUT);
                b.store(a_out, offs[0] as u32, &[total]);

                let sum: i64 = offs[1..].iter().map(|&o| grid[o]).sum();
                out[offs[0]] = c0 * grid[offs[0]] + c1 * sum;
                b.next_iter();
            }
        }
    }

    let checksum = out.iter().map(|&x| x as f64).sum();
    Workload { name: "stencil3d", trace: b.finish(), checksum }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interior_cells_only() {
        let dim = 5;
        let wl = generate(dim);
        let interior = (dim - 2) * (dim - 2) * (dim - 2);
        // 7 loads + 1 store per interior cell
        assert_eq!(wl.trace.mem_ops(), interior * 8);
    }

    #[test]
    fn boundary_unchanged_in_checksum() {
        let dim = 4;
        let mut rng = Rng::new(0x57E4C3D);
        let grid: Vec<i64> = (0..dim * dim * dim).map(|_| rng.below(100) as i64).collect();
        let idx = |i: usize, j: usize, k: usize| (i * dim + j) * dim + k;
        let mut want: f64 = grid.iter().map(|&x| x as f64).sum();
        for i in 1..dim - 1 {
            for j in 1..dim - 1 {
                for k in 1..dim - 1 {
                    let sum: i64 = [
                        grid[idx(i - 1, j, k)],
                        grid[idx(i + 1, j, k)],
                        grid[idx(i, j - 1, k)],
                        grid[idx(i, j + 1, k)],
                        grid[idx(i, j, k - 1)],
                        grid[idx(i, j, k + 1)],
                    ]
                    .iter()
                    .sum();
                    want += (2 * grid[idx(i, j, k)] + sum - grid[idx(i, j, k)]) as f64;
                }
            }
        }
        assert_eq!(generate(dim).checksum, want);
    }
}
