//! FFT-Strided (MachSuite `fft/strided`): iterative radix-2 butterfly
//! with span-strided access — the span halves every stage, so the access
//! stride sweeps n/2 · 8 bytes down to 8 bytes. Double precision ⇒
//! minimum byte-stride 8 ⇒ low Weinberg locality (paper §IV-B).

use super::Workload;
use crate::trace::{AluKind, TraceBuilder};

const SITE_RE_EVEN: u32 = 0;
const SITE_RE_ODD: u32 = 1;
const SITE_IM_EVEN: u32 = 2;
const SITE_IM_ODD: u32 = 3;
const SITE_TW_RE: u32 = 4;
const SITE_TW_IM: u32 = 5;
const SITE_ST_RE_ODD: u32 = 6;
const SITE_ST_RE_EVEN: u32 = 7;
const SITE_ST_IM_ODD: u32 = 8;
const SITE_ST_IM_EVEN: u32 = 9;

/// Generate an `n`-point strided FFT trace (n must be a power of two).
/// Checksum = Σ |re| + |im| over the transformed signal.
pub fn generate(n: usize) -> Workload {
    assert!(n.is_power_of_two() && n >= 4, "fft size must be a power of two >= 4");
    // Input: a deterministic tone mix.
    let mut re: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).sin() + 0.5 * (i as f64 * 1.7).cos()).collect();
    let mut im: Vec<f64> = vec![0.0; n];
    let phase = |i: usize| -2.0 * std::f64::consts::PI * i as f64 / n as f64;
    let tw_re: Vec<f64> = (0..n / 2).map(|i| phase(i).cos()).collect();
    let tw_im: Vec<f64> = (0..n / 2).map(|i| phase(i).sin()).collect();

    let mut b = TraceBuilder::new();
    let a_re = b.array("real", 8, n as u32);
    let a_im = b.array("img", 8, n as u32);
    let a_twr = b.array("real_twid", 8, (n / 2) as u32);
    let a_twi = b.array("img_twid", 8, (n / 2) as u32);

    let mut log = 0u32;
    let mut span = n >> 1;
    while span != 0 {
        let mut odd = span;
        while odd < n {
            odd |= span;
            let even = odd ^ span;

            b.site(SITE_RE_EVEN);
            let l_re_e = b.load(a_re, even as u32);
            b.site(SITE_RE_ODD);
            let l_re_o = b.load(a_re, odd as u32);
            let sum_re = b.alu(AluKind::FAdd, &[l_re_e, l_re_o]);
            let dif_re = b.alu(AluKind::FAdd, &[l_re_e, l_re_o]);
            b.site(SITE_ST_RE_ODD);
            let s_re_o = b.store(a_re, odd as u32, &[dif_re]);
            b.site(SITE_ST_RE_EVEN);
            b.store(a_re, even as u32, &[sum_re]);

            b.site(SITE_IM_EVEN);
            let l_im_e = b.load(a_im, even as u32);
            b.site(SITE_IM_ODD);
            let l_im_o = b.load(a_im, odd as u32);
            let sum_im = b.alu(AluKind::FAdd, &[l_im_e, l_im_o]);
            let dif_im = b.alu(AluKind::FAdd, &[l_im_e, l_im_o]);
            b.site(SITE_ST_IM_ODD);
            let s_im_o = b.store(a_im, odd as u32, &[dif_im]);
            b.site(SITE_ST_IM_EVEN);
            b.store(a_im, even as u32, &[sum_im]);

            // Mirror the arithmetic on the data side.
            let t = re[even] + re[odd];
            re[odd] = re[even] - re[odd];
            re[even] = t;
            let t = im[even] + im[odd];
            im[odd] = im[even] - im[odd];
            im[even] = t;

            let rootindex = (even << log) & (n - 1);
            if rootindex != 0 {
                b.site(SITE_TW_RE);
                let l_twr = b.load(a_twr, rootindex as u32);
                b.site(SITE_TW_IM);
                let l_twi = b.load(a_twi, rootindex as u32);
                // temp = twr*re[odd] - twi*im[odd]
                b.site(SITE_RE_ODD);
                let l_ro = b.load_dep(a_re, odd as u32, &[s_re_o]);
                b.site(SITE_IM_ODD);
                let l_io = b.load_dep(a_im, odd as u32, &[s_im_o]);
                let m1 = b.alu(AluKind::FMul, &[l_twr, l_ro]);
                let m2 = b.alu(AluKind::FMul, &[l_twi, l_io]);
                let temp = b.alu(AluKind::FAdd, &[m1, m2]);
                let m3 = b.alu(AluKind::FMul, &[l_twr, l_io]);
                let m4 = b.alu(AluKind::FMul, &[l_twi, l_ro]);
                let imv = b.alu(AluKind::FAdd, &[m3, m4]);
                b.site(SITE_ST_IM_ODD);
                b.store(a_im, odd as u32, &[imv]);
                b.site(SITE_ST_RE_ODD);
                b.store(a_re, odd as u32, &[temp]);

                let tv = tw_re[rootindex] * re[odd] - tw_im[rootindex] * im[odd];
                im[odd] = tw_re[rootindex] * im[odd] + tw_im[rootindex] * re[odd];
                re[odd] = tv;
            }
            b.next_iter();
            odd += 1;
        }
        span >>= 1;
        log += 1;
    }

    let checksum = re.iter().map(|x| x.abs()).sum::<f64>() + im.iter().map(|x| x.abs()).sum::<f64>();
    Workload { name: "fft", trace: b.finish(), checksum }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference DFT energy check: the strided FFT's output bins, when
    /// bit-reversal-reordered, match a naive DFT.
    #[test]
    fn energy_preserved_vs_dft() {
        let n = 64usize;
        let input: Vec<f64> =
            (0..n).map(|i| (i as f64 * 0.3).sin() + 0.5 * (i as f64 * 1.7).cos()).collect();
        // naive DFT magnitude-sum (Parseval-like invariant under reorder)
        let mut mag2 = 0.0;
        for k in 0..n {
            let (mut sr, mut si) = (0.0, 0.0);
            for (t, &x) in input.iter().enumerate() {
                let ang = -2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64;
                sr += x * ang.cos();
                si += x * ang.sin();
            }
            mag2 += sr * sr + si * si;
        }
        let wl = generate(n);
        // The traced FFT computes the same transform (in bit-reversed
        // order); compare total energy.
        // Re-run the pure data computation to get bins:
        // (generate() already did, its checksum is the L1 norm — compare
        // magnitude² via a second pass)
        let (re, im) = run_data_fft(n);
        let got: f64 = re.iter().zip(&im).map(|(r, i)| r * r + i * i).sum();
        assert!((got - mag2).abs() / mag2 < 1e-9, "got {got} want {mag2}");
        assert!(wl.checksum > 0.0);
    }

    fn run_data_fft(n: usize) -> (Vec<f64>, Vec<f64>) {
        let mut re: Vec<f64> =
            (0..n).map(|i| (i as f64 * 0.3).sin() + 0.5 * (i as f64 * 1.7).cos()).collect();
        let mut im = vec![0.0; n];
        let tw_re: Vec<f64> =
            (0..n / 2).map(|i| (-2.0 * std::f64::consts::PI * i as f64 / n as f64).cos()).collect();
        let tw_im: Vec<f64> =
            (0..n / 2).map(|i| (-2.0 * std::f64::consts::PI * i as f64 / n as f64).sin()).collect();
        let mut log = 0;
        let mut span = n >> 1;
        while span != 0 {
            let mut odd = span;
            while odd < n {
                odd |= span;
                let even = odd ^ span;
                let t = re[even] + re[odd];
                re[odd] = re[even] - re[odd];
                re[even] = t;
                let t = im[even] + im[odd];
                im[odd] = im[even] - im[odd];
                im[even] = t;
                let rootindex = (even << log) & (n - 1);
                if rootindex != 0 {
                    let tv = tw_re[rootindex] * re[odd] - tw_im[rootindex] * im[odd];
                    im[odd] = tw_re[rootindex] * im[odd] + tw_im[rootindex] * re[odd];
                    re[odd] = tv;
                }
                odd += 1;
            }
            span >>= 1;
            log += 1;
        }
        (re, im)
    }

    #[test]
    fn stage_count_drives_trace_size() {
        let w64 = generate(64).trace.len();
        let w256 = generate(256).trace.len();
        // n log n growth: 256·8 vs 64·6 ≈ 5.3×
        let ratio = w256 as f64 / w64 as f64;
        assert!(ratio > 4.0 && ratio < 7.0, "ratio {ratio}");
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_pow2() {
        generate(100);
    }
}
