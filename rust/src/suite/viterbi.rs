//! Viterbi (MachSuite `viterbi/viterbi`): HMM maximum-likelihood path
//! over `n_states` hidden states and an observation sequence, in
//! negative-log space (min-sum). The transition-matrix walk is row-major
//! but every step reads a full `n_states²` block ⇒ mid locality.

use super::Workload;
use crate::trace::{AluKind, TraceBuilder};
use crate::util::rng::Rng;

const SITE_OBS: u32 = 0;
const SITE_TRANS: u32 = 1;
const SITE_EMIT: u32 = 2;
const SITE_PROB_RD: u32 = 3;
const SITE_PROB_WR: u32 = 4;

/// Observation alphabet size.
const N_OBS: usize = 16;
/// Sequence length multiplier (length = 2 × n_states keeps the trace
/// quadratic like MachSuite's fixed input).
const SEQ_FACTOR: usize = 2;

/// Generate a Viterbi trace with `n_states` states.
/// Checksum = final minimum path metric.
pub fn generate(n_states: usize) -> Workload {
    let seq_len = n_states * SEQ_FACTOR;
    let mut rng = Rng::new(0x517E ^ n_states as u64);
    let obs: Vec<u8> = (0..seq_len).map(|_| rng.below_usize(N_OBS) as u8).collect();
    let init: Vec<f64> = (0..n_states).map(|_| rng.f64() * 4.0 + 0.1).collect();
    let trans: Vec<f64> = (0..n_states * n_states).map(|_| rng.f64() * 4.0 + 0.1).collect();
    let emit: Vec<f64> = (0..n_states * N_OBS).map(|_| rng.f64() * 4.0 + 0.1).collect();

    let mut b = TraceBuilder::new();
    let a_obs = b.array("obs", 1, seq_len as u32);
    let a_trans = b.array("transition", 8, (n_states * n_states) as u32);
    let a_emit = b.array("emission", 8, (n_states * N_OBS) as u32);
    let a_prob = b.array("llike", 8, (2 * n_states) as u32); // ping-pong rows

    // init row 0
    let mut cur = init.clone();
    let mut prob_store: Vec<Option<crate::trace::NodeId>> = vec![None; 2 * n_states];
    for s in 0..n_states {
        b.site(SITE_PROB_WR);
        let st = b.store(a_prob, s as u32, &[]);
        prob_store[s] = Some(st);
    }

    for t in 1..seq_len {
        b.site(SITE_OBS);
        let lo = b.load(a_obs, t as u32);
        let (prev_off, cur_off) = if t % 2 == 1 { (0, n_states) } else { (n_states, 0) };
        let mut next = vec![0.0f64; n_states];
        for s in 0..n_states {
            let mut best = f64::INFINITY;
            let mut acc: Option<crate::trace::NodeId> = None;
            for p in 0..n_states {
                b.site(SITE_PROB_RD);
                let mut deps = vec![lo];
                if let Some(ps) = prob_store[prev_off + p] {
                    deps.push(ps);
                }
                let lp = b.load_dep(a_prob, (prev_off + p) as u32, &deps);
                b.site(SITE_TRANS);
                let lt = b.load(a_trans, (p * n_states + s) as u32);
                let add = b.alu(AluKind::FAdd, &[lp, lt]);
                acc = Some(match acc {
                    None => add,
                    Some(a) => b.alu(AluKind::Cmp, &[a, add]), // running min
                });
                let cand = cur[p] + trans[p * n_states + s];
                if cand < best {
                    best = cand;
                }
            }
            b.site(SITE_EMIT);
            let le = b.load_dep(a_emit, (s * N_OBS + obs[t] as usize) as u32, &[lo]);
            let tot = b.alu(AluKind::FAdd, &[acc.unwrap(), le]);
            b.site(SITE_PROB_WR);
            let st = b.store(a_prob, (cur_off + s) as u32, &[tot]);
            prob_store[cur_off + s] = Some(st);
            next[s] = best + emit[s * N_OBS + obs[t] as usize];
            b.next_iter();
        }
        cur = next;
    }

    let checksum = cur.iter().cloned().fold(f64::INFINITY, f64::min);
    Workload { name: "viterbi", trace: b.finish(), checksum }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metric_grows_with_sequence() {
        // Path metric is a sum of ~seq_len positive terms.
        let wl = generate(8);
        assert!(wl.checksum > 0.0);
        assert!(wl.checksum.is_finite());
        // bounded by seq_len * max(term) = 16 * ~8.2
        assert!(wl.checksum < 8.0 * 16.0 * 2.0);
    }

    #[test]
    fn quadratic_trace_growth() {
        let a = generate(8).trace.len();
        let b = generate(16).trace.len();
        // states² · seq ⇒ ×2 states = ×8 nodes
        let ratio = b as f64 / a as f64;
        assert!(ratio > 6.0 && ratio < 10.0, "ratio {ratio}");
    }
}
