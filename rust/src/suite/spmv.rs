//! SPMV-CRS (MachSuite `spmv/crs`): sparse matrix–vector multiply in
//! compressed-row storage. The column-index indirection into the dense
//! vector is a scattered 8-byte gather ⇒ low locality.

use super::Workload;
use crate::trace::{AluKind, TraceBuilder};
use crate::util::rng::Rng;

const SITE_VAL: u32 = 0;
const SITE_COL: u32 = 1;
const SITE_VEC: u32 = 2;
const SITE_ROWB: u32 = 3;
const SITE_OUT: u32 = 4;

/// Nonzeros per row (MachSuite crs uses a fixed-ish density).
const NNZ_PER_ROW: usize = 13;

/// Generate an `n`-row SPMV trace. Checksum = Σ out.
pub fn generate(n: usize) -> Workload {
    assert!(n > NNZ_PER_ROW);
    let mut rng = Rng::new(0x5B37 ^ n as u64);
    let nnz = n * NNZ_PER_ROW;
    let vals: Vec<f64> = (0..nnz).map(|_| rng.f64() * 2.0 - 1.0).collect();
    let mut cols = vec![0u32; nnz];
    let mut rowb = vec![0u32; n + 1];
    for r in 0..n {
        rowb[r + 1] = ((r + 1) * NNZ_PER_ROW) as u32;
        let mut seen = std::collections::HashSet::new();
        let mut j = 0;
        while j < NNZ_PER_ROW {
            let c = rng.below_usize(n);
            if seen.insert(c) {
                cols[r * NNZ_PER_ROW + j] = c as u32;
                j += 1;
            }
        }
        cols[r * NNZ_PER_ROW..(r + 1) * NNZ_PER_ROW].sort_unstable();
    }
    let vec: Vec<f64> = (0..n).map(|_| rng.f64() * 2.0 - 1.0).collect();
    let mut out = vec![0.0f64; n];

    let mut b = TraceBuilder::new();
    let a_val = b.array("val", 8, nnz as u32);
    let a_cols = b.array("cols", 4, nnz as u32);
    let a_rowb = b.array("rowDelimiters", 4, (n + 1) as u32);
    let a_vec = b.array("vec", 8, n as u32);
    let a_out = b.array("out", 8, n as u32);

    for r in 0..n {
        b.site(SITE_ROWB);
        let l_start = b.load(a_rowb, r as u32);
        let l_end = b.load(a_rowb, (r + 1) as u32);
        let bound = b.alu(AluKind::Cmp, &[l_start, l_end]);
        let mut acc = None;
        let mut sum = 0.0f64;
        for j in rowb[r]..rowb[r + 1] {
            b.site(SITE_VAL);
            let lv = b.load_dep(a_val, j, &[bound]);
            b.site(SITE_COL);
            let lc = b.load_dep(a_cols, j, &[bound]);
            b.site(SITE_VEC);
            let lx = b.load_dep(a_vec, cols[j as usize], &[lc]);
            let mul = b.alu(AluKind::FMul, &[lv, lx]);
            acc = Some(match acc {
                None => mul,
                Some(p) => b.alu(AluKind::FAdd, &[p, mul]),
            });
            sum += vals[j as usize] * vec[cols[j as usize] as usize];
            b.next_iter();
        }
        out[r] = sum;
        b.site(SITE_OUT);
        b.store(a_out, r as u32, &[acc.unwrap()]);
    }

    Workload { name: "spmv", trace: b.finish(), checksum: out.iter().sum() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_lengths_are_uniform() {
        let wl = generate(32);
        // mem ops: per row: 2 rowb + nnz*(3 loads) + 1 store
        assert_eq!(wl.trace.mem_ops(), 32 * (2 + NNZ_PER_ROW * 3 + 1));
    }

    #[test]
    fn checksum_is_finite_nonzero() {
        let wl = generate(20);
        assert!(wl.checksum.is_finite());
        assert!(wl.checksum.abs() > 1e-12);
    }

    #[test]
    fn vector_gather_is_scattered() {
        let wl = generate(32);
        let vid = wl.trace.arrays.iter().position(|a| a.name == "vec").unwrap() as u16;
        let idxs: Vec<u32> = wl
            .trace
            .nodes
            .iter()
            .filter_map(|n| match n.kind.mem_ref() {
                Some((a, i)) if a == vid => Some(i),
                _ => None,
            })
            .collect();
        let stride1 = idxs.windows(2).filter(|w| w[1] == w[0] + 1).count();
        assert!((stride1 as f64) < 0.5 * idxs.len() as f64);
    }
}
