//! GEMM-NCUBED (MachSuite `gemm/ncubed`): dense `C = A·B`, triple loop,
//! double precision. Low spatial locality: 8-byte elements and the
//! column-strided walk of `B` (stride = n·8 bytes).

use super::Workload;
use crate::trace::{AluKind, TraceBuilder};
use crate::util::rng::Rng;

/// Sites (static load/store instructions).
const SITE_LOAD_A: u32 = 0;
const SITE_LOAD_B: u32 = 1;
const SITE_STORE_C: u32 = 2;

/// Generate an `n × n × n` GEMM trace. Checksum = Σ C[i][j].
pub fn generate(n: usize) -> Workload {
    let mut rng = Rng::new(0x6E44 ^ n as u64);
    let a: Vec<f64> = (0..n * n).map(|_| rng.f64() * 2.0 - 1.0).collect();
    let bm: Vec<f64> = (0..n * n).map(|_| rng.f64() * 2.0 - 1.0).collect();
    let mut c = vec![0.0f64; n * n];

    let mut b = TraceBuilder::new();
    let arr_a = b.array("A", 8, (n * n) as u32);
    let arr_b = b.array("B", 8, (n * n) as u32);
    let arr_c = b.array("C", 8, (n * n) as u32);

    for i in 0..n {
        for j in 0..n {
            let mut sum = 0.0f64;
            let mut acc_node = None;
            for k in 0..n {
                b.site(SITE_LOAD_A);
                let la = b.load(arr_a, (i * n + k) as u32);
                b.site(SITE_LOAD_B);
                let lb = b.load(arr_b, (k * n + j) as u32);
                let mul = b.alu(AluKind::FMul, &[la, lb]);
                acc_node = Some(match acc_node {
                    None => mul,
                    Some(prev) => b.alu(AluKind::FAdd, &[prev, mul]),
                });
                sum += a[i * n + k] * bm[k * n + j];
                b.next_iter();
            }
            c[i * n + j] = sum;
            b.site(SITE_STORE_C);
            b.store(arr_c, (i * n + j) as u32, &[acc_node.unwrap()]);
        }
    }

    Workload { name: "gemm", trace: b.finish(), checksum: c.iter().sum() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_multiply() {
        // Independent recomputation with the same RNG stream.
        let n = 8;
        let mut rng = Rng::new(0x6E44 ^ n as u64);
        let a: Vec<f64> = (0..n * n).map(|_| rng.f64() * 2.0 - 1.0).collect();
        let bm: Vec<f64> = (0..n * n).map(|_| rng.f64() * 2.0 - 1.0).collect();
        let mut want = 0.0;
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += a[i * n + k] * bm[k * n + j];
                }
                want += s;
            }
        }
        let wl = generate(n);
        assert!((wl.checksum - want).abs() < 1e-9);
    }

    #[test]
    fn node_count_is_n_cubed_scale() {
        let n = 8;
        let wl = generate(n);
        // per (i,j,k): 2 loads + 1 mul + (1 add except first k) ; per (i,j): 1 store
        let expect = n * n * n * 4 - n * n + n * n;
        assert_eq!(wl.trace.len(), expect);
    }

    #[test]
    fn mem_to_alu_ratio() {
        let wl = generate(8);
        // 2 loads per 2 flops + stores: memory-heavy benchmark.
        assert!(wl.trace.mem_ops() as f64 / wl.trace.len() as f64 > 0.4);
    }
}
