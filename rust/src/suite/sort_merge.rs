//! Sort-Merge (MachSuite `sort/merge`): bottom-up merge sort. Mostly
//! sequential 4-byte walks with a ping-pong temp array — a memory-bound
//! benchmark with mid-range locality.

use super::Workload;
use crate::trace::{AluKind, TraceBuilder};
use crate::util::rng::Rng;

const SITE_A_RD: u32 = 0;
const SITE_TMP_WR: u32 = 1;
const SITE_TMP_RD: u32 = 2;
const SITE_A_WR: u32 = 3;

/// Generate a merge-sort trace over `n` i32 keys.
/// Checksum = Σ a[i]·(i+1) of the sorted array (order-sensitive).
pub fn generate(n: usize) -> Workload {
    let mut rng = Rng::new(0x50B7 ^ n as u64);
    let mut a: Vec<i32> = (0..n).map(|_| rng.next_u32() as i32 % 10_000).collect();

    let mut b = TraceBuilder::new();
    let a_arr = b.array("a", 4, n as u32);
    let a_tmp = b.array("temp", 4, n as u32);

    let mut width = 1usize;
    while width < n {
        let mut lo = 0usize;
        while lo < n {
            let mid = (lo + width).min(n);
            let hi = (lo + 2 * width).min(n);
            // merge a[lo..mid] and a[mid..hi] into tmp[lo..hi]
            let (mut i, mut j) = (lo, mid);
            let mut tmp_nodes: Vec<crate::trace::NodeId> = Vec::with_capacity(hi - lo);
            let mut merged: Vec<i32> = Vec::with_capacity(hi - lo);
            for k in lo..hi {
                let take_left = j >= hi || (i < mid && a[i] <= a[j]);
                let src = if take_left { i } else { j };
                b.site(SITE_A_RD);
                let l = b.load(a_arr, src as u32);
                let c = b.alu(AluKind::Cmp, &[l]);
                b.site(SITE_TMP_WR);
                let s = b.store(a_tmp, k as u32, &[c]);
                tmp_nodes.push(s);
                merged.push(a[src]);
                if take_left {
                    i += 1;
                } else {
                    j += 1;
                }
                b.next_iter();
            }
            // copy back
            for (off, k) in (lo..hi).enumerate() {
                b.site(SITE_TMP_RD);
                let l = b.load_dep(a_tmp, k as u32, &[tmp_nodes[off]]);
                b.site(SITE_A_WR);
                b.store(a_arr, k as u32, &[l]);
                a[k] = merged[off];
                b.next_iter();
            }
            lo += 2 * width;
        }
        width *= 2;
    }

    let checksum = a.iter().enumerate().map(|(i, &x)| x as f64 * (i + 1) as f64).sum();
    Workload { name: "sort-merge", trace: b.finish(), checksum }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sorts_correctly() {
        let n = 64;
        let mut rng = Rng::new(0x50B7 ^ n as u64);
        let mut want: Vec<i32> = (0..n).map(|_| rng.next_u32() as i32 % 10_000).collect();
        want.sort_unstable();
        let want_sum: f64 =
            want.iter().enumerate().map(|(i, &x)| x as f64 * (i + 1) as f64).sum();
        assert_eq!(generate(n).checksum, want_sum);
    }

    #[test]
    fn n_log_n_mem_ops() {
        let w = generate(64);
        let levels = 6; // log2(64)
        // each level: n merge (1 load+1 store) + n copy-back (1+1)
        assert_eq!(w.trace.mem_ops(), 64 * levels * 4);
    }
}
