//! Stencil-2D (MachSuite `stencil/stencil2d`): 3×3 convolution filter
//! over a 2-D grid. Row-major inner loop is stride-1 over 4-byte
//! elements, but each output reads three rows ⇒ moderate locality.

use super::Workload;
use crate::trace::{AluKind, TraceBuilder};
use crate::util::rng::Rng;

const SITE_ORIG: u32 = 0;
const SITE_FILT: u32 = 1;
const SITE_SOL: u32 = 2;

/// Generate a `rows × rows` stencil trace. Checksum = Σ output.
pub fn generate(rows: usize) -> Workload {
    let cols = rows;
    let mut rng = Rng::new(0x57E4C11);
    let orig: Vec<i64> = (0..rows * cols).map(|_| (rng.below(100)) as i64).collect();
    let filt: Vec<i64> = (0..9).map(|i| (i as i64) - 4).collect();
    let mut sol = vec![0i64; rows * cols];

    let mut b = TraceBuilder::new();
    let a_orig = b.array("orig", 4, (rows * cols) as u32);
    let a_filt = b.array("filter", 4, 9);
    let a_sol = b.array("sol", 4, (rows * cols) as u32);

    for r in 0..rows - 2 {
        for c in 0..cols - 2 {
            let mut acc = None;
            let mut temp = 0i64;
            for k1 in 0..3 {
                for k2 in 0..3 {
                    b.site(SITE_FILT);
                    let lf = b.load(a_filt, (k1 * 3 + k2) as u32);
                    b.site(SITE_ORIG);
                    let lo = b.load(a_orig, ((r + k1) * cols + c + k2) as u32);
                    let mul = b.alu(AluKind::IntMul, &[lf, lo]);
                    acc = Some(match acc {
                        None => mul,
                        Some(p) => b.alu(AluKind::IntAdd, &[p, mul]),
                    });
                    temp += filt[k1 * 3 + k2] * orig[(r + k1) * cols + c + k2];
                }
            }
            sol[r * cols + c] = temp;
            b.site(SITE_SOL);
            b.store(a_sol, (r * cols + c) as u32, &[acc.unwrap()]);
            b.next_iter();
        }
    }

    let checksum = sol.iter().map(|&x| x as f64).sum();
    Workload { name: "stencil2d", trace: b.finish(), checksum }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_convolution() {
        let rows = 8;
        let mut rng = Rng::new(0x57E4C11);
        let orig: Vec<i64> = (0..rows * rows).map(|_| rng.below(100) as i64).collect();
        let filt: Vec<i64> = (0..9).map(|i| (i as i64) - 4).collect();
        let mut want = 0f64;
        for r in 0..rows - 2 {
            for c in 0..rows - 2 {
                let mut t = 0i64;
                for k1 in 0..3 {
                    for k2 in 0..3 {
                        t += filt[k1 * 3 + k2] * orig[(r + k1) * rows + c + k2];
                    }
                }
                want += t as f64;
            }
        }
        assert_eq!(generate(rows).checksum, want);
    }

    #[test]
    fn nine_point_reads_per_output() {
        let wl = generate(8);
        let outputs = (8 - 2) * (8 - 2);
        // 9 orig + 9 filt loads + 1 store per output
        assert_eq!(wl.trace.mem_ops(), outputs * 19);
    }
}
