//! Sort-Radix (MachSuite `sort/radix`): LSD radix sort, 2 bits per pass,
//! with histogram buckets — scattered bucket updates plus sequential
//! scans.

use super::Workload;
use crate::trace::{AluKind, TraceBuilder};
use crate::util::rng::Rng;

const SITE_A_RD: u32 = 0;
const SITE_BKT: u32 = 1;
const SITE_SUM: u32 = 2;
const SITE_B_WR: u32 = 3;

const RADIX_BITS: u32 = 2;
const BUCKETS: usize = 1 << RADIX_BITS;

/// Generate a radix-sort trace over `n` u32 keys.
/// Checksum = Σ a[i]·(i+1) of the sorted array.
pub fn generate(n: usize) -> Workload {
    let mut rng = Rng::new(0x5AD1 ^ n as u64);
    let mut a: Vec<u32> = (0..n).map(|_| rng.next_u32() % 65_536).collect();
    let mut tmp = vec![0u32; n];

    let mut b = TraceBuilder::new();
    let a_arr = b.array("a", 4, n as u32);
    let a_tmp = b.array("b", 4, n as u32);
    let a_bkt = b.array("bucket", 4, BUCKETS as u32);

    let passes = 16 / RADIX_BITS; // keys < 2^16
    for pass in 0..passes {
        // Ping-pong buffers: even passes read `a`/write `b`, odd passes
        // the reverse — cross-pass RAW dependences are what serialize the
        // passes in the DDG.
        let (src_arr, dst_arr) = if pass % 2 == 0 { (a_arr, a_tmp) } else { (a_tmp, a_arr) };
        let shift = pass * RADIX_BITS;
        // histogram
        let mut hist = [0u32; BUCKETS];
        let mut bkt_nodes = [None; BUCKETS];
        for i in 0..n {
            b.site(SITE_A_RD);
            let l = b.load(src_arr, i as u32);
            let d = b.alu(AluKind::Shift, &[l]);
            let bi = ((a[i] >> shift) & (BUCKETS as u32 - 1)) as usize;
            b.site(SITE_BKT);
            let mut deps = vec![d];
            if let Some(p) = bkt_nodes[bi] {
                deps.push(p);
            }
            let lb = b.load_dep(a_bkt, bi as u32, &deps);
            let inc = b.alu(AluKind::IntAdd, &[lb]);
            let s = b.store(a_bkt, bi as u32, &[inc]);
            bkt_nodes[bi] = Some(s);
            hist[bi] += 1;
            b.next_iter();
        }
        // exclusive prefix sum over buckets
        let mut offs = [0u32; BUCKETS];
        let mut run = 0u32;
        let mut prev = None;
        for bi in 0..BUCKETS {
            offs[bi] = run;
            run += hist[bi];
            b.site(SITE_SUM);
            let mut deps = Vec::new();
            if let Some(bn) = bkt_nodes[bi] {
                deps.push(bn);
            }
            if let Some(p) = prev {
                deps.push(p);
            }
            let l = b.load_dep(a_bkt, bi as u32, &deps);
            let add = b.alu(AluKind::IntAdd, &[l]);
            let s = b.store(a_bkt, bi as u32, &[add]);
            prev = Some(s);
            b.next_iter();
        }
        // scatter
        let mut cursor = offs;
        for i in 0..n {
            b.site(SITE_A_RD);
            let l = b.load(src_arr, i as u32);
            let d = b.alu(AluKind::Shift, &[l]);
            let bi = ((a[i] >> shift) & (BUCKETS as u32 - 1)) as usize;
            b.site(SITE_BKT);
            let lb = b.load_dep(a_bkt, bi as u32, &[d]);
            let pos = cursor[bi];
            cursor[bi] += 1;
            b.site(SITE_B_WR);
            b.store(dst_arr, pos, &[l, lb]);
            tmp[pos as usize] = a[i];
            b.next_iter();
        }
        std::mem::swap(&mut a, &mut tmp);
    }

    let checksum = a.iter().enumerate().map(|(i, &x)| x as f64 * (i + 1) as f64).sum();
    Workload { name: "sort-radix", trace: b.finish(), checksum }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sorts_correctly() {
        let n = 64;
        let mut rng = Rng::new(0x5AD1 ^ n as u64);
        let mut want: Vec<u32> = (0..n).map(|_| rng.next_u32() % 65_536).collect();
        want.sort_unstable();
        let want_sum: f64 =
            want.iter().enumerate().map(|(i, &x)| x as f64 * (i + 1) as f64).sum();
        assert_eq!(generate(n).checksum, want_sum);
    }

    #[test]
    fn pass_count_fixed() {
        // 8 passes × per-pass (2n + BUCKETS) stores-ish; just check scaling
        let a = generate(64).trace.len();
        let b = generate(128).trace.len();
        assert!((b as f64 / a as f64) > 1.8 && (b as f64 / a as f64) < 2.2);
    }
}
