//! MD-KNN (MachSuite `md/knn`): Lennard-Jones force between each atom
//! and its k nearest neighbours via an indirection list. The neighbour
//! gather makes the position-array strides effectively random ⇒ very low
//! spatial locality.

use super::Workload;
use crate::trace::{AluKind, TraceBuilder};
use crate::util::rng::Rng;

const SITE_NL: u32 = 0;
const SITE_PX: u32 = 1;
const SITE_PY: u32 = 2;
const SITE_PZ: u32 = 3;
const SITE_NX: u32 = 4;
const SITE_NY: u32 = 5;
const SITE_NZ: u32 = 6;
const SITE_FX: u32 = 7;
const SITE_FY: u32 = 8;
const SITE_FZ: u32 = 9;

/// Neighbours per atom (MachSuite uses 16).
pub const MAX_NEIGHBOURS: usize = 16;

/// Generate an `n_atoms` MD-KNN trace. Checksum = Σ |force|.
pub fn generate(n_atoms: usize) -> Workload {
    assert!(n_atoms > MAX_NEIGHBOURS);
    let mut rng = Rng::new(0x6D64 ^ n_atoms as u64);
    let px: Vec<f64> = (0..n_atoms).map(|_| rng.f64() * 10.0).collect();
    let py: Vec<f64> = (0..n_atoms).map(|_| rng.f64() * 10.0).collect();
    let pz: Vec<f64> = (0..n_atoms).map(|_| rng.f64() * 10.0).collect();
    // Neighbour list: k distinct atoms ≠ i (uniform — MachSuite's input
    // is a precomputed list with the same random-gather behaviour).
    let mut nl = vec![0u32; n_atoms * MAX_NEIGHBOURS];
    for i in 0..n_atoms {
        let mut seen = std::collections::HashSet::new();
        let mut j = 0;
        while j < MAX_NEIGHBOURS {
            let cand = rng.below_usize(n_atoms);
            if cand != i && seen.insert(cand) {
                nl[i * MAX_NEIGHBOURS + j] = cand as u32;
                j += 1;
            }
        }
    }

    let mut b = TraceBuilder::new();
    let a_px = b.array("position_x", 8, n_atoms as u32);
    let a_py = b.array("position_y", 8, n_atoms as u32);
    let a_pz = b.array("position_z", 8, n_atoms as u32);
    let a_fx = b.array("force_x", 8, n_atoms as u32);
    let a_fy = b.array("force_y", 8, n_atoms as u32);
    let a_fz = b.array("force_z", 8, n_atoms as u32);
    let a_nl = b.array("NL", 4, (n_atoms * MAX_NEIGHBOURS) as u32);

    const LJ1: f64 = 1.5;
    const LJ2: f64 = 2.0;

    let mut checksum = 0.0f64;
    for i in 0..n_atoms {
        b.site(SITE_PX);
        let l_ix = b.load(a_px, i as u32);
        b.site(SITE_PY);
        let l_iy = b.load(a_py, i as u32);
        b.site(SITE_PZ);
        let l_iz = b.load(a_pz, i as u32);

        let (mut fx, mut fy, mut fz) = (0.0f64, 0.0f64, 0.0f64);
        let (mut nfx, mut nfy, mut nfz) = (None, None, None);
        for j in 0..MAX_NEIGHBOURS {
            b.site(SITE_NL);
            let l_nl = b.load(a_nl, (i * MAX_NEIGHBOURS + j) as u32);
            let jidx = nl[i * MAX_NEIGHBOURS + j] as usize;
            b.site(SITE_NX);
            let l_jx = b.load_dep(a_px, jidx as u32, &[l_nl]);
            b.site(SITE_NY);
            let l_jy = b.load_dep(a_py, jidx as u32, &[l_nl]);
            b.site(SITE_NZ);
            let l_jz = b.load_dep(a_pz, jidx as u32, &[l_nl]);

            // delx/dely/delz
            let dx = b.alu(AluKind::FAdd, &[l_ix, l_jx]);
            let dy = b.alu(AluKind::FAdd, &[l_iy, l_jy]);
            let dz = b.alu(AluKind::FAdd, &[l_iz, l_jz]);
            // r2 = dx² + dy² + dz²
            let dx2 = b.alu(AluKind::FMul, &[dx, dx]);
            let dy2 = b.alu(AluKind::FMul, &[dy, dy]);
            let dz2 = b.alu(AluKind::FMul, &[dz, dz]);
            let s1 = b.alu(AluKind::FAdd, &[dx2, dy2]);
            let r2 = b.alu(AluKind::FAdd, &[s1, dz2]);
            // r2inv = 1/r2 ; r6inv = r2inv³ ; pot = r6inv·(LJ1·r6inv − LJ2)
            let r2inv = b.alu(AluKind::FDiv, &[r2]);
            let r4 = b.alu(AluKind::FMul, &[r2inv, r2inv]);
            let r6inv = b.alu(AluKind::FMul, &[r4, r2inv]);
            let t1 = b.alu(AluKind::FMul, &[r6inv]);
            let t2 = b.alu(AluKind::FAdd, &[t1]);
            let pot = b.alu(AluKind::FMul, &[r6inv, t2]);
            let force = b.alu(AluKind::FMul, &[r2inv, pot]);
            // accumulate
            let fxm = b.alu(AluKind::FMul, &[force, dx]);
            let fym = b.alu(AluKind::FMul, &[force, dy]);
            let fzm = b.alu(AluKind::FMul, &[force, dz]);
            nfx = Some(match nfx {
                None => fxm,
                Some(p) => b.alu(AluKind::FAdd, &[p, fxm]),
            });
            nfy = Some(match nfy {
                None => fym,
                Some(p) => b.alu(AluKind::FAdd, &[p, fym]),
            });
            nfz = Some(match nfz {
                None => fzm,
                Some(p) => b.alu(AluKind::FAdd, &[p, fzm]),
            });

            // data side
            let (dxv, dyv, dzv) = (px[i] - px[jidx], py[i] - py[jidx], pz[i] - pz[jidx]);
            let r2v = dxv * dxv + dyv * dyv + dzv * dzv;
            let r2i = 1.0 / r2v;
            let r6i = r2i * r2i * r2i;
            let potv = r6i * (LJ1 * r6i - LJ2);
            let fv = r2i * potv;
            fx += fv * dxv;
            fy += fv * dyv;
            fz += fv * dzv;
            b.next_iter();
        }
        b.site(SITE_FX);
        b.store(a_fx, i as u32, &[nfx.unwrap()]);
        b.site(SITE_FY);
        b.store(a_fy, i as u32, &[nfy.unwrap()]);
        b.site(SITE_FZ);
        b.store(a_fz, i as u32, &[nfz.unwrap()]);
        checksum += fx.abs() + fy.abs() + fz.abs();
    }

    Workload { name: "md-knn", trace: b.finish(), checksum }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_size_scales_with_atoms() {
        let a = generate(20).trace.len();
        let b = generate(40).trace.len();
        assert!((b as f64 / a as f64 - 2.0).abs() < 0.1);
    }

    #[test]
    fn neighbour_gather_is_indirect() {
        let wl = generate(20);
        // Position loads through the NL are scattered: consecutive SITE_NX
        // indices should NOT be stride-1 for the most part.
        let px_id = wl.trace.arrays.iter().position(|a| a.name == "position_x").unwrap() as u16;
        let idxs: Vec<u32> = wl
            .trace
            .nodes
            .iter()
            .filter_map(|n| match n.kind.mem_ref() {
                Some((a, i)) if a == px_id && n.site == SITE_NX => Some(i),
                _ => None,
            })
            .collect();
        let stride1 = idxs.windows(2).filter(|w| w[1] == w[0].wrapping_add(1)).count();
        assert!((stride1 as f64) < 0.2 * idxs.len() as f64, "too sequential: {stride1}/{}", idxs.len());
    }

    #[test]
    fn forces_are_finite_and_nonzero() {
        let wl = generate(17);
        assert!(wl.checksum.is_finite());
        assert!(wl.checksum > 0.0);
    }
}
