//! NW (MachSuite `nw/nw`): Needleman–Wunsch global sequence alignment —
//! a 2-D dynamic program over the score matrix. Row-major fill reads the
//! west/north/north-west cells: stride-1 plus row-stride accesses.

use super::Workload;
use crate::trace::{AluKind, TraceBuilder};
use crate::util::rng::Rng;

const SITE_SEQA: u32 = 0;
const SITE_SEQB: u32 = 1;
const SITE_M_NW: u32 = 2;
const SITE_M_N: u32 = 3;
const SITE_M_W: u32 = 4;
const SITE_M_WR: u32 = 5;

const MATCH: i32 = 1;
const MISMATCH: i32 = -1;
const GAP: i32 = -1;

/// Generate an `n × n` alignment trace. Checksum = final score.
pub fn generate(n: usize) -> Workload {
    let mut rng = Rng::new(0x0A11 ^ n as u64);
    let alpha = b"ACGT";
    let seq_a: Vec<u8> = (0..n).map(|_| *rng.pick(alpha)).collect();
    let seq_b: Vec<u8> = (0..n).map(|_| *rng.pick(alpha)).collect();

    let w = n + 1;
    let mut m = vec![0i32; w * w];
    for i in 0..w {
        m[i * w] = GAP * i as i32;
        m[i] = GAP * i as i32;
    }

    let mut b = TraceBuilder::new();
    let a_seqa = b.array("seqA", 1, n as u32);
    let a_seqb = b.array("seqB", 1, n as u32);
    let a_m = b.array("M", 4, (w * w) as u32);

    // Trace boundary initialization stores.
    let mut m_store: Vec<Option<crate::trace::NodeId>> = vec![None; w * w];
    b.site(SITE_M_WR);
    for i in 0..w {
        let s1 = b.store(a_m, (i * w) as u32, &[]);
        m_store[i * w] = Some(s1);
        if i > 0 {
            let s2 = b.store(a_m, i as u32, &[]);
            m_store[i] = Some(s2);
        }
    }

    for i in 1..w {
        for j in 1..w {
            b.site(SITE_SEQA);
            let la = b.load(a_seqa, (i - 1) as u32);
            b.site(SITE_SEQB);
            let lb = b.load(a_seqb, (j - 1) as u32);
            let cmp = b.alu(AluKind::Cmp, &[la, lb]);
            b.site(SITE_M_NW);
            let lnw = b.load_dep(a_m, ((i - 1) * w + j - 1) as u32, &[m_store[(i - 1) * w + j - 1].unwrap()]);
            b.site(SITE_M_N);
            let ln = b.load_dep(a_m, ((i - 1) * w + j) as u32, &[m_store[(i - 1) * w + j].unwrap()]);
            b.site(SITE_M_W);
            let lw = b.load_dep(a_m, (i * w + j - 1) as u32, &[m_store[i * w + j - 1].unwrap()]);
            let diag = b.alu(AluKind::IntAdd, &[lnw, cmp]);
            let up = b.alu(AluKind::IntAdd, &[ln]);
            let left = b.alu(AluKind::IntAdd, &[lw]);
            let mx1 = b.alu(AluKind::Cmp, &[diag, up]);
            let mx2 = b.alu(AluKind::Cmp, &[mx1, left]);
            b.site(SITE_M_WR);
            let st = b.store(a_m, (i * w + j) as u32, &[mx2]);
            m_store[i * w + j] = Some(st);

            let sub = if seq_a[i - 1] == seq_b[j - 1] { MATCH } else { MISMATCH };
            let score =
                (m[(i - 1) * w + j - 1] + sub).max(m[(i - 1) * w + j] + GAP).max(m[i * w + j - 1] + GAP);
            m[i * w + j] = score;
            b.next_iter();
        }
    }

    let checksum = m[w * w - 1] as f64;
    Workload { name: "nw", trace: b.finish(), checksum }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_sequences_score_n() {
        // Direct DP check on small fixed input.
        let n = 16;
        let wl = generate(n);
        // score ∈ [-n, n]
        assert!(wl.checksum.abs() <= n as f64);
    }

    #[test]
    fn wavefront_dependences_exist() {
        // m[i][j] depends on m[i-1][j-1], m[i-1][j], m[i][j-1]: the cell
        // store must transitively follow the three neighbour stores.
        let wl = generate(4);
        wl.trace.validate().unwrap();
        // critical path must be at least 2n (the DP wavefront).
        assert!(wl.trace.critical_path_len() >= 8);
    }

    #[test]
    fn quadratic_scaling() {
        let a = generate(8).trace.len();
        let b = generate(16).trace.len();
        let ratio = b as f64 / a as f64;
        assert!(ratio > 3.0 && ratio < 5.0, "ratio {ratio}");
    }
}
