//! # amm-dse — Design Space Exploration of Algorithmic Multi-Port Memories
//!
//! Reproduction of *"Design Space Exploration of Algorithmic Multi-port
//! Memory for High-Performance Application-Specific Accelerators"*
//! (K. Sethi, cs.AR 2020).
//!
//! The library is a complete pre-RTL accelerator-memory exploration
//! framework (a "Mem-Aladdin") organized around two seams:
//!
//! * **Memory models as a trait** ([`mem::MemModel`] + [`mem::registry`]):
//!   every organization — banked scratchpads, multipumping, LVT and XOR
//!   AMMs, circuit-level multiport — is a registered trait object that
//!   knows its id, port semantics and cost composition. Adding a new
//!   AMM scheme is a one-module change in `mem/`.
//! * **The [`Explorer`] facade**: one builder that traces a benchmark,
//!   runs the sweep through the batched cost service, and returns an
//!   [`Exploration`] with design points, Pareto frontiers, performance
//!   ratios and CSV emitters.
//!
//! ## Quickstart
//!
//! ```no_run
//! use amm_dse::{Explorer, dse::Sweep, suite::Scale};
//!
//! let ex = Explorer::new()
//!     .workload("gemm", Scale::Paper)
//!     .sweep(Sweep::default())
//!     .threads(8)
//!     .run()
//!     .expect("exploration failed");
//! println!("{} design points, L_spatial {:.3}", ex.points().len(), ex.locality);
//! for p in ex.pareto_area() {
//!     println!("  {:<24} {:>10} cycles {:>12.0} um^2", p.id, p.out.cycles, p.area());
//! }
//! if let Some(r) = ex.performance_ratio() {
//!     println!("banking/AMM area ratio (gmean): {r:.3}");
//! }
//! ex.write_csv("results/gemm.csv").expect("write csv");
//! ```
//!
//! ## Suite-scale campaigns
//!
//! The whole suite × sweep cross-product runs as **one** work stream —
//! one shared worker pool, one globally-deduplicated cost batch, and an
//! append-only JSONL sink that makes the run observable mid-flight and
//! resumable after a kill:
//!
//! ```no_run
//! use amm_dse::{Campaign, dse::Sweep, suite::Scale};
//!
//! let outcome = Campaign::new()
//!     .benchmarks(amm_dse::suite::DSE_BENCHMARKS)
//!     .scale(Scale::Paper)
//!     .sweep(Sweep::default())
//!     .sink("results/campaign.jsonl") // streaming + resumable
//!     .run()
//!     .expect("campaign failed");
//! println!("{} points ({} resumed)", outcome.total_points(), outcome.resumed);
//! println!("{}", outcome.fig5_ascii());
//! ```
//!
//! Every run is also describable **as data**: builders lower to a
//! serializable [`CampaignSpec`] (TOML round-trip), which shards
//! deterministically across processes/hosts and merges back:
//!
//! ```no_run
//! use amm_dse::{campaign, CampaignSpec};
//!
//! let spec = CampaignSpec::load("configs/suite.toml".as_ref()).expect("parse spec");
//! // host i of n runs: spec.clone().with_shard(i, n).run()
//! let shard0 = spec.clone().with_shard(0, 2);
//! let shard1 = spec.clone().with_shard(1, 2);
//! // ... later, reconcile the shard sinks against the plan:
//! let merged = campaign::merge::merge(&spec, &["s0.jsonl", "s1.jsonl"]).expect("merge");
//! println!("{}", merged.outcome.fig5_ascii());
//! # let _ = (shard0, shard1);
//! ```
//!
//! Single design points are still available through the value-level
//! compat API:
//!
//! ```no_run
//! use amm_dse::{suite, sched, mem};
//!
//! // Trace a GEMM, schedule it on a 2R1W XOR-based AMM.
//! let wl = suite::generate("gemm", suite::Scale::Tiny);
//! let cfg = sched::DesignConfig {
//!     mem: mem::MemKind::XorAmm { read_ports: 2, write_ports: 1 },
//!     unroll: 4,
//!     word_bytes: 8,
//!     alus: 4,
//! };
//! let out = sched::simulate(&wl.trace, &cfg);
//! println!("cycles={} area={:.1}um^2 power={:.2}mW",
//!          out.cycles, out.area_um2, out.power_mw);
//! ```
//!
//! ## Module map
//!
//! * [`suite`] — faithful ports of 13 MachSuite benchmarks that produce
//!   dynamic instruction traces with true data dependencies, plus the
//!   [`suite::synthetic`] locality-dial generator behind parametric
//!   `synth:stride=…,rw=…` benchmark names.
//! * [`trace`] — the dynamic trace / data-dependence-graph substrate.
//! * [`sram`] — CACTI-lite analytical SRAM macro model (45 nm).
//! * [`synth`] — DC-lite gate-level model of AMM read/write-path logic.
//! * [`mem`] — the memory-model trait, registry, and the eight built-in
//!   organizations; functional (bit-accurate) AMM simulators.
//! * [`sched`] — Aladdin-style resource-constrained cycle-accurate
//!   scheduler over the DDG.
//! * [`locality`] — Weinberg spatial-locality metric.
//! * [`dse`] — sweep enumeration, Pareto frontiers, and the paper's
//!   geometric-mean performance ratio.
//! * [`explore`] — the [`Explorer`]/[`Exploration`] facade (a thin
//!   single-benchmark campaign).
//! * [`spec`] — the declarative [`CampaignSpec`]: one serializable,
//!   validated plan (TOML round-trip) that every front-end lowers to
//!   and the campaign engine consumes, with deterministic sharding.
//! * [`campaign`] — the suite-scale campaign engine: the whole
//!   {benchmarks} × {sweep points} cross-product as one flat work
//!   stream with one shared worker pool, one globally-deduplicated
//!   cost batch, a streaming + resumable JSONL result sink, and
//!   shard-sink merging ([`campaign::merge`]).
//! * [`runtime`] — PJRT client wrapper for the AOT-compiled JAX/Pallas
//!   cost-model artifacts (stubbed without the `pjrt` feature).
//! * [`cost`] — the tiered macro-cost provider subsystem: the
//!   [`cost::CostProvider`] trait, an in-process memo, the persistent
//!   `cost-store/v1` JSONL store (fingerprint-keyed so stub- and
//!   pjrt-scored rows never mix), and the runtime batch backend as the
//!   miss path.
//! * [`sim`] — the tiered simulation-result subsystem: canonical
//!   [`sim::Key`]s (trace content hash + knobs + design + engine
//!   version), the persistent `sim-store/v1` JSONL store, and the
//!   [`sim::SimStack`] memo/store tiers the campaign probes before
//!   lane packing, so warm campaigns skip simulation itself.
//! * [`coordinator`] — the parallel DSE orchestrator: a thin front over
//!   the cost stack that batches design-point cost queries.
//! * [`report`] — CSV and ASCII-plot emitters for every paper figure.
//! * [`config`] — TOML-subset run configuration files.
//! * [`serve`] — DSE-as-a-service: the zero-dependency HTTP daemon
//!   behind `repro serve` (job queue, worker fleet, result/cost APIs).
//! * [`error`] — the unified [`Error`]/[`Result`] pair.
//! * [`util`] — in-tree replacements for crates unavailable offline
//!   (PRNG, stats, thread pool, mini-TOML, property testing, benchkit).

pub mod error;
pub mod util;

pub mod trace;
pub mod suite;

pub mod sram;
pub mod synth;
pub mod mem;

pub mod sched;
pub mod locality;
pub mod dse;

pub mod explore;
pub mod runtime;
pub mod cost;
pub mod sim;
pub mod coordinator;
pub mod spec;
pub mod campaign;
pub mod report;
pub mod config;
pub mod serve;

pub use campaign::{Campaign, CampaignOutcome};
pub use error::{Error, Result};
pub use explore::{Exploration, Explorer};
pub use spec::CampaignSpec;

/// Library version (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Technology node every cost model in this crate is calibrated to.
pub const TECH_NM: f32 = 45.0;
