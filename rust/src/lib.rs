//! # amm-dse — Design Space Exploration of Algorithmic Multi-Port Memories
//!
//! Reproduction of *"Design Space Exploration of Algorithmic Multi-port
//! Memory for High-Performance Application-Specific Accelerators"*
//! (K. Sethi, cs.AR 2020).
//!
//! The library is a complete pre-RTL accelerator-memory exploration
//! framework (a "Mem-Aladdin"):
//!
//! * [`suite`] — faithful ports of 13 MachSuite benchmarks that produce
//!   dynamic instruction traces with true data dependencies.
//! * [`trace`] — the dynamic trace / data-dependence-graph substrate.
//! * [`sram`] — CACTI-lite analytical SRAM macro model (45 nm).
//! * [`synth`] — DC-lite gate-level model of AMM read/write-path logic.
//! * [`mem`] — memory-system models: banked scratchpads, multipumping,
//!   LVT and XOR-based algorithmic multi-port memories (H-NTX-Rd,
//!   B-NTX-Wr, HB-NTX-RdWr), and a circuit-level true-multiport reference.
//! * [`sched`] — Aladdin-style resource-constrained cycle-accurate
//!   scheduler over the DDG.
//! * [`locality`] — Weinberg spatial-locality metric.
//! * [`dse`] — design-space sweeps, Pareto frontiers, and the paper's
//!   geometric-mean performance ratio.
//! * [`runtime`] — PJRT client wrapper that loads the AOT-compiled JAX/
//!   Pallas cost-model and workload artifacts (`artifacts/*.hlo.txt`).
//! * [`coordinator`] — the parallel DSE orchestrator which batches
//!   design-point cost queries through the PJRT cost model.
//! * [`report`] — CSV and ASCII-plot emitters for every paper figure.
//! * [`util`] — in-tree replacements for crates unavailable offline
//!   (PRNG, stats, thread pool, mini-TOML, property testing, benchkit).
//!
//! ## Quickstart
//!
//! ```no_run
//! use amm_dse::{suite, sched, mem, dse};
//!
//! // Trace a 16x16x16 GEMM, schedule it on a 2R1W XOR-based AMM.
//! let wl = suite::gemm::generate(16);
//! let cfg = sched::DesignConfig {
//!     mem: mem::MemKind::XorAmm { read_ports: 2, write_ports: 1 },
//!     unroll: 4,
//!     word_bytes: 8,
//!     alus: 4,
//! };
//! let out = sched::simulate(&wl.trace, &cfg);
//! println!("cycles={} area={:.1}um^2 power={:.2}mW",
//!          out.cycles, out.area_um2, out.power_mw);
//! ```

pub mod util;

pub mod trace;
pub mod suite;

pub mod sram;
pub mod synth;
pub mod mem;

pub mod sched;
pub mod locality;
pub mod dse;

pub mod runtime;
pub mod coordinator;
pub mod report;
pub mod config;

/// Library version (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Technology node every cost model in this crate is calibrated to.
pub const TECH_NM: f32 = 45.0;
