//! Hand-rolled HTTP/1.1 request/response layer for [`crate::serve`].
//!
//! In idiom with the crate's other in-tree formats (`util::tomlmini`,
//! `util::jsonl`): a deliberately small subset, not a general HTTP
//! implementation. What it supports is exactly what the daemon needs —
//! `GET`/`POST`/`DELETE`/`HEAD`, `Content-Length` bodies, keep-alive
//! with pipelining, percent-encoded query strings — and everything else
//! is rejected with the right status code instead of misparsed.
//!
//! The parser is *feed-based*: callers push raw socket bytes into a
//! [`RequestBuf`] and drain complete requests out, so torn reads (a
//! request split across arbitrary TCP segment boundaries) and pipelined
//! requests (two requests in one segment) are handled by construction
//! and unit-testable without sockets.

use std::io::{self, Write};

/// Maximum accepted request-head size (request line + headers).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Maximum accepted request-body size (campaign spec TOMLs are ~1 KiB).
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// One parsed HTTP request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Request {
    /// Upper-case method token (`GET`, `POST`, …).
    pub method: String,
    /// Decoded path component, query stripped (`/campaigns/c0001/status`).
    pub path: String,
    /// Decoded `key=value` query parameters, in order of appearance.
    pub query: Vec<(String, String)>,
    /// Headers with lower-cased names and trimmed values, in order.
    pub headers: Vec<(String, String)>,
    /// Raw body bytes (exactly `Content-Length` of them).
    pub body: Vec<u8>,
    /// True when the request was `HTTP/1.1` (keep-alive by default).
    http11: bool,
}

impl Request {
    /// First header value with this (case-insensitive) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }

    /// First query parameter with this name.
    pub fn query_param(&self, name: &str) -> Option<&str> {
        self.query.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }

    /// Whether the connection should stay open after this request:
    /// HTTP/1.1 defaults to keep-alive unless `Connection: close`;
    /// HTTP/1.0 defaults to close unless `Connection: keep-alive`.
    pub fn keep_alive(&self) -> bool {
        match self.header("connection").map(str::to_ascii_lowercase) {
            Some(v) if v.contains("close") => false,
            Some(v) if v.contains("keep-alive") => true,
            _ => self.http11,
        }
    }
}

/// Why a request could not be parsed. Maps to the HTTP status the
/// connection handler sends before closing.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ParseError {
    /// Malformed request line, header, or unsupported framing → 400.
    BadRequest(String),
    /// Method token is not one the daemon implements → 501.
    BadMethod(String),
    /// Declared `Content-Length` exceeds [`MAX_BODY_BYTES`] → 413.
    BodyTooLarge(usize),
    /// Head grew past [`MAX_HEAD_BYTES`] without terminating → 431.
    HeadTooLarge,
}

impl ParseError {
    /// HTTP status code for the error response.
    pub fn status(&self) -> u16 {
        match self {
            ParseError::BadRequest(_) => 400,
            ParseError::BadMethod(_) => 501,
            ParseError::BodyTooLarge(_) => 413,
            ParseError::HeadTooLarge => 431,
        }
    }

    /// Human-readable detail for the error body.
    pub fn detail(&self) -> String {
        match self {
            ParseError::BadRequest(m) => format!("bad request: {m}"),
            ParseError::BadMethod(m) => format!("method not implemented: {m}"),
            ParseError::BodyTooLarge(n) => {
                format!("body of {n} bytes exceeds limit of {MAX_BODY_BYTES}")
            }
            ParseError::HeadTooLarge => {
                format!("request head exceeds limit of {MAX_HEAD_BYTES} bytes")
            }
        }
    }
}

/// Incremental request parser: push raw bytes in, drain requests out.
#[derive(Debug, Default)]
pub struct RequestBuf {
    buf: Vec<u8>,
}

impl RequestBuf {
    /// Fresh empty buffer.
    pub fn new() -> Self {
        RequestBuf::default()
    }

    /// Append raw bytes read from the socket.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes currently buffered (parsed requests are drained out).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when no bytes are buffered.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Try to parse one complete request off the front of the buffer.
    /// `Ok(None)` means "need more bytes"; an `Err` poisons the
    /// connection (the caller responds with [`ParseError::status`] and
    /// closes). Call repeatedly to drain pipelined requests.
    pub fn next_request(&mut self) -> Result<Option<Request>, ParseError> {
        let head_end = match find_head_end(&self.buf) {
            Some(e) => e,
            None if self.buf.len() > MAX_HEAD_BYTES => return Err(ParseError::HeadTooLarge),
            None => return Ok(None),
        };
        if head_end > MAX_HEAD_BYTES {
            return Err(ParseError::HeadTooLarge);
        }
        let head = std::str::from_utf8(&self.buf[..head_end])
            .map_err(|_| ParseError::BadRequest("head is not UTF-8".into()))?;
        let mut lines = head.lines().map(|l| l.strip_suffix('\r').unwrap_or(l));
        let request_line =
            lines.next().ok_or_else(|| ParseError::BadRequest("empty head".into()))?;
        let (method, target, http11) = parse_request_line(request_line)?;
        let mut headers = Vec::new();
        for line in lines {
            if line.is_empty() {
                break;
            }
            let (name, value) = line
                .split_once(':')
                .ok_or_else(|| ParseError::BadRequest(format!("header without colon: {line:?}")))?;
            headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
        }
        let chunked = headers
            .iter()
            .any(|(n, v)| n == "transfer-encoding" && !v.eq_ignore_ascii_case("identity"));
        if chunked {
            return Err(ParseError::BadRequest("transfer-encoding not supported".into()));
        }
        let body_len = match headers.iter().find(|(n, _)| n == "content-length") {
            Some((_, v)) => v
                .parse::<usize>()
                .map_err(|_| ParseError::BadRequest(format!("bad content-length: {v:?}")))?,
            None => 0,
        };
        if body_len > MAX_BODY_BYTES {
            return Err(ParseError::BodyTooLarge(body_len));
        }
        if self.buf.len() < head_end + body_len {
            return Ok(None); // body not fully arrived yet
        }
        let body = self.buf[head_end..head_end + body_len].to_vec();
        self.buf.drain(..head_end + body_len);
        let (path, query) = parse_target(target)?;
        Ok(Some(Request { method, path, query, headers, body, http11 }))
    }
}

/// Index one past the blank line terminating the header block, if the
/// buffer holds one. Accepts both `\r\n` and bare-`\n` line endings.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    let mut line_start = 0;
    for (i, &b) in buf.iter().enumerate() {
        if b != b'\n' {
            continue;
        }
        let mut line = &buf[line_start..i];
        if let [rest @ .., b'\r'] = line {
            line = rest;
        }
        if line.is_empty() {
            return Some(i + 1);
        }
        line_start = i + 1;
    }
    None
}

/// Split and validate `METHOD /target HTTP/1.x`.
fn parse_request_line(line: &str) -> Result<(String, String, bool), ParseError> {
    let mut parts = line.split_whitespace();
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v), None) => (m, t, v),
        _ => return Err(ParseError::BadRequest(format!("malformed request line: {line:?}"))),
    };
    if method.is_empty() || !method.chars().all(|c| c.is_ascii_uppercase()) {
        return Err(ParseError::BadRequest(format!("malformed method: {method:?}")));
    }
    if !matches!(method, "GET" | "POST" | "DELETE" | "HEAD") {
        return Err(ParseError::BadMethod(method.to_string()));
    }
    if !target.starts_with('/') {
        return Err(ParseError::BadRequest(format!("target must be absolute: {target:?}")));
    }
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        other => return Err(ParseError::BadRequest(format!("unsupported version: {other:?}"))),
    };
    Ok((method.to_string(), target.to_string(), http11))
}

/// Split a request target into decoded path + query parameters.
fn parse_target(target: String) -> Result<(String, Vec<(String, String)>), ParseError> {
    let (raw_path, raw_query) = match target.split_once('?') {
        Some((p, q)) => (p, Some(q)),
        None => (target.as_str(), None),
    };
    let path = percent_decode(raw_path)?;
    let mut query = Vec::new();
    if let Some(q) = raw_query {
        for pair in q.split('&').filter(|p| !p.is_empty()) {
            let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
            query.push((percent_decode(k)?, percent_decode(v)?));
        }
    }
    Ok((path, query))
}

/// Decode `%XX` escapes and `+`-as-space (query-string convention).
fn percent_decode(s: &str) -> Result<String, ParseError> {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' => {
                let hex = bytes
                    .get(i + 1..i + 3)
                    .and_then(|h| std::str::from_utf8(h).ok())
                    .and_then(|h| u8::from_str_radix(h, 16).ok())
                    .ok_or_else(|| ParseError::BadRequest(format!("bad escape in {s:?}")))?;
                out.push(hex);
                i += 3;
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8(out).map_err(|_| ParseError::BadRequest(format!("non-UTF-8 target: {s:?}")))
}

/// One HTTP response, written with `Content-Length` framing.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Response {
    /// HTTP status code.
    pub status: u16,
    /// `Content-Type` header value.
    pub content_type: &'static str,
    /// Body bytes.
    pub body: Vec<u8>,
    /// Extra response headers (name, value) — already well-formed.
    pub extra: Vec<(String, String)>,
}

impl Response {
    /// Response with an arbitrary content type.
    pub fn new(status: u16, content_type: &'static str, body: impl Into<Vec<u8>>) -> Response {
        Response { status, content_type, body: body.into(), extra: Vec::new() }
    }

    /// `application/json` response (bodies are flat `serve/v1` objects).
    pub fn json(status: u16, body: impl Into<String>) -> Response {
        let mut body = body.into();
        if !body.ends_with('\n') {
            body.push('\n');
        }
        Response::new(status, "application/json", body.into_bytes())
    }

    /// `text/plain` response.
    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response::new(status, "text/plain; charset=utf-8", body.into().into_bytes())
    }

    /// Uniform JSON error body (`serve/v1`, `error` field).
    pub fn error(status: u16, detail: &str) -> Response {
        let msg = crate::util::jsonl::escape(detail);
        Response::json(status, format!("{{\"schema\":\"serve/v1\",\"error\":\"{msg}\"}}"))
    }

    /// Attach an extra header.
    pub fn with_header(mut self, name: &str, value: impl Into<String>) -> Response {
        self.extra.push((name.to_string(), value.into()));
        self
    }

    /// Serialize to the wire. `keep_alive` controls the `Connection`
    /// header; the caller closes the stream when it is false.
    pub fn write_to(&self, w: &mut impl Write, keep_alive: bool) -> io::Result<()> {
        let conn = if keep_alive { "keep-alive" } else { "close" };
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
            self.status,
            status_text(self.status),
            self.content_type,
            self.body.len(),
            conn
        )?;
        for (name, value) in &self.extra {
            write!(w, "{name}: {value}\r\n")?;
        }
        w.write_all(b"\r\n")?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

/// Reason phrase for the status codes the daemon emits.
pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_one(raw: &[u8]) -> Result<Option<Request>, ParseError> {
        let mut rb = RequestBuf::new();
        rb.push(raw);
        rb.next_request()
    }

    #[test]
    fn parses_a_simple_get() {
        let req = parse_one(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap().unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert!(req.query.is_empty());
        assert_eq!(req.header("host"), Some("x"));
        assert!(req.body.is_empty());
        assert!(req.keep_alive(), "HTTP/1.1 defaults to keep-alive");
    }

    #[test]
    fn decodes_query_parameters() {
        let raw: &[u8] = b"GET /query/pareto?benchmark=md%2Dknn&scale=large&x=a+b HTTP/1.1\r\n\r\n";
        let req = parse_one(raw).unwrap().unwrap();
        assert_eq!(req.path, "/query/pareto");
        assert_eq!(req.query_param("benchmark"), Some("md-knn"));
        assert_eq!(req.query_param("scale"), Some("large"));
        assert_eq!(req.query_param("x"), Some("a b"));
        assert_eq!(req.query_param("missing"), None);
    }

    #[test]
    fn torn_reads_reassemble_byte_by_byte() {
        let raw = b"POST /campaigns HTTP/1.1\r\nContent-Length: 5\r\n\r\nhello";
        let mut rb = RequestBuf::new();
        for (i, b) in raw.iter().enumerate() {
            assert!(
                rb.next_request().unwrap().is_none(),
                "no request before byte {i} of {}",
                raw.len()
            );
            rb.push(&[*b]);
        }
        let req = rb.next_request().unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"hello");
        assert!(rb.is_empty(), "request fully drained");
    }

    #[test]
    fn keep_alive_pipelining_drains_two_requests() {
        let mut rb = RequestBuf::new();
        rb.push(b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\nConnection: close\r\n\r\n");
        let a = rb.next_request().unwrap().unwrap();
        let b = rb.next_request().unwrap().unwrap();
        assert_eq!((a.path.as_str(), a.keep_alive()), ("/a", true));
        assert_eq!((b.path.as_str(), b.keep_alive()), ("/b", false));
        assert!(rb.next_request().unwrap().is_none());
    }

    #[test]
    fn http10_defaults_to_close() {
        let req = parse_one(b"GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!req.keep_alive());
        let req =
            parse_one(b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap().unwrap();
        assert!(req.keep_alive(), "explicit keep-alive overrides the 1.0 default");
    }

    #[test]
    fn bad_methods_are_rejected_with_the_right_status() {
        let err = parse_one(b"FROB / HTTP/1.1\r\n\r\n").unwrap_err();
        assert_eq!(err, ParseError::BadMethod("FROB".into()));
        assert_eq!(err.status(), 501);
        let err = parse_one(b"frob / HTTP/1.1\r\n\r\n").unwrap_err();
        assert_eq!(err.status(), 400, "lower-case token is malformed, not a method");
        let err = parse_one(b"GET /\r\n\r\n").unwrap_err();
        assert_eq!(err.status(), 400, "missing version");
    }

    #[test]
    fn oversized_body_is_rejected_before_it_arrives() {
        let n = MAX_BODY_BYTES + 1;
        let raw = format!("POST /campaigns HTTP/1.1\r\nContent-Length: {n}\r\n\r\n");
        let err = parse_one(raw.as_bytes()).unwrap_err();
        assert_eq!(err, ParseError::BodyTooLarge(n));
        assert_eq!(err.status(), 413);
    }

    #[test]
    fn oversized_head_is_rejected() {
        let mut rb = RequestBuf::new();
        rb.push(b"GET / HTTP/1.1\r\n");
        rb.push(&vec![b'x'; MAX_HEAD_BYTES + 1]);
        assert_eq!(rb.next_request().unwrap_err(), ParseError::HeadTooLarge);
        assert_eq!(ParseError::HeadTooLarge.status(), 431);
    }

    #[test]
    fn framing_oddities_are_bad_requests() {
        let chunked: &[u8] = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n";
        assert_eq!(parse_one(chunked).unwrap_err().status(), 400);
        let bad_len: &[u8] = b"POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n";
        assert_eq!(parse_one(bad_len).unwrap_err().status(), 400);
        assert_eq!(parse_one(b"GET relative HTTP/1.1\r\n\r\n").unwrap_err().status(), 400);
        assert_eq!(parse_one(b"GET / HTTP/2\r\n\r\n").unwrap_err().status(), 400);
    }

    #[test]
    fn bare_lf_line_endings_are_accepted() {
        let req = parse_one(b"GET /healthz HTTP/1.1\nHost: y\n\n").unwrap().unwrap();
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.header("host"), Some("y"));
    }

    #[test]
    fn responses_serialize_with_length_framing() {
        let mut out = Vec::new();
        Response::json(202, "{\"schema\":\"serve/v1\"}".to_string())
            .with_header("X-After", "7")
            .write_to(&mut out, true)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 202 Accepted\r\n"), "{text}");
        assert!(text.contains("Content-Length: 22\r\n"), "{text}");
        assert!(text.contains("Connection: keep-alive\r\n"), "{text}");
        assert!(text.contains("X-After: 7\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{\"schema\":\"serve/v1\"}\n"), "{text}");
    }
}
