//! Endpoint dispatch for the serve daemon (`serve/v1`).
//!
//! | Method | Path                        | Body / query            | Returns |
//! |--------|-----------------------------|-------------------------|---------|
//! | GET    | `/healthz`                  |                         | daemon + queue summary |
//! | GET    | `/campaigns`                |                         | job list |
//! | POST   | `/campaigns`                | campaign-spec TOML      | 202 + job id |
//! | GET    | `/campaigns/<id>`           |                         | job detail |
//! | DELETE | `/campaigns/<id>`           |                         | cancel |
//! | GET    | `/campaigns/<id>/status`    | `?history=1` for the ring | live status sidecar |
//! | GET    | `/campaigns/<id>/results`   | `?after=<n>`            | sink tail (JSONL) |
//! | GET    | `/query/pareto`             | `?benchmark=&scale=`    | Pareto front CSV |
//! | GET    | `/cost-store/stat`          |                         | shared-store counters |
//! | POST   | `/shutdown`                 |                         | graceful stop |
//!
//! Every JSON body is a flat `serve/v1` object (one line, no nesting
//! beyond the fingerprint array of `stat`), in idiom with the crate's
//! other flat-JSON formats. Raw sidecar/sink files are served verbatim
//! — their own schemas (`campaign-status/v1`, `campaign/v1`) are the
//! contract, so a poller of the daemon and a poller of the files see
//! identical documents.

use super::http::{Request, Response};
use super::jobs::{JobState, JobView};
use super::ServeState;
use crate::campaign::{merge, sink};
use crate::cost::CostStore;
use crate::report;
use crate::spec::CampaignSpec;
use crate::suite::Scale;
use crate::util::jsonl::escape;

/// Dispatch one parsed request.
pub fn route(state: &ServeState, req: &Request) -> Response {
    let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => healthz(state),
        ("GET", ["campaigns"]) => list(state),
        ("POST", ["campaigns"]) => submit(state, req),
        ("GET", ["campaigns", id]) => with_job(state, id, job_detail),
        ("DELETE", ["campaigns", id]) => cancel(state, id),
        ("GET", ["campaigns", id, "status"]) => with_job(state, id, |v| status(v, req)),
        ("GET", ["campaigns", id, "results"]) => with_job(state, id, |v| results(v, req)),
        ("GET", ["query", "pareto"]) => pareto(state, req),
        ("GET", ["cost-store", "stat"]) => store_stat(state),
        ("POST", ["shutdown"]) => shutdown(state),
        // known path, wrong method → 405; anything else → 404
        (_, ["healthz"] | ["campaigns"] | ["campaigns", _] | ["campaigns", _, "status"])
        | (_, ["campaigns", _, "results"] | ["query", "pareto"] | ["cost-store", "stat"])
        | (_, ["shutdown"]) => {
            Response::error(405, &format!("method {} not allowed for {}", req.method, req.path))
        }
        _ => Response::error(404, &format!("no such endpoint: {}", req.path)),
    }
}

/// Look a job up by id, or 404.
fn with_job(state: &ServeState, id: &str, f: impl FnOnce(&JobView) -> Response) -> Response {
    match state.jobs.get(id) {
        Some(view) => f(&view),
        None => Response::error(404, &format!("no such job: {id}")),
    }
}

fn healthz(state: &ServeState) -> Response {
    let jobs = state.jobs.list();
    let count = |s: JobState| jobs.iter().filter(|j| j.state == s).count();
    Response::json(
        200,
        format!(
            concat!(
                "{{\"schema\":\"serve/v1\",\"ok\":true,\"workers\":{},\"uptime_s\":{},",
                "\"jobs\":{},\"queued\":{},\"running\":{},\"done\":{},\"failed\":{},",
                "\"cancelled\":{},\"data_dir\":\"{}\"}}"
            ),
            state.workers,
            state.started.elapsed().as_secs(),
            jobs.len(),
            count(JobState::Queued),
            count(JobState::Running),
            count(JobState::Done),
            count(JobState::Failed),
            count(JobState::Cancelled),
            escape(&state.data_dir.display().to_string()),
        ),
    )
}

fn list(state: &ServeState) -> Response {
    let rows: Vec<String> = state.jobs.list().iter().map(job_json).collect();
    Response::json(
        200,
        format!("{{\"schema\":\"serve/v1\",\"jobs\":[{}]}}", rows.join(",")),
    )
}

fn submit(state: &ServeState, req: &Request) -> Response {
    if state.jobs.stopping() {
        return Response::error(503, "daemon is shutting down");
    }
    let text = match std::str::from_utf8(&req.body) {
        Ok(t) => t,
        Err(_) => return Response::error(400, "spec body must be UTF-8 TOML"),
    };
    let spec = match CampaignSpec::parse(text) {
        Ok(s) => s,
        Err(e) => return Response::error(400, &format!("bad campaign spec: {e}")),
    };
    match state.jobs.submit(spec) {
        Ok(view) => Response::json(
            202,
            format!(
                concat!(
                    "{{\"schema\":\"serve/v1\",\"id\":\"{}\",\"state\":\"{}\",",
                    "\"status\":\"/campaigns/{}/status\",\"results\":\"/campaigns/{}/results\"}}"
                ),
                view.id,
                view.state.as_str(),
                view.id,
                view.id,
            ),
        ),
        Err(e) => Response::error(500, &format!("submit failed: {e}")),
    }
}

fn job_detail(view: &JobView) -> Response {
    Response::json(200, job_json(view))
}

fn cancel(state: &ServeState, id: &str) -> Response {
    if state.jobs.get(id).is_none() {
        return Response::error(404, &format!("no such job: {id}"));
    }
    match state.jobs.cancel(id) {
        // a running job stops at its next cancellation probe
        Ok(JobState::Running) => Response::json(
            200,
            format!(
                "{{\"schema\":\"serve/v1\",\"id\":\"{}\",\"state\":\"cancelling\"}}",
                escape(id)
            ),
        ),
        Ok(st) => Response::json(
            200,
            format!(
                "{{\"schema\":\"serve/v1\",\"id\":\"{}\",\"state\":\"{}\"}}",
                escape(id),
                st.as_str()
            ),
        ),
        Err(e) => Response::error(409, &e.to_string()),
    }
}

/// `GET /campaigns/<id>/status`: the live `campaign-status/v1` sidecar,
/// verbatim. Before the worker's first flush (or for a never-started
/// job) a minimal `serve/v1` document carries the job state instead.
/// `?history=1` serves the bounded snapshot ring as JSONL.
fn status(view: &JobView, req: &Request) -> Response {
    let history = req.query_param("history").map_or(false, |h| h == "1" || h == "true");
    if history {
        let text = std::fs::read_to_string(sink::history_path(&view.sink)).unwrap_or_default();
        return Response::new(200, "application/x-ndjson", text.into_bytes());
    }
    match std::fs::read_to_string(sink::status_path(&view.sink)) {
        Ok(doc) => Response::new(200, "application/json", doc.into_bytes()),
        Err(_) => Response::json(
            200,
            format!(
                "{{\"schema\":\"serve/v1\",\"id\":\"{}\",\"state\":\"{}\"}}",
                view.id,
                view.state.as_str()
            ),
        ),
    }
}

/// `GET /campaigns/<id>/results?after=<n>`: the sink's complete lines
/// past the first `n`, as JSONL. The `X-After` response header carries
/// the new total — pass it back as the next `after` to tail
/// incrementally. A torn (newline-less) tail is never served.
fn results(view: &JobView, req: &Request) -> Response {
    let after = match req.query_param("after").map(str::parse::<usize>) {
        None => 0,
        Some(Ok(n)) => n,
        Some(Err(_)) => return Response::error(400, "after must be a non-negative integer"),
    };
    let text = std::fs::read_to_string(&view.sink).unwrap_or_default();
    let mut complete: Vec<&str> = text.lines().collect();
    if !text.is_empty() && !text.ends_with('\n') {
        complete.pop(); // torn tail: not a record yet
    }
    let total = complete.len();
    let mut body = String::new();
    for line in complete.iter().skip(after) {
        body.push_str(line);
        body.push('\n');
    }
    Response::new(200, "application/x-ndjson", body.into_bytes())
        .with_header("X-After", total.to_string())
}

/// `GET /query/pareto?benchmark=<b>[&scale=<s>]`: the Pareto front of
/// the newest completed job covering that benchmark (and scale, when
/// given), as the same fig4-format CSV `repro pareto` writes — byte-
/// identical to the offline `Explorer` path over the same sweep.
fn pareto(state: &ServeState, req: &Request) -> Response {
    let Some(bench) = req.query_param("benchmark") else {
        return Response::error(400, "missing query parameter: benchmark");
    };
    let scale = match req.query_param("scale") {
        Some(s) => match Scale::parse(s) {
            Some(sc) => Some(sc),
            None => return Response::error(400, &format!("bad scale: {s:?}")),
        },
        None => None,
    };
    let mut jobs = state.jobs.list();
    jobs.reverse(); // newest first
    for view in jobs.iter().filter(|v| v.state == JobState::Done) {
        if !view.spec.swept().contains(&bench) {
            continue;
        }
        if scale.map_or(false, |sc| view.spec.scale != sc) {
            continue;
        }
        let mut spec = view.spec.clone();
        spec.shard = None; // reassemble against the full plan
        let merged = match merge::merge(&spec, &[&view.sink]) {
            Ok(m) => m,
            Err(e) => return Response::error(500, &format!("merge {}: {e}", view.id)),
        };
        if !merged.missing.is_empty() {
            continue; // a shard job's sink alone is partial: keep looking
        }
        if let Some(ex) = merged.outcome.get(bench) {
            return Response::new(200, "text/csv; charset=utf-8", report::pareto_csv(ex.points()))
                .with_header("X-Job", view.id.clone());
        }
    }
    let scale_note = scale.map_or(String::new(), |s| format!(" at scale {}", s.as_str()));
    Response::error(404, &format!("no completed campaign covers {bench}{scale_note}"))
}

/// `GET /cost-store/stat`: the shared store's on-disk counters plus the
/// live coordinator's cost-stack counters (memo/store hits, backend
/// misses and batches across every job this daemon ran).
fn store_stat(state: &ServeState) -> Response {
    let path = state.jobs.shared_store();
    let store = match CostStore::open(path) {
        Ok(s) => s,
        Err(e) => return Response::error(500, &format!("open cost store: {e}")),
    };
    let rep = store.report();
    let fps: Vec<String> = store
        .per_fingerprint()
        .iter()
        .map(|(fp, n)| format!("{{\"fp\":\"{}\",\"rows\":{n}}}", escape(fp)))
        .collect();
    let c = state.coord.cost_counters();
    Response::json(
        200,
        format!(
            concat!(
                "{{\"schema\":\"serve/v1\",\"path\":\"{}\",\"rows\":{},",
                "\"malformed\":{},\"duplicates\":{},\"conflicts\":{},\"torn_tail\":{},",
                "\"memo_hits\":{},\"store_hits\":{},\"misses\":{},\"batches\":{},",
                "\"fingerprints\":[{}]}}"
            ),
            escape(&path.display().to_string()),
            store.len(),
            rep.malformed,
            rep.duplicates,
            rep.conflicts,
            rep.torn_tail,
            c.memo_hits,
            c.store_hits,
            c.misses,
            c.batches,
            fps.join(","),
        ),
    )
}

fn shutdown(state: &ServeState) -> Response {
    state.begin_shutdown();
    let body = "{\"schema\":\"serve/v1\",\"stopping\":true}".to_string();
    Response::json(200, body)
}

/// One job as a flat `serve/v1` JSON object.
fn job_json(view: &JobView) -> String {
    let mut s = format!(
        concat!(
            "{{\"schema\":\"serve/v1\",\"id\":\"{}\",\"state\":\"{}\",\"scale\":\"{}\",",
            "\"benchmarks\":{},\"shard\":{},\"sink\":\"{}\""
        ),
        view.id,
        view.state.as_str(),
        view.spec.scale.as_str(),
        view.spec.swept().len(),
        match &view.spec.shard {
            Some(sh) => format!("\"{sh}\""),
            None => "null".to_string(),
        },
        escape(&view.sink.display().to_string()),
    );
    if let Some(err) = &view.error {
        s.push_str(&format!(",\"error\":\"{}\"", escape(err)));
    }
    if let Some(o) = &view.outcome {
        s.push_str(&format!(
            concat!(
                ",\"points\":{},\"simulated\":{},\"memoized\":{},\"resumed\":{},",
                "\"cost_batches\":{},\"cost_hits\":{},\"cost_misses\":{}"
            ),
            o.points,
            o.simulated,
            o.memoized,
            o.resumed,
            o.cost_batches,
            o.cost_hits,
            o.cost_misses
        ));
    }
    s.push('}');
    s
}
