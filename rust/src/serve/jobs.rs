//! Job registry + queue + worker fleet for the serve daemon.
//!
//! Every submitted [`CampaignSpec`] becomes a *job*: a numbered
//! directory under `<data-dir>/campaigns/` holding the canonical spec
//! (`spec.toml`) and the result sink (`results.jsonl` plus its status /
//! history sidecars). Jobs are queued FIFO onto a fixed worker fleet
//! that executes through one shared [`Coordinator`], so every job in
//! the daemon's lifetime shares one cost service, one in-process macro
//! memo, one persistent cost store, and one persistent simulation
//! store under the data dir — a warm re-submission of a spec scores
//! with **0 backend batches** and simulates **0 points** (the whole
//! run answers from the shared [`crate::sim::SimStack`]).
//!
//! On restart the registry rescans the campaign directories: completed
//! jobs stay queryable (Pareto endpoint), interrupted ones surface as
//! failed with a resubmit hint (their sinks resume on the next run).

use crate::campaign::{self, sink, ExecOptions};
use crate::coordinator::Coordinator;
use crate::error::{Error, Result};
use crate::spec::CampaignSpec;
use crate::util::jsonl;
use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Lifecycle of one job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Accepted, waiting for a worker.
    Queued,
    /// A worker is executing it.
    Running,
    /// Completed successfully.
    Done,
    /// Execution returned an error.
    Failed,
    /// Cancelled before or during execution.
    Cancelled,
}

impl JobState {
    /// Stable lowercase name (JSON `state` field).
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    /// True once the job can no longer change state.
    pub fn terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

/// Summary numbers kept from a completed campaign.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct JobOutcome {
    /// Total design points across the job's explorations.
    pub points: usize,
    /// Points simulated fresh by this run.
    pub simulated: usize,
    /// Points answered by the shared simulation stack (memo or
    /// persistent sim store) instead of the scheduler.
    pub memoized: usize,
    /// Points restored from the sink.
    pub resumed: usize,
    /// Runtime-backend batches issued (0 = fully warm).
    pub cost_batches: usize,
    /// Cost-stack cache hits (memo + store).
    pub cost_hits: usize,
    /// Cost-stack backend misses.
    pub cost_misses: usize,
}

impl JobOutcome {
    fn from_campaign(o: &campaign::CampaignOutcome) -> JobOutcome {
        JobOutcome {
            points: o.total_points(),
            simulated: o.simulated,
            memoized: o.memoized,
            resumed: o.resumed,
            cost_batches: o.cost_batches,
            cost_hits: o.cost.hits(),
            cost_misses: o.cost.misses,
        }
    }
}

/// Internal mutable job record.
struct Job {
    id: String,
    dir: PathBuf,
    spec: CampaignSpec,
    state: JobState,
    error: Option<String>,
    cancel: Arc<AtomicBool>,
    outcome: Option<JobOutcome>,
}

/// Immutable snapshot of one job, handed to the router.
#[derive(Clone, Debug)]
pub struct JobView {
    /// Job id (`c0001`, …).
    pub id: String,
    /// Job directory under the data dir.
    pub dir: PathBuf,
    /// Result sink path (`<dir>/results.jsonl`).
    pub sink: PathBuf,
    /// The spec as executed (sink / cost store rewritten under the
    /// data dir).
    pub spec: CampaignSpec,
    /// Current lifecycle state.
    pub state: JobState,
    /// Failure detail, when [`JobState::Failed`].
    pub error: Option<String>,
    /// Summary numbers, when [`JobState::Done`].
    pub outcome: Option<JobOutcome>,
}

struct Inner {
    jobs: Vec<Job>,
    queue: VecDeque<usize>,
    next_id: usize,
}

/// The daemon's job registry: a FIFO queue guarded by a condvar, plus
/// the persistent directory layout that survives restarts.
pub struct JobQueue {
    root: PathBuf,
    shared_store: PathBuf,
    shared_sim_store: PathBuf,
    shared_weights: PathBuf,
    inner: Mutex<Inner>,
    ready: Condvar,
    stopping: AtomicBool,
}

impl JobQueue {
    /// Open (and create) the registry under `data_dir`, re-registering
    /// any jobs a previous daemon left behind.
    pub fn open(data_dir: &Path) -> Result<JobQueue> {
        let root = data_dir.join("campaigns");
        std::fs::create_dir_all(&root)
            .map_err(|e| Error::io(format!("create {}", root.display()), e))?;
        let q = JobQueue {
            root: root.clone(),
            shared_store: data_dir.join("cost-store.jsonl"),
            shared_sim_store: data_dir.join("sim-store.jsonl"),
            shared_weights: data_dir.join("weights.jsonl"),
            inner: Mutex::new(Inner { jobs: Vec::new(), queue: VecDeque::new(), next_id: 1 }),
            ready: Condvar::new(),
            stopping: AtomicBool::new(false),
        };
        q.rescan(&root)?;
        Ok(q)
    }

    /// Path of the cost store every job shares.
    pub fn shared_store(&self) -> &Path {
        &self.shared_store
    }

    /// Path of the simulation store every job shares.
    pub fn shared_sim_store(&self) -> &Path {
        &self.shared_sim_store
    }

    /// Path of the trace-weight table every job shares.
    pub fn shared_weights(&self) -> &Path {
        &self.shared_weights
    }

    /// Re-register jobs from a previous daemon run. Completed jobs stay
    /// queryable; anything else is surfaced as failed with a hint (the
    /// sink is resumable by re-submitting the same spec).
    fn rescan(&self, root: &Path) -> Result<()> {
        let mut dirs: Vec<PathBuf> = std::fs::read_dir(root)
            .map_err(|e| Error::io(format!("scan {}", root.display()), e))?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.is_dir())
            .collect();
        dirs.sort();
        let mut inner = self.inner.lock().expect("job registry poisoned");
        for dir in dirs {
            let id = match dir.file_name().and_then(|n| n.to_str()) {
                Some(n) => n.to_string(),
                None => continue,
            };
            let spec = match CampaignSpec::load(&dir.join("spec.toml")) {
                Ok(s) => s,
                Err(_) => continue, // not a job directory
            };
            let sink = dir.join("results.jsonl");
            let complete = std::fs::read_to_string(sink::status_path(&sink))
                .ok()
                .and_then(|doc| jsonl::field(&doc, "complete").map(|v| v == "true"))
                .unwrap_or(false);
            let (state, error) = if complete {
                (JobState::Done, None)
            } else {
                (JobState::Failed, Some("interrupted; resubmit the spec to resume".to_string()))
            };
            if let Some(n) = id.strip_prefix('c').and_then(|n| n.parse::<usize>().ok()) {
                inner.next_id = inner.next_id.max(n + 1);
            }
            inner.jobs.push(Job {
                id,
                dir,
                spec,
                state,
                error,
                cancel: Arc::new(AtomicBool::new(false)),
                outcome: None,
            });
        }
        Ok(())
    }

    /// Accept a validated spec: assign an id, pin its sink / cost store
    /// / sim store / weight table under the data dir, persist the
    /// canonical spec, and queue it for the worker fleet.
    pub fn submit(&self, mut spec: CampaignSpec) -> Result<JobView> {
        spec.validate()?;
        let mut inner = self.inner.lock().expect("job registry poisoned");
        let id = format!("c{:04}", inner.next_id);
        inner.next_id += 1;
        let dir = self.root.join(&id);
        std::fs::create_dir_all(&dir)
            .map_err(|e| Error::io(format!("create {}", dir.display()), e))?;
        spec.sink = Some(dir.join("results.jsonl"));
        spec.cost_store = Some(self.shared_store.clone());
        spec.sim_store = Some(self.shared_sim_store.clone());
        if spec.weights.is_none() {
            spec.weights = Some(self.shared_weights.clone());
        }
        let spec_path = dir.join("spec.toml");
        std::fs::write(&spec_path, spec.to_toml())
            .map_err(|e| Error::io(format!("write {}", spec_path.display()), e))?;
        let ix = inner.jobs.len();
        inner.jobs.push(Job {
            id,
            dir,
            spec,
            state: JobState::Queued,
            error: None,
            cancel: Arc::new(AtomicBool::new(false)),
            outcome: None,
        });
        inner.queue.push_back(ix);
        let view = view_of(&inner.jobs[ix]);
        drop(inner);
        self.ready.notify_one();
        Ok(view)
    }

    /// Block until a job is available (marking it running) or the
    /// queue is shut down (`None`).
    pub fn claim(&self) -> Option<(usize, CampaignSpec, Arc<AtomicBool>)> {
        let mut inner = self.inner.lock().expect("job registry poisoned");
        loop {
            if self.stopping.load(Ordering::SeqCst) {
                return None;
            }
            if let Some(ix) = inner.queue.pop_front() {
                let job = &mut inner.jobs[ix];
                job.state = JobState::Running;
                return Some((ix, job.spec.clone(), Arc::clone(&job.cancel)));
            }
            inner = self.ready.wait(inner).expect("job registry poisoned");
        }
    }

    /// Record a worker's result for a claimed job.
    pub fn finish(&self, ix: usize, result: Result<JobOutcome>) {
        let mut inner = self.inner.lock().expect("job registry poisoned");
        let job = &mut inner.jobs[ix];
        match result {
            Ok(outcome) => {
                job.state = JobState::Done;
                job.outcome = Some(outcome);
            }
            Err(e) => {
                if job.cancel.load(Ordering::SeqCst) {
                    job.state = JobState::Cancelled;
                } else {
                    job.state = JobState::Failed;
                    job.error = Some(e.to_string());
                }
            }
        }
    }

    /// Cancel a job: queued jobs flip to cancelled immediately, running
    /// jobs get their cooperative flag raised (the worker records the
    /// terminal state). Returns the state after the request.
    pub fn cancel(&self, id: &str) -> Result<JobState> {
        let mut inner = self.inner.lock().expect("job registry poisoned");
        let ix = inner
            .jobs
            .iter()
            .position(|j| j.id == id)
            .ok_or_else(|| Error::msg(format!("no such job: {id}")))?;
        let state = inner.jobs[ix].state;
        match state {
            JobState::Queued => {
                inner.queue.retain(|&q| q != ix);
                inner.jobs[ix].state = JobState::Cancelled;
                Ok(JobState::Cancelled)
            }
            JobState::Running => {
                inner.jobs[ix].cancel.store(true, Ordering::SeqCst);
                Ok(JobState::Running)
            }
            terminal => Err(Error::msg(format!("job {id} already {}", terminal.as_str()))),
        }
    }

    /// Snapshot one job by id.
    pub fn get(&self, id: &str) -> Option<JobView> {
        let inner = self.inner.lock().expect("job registry poisoned");
        inner.jobs.iter().find(|j| j.id == id).map(view_of)
    }

    /// Snapshot every job, oldest first.
    pub fn list(&self) -> Vec<JobView> {
        let inner = self.inner.lock().expect("job registry poisoned");
        inner.jobs.iter().map(view_of).collect()
    }

    /// Wake every worker and make [`JobQueue::claim`] return `None`.
    pub fn stop(&self) {
        self.stopping.store(true, Ordering::SeqCst);
        self.ready.notify_all();
    }

    /// True once [`JobQueue::stop`] has been called.
    pub fn stopping(&self) -> bool {
        self.stopping.load(Ordering::SeqCst)
    }
}

fn view_of(job: &Job) -> JobView {
    JobView {
        id: job.id.clone(),
        dir: job.dir.clone(),
        sink: job.dir.join("results.jsonl"),
        spec: job.spec.clone(),
        state: job.state,
        error: job.error.clone(),
        outcome: job.outcome,
    }
}

/// One worker thread's main loop: claim → execute via the shared
/// coordinator → record, until the queue stops. `base` carries the
/// daemon-wide [`ExecOptions`] (artifacts dir, status-history length);
/// the per-job cancellation flag is layered on top.
pub fn worker_loop(queue: &JobQueue, coord: &Coordinator, base: &ExecOptions) {
    while let Some((ix, spec, cancel)) = queue.claim() {
        let mut opts = base.clone();
        opts.cancel = Some(Arc::clone(&cancel));
        let result =
            campaign::run_with(&spec, coord, &opts).map(|o| JobOutcome::from_campaign(&o));
        queue.finish(ix, result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suite::Scale;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("amm-serve-jobs-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn tiny_spec() -> CampaignSpec {
        let mut spec = CampaignSpec::default().benchmark("gemm");
        spec.scale = Scale::Tiny;
        spec.sweep = crate::dse::Sweep::quick();
        spec
    }

    #[test]
    fn submit_pins_paths_and_persists_the_spec() {
        let dir = tmpdir("submit");
        let q = JobQueue::open(&dir).unwrap();
        let view = q.submit(tiny_spec()).unwrap();
        assert_eq!(view.id, "c0001");
        assert_eq!(view.state, JobState::Queued);
        assert_eq!(view.spec.sink.as_deref(), Some(view.sink.as_path()));
        assert_eq!(view.spec.cost_store.as_deref(), Some(q.shared_store()));
        assert_eq!(view.spec.sim_store.as_deref(), Some(q.shared_sim_store()));
        assert_eq!(view.spec.weights.as_deref(), Some(q.shared_weights()));
        let persisted = CampaignSpec::load(&view.dir.join("spec.toml")).unwrap();
        assert_eq!(persisted, view.spec, "spec.toml round-trips the executed spec");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn claim_marks_running_and_finish_records_terminal_states() {
        let dir = tmpdir("claim");
        let q = JobQueue::open(&dir).unwrap();
        let a = q.submit(tiny_spec()).unwrap();
        let b = q.submit(tiny_spec()).unwrap();
        assert_eq!(b.id, "c0002");
        let (ix, _, cancel) = q.claim().unwrap();
        assert_eq!(q.get(&a.id).unwrap().state, JobState::Running);
        q.finish(ix, Ok(JobOutcome { points: 6, ..JobOutcome::default() }));
        assert_eq!(q.get(&a.id).unwrap().state, JobState::Done);
        assert_eq!(q.get(&a.id).unwrap().outcome.unwrap().points, 6);
        assert!(!cancel.load(Ordering::SeqCst));
        let (ix, _, cancel) = q.claim().unwrap();
        cancel.store(true, Ordering::SeqCst);
        q.finish(ix, Err(Error::runtime("campaign cancelled")));
        assert_eq!(q.get(&b.id).unwrap().state, JobState::Cancelled);
        q.stop();
        assert!(q.claim().is_none(), "stopped queue releases workers");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn queued_jobs_cancel_immediately_and_terminal_jobs_conflict() {
        let dir = tmpdir("cancel");
        let q = JobQueue::open(&dir).unwrap();
        let a = q.submit(tiny_spec()).unwrap();
        assert_eq!(q.cancel(&a.id).unwrap(), JobState::Cancelled);
        assert!(q.cancel(&a.id).is_err(), "cancelling twice conflicts");
        assert!(q.cancel("c9999").is_err());
        // the cancelled job never reaches a worker
        q.stop();
        assert!(q.claim().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn restart_rescan_recovers_completed_and_interrupted_jobs() {
        let dir = tmpdir("rescan");
        {
            let q = JobQueue::open(&dir).unwrap();
            let done = q.submit(tiny_spec()).unwrap();
            let torn = q.submit(tiny_spec()).unwrap();
            // fake a completed sidecar for the first, none for the second
            let doc = "{\"schema\":\"campaign-status/v1\",\"done\":6,\"complete\":true}\n";
            std::fs::write(sink::status_path(&done.sink), doc).unwrap();
            std::fs::write(&torn.sink, "").unwrap();
        }
        let q = JobQueue::open(&dir).unwrap();
        let jobs = q.list();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].state, JobState::Done);
        assert_eq!(jobs[1].state, JobState::Failed);
        assert!(jobs[1].error.as_deref().unwrap_or("").contains("resubmit"));
        // numbering continues past recovered jobs
        assert_eq!(q.submit(tiny_spec()).unwrap().id, "c0003");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
