//! DSE-as-a-service: a zero-dependency HTTP daemon around the
//! campaign engine (`repro serve`).
//!
//! One process owns one data directory, one [`Coordinator`] (so every
//! job shares the memo → store → backend cost stack *and* the sim
//! memo → sim store tiers — a warm re-submission reaches the backend
//! zero times and simulates zero points), and one persistent
//! [`jobs::JobQueue`] worker fleet. Campaign specs arrive as the same
//! TOML `repro run --spec` takes; results, status sidecars and the
//! shared cost store are plain files under the data dir, served
//! verbatim — the daemon adds transport, not formats:
//!
//! ```text
//! <data-dir>/
//!   cost-store.jsonl            shared macro-cost store (cost-store/v1)
//!   sim-store.jsonl             shared simulation store (sim-store/v1)
//!   weights.jsonl               trace weight table (weight-table/v1)
//!   campaigns/c0001/spec.toml   pinned spec (campaign-spec/v1)
//!   campaigns/c0001/results.jsonl                 sink (campaign/v1)
//!   campaigns/c0001/results.jsonl.status.json     live status (campaign-status/v1)
//!   campaigns/c0001/results.jsonl.status.history.jsonl  status ring
//! ```
//!
//! The server is std-only: a blocking [`TcpListener`] accept loop,
//! one thread per connection feeding [`http::RequestBuf`], and the
//! endpoint table in [`router`]. Shutdown (`POST /shutdown`, or
//! [`ServeState::begin_shutdown`]) raises a flag and pokes the
//! listener with a loopback connect so the blocking accept observes
//! it; workers drain via the queue's condvar and are joined before
//! [`Server::run`] returns.

pub mod http;
pub mod jobs;
pub mod router;

use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::campaign::{sink, ExecOptions};
use crate::coordinator::Coordinator;
use crate::error::{Error, Result};
use crate::util::log;
use http::{RequestBuf, Response};
use jobs::JobQueue;

/// Schema tag on every JSON body the daemon itself authors.
pub const SCHEMA: &str = "serve/v1";

/// How long a keep-alive connection may sit idle between requests.
const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Daemon configuration (`repro serve` flags).
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Bind address; port 0 picks an ephemeral port (tests).
    pub addr: String,
    /// Campaign worker threads (jobs run concurrently, ≥ 1).
    pub workers: usize,
    /// Root for job dirs, the shared cost/sim stores and weight table.
    pub data_dir: PathBuf,
    /// Backend artifacts dir override (None: `AMM_DSE_ARTIFACTS` or
    /// the baked-in default, falling back to the Rust model).
    pub artifacts: Option<PathBuf>,
    /// Status-history ring length handed to every job's sidecar.
    pub status_history: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:7878".to_string(),
            workers: 1,
            data_dir: PathBuf::from("serve-data"),
            artifacts: None,
            status_history: sink::DEFAULT_HISTORY,
        }
    }
}

/// Shared daemon state: the job queue, the one coordinator, and the
/// shutdown flag. Handed to every connection thread and the router.
pub struct ServeState {
    pub data_dir: PathBuf,
    pub jobs: JobQueue,
    pub coord: Coordinator,
    pub workers: usize,
    pub started: Instant,
    pub addr: SocketAddr,
    stop: AtomicBool,
}

impl ServeState {
    /// Raise the stop flag, wake queued workers, and poke the
    /// listener so the blocking accept loop sees the flag.
    pub fn begin_shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.jobs.stop();
        if let Ok(poke) = TcpStream::connect(self.addr) {
            drop(poke);
        }
    }

    pub fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }
}

/// A bound, not-yet-running daemon. `bind` then `run`; `addr` is
/// resolved (so `:0` binds are queryable) between the two.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServeState>,
    base: ExecOptions,
}

impl Server {
    pub fn bind(opts: &ServeOptions) -> Result<Server> {
        std::fs::create_dir_all(&opts.data_dir)
            .map_err(|e| Error::io(format!("create {}", opts.data_dir.display()), e))?;
        let jobs = JobQueue::open(&opts.data_dir)?;
        let listener = TcpListener::bind(opts.addr.as_str())
            .map_err(|e| Error::io(format!("bind {}", opts.addr), e))?;
        let addr = listener.local_addr().map_err(|e| Error::io("local_addr", e))?;
        let dir = opts.artifacts.clone().unwrap_or_else(crate::runtime::artifacts_dir);
        let coord = Coordinator::with_artifacts(dir);
        let state = Arc::new(ServeState {
            data_dir: opts.data_dir.clone(),
            jobs,
            coord,
            workers: opts.workers.max(1),
            started: Instant::now(),
            addr,
            stop: AtomicBool::new(false),
        });
        let base = ExecOptions {
            artifacts: opts.artifacts.clone(),
            status_history: opts.status_history,
            ..ExecOptions::default()
        };
        Ok(Server { listener, state, base })
    }

    /// The resolved bind address.
    pub fn addr(&self) -> SocketAddr {
        self.state.addr
    }

    /// A handle to the shared state (tests; shutdown from outside).
    pub fn state(&self) -> Arc<ServeState> {
        Arc::clone(&self.state)
    }

    /// Serve until shutdown: spawn the worker fleet, accept
    /// connections, then drain and join the workers.
    pub fn run(self) -> Result<()> {
        let Server { listener, state, base } = self;
        let mut workers = Vec::with_capacity(state.workers);
        for i in 0..state.workers {
            let st = Arc::clone(&state);
            let base = base.clone();
            let handle = std::thread::Builder::new()
                .name(format!("serve-worker-{i}"))
                .spawn(move || jobs::worker_loop(&st.jobs, &st.coord, &base))
                .map_err(|e| Error::io("spawn worker", e))?;
            workers.push(handle);
        }
        log::info(&format!(
            "serve: listening on {} ({} worker(s), data dir {})",
            state.addr,
            state.workers,
            state.data_dir.display()
        ));
        for conn in listener.incoming() {
            if state.stopping() {
                break;
            }
            match conn {
                Ok(stream) => {
                    let st = Arc::clone(&state);
                    let spawned = std::thread::Builder::new()
                        .name("serve-conn".to_string())
                        .spawn(move || handle_connection(&st, stream));
                    if let Err(e) = spawned {
                        log::warn(&format!("serve: spawn connection thread: {e}"));
                    }
                }
                Err(e) => log::warn(&format!("serve: accept: {e}")),
            }
        }
        state.jobs.stop();
        for handle in workers {
            let _ = handle.join();
        }
        log::info("serve: stopped");
        Ok(())
    }
}

/// Bind and serve in one call (the `repro serve` entry point).
pub fn serve(opts: &ServeOptions) -> Result<()> {
    Server::bind(opts)?.run()
}

/// Per-connection loop: read, parse (tolerating torn reads), route,
/// respond; keep-alive until the peer closes, errors, times out, or
/// the daemon stops.
fn handle_connection(state: &ServeState, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let mut buf = RequestBuf::new();
    let mut chunk = [0u8; 8192];
    loop {
        match buf.next_request() {
            Ok(Some(req)) => {
                let resp = router::route(state, &req);
                let keep = req.keep_alive() && !state.stopping();
                if resp.write_to(&mut stream, keep).is_err() || !keep {
                    return;
                }
            }
            Ok(None) => match stream.read(&mut chunk) {
                Ok(0) => return, // peer closed
                Ok(n) => buf.push(&chunk[..n]),
                Err(_) => return, // timeout or reset
            },
            Err(e) => {
                let _ = Response::error(e.status(), &e.detail()).write_to(&mut stream, false);
                return;
            }
        }
    }
}
