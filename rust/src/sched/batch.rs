//! Lane-batched simulation engine — layer 4 of the scheduler stack.
//!
//! A DSE sweep scores the *same* compiled trace against many memory
//! designs whose only differences are port counts, bank maps and access
//! costs. The scalar engine walks the full trace once per design point,
//! so a sweep re-traverses identical successor lists and re-pops
//! identical ready events for every point.
//! [`CompiledTrace::simulate_batch`] instead schedules up to L
//! *compatible* design points (same trace, same `word_bytes`; knobs
//! shared, ports/banking/model varying per lane) in ONE pass: the
//! trace-shaped work — iteration gates ([`BatchArena::gates`] is
//! computed once per call), node classes, sub-word decomposition,
//! successor lists — is shared across lanes marching in cache-friendly
//! lockstep, while the design-dependent port-arbitration step
//! ([`CompiledTrace::try_mem`]) diverges per lane.
//!
//! The per-lane event machinery also drops the scalar engine's five
//! `BinaryHeap`s for [`ReadyQ`]s — cycle-indexed ready queues whose
//! common case (a successor becoming ready at the cycle being retired)
//! is an O(1) push and whose pops come off a pre-sorted list, reserving
//! the heap for the rare far-future iteration-gate events.
//!
//! The v2 kernel replaces the v1 global clock (a per-step linear
//! `min(next_visit)` scan over every lane) with an [`EventWheel`]: a
//! 64-slot bucket queue of lane bitmasks keyed by cycle, with an
//! occupancy summary word and a far-event mask. Advancing the clock is
//! one rotate + `trailing_zeros`, lanes due at the new cycle pop as a
//! bitmask, and idle lanes cost literally zero per step. The same trick
//! collapses the per-lane completion-ring probe loop (`Lane::ring_occ`)
//! and raises the lane cap from 8 to the bitmask width
//! ([`crate::dse::MAX_LANES`] caps dispatch at 32; the kernel itself
//! accepts up to 64).
//!
//! **Bit-identity contract**: every lane must produce the exact
//! [`SimOutput`] the scalar [`CompiledTrace::simulate`] produces for
//! that design (`PartialEq`, no tolerance) — the scalar engine stays
//! the oracle. Each lane therefore runs the scalar state machine
//! unmodified: a lane is stepped only at the cycles its own advance
//! rule would visit (skipped cycles touch no lane state, so skipping is
//! exact), every step executes the scalar phase order — retire, reg
//! drain, FU issue, memory issue, advance — and the port arbitration
//! and physical composition are the *same functions* the scalar engine
//! calls ([`CompiledTrace::try_mem`] /
//! [`CompiledTrace::compose_output`]). The [`ReadyQ`] preserves the
//! heaps' exact `(ready_cycle, node)` pop order (keys are unique per
//! queue, so heap order is fully determined by the key set).
//! `tests/engine_golden.rs` pins the contract across all suite
//! benchmarks × mixed model families; `tests/sched_props.rs` fuzzes it
//! over random traces × random lane mixes.

use super::arena::{Heap, RING};
use super::compile::{Accum, CompiledTrace, MemIssue, NodeClass, PortCfg};
use super::{Knobs, SimOutput};
use crate::mem::MemDesign;
use crate::trace::OpKind;
use std::cmp::Reverse;
use std::collections::VecDeque;

/// A cycle-aware ready queue with the scalar heap's exact pop order —
/// ascending `(ready_cycle, node)` — but O(1) for the dominant flows:
/// same-cycle wakeups append to a scratch list and pops read a
/// pre-sorted deque; only far-future events (iteration gates ahead of
/// the clock) pay heap costs.
///
/// Ordering invariant for `due`: leftover entries (ready at some
/// earlier visited cycle) precede newly matured ones (which mature in
/// ascending heap order at strictly later cycles), so `due` is always
/// sorted by `(ready_cycle, node)` and `pop_due` replays the heap's
/// order exactly.
struct ReadyQ {
    /// Events ready at or before the last synced cycle, in pop order.
    due: VecDeque<(u64, u32)>,
    /// Events pushed at exactly the cycle being processed (a successor
    /// freed by a completion this cycle — the common case).
    today: Vec<u32>,
    /// Far events, keyed `(ready_cycle, node)` like the scalar heaps.
    fut: Heap,
    /// Scratch: heap events maturing exactly at the syncing cycle.
    tmp: Vec<u32>,
    /// Total queued events across `due`/`today`/`fut`.
    len: usize,
}

impl ReadyQ {
    fn new() -> ReadyQ {
        ReadyQ {
            due: VecDeque::new(),
            today: Vec::new(),
            fut: Heap::new(),
            tmp: Vec::new(),
            len: 0,
        }
    }

    fn clear(&mut self) {
        self.due.clear();
        self.today.clear();
        self.fut.clear();
        self.tmp.clear();
        self.len = 0;
    }

    /// Queue `nid` to become ready at cycle `at` (`at >= now` always:
    /// seed and retire pushes never target the past).
    #[inline]
    fn push(&mut self, at: u64, now: u64, nid: u32) {
        if at <= now {
            self.today.push(nid);
        } else {
            self.fut.push(Reverse((at, nid)));
        }
        self.len += 1;
    }

    /// Fold matured events into `due`, preserving `(cycle, node)`
    /// order. Called once per visited cycle, after the retire phase
    /// (the only pusher) and before any pop.
    fn sync(&mut self, now: u64) {
        if self.len == 0 {
            return;
        }
        while let Some(&Reverse((rc, _))) = self.fut.peek() {
            if rc > now {
                break;
            }
            let Reverse((rc, nid)) = self.fut.pop().unwrap();
            if rc < now {
                // matured between visits: pops ascending, all later than
                // any leftover already in `due`
                self.due.push_back((rc, nid));
            } else {
                self.tmp.push(nid);
            }
        }
        if self.today.is_empty() && self.tmp.is_empty() {
            return;
        }
        // merge the two node-ascending runs ready at exactly `now` (a
        // node is queued at most once, so the runs never share an id)
        self.today.sort_unstable();
        let (mut i, mut j) = (0usize, 0usize);
        while i < self.tmp.len() && j < self.today.len() {
            if self.tmp[i] < self.today[j] {
                self.due.push_back((now, self.tmp[i]));
                i += 1;
            } else {
                self.due.push_back((now, self.today[j]));
                j += 1;
            }
        }
        for &nid in &self.tmp[i..] {
            self.due.push_back((now, nid));
        }
        for &nid in &self.today[j..] {
            self.due.push_back((now, nid));
        }
        self.tmp.clear();
        self.today.clear();
    }

    /// Pop the next matured event (everything in `due` is ready at the
    /// current cycle by construction).
    #[inline]
    fn pop_due(&mut self) -> Option<(u64, u32)> {
        let e = self.due.pop_front()?;
        self.len -= 1;
        Some(e)
    }

    /// Re-queue a popped-but-stalled op under its ORIGINAL key. It was
    /// the queue minimum when popped, so the front keeps exact order —
    /// the scalar engine's `push(Reverse((rc0, nid)))` equivalent.
    #[inline]
    fn requeue_front(&mut self, rc0: u64, nid: u32) {
        self.due.push_front((rc0, nid));
        self.len += 1;
    }

    /// Earliest queued event, `u64::MAX` when empty — the scalar
    /// engine's heap peek for the advance step. (`today` is always
    /// empty by advance time: only the retire phase feeds it and `sync`
    /// drains it.)
    #[inline]
    fn next_at(&self) -> u64 {
        let d = self.due.front().map_or(u64::MAX, |&(rc, _)| rc);
        let f = self.fut.peek().map_or(u64::MAX, |&Reverse((rc, _))| rc);
        d.min(f)
    }
}

/// The five per-class ready queues of one lane (mirrors `SimArena`'s
/// heap quintet; which memory queue is live depends on the lane's
/// banked-vs-true-port split).
struct ReadySet {
    reg: ReadyQ,
    alu: ReadyQ,
    mem: ReadyQ,
    rd: ReadyQ,
    wr: ReadyQ,
}

impl ReadySet {
    fn new() -> ReadySet {
        ReadySet {
            reg: ReadyQ::new(),
            alu: ReadyQ::new(),
            mem: ReadyQ::new(),
            rd: ReadyQ::new(),
            wr: ReadyQ::new(),
        }
    }

    fn clear(&mut self) {
        self.reg.clear();
        self.alu.clear();
        self.mem.clear();
        self.rd.clear();
        self.wr.clear();
    }

    /// Route one ready node to its class queue — the scalar engine's
    /// `push_ready!` with the per-lane port split made explicit.
    #[inline]
    fn push(&mut self, class: NodeClass, per_bank: bool, nid: u32, at: u64, now: u64) {
        match class {
            NodeClass::Alu => self.alu.push(at, now, nid),
            NodeClass::Reg => self.reg.push(at, now, nid),
            NodeClass::Load => {
                if per_bank {
                    self.mem.push(at, now, nid);
                } else {
                    self.rd.push(at, now, nid);
                }
            }
            NodeClass::Store => {
                if per_bank {
                    self.mem.push(at, now, nid);
                } else {
                    self.wr.push(at, now, nid);
                }
            }
        }
    }

    fn sync(&mut self, now: u64) {
        self.reg.sync(now);
        self.alu.sync(now);
        self.mem.sync(now);
        self.rd.sync(now);
        self.wr.sync(now);
    }

    /// Earliest ready event across every queue.
    fn next_at(&self) -> u64 {
        self.reg
            .next_at()
            .min(self.alu.next_at())
            .min(self.mem.next_at())
            .min(self.rd.next_at())
            .min(self.wr.next_at())
    }
}

/// One lane's private scheduling state: everything of the scalar
/// engine's per-run state that is design-dependent. The trace-shaped
/// halves (`remaining`, `subs_left`, iteration gates) live lane-major
/// in the [`BatchArena`].
struct Lane {
    ready: ReadySet,
    /// Completion ring, `RING` slots indexed `cycle % RING` (same
    /// schema as `SimArena::ring`, but per lane — each lane's retire
    /// set and ring scan must match its own scalar run exactly).
    ring: Vec<Vec<u32>>,
    /// Slot-occupancy bitmask: bit `s` set iff `ring[s]` is non-empty,
    /// so the advance step finds the nearest completion with a rotate +
    /// `trailing_zeros` instead of probing up to `RING` slots.
    ring_occ: u32,
    ring_pending: usize,
    retire_buf: Vec<u32>,
    used_rd: Vec<u32>,
    used_wr: Vec<u32>,
    cfg: PortCfg,
    acc: Accum,
    /// Last cycle this lane was stepped at (the scalar engine's clock).
    cycle: u64,
    /// Next cycle this lane's advance rule wants to visit.
    next_visit: u64,
    done: usize,
    finished: bool,
}

impl Lane {
    fn new() -> Lane {
        Lane {
            ready: ReadySet::new(),
            ring: vec![Vec::new(); RING],
            ring_occ: 0,
            ring_pending: 0,
            retire_buf: Vec::new(),
            used_rd: Vec::new(),
            used_wr: Vec::new(),
            cfg: PortCfg::default(),
            acc: Accum::default(),
            cycle: 0,
            next_visit: 0,
            done: 0,
            finished: false,
        }
    }

    /// Re-arm for a new batch, keeping allocations (dirty reuse across
    /// traces and lane mixes is part of the contract, like
    /// `SimArena::reset`).
    fn reset(&mut self) {
        self.ready.clear();
        for slot in &mut self.ring {
            slot.clear();
        }
        self.ring_occ = 0;
        self.ring_pending = 0;
        self.retire_buf.clear();
        self.acc = Accum::default();
        self.cycle = 0;
        self.next_visit = 0;
        self.done = 0;
        self.finished = false;
    }

    /// Run ONE cycle of this lane's scalar state machine — the exact
    /// phase order of `CompiledTrace::simulate` — then compute the
    /// lane's next visit cycle via the scalar advance rule. Marks the
    /// lane finished when its DDG drains (or when no events remain).
    fn step(
        &mut self,
        ct: &CompiledTrace<'_>,
        gates: &[u64],
        rem: &mut [u32],
        subs: &mut [u32],
        alus: u32,
        now: u64,
    ) {
        let Lane {
            ready,
            ring,
            ring_occ,
            ring_pending,
            retire_buf,
            used_rd,
            used_wr,
            cfg,
            acc,
            cycle,
            next_visit,
            done,
            finished,
        } = self;
        let cfg = *cfg;
        *cycle = now;
        let n = ct.trace.len();

        // retire completions for this cycle
        let slot = (now % RING as u64) as usize;
        if !ring[slot].is_empty() {
            retire_buf.clear();
            retire_buf.append(&mut ring[slot]);
            *ring_occ &= !(1u32 << slot);
            *ring_pending -= retire_buf.len();
            *done += retire_buf.len();
            for &node in retire_buf.iter() {
                for &s in ct.trace.successors(node) {
                    rem[s as usize] -= 1;
                    if rem[s as usize] == 0 {
                        // producer completes at the start of this cycle,
                        // so the consumer may issue this cycle
                        let si = s as usize;
                        ready.push(ct.class[si], cfg.per_bank, s, gates[si].max(now), now);
                    }
                }
            }
        }
        ready.sync(now);

        macro_rules! complete_at {
            ($cycle:expr, $nid:expr) => {{
                let s = ($cycle % RING as u64) as usize;
                ring[s].push($nid);
                *ring_occ |= 1u32 << s;
                *ring_pending += 1;
            }};
        }

        // reset per-cycle port + FU counters
        let mut st = MemIssue {
            used_rd: used_rd.as_mut_slice(),
            used_wr: used_wr.as_mut_slice(),
            subs_left: subs,
            n_reads: &mut acc.n_reads,
            n_writes: &mut acc.n_writes,
            port_stalls: &mut acc.port_stalls,
            issued_mem: &mut acc.issued_mem,
        };
        for c in st.used_rd.iter_mut() {
            *c = 0;
        }
        for c in st.used_wr.iter_mut() {
            *c = 0;
        }
        let mut alu_slots = alus;
        let mut had_mem_stall = false;

        // register-promoted accesses are free: drain them all
        while let Some((_, nid)) = ready.reg.pop_due() {
            *st.issued_mem += 1;
            acc.n_reg += 1;
            complete_at!(now + 1, nid);
        }

        // FU issue: stop the moment slots run out
        while alu_slots > 0 {
            let Some((_, nid)) = ready.alu.pop_due() else { break };
            let OpKind::Alu(kind) = ct.trace.nodes[nid as usize].kind else { unreachable!() };
            alu_slots -= 1;
            acc.n_alu_energy += kind.energy_pj() as f64;
            complete_at!(now + kind.latency() as u64, nid);
        }

        if cfg.per_bank {
            // banked: in-order issue, first conflict stalls the rest
            while let Some((rc0, nid)) = ready.mem.pop_due() {
                let left = ct.try_mem(nid, &cfg, &mut st);
                if left > 0 {
                    had_mem_stall = true;
                    ready.mem.requeue_front(rc0, nid);
                    break;
                }
                complete_at!(now + 1, nid);
            }
        } else {
            // true multi-port: reads and writes issue independently
            while st.used_rd[0] < cfg.rd_ports {
                let Some((rc0, nid)) = ready.rd.pop_due() else { break };
                let left = ct.try_mem(nid, &cfg, &mut st);
                if left > 0 {
                    had_mem_stall = true;
                    ready.rd.requeue_front(rc0, nid);
                    break;
                }
                complete_at!(now + 1, nid);
            }
            while st.used_wr[0] < cfg.wr_ports {
                let Some((rc0, nid)) = ready.wr.pop_due() else { break };
                let left = ct.try_mem(nid, &cfg, &mut st);
                if left > 0 {
                    had_mem_stall = true;
                    ready.wr.requeue_front(rc0, nid);
                    break;
                }
                complete_at!(now + 1, nid);
            }
        }
        if had_mem_stall {
            acc.stall_cycles += 1;
        }

        // advance to this lane's next event; the nearest completion
        // comes off the ring-occupancy mask in one rotate (every pending
        // completion lies in `(now, now + RING]`, so residues are
        // unambiguous — the same window the scalar probe loop assumes)
        let mut next = ready.next_at();
        if *ring_pending > 0 {
            let from = ((now + 1) % RING as u64) as u32;
            let d = ring_occ.rotate_right(from).trailing_zeros() as u64;
            next = next.min(now + 1 + d);
        }
        if *done >= n || next == u64::MAX {
            *finished = true;
        } else {
            *next_visit = next.max(now + 1);
        }
    }
}

/// Bitmask width of the global clock: one `u64` bit per lane, and one
/// wheel slot per cycle residue. The kernel's hard lane cap.
const WHEEL: usize = 64;

/// The global batch clock — a single-level bucket queue (event wheel)
/// of lane bitmasks keyed by cycle.
///
/// Window invariant: a lane stepped at cycle `now` re-arms for
/// `next_visit > now`, and wheeled visits always satisfy
/// `next_visit <= insert_now + WHEEL <= now + WHEEL` (the clock only
/// advances to queued visits), so every wheeled event lies in
/// `(now, now + WHEEL]` — cycle residues are unambiguous and the next
/// event falls out of one rotate + `trailing_zeros` over `occ`. Visits
/// beyond the window (far-future iteration gates) park in `far` and
/// migrate into the wheel as the clock reaches them.
struct EventWheel {
    /// Lane bitmask per cycle residue (`cycle % WHEEL`).
    slots: [u64; WHEEL],
    /// Slot-occupancy summary: bit `s` set iff `slots[s] != 0`.
    occ: u64,
    /// Lanes whose next visit is beyond `now + WHEEL`.
    far: u64,
}

impl EventWheel {
    fn new() -> EventWheel {
        EventWheel { slots: [0; WHEEL], occ: 0, far: 0 }
    }

    fn clear(&mut self) {
        self.slots = [0; WHEEL];
        self.occ = 0;
        self.far = 0;
    }

    /// Queue lane `l`'s next visit at cycle `at` (strictly ahead of the
    /// clock).
    #[inline]
    fn insert(&mut self, l: usize, at: u64, now: u64) {
        debug_assert!(at > now, "lane re-arm must be strictly ahead of the clock");
        if at - now <= WHEEL as u64 {
            let s = (at % WHEEL as u64) as usize;
            self.slots[s] |= 1u64 << l;
            self.occ |= 1u64 << s;
        } else {
            self.far |= 1u64 << l;
        }
    }

    /// Advance the clock to the earliest queued visit: returns the new
    /// cycle and the bitmask of lanes due there (`None` when nothing is
    /// queued). Far lanes whose visit enters the new window migrate into
    /// the wheel here — a far event can become the nearest one after an
    /// advance, so migration is part of the pop, not best-effort.
    fn pop_next(&mut self, now: u64, lanes: &[Lane]) -> Option<(u64, u64)> {
        let next = if self.occ != 0 {
            let from = ((now + 1) % WHEEL as u64) as u32;
            now + 1 + self.occ.rotate_right(from).trailing_zeros() as u64
        } else if self.far != 0 {
            // wheel empty: the nearest far visit is the next event
            let mut m = self.far;
            let mut min = u64::MAX;
            while m != 0 {
                let l = m.trailing_zeros() as usize;
                m &= m - 1;
                min = min.min(lanes[l].next_visit);
            }
            min
        } else {
            return None;
        };
        // Pop the due slot BEFORE migrating: a far visit at exactly
        // `next + WHEEL` shares the slot residue of `next` and belongs
        // to the emptied slot, not to this advance.
        let mut due: u64 = 0;
        let s = (next % WHEEL as u64) as usize;
        if self.occ & (1u64 << s) != 0 {
            due |= self.slots[s];
            self.slots[s] = 0;
            self.occ &= !(1u64 << s);
        }
        if self.far != 0 {
            let mut m = self.far;
            while m != 0 {
                let l = m.trailing_zeros() as usize;
                m &= m - 1;
                let at = lanes[l].next_visit;
                if at == next {
                    due |= 1u64 << l;
                    self.far &= !(1u64 << l);
                } else if at - next <= WHEEL as u64 {
                    let sl = (at % WHEEL as u64) as usize;
                    self.slots[sl] |= 1u64 << l;
                    self.occ |= 1u64 << sl;
                    self.far &= !(1u64 << l);
                }
            }
        }
        debug_assert!(due != 0, "advance must land on at least one due lane");
        Some((next, due))
    }
}

/// Struct-of-arrays scratch state for [`CompiledTrace::simulate_batch`]:
/// the trace-shaped counters are lane-major flat vectors (lane `l` owns
/// `[l*n, (l+1)*n)`), the iteration gates are computed once and shared
/// by every lane, and the design-dependent event state is per [`Lane`].
/// Like `SimArena`, an arena may be dirty from ANY previous batch —
/// `simulate_batch` resets it allocation-preservingly, so reuse across
/// campaign units is allocation-exact once the high-water trace × lane
/// footprint has been reached (pinned by the `reuse` unit test below).
pub struct BatchArena {
    lanes: Vec<Lane>,
    /// Lane-major unsatisfied-predecessor counts.
    remaining: Vec<u32>,
    /// Lane-major outstanding sub-word accesses per node.
    subs_left: Vec<u32>,
    /// Shared per-batch iteration gates: `node.iter / unroll`, computed
    /// once for all lanes (knobs are batch-uniform).
    gates: Vec<u64>,
    /// The global clock (fixed-size; cleared per batch).
    wheel: EventWheel,
}

impl BatchArena {
    /// A fresh (empty) arena; lanes and counters are sized lazily by
    /// the first `simulate_batch` call.
    pub fn new() -> BatchArena {
        BatchArena {
            lanes: Vec::new(),
            remaining: Vec::new(),
            subs_left: Vec::new(),
            gates: Vec::new(),
            wheel: EventWheel::new(),
        }
    }

    /// Re-arm for `lanes` lanes over `ct`, keeping allocations.
    fn reset(&mut self, ct: &CompiledTrace<'_>, unroll: u32, lanes: usize) {
        if self.lanes.len() < lanes {
            self.lanes.resize_with(lanes, Lane::new);
        }
        self.gates.clear();
        self.gates.extend(ct.trace.nodes.iter().map(|nd| (nd.iter / unroll) as u64));
        self.remaining.clear();
        self.subs_left.clear();
        for _ in 0..lanes {
            self.remaining.extend_from_slice(&ct.trace.pred_count);
            self.subs_left.extend_from_slice(&ct.subs_init);
        }
        for lane in &mut self.lanes[..lanes] {
            lane.reset();
        }
    }
}

impl Default for BatchArena {
    fn default() -> Self {
        BatchArena::new()
    }
}

impl<'t> CompiledTrace<'t> {
    /// Schedule up to L compatible design points in one pass over the
    /// trace: `designs[l]` becomes lane `l`, and the result vector
    /// matches the input order. All lanes share this compiled trace and
    /// `knobs` (`knobs.word_bytes` must match the compiled word size);
    /// ports, banking and model vary freely per lane.
    ///
    /// Bit-identical to running [`CompiledTrace::simulate`] per design:
    /// each lane advances by its own scalar event rule on a global
    /// lockstep clock (the global cycle is the min over active lanes'
    /// next events, and only lanes due at that cycle are stepped — a
    /// skipped cycle would have been a no-op for the lane anyway).
    pub fn simulate_batch(
        &self,
        arena: &mut BatchArena,
        knobs: &Knobs,
        designs: &[MemDesign],
    ) -> Vec<SimOutput> {
        debug_assert_eq!(
            knobs.word_bytes.max(1),
            self.word_bytes,
            "CompiledTrace built for word_bytes={}, knobs ask {}",
            self.word_bytes,
            knobs.word_bytes
        );
        let lanes = designs.len();
        if lanes == 0 {
            return Vec::new();
        }
        assert!(lanes <= WHEEL, "simulate_batch caps at {WHEEL} lanes per call, got {lanes}");
        let n = self.trace.len();
        let unroll = knobs.unroll.max(1);
        let alus = knobs.alus.max(1);

        arena.reset(self, unroll, lanes);
        let BatchArena { lanes: lane_vec, remaining, subs_left, gates, wheel } = arena;
        let lane_vec = &mut lane_vec[..lanes];
        let gates = &gates[..];
        wheel.clear();

        // per-lane port config + counters + ready seed
        for (l, lane) in lane_vec.iter_mut().enumerate() {
            lane.cfg = PortCfg::of(&designs[l]);
            let counters = lane.cfg.counters();
            lane.used_rd.clear();
            lane.used_rd.resize(counters, 0);
            lane.used_wr.clear();
            lane.used_wr.resize(counters, 0);
            let rem = &remaining[l * n..(l + 1) * n];
            let per_bank = lane.cfg.per_bank;
            for i in 0..n {
                if rem[i] == 0 {
                    lane.ready.push(self.class[i], per_bank, i as u32, gates[i], 0);
                }
            }
            if n == 0 {
                lane.finished = true;
            }
        }

        // Global event-wheel clock: every lane is stepped at exactly the
        // cycles its own scalar run would visit, the shared trace data
        // stays hot across lanes working the same region of the DDG, and
        // the clock advances in O(next event) — a stepped lane re-arms
        // into the wheel and lanes not due at a cycle are never touched.
        let mut active: u64 = 0;
        for (l, lane) in lane_vec.iter().enumerate() {
            if !lane.finished {
                active |= 1u64 << l;
            }
        }
        let mut gcycle: u64 = 0;
        // every live lane's scalar run starts with a visit at cycle 0
        let mut due = active;
        while due != 0 {
            let mut m = due;
            while m != 0 {
                let l = m.trailing_zeros() as usize;
                m &= m - 1;
                let lane = &mut lane_vec[l];
                let rem = &mut remaining[l * n..(l + 1) * n];
                let subs = &mut subs_left[l * n..(l + 1) * n];
                lane.step(self, gates, rem, subs, alus, gcycle);
                if lane.finished {
                    active &= !(1u64 << l);
                } else {
                    wheel.insert(l, lane.next_visit, gcycle);
                }
            }
            if active == 0 {
                break;
            }
            let Some((next, d)) = wheel.pop_next(gcycle, lane_vec) else {
                break; // no events anywhere (every live lane is idle)
            };
            gcycle = next;
            due = d;
        }

        lane_vec
            .iter()
            .zip(designs)
            .map(|(lane, design)| self.compose_output(design, alus, lane.cycle, &lane.acc))
            .collect()
    }
}

/// Test seam for `tests/sched_props.rs` (`#[doc(hidden)]` — not API):
/// drive a [`ReadyQ`] and a scalar-engine `BinaryHeap` mirror through
/// the same randomized push / sync / pop / requeue script, respecting
/// the engine's usage contract (pushes at or after the clock, one sync
/// per visited cycle, requeue-then-stop on a stall, advance to the next
/// event), and return the two pop sequences. They must be identical —
/// that is the queue's exact-pop-order claim, under tie storms.
#[doc(hidden)]
pub fn readyq_heap_pop_orders(seed: u64, rounds: usize) -> (Vec<(u64, u32)>, Vec<(u64, u32)>) {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let mut q = ReadyQ::new();
    let mut h: Heap = Heap::new();
    let (mut qa, mut ha) = (Vec::new(), Vec::new());
    let mut now: u64 = 0;
    let mut next_id: u32 = 0;
    for _ in 0..rounds {
        // retire phase: a burst of pushes, mostly tied at `now` (the
        // storm), arriving in shuffled node order
        let burst = rng.below_usize(9);
        let mut ids: Vec<u32> = (next_id..next_id + burst as u32).collect();
        next_id += burst as u32;
        for i in (1..ids.len()).rev() {
            ids.swap(i, rng.below_usize(i + 1));
        }
        for id in ids {
            let at = match rng.below(4) {
                0 | 1 => now,
                2 => now + 1 + rng.below(4),
                _ => now + 10 + rng.below(100),
            };
            q.push(at, now, id);
            h.push(Reverse((at, id)));
        }
        q.sync(now);
        // issue phase: pop due events; sometimes re-queue the head like
        // a port-stalled memory op (and stop, as the issue loops do)
        for _ in 0..rng.below_usize(10) {
            let Some((rc, id)) = q.pop_due() else { break };
            qa.push((rc, id));
            if let Some(Reverse(e)) = h.pop() {
                ha.push(e);
            }
            if rng.below(8) == 0 {
                q.requeue_front(rc, id);
                h.push(Reverse((rc, id)));
                break;
            }
        }
        // advance like the engine: to the next event, at least one cycle
        let next = q.next_at();
        now = if next == u64::MAX { now + 1 } else { next.max(now + 1) };
    }
    // drain both queues to the end
    loop {
        q.sync(now);
        while let Some(e) = q.pop_due() {
            qa.push(e);
            if let Some(Reverse(e2)) = h.pop() {
                ha.push(e2);
            }
        }
        let next = q.next_at();
        if next == u64::MAX {
            break;
        }
        now = next.max(now + 1);
    }
    (qa, ha)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::MemKind;
    use crate::sched::build_memory_model;
    use crate::trace::{AluKind, Trace, TraceBuilder};

    fn chain_trace(n: usize) -> Trace {
        let mut b = TraceBuilder::new();
        let a = b.array("a", 4, 64);
        let mut prev: Option<u32> = None;
        for i in 0..n {
            if i % 5 == 0 {
                b.next_iter();
            }
            let id = match prev {
                Some(p) => b.alu(AluKind::FAdd, &[p]),
                None => b.load(a, (i % 64) as u32),
            };
            prev = Some(id);
        }
        b.finish()
    }

    /// Unit-to-unit reuse is allocation-exact: once the arena has seen
    /// its high-water (trace × lanes) footprint, later batches — same
    /// size, smaller, or a different trace — never regrow any buffer.
    #[test]
    fn reuse_is_allocation_exact_after_high_water() {
        let big = chain_trace(400);
        let small = chain_trace(40);
        let ct_big = CompiledTrace::new(&big, 8);
        let ct_small = CompiledTrace::new(&small, 8);
        let knobs = Knobs { unroll: 2, word_bytes: 8, alus: 4 };
        let designs: Vec<MemDesign> = [1u32, 2, 4, 8]
            .iter()
            .map(|&b| build_memory_model(&big, &*MemKind::Banked { banks: b }.model(), 8))
            .collect();

        let mut arena = BatchArena::new();
        // high-water pass, then record every buffer's capacity
        let _ = ct_big.simulate_batch(&mut arena, &knobs, &designs);
        let caps = (
            arena.lanes.capacity(),
            arena.remaining.capacity(),
            arena.subs_left.capacity(),
            arena.gates.capacity(),
        );
        // smaller trace, fewer lanes, then back to the high-water shape
        let _ = ct_small.simulate_batch(&mut arena, &knobs, &designs[..2]);
        let _ = ct_big.simulate_batch(&mut arena, &knobs, &designs);
        let after = (
            arena.lanes.capacity(),
            arena.remaining.capacity(),
            arena.subs_left.capacity(),
            arena.gates.capacity(),
        );
        assert_eq!(caps, after, "unit-to-unit reuse regrew an arena buffer");
    }

    /// The event wheel hands back due lanes in exactly the cycles their
    /// next_visit asks for, including far events parked past the window.
    #[test]
    fn event_wheel_pops_far_events_in_cycle_order() {
        let mut lanes: Vec<Lane> = (0..3).map(|_| Lane::new()).collect();
        let mut wheel = EventWheel::new();
        lanes[0].next_visit = 5;
        lanes[1].next_visit = WHEEL as u64 + 9; // beyond the first window
        lanes[2].next_visit = 5;
        for (l, lane) in lanes.iter().enumerate() {
            wheel.insert(l, lane.next_visit, 0);
        }
        assert_eq!(wheel.pop_next(0, &lanes), Some((5, 0b101)));
        assert_eq!(wheel.pop_next(5, &lanes), Some((WHEEL as u64 + 9, 0b010)));
        assert_eq!(wheel.pop_next(WHEEL as u64 + 9, &lanes), None);
    }
}
