//! Lane-batched simulation engine — layer 4 of the scheduler stack.
//!
//! A DSE sweep scores the *same* compiled trace against many memory
//! designs whose only differences are port counts, bank maps and access
//! costs. The scalar engine walks the full trace once per design point,
//! so a sweep re-traverses identical successor lists and re-pops
//! identical ready events for every point.
//! [`CompiledTrace::simulate_batch`] instead schedules up to L
//! *compatible* design points (same trace, same `word_bytes`; knobs
//! shared, ports/banking/model varying per lane) in ONE pass: the
//! trace-shaped work — iteration gates ([`BatchArena::gates`] is
//! computed once per call), node classes, sub-word decomposition,
//! successor lists — is shared across lanes marching in cache-friendly
//! lockstep, while the design-dependent port-arbitration step
//! ([`CompiledTrace::try_mem`]) diverges per lane.
//!
//! The per-lane event machinery also drops the scalar engine's five
//! `BinaryHeap`s for [`ReadyQ`]s — cycle-indexed ready queues whose
//! common case (a successor becoming ready at the cycle being retired)
//! is an O(1) push and whose pops come off a pre-sorted list, reserving
//! the heap for the rare far-future iteration-gate events.
//!
//! **Bit-identity contract**: every lane must produce the exact
//! [`SimOutput`] the scalar [`CompiledTrace::simulate`] produces for
//! that design (`PartialEq`, no tolerance) — the scalar engine stays
//! the oracle. Each lane therefore runs the scalar state machine
//! unmodified: a lane is stepped only at the cycles its own advance
//! rule would visit (skipped cycles touch no lane state, so skipping is
//! exact), every step executes the scalar phase order — retire, reg
//! drain, FU issue, memory issue, advance — and the port arbitration
//! and physical composition are the *same functions* the scalar engine
//! calls ([`CompiledTrace::try_mem`] /
//! [`CompiledTrace::compose_output`]). The [`ReadyQ`] preserves the
//! heaps' exact `(ready_cycle, node)` pop order (keys are unique per
//! queue, so heap order is fully determined by the key set).
//! `tests/engine_golden.rs` pins the contract across all suite
//! benchmarks × mixed model families; `tests/sched_props.rs` fuzzes it
//! over random traces × random lane mixes.

use super::arena::{Heap, RING};
use super::compile::{Accum, CompiledTrace, MemIssue, NodeClass, PortCfg};
use super::{Knobs, SimOutput};
use crate::mem::MemDesign;
use crate::trace::OpKind;
use std::cmp::Reverse;
use std::collections::VecDeque;

/// A cycle-aware ready queue with the scalar heap's exact pop order —
/// ascending `(ready_cycle, node)` — but O(1) for the dominant flows:
/// same-cycle wakeups append to a scratch list and pops read a
/// pre-sorted deque; only far-future events (iteration gates ahead of
/// the clock) pay heap costs.
///
/// Ordering invariant for `due`: leftover entries (ready at some
/// earlier visited cycle) precede newly matured ones (which mature in
/// ascending heap order at strictly later cycles), so `due` is always
/// sorted by `(ready_cycle, node)` and `pop_due` replays the heap's
/// order exactly.
struct ReadyQ {
    /// Events ready at or before the last synced cycle, in pop order.
    due: VecDeque<(u64, u32)>,
    /// Events pushed at exactly the cycle being processed (a successor
    /// freed by a completion this cycle — the common case).
    today: Vec<u32>,
    /// Far events, keyed `(ready_cycle, node)` like the scalar heaps.
    fut: Heap,
    /// Scratch: heap events maturing exactly at the syncing cycle.
    tmp: Vec<u32>,
    /// Total queued events across `due`/`today`/`fut`.
    len: usize,
}

impl ReadyQ {
    fn new() -> ReadyQ {
        ReadyQ {
            due: VecDeque::new(),
            today: Vec::new(),
            fut: Heap::new(),
            tmp: Vec::new(),
            len: 0,
        }
    }

    fn clear(&mut self) {
        self.due.clear();
        self.today.clear();
        self.fut.clear();
        self.tmp.clear();
        self.len = 0;
    }

    /// Queue `nid` to become ready at cycle `at` (`at >= now` always:
    /// seed and retire pushes never target the past).
    #[inline]
    fn push(&mut self, at: u64, now: u64, nid: u32) {
        if at <= now {
            self.today.push(nid);
        } else {
            self.fut.push(Reverse((at, nid)));
        }
        self.len += 1;
    }

    /// Fold matured events into `due`, preserving `(cycle, node)`
    /// order. Called once per visited cycle, after the retire phase
    /// (the only pusher) and before any pop.
    fn sync(&mut self, now: u64) {
        if self.len == 0 {
            return;
        }
        while let Some(&Reverse((rc, _))) = self.fut.peek() {
            if rc > now {
                break;
            }
            let Reverse((rc, nid)) = self.fut.pop().unwrap();
            if rc < now {
                // matured between visits: pops ascending, all later than
                // any leftover already in `due`
                self.due.push_back((rc, nid));
            } else {
                self.tmp.push(nid);
            }
        }
        if self.today.is_empty() && self.tmp.is_empty() {
            return;
        }
        // merge the two node-ascending runs ready at exactly `now` (a
        // node is queued at most once, so the runs never share an id)
        self.today.sort_unstable();
        let (mut i, mut j) = (0usize, 0usize);
        while i < self.tmp.len() && j < self.today.len() {
            if self.tmp[i] < self.today[j] {
                self.due.push_back((now, self.tmp[i]));
                i += 1;
            } else {
                self.due.push_back((now, self.today[j]));
                j += 1;
            }
        }
        for &nid in &self.tmp[i..] {
            self.due.push_back((now, nid));
        }
        for &nid in &self.today[j..] {
            self.due.push_back((now, nid));
        }
        self.tmp.clear();
        self.today.clear();
    }

    /// Pop the next matured event (everything in `due` is ready at the
    /// current cycle by construction).
    #[inline]
    fn pop_due(&mut self) -> Option<(u64, u32)> {
        let e = self.due.pop_front()?;
        self.len -= 1;
        Some(e)
    }

    /// Re-queue a popped-but-stalled op under its ORIGINAL key. It was
    /// the queue minimum when popped, so the front keeps exact order —
    /// the scalar engine's `push(Reverse((rc0, nid)))` equivalent.
    #[inline]
    fn requeue_front(&mut self, rc0: u64, nid: u32) {
        self.due.push_front((rc0, nid));
        self.len += 1;
    }

    /// Earliest queued event, `u64::MAX` when empty — the scalar
    /// engine's heap peek for the advance step. (`today` is always
    /// empty by advance time: only the retire phase feeds it and `sync`
    /// drains it.)
    #[inline]
    fn next_at(&self) -> u64 {
        let d = self.due.front().map_or(u64::MAX, |&(rc, _)| rc);
        let f = self.fut.peek().map_or(u64::MAX, |&Reverse((rc, _))| rc);
        d.min(f)
    }
}

/// The five per-class ready queues of one lane (mirrors `SimArena`'s
/// heap quintet; which memory queue is live depends on the lane's
/// banked-vs-true-port split).
struct ReadySet {
    reg: ReadyQ,
    alu: ReadyQ,
    mem: ReadyQ,
    rd: ReadyQ,
    wr: ReadyQ,
}

impl ReadySet {
    fn new() -> ReadySet {
        ReadySet {
            reg: ReadyQ::new(),
            alu: ReadyQ::new(),
            mem: ReadyQ::new(),
            rd: ReadyQ::new(),
            wr: ReadyQ::new(),
        }
    }

    fn clear(&mut self) {
        self.reg.clear();
        self.alu.clear();
        self.mem.clear();
        self.rd.clear();
        self.wr.clear();
    }

    /// Route one ready node to its class queue — the scalar engine's
    /// `push_ready!` with the per-lane port split made explicit.
    #[inline]
    fn push(&mut self, class: NodeClass, per_bank: bool, nid: u32, at: u64, now: u64) {
        match class {
            NodeClass::Alu => self.alu.push(at, now, nid),
            NodeClass::Reg => self.reg.push(at, now, nid),
            NodeClass::Load => {
                if per_bank {
                    self.mem.push(at, now, nid);
                } else {
                    self.rd.push(at, now, nid);
                }
            }
            NodeClass::Store => {
                if per_bank {
                    self.mem.push(at, now, nid);
                } else {
                    self.wr.push(at, now, nid);
                }
            }
        }
    }

    fn sync(&mut self, now: u64) {
        self.reg.sync(now);
        self.alu.sync(now);
        self.mem.sync(now);
        self.rd.sync(now);
        self.wr.sync(now);
    }

    /// Earliest ready event across every queue.
    fn next_at(&self) -> u64 {
        self.reg
            .next_at()
            .min(self.alu.next_at())
            .min(self.mem.next_at())
            .min(self.rd.next_at())
            .min(self.wr.next_at())
    }
}

/// One lane's private scheduling state: everything of the scalar
/// engine's per-run state that is design-dependent. The trace-shaped
/// halves (`remaining`, `subs_left`, iteration gates) live lane-major
/// in the [`BatchArena`].
struct Lane {
    ready: ReadySet,
    /// Completion ring, `RING` slots indexed `cycle % RING` (same
    /// schema as `SimArena::ring`, but per lane — each lane's retire
    /// set and ring scan must match its own scalar run exactly).
    ring: Vec<Vec<u32>>,
    ring_pending: usize,
    retire_buf: Vec<u32>,
    used_rd: Vec<u32>,
    used_wr: Vec<u32>,
    cfg: PortCfg,
    acc: Accum,
    /// Last cycle this lane was stepped at (the scalar engine's clock).
    cycle: u64,
    /// Next cycle this lane's advance rule wants to visit.
    next_visit: u64,
    done: usize,
    finished: bool,
}

impl Lane {
    fn new() -> Lane {
        Lane {
            ready: ReadySet::new(),
            ring: vec![Vec::new(); RING],
            ring_pending: 0,
            retire_buf: Vec::new(),
            used_rd: Vec::new(),
            used_wr: Vec::new(),
            cfg: PortCfg::default(),
            acc: Accum::default(),
            cycle: 0,
            next_visit: 0,
            done: 0,
            finished: false,
        }
    }

    /// Re-arm for a new batch, keeping allocations (dirty reuse across
    /// traces and lane mixes is part of the contract, like
    /// `SimArena::reset`).
    fn reset(&mut self) {
        self.ready.clear();
        for slot in &mut self.ring {
            slot.clear();
        }
        self.ring_pending = 0;
        self.retire_buf.clear();
        self.acc = Accum::default();
        self.cycle = 0;
        self.next_visit = 0;
        self.done = 0;
        self.finished = false;
    }

    /// Run ONE cycle of this lane's scalar state machine — the exact
    /// phase order of `CompiledTrace::simulate` — then compute the
    /// lane's next visit cycle via the scalar advance rule. Marks the
    /// lane finished when its DDG drains (or when no events remain).
    fn step(
        &mut self,
        ct: &CompiledTrace<'_>,
        gates: &[u64],
        rem: &mut [u32],
        subs: &mut [u32],
        alus: u32,
        now: u64,
    ) {
        let Lane {
            ready,
            ring,
            ring_pending,
            retire_buf,
            used_rd,
            used_wr,
            cfg,
            acc,
            cycle,
            next_visit,
            done,
            finished,
        } = self;
        let cfg = *cfg;
        *cycle = now;
        let n = ct.trace.len();

        // retire completions for this cycle
        let slot = (now % RING as u64) as usize;
        if !ring[slot].is_empty() {
            retire_buf.clear();
            retire_buf.append(&mut ring[slot]);
            *ring_pending -= retire_buf.len();
            *done += retire_buf.len();
            for &node in retire_buf.iter() {
                for &s in ct.trace.successors(node) {
                    rem[s as usize] -= 1;
                    if rem[s as usize] == 0 {
                        // producer completes at the start of this cycle,
                        // so the consumer may issue this cycle
                        let si = s as usize;
                        ready.push(ct.class[si], cfg.per_bank, s, gates[si].max(now), now);
                    }
                }
            }
        }
        ready.sync(now);

        macro_rules! complete_at {
            ($cycle:expr, $nid:expr) => {{
                ring[($cycle % RING as u64) as usize].push($nid);
                *ring_pending += 1;
            }};
        }

        // reset per-cycle port + FU counters
        let mut st = MemIssue {
            used_rd: used_rd.as_mut_slice(),
            used_wr: used_wr.as_mut_slice(),
            subs_left: subs,
            n_reads: &mut acc.n_reads,
            n_writes: &mut acc.n_writes,
            port_stalls: &mut acc.port_stalls,
            issued_mem: &mut acc.issued_mem,
        };
        for c in st.used_rd.iter_mut() {
            *c = 0;
        }
        for c in st.used_wr.iter_mut() {
            *c = 0;
        }
        let mut alu_slots = alus;
        let mut had_mem_stall = false;

        // register-promoted accesses are free: drain them all
        while let Some((_, nid)) = ready.reg.pop_due() {
            *st.issued_mem += 1;
            acc.n_reg += 1;
            complete_at!(now + 1, nid);
        }

        // FU issue: stop the moment slots run out
        while alu_slots > 0 {
            let Some((_, nid)) = ready.alu.pop_due() else { break };
            let OpKind::Alu(kind) = ct.trace.nodes[nid as usize].kind else { unreachable!() };
            alu_slots -= 1;
            acc.n_alu_energy += kind.energy_pj() as f64;
            complete_at!(now + kind.latency() as u64, nid);
        }

        if cfg.per_bank {
            // banked: in-order issue, first conflict stalls the rest
            while let Some((rc0, nid)) = ready.mem.pop_due() {
                let left = ct.try_mem(nid, &cfg, &mut st);
                if left > 0 {
                    had_mem_stall = true;
                    ready.mem.requeue_front(rc0, nid);
                    break;
                }
                complete_at!(now + 1, nid);
            }
        } else {
            // true multi-port: reads and writes issue independently
            while st.used_rd[0] < cfg.rd_ports {
                let Some((rc0, nid)) = ready.rd.pop_due() else { break };
                let left = ct.try_mem(nid, &cfg, &mut st);
                if left > 0 {
                    had_mem_stall = true;
                    ready.rd.requeue_front(rc0, nid);
                    break;
                }
                complete_at!(now + 1, nid);
            }
            while st.used_wr[0] < cfg.wr_ports {
                let Some((rc0, nid)) = ready.wr.pop_due() else { break };
                let left = ct.try_mem(nid, &cfg, &mut st);
                if left > 0 {
                    had_mem_stall = true;
                    ready.wr.requeue_front(rc0, nid);
                    break;
                }
                complete_at!(now + 1, nid);
            }
        }
        if had_mem_stall {
            acc.stall_cycles += 1;
        }

        // advance to this lane's next event
        let mut next = ready.next_at();
        if *ring_pending > 0 {
            for d in 1..=RING as u64 {
                if !ring[((now + d) % RING as u64) as usize].is_empty() {
                    next = next.min(now + d);
                    break;
                }
            }
        }
        if *done >= n || next == u64::MAX {
            *finished = true;
        } else {
            *next_visit = next.max(now + 1);
        }
    }
}

/// Struct-of-arrays scratch state for [`CompiledTrace::simulate_batch`]:
/// the trace-shaped counters are lane-major flat vectors (lane `l` owns
/// `[l*n, (l+1)*n)`), the iteration gates are computed once and shared
/// by every lane, and the design-dependent event state is per [`Lane`].
/// Like `SimArena`, an arena may be dirty from ANY previous batch —
/// `simulate_batch` resets it allocation-preservingly.
pub struct BatchArena {
    lanes: Vec<Lane>,
    /// Lane-major unsatisfied-predecessor counts.
    remaining: Vec<u32>,
    /// Lane-major outstanding sub-word accesses per node.
    subs_left: Vec<u32>,
    /// Shared per-batch iteration gates: `node.iter / unroll`, computed
    /// once for all lanes (knobs are batch-uniform).
    gates: Vec<u64>,
}

impl BatchArena {
    /// A fresh (empty) arena; lanes and counters are sized lazily by
    /// the first `simulate_batch` call.
    pub fn new() -> BatchArena {
        BatchArena {
            lanes: Vec::new(),
            remaining: Vec::new(),
            subs_left: Vec::new(),
            gates: Vec::new(),
        }
    }

    /// Re-arm for `lanes` lanes over `ct`, keeping allocations.
    fn reset(&mut self, ct: &CompiledTrace<'_>, unroll: u32, lanes: usize) {
        if self.lanes.len() < lanes {
            self.lanes.resize_with(lanes, Lane::new);
        }
        self.gates.clear();
        self.gates.extend(ct.trace.nodes.iter().map(|nd| (nd.iter / unroll) as u64));
        self.remaining.clear();
        self.subs_left.clear();
        for _ in 0..lanes {
            self.remaining.extend_from_slice(&ct.trace.pred_count);
            self.subs_left.extend_from_slice(&ct.subs_init);
        }
        for lane in &mut self.lanes[..lanes] {
            lane.reset();
        }
    }
}

impl Default for BatchArena {
    fn default() -> Self {
        BatchArena::new()
    }
}

impl<'t> CompiledTrace<'t> {
    /// Schedule up to L compatible design points in one pass over the
    /// trace: `designs[l]` becomes lane `l`, and the result vector
    /// matches the input order. All lanes share this compiled trace and
    /// `knobs` (`knobs.word_bytes` must match the compiled word size);
    /// ports, banking and model vary freely per lane.
    ///
    /// Bit-identical to running [`CompiledTrace::simulate`] per design:
    /// each lane advances by its own scalar event rule on a global
    /// lockstep clock (the global cycle is the min over active lanes'
    /// next events, and only lanes due at that cycle are stepped — a
    /// skipped cycle would have been a no-op for the lane anyway).
    pub fn simulate_batch(
        &self,
        arena: &mut BatchArena,
        knobs: &Knobs,
        designs: &[MemDesign],
    ) -> Vec<SimOutput> {
        debug_assert_eq!(
            knobs.word_bytes.max(1),
            self.word_bytes,
            "CompiledTrace built for word_bytes={}, knobs ask {}",
            self.word_bytes,
            knobs.word_bytes
        );
        let lanes = designs.len();
        if lanes == 0 {
            return Vec::new();
        }
        let n = self.trace.len();
        let unroll = knobs.unroll.max(1);
        let alus = knobs.alus.max(1);

        arena.reset(self, unroll, lanes);
        let BatchArena { lanes: lane_vec, remaining, subs_left, gates } = arena;
        let lane_vec = &mut lane_vec[..lanes];
        let gates = &gates[..];

        // per-lane port config + counters + ready seed
        for (l, lane) in lane_vec.iter_mut().enumerate() {
            lane.cfg = PortCfg::of(&designs[l]);
            let counters = lane.cfg.counters();
            lane.used_rd.clear();
            lane.used_rd.resize(counters, 0);
            lane.used_wr.clear();
            lane.used_wr.resize(counters, 0);
            let rem = &remaining[l * n..(l + 1) * n];
            let per_bank = lane.cfg.per_bank;
            for i in 0..n {
                if rem[i] == 0 {
                    lane.ready.push(self.class[i], per_bank, i as u32, gates[i], 0);
                }
            }
            if n == 0 {
                lane.finished = true;
            }
        }

        // Global lockstep clock: every lane is stepped at exactly the
        // cycles its own scalar run would visit; the shared trace data
        // stays hot across lanes working the same region of the DDG.
        let mut active = lane_vec.iter().filter(|l| !l.finished).count();
        let mut gcycle: u64 = 0;
        while active > 0 {
            let mut next_g = u64::MAX;
            for (l, lane) in lane_vec.iter_mut().enumerate() {
                if lane.finished {
                    continue;
                }
                if lane.next_visit > gcycle {
                    next_g = next_g.min(lane.next_visit);
                    continue;
                }
                let rem = &mut remaining[l * n..(l + 1) * n];
                let subs = &mut subs_left[l * n..(l + 1) * n];
                lane.step(self, gates, rem, subs, alus, gcycle);
                if lane.finished {
                    active -= 1;
                } else {
                    next_g = next_g.min(lane.next_visit);
                }
            }
            if next_g == u64::MAX {
                break; // no events anywhere (or every lane drained)
            }
            gcycle = next_g;
        }

        lane_vec
            .iter()
            .zip(designs)
            .map(|(lane, design)| self.compose_output(design, alus, lane.cycle, &lane.acc))
            .collect()
    }
}
