//! Aladdin-style resource-constrained cycle-accurate scheduler.
//!
//! Takes a benchmark's dynamic DDG ([`crate::trace::Trace`]) and a design
//! configuration, and schedules every node under:
//!
//! * **dependences** — a node issues only after all its DDG predecessors
//!   complete;
//! * **loop control** — with unrolling factor `U`, iteration group
//!   `g = iter / U` cannot begin before cycle `g` (the index-increment
//!   chain Aladdin materializes when it unrolls a loop `U`-wide);
//! * **functional units** — at most `alus` ALU ops issue per cycle;
//! * **memory ports** — the design's [`crate::mem::PortModel`]: per-bank
//!   ports with conflict serialization for banked scratchpads, global
//!   conflict-free ports for AMMs / multipumping.
//!
//! The output combines the cycle count with the design's physical cost
//! (area, power, clock period) exactly as Aladdin's backend does
//! (paper §III-B/§III-C).
//!
//! ## Layering (sweep-aware engine)
//!
//! The scheduler is split into layers so Cartesian sweeps never repeat
//! `(trace, word_bytes)`-invariant work:
//!
//! 1. [`compile`] — [`CompiledTrace`] precomputes, once per word size,
//!    everything the inner loop consumes: promotion mask, sub-word
//!    counts, word indices, per-node resource class, FU-mix blend,
//!    footprint depth.
//! 2. [`arena`] — [`SimArena`] owns the mutable run state (ready heaps,
//!    completion ring, dependence/sub-access counters) and is `reset()`
//!    between runs instead of reallocated; one arena per worker thread.
//! 3. the scalar engine — [`CompiledTrace::simulate`] schedules one
//!    design point against an arena. It is the correctness oracle.
//! 4. [`batch`] — [`CompiledTrace::simulate_batch`] schedules up to
//!    [`crate::dse::MAX_LANES`] compatible design points (same trace /
//!    word size / knobs; ports, banking and model varying per lane) in
//!    ONE pass over the trace, against a lane-major [`BatchArena`].
//!    The v2 kernel advances a global event wheel + active-lane bitmask
//!    instead of scanning lanes per step, and routes memory ops through
//!    tables precompiled on the [`CompiledTrace`]; still bit-identical
//!    to the scalar engine per lane.
//!
//! [`simulate`] and [`simulate_design`] remain as compat wrappers
//! (compile + fresh arena per call) with byte-identical [`SimOutput`];
//! sweep layers ([`crate::dse`], [`crate::coordinator`]) drive the
//! engines directly, grouping compatible points into lane sets.

pub mod arena;
pub mod batch;
pub mod compile;

pub use arena::SimArena;
pub use batch::BatchArena;
#[doc(hidden)]
pub use batch::readyq_heap_pop_orders;
pub use compile::{CompiledTrace, ENGINE_VERSION};

use crate::mem::{MemDesign, MemKind, MemModel};
use crate::trace::Trace;

/// One point in the design space (the paper's sweep axes, §IV-A).
///
/// Compat value type for the built-in [`MemKind`] organizations. The
/// scheduler itself is memory-model-agnostic: it consumes a pre-built
/// [`MemDesign`] plus [`Knobs`], so registry-extension models run
/// through [`simulate_design`] without ever constructing a `MemKind`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DesignConfig {
    /// Memory organization.
    pub mem: MemKind,
    /// Loop unrolling factor (≥1).
    pub unroll: u32,
    /// Scratchpad word size in bytes (the paper's word-size axis).
    pub word_bytes: u32,
    /// ALU issue slots per cycle.
    pub alus: u32,
}

impl DesignConfig {
    /// A minimal single-port baseline.
    pub fn baseline() -> Self {
        DesignConfig { mem: MemKind::Banked { banks: 1 }, unroll: 1, word_bytes: 8, alus: 2 }
    }

    /// The memory-agnostic scheduling knobs of this configuration.
    pub fn knobs(&self) -> Knobs {
        Knobs { unroll: self.unroll, word_bytes: self.word_bytes, alus: self.alus }
    }
}

/// The non-memory sweep axes: everything the scheduler needs besides the
/// built [`MemDesign`] itself.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Knobs {
    /// Loop unrolling factor (≥1).
    pub unroll: u32,
    /// Scratchpad word size in bytes.
    pub word_bytes: u32,
    /// ALU issue slots per cycle.
    pub alus: u32,
}

impl Default for Knobs {
    fn default() -> Self {
        Knobs { unroll: 1, word_bytes: 8, alus: 2 }
    }
}

/// Scheduling + costing result for one design point.
///
/// `PartialEq` is bit-exact — the engine-vs-compat golden tests compare
/// whole outputs with `==`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SimOutput {
    /// Total cycles to drain the DDG.
    pub cycles: u64,
    /// Clock period, ns (max of base 1 ns and the memory path, times the
    /// multipumping frequency degradation).
    pub period_ns: f32,
    /// Execution time, ns.
    pub time_ns: f64,
    /// Memory area, µm².
    pub mem_area_um2: f32,
    /// Functional-unit area, µm².
    pub fu_area_um2: f32,
    /// Total area, µm².
    pub area_um2: f32,
    /// Average power, mW (dynamic + leakage).
    pub power_mw: f32,
    /// Dynamic energy, pJ.
    pub dyn_energy_pj: f64,
    /// Memory accesses that were issued.
    pub mem_accesses: u64,
    /// Accesses that had to retry due to port conflicts (banked designs).
    pub port_stalls: u64,
    /// Cycles in which at least one ready mem op could not issue.
    pub stall_cycles: u64,
}

/// Base accelerator clock: 1 GHz (1 ns) — Aladdin's default design clock.
pub const BASE_PERIOD_NS: f32 = 1.0;

/// Arrays at or below this footprint are *completely partitioned* into
/// registers (Aladdin's `partition,complete` directive applied to small
/// arrays — lookup tables, failure tables, filter taps). Register
/// accesses bypass the scratchpad ports entirely.
pub const REG_PROMOTE_BYTES: u64 = 64;

/// Register-file access energy, pJ (flop read/write at 45 nm).
const REG_ACCESS_PJ: f64 = 0.018;

/// Which arrays are register-promoted for this trace.
pub fn promoted_arrays(trace: &Trace) -> Vec<bool> {
    trace.arrays.iter().map(|a| a.bytes() <= REG_PROMOTE_BYTES).collect()
}

/// Fraction of FU area counted as leakage, µW per µm² (45 nm HVT logic).
const FU_LEAK_UW_PER_UM2: f32 = 0.012;

/// Schedule `trace` under `cfg`, returning cycles + physical cost.
pub fn simulate(trace: &Trace, cfg: &DesignConfig) -> SimOutput {
    let design = build_memory(trace, cfg);
    simulate_design(trace, &cfg.knobs(), &design)
}

/// Scratchpad depth (words) needed to hold every non-promoted traced
/// array at the given word size.
pub fn footprint_depth(trace: &Trace, word_bytes: u32) -> u32 {
    let word_bytes = word_bytes.max(1);
    let promoted = promoted_arrays(trace);
    let total_bytes: u64 = trace
        .arrays
        .iter()
        .zip(&promoted)
        .filter(|(_, &p)| !p)
        .map(|(a, _)| a.bytes())
        .sum();
    (total_bytes.div_ceil(word_bytes as u64)).max(4) as u32
}

/// Build the memory design implied by `cfg` for this trace: the
/// scratchpad must hold every traced array at the configured word size.
pub fn build_memory(trace: &Trace, cfg: &DesignConfig) -> MemDesign {
    let word_bytes = cfg.word_bytes.max(1);
    cfg.mem.build(footprint_depth(trace, word_bytes), word_bytes * 8)
}

/// Trait-object flavor of [`build_memory`]: size the scratchpad for
/// `trace` and build it with any registered memory model.
pub fn build_memory_model(trace: &Trace, model: &dyn MemModel, word_bytes: u32) -> MemDesign {
    DesignBuilder::new(trace).build(model, word_bytes)
}

/// Builds sized memory designs for one trace, memoizing the footprint
/// depth per word size — the single home of the "clamp word, depth from
/// footprint, width = word × 8" sizing rule. Sweep loops
/// ([`crate::dse::run_points`], the coordinator) hold one of these so
/// the depth is computed once per word size, not once per design point;
/// [`build_memory_model`] is the one-shot flavor.
pub struct DesignBuilder<'t> {
    trace: &'t Trace,
    depth_for: std::collections::HashMap<u32, u32>,
}

impl<'t> DesignBuilder<'t> {
    /// A builder with an empty depth cache.
    pub fn new(trace: &'t Trace) -> Self {
        DesignBuilder { trace, depth_for: std::collections::HashMap::new() }
    }

    /// Build `model`'s fully-costed design at `word_bytes` (clamped to
    /// ≥ 1 B), sized to hold every non-promoted traced array.
    pub fn build(&mut self, model: &dyn MemModel, word_bytes: u32) -> MemDesign {
        let wb = word_bytes.max(1);
        let depth =
            *self.depth_for.entry(wb).or_insert_with(|| footprint_depth(self.trace, wb));
        model.build(depth, wb * 8)
    }
}

/// Area of the register file holding the promoted arrays, µm².
pub fn promoted_reg_area(trace: &Trace) -> f32 {
    let bits: u64 = trace
        .arrays
        .iter()
        .filter(|a| a.bytes() <= REG_PROMOTE_BYTES)
        .map(|a| a.bytes() * 8)
        .sum();
    bits as f32 * crate::synth::cal::FF_GE * crate::synth::cal::GATE_UM2
}

/// Schedule with an explicit, pre-built memory design (compat wrapper;
/// `cfg.mem` is ignored — the design rules).
pub fn simulate_with_design(trace: &Trace, cfg: &DesignConfig, design: &MemDesign) -> SimOutput {
    simulate_design(trace, &cfg.knobs(), design)
}

/// Schedule with an explicit, pre-built memory design and the non-memory
/// knobs (lets the coordinator inject PJRT-evaluated costs, and lets
/// registry-extension models run without a [`MemKind`]).
///
/// Compat wrapper: compiles the trace and allocates a fresh arena per
/// call. Sweeps should compile once per word size and reuse one
/// [`SimArena`] per worker via [`CompiledTrace::simulate`] — this
/// wrapper's output is byte-identical, just slower across many points.
pub fn simulate_design(trace: &Trace, knobs: &Knobs, design: &MemDesign) -> SimOutput {
    CompiledTrace::new(trace, knobs.word_bytes).simulate(&mut SimArena::new(), knobs, design)
}

/// FU area for `alus` issue slots: blended over the trace's op mix (an
/// `alus`-wide datapath provisioned proportionally to what the kernel
/// actually executes). Reads the op-mix counts cached on the trace at
/// build time — O(8), not O(nodes × 8).
pub fn fu_area(trace: &Trace, alus: u32) -> f32 {
    let counts = &trace.alu_kind_counts;
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let blended: f32 = crate::trace::AluKind::ALL
        .iter()
        .enumerate()
        .map(|(i, k)| k.fu_area_um2() * (counts[i] as f64 / total as f64) as f32)
        .sum();
    blended * alus as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suite::{self, Scale};
    use crate::trace::{AluKind, TraceBuilder};

    fn chain_trace(len: u32) -> Trace {
        // serial dependence chain of IntAdds: cycles == len
        let mut b = TraceBuilder::new();
        let _a = b.array("a", 4, 4);
        let mut prev = b.alu(AluKind::IntAdd, &[]);
        for _ in 1..len {
            prev = b.alu(AluKind::IntAdd, &[prev]);
        }
        b.finish()
    }

    #[test]
    fn serial_chain_takes_len_cycles() {
        let t = chain_trace(100);
        let out = simulate(&t, &DesignConfig::baseline());
        assert_eq!(out.cycles, 100);
    }

    #[test]
    fn parallel_ops_bounded_by_alus() {
        // 64 independent IntAdds, 4 ALUs → 16 cycles.
        let mut b = TraceBuilder::new();
        let _ = b.array("a", 4, 4);
        for _ in 0..64 {
            b.alu(AluKind::IntAdd, &[]);
        }
        let t = b.finish();
        let cfg = DesignConfig { alus: 4, ..DesignConfig::baseline() };
        let out = simulate(&t, &cfg);
        assert_eq!(out.cycles, 16);
    }

    #[test]
    fn single_port_serializes_parallel_loads() {
        // 32 independent loads of distinct addresses in one bank.
        let mut b = TraceBuilder::new();
        let a = b.array("a", 4, 64);
        for i in 0..32 {
            b.load(a, i);
        }
        let t = b.finish();
        let single = simulate(&t, &DesignConfig::baseline());
        assert_eq!(single.cycles, 32);
        // 4R AMM: 8 cycles.
        let amm = DesignConfig {
            mem: MemKind::XorAmm { read_ports: 4, write_ports: 1 },
            ..DesignConfig::baseline()
        };
        let out = simulate(&t, &amm);
        assert_eq!(out.cycles, 8);
        assert!(single.stall_cycles > 0, "single-port run must report stalls");
    }

    #[test]
    fn banking_helps_only_without_conflicts() {
        // Loads with stride 4 over 4 banks (word=4B): all hit bank 0 →
        // banking gives no speedup; an AMM does.
        let mut b = TraceBuilder::new();
        let a = b.array("a", 4, 256);
        for i in 0..32 {
            b.load(a, i * 4);
        }
        let t = b.finish();
        let banked = DesignConfig {
            mem: MemKind::Banked { banks: 4 },
            word_bytes: 4,
            ..DesignConfig::baseline()
        };
        let conflicted = simulate(&t, &banked);
        assert_eq!(conflicted.cycles, 32, "stride-4 over 4 banks must serialize");
        let stride1 = {
            let mut b = TraceBuilder::new();
            let a = b.array("a", 4, 256);
            for i in 0..32 {
                b.load(a, i);
            }
            b.finish()
        };
        let spread = simulate(&stride1, &banked);
        assert_eq!(spread.cycles, 8, "stride-1 over 4 banks runs 4-wide");
    }

    #[test]
    fn unroll_gates_iteration_groups() {
        // 64 independent loads, one per iteration, unroll=1 → ≥64 cycles
        // even on a wide AMM (loop control serializes).
        let mut b = TraceBuilder::new();
        let a = b.array("a", 4, 64);
        for i in 0..64 {
            b.load(a, i);
            b.next_iter();
        }
        let t = b.finish();
        let amm = DesignConfig {
            mem: MemKind::XorAmm { read_ports: 4, write_ports: 2 },
            unroll: 1,
            ..DesignConfig::baseline()
        };
        assert!(simulate(&t, &amm).cycles >= 64);
        let amm8 = DesignConfig { unroll: 8, ..amm };
        assert!(simulate(&t, &amm8).cycles <= 17);
    }

    #[test]
    fn multipump_trades_cycles_for_period() {
        let mut b = TraceBuilder::new();
        let a = b.array("a", 4, 64);
        for i in 0..32 {
            b.load(a, i);
        }
        let t = b.finish();
        let pump = DesignConfig { mem: MemKind::MultiPump { factor: 2 }, ..DesignConfig::baseline() };
        let out = simulate(&t, &pump);
        assert_eq!(out.cycles, 16, "2 pseudo-ports");
        let single = simulate(&t, &DesignConfig::baseline());
        // but the external clock runs 2× slower → no net time win
        assert!(out.time_ns >= single.time_ns * 0.95);
    }

    #[test]
    fn engine_with_reused_arena_matches_compat() {
        let wl = suite::generate("gemm", Scale::Tiny);
        let cfg = DesignConfig { unroll: 8, alus: 8, ..DesignConfig::baseline() };
        let design = build_memory(&wl.trace, &cfg);
        let compat = simulate(&wl.trace, &cfg);
        let ct = CompiledTrace::new(&wl.trace, cfg.word_bytes);
        let mut arena = SimArena::new();
        for round in 0..3 {
            let out = ct.simulate(&mut arena, &cfg.knobs(), &design);
            assert_eq!(out, compat, "round {round}");
        }
        assert_eq!(ct.depth(), footprint_depth(&wl.trace, cfg.word_bytes));
        assert_eq!(ct.fu_area(8), fu_area(&wl.trace, 8));
    }

    #[test]
    fn real_benchmarks_schedule_and_cost() {
        for name in ["gemm", "fft", "kmp"] {
            let wl = suite::generate(name, Scale::Tiny);
            let out = simulate(&wl.trace, &DesignConfig::baseline());
            assert!(out.cycles >= wl.trace.critical_path_len() as u64 / 2, "{name}");
            assert!(out.area_um2 > 0.0, "{name}");
            assert!(out.power_mw > 0.0, "{name}");
            assert!(out.time_ns > 0.0, "{name}");
            // every memory op must be issued exactly once
            assert_eq!(out.mem_accesses, wl.trace.mem_ops() as u64, "{name}");
        }
    }

    #[test]
    fn more_ports_never_slower() {
        let wl = suite::generate("gemm", Scale::Tiny);
        let mut prev = u64::MAX;
        for r in [1u32, 2, 4, 8] {
            let cfg = DesignConfig {
                mem: MemKind::LvtAmm { read_ports: r, write_ports: 1 },
                unroll: 8,
                alus: 8,
                ..DesignConfig::baseline()
            };
            let out = simulate(&wl.trace, &cfg);
            assert!(out.cycles <= prev, "r={r}: {} > {}", out.cycles, prev);
            prev = out.cycles;
        }
    }
}
