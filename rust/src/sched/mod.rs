//! Aladdin-style resource-constrained cycle-accurate scheduler.
//!
//! Takes a benchmark's dynamic DDG ([`crate::trace::Trace`]) and a design
//! configuration, and schedules every node under:
//!
//! * **dependences** — a node issues only after all its DDG predecessors
//!   complete;
//! * **loop control** — with unrolling factor `U`, iteration group
//!   `g = iter / U` cannot begin before cycle `g` (the index-increment
//!   chain Aladdin materializes when it unrolls a loop `U`-wide);
//! * **functional units** — at most `alus` ALU ops issue per cycle;
//! * **memory ports** — the design's [`crate::mem::PortModel`]: per-bank
//!   ports with conflict serialization for banked scratchpads, global
//!   conflict-free ports for AMMs / multipumping.
//!
//! The output combines the cycle count with the design's physical cost
//! (area, power, clock period) exactly as Aladdin's backend does
//! (paper §III-B/§III-C).

use crate::mem::{MemDesign, MemKind, MemModel, PortModel};
use crate::trace::{OpKind, Trace};
use std::collections::BinaryHeap;

/// One point in the design space (the paper's sweep axes, §IV-A).
///
/// Compat value type for the built-in [`MemKind`] organizations. The
/// scheduler itself is memory-model-agnostic: it consumes a pre-built
/// [`MemDesign`] plus [`Knobs`], so registry-extension models run
/// through [`simulate_design`] without ever constructing a `MemKind`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DesignConfig {
    /// Memory organization.
    pub mem: MemKind,
    /// Loop unrolling factor (≥1).
    pub unroll: u32,
    /// Scratchpad word size in bytes (the paper's word-size axis).
    pub word_bytes: u32,
    /// ALU issue slots per cycle.
    pub alus: u32,
}

impl DesignConfig {
    /// A minimal single-port baseline.
    pub fn baseline() -> Self {
        DesignConfig { mem: MemKind::Banked { banks: 1 }, unroll: 1, word_bytes: 8, alus: 2 }
    }

    /// The memory-agnostic scheduling knobs of this configuration.
    pub fn knobs(&self) -> Knobs {
        Knobs { unroll: self.unroll, word_bytes: self.word_bytes, alus: self.alus }
    }
}

/// The non-memory sweep axes: everything the scheduler needs besides the
/// built [`MemDesign`] itself.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Knobs {
    /// Loop unrolling factor (≥1).
    pub unroll: u32,
    /// Scratchpad word size in bytes.
    pub word_bytes: u32,
    /// ALU issue slots per cycle.
    pub alus: u32,
}

impl Default for Knobs {
    fn default() -> Self {
        Knobs { unroll: 1, word_bytes: 8, alus: 2 }
    }
}

/// Scheduling + costing result for one design point.
#[derive(Clone, Debug, Default)]
pub struct SimOutput {
    /// Total cycles to drain the DDG.
    pub cycles: u64,
    /// Clock period, ns (max of base 1 ns and the memory path, times the
    /// multipumping frequency degradation).
    pub period_ns: f32,
    /// Execution time, ns.
    pub time_ns: f64,
    /// Memory area, µm².
    pub mem_area_um2: f32,
    /// Functional-unit area, µm².
    pub fu_area_um2: f32,
    /// Total area, µm².
    pub area_um2: f32,
    /// Average power, mW (dynamic + leakage).
    pub power_mw: f32,
    /// Dynamic energy, pJ.
    pub dyn_energy_pj: f64,
    /// Memory accesses that were issued.
    pub mem_accesses: u64,
    /// Accesses that had to retry due to port conflicts (banked designs).
    pub port_stalls: u64,
    /// Cycles in which at least one ready mem op could not issue.
    pub stall_cycles: u64,
}

/// Base accelerator clock: 1 GHz (1 ns) — Aladdin's default design clock.
pub const BASE_PERIOD_NS: f32 = 1.0;

/// Arrays at or below this footprint are *completely partitioned* into
/// registers (Aladdin's `partition,complete` directive applied to small
/// arrays — lookup tables, failure tables, filter taps). Register
/// accesses bypass the scratchpad ports entirely.
pub const REG_PROMOTE_BYTES: u64 = 64;

/// Register-file access energy, pJ (flop read/write at 45 nm).
const REG_ACCESS_PJ: f64 = 0.018;

/// Which arrays are register-promoted for this trace.
pub fn promoted_arrays(trace: &Trace) -> Vec<bool> {
    trace.arrays.iter().map(|a| a.bytes() <= REG_PROMOTE_BYTES).collect()
}

/// Fraction of FU area counted as leakage, µW per µm² (45 nm HVT logic).
const FU_LEAK_UW_PER_UM2: f32 = 0.012;

/// Schedule `trace` under `cfg`, returning cycles + physical cost.
pub fn simulate(trace: &Trace, cfg: &DesignConfig) -> SimOutput {
    let design = build_memory(trace, cfg);
    simulate_design(trace, &cfg.knobs(), &design)
}

/// Scratchpad depth (words) needed to hold every non-promoted traced
/// array at the given word size.
pub fn footprint_depth(trace: &Trace, word_bytes: u32) -> u32 {
    let word_bytes = word_bytes.max(1);
    let promoted = promoted_arrays(trace);
    let total_bytes: u64 = trace
        .arrays
        .iter()
        .zip(&promoted)
        .filter(|(_, &p)| !p)
        .map(|(a, _)| a.bytes())
        .sum();
    (total_bytes.div_ceil(word_bytes as u64)).max(4) as u32
}

/// Build the memory design implied by `cfg` for this trace: the
/// scratchpad must hold every traced array at the configured word size.
pub fn build_memory(trace: &Trace, cfg: &DesignConfig) -> MemDesign {
    let word_bytes = cfg.word_bytes.max(1);
    cfg.mem.build(footprint_depth(trace, word_bytes), word_bytes * 8)
}

/// Trait-object flavor of [`build_memory`]: size the scratchpad for
/// `trace` and build it with any registered memory model.
pub fn build_memory_model(trace: &Trace, model: &dyn MemModel, word_bytes: u32) -> MemDesign {
    let word_bytes = word_bytes.max(1);
    model.build(footprint_depth(trace, word_bytes), word_bytes * 8)
}

/// Area of the register file holding the promoted arrays, µm².
pub fn promoted_reg_area(trace: &Trace) -> f32 {
    let bits: u64 = trace
        .arrays
        .iter()
        .filter(|a| a.bytes() <= REG_PROMOTE_BYTES)
        .map(|a| a.bytes() * 8)
        .sum();
    bits as f32 * crate::synth::cal::FF_GE * crate::synth::cal::GATE_UM2
}

/// Map a memory op to its scratchpad *word* index (arrays are packed
/// back-to-back; narrower elements share words).
#[inline]
fn word_index(trace: &Trace, array: u16, index: u32, word_bytes: u32) -> u32 {
    let a = &trace.arrays[array as usize];
    (a.byte_addr(index) / word_bytes as u64) as u32
}

/// Schedule with an explicit, pre-built memory design (compat wrapper;
/// `cfg.mem` is ignored — the design rules).
pub fn simulate_with_design(trace: &Trace, cfg: &DesignConfig, design: &MemDesign) -> SimOutput {
    simulate_design(trace, &cfg.knobs(), design)
}

/// Schedule with an explicit, pre-built memory design and the non-memory
/// knobs (lets the coordinator inject PJRT-evaluated costs, and lets
/// registry-extension models run without a [`MemKind`]).
pub fn simulate_design(trace: &Trace, knobs: &Knobs, design: &MemDesign) -> SimOutput {
    let n = trace.len();
    let unroll = knobs.unroll.max(1);
    let alus = knobs.alus.max(1);
    let word_bytes = knobs.word_bytes.max(1);
    let promoted = promoted_arrays(trace);
    // Sub-word splitting: an element wider than the scratchpad word takes
    // ceil(elem/word) port acquisitions (consecutive words ⇒ consecutive
    // cyclic banks) — the paper's word-size axis.
    let subwords: Vec<u32> = trace
        .arrays
        .iter()
        .map(|a| a.elem_bytes.div_ceil(word_bytes).max(1))
        .collect();
    // Per-node sub-accesses still outstanding (only mem ops use this).
    let mut subs_left: Vec<u32> = trace
        .nodes
        .iter()
        .map(|nd| match nd.kind.mem_ref() {
            Some((a, _)) if !promoted[a as usize] => subwords[a as usize],
            _ => 0,
        })
        .collect();
    // Precomputed scratchpad word index per mem node (recomputing it on
    // every stall retry showed up in the §Perf profile).
    let base_words: Vec<u32> = trace
        .nodes
        .iter()
        .map(|nd| match nd.kind.mem_ref() {
            Some((a, i)) => word_index(trace, a, i, word_bytes),
            None => 0,
        })
        .collect();

    // --- dependence state --------------------------------------------
    let mut remaining = trace.pred_count.clone();

    // Ready min-heaps keyed by (ready_cycle, node id), one per resource
    // class so the issue loop never pops an op it cannot issue (that
    // would be O(backlog) per cycle):
    //   · reg  — register-promoted accesses (free, always drained)
    //   · alu  — FU ops
    //   · mem  — banked designs (single queue: program-order issue)
    //   · rd/wr — true-port designs (independent read/write ports)
    use std::cmp::Reverse;
    type Heap = BinaryHeap<Reverse<(u64, u32)>>;
    let mut ready_reg: Heap = BinaryHeap::new();
    let mut ready_alu: Heap = BinaryHeap::new();
    let mut ready_mem: Heap = BinaryHeap::new();
    let mut ready_rd: Heap = BinaryHeap::new();
    let mut ready_wr: Heap = BinaryHeap::new();

    let (bank_count, rd_ports, wr_ports, shared, block) = match design.ports {
        PortModel::PerBank { banks, reads, writes, shared, block } => {
            (banks, reads, writes, shared, block)
        }
        PortModel::TruePorts { reads, writes } => (0, reads, writes, false, false),
    };
    let per_bank = bank_count > 0;
    // Block partitioning: contiguous address ranges per bank.
    let block_size = if block { design.depth.div_ceil(bank_count.max(1)).max(1) } else { 0 };

    macro_rules! push_ready {
        ($nid:expr, $at:expr) => {{
            let nid: u32 = $nid;
            let at: u64 = $at;
            match trace.nodes[nid as usize].kind {
                OpKind::Alu(_) => ready_alu.push(Reverse((at, nid))),
                OpKind::Load { array, .. } | OpKind::Store { array, .. } => {
                    if promoted[array as usize] {
                        ready_reg.push(Reverse((at, nid)));
                    } else if per_bank {
                        ready_mem.push(Reverse((at, nid)));
                    } else if matches!(trace.nodes[nid as usize].kind, OpKind::Store { .. }) {
                        ready_wr.push(Reverse((at, nid)));
                    } else {
                        ready_rd.push(Reverse((at, nid)));
                    }
                }
            }
        }};
    }

    for i in 0..n {
        if remaining[i] == 0 {
            let gate = (trace.nodes[i].iter / unroll) as u64;
            push_ready!(i as u32, gate);
        }
    }

    // Completion events live in a ring of buckets instead of a heap:
    // every op latency is <= 16 cycles, so a 32-slot ring indexed by
    // cycle % 32 gives O(1) push/retire (§Perf iteration 2).
    const RING: usize = 32;
    let mut ring: Vec<Vec<u32>> = vec![Vec::new(); RING];
    let mut ring_pending: usize = 0;
    macro_rules! complete_at {
        ($cycle:expr, $nid:expr) => {{
            ring[($cycle % RING as u64) as usize].push($nid);
            ring_pending += 1;
        }};
    }

    // Per-cycle port counters: per bank for banked designs, a single
    // global pair for true-port designs.
    let counters = if per_bank { bank_count as usize } else { 1 };
    let mut used_rd = vec![0u32; counters];
    let mut used_wr = vec![0u32; counters];

    let mut cycle: u64 = 0;
    let mut done = 0usize;
    let mut issued_mem: u64 = 0;
    let mut port_stalls: u64 = 0;
    let mut stall_cycles: u64 = 0;
    let mut n_reads: u64 = 0;
    let mut n_writes: u64 = 0;
    let mut n_reg: u64 = 0;
    let mut n_alu_energy: f64 = 0.0;

    let mut retire_buf: Vec<u32> = Vec::new();
    while done < n {
        // retire completions for this cycle (ring slot owns exactly the
        // events for `cycle`: pushes always target < RING cycles ahead,
        // and the advance step visits slots in order)
        let slot = (cycle % RING as u64) as usize;
        if !ring[slot].is_empty() {
            retire_buf.clear();
            retire_buf.append(&mut ring[slot]);
            ring_pending -= retire_buf.len();
            done += retire_buf.len();
            for &node in &retire_buf {
                for &s in trace.successors(node) {
                    remaining[s as usize] -= 1;
                    if remaining[s as usize] == 0 {
                        // The producer completes at the start of this
                        // cycle, so the consumer may issue this cycle.
                        let gate = (trace.nodes[s as usize].iter / unroll) as u64;
                        push_ready!(s, gate.max(cycle));
                    }
                }
            }
        }

        // reset per-cycle port + FU counters
        for c in used_rd.iter_mut() {
            *c = 0;
        }
        for c in used_wr.iter_mut() {
            *c = 0;
        }
        let mut alu_slots = alus;
        let mut had_mem_stall = false;

        // register-promoted accesses are free: drain them all
        while let Some(&Reverse((rc, _))) = ready_reg.peek() {
            if rc > cycle {
                break;
            }
            let Reverse((_, nid)) = ready_reg.pop().unwrap();
            issued_mem += 1;
            n_reg += 1;
            complete_at!(cycle + 1, nid);
        }

        // FU issue: stop the moment slots run out (no wasted pops)
        while alu_slots > 0 {
            match ready_alu.peek() {
                Some(&Reverse((rc, _))) if rc <= cycle => {}
                _ => break,
            }
            let Reverse((_, nid)) = ready_alu.pop().unwrap();
            let OpKind::Alu(kind) = trace.nodes[nid as usize].kind else { unreachable!() };
            alu_slots -= 1;
            n_alu_energy += kind.energy_pj() as f64;
            complete_at!(cycle + kind.latency() as u64, nid);
        }

        // Try to issue the sub-word accesses of one memory op; returns
        // the number still outstanding after this cycle.
        let try_mem = |nid: u32,
                           used_rd: &mut Vec<u32>,
                           used_wr: &mut Vec<u32>,
                           n_reads: &mut u64,
                           n_writes: &mut u64,
                           subs_left: &mut Vec<u32>,
                           port_stalls: &mut u64,
                           issued_mem: &mut u64|
         -> u32 {
            let node = &trace.nodes[nid as usize];
            let (array, _index) = node.kind.mem_ref().unwrap();
            let is_write = matches!(node.kind, OpKind::Store { .. });
            let total_subs = subwords[array as usize];
            let base_word = base_words[nid as usize];
            let mut left = subs_left[nid as usize];
            let mut progressed = false;
            while left > 0 {
                let sub = total_subs - left;
                let slot = if !per_bank {
                    0
                } else if block {
                    (((base_word + sub) / block_size).min(bank_count - 1)) as usize
                } else {
                    ((base_word + sub) % bank_count) as usize
                };
                let ok = if shared {
                    // 1RW: reads and writes share one port per bank
                    if used_rd[slot] + used_wr[slot] < rd_ports.max(wr_ports) {
                        if is_write {
                            used_wr[slot] += 1;
                        } else {
                            used_rd[slot] += 1;
                        }
                        true
                    } else {
                        false
                    }
                } else if is_write {
                    if used_wr[slot] < wr_ports {
                        used_wr[slot] += 1;
                        true
                    } else {
                        false
                    }
                } else if used_rd[slot] < rd_ports {
                    used_rd[slot] += 1;
                    true
                } else {
                    false
                };
                if !ok {
                    break;
                }
                left -= 1;
                progressed = true;
                if is_write {
                    *n_writes += 1;
                } else {
                    *n_reads += 1;
                }
            }
            subs_left[nid as usize] = left;
            if left == 0 {
                *issued_mem += 1;
            } else if !progressed {
                *port_stalls += 1;
            }
            left
        };

        if per_bank {
            // Banked designs model Aladdin's *static* schedule: memory
            // issues in program order; the first bank conflict stalls all
            // later memory ops this cycle (the compiler cannot reorder
            // around a dynamic conflict).
            while let Some(&Reverse((rc, _))) = ready_mem.peek() {
                if rc > cycle {
                    break;
                }
                let Reverse((rc0, nid)) = ready_mem.pop().unwrap();
                let left = try_mem(
                    nid, &mut used_rd, &mut used_wr, &mut n_reads, &mut n_writes,
                    &mut subs_left, &mut port_stalls, &mut issued_mem,
                );
                if left > 0 {
                    had_mem_stall = true;
                    // Re-queue under the ORIGINAL key so program order
                    // among ready ops is preserved across the stall.
                    ready_mem.push(Reverse((rc0, nid)));
                    break; // in-order: nothing younger may issue
                }
                complete_at!(cycle + 1, nid);
            }
        } else {
            // True multi-port (AMM / multipump / circuit MP): reads and
            // writes issue independently until their port class is full.
            while used_rd[0] < rd_ports {
                match ready_rd.peek() {
                    Some(&Reverse((rc, _))) if rc <= cycle => {}
                    _ => break,
                }
                let Reverse((rc0, nid)) = ready_rd.pop().unwrap();
                let left = try_mem(
                    nid, &mut used_rd, &mut used_wr, &mut n_reads, &mut n_writes,
                    &mut subs_left, &mut port_stalls, &mut issued_mem,
                );
                if left > 0 {
                    had_mem_stall = true;
                    // Re-queue under the ORIGINAL key so program order
                    // among ready ops is preserved across the stall.
                    ready_rd.push(Reverse((rc0, nid)));
                    break;
                }
                complete_at!(cycle + 1, nid);
            }
            while used_wr[0] < wr_ports {
                match ready_wr.peek() {
                    Some(&Reverse((rc, _))) if rc <= cycle => {}
                    _ => break,
                }
                let Reverse((rc0, nid)) = ready_wr.pop().unwrap();
                let left = try_mem(
                    nid, &mut used_rd, &mut used_wr, &mut n_reads, &mut n_writes,
                    &mut subs_left, &mut port_stalls, &mut issued_mem,
                );
                if left > 0 {
                    had_mem_stall = true;
                    // Re-queue under the ORIGINAL key so program order
                    // among ready ops is preserved across the stall.
                    ready_wr.push(Reverse((rc0, nid)));
                    break;
                }
                complete_at!(cycle + 1, nid);
            }
        }
        if had_mem_stall {
            stall_cycles += 1;
        }

        // advance to the next event (earliest ready or completion)
        let mut next = u64::MAX;
        for h in [&ready_reg, &ready_alu, &ready_mem, &ready_rd, &ready_wr] {
            if let Some(&Reverse((c, _))) = h.peek() {
                next = next.min(c);
            }
        }
        if ring_pending > 0 {
            // nearest non-empty ring slot within the next RING cycles
            for d in 1..=RING as u64 {
                if !ring[((cycle + d) % RING as u64) as usize].is_empty() {
                    next = next.min(cycle + d);
                    break;
                }
            }
        }
        if next == u64::MAX {
            break;
        }
        cycle = next.max(cycle + 1);
    }

    // --- physical composition (the Aladdin backend step) --------------
    let period_ns =
        BASE_PERIOD_NS.max(design.t_access_ns()) * design.freq_factor;
    let cycles = cycle.max(1);
    let time_ns = cycles as f64 * period_ns as f64;

    let mem_area = design.area_um2() + promoted_reg_area(trace);
    let fu_area = fu_area(trace, alus);
    let dyn_energy = n_reads as f64 * design.e_read_pj() as f64
        + n_writes as f64 * design.e_write_pj() as f64
        + n_reg as f64 * REG_ACCESS_PJ
        + n_alu_energy;
    let leak_uw = design.leak_uw() + fu_area * FU_LEAK_UW_PER_UM2;
    // pJ / ns = mW; leakage µW → mW.
    let power_mw = (dyn_energy / time_ns) as f32 + leak_uw / 1000.0;

    SimOutput {
        cycles,
        period_ns,
        time_ns,
        mem_area_um2: mem_area,
        fu_area_um2: fu_area,
        area_um2: mem_area + fu_area,
        power_mw,
        dyn_energy_pj: dyn_energy,
        mem_accesses: issued_mem,
        port_stalls,
        stall_cycles,
    }
}

/// FU area for `alus` issue slots: blended over the trace's op mix (an
/// `alus`-wide datapath provisioned proportionally to what the kernel
/// actually executes).
pub fn fu_area(trace: &Trace, alus: u32) -> f32 {
    let mut counts = [0u64; 8];
    let mut total = 0u64;
    for node in &trace.nodes {
        if let OpKind::Alu(k) = node.kind {
            let i = crate::trace::AluKind::ALL.iter().position(|&x| x == k).unwrap();
            counts[i] += 1;
            total += 1;
        }
    }
    if total == 0 {
        return 0.0;
    }
    let blended: f32 = crate::trace::AluKind::ALL
        .iter()
        .enumerate()
        .map(|(i, k)| k.fu_area_um2() * (counts[i] as f64 / total as f64) as f32)
        .sum();
    blended * alus as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suite::{self, Scale};
    use crate::trace::{AluKind, TraceBuilder};

    fn chain_trace(len: u32) -> Trace {
        // serial dependence chain of IntAdds: cycles == len
        let mut b = TraceBuilder::new();
        let _a = b.array("a", 4, 4);
        let mut prev = b.alu(AluKind::IntAdd, &[]);
        for _ in 1..len {
            prev = b.alu(AluKind::IntAdd, &[prev]);
        }
        b.finish()
    }

    #[test]
    fn serial_chain_takes_len_cycles() {
        let t = chain_trace(100);
        let out = simulate(&t, &DesignConfig::baseline());
        assert_eq!(out.cycles, 100);
    }

    #[test]
    fn parallel_ops_bounded_by_alus() {
        // 64 independent IntAdds, 4 ALUs → 16 cycles.
        let mut b = TraceBuilder::new();
        let _ = b.array("a", 4, 4);
        for _ in 0..64 {
            b.alu(AluKind::IntAdd, &[]);
        }
        let t = b.finish();
        let cfg = DesignConfig { alus: 4, ..DesignConfig::baseline() };
        let out = simulate(&t, &cfg);
        assert_eq!(out.cycles, 16);
    }

    #[test]
    fn single_port_serializes_parallel_loads() {
        // 32 independent loads of distinct addresses in one bank.
        let mut b = TraceBuilder::new();
        let a = b.array("a", 4, 64);
        for i in 0..32 {
            b.load(a, i);
        }
        let t = b.finish();
        let single = simulate(&t, &DesignConfig::baseline());
        assert_eq!(single.cycles, 32);
        // 4R AMM: 8 cycles.
        let amm = DesignConfig {
            mem: MemKind::XorAmm { read_ports: 4, write_ports: 1 },
            ..DesignConfig::baseline()
        };
        let out = simulate(&t, &amm);
        assert_eq!(out.cycles, 8);
        assert!(single.stall_cycles > 0, "single-port run must report stalls");
    }

    #[test]
    fn banking_helps_only_without_conflicts() {
        // Loads with stride 4 over 4 banks (word=4B): all hit bank 0 →
        // banking gives no speedup; an AMM does.
        let mut b = TraceBuilder::new();
        let a = b.array("a", 4, 256);
        for i in 0..32 {
            b.load(a, i * 4);
        }
        let t = b.finish();
        let banked = DesignConfig {
            mem: MemKind::Banked { banks: 4 },
            word_bytes: 4,
            ..DesignConfig::baseline()
        };
        let conflicted = simulate(&t, &banked);
        assert_eq!(conflicted.cycles, 32, "stride-4 over 4 banks must serialize");
        let stride1 = {
            let mut b = TraceBuilder::new();
            let a = b.array("a", 4, 256);
            for i in 0..32 {
                b.load(a, i);
            }
            b.finish()
        };
        let spread = simulate(&stride1, &banked);
        assert_eq!(spread.cycles, 8, "stride-1 over 4 banks runs 4-wide");
    }

    #[test]
    fn unroll_gates_iteration_groups() {
        // 64 independent loads, one per iteration, unroll=1 → ≥64 cycles
        // even on a wide AMM (loop control serializes).
        let mut b = TraceBuilder::new();
        let a = b.array("a", 4, 64);
        for i in 0..64 {
            b.load(a, i);
            b.next_iter();
        }
        let t = b.finish();
        let amm = DesignConfig {
            mem: MemKind::XorAmm { read_ports: 4, write_ports: 2 },
            unroll: 1,
            ..DesignConfig::baseline()
        };
        assert!(simulate(&t, &amm).cycles >= 64);
        let amm8 = DesignConfig { unroll: 8, ..amm };
        assert!(simulate(&t, &amm8).cycles <= 17);
    }

    #[test]
    fn multipump_trades_cycles_for_period() {
        let mut b = TraceBuilder::new();
        let a = b.array("a", 4, 64);
        for i in 0..32 {
            b.load(a, i);
        }
        let t = b.finish();
        let pump = DesignConfig { mem: MemKind::MultiPump { factor: 2 }, ..DesignConfig::baseline() };
        let out = simulate(&t, &pump);
        assert_eq!(out.cycles, 16, "2 pseudo-ports");
        let single = simulate(&t, &DesignConfig::baseline());
        // but the external clock runs 2× slower → no net time win
        assert!(out.time_ns >= single.time_ns * 0.95);
    }

    #[test]
    fn real_benchmarks_schedule_and_cost() {
        for name in ["gemm", "fft", "kmp"] {
            let wl = suite::generate(name, Scale::Tiny);
            let out = simulate(&wl.trace, &DesignConfig::baseline());
            assert!(out.cycles >= wl.trace.critical_path_len() as u64 / 2, "{name}");
            assert!(out.area_um2 > 0.0, "{name}");
            assert!(out.power_mw > 0.0, "{name}");
            assert!(out.time_ns > 0.0, "{name}");
            // every memory op must be issued exactly once
            assert_eq!(out.mem_accesses, wl.trace.mem_ops() as u64, "{name}");
        }
    }

    #[test]
    fn more_ports_never_slower() {
        let wl = suite::generate("gemm", Scale::Tiny);
        let mut prev = u64::MAX;
        for r in [1u32, 2, 4, 8] {
            let cfg = DesignConfig {
                mem: MemKind::LvtAmm { read_ports: r, write_ports: 1 },
                unroll: 8,
                alus: 8,
                ..DesignConfig::baseline()
            };
            let out = simulate(&wl.trace, &cfg);
            assert!(out.cycles <= prev, "r={r}: {} > {}", out.cycles, prev);
            prev = out.cycles;
        }
    }
}
