//! Trace compilation — the once-per-`(trace, word_bytes)` layer.
//!
//! The monolithic `simulate_design` used to re-derive the same
//! trace-invariant state for *every* design point of a sweep: the
//! register-promotion mask, per-array sub-word counts, scratchpad word
//! indices, per-node resource classes, the FU-mix area blend and the
//! footprint depth. Across a Cartesian sweep those hundreds of
//! re-derivations (plus ~6 trace-sized allocations per run) were pure
//! waste. [`CompiledTrace`] hoists all of it: compile once per word
//! size, then run any number of `(design, unroll, alus)` points through
//! [`CompiledTrace::simulate`] with a reusable
//! [`SimArena`](super::SimArena).
//!
//! The design-dependent halves of the inner loop live here too, shared
//! with the lane-batched engine (`super::batch`): [`PortCfg`] resolves a
//! design's port model once, [`MemIssue`] bundles everything one
//! memory-issue attempt mutates (so the issue loops thread ONE `&mut`
//! instead of eight), and [`CompiledTrace::try_mem`] /
//! [`CompiledTrace::compose_output`] are the single implementations of
//! sub-word port arbitration and the Aladdin physical backend — which is
//! what makes the batch kernel bit-identical by construction on those
//! steps. Per-node routing (word index, sub-word split, load/store
//! class) is precompiled into one [`MemRoute`] SoA table so `try_mem`
//! never dereferences trace nodes on the arbitration path.
//!
//! The compat wrappers [`super::simulate`] / [`super::simulate_design`]
//! are thin shims over this engine and produce byte-identical
//! [`SimOutput`]s (asserted by `tests/engine_golden.rs`).

use super::arena::{SimArena, RING};
use super::{footprint_depth, fu_area, promoted_arrays, promoted_reg_area, Knobs, SimOutput};
use super::{BASE_PERIOD_NS, FU_LEAK_UW_PER_UM2, REG_ACCESS_PJ};
use crate::mem::{MemDesign, PortModel};
use crate::trace::{OpKind, Trace};
use crate::util::hash::{fnv1a, FNV_OFFSET};
use std::cmp::Reverse;

/// Semantic version of the scheduling engine. Folded into every
/// [`crate::sim::Key`], so persisted simulation rows from an older
/// kernel are quarantined rather than replayed: **bump this on any
/// change that can alter a [`SimOutput`]** (issue rules, port
/// arbitration, energy/area composition, trace compilation). Currently
/// 2: the event-wheel lane-batched kernel (PR 8).
pub const ENGINE_VERSION: u32 = 2;

/// Which issue resource a node consumes (register promotion folded in;
/// the banked-vs-true-port split stays design-dependent and is resolved
/// at push time).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(super) enum NodeClass {
    /// Functional-unit op.
    Alu,
    /// Register-promoted memory access (free port-wise).
    Reg,
    /// Scratchpad load.
    Load,
    /// Scratchpad store.
    Store,
}

/// Precompiled port routing for one trace node: everything the
/// arbitration loop used to re-derive per issue attempt (trace-node
/// deref, `mem_ref()` unwrap, store test, per-array sub-word count,
/// word index) fused into one SoA record. Zeroed for non-memory nodes;
/// register-promoted accesses keep their split but never reach
/// `try_mem` (they drain through the free register queue).
#[derive(Clone, Copy, Debug, Default)]
pub(super) struct MemRoute {
    /// Scratchpad word index of the first sub-access.
    pub base_word: u32,
    /// Port acquisitions for one full access (sub-word split).
    pub subs: u32,
    /// Store (write port) vs load (read port).
    pub write: bool,
}

/// A design's port model resolved for the scheduler: the only part of
/// the inner loop that differs between the lanes of a batched run.
#[derive(Clone, Copy, Debug, Default)]
pub(super) struct PortCfg {
    /// Bank count for banked designs, 0 for true multi-port.
    pub bank_count: u32,
    /// Read ports (per bank when `per_bank`).
    pub rd_ports: u32,
    /// Write ports (per bank when `per_bank`).
    pub wr_ports: u32,
    /// 1RW: reads and writes share one port budget per bank.
    pub shared: bool,
    /// Block (contiguous-range) partitioning instead of cyclic.
    pub block: bool,
    /// Banked conflict model (in-order issue, per-bank counters).
    pub per_bank: bool,
    /// Words per bank under block partitioning (0 when cyclic).
    pub block_size: u32,
    /// `bank_count - 1` when `pow2` (cyclic slot = `word & bank_mask`).
    pub bank_mask: u32,
    /// Cyclic routing over a power-of-two bank count: the hot slot
    /// computation strength-reduces `%` to `&` (identical results).
    pub pow2: bool,
}

impl PortCfg {
    /// Resolve `design.ports` (the block size needs the design's depth).
    pub fn of(design: &MemDesign) -> PortCfg {
        let (bank_count, rd_ports, wr_ports, shared, block) = match design.ports {
            PortModel::PerBank { banks, reads, writes, shared, block } => {
                (banks, reads, writes, shared, block)
            }
            PortModel::TruePorts { reads, writes } => (0, reads, writes, false, false),
        };
        let per_bank = bank_count > 0;
        // Block partitioning: contiguous address ranges per bank.
        let block_size = if block { design.depth.div_ceil(bank_count.max(1)).max(1) } else { 0 };
        let pow2 = per_bank && !block && bank_count.is_power_of_two();
        let bank_mask = if pow2 { bank_count - 1 } else { 0 };
        PortCfg {
            bank_count,
            rd_ports,
            wr_ports,
            shared,
            block,
            per_bank,
            block_size,
            bank_mask,
            pow2,
        }
    }

    /// Per-cycle port-counter slots: one per bank, or one global pair.
    pub fn counters(&self) -> usize {
        if self.per_bank {
            self.bank_count as usize
        } else {
            1
        }
    }
}

/// Everything one memory-issue attempt mutates, bundled so the issue
/// loops hand [`CompiledTrace::try_mem`] a single `&mut` (and so the
/// batch engine can aim the same code at any lane's slice of its
/// lane-major arena).
pub(super) struct MemIssue<'a> {
    /// Read-port usage this cycle (per bank, or one global slot).
    pub used_rd: &'a mut [u32],
    /// Write-port usage this cycle.
    pub used_wr: &'a mut [u32],
    /// Outstanding sub-accesses per node.
    pub subs_left: &'a mut [u32],
    /// Scratchpad word reads issued.
    pub n_reads: &'a mut u64,
    /// Scratchpad word writes issued.
    pub n_writes: &'a mut u64,
    /// Cycles a memory op made zero progress on ports.
    pub port_stalls: &'a mut u64,
    /// Memory ops fully issued.
    pub issued_mem: &'a mut u64,
}

/// Activity accumulated by one scheduled run (one lane of a batch) —
/// the inputs to [`CompiledTrace::compose_output`].
#[derive(Clone, Copy, Debug, Default)]
pub(super) struct Accum {
    /// Memory ops fully issued (promoted accesses included).
    pub issued_mem: u64,
    /// Zero-progress memory-op cycles.
    pub port_stalls: u64,
    /// Cycles with at least one stalled memory op.
    pub stall_cycles: u64,
    /// Scratchpad word reads.
    pub n_reads: u64,
    /// Scratchpad word writes.
    pub n_writes: u64,
    /// Register-file accesses (promoted arrays).
    pub n_reg: u64,
    /// FU energy, pJ (accumulated in issue order).
    pub n_alu_energy: f64,
}

/// Map a memory op to its scratchpad *word* index (arrays are packed
/// back-to-back; narrower elements share words).
#[inline]
fn word_index(trace: &Trace, array: u16, index: u32, word_bytes: u32) -> u32 {
    let a = &trace.arrays[array as usize];
    (a.byte_addr(index) / word_bytes as u64) as u32
}

/// Everything the scheduler's inner loop needs that depends only on
/// `(trace, word_bytes)` — compiled once, shared (it is `Sync`) by every
/// worker evaluating design points at that word size.
pub struct CompiledTrace<'t> {
    /// The underlying trace.
    pub(super) trace: &'t Trace,
    /// Clamped scratchpad word size, bytes.
    pub(super) word_bytes: u32,
    /// Register-promotion mask per array.
    pub(super) promoted: Vec<bool>,
    /// Initial outstanding sub-accesses per node (0 for non-mem /
    /// promoted nodes) — the seed for `SimArena::subs_left`.
    pub(super) subs_init: Vec<u32>,
    /// Precompiled per-node port routing ([`MemRoute`] SoA table).
    pub(super) routes: Vec<MemRoute>,
    /// Issue resource class per node.
    pub(super) class: Vec<NodeClass>,
    /// Scratchpad depth (words) holding every non-promoted array.
    pub(super) depth: u32,
    /// Area of the promoted-array register file, µm².
    pub(super) reg_area_um2: f32,
    /// Op-mix-blended FU area per ALU issue slot, µm².
    pub(super) fu_blend: f32,
    /// FNV-1a content hash of the underlying trace (arrays, node
    /// stream, dependence edges) — the trace half of a simulation
    /// memoization key ([`crate::sim::Key`]).
    pub(super) trace_hash: u64,
}

/// FNV-1a over everything that makes two traces schedule identically:
/// the array table (name, element size, length, base address), the
/// node stream (op tag, operands, site, iteration) and the CSR
/// dependence edges. Word size is *not* folded in — it is a separate
/// key axis — so all word-size compilations of one trace share a hash.
fn trace_content_hash(trace: &Trace) -> u64 {
    let mut h = fnv1a(FNV_OFFSET, &(trace.arrays.len() as u64).to_le_bytes());
    for a in &trace.arrays {
        h = fnv1a(h, a.name.as_bytes());
        h = fnv1a(h, &[0u8]);
        h = fnv1a(h, &a.elem_bytes.to_le_bytes());
        h = fnv1a(h, &a.length.to_le_bytes());
        h = fnv1a(h, &a.base.to_le_bytes());
    }
    for nd in &trace.nodes {
        let (tag, a, i) = match nd.kind {
            OpKind::Load { array, index } => (0u8, u32::from(array), index),
            OpKind::Store { array, index } => (1u8, u32::from(array), index),
            OpKind::Alu(k) => (2u8, k.index() as u32, 0),
        };
        let mut buf = [0u8; 17];
        buf[0] = tag;
        buf[1..5].copy_from_slice(&a.to_le_bytes());
        buf[5..9].copy_from_slice(&i.to_le_bytes());
        buf[9..13].copy_from_slice(&nd.site.to_le_bytes());
        buf[13..17].copy_from_slice(&nd.iter.to_le_bytes());
        h = fnv1a(h, &buf);
    }
    for &off in &trace.succ_off {
        h = fnv1a(h, &off.to_le_bytes());
    }
    for &s in &trace.succ {
        h = fnv1a(h, &s.to_le_bytes());
    }
    h
}

impl<'t> CompiledTrace<'t> {
    /// Compile `trace` for one scratchpad word size (clamped to ≥ 1 B).
    pub fn new(trace: &'t Trace, word_bytes: u32) -> Self {
        let word_bytes = word_bytes.max(1);
        let promoted = promoted_arrays(trace);
        // Sub-word splitting: an element wider than the scratchpad word
        // takes ceil(elem/word) port acquisitions (consecutive words ⇒
        // consecutive cyclic banks) — the paper's word-size axis.
        let subwords: Vec<u32> = trace
            .arrays
            .iter()
            .map(|a| a.elem_bytes.div_ceil(word_bytes).max(1))
            .collect();
        let subs_init: Vec<u32> = trace
            .nodes
            .iter()
            .map(|nd| match nd.kind.mem_ref() {
                Some((a, _)) if !promoted[a as usize] => subwords[a as usize],
                _ => 0,
            })
            .collect();
        let routes: Vec<MemRoute> = trace
            .nodes
            .iter()
            .map(|nd| match nd.kind.mem_ref() {
                Some((a, i)) => MemRoute {
                    base_word: word_index(trace, a, i, word_bytes),
                    subs: subwords[a as usize],
                    write: matches!(nd.kind, OpKind::Store { .. }),
                },
                None => MemRoute::default(),
            })
            .collect();
        let class: Vec<NodeClass> = trace
            .nodes
            .iter()
            .map(|nd| match nd.kind {
                OpKind::Alu(_) => NodeClass::Alu,
                OpKind::Load { array, .. } if promoted[array as usize] => NodeClass::Reg,
                OpKind::Store { array, .. } if promoted[array as usize] => NodeClass::Reg,
                OpKind::Load { .. } => NodeClass::Load,
                OpKind::Store { .. } => NodeClass::Store,
            })
            .collect();
        CompiledTrace {
            trace,
            word_bytes,
            promoted,
            subs_init,
            routes,
            class,
            depth: footprint_depth(trace, word_bytes),
            reg_area_um2: promoted_reg_area(trace),
            fu_blend: fu_area(trace, 1),
            trace_hash: trace_content_hash(trace),
        }
    }

    /// The compiled trace's underlying DDG.
    pub fn trace(&self) -> &'t Trace {
        self.trace
    }

    /// The (clamped) word size this compilation is specialized for.
    pub fn word_bytes(&self) -> u32 {
        self.word_bytes
    }

    /// Scratchpad depth (words) for every non-promoted traced array.
    pub fn depth(&self) -> u32 {
        self.depth
    }

    /// Register-promotion mask per array.
    pub fn promoted(&self) -> &[bool] {
        &self.promoted
    }

    /// FU area for `alus` issue slots, µm² (op-mix blend precomputed).
    pub fn fu_area(&self, alus: u32) -> f32 {
        self.fu_blend * alus as f32
    }

    /// FNV-1a content hash of the underlying trace — stable across
    /// processes and hosts, so it can key persisted simulation rows
    /// ([`crate::sim::Key`]).
    pub fn content_hash(&self) -> u64 {
        self.trace_hash
    }

    /// Try to issue the sub-word accesses of one memory op under `cfg`'s
    /// port budget; returns the number still outstanding after this
    /// cycle. Shared verbatim by the scalar and batch engines; the
    /// per-node half of the routing decision is a single [`MemRoute`]
    /// table read.
    pub(super) fn try_mem(&self, nid: u32, cfg: &PortCfg, st: &mut MemIssue<'_>) -> u32 {
        let MemRoute { base_word, subs: total_subs, write: is_write } = self.routes[nid as usize];
        let mut left = st.subs_left[nid as usize];
        let mut progressed = false;
        while left > 0 {
            let sub = total_subs - left;
            let slot = if !cfg.per_bank {
                0
            } else if cfg.block {
                (((base_word + sub) / cfg.block_size).min(cfg.bank_count - 1)) as usize
            } else if cfg.pow2 {
                ((base_word + sub) & cfg.bank_mask) as usize
            } else {
                ((base_word + sub) % cfg.bank_count) as usize
            };
            let ok = if cfg.shared {
                // 1RW: reads and writes share one port per bank
                if st.used_rd[slot] + st.used_wr[slot] < cfg.rd_ports.max(cfg.wr_ports) {
                    if is_write {
                        st.used_wr[slot] += 1;
                    } else {
                        st.used_rd[slot] += 1;
                    }
                    true
                } else {
                    false
                }
            } else if is_write {
                if st.used_wr[slot] < cfg.wr_ports {
                    st.used_wr[slot] += 1;
                    true
                } else {
                    false
                }
            } else if st.used_rd[slot] < cfg.rd_ports {
                st.used_rd[slot] += 1;
                true
            } else {
                false
            };
            if !ok {
                break;
            }
            left -= 1;
            progressed = true;
            if is_write {
                *st.n_writes += 1;
            } else {
                *st.n_reads += 1;
            }
        }
        st.subs_left[nid as usize] = left;
        if left == 0 {
            *st.issued_mem += 1;
        } else if !progressed {
            *st.port_stalls += 1;
        }
        left
    }

    /// The physical composition (the Aladdin backend step) shared by
    /// the scalar and batch engines: schedule length + accumulated
    /// activity → timing, area, energy, power.
    pub(super) fn compose_output(
        &self,
        design: &MemDesign,
        alus: u32,
        cycle: u64,
        acc: &Accum,
    ) -> SimOutput {
        let period_ns = BASE_PERIOD_NS.max(design.t_access_ns()) * design.freq_factor;
        let cycles = cycle.max(1);
        let time_ns = cycles as f64 * period_ns as f64;

        let mem_area = design.area_um2() + self.reg_area_um2;
        let fu_area_um2 = self.fu_area(alus);
        let dyn_energy = acc.n_reads as f64 * design.e_read_pj() as f64
            + acc.n_writes as f64 * design.e_write_pj() as f64
            + acc.n_reg as f64 * REG_ACCESS_PJ
            + acc.n_alu_energy;
        let leak_uw = design.leak_uw() + fu_area_um2 * FU_LEAK_UW_PER_UM2;
        // pJ / ns = mW; leakage µW → mW.
        let power_mw = (dyn_energy / time_ns) as f32 + leak_uw / 1000.0;

        SimOutput {
            cycles,
            period_ns,
            time_ns,
            mem_area_um2: mem_area,
            fu_area_um2,
            area_um2: mem_area + fu_area_um2,
            power_mw,
            dyn_energy_pj: dyn_energy,
            mem_accesses: acc.issued_mem,
            port_stalls: acc.port_stalls,
            stall_cycles: acc.stall_cycles,
        }
    }

    /// Schedule one design point: cycles + physical cost, exactly as the
    /// compat [`super::simulate_design`] computes them.
    ///
    /// `knobs.word_bytes` must match the word size this trace was
    /// compiled for (debug-asserted); `arena` may be dirty from any
    /// previous run — it is reset (allocation-preserving) here.
    pub fn simulate(&self, arena: &mut SimArena, knobs: &Knobs, design: &MemDesign) -> SimOutput {
        debug_assert_eq!(
            knobs.word_bytes.max(1),
            self.word_bytes,
            "CompiledTrace built for word_bytes={}, knobs ask {}",
            self.word_bytes,
            knobs.word_bytes
        );
        let trace = self.trace;
        let n = trace.len();
        let unroll = knobs.unroll.max(1);
        let alus = knobs.alus.max(1);

        arena.reset(self);
        let SimArena {
            remaining,
            subs_left,
            ready_reg,
            ready_alu,
            ready_mem,
            ready_rd,
            ready_wr,
            ring,
            used_rd,
            used_wr,
            retire_buf,
        } = arena;

        let cfg = PortCfg::of(design);
        let per_bank = cfg.per_bank;

        macro_rules! push_ready {
            ($nid:expr, $at:expr) => {{
                let nid: u32 = $nid;
                let at: u64 = $at;
                match self.class[nid as usize] {
                    NodeClass::Alu => ready_alu.push(Reverse((at, nid))),
                    NodeClass::Reg => ready_reg.push(Reverse((at, nid))),
                    NodeClass::Load => {
                        if per_bank {
                            ready_mem.push(Reverse((at, nid)));
                        } else {
                            ready_rd.push(Reverse((at, nid)));
                        }
                    }
                    NodeClass::Store => {
                        if per_bank {
                            ready_mem.push(Reverse((at, nid)));
                        } else {
                            ready_wr.push(Reverse((at, nid)));
                        }
                    }
                }
            }};
        }

        for i in 0..n {
            if remaining[i] == 0 {
                let gate = (trace.nodes[i].iter / unroll) as u64;
                push_ready!(i as u32, gate);
            }
        }

        let mut ring_pending: usize = 0;
        macro_rules! complete_at {
            ($cycle:expr, $nid:expr) => {{
                ring[($cycle % RING as u64) as usize].push($nid);
                ring_pending += 1;
            }};
        }

        // Per-cycle port counters: per bank for banked designs, a single
        // global pair for true-port designs.
        used_rd.clear();
        used_rd.resize(cfg.counters(), 0);
        used_wr.clear();
        used_wr.resize(cfg.counters(), 0);

        let mut cycle: u64 = 0;
        let mut done = 0usize;
        let mut acc = Accum::default();
        // One issue-state bundle for the whole run: every counter the
        // memory pipeline touches flows through `st`, so the issue loops
        // below stay single-`&mut` (NLL releases the `acc` field borrows
        // for the composition tail after the loop).
        let mut st = MemIssue {
            used_rd: used_rd.as_mut_slice(),
            used_wr: used_wr.as_mut_slice(),
            subs_left: subs_left.as_mut_slice(),
            n_reads: &mut acc.n_reads,
            n_writes: &mut acc.n_writes,
            port_stalls: &mut acc.port_stalls,
            issued_mem: &mut acc.issued_mem,
        };

        while done < n {
            // retire completions for this cycle (ring slot owns exactly
            // the events for `cycle`: pushes always target < RING cycles
            // ahead, and the advance step visits slots in order)
            let slot = (cycle % RING as u64) as usize;
            if !ring[slot].is_empty() {
                retire_buf.clear();
                retire_buf.append(&mut ring[slot]);
                ring_pending -= retire_buf.len();
                done += retire_buf.len();
                for &node in retire_buf.iter() {
                    for &s in trace.successors(node) {
                        remaining[s as usize] -= 1;
                        if remaining[s as usize] == 0 {
                            // The producer completes at the start of this
                            // cycle, so the consumer may issue this cycle.
                            let gate = (trace.nodes[s as usize].iter / unroll) as u64;
                            push_ready!(s, gate.max(cycle));
                        }
                    }
                }
            }

            // reset per-cycle port + FU counters
            for c in st.used_rd.iter_mut() {
                *c = 0;
            }
            for c in st.used_wr.iter_mut() {
                *c = 0;
            }
            let mut alu_slots = alus;
            let mut had_mem_stall = false;

            // register-promoted accesses are free: drain them all
            while let Some(&Reverse((rc, _))) = ready_reg.peek() {
                if rc > cycle {
                    break;
                }
                let Reverse((_, nid)) = ready_reg.pop().unwrap();
                *st.issued_mem += 1;
                acc.n_reg += 1;
                complete_at!(cycle + 1, nid);
            }

            // FU issue: stop the moment slots run out (no wasted pops)
            while alu_slots > 0 {
                match ready_alu.peek() {
                    Some(&Reverse((rc, _))) if rc <= cycle => {}
                    _ => break,
                }
                let Reverse((_, nid)) = ready_alu.pop().unwrap();
                let OpKind::Alu(kind) = trace.nodes[nid as usize].kind else { unreachable!() };
                alu_slots -= 1;
                acc.n_alu_energy += kind.energy_pj() as f64;
                complete_at!(cycle + kind.latency() as u64, nid);
            }

            if per_bank {
                // Banked designs model Aladdin's *static* schedule:
                // memory issues in program order; the first bank conflict
                // stalls all later memory ops this cycle (the compiler
                // cannot reorder around a dynamic conflict).
                while let Some(&Reverse((rc, _))) = ready_mem.peek() {
                    if rc > cycle {
                        break;
                    }
                    let Reverse((rc0, nid)) = ready_mem.pop().unwrap();
                    let left = self.try_mem(nid, &cfg, &mut st);
                    if left > 0 {
                        had_mem_stall = true;
                        // Re-queue under the ORIGINAL key so program order
                        // among ready ops is preserved across the stall.
                        ready_mem.push(Reverse((rc0, nid)));
                        break; // in-order: nothing younger may issue
                    }
                    complete_at!(cycle + 1, nid);
                }
            } else {
                // True multi-port (AMM / multipump / circuit MP): reads
                // and writes issue independently until their port class
                // is full.
                while st.used_rd[0] < cfg.rd_ports {
                    match ready_rd.peek() {
                        Some(&Reverse((rc, _))) if rc <= cycle => {}
                        _ => break,
                    }
                    let Reverse((rc0, nid)) = ready_rd.pop().unwrap();
                    let left = self.try_mem(nid, &cfg, &mut st);
                    if left > 0 {
                        had_mem_stall = true;
                        // Re-queue under the ORIGINAL key so program order
                        // among ready ops is preserved across the stall.
                        ready_rd.push(Reverse((rc0, nid)));
                        break;
                    }
                    complete_at!(cycle + 1, nid);
                }
                while st.used_wr[0] < cfg.wr_ports {
                    match ready_wr.peek() {
                        Some(&Reverse((rc, _))) if rc <= cycle => {}
                        _ => break,
                    }
                    let Reverse((rc0, nid)) = ready_wr.pop().unwrap();
                    let left = self.try_mem(nid, &cfg, &mut st);
                    if left > 0 {
                        had_mem_stall = true;
                        // Re-queue under the ORIGINAL key so program order
                        // among ready ops is preserved across the stall.
                        ready_wr.push(Reverse((rc0, nid)));
                        break;
                    }
                    complete_at!(cycle + 1, nid);
                }
            }
            if had_mem_stall {
                acc.stall_cycles += 1;
            }

            // advance to the next event (earliest ready or completion)
            let mut next = u64::MAX;
            for h in [&*ready_reg, &*ready_alu, &*ready_mem, &*ready_rd, &*ready_wr] {
                if let Some(&Reverse((c, _))) = h.peek() {
                    next = next.min(c);
                }
            }
            if ring_pending > 0 {
                // nearest non-empty ring slot within the next RING cycles
                for d in 1..=RING as u64 {
                    if !ring[((cycle + d) % RING as u64) as usize].is_empty() {
                        next = next.min(cycle + d);
                        break;
                    }
                }
            }
            if next == u64::MAX {
                break;
            }
            cycle = next.max(cycle + 1);
        }

        self.compose_output(design, alus, cycle, &acc)
    }
}
