//! Trace compilation — the once-per-`(trace, word_bytes)` layer.
//!
//! The monolithic `simulate_design` used to re-derive the same
//! trace-invariant state for *every* design point of a sweep: the
//! register-promotion mask, per-array sub-word counts, scratchpad word
//! indices, per-node resource classes, the FU-mix area blend and the
//! footprint depth. Across a Cartesian sweep those hundreds of
//! re-derivations (plus ~6 trace-sized allocations per run) were pure
//! waste. [`CompiledTrace`] hoists all of it: compile once per word
//! size, then run any number of `(design, unroll, alus)` points through
//! [`CompiledTrace::simulate`] with a reusable
//! [`SimArena`](super::SimArena).
//!
//! The compat wrappers [`super::simulate`] / [`super::simulate_design`]
//! are thin shims over this engine and produce byte-identical
//! [`SimOutput`]s (asserted by `tests/engine_golden.rs`).

use super::arena::{SimArena, RING};
use super::{footprint_depth, fu_area, promoted_arrays, promoted_reg_area, Knobs, SimOutput};
use super::{BASE_PERIOD_NS, FU_LEAK_UW_PER_UM2, REG_ACCESS_PJ};
use crate::mem::{MemDesign, PortModel};
use crate::trace::{OpKind, Trace};
use std::cmp::Reverse;

/// Which issue resource a node consumes (register promotion folded in;
/// the banked-vs-true-port split stays design-dependent and is resolved
/// at push time).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(super) enum NodeClass {
    /// Functional-unit op.
    Alu,
    /// Register-promoted memory access (free port-wise).
    Reg,
    /// Scratchpad load.
    Load,
    /// Scratchpad store.
    Store,
}

/// Map a memory op to its scratchpad *word* index (arrays are packed
/// back-to-back; narrower elements share words).
#[inline]
fn word_index(trace: &Trace, array: u16, index: u32, word_bytes: u32) -> u32 {
    let a = &trace.arrays[array as usize];
    (a.byte_addr(index) / word_bytes as u64) as u32
}

/// Everything the scheduler's inner loop needs that depends only on
/// `(trace, word_bytes)` — compiled once, shared (it is `Sync`) by every
/// worker evaluating design points at that word size.
pub struct CompiledTrace<'t> {
    /// The underlying trace.
    pub(super) trace: &'t Trace,
    /// Clamped scratchpad word size, bytes.
    pub(super) word_bytes: u32,
    /// Register-promotion mask per array.
    pub(super) promoted: Vec<bool>,
    /// Port acquisitions per access, per array (sub-word splitting).
    pub(super) subwords: Vec<u32>,
    /// Initial outstanding sub-accesses per node (0 for non-mem /
    /// promoted nodes) — the seed for `SimArena::subs_left`.
    pub(super) subs_init: Vec<u32>,
    /// Scratchpad word index per mem node.
    pub(super) base_words: Vec<u32>,
    /// Issue resource class per node.
    pub(super) class: Vec<NodeClass>,
    /// Scratchpad depth (words) holding every non-promoted array.
    pub(super) depth: u32,
    /// Area of the promoted-array register file, µm².
    pub(super) reg_area_um2: f32,
    /// Op-mix-blended FU area per ALU issue slot, µm².
    pub(super) fu_blend: f32,
}

impl<'t> CompiledTrace<'t> {
    /// Compile `trace` for one scratchpad word size (clamped to ≥ 1 B).
    pub fn new(trace: &'t Trace, word_bytes: u32) -> Self {
        let word_bytes = word_bytes.max(1);
        let promoted = promoted_arrays(trace);
        // Sub-word splitting: an element wider than the scratchpad word
        // takes ceil(elem/word) port acquisitions (consecutive words ⇒
        // consecutive cyclic banks) — the paper's word-size axis.
        let subwords: Vec<u32> = trace
            .arrays
            .iter()
            .map(|a| a.elem_bytes.div_ceil(word_bytes).max(1))
            .collect();
        let subs_init: Vec<u32> = trace
            .nodes
            .iter()
            .map(|nd| match nd.kind.mem_ref() {
                Some((a, _)) if !promoted[a as usize] => subwords[a as usize],
                _ => 0,
            })
            .collect();
        let base_words: Vec<u32> = trace
            .nodes
            .iter()
            .map(|nd| match nd.kind.mem_ref() {
                Some((a, i)) => word_index(trace, a, i, word_bytes),
                None => 0,
            })
            .collect();
        let class: Vec<NodeClass> = trace
            .nodes
            .iter()
            .map(|nd| match nd.kind {
                OpKind::Alu(_) => NodeClass::Alu,
                OpKind::Load { array, .. } if promoted[array as usize] => NodeClass::Reg,
                OpKind::Store { array, .. } if promoted[array as usize] => NodeClass::Reg,
                OpKind::Load { .. } => NodeClass::Load,
                OpKind::Store { .. } => NodeClass::Store,
            })
            .collect();
        CompiledTrace {
            trace,
            word_bytes,
            promoted,
            subwords,
            subs_init,
            base_words,
            class,
            depth: footprint_depth(trace, word_bytes),
            reg_area_um2: promoted_reg_area(trace),
            fu_blend: fu_area(trace, 1),
        }
    }

    /// The compiled trace's underlying DDG.
    pub fn trace(&self) -> &'t Trace {
        self.trace
    }

    /// The (clamped) word size this compilation is specialized for.
    pub fn word_bytes(&self) -> u32 {
        self.word_bytes
    }

    /// Scratchpad depth (words) for every non-promoted traced array.
    pub fn depth(&self) -> u32 {
        self.depth
    }

    /// Register-promotion mask per array.
    pub fn promoted(&self) -> &[bool] {
        &self.promoted
    }

    /// FU area for `alus` issue slots, µm² (op-mix blend precomputed).
    pub fn fu_area(&self, alus: u32) -> f32 {
        self.fu_blend * alus as f32
    }

    /// Schedule one design point: cycles + physical cost, exactly as the
    /// compat [`super::simulate_design`] computes them.
    ///
    /// `knobs.word_bytes` must match the word size this trace was
    /// compiled for (debug-asserted); `arena` may be dirty from any
    /// previous run — it is reset (allocation-preserving) here.
    pub fn simulate(&self, arena: &mut SimArena, knobs: &Knobs, design: &MemDesign) -> SimOutput {
        debug_assert_eq!(
            knobs.word_bytes.max(1),
            self.word_bytes,
            "CompiledTrace built for word_bytes={}, knobs ask {}",
            self.word_bytes,
            knobs.word_bytes
        );
        let trace = self.trace;
        let n = trace.len();
        let unroll = knobs.unroll.max(1);
        let alus = knobs.alus.max(1);

        arena.reset(self);
        let SimArena {
            remaining,
            subs_left,
            ready_reg,
            ready_alu,
            ready_mem,
            ready_rd,
            ready_wr,
            ring,
            used_rd,
            used_wr,
            retire_buf,
        } = arena;

        let (bank_count, rd_ports, wr_ports, shared, block) = match design.ports {
            PortModel::PerBank { banks, reads, writes, shared, block } => {
                (banks, reads, writes, shared, block)
            }
            PortModel::TruePorts { reads, writes } => (0, reads, writes, false, false),
        };
        let per_bank = bank_count > 0;
        // Block partitioning: contiguous address ranges per bank.
        let block_size = if block { design.depth.div_ceil(bank_count.max(1)).max(1) } else { 0 };

        macro_rules! push_ready {
            ($nid:expr, $at:expr) => {{
                let nid: u32 = $nid;
                let at: u64 = $at;
                match self.class[nid as usize] {
                    NodeClass::Alu => ready_alu.push(Reverse((at, nid))),
                    NodeClass::Reg => ready_reg.push(Reverse((at, nid))),
                    NodeClass::Load => {
                        if per_bank {
                            ready_mem.push(Reverse((at, nid)));
                        } else {
                            ready_rd.push(Reverse((at, nid)));
                        }
                    }
                    NodeClass::Store => {
                        if per_bank {
                            ready_mem.push(Reverse((at, nid)));
                        } else {
                            ready_wr.push(Reverse((at, nid)));
                        }
                    }
                }
            }};
        }

        for i in 0..n {
            if remaining[i] == 0 {
                let gate = (trace.nodes[i].iter / unroll) as u64;
                push_ready!(i as u32, gate);
            }
        }

        let mut ring_pending: usize = 0;
        macro_rules! complete_at {
            ($cycle:expr, $nid:expr) => {{
                ring[($cycle % RING as u64) as usize].push($nid);
                ring_pending += 1;
            }};
        }

        // Per-cycle port counters: per bank for banked designs, a single
        // global pair for true-port designs.
        let counters = if per_bank { bank_count as usize } else { 1 };
        used_rd.clear();
        used_rd.resize(counters, 0);
        used_wr.clear();
        used_wr.resize(counters, 0);

        let mut cycle: u64 = 0;
        let mut done = 0usize;
        let mut issued_mem: u64 = 0;
        let mut port_stalls: u64 = 0;
        let mut stall_cycles: u64 = 0;
        let mut n_reads: u64 = 0;
        let mut n_writes: u64 = 0;
        let mut n_reg: u64 = 0;
        let mut n_alu_energy: f64 = 0.0;

        while done < n {
            // retire completions for this cycle (ring slot owns exactly
            // the events for `cycle`: pushes always target < RING cycles
            // ahead, and the advance step visits slots in order)
            let slot = (cycle % RING as u64) as usize;
            if !ring[slot].is_empty() {
                retire_buf.clear();
                retire_buf.append(&mut ring[slot]);
                ring_pending -= retire_buf.len();
                done += retire_buf.len();
                for &node in retire_buf.iter() {
                    for &s in trace.successors(node) {
                        remaining[s as usize] -= 1;
                        if remaining[s as usize] == 0 {
                            // The producer completes at the start of this
                            // cycle, so the consumer may issue this cycle.
                            let gate = (trace.nodes[s as usize].iter / unroll) as u64;
                            push_ready!(s, gate.max(cycle));
                        }
                    }
                }
            }

            // reset per-cycle port + FU counters
            for c in used_rd.iter_mut() {
                *c = 0;
            }
            for c in used_wr.iter_mut() {
                *c = 0;
            }
            let mut alu_slots = alus;
            let mut had_mem_stall = false;

            // register-promoted accesses are free: drain them all
            while let Some(&Reverse((rc, _))) = ready_reg.peek() {
                if rc > cycle {
                    break;
                }
                let Reverse((_, nid)) = ready_reg.pop().unwrap();
                issued_mem += 1;
                n_reg += 1;
                complete_at!(cycle + 1, nid);
            }

            // FU issue: stop the moment slots run out (no wasted pops)
            while alu_slots > 0 {
                match ready_alu.peek() {
                    Some(&Reverse((rc, _))) if rc <= cycle => {}
                    _ => break,
                }
                let Reverse((_, nid)) = ready_alu.pop().unwrap();
                let OpKind::Alu(kind) = trace.nodes[nid as usize].kind else { unreachable!() };
                alu_slots -= 1;
                n_alu_energy += kind.energy_pj() as f64;
                complete_at!(cycle + kind.latency() as u64, nid);
            }

            // Try to issue the sub-word accesses of one memory op;
            // returns the number still outstanding after this cycle.
            let try_mem = |nid: u32,
                               used_rd: &mut Vec<u32>,
                               used_wr: &mut Vec<u32>,
                               n_reads: &mut u64,
                               n_writes: &mut u64,
                               subs_left: &mut Vec<u32>,
                               port_stalls: &mut u64,
                               issued_mem: &mut u64|
             -> u32 {
                let node = &trace.nodes[nid as usize];
                let (array, _index) = node.kind.mem_ref().unwrap();
                let is_write = matches!(node.kind, OpKind::Store { .. });
                let total_subs = self.subwords[array as usize];
                let base_word = self.base_words[nid as usize];
                let mut left = subs_left[nid as usize];
                let mut progressed = false;
                while left > 0 {
                    let sub = total_subs - left;
                    let slot = if !per_bank {
                        0
                    } else if block {
                        (((base_word + sub) / block_size).min(bank_count - 1)) as usize
                    } else {
                        ((base_word + sub) % bank_count) as usize
                    };
                    let ok = if shared {
                        // 1RW: reads and writes share one port per bank
                        if used_rd[slot] + used_wr[slot] < rd_ports.max(wr_ports) {
                            if is_write {
                                used_wr[slot] += 1;
                            } else {
                                used_rd[slot] += 1;
                            }
                            true
                        } else {
                            false
                        }
                    } else if is_write {
                        if used_wr[slot] < wr_ports {
                            used_wr[slot] += 1;
                            true
                        } else {
                            false
                        }
                    } else if used_rd[slot] < rd_ports {
                        used_rd[slot] += 1;
                        true
                    } else {
                        false
                    };
                    if !ok {
                        break;
                    }
                    left -= 1;
                    progressed = true;
                    if is_write {
                        *n_writes += 1;
                    } else {
                        *n_reads += 1;
                    }
                }
                subs_left[nid as usize] = left;
                if left == 0 {
                    *issued_mem += 1;
                } else if !progressed {
                    *port_stalls += 1;
                }
                left
            };

            if per_bank {
                // Banked designs model Aladdin's *static* schedule:
                // memory issues in program order; the first bank conflict
                // stalls all later memory ops this cycle (the compiler
                // cannot reorder around a dynamic conflict).
                while let Some(&Reverse((rc, _))) = ready_mem.peek() {
                    if rc > cycle {
                        break;
                    }
                    let Reverse((rc0, nid)) = ready_mem.pop().unwrap();
                    let left = try_mem(
                        nid, &mut *used_rd, &mut *used_wr, &mut n_reads, &mut n_writes,
                        &mut *subs_left, &mut port_stalls, &mut issued_mem,
                    );
                    if left > 0 {
                        had_mem_stall = true;
                        // Re-queue under the ORIGINAL key so program order
                        // among ready ops is preserved across the stall.
                        ready_mem.push(Reverse((rc0, nid)));
                        break; // in-order: nothing younger may issue
                    }
                    complete_at!(cycle + 1, nid);
                }
            } else {
                // True multi-port (AMM / multipump / circuit MP): reads
                // and writes issue independently until their port class
                // is full.
                while used_rd[0] < rd_ports {
                    match ready_rd.peek() {
                        Some(&Reverse((rc, _))) if rc <= cycle => {}
                        _ => break,
                    }
                    let Reverse((rc0, nid)) = ready_rd.pop().unwrap();
                    let left = try_mem(
                        nid, &mut *used_rd, &mut *used_wr, &mut n_reads, &mut n_writes,
                        &mut *subs_left, &mut port_stalls, &mut issued_mem,
                    );
                    if left > 0 {
                        had_mem_stall = true;
                        // Re-queue under the ORIGINAL key so program order
                        // among ready ops is preserved across the stall.
                        ready_rd.push(Reverse((rc0, nid)));
                        break;
                    }
                    complete_at!(cycle + 1, nid);
                }
                while used_wr[0] < wr_ports {
                    match ready_wr.peek() {
                        Some(&Reverse((rc, _))) if rc <= cycle => {}
                        _ => break,
                    }
                    let Reverse((rc0, nid)) = ready_wr.pop().unwrap();
                    let left = try_mem(
                        nid, &mut *used_rd, &mut *used_wr, &mut n_reads, &mut n_writes,
                        &mut *subs_left, &mut port_stalls, &mut issued_mem,
                    );
                    if left > 0 {
                        had_mem_stall = true;
                        // Re-queue under the ORIGINAL key so program order
                        // among ready ops is preserved across the stall.
                        ready_wr.push(Reverse((rc0, nid)));
                        break;
                    }
                    complete_at!(cycle + 1, nid);
                }
            }
            if had_mem_stall {
                stall_cycles += 1;
            }

            // advance to the next event (earliest ready or completion)
            let mut next = u64::MAX;
            for h in [&*ready_reg, &*ready_alu, &*ready_mem, &*ready_rd, &*ready_wr] {
                if let Some(&Reverse((c, _))) = h.peek() {
                    next = next.min(c);
                }
            }
            if ring_pending > 0 {
                // nearest non-empty ring slot within the next RING cycles
                for d in 1..=RING as u64 {
                    if !ring[((cycle + d) % RING as u64) as usize].is_empty() {
                        next = next.min(cycle + d);
                        break;
                    }
                }
            }
            if next == u64::MAX {
                break;
            }
            cycle = next.max(cycle + 1);
        }

        // --- physical composition (the Aladdin backend step) ----------
        let period_ns = BASE_PERIOD_NS.max(design.t_access_ns()) * design.freq_factor;
        let cycles = cycle.max(1);
        let time_ns = cycles as f64 * period_ns as f64;

        let mem_area = design.area_um2() + self.reg_area_um2;
        let fu_area_um2 = self.fu_area(alus);
        let dyn_energy = n_reads as f64 * design.e_read_pj() as f64
            + n_writes as f64 * design.e_write_pj() as f64
            + n_reg as f64 * REG_ACCESS_PJ
            + n_alu_energy;
        let leak_uw = design.leak_uw() + fu_area_um2 * FU_LEAK_UW_PER_UM2;
        // pJ / ns = mW; leakage µW → mW.
        let power_mw = (dyn_energy / time_ns) as f32 + leak_uw / 1000.0;

        SimOutput {
            cycles,
            period_ns,
            time_ns,
            mem_area_um2: mem_area,
            fu_area_um2,
            area_um2: mem_area + fu_area_um2,
            power_mw,
            dyn_energy_pj: dyn_energy,
            mem_accesses: issued_mem,
            port_stalls,
            stall_cycles,
        }
    }
}
