//! Reusable simulation arena — the scheduler's mutable working state.
//!
//! One cycle-accurate run needs ~6 trace-sized buffers (dependence
//! counters, sub-access counters, per-class ready heaps, the completion
//! ring). Allocating them per design point dominated sweep wall-clock,
//! so the engine keeps them in a [`SimArena`] that is [`reset`] between
//! runs instead of reallocated: each
//! [`crate::util::pool::parallel_map_with`] worker owns one arena for
//! every point it evaluates within a word-size group (the sweep layers
//! dispatch one worker pool per group).
//!
//! [`reset`]: SimArena::reset

use super::compile::CompiledTrace;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Completion events live in a ring of buckets instead of a heap: every
/// op latency is ≤ 16 cycles, so a 32-slot ring indexed by `cycle % 32`
/// gives O(1) push/retire (§Perf iteration 2).
pub(super) const RING: usize = 32;

/// Ready min-heap keyed by `(ready_cycle, node id)`.
pub(super) type Heap = BinaryHeap<Reverse<(u64, u32)>>;

/// Reusable mutable state for one scheduler run.
///
/// Create once per worker thread, pass to
/// [`CompiledTrace::simulate`] for any number of runs — including runs
/// over *different* traces; the engine resets it (preserving the
/// allocations) at the start of every run.
pub struct SimArena {
    /// Unsatisfied-predecessor count per node.
    pub(super) remaining: Vec<u32>,
    /// Sub-word accesses still outstanding per node.
    pub(super) subs_left: Vec<u32>,
    /// Register-promoted accesses (free, always drained).
    pub(super) ready_reg: Heap,
    /// FU ops.
    pub(super) ready_alu: Heap,
    /// Banked designs (single queue: program-order issue).
    pub(super) ready_mem: Heap,
    /// True-port designs: independent read port queue.
    pub(super) ready_rd: Heap,
    /// True-port designs: independent write port queue.
    pub(super) ready_wr: Heap,
    /// Completion ring (`RING` slots of node ids).
    pub(super) ring: Vec<Vec<u32>>,
    /// Per-cycle read-port counters (per bank, or one global slot).
    pub(super) used_rd: Vec<u32>,
    /// Per-cycle write-port counters.
    pub(super) used_wr: Vec<u32>,
    /// Scratch buffer for the retire step.
    pub(super) retire_buf: Vec<u32>,
}

impl SimArena {
    /// Empty arena; buffers grow on first use and are then reused.
    pub fn new() -> Self {
        SimArena {
            remaining: Vec::new(),
            subs_left: Vec::new(),
            ready_reg: BinaryHeap::new(),
            ready_alu: BinaryHeap::new(),
            ready_mem: BinaryHeap::new(),
            ready_rd: BinaryHeap::new(),
            ready_wr: BinaryHeap::new(),
            ring: vec![Vec::new(); RING],
            used_rd: Vec::new(),
            used_wr: Vec::new(),
            retire_buf: Vec::new(),
        }
    }

    /// Re-initialize for a run of `ct`, keeping every allocation. Safe to
    /// call on an arena dirtied by a run over a different trace (heaps
    /// and ring slots are drained defensively, counters re-seeded from
    /// the compiled trace).
    pub(super) fn reset(&mut self, ct: &CompiledTrace<'_>) {
        self.remaining.clear();
        self.remaining.extend_from_slice(&ct.trace.pred_count);
        self.subs_left.clear();
        self.subs_left.extend_from_slice(&ct.subs_init);
        self.ready_reg.clear();
        self.ready_alu.clear();
        self.ready_mem.clear();
        self.ready_rd.clear();
        self.ready_wr.clear();
        for slot in &mut self.ring {
            slot.clear();
        }
        self.retire_buf.clear();
    }
}

impl Default for SimArena {
    fn default() -> Self {
        Self::new()
    }
}
