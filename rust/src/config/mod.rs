//! Sweep/config files: TOML-subset documents under `configs/` describing
//! a benchmark run — the framework's equivalent of Aladdin's per-kernel
//! config files.
//!
//! ```toml
//! benchmark = "gemm"
//! scale = "paper"
//!
//! [sweep]
//! unrolls = [1, 2, 4, 8, 16]
//! word_bytes = [4, 8]
//! alus = [2, 4, 8]
//! bank_counts = [1, 2, 4, 8, 16, 32]
//! multipump = true
//! lvt = true
//! # extra memory models by registry id (any registered organization)
//! models = ["xorflat4r2w", "cmp4r4w"]
//!
//! [[amm]]
//! read_ports = 2
//! write_ports = 1
//! ```

use crate::dse::Sweep;
use crate::error::{Error, Result};
use crate::suite::Scale;
use crate::util::tomlmini::{self, Value};
use std::path::Path;

/// A parsed run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Benchmark name (must be in [`crate::suite::ALL_BENCHMARKS`]).
    pub benchmark: String,
    /// Workload scale.
    pub scale: Scale,
    /// The sweep to run.
    pub sweep: Sweep,
    /// Output CSV path (default `results/<benchmark>.csv`).
    pub out_csv: Option<String>,
}

impl RunConfig {
    /// Build the [`crate::Explorer`] this configuration describes.
    pub fn explorer(&self) -> crate::Explorer {
        crate::Explorer::new()
            .workload(self.benchmark.clone(), self.scale)
            .sweep(self.sweep.clone())
    }
}

/// Parse a config file.
pub fn load(path: &Path) -> Result<RunConfig> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::io(format!("read config {}", path.display()), e))?;
    parse(&text)
}

/// Parse config text.
pub fn parse(text: &str) -> Result<RunConfig> {
    let doc = tomlmini::parse(text).map_err(|e| Error::config(e.to_string()))?;
    let benchmark = doc
        .root
        .get("benchmark")
        .and_then(Value::as_str)
        .ok_or_else(|| Error::config("missing `benchmark`"))?
        .to_string();
    if !crate::suite::ALL_BENCHMARKS.contains(&benchmark.as_str()) {
        return Err(Error::UnknownBenchmark { name: benchmark });
    }
    let scale = match doc.root.get("scale").and_then(Value::as_str).unwrap_or("paper") {
        "tiny" => Scale::Tiny,
        "paper" => Scale::Paper,
        "large" => Scale::Large,
        other => return Err(Error::config(format!("unknown scale {other:?} (tiny|paper|large)"))),
    };
    let mut sweep = Sweep::default();
    if let Some(t) = doc.table("sweep") {
        if let Some(v) = t.get("unrolls") {
            sweep.unrolls = ints(v, "unrolls")?;
        }
        if let Some(v) = t.get("word_bytes") {
            sweep.word_bytes = ints(v, "word_bytes")?;
        }
        if let Some(v) = t.get("alus") {
            sweep.alus = ints(v, "alus")?;
        }
        if let Some(v) = t.get("bank_counts") {
            sweep.bank_counts = ints(v, "bank_counts")?;
        }
        if let Some(v) = t.get("multipump") {
            sweep.include_multipump =
                v.as_bool().ok_or_else(|| Error::config("multipump must be bool"))?;
        }
        if let Some(v) = t.get("lvt") {
            sweep.include_lvt = v.as_bool().ok_or_else(|| Error::config("lvt must be bool"))?;
        }
        if let Some(v) = t.get("block_partitioning") {
            sweep.include_block =
                v.as_bool().ok_or_else(|| Error::config("block_partitioning must be bool"))?;
        }
        if let Some(v) = t.get("flat_xor") {
            sweep.include_flat_xor =
                v.as_bool().ok_or_else(|| Error::config("flat_xor must be bool"))?;
        }
        if let Some(v) = t.get("models") {
            // Extra organizations by registry id — validated through the
            // model registry, so registered extensions work here too.
            let ids = v.as_array().ok_or_else(|| Error::config("models must be an array"))?;
            for id in ids {
                let id = id
                    .as_str()
                    .ok_or_else(|| Error::config("models entries must be strings"))?;
                if crate::mem::parse_model(id).is_none() {
                    return Err(Error::UnknownModel { id: id.to_string() });
                }
                sweep.extra_models.push(id.to_string());
            }
        }
        if let Some(v) = t.get("threads") {
            sweep.threads =
                v.as_int().ok_or_else(|| Error::config("threads must be int"))? as usize;
        }
    }
    let amms = doc.array_of("amm");
    if !amms.is_empty() {
        sweep.amm_ports = amms
            .iter()
            .map(|t| {
                let r = t
                    .get("read_ports")
                    .and_then(Value::as_int)
                    .ok_or_else(|| Error::config("amm.read_ports missing or not an int"))?;
                let w = t
                    .get("write_ports")
                    .and_then(Value::as_int)
                    .ok_or_else(|| Error::config("amm.write_ports missing or not an int"))?;
                Ok((r as u32, w as u32))
            })
            .collect::<Result<Vec<_>>>()?;
    }
    let out_csv = doc.root.get("out_csv").and_then(Value::as_str).map(|s| s.to_string());
    Ok(RunConfig { benchmark, scale, sweep, out_csv })
}

fn ints(v: &Value, what: &str) -> Result<Vec<u32>> {
    v.as_array()
        .ok_or_else(|| Error::config(format!("{what} must be an array")))?
        .iter()
        .map(|x| {
            x.as_int()
                .map(|i| i as u32)
                .ok_or_else(|| Error::config(format!("{what}: not an int")))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = parse(
            r#"
            benchmark = "gemm"
            scale = "tiny"
            out_csv = "results/custom.csv"
            [sweep]
            unrolls = [1, 8]
            word_bytes = [8]
            alus = [4]
            bank_counts = [1, 16]
            multipump = false
            lvt = false
            models = ["cmp4r2w"]
            [[amm]]
            read_ports = 2
            write_ports = 2
            "#,
        )
        .unwrap();
        assert_eq!(cfg.benchmark, "gemm");
        assert_eq!(cfg.scale, Scale::Tiny);
        assert_eq!(cfg.sweep.unrolls, vec![1, 8]);
        assert_eq!(cfg.sweep.amm_ports, vec![(2, 2)]);
        assert!(!cfg.sweep.include_multipump);
        assert_eq!(cfg.sweep.extra_models, vec!["cmp4r2w".to_string()]);
        assert_eq!(cfg.out_csv.as_deref(), Some("results/custom.csv"));
    }

    #[test]
    fn defaults_fill_in() {
        let cfg = parse("benchmark = \"kmp\"\n").unwrap();
        assert_eq!(cfg.scale, Scale::Paper);
        assert_eq!(cfg.sweep.unrolls, Sweep::default().unrolls);
        assert!(cfg.sweep.extra_models.is_empty());
    }

    #[test]
    fn rejects_unknown_benchmark() {
        let err = parse("benchmark = \"nope\"\n").unwrap_err();
        assert!(matches!(err, Error::UnknownBenchmark { .. }), "{err}");
    }

    #[test]
    fn rejects_bad_scale() {
        assert!(parse("benchmark = \"kmp\"\nscale = \"huge\"\n").is_err());
    }

    #[test]
    fn rejects_unknown_model_id() {
        let err = parse("benchmark = \"kmp\"\n[sweep]\nmodels = [\"warp9\"]\n").unwrap_err();
        assert!(matches!(err, Error::UnknownModel { .. }), "{err}");
    }

    #[test]
    fn explorer_builder_carries_the_config() {
        let cfg = parse("benchmark = \"stencil2d\"\nscale = \"tiny\"\n").unwrap();
        // The facade validates the same invariants the parser enforced.
        let ex = cfg.explorer().offline().run().unwrap();
        assert_eq!(ex.benchmark, "stencil2d");
    }
}
