//! Sweep/config files: TOML-subset documents under `configs/` describing
//! a run — the framework's equivalent of Aladdin's per-kernel config
//! files. Every parse lowers to a [`CampaignSpec`], the crate's single
//! plan artifact (see [`crate::spec`]).
//!
//! Single-benchmark form (the original `repro sweep` shape):
//!
//! ```toml
//! benchmark = "gemm"
//! scale = "paper"
//!
//! [sweep]
//! unrolls = [1, 2, 4, 8, 16]
//! word_bytes = [4, 8]
//! alus = [2, 4, 8]
//! bank_counts = [1, 2, 4, 8, 16, 32]
//! multipump = true
//! lvt = true
//! # extra memory models by registry id (any registered organization)
//! models = ["xorflat4r2w", "cmp4r4w"]
//!
//! [[amm]]
//! read_ports = 2
//! write_ports = 1
//! ```
//!
//! Suite form: replace the top-level `benchmark` with a `[campaign]`
//! table (see `configs/suite.toml`) and the file describes a whole
//! multi-benchmark campaign — shardable across hosts and runnable with
//! `repro run`:
//!
//! ```toml
//! scale = "paper"
//!
//! [campaign]
//! benchmarks = ["fft", "gemm", "kmp", "md-knn"]
//! locality_only = ["aes", "bfs"]
//! sink = "results/suite.jsonl"
//! threads = 8
//! shard = "0/2"   # usually set per host via `repro run --shard i/n`
//! ```

use crate::dse::Sweep;
use crate::error::{Error, Result};
use crate::spec::{self, CampaignSpec, PlanEntry, Shard, ShardStrategy};
use crate::suite::Scale;
use crate::util::tomlmini::{self, Table, Value};
use std::path::Path;

/// A parsed run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Primary benchmark: the top-level `benchmark` key, or the first
    /// plan entry of a `[campaign]` config (compat accessor — the full
    /// plan lives in [`RunConfig::campaign`]).
    pub benchmark: String,
    /// Workload scale.
    pub scale: Scale,
    /// The sweep to run.
    pub sweep: Sweep,
    /// Output CSV path (default `results/<benchmark>.csv`).
    pub out_csv: Option<String>,
    /// The lowered campaign spec — what this file *means*. For a
    /// single-benchmark config this is a one-entry plan.
    pub campaign: CampaignSpec,
}

impl RunConfig {
    /// Build the [`crate::Explorer`] this configuration describes
    /// (single-benchmark compat path; campaigns use
    /// [`RunConfig::campaign`]).
    pub fn explorer(&self) -> crate::Explorer {
        crate::Explorer::new()
            .workload(self.benchmark.clone(), self.scale)
            .sweep(self.sweep.clone())
    }
}

/// Parse a config file.
pub fn load(path: &Path) -> Result<RunConfig> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::io(format!("read config {}", path.display()), e))?;
    parse(&text)
}

/// Parse config text.
pub fn parse(text: &str) -> Result<RunConfig> {
    let doc = tomlmini::parse(text).map_err(|e| Error::config(e.to_string()))?;
    // Spec evolution: an explicit schema tag must be one we understand;
    // a missing tag is read as v1 (every pre-tag document is v1).
    if let Some(v) = doc.root.get("schema") {
        let tag = v.as_str().ok_or_else(|| Error::config("schema must be a string"))?;
        if tag != spec::SCHEMA {
            return Err(Error::config(format!(
                "unsupported spec schema {tag:?} (this build reads {:?})",
                spec::SCHEMA
            )));
        }
    }
    let scale = match doc.root.get("scale").and_then(Value::as_str).unwrap_or("paper") {
        "tiny" => Scale::Tiny,
        "paper" => Scale::Paper,
        "large" => Scale::Large,
        other => return Err(Error::config(format!("unknown scale {other:?} (tiny|paper|large)"))),
    };
    let mut sweep = Sweep::default();
    if let Some(t) = doc.table("sweep") {
        if let Some(v) = t.get("unrolls") {
            sweep.unrolls = ints(v, "unrolls")?;
        }
        if let Some(v) = t.get("word_bytes") {
            sweep.word_bytes = ints(v, "word_bytes")?;
        }
        if let Some(v) = t.get("alus") {
            sweep.alus = ints(v, "alus")?;
        }
        if let Some(v) = t.get("bank_counts") {
            sweep.bank_counts = ints(v, "bank_counts")?;
        }
        if let Some(v) = t.get("multipump") {
            sweep.include_multipump =
                v.as_bool().ok_or_else(|| Error::config("multipump must be bool"))?;
        }
        if let Some(v) = t.get("lvt") {
            sweep.include_lvt = v.as_bool().ok_or_else(|| Error::config("lvt must be bool"))?;
        }
        if let Some(v) = t.get("dual_port") {
            sweep.include_dual_port =
                v.as_bool().ok_or_else(|| Error::config("dual_port must be bool"))?;
        }
        if let Some(v) = t.get("block_partitioning") {
            sweep.include_block =
                v.as_bool().ok_or_else(|| Error::config("block_partitioning must be bool"))?;
        }
        if let Some(v) = t.get("flat_xor") {
            sweep.include_flat_xor =
                v.as_bool().ok_or_else(|| Error::config("flat_xor must be bool"))?;
        }
        if let Some(v) = t.get("models") {
            // Extra organizations by registry id — validated through the
            // model registry, so registered extensions work here too.
            let ids = v.as_array().ok_or_else(|| Error::config("models must be an array"))?;
            for id in ids {
                let id = id
                    .as_str()
                    .ok_or_else(|| Error::config("models entries must be strings"))?;
                if crate::mem::parse_model(id).is_none() {
                    return Err(Error::UnknownModel { id: id.to_string() });
                }
                sweep.extra_models.push(id.to_string());
            }
        }
        if let Some(v) = t.get("threads") {
            sweep.threads =
                v.as_int().ok_or_else(|| Error::config("threads must be int"))? as usize;
        }
        // Batch width: 0 (default) auto-calibrates per compatible group
        // from group size and trace footprint; explicit values are
        // clamped to `dse::MAX_LANES` (32), and 1 forces the scalar
        // engine. Purely a scheduling knob — results are bit-identical.
        if let Some(v) = t.get("lanes") {
            sweep.lanes =
                v.as_int().ok_or_else(|| Error::config("lanes must be int"))? as usize;
        }
    }
    let amms = doc.array_of("amm");
    if !amms.is_empty() {
        sweep.amm_ports = amms
            .iter()
            .map(|t| {
                let r = t
                    .get("read_ports")
                    .and_then(Value::as_int)
                    .ok_or_else(|| Error::config("amm.read_ports missing or not an int"))?;
                let w = t
                    .get("write_ports")
                    .and_then(Value::as_int)
                    .ok_or_else(|| Error::config("amm.write_ports missing or not an int"))?;
                Ok((r as u32, w as u32))
            })
            .collect::<Result<Vec<_>>>()?;
    }
    let out_csv = doc.root.get("out_csv").and_then(Value::as_str).map(|s| s.to_string());

    // ---- plan: [campaign] table, or the single top-level benchmark ----
    let mut spec = CampaignSpec { scale, sweep, ..CampaignSpec::default() };
    if let Some(t) = doc.table("campaign") {
        if doc.root.contains_key("benchmark") {
            return Err(Error::config(
                "give either a top-level `benchmark` or a `[campaign]` table, not both",
            ));
        }
        for name in names(t, "benchmarks")? {
            spec.plan.push(PlanEntry { name, swept: true });
        }
        for name in names(t, "locality_only")? {
            spec.plan.push(PlanEntry { name, swept: false });
        }
        if let Some(v) = t.get("sink") {
            let s = v.as_str().ok_or_else(|| Error::config("campaign.sink must be a string"))?;
            spec.sink = Some(s.into());
        }
        if let Some(v) = t.get("cost_store") {
            let s = v
                .as_str()
                .ok_or_else(|| Error::config("campaign.cost_store must be a string"))?;
            spec.cost_store = Some(s.into());
        }
        if let Some(v) = t.get("sim_store") {
            let s = v
                .as_str()
                .ok_or_else(|| Error::config("campaign.sim_store must be a string"))?;
            spec.sim_store = Some(s.into());
        }
        if let Some(v) = t.get("weights") {
            let s = v
                .as_str()
                .ok_or_else(|| Error::config("campaign.weights must be a string"))?;
            spec.weights = Some(s.into());
        }
        if let Some(v) = t.get("threads") {
            spec.threads =
                v.as_int().ok_or_else(|| Error::config("campaign.threads must be int"))? as usize;
        }
        if let Some(v) = t.get("shard") {
            let s =
                v.as_str().ok_or_else(|| Error::config("campaign.shard must be a string"))?;
            spec.shard = Some(Shard::parse(s)?);
        }
        if let Some(v) = t.get("shard_strategy") {
            let s = v
                .as_str()
                .ok_or_else(|| Error::config("campaign.shard_strategy must be a string"))?;
            spec.shard_strategy = ShardStrategy::parse(s).ok_or_else(|| {
                Error::config(format!("unknown shard_strategy {s:?} (hash|weighted)"))
            })?;
        }
    } else {
        let name = doc
            .root
            .get("benchmark")
            .and_then(Value::as_str)
            .ok_or_else(|| Error::config("missing `benchmark` (or a `[campaign]` table)"))?
            .to_string();
        spec.plan.push(PlanEntry { name, swept: true });
    }
    spec.validate()?;
    Ok(RunConfig {
        benchmark: spec.plan[0].name.clone(),
        scale,
        sweep: spec.sweep.clone(),
        out_csv,
        campaign: spec,
    })
}

fn names(t: &Table, key: &str) -> Result<Vec<String>> {
    let Some(v) = t.get(key) else { return Ok(Vec::new()) };
    v.as_array()
        .ok_or_else(|| Error::config(format!("campaign.{key} must be an array")))?
        .iter()
        .map(|x| {
            x.as_str()
                .map(str::to_string)
                .ok_or_else(|| Error::config(format!("campaign.{key} entries must be strings")))
        })
        .collect()
}

fn ints(v: &Value, what: &str) -> Result<Vec<u32>> {
    v.as_array()
        .ok_or_else(|| Error::config(format!("{what} must be an array")))?
        .iter()
        .map(|x| {
            x.as_int()
                .map(|i| i as u32)
                .ok_or_else(|| Error::config(format!("{what}: not an int")))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = parse(
            r#"
            benchmark = "gemm"
            scale = "tiny"
            out_csv = "results/custom.csv"
            [sweep]
            unrolls = [1, 8]
            word_bytes = [8]
            alus = [4]
            bank_counts = [1, 16]
            multipump = false
            lvt = false
            models = ["cmp4r2w"]
            [[amm]]
            read_ports = 2
            write_ports = 2
            "#,
        )
        .unwrap();
        assert_eq!(cfg.benchmark, "gemm");
        assert_eq!(cfg.scale, Scale::Tiny);
        assert_eq!(cfg.sweep.unrolls, vec![1, 8]);
        assert_eq!(cfg.sweep.amm_ports, vec![(2, 2)]);
        assert!(!cfg.sweep.include_multipump);
        assert_eq!(cfg.sweep.extra_models, vec!["cmp4r2w".to_string()]);
        assert_eq!(cfg.out_csv.as_deref(), Some("results/custom.csv"));
        // the single-benchmark form lowers to a one-entry plan
        assert_eq!(cfg.campaign.plan, vec![PlanEntry { name: "gemm".into(), swept: true }]);
        assert_eq!(cfg.campaign.sweep, cfg.sweep);
        assert!(cfg.campaign.sink.is_none());
        assert!(cfg.campaign.shard.is_none());
    }

    #[test]
    fn parses_campaign_table() {
        let cfg = parse(
            r#"
            scale = "tiny"
            [campaign]
            benchmarks = ["gemm", "fft"]
            locality_only = ["kmp"]
            sink = "results/suite.jsonl"
            threads = 6
            shard = "1/3"
            "#,
        )
        .unwrap();
        let spec = &cfg.campaign;
        assert_eq!(cfg.benchmark, "gemm", "compat accessor = first plan entry");
        assert_eq!(spec.swept(), ["gemm", "fft"]);
        assert_eq!(spec.locality_names(), ["kmp"]);
        assert_eq!(spec.sink.as_deref(), Some(Path::new("results/suite.jsonl")));
        assert_eq!(spec.threads, 6);
        assert_eq!(spec.shard, Some(Shard { index: 1, count: 3 }));
        assert_eq!(spec.scale, Scale::Tiny);
    }

    #[test]
    fn campaign_table_excludes_top_level_benchmark() {
        let err = parse(
            "benchmark = \"gemm\"\n[campaign]\nbenchmarks = [\"fft\"]\n",
        )
        .unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
    }

    #[test]
    fn campaign_table_rejects_bad_entries() {
        assert!(parse("[campaign]\nbenchmarks = [\"nope\"]\n").is_err());
        assert!(parse("[campaign]\nbenchmarks = [1]\n").is_err());
        assert!(parse("[campaign]\nbenchmarks = [\"gemm\"]\nshard = \"9/2\"\n").is_err());
        // an empty plan is a config error, not a silent no-op campaign
        assert!(parse("[campaign]\nbenchmarks = []\n").is_err());
        assert!(
            parse("[campaign]\nbenchmarks = [\"gemm\"]\nshard_strategy = \"rr\"\n").is_err(),
            "unknown shard strategies fail loudly"
        );
    }

    #[test]
    fn schema_tag_accepts_v1_and_rejects_the_future() {
        // missing tag = v1
        assert!(parse("benchmark = \"gemm\"\n").is_ok());
        let tagged = format!("schema = \"{}\"\nbenchmark = \"gemm\"\n", spec::SCHEMA);
        assert!(parse(&tagged).is_ok());
        let err =
            parse("schema = \"campaign-spec/v9\"\nbenchmark = \"gemm\"\n").unwrap_err();
        assert!(err.to_string().contains("campaign-spec/v9"), "{err}");
        assert!(parse("schema = 7\nbenchmark = \"gemm\"\n").is_err());
    }

    #[test]
    fn campaign_table_parses_cost_store_and_shard_strategy() {
        let cfg = parse(
            r#"
            [campaign]
            benchmarks = ["gemm"]
            cost_store = "results/suite.cost.jsonl"
            sim_store = "results/suite.sim.jsonl"
            weights = "results/weights.jsonl"
            shard = "0/2"
            shard_strategy = "weighted"
            "#,
        )
        .unwrap();
        let spec = &cfg.campaign;
        assert_eq!(
            spec.cost_store.as_deref(),
            Some(Path::new("results/suite.cost.jsonl"))
        );
        assert_eq!(
            spec.sim_store.as_deref(),
            Some(Path::new("results/suite.sim.jsonl"))
        );
        assert_eq!(spec.weights.as_deref(), Some(Path::new("results/weights.jsonl")));
        assert_eq!(spec.shard_strategy, ShardStrategy::Weighted);
        // round-trip: the canonical TOML re-parses to the same spec
        assert_eq!(CampaignSpec::parse(&spec.to_toml()).unwrap(), *spec);
        // defaults: no store, no weight table, hash strategy
        let plain = parse("benchmark = \"gemm\"\n").unwrap();
        assert!(plain.campaign.cost_store.is_none());
        assert!(plain.campaign.sim_store.is_none());
        assert!(plain.campaign.weights.is_none());
        assert_eq!(plain.campaign.shard_strategy, ShardStrategy::Hash);
    }

    #[test]
    fn defaults_fill_in() {
        let cfg = parse("benchmark = \"kmp\"\n").unwrap();
        assert_eq!(cfg.scale, Scale::Paper);
        assert_eq!(cfg.sweep.unrolls, Sweep::default().unrolls);
        assert!(cfg.sweep.extra_models.is_empty());
    }

    #[test]
    fn rejects_unknown_benchmark() {
        let err = parse("benchmark = \"nope\"\n").unwrap_err();
        assert!(matches!(err, Error::UnknownBenchmark { .. }), "{err}");
    }

    #[test]
    fn rejects_bad_scale() {
        assert!(parse("benchmark = \"kmp\"\nscale = \"huge\"\n").is_err());
    }

    #[test]
    fn rejects_unknown_model_id() {
        let err = parse("benchmark = \"kmp\"\n[sweep]\nmodels = [\"warp9\"]\n").unwrap_err();
        assert!(matches!(err, Error::UnknownModel { .. }), "{err}");
    }

    #[test]
    fn explorer_builder_carries_the_config() {
        let cfg = parse("benchmark = \"stencil2d\"\nscale = \"tiny\"\n").unwrap();
        // The facade validates the same invariants the parser enforced.
        let ex = cfg.explorer().offline().run().unwrap();
        assert_eq!(ex.benchmark, "stencil2d");
    }
}
