//! Merge sharded campaign sinks back into one result set.
//!
//! A sharded campaign (see [`crate::spec::Shard`]) leaves one JSONL
//! sink per shard. [`merge`] reconciles them against the plan: every
//! sink is loaded into one `(benchmark, scale, point id)`-keyed map
//! (cross-sink duplicates collapse, conflicts keep the first record and
//! warn), then the spec's full cross-product is walked in enumeration
//! order, pulling each expected record into a per-benchmark
//! [`Exploration`]. The result is a [`CampaignOutcome`]
//! indistinguishable from an unsharded run — same plan order, same
//! point order, bit-identical payloads — so its fig5 CSV matches the
//! unsharded campaign's byte for byte (pinned by
//! `tests/spec_shard.rs`). Locality is recomputed from the (memoized)
//! workload traces; it is deterministic and never recorded in sinks.
//!
//! [`merge_loose`] is the plan-free variant behind bare
//! `repro merge <sinks...>`: with no spec to enumerate from, it trusts
//! the records — benchmarks appear in first-seen order and coverage
//! cannot be checked, so prefer passing `--config` when the plan file
//! is at hand.

use super::sink;
use super::CampaignOutcome;
use crate::dse::{self, DesignPoint};
use crate::error::{Error, Result};
use crate::explore::Exploration;
use crate::locality;
use crate::spec::CampaignSpec;
use crate::suite::{self, Scale};
use crate::util::log;
use std::collections::HashMap;
use std::path::Path;

/// A merged result set plus reconciliation accounting.
#[derive(Clone, Debug)]
pub struct Merged {
    /// The reassembled campaign result (backend `None`: nothing was
    /// simulated here, every point came from a sink).
    pub outcome: CampaignOutcome,
    /// Parseable records read across all sinks.
    pub records: usize,
    /// Cross-sink identical repeats, collapsed.
    pub duplicates: usize,
    /// Cross-sink same-key records with differing payloads (first wins).
    pub conflicts: usize,
    /// Sinks ending in a torn (newline-less) tail.
    pub torn_tails: usize,
    /// Records matching no planned unit (wrong scale, sweep, or
    /// benchmark set). Always 0 for [`merge_loose`].
    pub foreign: usize,
    /// Planned `(benchmark, point id)` units no sink supplied (a shard
    /// is missing or died mid-run). Always empty for [`merge_loose`].
    pub missing: Vec<(String, String)>,
}

/// Merge shard sinks against a plan: load + dedupe every sink, then
/// reassemble the spec's cross-product in enumeration order. The
/// spec's own `shard` field is ignored — a merge spans all shards.
pub fn merge<P: AsRef<Path>>(spec: &CampaignSpec, sinks: &[P]) -> Result<Merged> {
    spec.validate()?;
    if sinks.is_empty() {
        return Err(Error::config("merge: no sink files given"));
    }
    let mut map: HashMap<sink::Key, DesignPoint> = HashMap::new();
    let mut merged = empty_accounting();
    for path in sinks {
        absorb(path.as_ref(), &mut map, &mut merged)?;
    }

    let points = spec.sweep.points();
    let mut explorations = Vec::with_capacity(spec.plan.len());
    let mut used = 0usize;
    for e in &spec.plan {
        let wl = suite::generate_cached(&e.name, spec.scale);
        let mut pts: Vec<DesignPoint> = Vec::new();
        if e.swept {
            let designs = dse::build_designs(&wl.trace, &points);
            pts.reserve(points.len());
            for (p, design) in points.iter().zip(designs) {
                let id = dse::point_id(&design.id, &p.knobs);
                match map.remove(&sink::key(&e.name, spec.scale, &id)) {
                    Some(rec) => {
                        pts.push(rec);
                        used += 1;
                    }
                    None => merged.missing.push((e.name.clone(), id)),
                }
            }
        }
        explorations.push(exploration(&e.name, spec.scale, &wl, pts));
    }
    merged.foreign = map.len();
    if merged.foreign > 0 {
        log::warn(format!(
            "merge: {} record(s) match no planned unit (different scale, sweep or benchmark set?)",
            merged.foreign
        ));
    }
    merged.outcome = outcome(spec.scale, explorations, used);
    Ok(merged)
}

/// Plan-free merge: reassemble purely from the records. Benchmarks
/// appear in first-seen order across the sinks (every one swept, no
/// locality-only rows), points in first-seen order within a benchmark.
/// All records must share one scale. Coverage cannot be verified —
/// prefer [`merge`] with the campaign's config when available.
pub fn merge_loose<P: AsRef<Path>>(sinks: &[P]) -> Result<Merged> {
    if sinks.is_empty() {
        return Err(Error::config("merge: no sink files given"));
    }
    let mut map: HashMap<sink::Key, DesignPoint> = HashMap::new();
    let mut merged = empty_accounting();
    // load() preserves file order; replay it to recover first-seen order
    let mut order: Vec<(String, Vec<String>)> = Vec::new();
    let mut scale: Option<Scale> = None;
    for path in sinks {
        let (records, _) = sink::load(path.as_ref())?;
        for (bench, rec_scale, p) in &records {
            match scale {
                None => scale = Some(*rec_scale),
                Some(s) if s != *rec_scale => {
                    return Err(Error::config(format!(
                        "merge: sinks mix scales ({} vs {}); merge one scale at a time",
                        s.as_str(),
                        rec_scale.as_str()
                    )));
                }
                Some(_) => {}
            }
            if !suite::ALL_BENCHMARKS.contains(&bench.as_str()) {
                return Err(Error::UnknownBenchmark { name: bench.clone() });
            }
            let at = match order.iter().position(|(b, _)| b == bench) {
                Some(at) => at,
                None => {
                    order.push((bench.clone(), Vec::new()));
                    order.len() - 1
                }
            };
            if !map.contains_key(&sink::key(bench, *rec_scale, &p.id)) {
                order[at].1.push(p.id.clone());
            }
        }
        absorb(path.as_ref(), &mut map, &mut merged)?;
    }
    let scale = scale.ok_or_else(|| Error::config("merge: sinks contain no records"))?;
    let mut explorations = Vec::with_capacity(order.len());
    let mut used = 0usize;
    for (bench, ids) in &order {
        let wl = suite::generate_cached(bench, scale);
        let pts: Vec<DesignPoint> = ids
            .iter()
            .filter_map(|id| map.remove(&sink::key(bench, scale, id)))
            .collect();
        used += pts.len();
        explorations.push(exploration(bench, scale, &wl, pts));
    }
    merged.outcome = outcome(scale, explorations, used);
    Ok(merged)
}

fn empty_accounting() -> Merged {
    Merged {
        outcome: outcome(Scale::Tiny, Vec::new(), 0),
        records: 0,
        duplicates: 0,
        conflicts: 0,
        torn_tails: 0,
        foreign: 0,
        missing: Vec::new(),
    }
}

fn absorb(
    path: &Path,
    map: &mut HashMap<sink::Key, DesignPoint>,
    merged: &mut Merged,
) -> Result<()> {
    let info = sink::load_keyed_into(path, map)?;
    merged.records += info.records;
    merged.duplicates += info.duplicates;
    merged.conflicts += info.conflicts;
    if info.torn_tail {
        merged.torn_tails += 1;
        log::warn(format!(
            "merge: sink {} ends in a torn line (campaign killed mid-write?)",
            path.display()
        ));
    }
    Ok(())
}

fn exploration(
    name: &str,
    scale: Scale,
    wl: &suite::Workload,
    points: Vec<DesignPoint>,
) -> Exploration {
    Exploration {
        benchmark: name.to_string(),
        scale,
        locality: locality::analyze(&wl.trace).spatial_locality(),
        backend: None,
        trace_nodes: wl.trace.len(),
        checksum: wl.checksum,
        points,
    }
}

fn outcome(scale: Scale, explorations: Vec<Exploration>, resumed: usize) -> CampaignOutcome {
    CampaignOutcome {
        scale,
        backend: None,
        shard: None,
        explorations,
        simulated: 0,
        memoized: 0,
        resumed,
        points_per_s: 0.0,
        cost_batches: 0,
        cost: Default::default(),
        sim: Default::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::Campaign;
    use crate::dse::Sweep;

    fn write_sink(dir: &Path, name: &str, lines: &[String]) -> std::path::PathBuf {
        let path = dir.join(name);
        std::fs::write(&path, lines.iter().map(|l| format!("{l}\n")).collect::<String>())
            .unwrap();
        path
    }

    #[test]
    fn merge_requires_sinks_and_a_valid_spec() {
        let spec = CampaignSpec::new().benchmark("gemm");
        let none: [&Path; 0] = [];
        assert!(merge(&spec, &none).is_err());
        assert!(merge_loose(&none).is_err());
        let bad = CampaignSpec::new();
        assert!(merge(&bad, &[Path::new("x.jsonl")]).is_err(), "empty plan");
    }

    #[test]
    fn merge_reconstructs_an_unsharded_outcome_and_reports_missing() {
        let dir = std::env::temp_dir().join("amm_dse_merge_unit");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut spec = CampaignSpec::new().benchmark("gemm").locality_only("kmp");
        spec.scale = Scale::Tiny;
        spec.sweep = Sweep::quick();
        let full = Campaign::from_spec(spec.clone()).offline().run().unwrap();
        let lines: Vec<String> = full.get("gemm").unwrap().points()
            .iter()
            .map(|p| sink::record_line("gemm", Scale::Tiny, p))
            .collect();
        // split the records over two "shard" sinks, out of order
        let (a, b) = lines.split_at(lines.len() / 2);
        let s0 = write_sink(&dir, "s0.jsonl", b);
        let s1 = write_sink(&dir, "s1.jsonl", a);
        let m = merge(&spec, &[&s0, &s1]).unwrap();
        assert!(m.missing.is_empty(), "{:?}", m.missing);
        assert_eq!((m.duplicates, m.conflicts, m.foreign, m.torn_tails), (0, 0, 0, 0));
        assert_eq!(m.outcome.fig5_csv(), full.fig5_csv(), "byte-for-byte fig5");
        for (x, y) in full.get("gemm").unwrap().points().iter()
            .zip(m.outcome.get("gemm").unwrap().points())
        {
            assert_eq!(x, y, "enumeration order and payload survive the merge");
        }
        // drop one record: merge reports exactly that key as missing
        let short = merge(&spec, &[&s0]).unwrap();
        assert_eq!(short.missing.len(), a.len());
        // duplicates across sinks collapse
        let dup = merge(&spec, &[&s0, &s1, &s0]).unwrap();
        assert_eq!(dup.duplicates, b.len());
        assert_eq!(dup.outcome.fig5_csv(), full.fig5_csv());
    }

    #[test]
    fn loose_merge_trusts_the_records() {
        let dir = std::env::temp_dir().join("amm_dse_merge_loose_unit");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let full = Campaign::new()
            .benchmark("gemm")
            .scale(Scale::Tiny)
            .sweep(Sweep::quick())
            .offline()
            .run()
            .unwrap();
        let lines: Vec<String> = full.get("gemm").unwrap().points()
            .iter()
            .map(|p| sink::record_line("gemm", Scale::Tiny, p))
            .collect();
        let s0 = write_sink(&dir, "loose.jsonl", &lines);
        let m = merge_loose(&[&s0]).unwrap();
        assert_eq!(m.outcome.scale, Scale::Tiny);
        assert_eq!(m.outcome.total_points(), lines.len());
        assert_eq!(m.outcome.fig5_csv(), full.fig5_csv());
        // mixed scales are rejected
        let mut mixed = lines.clone();
        mixed.push(sink::record_line(
            "gemm",
            Scale::Paper,
            &full.get("gemm").unwrap().points()[0],
        ));
        let s1 = write_sink(&dir, "mixed.jsonl", &mixed);
        assert!(merge_loose(&[&s1]).is_err());
    }
}
