//! Suite-scale campaign engine: one work-stream across all benchmarks.
//!
//! The paper's headline artifact (Fig 5, §IV) is a *cross-benchmark*
//! analysis, but running it as N sequential [`crate::Explorer`]s leaves
//! three kinds of waste on the table:
//!
//! * **barriers** — each benchmark's sweep drains completely before the
//!   next starts, so the worker pool idles on every straggler tail;
//! * **fragmented cost batches** — each sweep issues its own macro-cost
//!   batch even though benchmarks share most macro shapes;
//! * **all-or-nothing results** — nothing lands on disk until the whole
//!   run finishes, so a killed run is a lost run.
//!
//! Since the spec redesign, the engine's only input is a
//! [`CampaignSpec`] — the serializable plan every front-end lowers to
//! (see [`crate::spec`]). [`run`] plans the entire {benchmarks} ×
//! {sweep points} cross-product as **one flat stream of work units**
//! and executes it with one shared worker pool:
//!
//! 1. **plan** — workloads come from the memoized
//!    [`crate::suite::generate_cached`] (each benchmark traced exactly
//!    once per process), designs from [`crate::dse::build_designs`]
//!    (one build per distinct (model, word-size) run);
//! 2. **shard** — with [`CampaignSpec::shard`] set, units whose stable
//!    `(benchmark, point id)` hash lands outside this bucket are
//!    skipped — and benchmarks owning no unit here are never traced on
//!    this host at all — so `n` shard runs partition the plan exactly
//!    (merge the sinks back with [`merge`] / `repro merge`);
//! 3. **resume** — if a [`sink`] file exists, points already recorded
//!    there (keyed by `(benchmark, scale, point id)`, so a sink written
//!    at another scale can never satisfy a resume) are restored
//!    verbatim and never re-simulated;
//! 4. **compile** — one [`CompiledTrace`] per `(benchmark, word_bytes)`
//!    group, shared by every model/knob variant in the group;
//! 5. **probe** — each pending unit's canonical [`crate::sim::Key`]
//!    (trace content hash + knobs + design id + engine version) is
//!    probed against the tiered simulation stack ([`crate::sim`],
//!    opened from [`CampaignSpec::sim_store`] or `<sink>.sim.jsonl`):
//!    hits skip scoring, lane packing and the scheduler entirely and
//!    stream straight to the sink writer, so a warm campaign against a
//!    **fresh sink** re-simulates zero points and a superset sweep
//!    simulates only the delta;
//! 6. **score** — the macro-cost queries of every design still pending,
//!    across *all* benchmarks, go through
//!    [`crate::coordinator::Coordinator::score_designs`] as **one**
//!    deduplicated batch, resolved through the tiered cost stack
//!    ([`crate::cost`]): the campaign opens the persistent cost store
//!    ([`CampaignSpec::cost_store`], or `<sink>.cost.jsonl` next to the
//!    sink) before scoring and newly scored rows are flushed to it per
//!    batch, so only shapes *no prior run ever scored* reach the PJRT
//!    backend — a warmed re-run issues **zero** backend batches;
//! 7. **simulate** — units sharing a compiled-trace group and
//!    `(unroll, alus)` knobs are bucketed into lane chunks of up to the
//!    sweep's `lanes` (0 = auto) and scored through the lane-batched
//!    engine ([`crate::sched::CompiledTrace::simulate_batch`]; scalar
//!    for singleton chunks) in a single
//!    [`crate::util::pool::parallel_map_with`] dispatch: workers steal
//!    chunks across benchmark boundaries (no per-benchmark barrier) and
//!    own one [`SimArena`] + [`BatchArena`] each for the entire
//!    campaign;
//! 8. **stream** — completed points flow through a reorder buffer to the
//!    append-only JSONL [`sink`] in enumeration order (with optional
//!    stderr progress/ETA lines, [`ExecOptions::progress`]), so the
//!    file grows as the in-order prefix completes, is byte-stable for
//!    identical runs, and a kill leaves a clean resumable prefix.
//!
//! The [`Campaign`] builder (and [`crate::Explorer`], a thin
//! single-benchmark campaign) are compat front-ends that assemble a
//! spec and call [`run`]; the campaign-vs-sequential equivalence is
//! pinned bit-for-bit by `tests/campaign_golden.rs`, the shard/merge
//! partition by `tests/spec_shard.rs`.

pub mod merge;
pub mod sink;

use crate::coordinator::{Coordinator, CostBackend};
use crate::cost::CostCounters;
use crate::dse::{self, BenchSummary, DesignPoint, Sweep};
use crate::error::{Error, Result};
use crate::explore::Exploration;
use crate::locality;
use crate::mem::MemDesign;
use crate::report;
use crate::sched::{BatchArena, CompiledTrace, SimArena, SimOutput};
use crate::spec::{CampaignSpec, Shard, ShardStrategy};
use crate::suite::{self, Scale};
use crate::util::{log, pool};
use std::collections::{HashMap, HashSet};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};

/// The default cost-store path for a sinked campaign:
/// `<sink>.cost.jsonl`, next to the sidecar `<sink>.status.json`.
pub fn default_cost_store(sink: &Path) -> PathBuf {
    crate::util::jsonl::path_with_suffix(sink, ".cost.jsonl")
}

/// The default simulation-store path for a sinked campaign:
/// `<sink>.sim.jsonl`, next to the cost store and status sidecar.
pub fn default_sim_store(sink: &Path) -> PathBuf {
    crate::util::jsonl::path_with_suffix(sink, ".sim.jsonl")
}

/// Execution-context knobs that ride *alongside* a [`CampaignSpec`]:
/// they select how the plan runs here (cost service, progress
/// reporting), not what the plan is, so they are never serialized.
#[derive(Clone, Debug)]
pub struct ExecOptions {
    /// Artifacts directory for the PJRT cost model (default:
    /// [`crate::runtime::artifacts_dir`]).
    pub artifacts: Option<PathBuf>,
    /// Skip the coordinator/cost service and evaluate in-process with
    /// the pure-Rust cost model (tests, doctests).
    pub offline: bool,
    /// Emit stderr progress/ETA lines as completions stream in.
    pub progress: bool,
    /// Cooperative cancellation flag (the serve daemon's job-scoped
    /// hook): checked before scoring and per simulated unit. A raised
    /// flag aborts the run with a `campaign cancelled` error, leaving
    /// the sink's clean in-order prefix behind (`complete:false` in the
    /// status sidecar) — re-running the same spec resumes it.
    pub cancel: Option<Arc<AtomicBool>>,
    /// Status-history ring length: snapshots kept in
    /// `<sink>.status.history.jsonl` alongside the last-write-wins
    /// sidecar (see [`sink::StatusWriter`]). 0 disables the ring.
    pub status_history: usize,
    /// Probe the tiered simulation stack ([`crate::sim`]) before lane
    /// packing, so units any prior run already simulated skip the
    /// scheduler entirely (default on; coordinator-less offline runs
    /// never probe). Disable to force every owned unit through the
    /// engine — the half-warm golden uses this for its cold control.
    pub sim_memo: bool,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions {
            artifacts: None,
            offline: false,
            progress: false,
            cancel: None,
            status_history: sink::DEFAULT_HISTORY,
            sim_memo: true,
        }
    }
}

/// Builder for one exploration campaign over many benchmarks — a thin
/// front-end that assembles a [`CampaignSpec`] (+ [`ExecOptions`]) and
/// hands it to [`run`]. Use [`Campaign::spec`]/[`Campaign::into_spec`]
/// to extract the plan as data (serialize it, ship it, shard it).
#[derive(Clone, Debug, Default)]
pub struct Campaign {
    spec: CampaignSpec,
    opts: ExecOptions,
}

impl Campaign {
    /// An empty campaign (paper scale, default sweep, auto threads, no
    /// sink, batched cost service on).
    pub fn new() -> Self {
        Campaign::default()
    }

    /// A campaign executing an existing spec with default options.
    pub fn from_spec(spec: CampaignSpec) -> Self {
        Campaign { spec, opts: ExecOptions::default() }
    }

    /// The spec this builder has assembled so far.
    pub fn spec(&self) -> &CampaignSpec {
        &self.spec
    }

    /// Lower the builder to its spec — the serializable plan artifact.
    pub fn into_spec(self) -> CampaignSpec {
        self.spec
    }

    /// Add one benchmark to the swept set.
    pub fn benchmark(mut self, name: impl Into<String>) -> Self {
        self.spec = self.spec.benchmark(name);
        self
    }

    /// Add several benchmarks to the swept set.
    pub fn benchmarks<I>(mut self, names: I) -> Self
    where
        I: IntoIterator,
        I::Item: Into<String>,
    {
        for n in names {
            self.spec = self.spec.benchmark(n);
        }
        self
    }

    /// Add a locality-only benchmark: traced and analyzed, not swept
    /// (the grey rows of Fig 5).
    pub fn locality_only(mut self, name: impl Into<String>) -> Self {
        self.spec = self.spec.locality_only(name);
        self
    }

    /// Workload scale for every benchmark in the campaign.
    pub fn scale(mut self, scale: Scale) -> Self {
        self.spec.scale = scale;
        self
    }

    /// The sweep applied to every swept benchmark.
    pub fn sweep(mut self, sweep: Sweep) -> Self {
        self.spec.sweep = sweep;
        self
    }

    /// Worker threads for the shared pool (0 = auto).
    pub fn threads(mut self, n: usize) -> Self {
        self.spec.threads = n;
        self
    }

    /// Stream results to (and resume from) an append-only JSONL file:
    /// points already recorded there are restored instead of
    /// re-simulated, fresh points are appended as they complete.
    pub fn sink(mut self, path: impl Into<PathBuf>) -> Self {
        self.spec.sink = Some(path.into());
        self
    }

    /// Persist (and warm-start from) the macro-cost store at `path`
    /// (default for sinked runs: `<sink>.cost.jsonl`). See
    /// [`crate::cost`].
    pub fn cost_store(mut self, path: impl Into<PathBuf>) -> Self {
        self.spec.cost_store = Some(path.into());
        self
    }

    /// Persist (and warm-start from) the simulation-result store at
    /// `path` (default for sinked runs: `<sink>.sim.jsonl`). See
    /// [`crate::sim`].
    pub fn sim_store(mut self, path: impl Into<PathBuf>) -> Self {
        self.spec.sim_store = Some(path.into());
        self
    }

    /// Run only shard `index` of `count`: the planned units whose
    /// stable `(benchmark, point id)` hash lands in this bucket.
    pub fn shard(mut self, index: u32, count: u32) -> Self {
        self.spec.shard = Some(Shard { index, count });
        self
    }

    /// Artifacts directory for the PJRT cost model (default:
    /// [`crate::runtime::artifacts_dir`]).
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.opts.artifacts = Some(dir.into());
        self
    }

    /// Skip the coordinator/cost service and evaluate in-process with
    /// the pure-Rust cost model (tests, doctests).
    pub fn offline(mut self) -> Self {
        self.opts.offline = true;
        self
    }

    /// Emit stderr progress/ETA lines as completions stream in.
    pub fn progress(mut self, on: bool) -> Self {
        self.opts.progress = on;
        self
    }

    /// Validate and run, bringing up a private [`Coordinator`] (unless
    /// [`Campaign::offline`]). To share one cost service across several
    /// campaigns, use [`Campaign::run_with`].
    pub fn run(self) -> Result<CampaignOutcome> {
        run(&self.spec, &self.opts)
    }

    /// Validate and run through a caller-provided coordinator.
    pub fn run_with(self, coord: &Coordinator) -> Result<CampaignOutcome> {
        run_with(&self.spec, coord, &self.opts)
    }
}

/// Run a spec, bringing up a private [`Coordinator`] (unless
/// [`ExecOptions::offline`]). The only execution entry points of the
/// engine are this and [`run_with`] — every front-end (builders, config
/// files, the CLI) lowers to a [`CampaignSpec`] first.
pub fn run(spec: &CampaignSpec, opts: &ExecOptions) -> Result<CampaignOutcome> {
    if opts.offline {
        return execute(spec, None, opts);
    }
    let dir = opts.artifacts.clone().unwrap_or_else(crate::runtime::artifacts_dir);
    let threads = if spec.threads != 0 { spec.threads } else { spec.sweep.threads };
    let coord = Coordinator::with_artifacts(dir).threads(threads);
    execute(spec, Some(&coord), opts)
}

/// Run a spec through a caller-provided coordinator, so several
/// campaigns share one cost service (and one compiled PJRT artifact).
pub fn run_with(
    spec: &CampaignSpec,
    coord: &Coordinator,
    opts: &ExecOptions,
) -> Result<CampaignOutcome> {
    execute(spec, Some(coord), opts)
}

/// The engine: plan → shard → resume → score → compile → simulate →
/// stream.
fn execute(
    spec: &CampaignSpec,
    coord: Option<&Coordinator>,
    opts: &ExecOptions,
) -> Result<CampaignOutcome> {
    spec.validate()?;
    // Cooperative cancellation: cheap flag probes at the phase
    // boundaries that matter (before the expensive scoring call, per
    // simulated unit) — never mid-unit, so the sink prefix stays clean.
    let cancelled =
        || opts.cancel.as_ref().map_or(false, |c| c.load(Ordering::SeqCst));
    let cancel_err = || {
        Err(Error::runtime(
            "campaign cancelled (the sink keeps the completed prefix; re-run to resume)",
        ))
    };
    if cancelled() {
        return cancel_err();
    }
    // Thread precedence mirrors the pre-campaign run_sweep path:
    // explicit spec setting > sweep setting > the coordinator's
    // configured worker count > auto.
    let threads = if spec.threads != 0 {
        spec.threads
    } else if spec.sweep.threads != 0 {
        spec.sweep.threads
    } else if let Some(c) = coord {
        c.worker_threads()
    } else {
        pool::default_threads()
    };
    let scale = spec.scale;
    let shard = spec.shard;

    // ---- cost + sim stores: open the warm-start tiers up front --------
    // The spec's explicit paths win; a sinked run derives
    // `<sink>.cost.jsonl` / `<sink>.sim.jsonl`. Offline
    // (coordinator-less) runs score nothing and open nothing.
    if let Some(coord) = coord {
        let store_path = spec
            .cost_store
            .clone()
            .or_else(|| spec.sink.as_ref().map(|s| default_cost_store(s)));
        if let Some(path) = &store_path {
            coord.open_cost_store(path)?;
        }
        if opts.sim_memo {
            let sim_path = spec
                .sim_store
                .clone()
                .or_else(|| spec.sink.as_ref().map(|s| default_sim_store(s)));
            if let Some(path) = &sim_path {
                coord.open_sim_store(path)?;
            }
        }
    }

    // ---- plan: memoized workloads + locality + sweep points -----------
    // A sharded run materializes only what it owns: point ids depend on
    // (model id, knobs) alone, so ownership is decidable before any
    // workload is generated, and — under the default hash strategy — a
    // benchmark whose every unit hashes to another shard (locality-only
    // rows included) is never traced on this host; its exploration row
    // carries NaN locality and no workload stats, and `merge` recomputes
    // locality from the full plan. The weighted strategy needs every
    // swept benchmark's LPT weight: a warm [`crate::spec::weights`]
    // table answers those from disk, otherwise the host traces the
    // swept set first (memoized).
    struct Bench {
        name: String,
        swept: bool,
        wl: Option<Arc<suite::Workload>>,
        locality: f64,
    }
    let points = spec.sweep.points();
    // Weighted ownership, as benchmark -> owned point ids (probed by
    // &str, so the per-unit ownership test below allocates nothing).
    let weighted: Option<HashMap<String, HashSet<String>>> = match (&shard, spec.shard_strategy)
    {
        (Some(sh), ShardStrategy::Weighted) => {
            let keys = spec.plan_keys();
            // LPT weights come from the persistent weight table when
            // the spec names one (`weight-table/v1`): a warm table
            // answers every count from disk, so this host never traces
            // a benchmark it owns no units of. Cold keys fall back to
            // tracing (memoized) and are cached for the fleet.
            let mut table = match &spec.weights {
                Some(path) => crate::spec::weights::WeightTable::open(path)?,
                None => crate::spec::weights::WeightTable::in_memory(),
            };
            let assignment = crate::spec::weighted_shard_assignment(
                &keys,
                |bench| table.nodes_or_trace(bench, scale),
                sh.count,
            );
            let mut owned: HashMap<String, HashSet<String>> = HashMap::new();
            for ((bench, id), s) in keys.into_iter().zip(assignment) {
                if s == sh.index {
                    owned.entry(bench).or_default().insert(id);
                }
            }
            Some(owned)
        }
        _ => None,
    };
    let owns = |bench: &str, id: &str| match (&shard, &weighted) {
        (None, _) => true,
        (Some(_), Some(owned)) => owned.get(bench).map_or(false, |ids| ids.contains(id)),
        (Some(sh), None) => sh.contains(bench, id),
    };
    let owns_units = |name: &str| match &shard {
        None => true,
        Some(_) => {
            points.iter().any(|p| owns(name, &dse::point_id(&p.model.id(), &p.knobs)))
        }
    };
    let benches: Vec<Bench> = spec
        .plan
        .iter()
        .map(|e| {
            if shard.is_some() && !(e.swept && owns_units(&e.name)) {
                return Bench {
                    name: e.name.clone(),
                    swept: e.swept,
                    wl: None,
                    locality: f64::NAN,
                };
            }
            let wl = suite::generate_cached(&e.name, scale);
            let locality = locality::analyze(&wl.trace).spatial_locality();
            Bench { name: e.name.clone(), swept: e.swept, wl: Some(wl), locality }
        })
        .collect();

    // ---- resume: restore already-scored points from the sink ----------
    // The key includes the scale, so e.g. a sink written at `tiny` can
    // never satisfy a `paper` resume.
    let mut done: HashMap<sink::Key, DesignPoint> = HashMap::new();
    let mut torn_tail = false;
    if let Some(path) = &spec.sink {
        if path.exists() {
            torn_tail = sink::load_keyed_into(path, &mut done)?.torn_tail;
        }
    }

    // ---- flatten: one stream of units across all benchmarks -----------
    struct Unit {
        bench: usize,
        point: usize,
        group: usize,
        seq: usize,
        design: MemDesign,
    }
    let mut results: Vec<Vec<Option<DesignPoint>>> = benches
        .iter()
        .map(|b| if b.swept { vec![None; points.len()] } else { Vec::new() })
        .collect();
    let mut units: Vec<Unit> = Vec::new();
    let mut group_keys: Vec<(usize, u32)> = Vec::new();
    let mut resumed = 0usize;
    for (bi, b) in benches.iter().enumerate() {
        if !b.swept {
            continue;
        }
        let Some(wl) = &b.wl else { continue };
        let designs = dse::build_designs(&wl.trace, &points);
        for (pi, (p, design)) in points.iter().zip(designs).enumerate() {
            // the pre-generation ownership check above keyed on the
            // model id — the built design must carry the same id
            debug_assert_eq!(design.id, p.model.id(), "MemModel::build must preserve the id");
            let id = dse::point_id(&design.id, &p.knobs);
            if shard.is_some() && !owns(&b.name, &id) {
                continue;
            }
            if let Some(prev) = done.remove(&sink::key(&b.name, scale, &id)) {
                results[bi][pi] = Some(prev);
                resumed += 1;
                continue;
            }
            // word_bytes is the sweep's outermost axis, so each
            // (benchmark, word size) is one contiguous run — gaps from
            // resumed or out-of-shard points never split a group.
            if group_keys.last() != Some(&(bi, p.knobs.word_bytes)) {
                group_keys.push((bi, p.knobs.word_bytes));
            }
            let seq = units.len();
            units.push(Unit { bench: bi, point: pi, group: group_keys.len() - 1, seq, design });
        }
    }
    if shard.is_some() {
        // records owned by other shards are expected when sinks are
        // shared or pre-merged — only genuinely foreign records (wrong
        // scale, sweep or benchmark set) warrant noise below
        done.retain(|(b, s, id), _| *s != scale || owns(b, id));
    }
    if !done.is_empty() {
        log::warn(format!(
            "campaign sink: {} record(s) match no planned point (different scale, sweep or benchmark set?)",
            done.len()
        ));
    }
    if cancelled() {
        return cancel_err();
    }

    // ---- compile: one CompiledTrace per (benchmark, word) group -------
    // Compiled before scoring, because the simulation probe below keys
    // on each group's trace content hash. (Option<Arc<..>> only to
    // satisfy the pool's Default bound.)
    let groups: Vec<Arc<CompiledTrace<'_>>> =
        pool::parallel_map(&group_keys, threads, |&(bi, wb)| {
            let wl = benches[bi].wl.as_ref().expect("groups only form for owned benchmarks");
            Some(Arc::new(CompiledTrace::new(&wl.trace, wb)))
        })
        .into_iter()
        .map(|g| g.expect("group compilation cannot fail"))
        .collect();

    // ---- probe: feed memoized units straight past the scheduler ------
    // Every unit any prior run simulated under this scoring context +
    // engine version answers from the sim stack (memo or persistent
    // store) before lane packing: hits go straight to the sink writer
    // with their enumeration `seq` (so ordering and sink byte-stability
    // are untouched), and only the misses are scored, lane-packed and
    // simulated. `keys` is seq-aligned with `units`; hit slots are
    // taken (`None`) so the miss path below can move the rest.
    let sim_stack = coord.filter(|_| opts.sim_memo).map(|c| c.sim_stack());
    let mut sim = crate::sim::SimCounters::default();
    let mut keys: Vec<Option<crate::sim::Key>> = Vec::new();
    let mut hits: Vec<(usize, DesignPoint)> = Vec::new();
    let mut hit_mask = vec![false; units.len()];
    if let Some(stack) = sim_stack {
        let before = stack.counters();
        keys.reserve_exact(units.len());
        for (i, u) in units.iter().enumerate() {
            let knobs = &points[u.point].knobs;
            let key = crate::sim::Key::of(&groups[u.group], knobs, &u.design);
            match stack.probe(&key) {
                Some(out) => {
                    hits.push((i, dse::point_from(&u.design.id, u.design.is_amm, knobs, out)));
                    hit_mask[i] = true;
                    keys.push(None);
                }
                None => keys.push(Some(key)),
            }
        }
        sim = stack.counters().since(&before);
    }
    let memoized = hits.len();
    let simulated = units.len() - memoized;

    // ---- score: ONE deduplicated cost call for the whole campaign -----
    // Only units that must actually be simulated need cost-patched
    // designs (memoized units carry fully composed outputs already).
    // The stack answers from its memo/store tiers where it can; only
    // never-scored shapes reach the runtime backend (at most one
    // batch). Counter deltas attribute exactly this campaign's traffic
    // on a possibly long-lived coordinator.
    let mut cost = CostCounters::default();
    if cancelled() {
        return cancel_err();
    }
    if let Some(coord) = coord {
        if simulated > 0 {
            let before = coord.cost_counters();
            coord.score_designs(
                units
                    .iter_mut()
                    .enumerate()
                    .filter(|(i, _)| !hit_mask[*i])
                    .map(|(_, u)| &mut u.design),
            )?;
            cost = coord.cost_counters().since(&before);
        }
    }

    // ---- simulate + stream --------------------------------------------
    // One flat dispatch: workers steal units across benchmark
    // boundaries and keep one arena each for the whole campaign.
    // Completed points are sent to a writer thread that holds a reorder
    // buffer and appends to the sink in enumeration order, so the file
    // grows as the in-order prefix completes and two identical runs
    // produce byte-identical sinks. The same thread counts completions
    // for the progress/ETA line, so it is spawned for progress-only
    // runs too (with no file).
    let mut tx: Option<Mutex<mpsc::Sender<(usize, String)>>> = None;
    let mut writer: Option<std::thread::JoinHandle<std::io::Result<u64>>> = None;
    if spec.sink.is_some() || opts.progress {
        let mut file = None;
        let mut status = None;
        if let Some(path) = &spec.sink {
            if let Some(dir) = path.parent() {
                if !dir.as_os_str().is_empty() {
                    std::fs::create_dir_all(dir)
                        .map_err(|e| Error::io(format!("create {}", dir.display()), e))?;
                }
            }
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .map_err(|e| Error::io(format!("open campaign sink {}", path.display()), e))?;
            if torn_tail {
                // Terminate the torn line a killed writer left behind so
                // it can never merge with the first fresh record.
                f.write_all(b"\n")
                    .map_err(|e| Error::io(format!("repair {}", path.display()), e))?;
            }
            file = Some(f);
            status = Some(sink::StatusWriter::new(
                path,
                shard.map(|sh| sh.to_string()),
                scale,
                resumed,
                units.len(),
                memoized,
                cost.hits(),
                cost.misses,
                cost.batches,
                opts.status_history,
            ));
        }
        let progress =
            opts.progress.then(|| Progress::new(resumed, units.len(), memoized, &cost));
        let (s, r) = mpsc::channel::<(usize, String)>();
        tx = Some(Mutex::new(s));
        writer = Some(
            std::thread::Builder::new()
                .name("campaign-sink".into())
                .spawn(move || sink_writer(file, r, progress, status))
                .expect("spawn campaign sink writer"),
        );
    }
    // Memoized units skip the dispatch entirely: their record lines go
    // to the writer now, carrying their enumeration `seq`, so the
    // reorder buffer interleaves them with fresh completions and the
    // sink stays byte-identical to a cold run.
    if let Some(tx) = &tx {
        let tx = tx.lock().expect("sink sender poisoned");
        for (i, p) in &hits {
            let u = &units[*i];
            let line = sink::record_line(&benches[u.bench].name, scale, p);
            let _ = tx.send((u.seq, line));
        }
    }
    // Lane-group the unit stream: units sharing a compiled-trace group
    // and (unroll, alus) knobs form one batched engine call (singletons
    // take the scalar engine). The lane width resolves per bucket —
    // auto-calibration sees each bucket's size and its trace footprint
    // ([`dse::resolve_lanes`]). Buckets key on identity, not contiguity,
    // so resume/shard gaps never split a compatible set — and every unit
    // keeps its `seq`, so the reorder buffer, sink byte-stability and
    // resume semantics are untouched.
    let chunks: Vec<Vec<usize>> = {
        let mut index: HashMap<(usize, u32, u32), usize> = HashMap::new();
        let mut buckets: Vec<Vec<usize>> = Vec::new();
        // only the probe misses are re-packed into lane groups —
        // memoized units already streamed to the writer above
        for (i, u) in units.iter().enumerate().filter(|(i, _)| !hit_mask[*i]) {
            let k = &points[u.point].knobs;
            let b = *index.entry((u.group, k.unroll, k.alus)).or_insert_with(|| {
                buckets.push(Vec::new());
                buckets.len() - 1
            });
            buckets[b].push(i);
        }
        let mut chunks = Vec::new();
        for b in buckets {
            let g = units[b[0]].group;
            let width = dse::resolve_lanes(spec.sweep.lanes, b.len(), groups[g].trace().len());
            for c in b.chunks(width.max(1)) {
                chunks.push(c.to_vec());
            }
        }
        chunks
    };
    let sim_start = std::time::Instant::now();
    let fresh: Vec<Vec<(usize, DesignPoint)>> = pool::parallel_map_with(
        &chunks,
        threads,
        || (SimArena::new(), BatchArena::new(), Vec::new()),
        |(arena, batch, scratch), chunk| {
            if cancelled() {
                // drain the remaining chunks without simulating or
                // sending; every line already sent is a complete record,
                // so the sink stays a valid resume journal
                return Vec::new();
            }
            let first = &units[chunk[0]];
            let knobs = &points[first.point].knobs;
            let sims: Vec<SimOutput> = if chunk.len() == 1 {
                vec![groups[first.group].simulate(arena, knobs, &first.design)]
            } else {
                // design clones land in a per-worker scratch buffer so
                // the unit-to-unit path never allocates the lane vector
                let scratch: &mut Vec<MemDesign> = scratch;
                scratch.clear();
                scratch.extend(chunk.iter().map(|&i| units[i].design.clone()));
                groups[first.group].simulate_batch(batch, knobs, scratch)
            };
            if let Some(stack) = sim_stack {
                // one memo insert + store append per chunk: a killed
                // campaign still warms the next run up to its last chunk
                let rows: Vec<(crate::sim::Key, SimOutput)> = chunk
                    .iter()
                    .zip(&sims)
                    .map(|(&i, s)| {
                        let key = keys[i].clone().expect("miss units keep their key");
                        (key, s.clone())
                    })
                    .collect();
                stack.record_all(&rows);
            }
            chunk
                .iter()
                .zip(sims)
                .map(|(&i, sim)| {
                    let u = &units[i];
                    let p = dse::point_from(&u.design.id, u.design.is_amm, knobs, sim);
                    if let Some(tx) = &tx {
                        let line = sink::record_line(&benches[u.bench].name, scale, &p);
                        let _ = tx.lock().expect("sink sender poisoned").send((u.seq, line));
                    }
                    (i, p)
                })
                .collect()
        },
    );
    drop(tx); // hang up so the writer drains and exits
    if let Some(j) = writer {
        j.join()
            .expect("campaign sink writer panicked")
            .map_err(|e| Error::io("write campaign sink", e))?;
    }
    let sim_secs = sim_start.elapsed().as_secs_f64();
    let points_per_s = if sim_secs > 0.0 { simulated as f64 / sim_secs } else { 0.0 };
    if cancelled() {
        return cancel_err();
    }
    for (i, p) in hits.into_iter().chain(fresh.into_iter().flatten()) {
        let u = &units[i];
        results[u.bench][u.point] = Some(p);
    }

    // ---- assemble per-benchmark explorations, in plan order -----------
    let backend = coord.map(|c| c.backend);
    let explorations: Vec<Exploration> = benches
        .iter()
        .enumerate()
        .map(|(bi, b)| Exploration {
            benchmark: b.name.clone(),
            scale,
            locality: b.locality,
            backend,
            trace_nodes: b.wl.as_ref().map_or(0, |w| w.trace.len()),
            checksum: b.wl.as_ref().map_or(f64::NAN, |w| w.checksum),
            points: if b.swept {
                let got: Vec<DesignPoint> =
                    results[bi].iter_mut().filter_map(Option::take).collect();
                // a sharded run owns only its bucket; anything else must
                // account for every enumerated point
                assert!(
                    shard.is_some() || got.len() == points.len(),
                    "campaign point unaccounted for"
                );
                got
            } else {
                Vec::new()
            },
        })
        .collect();
    Ok(CampaignOutcome {
        scale,
        backend,
        shard,
        explorations,
        simulated,
        memoized,
        resumed,
        points_per_s,
        cost_batches: cost.batches,
        cost,
        sim,
    })
}

/// Stderr progress/ETA reporting for long campaigns: the sink-writer
/// thread already sees every completion, so it emits a line every
/// [`Progress::every`] completions (~20 lines per run) plus a final
/// one, each carrying the campaign's cost hit/miss/batch accounting.
/// Silenced by `repro run --quiet` (which simply clears
/// [`ExecOptions::progress`]).
struct Progress {
    resumed: usize,
    planned: usize,
    /// Planned units answered by the sim stack — they arrive at the
    /// writer as one instant burst, so ETA math uses fresh units only.
    memoized: usize,
    every: usize,
    /// Fixed suffix: probing and scoring finish before simulation
    /// starts, so the counters are final by the time the first line
    /// prints.
    cost_note: String,
    start: std::time::Instant,
}

impl Progress {
    fn new(resumed: usize, planned: usize, memoized: usize, cost: &CostCounters) -> Progress {
        let sim_note =
            if memoized > 0 { format!(", {memoized} memoized") } else { String::new() };
        Progress {
            resumed,
            planned,
            memoized,
            every: (planned / 20).max(1),
            cost_note: format!(
                "{sim_note}, cost {} hit/{} miss/{} batch",
                cost.hits(),
                cost.misses,
                cost.batches
            ),
            start: std::time::Instant::now(),
        }
    }

    fn line(&self, received: usize) {
        let done = self.resumed + received;
        let total = self.resumed + self.planned;
        if total == 0 {
            return;
        }
        let elapsed = self.start.elapsed().as_secs_f64();
        let pct = 100.0 * done as f64 / total as f64;
        let cost = &self.cost_note;
        // ETA extrapolates from freshly simulated completions only —
        // the memoized burst would otherwise fake an absurd rate.
        let fresh = received.saturating_sub(self.memoized);
        let fresh_planned = self.planned - self.memoized;
        if fresh == 0 || fresh >= fresh_planned {
            eprintln!(
                "campaign: {done}/{total} points ({pct:.0}%), {elapsed:.1}s elapsed{cost}"
            );
        } else {
            let eta = elapsed / fresh as f64 * (fresh_planned - fresh) as f64;
            eprintln!(
                "campaign: {done}/{total} points ({pct:.0}%), {elapsed:.1}s elapsed, eta {eta:.0}s{cost}"
            );
        }
    }
}

/// Drain `(seq, line)` completions: count them for [`Progress`], and —
/// when a sink file is attached — write lines in `seq` order through a
/// reorder buffer, so the file always grows as the in-order prefix
/// completes (and is flushed there, for `tail -f` observability), with
/// the `<sink>.status.json` sidecar rewritten atomically on each flush.
fn sink_writer(
    file: Option<std::fs::File>,
    rx: mpsc::Receiver<(usize, String)>,
    progress: Option<Progress>,
    mut status: Option<sink::StatusWriter>,
) -> std::io::Result<u64> {
    use std::collections::BTreeMap;
    let mut out = file.map(std::io::BufWriter::new);
    let mut pending: BTreeMap<usize, String> = BTreeMap::new();
    let mut next = 0usize;
    let mut written = 0u64;
    let mut received = 0usize;
    for (seq, line) in rx {
        received += 1;
        if let Some(p) = &progress {
            if received % p.every == 0 && received < p.planned {
                p.line(received);
            }
        }
        let Some(w) = out.as_mut() else { continue };
        pending.insert(seq, line);
        let mut flushed = false;
        while let Some(line) = pending.remove(&next) {
            w.write_all(line.as_bytes())?;
            w.write_all(b"\n")?;
            next += 1;
            written += 1;
            flushed = true;
        }
        if flushed {
            w.flush()?;
            if let Some(st) = status.as_mut() {
                st.update(written as usize, received, false);
            }
        }
    }
    if let Some(w) = out.as_mut() {
        // Anything still pending means a gap (a worker died); persist
        // what completed anyway — the resume path tolerates
        // out-of-order lines.
        for (_, line) in pending {
            w.write_all(line.as_bytes())?;
            w.write_all(b"\n")?;
            written += 1;
        }
        w.flush()?;
    }
    if let Some(st) = status.as_mut() {
        st.update(written as usize, received, true);
    }
    if let Some(p) = &progress {
        p.line(received);
    }
    Ok(written)
}

/// Results of one campaign: per-benchmark [`Exploration`]s (in plan
/// order) plus campaign-level accounting.
#[derive(Clone, Debug)]
pub struct CampaignOutcome {
    /// Workload scale the campaign ran at.
    pub scale: Scale,
    /// Cost backend (`None` for offline runs).
    pub backend: Option<CostBackend>,
    /// The shard this run executed, if the spec was sharded.
    pub shard: Option<Shard>,
    /// One exploration per planned benchmark (locality-only rows carry
    /// an empty point set; sharded runs carry only their bucket).
    pub explorations: Vec<Exploration>,
    /// Design points freshly simulated by this run — the scheduler
    /// actually ran for these. Memoized and restored points never
    /// count.
    pub simulated: usize,
    /// Design points answered by the tiered simulation stack
    /// ([`crate::sim`]) instead of the scheduler: in-process memo or
    /// persistent sim-store hits. Distinct from [`Self::resumed`]
    /// (sink restores) — a warm campaign against a *fresh* sink
    /// reports `simulated: 0` with everything here.
    pub memoized: usize,
    /// Design points restored from the sink instead of re-simulated
    /// (reported as both `resumed` and `restored` in the status
    /// sidecar; [`CampaignOutcome::restored`] is the reading accessor).
    pub resumed: usize,
    /// Sustained simulation throughput, derived STRICTLY from freshly
    /// simulated points over the simulate+stream stage's wall clock —
    /// restored points never count, so a warm resume reports 0.0, not
    /// an inflated number. The live (throttled) counterpart streams
    /// through the `campaign-status/v1` sidecar while the run is in
    /// flight.
    pub points_per_s: f64,
    /// Runtime-backend macro-cost batches issued by this campaign: 1
    /// when any macro shape had to be scored fresh, **0** when offline,
    /// fully resumed, or every shape was answered by the in-process
    /// memo / persistent cost store (compat alias of
    /// [`CampaignOutcome::cost`]`.batches`).
    pub cost_batches: usize,
    /// Full cost-stack accounting for this campaign's scoring call
    /// (memo/store hits, backend misses and batches).
    pub cost: CostCounters,
    /// Full sim-stack accounting for this campaign's probe pass
    /// (memo/store hits and misses; `hits() ==`
    /// [`CampaignOutcome::memoized`]).
    pub sim: crate::sim::SimCounters,
}

impl CampaignOutcome {
    /// The per-benchmark explorations, in plan order.
    pub fn explorations(&self) -> &[Exploration] {
        &self.explorations
    }

    /// Exploration for one benchmark, if it was in the plan.
    pub fn get(&self, benchmark: &str) -> Option<&Exploration> {
        self.explorations.iter().find(|e| e.benchmark == benchmark)
    }

    /// Total design points across the campaign (simulated + restored).
    pub fn total_points(&self) -> usize {
        self.explorations.iter().map(|e| e.points().len()).sum()
    }

    /// Design points restored from the sink instead of re-simulated —
    /// the number the status sidecar reports next to `simulated`.
    /// (Field name `resumed` predates the restored/simulated split and
    /// stays for compatibility.)
    pub fn restored(&self) -> usize {
        self.resumed
    }

    /// Fig-5 rows, one per planned benchmark, in plan order.
    pub fn summaries(&self) -> Vec<BenchSummary> {
        self.explorations.iter().map(Exploration::summary).collect()
    }

    /// Fig-5 CSV straight from the campaign result set.
    pub fn fig5_csv(&self) -> String {
        report::fig5_csv(&self.summaries())
    }

    /// Fig-5 ASCII chart straight from the campaign result set.
    pub fn fig5_ascii(&self) -> String {
        report::fig5_ascii(&self.summaries())
    }

    /// Human label for the cost backend.
    pub fn backend_label(&self) -> &'static str {
        match self.backend {
            Some(CostBackend::Pjrt) => "Pjrt",
            Some(CostBackend::RustFallback) => "RustFallback",
            None => "Offline",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_campaign_is_a_config_error() {
        let err = Campaign::new().offline().run().unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
    }

    #[test]
    fn unknown_benchmark_is_rejected() {
        let err = Campaign::new().benchmark("nope").offline().run().unwrap_err();
        assert!(matches!(err, Error::UnknownBenchmark { .. }), "{err}");
    }

    #[test]
    fn unknown_model_id_is_rejected() {
        let mut sweep = Sweep::quick();
        sweep.extra_models = vec!["warp9".into()];
        let err =
            Campaign::new().benchmark("gemm").sweep(sweep).offline().run().unwrap_err();
        assert!(matches!(err, Error::UnknownModel { .. }), "{err}");
    }

    #[test]
    fn cancellation_flag_aborts_cleanly_and_a_lowered_flag_is_inert() {
        let mut spec = CampaignSpec::new().benchmark("gemm");
        spec.scale = Scale::Tiny;
        spec.sweep = Sweep::quick();
        let raised = Arc::new(AtomicBool::new(true));
        let opts = ExecOptions {
            offline: true,
            cancel: Some(Arc::clone(&raised)),
            ..Default::default()
        };
        let err = run(&spec, &opts).unwrap_err();
        assert!(err.to_string().contains("cancelled"), "{err}");
        let lowered = Arc::new(AtomicBool::new(false));
        let opts =
            ExecOptions { offline: true, cancel: Some(lowered), ..Default::default() };
        let ok = run(&spec, &opts).unwrap();
        assert_eq!(ok.total_points(), spec.sweep.points().len());
    }

    #[test]
    fn locality_only_rows_carry_no_points_but_real_locality() {
        let outcome = Campaign::new()
            .benchmark("stencil2d")
            .locality_only("kmp")
            .scale(Scale::Tiny)
            .sweep(Sweep::quick())
            .offline()
            .run()
            .unwrap();
        assert_eq!(outcome.explorations().len(), 2);
        let swept = outcome.get("stencil2d").unwrap();
        let loc_only = outcome.get("kmp").unwrap();
        assert!(!swept.points().is_empty());
        assert!(loc_only.points().is_empty());
        assert!(loc_only.locality > 0.5, "kmp is the high-locality benchmark");
        assert_eq!(outcome.total_points(), swept.points().len());
        // summaries render through the campaign: the locality-only row
        // must not leak NaN into the CSV
        let csv = outcome.fig5_csv();
        assert!(!csv.contains("NaN"), "{csv}");
        assert_eq!(csv.lines().count(), 3);
        assert_eq!(outcome.backend_label(), "Offline");
        assert_eq!(outcome.cost_batches, 0);
        assert_eq!(outcome.shard, None);
    }

    #[test]
    fn campaign_order_follows_the_plan() {
        let outcome = Campaign::new()
            .locality_only("viterbi")
            .benchmark("gemm")
            .locality_only("aes")
            .scale(Scale::Tiny)
            .sweep(Sweep::quick())
            .offline()
            .run()
            .unwrap();
        let names: Vec<&str> =
            outcome.explorations().iter().map(|e| e.benchmark.as_str()).collect();
        assert_eq!(names, ["viterbi", "gemm", "aes"]);
    }

    #[test]
    fn builder_lowers_to_the_spec_it_describes() {
        let c = Campaign::new()
            .benchmark("gemm")
            .locality_only("kmp")
            .scale(Scale::Tiny)
            .sweep(Sweep::quick())
            .threads(3)
            .sink("results/x.jsonl")
            .cost_store("results/x.cost.jsonl")
            .sim_store("results/x.sim.jsonl")
            .shard(1, 2);
        let spec = c.spec();
        assert_eq!(spec.swept(), ["gemm"]);
        assert_eq!(spec.locality_names(), ["kmp"]);
        assert_eq!(spec.scale, Scale::Tiny);
        assert_eq!(spec.sweep, Sweep::quick());
        assert_eq!(spec.threads, 3);
        assert_eq!(spec.sink.as_deref(), Some(std::path::Path::new("results/x.jsonl")));
        assert_eq!(
            spec.cost_store.as_deref(),
            Some(std::path::Path::new("results/x.cost.jsonl"))
        );
        assert_eq!(
            spec.sim_store.as_deref(),
            Some(std::path::Path::new("results/x.sim.jsonl"))
        );
        assert_eq!(spec.shard, Some(Shard { index: 1, count: 2 }));
    }

    #[test]
    fn default_stores_sit_next_to_the_sink() {
        let p = default_cost_store(std::path::Path::new("results/s0.jsonl"));
        assert_eq!(p, std::path::Path::new("results/s0.jsonl.cost.jsonl"));
        let p = default_sim_store(std::path::Path::new("results/s0.jsonl"));
        assert_eq!(p, std::path::Path::new("results/s0.jsonl.sim.jsonl"));
    }
}
