//! Suite-scale campaign engine: one work-stream across all benchmarks.
//!
//! The paper's headline artifact (Fig 5, §IV) is a *cross-benchmark*
//! analysis, but running it as N sequential [`crate::Explorer`]s leaves
//! three kinds of waste on the table:
//!
//! * **barriers** — each benchmark's sweep drains completely before the
//!   next starts, so the worker pool idles on every straggler tail;
//! * **fragmented cost batches** — each sweep issues its own macro-cost
//!   batch even though benchmarks share most macro shapes;
//! * **all-or-nothing results** — nothing lands on disk until the whole
//!   run finishes, so a killed run is a lost run.
//!
//! A [`Campaign`] plans the entire {benchmarks} × {sweep points}
//! cross-product as **one flat stream of work units** and executes it
//! with one shared worker pool:
//!
//! 1. **plan** — workloads come from the memoized
//!    [`crate::suite::generate_cached`] (each benchmark traced exactly
//!    once per process), designs from [`crate::dse::build_designs`]
//!    (one build per distinct (model, word-size) run);
//! 2. **resume** — if a [`sink`] file exists, points already recorded
//!    there are restored verbatim and never re-simulated;
//! 3. **score** — the macro-cost queries of every pending design, across
//!    *all* benchmarks, go through
//!    [`crate::coordinator::Coordinator::score_designs`] as **one**
//!    deduplicated batch (one PJRT execute scores the whole campaign);
//! 4. **compile** — one [`CompiledTrace`] per `(benchmark, word_bytes)`
//!    group, shared by every model/knob variant in the group;
//! 5. **simulate** — a single [`crate::util::pool::parallel_map_with`]
//!    dispatch over the whole flat unit stream: workers steal across
//!    benchmark boundaries (no per-benchmark barrier) and own one
//!    [`SimArena`] each for the entire campaign;
//! 6. **stream** — completed points flow through a reorder buffer to the
//!    append-only JSONL [`sink`] in enumeration order, so the file grows
//!    as the in-order prefix completes, is byte-stable for identical
//!    runs, and a kill leaves a clean resumable prefix.
//!
//! [`crate::Explorer`] is a thin single-benchmark campaign, so the
//! facade, the `repro figure` commands and `perf-smoke` all ride this
//! engine; the campaign-vs-sequential equivalence is pinned bit-for-bit
//! by `tests/campaign_golden.rs`.

pub mod sink;

use crate::coordinator::{Coordinator, CostBackend};
use crate::dse::{self, BenchSummary, DesignPoint, Sweep};
use crate::error::{Error, Result};
use crate::explore::Exploration;
use crate::locality;
use crate::mem::MemDesign;
use crate::report;
use crate::sched::{CompiledTrace, SimArena};
use crate::suite::{self, Scale};
use crate::util::{log, pool};
use std::collections::HashMap;
use std::io::Write as _;
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Mutex};

/// Builder for one exploration campaign over many benchmarks.
#[derive(Clone, Debug)]
pub struct Campaign {
    /// `(benchmark, swept)` in display order; `swept == false` rows only
    /// contribute locality (the non-DSE rows of Fig 5).
    plan: Vec<(String, bool)>,
    scale: Scale,
    sweep: Sweep,
    threads: usize,
    sink: Option<PathBuf>,
    artifacts: Option<PathBuf>,
    offline: bool,
}

impl Default for Campaign {
    fn default() -> Self {
        Self::new()
    }
}

impl Campaign {
    /// An empty campaign (paper scale, default sweep, auto threads, no
    /// sink, batched cost service on).
    pub fn new() -> Self {
        Campaign {
            plan: Vec::new(),
            scale: Scale::Paper,
            sweep: Sweep::default(),
            threads: 0,
            sink: None,
            artifacts: None,
            offline: false,
        }
    }

    /// Add one benchmark to the swept set.
    pub fn benchmark(mut self, name: impl Into<String>) -> Self {
        self.plan.push((name.into(), true));
        self
    }

    /// Add several benchmarks to the swept set.
    pub fn benchmarks<I>(mut self, names: I) -> Self
    where
        I: IntoIterator,
        I::Item: Into<String>,
    {
        for n in names {
            self.plan.push((n.into(), true));
        }
        self
    }

    /// Add a locality-only benchmark: traced and analyzed, not swept
    /// (the grey rows of Fig 5).
    pub fn locality_only(mut self, name: impl Into<String>) -> Self {
        self.plan.push((name.into(), false));
        self
    }

    /// Workload scale for every benchmark in the campaign.
    pub fn scale(mut self, scale: Scale) -> Self {
        self.scale = scale;
        self
    }

    /// The sweep applied to every swept benchmark.
    pub fn sweep(mut self, sweep: Sweep) -> Self {
        self.sweep = sweep;
        self
    }

    /// Worker threads for the shared pool (0 = auto).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    /// Stream results to (and resume from) an append-only JSONL file:
    /// points already recorded there are restored instead of
    /// re-simulated, fresh points are appended as they complete.
    pub fn sink(mut self, path: impl Into<PathBuf>) -> Self {
        self.sink = Some(path.into());
        self
    }

    /// Artifacts directory for the PJRT cost model (default:
    /// [`crate::runtime::artifacts_dir`]).
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = Some(dir.into());
        self
    }

    /// Skip the coordinator/cost service and evaluate in-process with
    /// the pure-Rust cost model (tests, doctests).
    pub fn offline(mut self) -> Self {
        self.offline = true;
        self
    }

    /// Validate and run, bringing up a private [`Coordinator`] (unless
    /// [`Campaign::offline`]). To share one cost service across several
    /// campaigns, use [`Campaign::run_with`].
    pub fn run(self) -> Result<CampaignOutcome> {
        if self.offline {
            return self.execute(None);
        }
        let dir = self.artifacts.clone().unwrap_or_else(crate::runtime::artifacts_dir);
        let threads = if self.threads != 0 { self.threads } else { self.sweep.threads };
        let coord = Coordinator::with_artifacts(dir).threads(threads);
        self.execute(Some(&coord))
    }

    /// Validate and run through a caller-provided coordinator.
    pub fn run_with(self, coord: &Coordinator) -> Result<CampaignOutcome> {
        self.execute(Some(coord))
    }

    /// The engine: plan → resume → score → compile → simulate → stream.
    fn execute(self, coord: Option<&Coordinator>) -> Result<CampaignOutcome> {
        // ---- validate up front (benchmark names, registry model ids) --
        if self.plan.is_empty() {
            return Err(Error::config(
                "empty campaign: call .benchmark()/.benchmarks()/.locality_only()",
            ));
        }
        for (name, _) in &self.plan {
            if !suite::ALL_BENCHMARKS.contains(&name.as_str()) {
                return Err(Error::UnknownBenchmark { name: name.clone() });
            }
        }
        for id in &self.sweep.extra_models {
            if crate::mem::parse_model(id).is_none() {
                return Err(Error::UnknownModel { id: id.clone() });
            }
        }
        // Thread precedence mirrors the pre-campaign run_sweep path:
        // explicit campaign setting > sweep setting > the coordinator's
        // configured worker count > auto.
        let threads = if self.threads != 0 {
            self.threads
        } else if self.sweep.threads != 0 {
            self.sweep.threads
        } else if let Some(c) = coord {
            c.worker_threads()
        } else {
            pool::default_threads()
        };
        let scale = self.scale;

        // ---- plan: memoized workloads + locality + sweep points -------
        struct Bench {
            name: String,
            swept: bool,
            wl: Arc<suite::Workload>,
            locality: f64,
        }
        let points = self.sweep.points();
        let benches: Vec<Bench> = self
            .plan
            .iter()
            .map(|(name, swept)| {
                let wl = suite::generate_cached(name, scale);
                let locality = locality::analyze(&wl.trace).spatial_locality();
                Bench { name: name.clone(), swept: *swept, wl, locality }
            })
            .collect();

        // ---- resume: restore already-scored points from the sink ------
        let mut done: HashMap<(String, String), DesignPoint> = HashMap::new();
        let mut torn_tail = false;
        if let Some(path) = &self.sink {
            if path.exists() {
                let (records, torn) = sink::load(path)?;
                torn_tail = torn;
                for (bench, rec_scale, p) in records {
                    if rec_scale == scale {
                        done.insert((bench, p.id.clone()), p);
                    }
                }
            }
        }

        // ---- flatten: one stream of units across all benchmarks -------
        struct Unit {
            bench: usize,
            point: usize,
            group: usize,
            seq: usize,
            design: MemDesign,
        }
        let mut results: Vec<Vec<Option<DesignPoint>>> = benches
            .iter()
            .map(|b| if b.swept { vec![None; points.len()] } else { Vec::new() })
            .collect();
        let mut units: Vec<Unit> = Vec::new();
        let mut group_keys: Vec<(usize, u32)> = Vec::new();
        let mut resumed = 0usize;
        for (bi, b) in benches.iter().enumerate() {
            if !b.swept {
                continue;
            }
            let designs = dse::build_designs(&b.wl.trace, &points);
            for (pi, (p, design)) in points.iter().zip(designs).enumerate() {
                let id = dse::point_id(&design.id, &p.knobs);
                if let Some(prev) = done.remove(&(b.name.clone(), id)) {
                    results[bi][pi] = Some(prev);
                    resumed += 1;
                    continue;
                }
                // word_bytes is the sweep's outermost axis, so each
                // (benchmark, word size) is one contiguous run — gaps
                // from resumed points never split a group.
                if group_keys.last() != Some(&(bi, p.knobs.word_bytes)) {
                    group_keys.push((bi, p.knobs.word_bytes));
                }
                let seq = units.len();
                units.push(Unit {
                    bench: bi,
                    point: pi,
                    group: group_keys.len() - 1,
                    seq,
                    design,
                });
            }
        }
        if !done.is_empty() {
            log::warn(format!(
                "campaign sink: {} record(s) match no planned point (different sweep or benchmark set?)",
                done.len()
            ));
        }
        let simulated = units.len();

        // ---- score: ONE deduplicated cost batch for the whole campaign
        let mut cost_batches = 0usize;
        if let Some(coord) = coord {
            if !units.is_empty() {
                coord.score_designs(units.iter_mut().map(|u| &mut u.design))?;
                cost_batches = 1;
            }
        }

        // ---- compile: one CompiledTrace per (benchmark, word) group ---
        // (Option<Arc<..>> only to satisfy the pool's Default bound.)
        let groups: Vec<Arc<CompiledTrace<'_>>> =
            pool::parallel_map(&group_keys, threads, |&(bi, wb)| {
                Some(Arc::new(CompiledTrace::new(&benches[bi].wl.trace, wb)))
            })
            .into_iter()
            .map(|g| g.expect("group compilation cannot fail"))
            .collect();

        // ---- simulate + stream ----------------------------------------
        // One flat dispatch: workers steal units across benchmark
        // boundaries and keep one arena each for the whole campaign.
        // Completed points are sent to a writer thread that holds a
        // reorder buffer and appends to the sink in enumeration order,
        // so the file grows as the in-order prefix completes and two
        // identical runs produce byte-identical sinks.
        let mut tx: Option<Mutex<mpsc::Sender<(usize, String)>>> = None;
        let mut writer: Option<std::thread::JoinHandle<std::io::Result<u64>>> = None;
        if let Some(path) = &self.sink {
            if let Some(dir) = path.parent() {
                if !dir.as_os_str().is_empty() {
                    std::fs::create_dir_all(dir)
                        .map_err(|e| Error::io(format!("create {}", dir.display()), e))?;
                }
            }
            let mut file = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .map_err(|e| Error::io(format!("open campaign sink {}", path.display()), e))?;
            if torn_tail {
                // Terminate the torn line a killed writer left behind so
                // it can never merge with the first fresh record.
                file.write_all(b"\n")
                    .map_err(|e| Error::io(format!("repair {}", path.display()), e))?;
            }
            let (s, r) = mpsc::channel::<(usize, String)>();
            tx = Some(Mutex::new(s));
            writer = Some(
                std::thread::Builder::new()
                    .name("campaign-sink".into())
                    .spawn(move || sink_writer(file, r))
                    .expect("spawn campaign sink writer"),
            );
        }
        let fresh: Vec<DesignPoint> =
            pool::parallel_map_with(&units, threads, SimArena::new, |arena, u| {
                let knobs = &points[u.point].knobs;
                let sim = groups[u.group].simulate(arena, knobs, &u.design);
                let p = dse::point_from(&u.design.id, u.design.is_amm, knobs, sim);
                if let Some(tx) = &tx {
                    let line = sink::record_line(&benches[u.bench].name, scale, &p);
                    let _ = tx.lock().expect("sink sender poisoned").send((u.seq, line));
                }
                p
            });
        drop(tx); // hang up so the writer drains and exits
        if let Some(j) = writer {
            j.join()
                .expect("campaign sink writer panicked")
                .map_err(|e| Error::io("write campaign sink", e))?;
        }
        for (u, p) in units.iter().zip(fresh) {
            results[u.bench][u.point] = Some(p);
        }

        // ---- assemble per-benchmark explorations, in plan order -------
        let backend = coord.map(|c| c.backend);
        let explorations: Vec<Exploration> = benches
            .iter()
            .enumerate()
            .map(|(bi, b)| Exploration {
                benchmark: b.name.clone(),
                scale,
                locality: b.locality,
                backend,
                trace_nodes: b.wl.trace.len(),
                checksum: b.wl.checksum,
                points: if b.swept {
                    results[bi]
                        .iter_mut()
                        .map(|slot| slot.take().expect("campaign point unaccounted for"))
                        .collect()
                } else {
                    Vec::new()
                },
            })
            .collect();
        Ok(CampaignOutcome { scale, backend, explorations, simulated, resumed, cost_batches })
    }
}

/// Drain `(seq, line)` completions into the sink file, writing lines in
/// `seq` order: a reorder buffer holds out-of-order completions from the
/// work-stealing pool so the file always grows as the in-order prefix
/// completes (and is flushed there, for `tail -f` observability).
fn sink_writer(
    file: std::fs::File,
    rx: mpsc::Receiver<(usize, String)>,
) -> std::io::Result<u64> {
    use std::collections::BTreeMap;
    let mut out = std::io::BufWriter::new(file);
    let mut pending: BTreeMap<usize, String> = BTreeMap::new();
    let mut next = 0usize;
    let mut written = 0u64;
    for (seq, line) in rx {
        pending.insert(seq, line);
        let mut flushed = false;
        while let Some(line) = pending.remove(&next) {
            out.write_all(line.as_bytes())?;
            out.write_all(b"\n")?;
            next += 1;
            written += 1;
            flushed = true;
        }
        if flushed {
            out.flush()?;
        }
    }
    // Anything still pending means a gap (a worker died); persist what
    // completed anyway — the resume path tolerates out-of-order lines.
    for (_, line) in pending {
        out.write_all(line.as_bytes())?;
        out.write_all(b"\n")?;
        written += 1;
    }
    out.flush()?;
    Ok(written)
}

/// Results of one campaign: per-benchmark [`Exploration`]s (in plan
/// order) plus campaign-level accounting.
#[derive(Clone, Debug)]
pub struct CampaignOutcome {
    /// Workload scale the campaign ran at.
    pub scale: Scale,
    /// Cost backend (`None` for [`Campaign::offline`] runs).
    pub backend: Option<CostBackend>,
    /// One exploration per planned benchmark (locality-only rows carry
    /// an empty point set).
    pub explorations: Vec<Exploration>,
    /// Design points simulated by this run.
    pub simulated: usize,
    /// Design points restored from the sink instead of re-simulated.
    pub resumed: usize,
    /// Macro-cost batches issued (1 for any non-empty scored campaign,
    /// 0 when offline or fully resumed).
    pub cost_batches: usize,
}

impl CampaignOutcome {
    /// The per-benchmark explorations, in plan order.
    pub fn explorations(&self) -> &[Exploration] {
        &self.explorations
    }

    /// Exploration for one benchmark, if it was in the plan.
    pub fn get(&self, benchmark: &str) -> Option<&Exploration> {
        self.explorations.iter().find(|e| e.benchmark == benchmark)
    }

    /// Total design points across the campaign (simulated + resumed).
    pub fn total_points(&self) -> usize {
        self.explorations.iter().map(|e| e.points().len()).sum()
    }

    /// Fig-5 rows, one per planned benchmark, in plan order.
    pub fn summaries(&self) -> Vec<BenchSummary> {
        self.explorations.iter().map(Exploration::summary).collect()
    }

    /// Fig-5 CSV straight from the campaign result set.
    pub fn fig5_csv(&self) -> String {
        report::fig5_csv(&self.summaries())
    }

    /// Fig-5 ASCII chart straight from the campaign result set.
    pub fn fig5_ascii(&self) -> String {
        report::fig5_ascii(&self.summaries())
    }

    /// Human label for the cost backend.
    pub fn backend_label(&self) -> &'static str {
        match self.backend {
            Some(CostBackend::Pjrt) => "Pjrt",
            Some(CostBackend::RustFallback) => "RustFallback",
            None => "Offline",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_campaign_is_a_config_error() {
        let err = Campaign::new().offline().run().unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
    }

    #[test]
    fn unknown_benchmark_is_rejected() {
        let err = Campaign::new().benchmark("nope").offline().run().unwrap_err();
        assert!(matches!(err, Error::UnknownBenchmark { .. }), "{err}");
    }

    #[test]
    fn unknown_model_id_is_rejected() {
        let mut sweep = Sweep::quick();
        sweep.extra_models = vec!["warp9".into()];
        let err =
            Campaign::new().benchmark("gemm").sweep(sweep).offline().run().unwrap_err();
        assert!(matches!(err, Error::UnknownModel { .. }), "{err}");
    }

    #[test]
    fn locality_only_rows_carry_no_points_but_real_locality() {
        let outcome = Campaign::new()
            .benchmark("stencil2d")
            .locality_only("kmp")
            .scale(Scale::Tiny)
            .sweep(Sweep::quick())
            .offline()
            .run()
            .unwrap();
        assert_eq!(outcome.explorations().len(), 2);
        let swept = outcome.get("stencil2d").unwrap();
        let loc_only = outcome.get("kmp").unwrap();
        assert!(!swept.points().is_empty());
        assert!(loc_only.points().is_empty());
        assert!(loc_only.locality > 0.5, "kmp is the high-locality benchmark");
        assert_eq!(outcome.total_points(), swept.points().len());
        // summaries render through the campaign: the locality-only row
        // must not leak NaN into the CSV
        let csv = outcome.fig5_csv();
        assert!(!csv.contains("NaN"), "{csv}");
        assert_eq!(csv.lines().count(), 3);
        assert_eq!(outcome.backend_label(), "Offline");
        assert_eq!(outcome.cost_batches, 0);
    }

    #[test]
    fn campaign_order_follows_the_plan() {
        let outcome = Campaign::new()
            .locality_only("viterbi")
            .benchmark("gemm")
            .locality_only("aes")
            .scale(Scale::Tiny)
            .sweep(Sweep::quick())
            .offline()
            .run()
            .unwrap();
        let names: Vec<&str> =
            outcome.explorations().iter().map(|e| e.benchmark.as_str()).collect();
        assert_eq!(names, ["viterbi", "gemm", "aes"]);
    }
}
