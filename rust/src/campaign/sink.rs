//! The campaign's append-only JSONL result sink.
//!
//! One flat JSON object per line, one line per completed design point.
//! The format is deliberately self-contained — every line carries the
//! benchmark, scale, point id and the full [`SimOutput`] — so a sink is
//! (a) observable mid-run with `tail -f`/`jq`, (b) mergeable across
//! shards by concatenation, and (c) a resume journal: a restarted
//! campaign keys lines by `(benchmark, point id)` and skips what's
//! already scored.
//!
//! Numbers are emitted with Rust's shortest round-trip float formatting,
//! so `parse_line(record_line(p)) == p` **bit-for-bit** — resumed
//! campaigns reproduce fresh-run results exactly (pinned by
//! `tests/campaign_golden.rs`).
//!
//! A campaign killed mid-write leaves at most one torn (newline-less)
//! final line; [`load`] reports it so the writer can terminate it before
//! appending, and parsing skips it as malformed.

use crate::dse::DesignPoint;
use crate::error::{Error, Result};
use crate::sched::SimOutput;
use crate::suite::Scale;
use crate::util::jsonl::{escape, field, path_with_suffix};
use crate::util::log;
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// The resume/dedupe key: `(benchmark, scale, point id)`. The scale is
/// part of the key, so a sink written at `--scale tiny` can never
/// satisfy a `paper` resume (and merge never conflates scales).
pub type Key = (String, Scale, String);

/// Build a [`Key`].
pub fn key(benchmark: &str, scale: Scale, id: &str) -> Key {
    (benchmark.to_string(), scale, id.to_string())
}

/// Accounting from one [`load_keyed_into`] call.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadInfo {
    /// Parseable records read from this file.
    pub records: usize,
    /// Records whose key was already present with an identical payload
    /// (harmless repeats, collapsed).
    pub duplicates: usize,
    /// Records whose key was already present with a *different*
    /// payload — the first record wins, and a warning is logged.
    pub conflicts: usize,
    /// Whether the file ends in a torn (newline-less) tail.
    pub torn_tail: bool,
}

/// Schema tag carried by every record.
pub const SCHEMA: &str = "campaign/v1";

/// Schema tag of the status sidecar (see [`StatusWriter`]).
pub const STATUS_SCHEMA: &str = "campaign-status/v1";

/// Sidecar path convention: `<sink>.status.json` (the cost store uses
/// the parallel `<sink>.cost.jsonl`).
pub fn status_path(sink: &Path) -> PathBuf {
    path_with_suffix(sink, ".status.json")
}

/// History-ring path convention: `<sink>.status.history.jsonl`.
pub fn history_path(sink: &Path) -> PathBuf {
    path_with_suffix(sink, ".status.history.jsonl")
}

/// Default [`StatusWriter`] history-ring length (snapshots kept).
pub const DEFAULT_HISTORY: usize = 64;

/// The campaign's machine-readable health endpoint: the sink-writer
/// thread atomically rewrites `<sink>.status.json` (tmp file + rename,
/// so a poller never reads a half-written document) on every sink
/// flush — throttled to one write per 100 ms, plus a final
/// unconditional one — so fleet tooling polls shard progress without
/// parsing stderr. One flat JSON object:
///
/// ```json
/// {"schema":"campaign-status/v1","sink":"s0.jsonl","shard":"0/2",
///  "scale":"tiny","done":123,"total":456,"resumed":10,"restored":10,
///  "memoized":20,"simulated":93,"eta_s":42.1,"points_per_s":350.0,
///  "cost_hits":5,"cost_misses":7,"cost_batches":1,"complete":false,
///  "updated_unix":1690000000}
/// ```
///
/// `done` counts points *persisted to the sink* (resumed + written in
/// order), `total` the shard's whole plan, `eta_s` is `null` until the
/// first completion and after the last, `shard` is `null` for unsharded
/// runs. Three distinct provenance counters partition the non-fresh
/// work: `restored` (alias: the original `resumed`, kept for pollers of
/// the v1 document) counts points recovered from *this sink* without
/// re-simulation; `memoized` counts points satisfied by the tiered
/// simulation store ([`crate::sim::SimStack`]) — planned work that
/// never reached the kernel; `simulated` counts completions freshly
/// scored this run — and `points_per_s` is derived STRICTLY from
/// `simulated` over the stage's own wall clock (`null` until the first
/// fresh completion), so neither a warm resume nor a warm sim store can
/// inflate the throughput number. Best-effort: an unwritable status
/// file warns once and never fails the campaign.
///
/// Alongside the last-write-wins sidecar, every *emitted* document is
/// also appended to a bounded history ring at
/// `<sink>.status.history.jsonl` (same schema, one snapshot per line,
/// already throttled by the 100 ms rule), so tooling can graph shard
/// throughput over time. When the ring grows past twice its configured
/// length it is compacted (atomically) to the newest `history` lines;
/// `history = 0` disables the ring entirely.
pub struct StatusWriter {
    path: PathBuf,
    sink: String,
    shard: Option<String>,
    scale: Scale,
    resumed: usize,
    planned: usize,
    memoized: usize,
    cost_hits: usize,
    cost_misses: usize,
    cost_batches: usize,
    history_path: PathBuf,
    history_limit: usize,
    history_lines: usize,
    start: std::time::Instant,
    last: Option<std::time::Instant>,
    warned: bool,
}

impl StatusWriter {
    /// A writer for the campaign streaming into `sink`. `history` is
    /// the ring length (snapshots kept; 0 disables the history file).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        sink: &Path,
        shard: Option<String>,
        scale: Scale,
        resumed: usize,
        planned: usize,
        memoized: usize,
        cost_hits: usize,
        cost_misses: usize,
        cost_batches: usize,
        history: usize,
    ) -> StatusWriter {
        let history_path = history_path(sink);
        // a resumed campaign keeps appending to the prior ring; the
        // compaction threshold needs the current line count
        let history_lines = if history > 0 {
            std::fs::read_to_string(&history_path).map_or(0, |t| t.lines().count())
        } else {
            0
        };
        StatusWriter {
            path: status_path(sink),
            // escaped once here: the sink path is the one free-form
            // string in the document (backslashes on Windows, say)
            sink: escape(&sink.display().to_string()),
            shard,
            scale,
            resumed,
            planned,
            memoized,
            cost_hits,
            cost_misses,
            cost_batches,
            history_path,
            history_limit: history,
            history_lines,
            start: std::time::Instant::now(),
            last: None,
            warned: false,
        }
    }

    /// The sidecar being written (tests).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Record a flush: `written` sink lines persisted so far,
    /// `received` completions seen. Rewrites the status file unless one
    /// was written within the last 100 ms (pass `force` for the final
    /// write).
    pub fn update(&mut self, written: usize, received: usize, force: bool) {
        if !force {
            if let Some(last) = self.last {
                if last.elapsed() < std::time::Duration::from_millis(100) {
                    return;
                }
            }
        }
        self.last = Some(std::time::Instant::now());
        let done = self.resumed + written;
        let total = self.resumed + self.planned;
        let complete = written >= self.planned;
        // Everything below is strictly FRESH work: memoized completions
        // cost no simulation time, so folding them into the rate would
        // let a warm sim store fake an arbitrarily high throughput.
        let fresh = received.saturating_sub(self.memoized);
        let fresh_planned = self.planned.saturating_sub(self.memoized);
        let eta = if fresh > 0 && fresh < fresh_planned {
            let elapsed = self.start.elapsed().as_secs_f64();
            format!("{:.1}", elapsed / fresh as f64 * (fresh_planned - fresh) as f64)
        } else {
            "null".to_string()
        };
        // Sustained fresh-simulation throughput since the stage started
        // (null until the first fresh completion lands) — the field
        // serve fleets watch for live throughput regressions.
        let points_per_s = {
            let elapsed = self.start.elapsed().as_secs_f64();
            if fresh > 0 && elapsed > 0.0 {
                format!("{:.1}", fresh as f64 / elapsed)
            } else {
                "null".to_string()
            }
        };
        let shard = match &self.shard {
            Some(s) => format!("\"{}\"", escape(s)),
            None => "null".to_string(),
        };
        let updated = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let body = format!(
            concat!(
                "{{\"schema\":\"{}\",\"sink\":\"{}\",\"shard\":{},\"scale\":\"{}\",",
                "\"done\":{},\"total\":{},\"resumed\":{},\"restored\":{},",
                "\"memoized\":{},\"simulated\":{},",
                "\"eta_s\":{},\"points_per_s\":{},",
                "\"cost_hits\":{},\"cost_misses\":{},\"cost_batches\":{},",
                "\"complete\":{},\"updated_unix\":{}}}\n"
            ),
            STATUS_SCHEMA,
            self.sink,
            shard,
            self.scale.as_str(),
            done,
            total,
            self.resumed,
            self.resumed,
            self.memoized,
            fresh,
            eta,
            points_per_s,
            self.cost_hits,
            self.cost_misses,
            self.cost_batches,
            complete,
            updated,
        );
        // tmp + rename: a poller sees either the old or the new
        // document, never a torn one
        let tmp = path_with_suffix(&self.path, ".tmp");
        let result = std::fs::write(&tmp, body.as_bytes())
            .and_then(|()| std::fs::rename(&tmp, &self.path))
            .and_then(|()| self.append_history(&body));
        if let Err(e) = result {
            if !self.warned {
                self.warned = true;
                log::warn(format!(
                    "campaign status {}: {e} (status is best-effort; run continues)",
                    self.path.display()
                ));
            }
        }
    }

    /// Append one emitted snapshot to the history ring, compacting to
    /// the newest `history_limit` lines once it doubles past the limit
    /// (tmp + rename, so a tailing poller never sees a torn file).
    fn append_history(&mut self, body: &str) -> std::io::Result<()> {
        if self.history_limit == 0 {
            return Ok(());
        }
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.history_path)?;
        f.write_all(body.as_bytes())?;
        f.flush()?;
        self.history_lines += 1;
        if self.history_lines > 2 * self.history_limit {
            let text = std::fs::read_to_string(&self.history_path)?;
            let lines: Vec<&str> = text.lines().collect();
            let keep = lines.len().saturating_sub(self.history_limit);
            let mut compact = String::new();
            for line in &lines[keep..] {
                compact.push_str(line);
                compact.push('\n');
            }
            let tmp = path_with_suffix(&self.history_path, ".tmp");
            std::fs::write(&tmp, compact.as_bytes())?;
            std::fs::rename(&tmp, &self.history_path)?;
            self.history_lines = self.history_limit;
        }
        Ok(())
    }
}

/// Emit one design point as a single JSONL record.
pub fn record_line(benchmark: &str, scale: Scale, p: &DesignPoint) -> String {
    let o = &p.out;
    format!(
        concat!(
            "{{\"schema\":\"{}\",\"benchmark\":\"{}\",\"scale\":\"{}\",",
            "\"id\":\"{}\",\"mem\":\"{}\",\"is_amm\":{},",
            "\"unroll\":{},\"word_bytes\":{},\"alus\":{},",
            "\"cycles\":{},\"period_ns\":{},\"time_ns\":{},",
            "\"mem_area_um2\":{},\"fu_area_um2\":{},\"area_um2\":{},",
            "\"power_mw\":{},\"dyn_energy_pj\":{},",
            "\"mem_accesses\":{},\"port_stalls\":{},\"stall_cycles\":{}}}"
        ),
        SCHEMA,
        benchmark,
        scale.as_str(),
        p.id,
        p.mem_id,
        p.is_amm,
        p.unroll,
        p.word_bytes,
        p.alus,
        o.cycles,
        o.period_ns,
        o.time_ns,
        o.mem_area_um2,
        o.fu_area_um2,
        o.area_um2,
        o.power_mw,
        o.dyn_energy_pj,
        o.mem_accesses,
        o.port_stalls,
        o.stall_cycles,
    )
}

/// Parse one record back into `(benchmark, scale, point)`. `None` for
/// malformed lines (torn tails, foreign schemas) — resume treats those
/// as absent rather than failing the whole campaign.
pub fn parse_line(line: &str) -> Option<(String, Scale, DesignPoint)> {
    if field(line, "schema")? != SCHEMA {
        return None;
    }
    let benchmark = field(line, "benchmark")?.to_string();
    let scale = Scale::parse(field(line, "scale")?)?;
    let out = SimOutput {
        cycles: field(line, "cycles")?.parse().ok()?,
        period_ns: field(line, "period_ns")?.parse().ok()?,
        time_ns: field(line, "time_ns")?.parse().ok()?,
        mem_area_um2: field(line, "mem_area_um2")?.parse().ok()?,
        fu_area_um2: field(line, "fu_area_um2")?.parse().ok()?,
        area_um2: field(line, "area_um2")?.parse().ok()?,
        power_mw: field(line, "power_mw")?.parse().ok()?,
        dyn_energy_pj: field(line, "dyn_energy_pj")?.parse().ok()?,
        mem_accesses: field(line, "mem_accesses")?.parse().ok()?,
        port_stalls: field(line, "port_stalls")?.parse().ok()?,
        stall_cycles: field(line, "stall_cycles")?.parse().ok()?,
    };
    let point = DesignPoint {
        id: field(line, "id")?.to_string(),
        mem_id: field(line, "mem")?.to_string(),
        is_amm: field(line, "is_amm")? == "true",
        unroll: field(line, "unroll")?.parse().ok()?,
        word_bytes: field(line, "word_bytes")?.parse().ok()?,
        alus: field(line, "alus")?.parse().ok()?,
        out,
    };
    Some((benchmark, scale, point))
}

/// Load every parseable record from a sink file. Returns the records
/// plus whether the file ends in a torn (newline-less) tail — the
/// signature a campaign killed mid-write leaves behind; the campaign
/// terminates such a tail with a newline before appending so the torn
/// fragment can never merge with a fresh record.
pub fn load(path: &Path) -> Result<(Vec<(String, Scale, DesignPoint)>, bool)> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::io(format!("read campaign sink {}", path.display()), e))?;
    let torn_tail = !text.is_empty() && !text.ends_with('\n');
    let mut records = Vec::new();
    let mut malformed = 0usize;
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        match parse_line(line) {
            Some(rec) => records.push(rec),
            None => malformed += 1,
        }
    }
    if malformed > 0 {
        log::warn(format!(
            "campaign sink {}: skipped {malformed} malformed line(s) (torn tail from a kill, or foreign records)",
            path.display()
        ));
    }
    Ok((records, torn_tail))
}

/// Load a sink into a [`Key`]-indexed map (the shape the campaign
/// resume path and `repro merge` both consume), deduplicating against
/// whatever `map` already holds — so merging n shard sinks is n calls
/// over one map. First record wins on conflicting payloads.
pub fn load_keyed_into(path: &Path, map: &mut HashMap<Key, DesignPoint>) -> Result<LoadInfo> {
    let (records, torn_tail) = load(path)?;
    let mut info = LoadInfo { torn_tail, ..LoadInfo::default() };
    for (bench, scale, p) in records {
        info.records += 1;
        match map.entry((bench, scale, p.id.clone())) {
            Entry::Occupied(prev) => {
                if *prev.get() == p {
                    info.duplicates += 1;
                } else {
                    info.conflicts += 1;
                }
            }
            Entry::Vacant(slot) => {
                slot.insert(p);
            }
        }
    }
    if info.conflicts > 0 {
        log::warn(format!(
            "campaign sink {}: {} record(s) conflict with an earlier record for the same (benchmark, scale, point id) — keeping the first",
            path.display(),
            info.conflicts
        ));
    }
    Ok(info)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_point() -> DesignPoint {
        DesignPoint {
            id: "xor4r2w/u8/w8/a4".into(),
            mem_id: "xor4r2w".into(),
            is_amm: true,
            unroll: 8,
            word_bytes: 8,
            alus: 4,
            out: SimOutput {
                cycles: 12345,
                period_ns: 1.0625,
                time_ns: 13116.5625,
                mem_area_um2: 98765.4,
                fu_area_um2: 1234.5,
                area_um2: 99999.9,
                power_mw: 3.14159,
                dyn_energy_pj: 2.718281828459045,
                mem_accesses: 4096,
                port_stalls: 17,
                stall_cycles: 9,
            },
        }
    }

    #[test]
    fn record_round_trips_bit_for_bit() {
        let p = sample_point();
        let line = record_line("gemm", Scale::Tiny, &p);
        let (bench, scale, q) = parse_line(&line).expect("must parse");
        assert_eq!(bench, "gemm");
        assert_eq!(scale, Scale::Tiny);
        assert_eq!(q.id, p.id);
        assert_eq!(q.mem_id, p.mem_id);
        assert_eq!(q.is_amm, p.is_amm);
        assert_eq!((q.unroll, q.word_bytes, q.alus), (p.unroll, p.word_bytes, p.alus));
        // shortest float reprs parse back to the identical bits
        assert_eq!(q.out, p.out);
    }

    #[test]
    fn field_extraction_is_not_fooled_by_prefixed_keys() {
        let line = record_line("fft", Scale::Paper, &sample_point());
        // "id" vs "mem_id"-style overlaps: the quote in the pattern
        // anchors the match to the real key.
        assert_eq!(field(&line, "id"), Some("xor4r2w/u8/w8/a4"));
        assert_eq!(field(&line, "mem"), Some("xor4r2w"));
        assert_eq!(field(&line, "cycles"), Some("12345"));
        assert_eq!(field(&line, "area_um2"), Some("99999.9"));
        assert_eq!(field(&line, "mem_area_um2"), Some("98765.4"));
    }

    #[test]
    fn malformed_lines_parse_to_none() {
        assert!(parse_line("").is_none());
        assert!(parse_line("{\"schema\":\"other/v9\"}").is_none());
        let line = record_line("gemm", Scale::Tiny, &sample_point());
        assert!(parse_line(&line[..line.len() / 2]).is_none(), "torn tail must not parse");
    }

    #[test]
    fn keyed_load_separates_scales_and_collapses_duplicates() {
        let dir = std::env::temp_dir().join("amm_dse_sink_keyed_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("keyed.jsonl");
        let p = sample_point();
        let tiny = record_line("gemm", Scale::Tiny, &p);
        let paper = record_line("gemm", Scale::Paper, &p);
        let mut conflicted = parse_line(&tiny).unwrap().2;
        conflicted.out.cycles += 1;
        let conflict = record_line("gemm", Scale::Tiny, &conflicted);
        std::fs::write(&path, format!("{tiny}\n{paper}\n{tiny}\n{conflict}\n")).unwrap();
        let mut map = HashMap::new();
        let info = load_keyed_into(&path, &mut map).unwrap();
        assert_eq!(info.records, 4);
        assert_eq!(info.duplicates, 1, "identical repeat collapses");
        assert_eq!(info.conflicts, 1, "differing payload is a conflict");
        assert!(!info.torn_tail);
        // scale is part of the key: the tiny and paper records coexist,
        // and the tiny slot kept the FIRST (unconflicted) payload
        assert_eq!(map.len(), 2);
        assert_eq!(map[&key("gemm", Scale::Tiny, &p.id)].out, p.out);
        assert_eq!(map[&key("gemm", Scale::Paper, &p.id)].out, p.out);
        // a second load over the same map only adds duplicates
        let again = load_keyed_into(&path, &mut map).unwrap();
        assert_eq!(map.len(), 2);
        assert_eq!(again.duplicates + again.conflicts, 4);
    }

    #[test]
    fn status_writer_emits_a_complete_document_atomically() {
        let dir = std::env::temp_dir().join("amm_dse_status_unit");
        let _ = std::fs::create_dir_all(&dir);
        let sink = dir.join("s0.jsonl");
        let mut st = StatusWriter::new(
            &sink,
            Some("0/2".to_string()),
            Scale::Tiny,
            3,
            10,
            1, // one point served by the sim store
            5,
            7,
            1,
            0, // no history ring in this test
        );
        assert_eq!(st.path(), status_path(&sink));
        st.update(4, 4, true);
        let text = std::fs::read_to_string(status_path(&sink)).unwrap();
        assert!(text.ends_with('\n'));
        for needle in [
            "\"schema\":\"campaign-status/v1\"",
            "\"shard\":\"0/2\"",
            "\"scale\":\"tiny\"",
            "\"done\":7",
            "\"total\":13",
            "\"resumed\":3",
            "\"restored\":3",
            "\"memoized\":1",
            // 4 received minus the 1 memoized: simulated is fresh-only
            "\"simulated\":3",
            "\"cost_hits\":5",
            "\"cost_misses\":7",
            "\"cost_batches\":1",
            "\"complete\":false",
            "\"updated_unix\":",
        ] {
            assert!(text.contains(needle), "{needle} missing from {text}");
        }
        assert!(!text.contains("\"eta_s\":null"), "mid-run status carries an ETA: {text}");
        // the final write: complete, no ETA, null shard for unsharded
        let mut unsharded = StatusWriter::new(&sink, None, Scale::Tiny, 0, 2, 0, 0, 0, 0, 0);
        unsharded.update(2, 2, true);
        let text = std::fs::read_to_string(status_path(&sink)).unwrap();
        assert!(text.contains("\"shard\":null"), "{text}");
        assert!(text.contains("\"complete\":true"), "{text}");
        assert!(text.contains("\"eta_s\":null"), "{text}");
        // no torn tmp file lingers
        assert!(!status_path(&sink).with_extension("json.tmp").exists());
        // history disabled: no ring file appears
        assert!(!history_path(&sink).exists());
    }

    #[test]
    fn status_history_ring_appends_and_compacts() {
        let dir = std::env::temp_dir().join("amm_dse_status_history");
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::create_dir_all(&dir);
        let sink = dir.join("h.jsonl");
        let limit = 4usize;
        let mut st = StatusWriter::new(&sink, None, Scale::Tiny, 0, 100, 0, 0, 0, 0, limit);
        for i in 0..(2 * limit + 3) {
            st.update(i, i, true); // force past the 100 ms throttle
        }
        let text = std::fs::read_to_string(history_path(&sink)).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(
            lines.len() <= 2 * limit,
            "ring stays bounded: {} lines for limit {limit}",
            lines.len()
        );
        // every snapshot is a full status document, newest last
        for line in &lines {
            assert!(line.contains("\"schema\":\"campaign-status/v1\""), "{line}");
        }
        let newest = lines.last().unwrap();
        assert!(newest.contains(&format!("\"done\":{}", 2 * limit + 2)), "{newest}");
        // a resumed writer keeps appending to the surviving ring
        let before = lines.len();
        let mut resumed = StatusWriter::new(&sink, None, Scale::Tiny, 0, 100, 0, 0, 0, 0, limit);
        resumed.update(50, 50, true);
        let text = std::fs::read_to_string(history_path(&sink)).unwrap();
        assert_eq!(text.lines().count(), before + 1);
        assert!(text.lines().last().unwrap().contains("\"done\":50"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_reports_torn_tails_and_skips_them() {
        let dir = std::env::temp_dir().join("amm_dse_sink_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("torn.jsonl");
        let full = record_line("gemm", Scale::Tiny, &sample_point());
        std::fs::write(&path, format!("{full}\n{}", &full[..20])).unwrap();
        let (records, torn) = load(&path).unwrap();
        assert_eq!(records.len(), 1);
        assert!(torn, "newline-less tail must be reported");
        std::fs::write(&path, format!("{full}\n")).unwrap();
        let (records, torn) = load(&path).unwrap();
        assert_eq!(records.len(), 1);
        assert!(!torn);
    }
}
