//! Criterion-substitute benchmark harness.
//!
//! Each `cargo bench` target builds a [`Bench`] set, runs warmup +
//! measured iterations, and prints median / mean ± stddev per benchmark.
//! The figure benches additionally write their CSV series under
//! `results/` so `cargo bench` regenerates every paper artifact.

use super::stats;
use std::time::Instant;

/// One benchmark's measured timings.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Benchmark id, e.g. `fig4/gemm/sweep`.
    pub name: String,
    /// Per-iteration wall time in nanoseconds.
    pub iters_ns: Vec<f64>,
    /// Optional throughput denominator (items per iteration).
    pub items: Option<u64>,
}

impl Measurement {
    /// Median ns/iter.
    pub fn median_ns(&self) -> f64 {
        stats::median(&self.iters_ns)
    }

    /// Items per second at the median iteration time, if `items` is set.
    pub fn items_per_s(&self) -> Option<f64> {
        self.items.map(|i| i as f64 / (self.median_ns() / 1e9))
    }
}

/// Harness: collects measurements, prints a criterion-style report.
pub struct Bench {
    /// Target iterations per benchmark (after warmup).
    pub iters: usize,
    /// Warmup iterations.
    pub warmup: usize,
    results: Vec<Measurement>,
    filter: Option<String>,
}

impl Bench {
    /// Harness with explicit iteration counts and no CLI filter — for
    /// programmatic callers like the `repro perf-smoke` CI probe that
    /// need the measurements back, not just the printed report.
    pub fn new(iters: usize, warmup: usize) -> Self {
        Bench { iters: iters.max(1), warmup, results: Vec::new(), filter: None }
    }

    /// Measurements recorded so far, in run order.
    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Construct from CLI args (supports `cargo bench -- <filter>` and
    /// `--quick` for 3 iterations).
    pub fn from_args() -> Self {
        let args: Vec<String> = std::env::args().skip(1).collect();
        let quick = args.iter().any(|a| a == "--quick") || std::env::var("AMM_BENCH_QUICK").is_ok();
        let filter = args
            .iter()
            .find(|a| !a.starts_with('-') && *a != "bench")
            .cloned();
        Bench {
            iters: if quick { 3 } else { 5 },
            warmup: 1,
            results: Vec::new(),
            filter,
        }
    }

    /// Time `f` for `self.iters` iterations (plus warmup). `items` feeds a
    /// throughput line. Returns the last value produced by `f` (so callers
    /// can additionally write results to CSV outside the timed region).
    pub fn run<R>(&mut self, name: &str, items: Option<u64>, mut f: impl FnMut() -> R) -> Option<R> {
        if let Some(flt) = &self.filter {
            if !name.contains(flt.as_str()) {
                return None;
            }
        }
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut iters_ns = Vec::with_capacity(self.iters);
        let mut last = None;
        for _ in 0..self.iters {
            let t0 = Instant::now();
            last = Some(std::hint::black_box(f()));
            iters_ns.push(t0.elapsed().as_nanos() as f64);
        }
        let m = Measurement { name: name.to_string(), iters_ns, items };
        self.print_line(&m);
        self.results.push(m);
        last
    }

    fn print_line(&self, m: &Measurement) {
        let med = m.median_ns();
        let mean = stats::mean(&m.iters_ns);
        let sd = stats::stddev(&m.iters_ns);
        let (val, unit) = humanize_ns(med);
        print!("bench {:<44} median {val:>9.3} {unit:<2} (mean {:>9.3e} ns ± {:.1e})", m.name, mean, sd);
        if let Some(per_sec) = m.items_per_s() {
            print!("  thrpt {per_sec:>10.3e} items/s");
        }
        println!();
    }

    /// Finish: print a footer. (Kept for symmetry with criterion's
    /// lifecycle; figure benches write CSVs themselves.)
    pub fn finish(self) {
        println!("benchkit: {} benchmark(s) complete", self.results.len());
    }
}

/// Median over the per-invocation medians of every measurement recorded
/// under `name` — the de-flaked statistic `perf-smoke --repeats N`
/// reports (each repeat is one `Bench::run` call under the same name,
/// so a single noisy repeat cannot drag the reported number).
pub fn median_median_ns(results: &[Measurement], name: &str) -> f64 {
    let meds: Vec<f64> =
        results.iter().filter(|m| m.name == name).map(Measurement::median_ns).collect();
    stats::median(&meds)
}

/// Host fingerprint for benchmark JSON: the CPU model string (from
/// `/proc/cpuinfo`, best-effort — "unknown" off Linux) and the logical
/// core count. Recorded in every `BENCH_*.json` so the perf trajectory
/// is comparable across CI runners.
pub fn host_fingerprint() -> (String, usize) {
    let cpu = std::fs::read_to_string("/proc/cpuinfo")
        .ok()
        .and_then(|t| {
            t.lines()
                .find(|l| l.starts_with("model name"))
                .and_then(|l| l.split_once(':').map(|(_, v)| v.trim().to_string()))
        })
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string());
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    (cpu, cores)
}

/// Pick a human-friendly time unit.
pub fn humanize_ns(ns: f64) -> (f64, &'static str) {
    if ns < 1e3 {
        (ns, "ns")
    } else if ns < 1e6 {
        (ns / 1e3, "us")
    } else if ns < 1e9 {
        (ns / 1e6, "ms")
    } else {
        (ns / 1e9, "s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_records() {
        let mut b = Bench { iters: 3, warmup: 1, results: Vec::new(), filter: None };
        let out = b.run("unit/test", Some(10), || 42u32);
        assert_eq!(out, Some(42));
        assert_eq!(b.results.len(), 1);
        assert_eq!(b.results[0].iters_ns.len(), 3);
    }

    #[test]
    fn filter_skips() {
        let mut b = Bench { iters: 3, warmup: 0, results: Vec::new(), filter: Some("xyz".into()) };
        let out = b.run("unit/other", None, || 1u8);
        assert_eq!(out, None);
        assert!(b.results.is_empty());
    }

    #[test]
    fn median_of_repeats_ignores_one_noisy_run() {
        let m = |ns: f64| Measurement { name: "x".into(), iters_ns: vec![ns], items: None };
        let rs = vec![m(10.0), m(12.0), m(5000.0)];
        assert_eq!(median_median_ns(&rs, "x"), 12.0);
        let rs = vec![m(10.0), Measurement { name: "y".into(), iters_ns: vec![1.0], items: None }];
        assert_eq!(median_median_ns(&rs, "x"), 10.0);
    }

    #[test]
    fn host_fingerprint_is_nonempty() {
        let (cpu, cores) = host_fingerprint();
        assert!(!cpu.is_empty());
        assert!(cores >= 1);
    }

    #[test]
    fn humanize() {
        assert_eq!(humanize_ns(500.0).1, "ns");
        assert_eq!(humanize_ns(5e4).1, "us");
        assert_eq!(humanize_ns(5e7).1, "ms");
        assert_eq!(humanize_ns(5e9).1, "s");
    }
}
