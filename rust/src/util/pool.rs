//! A small scoped thread pool (rayon-substitute) for the DSE sweeps.
//!
//! `parallel_map` splits a work list over `n` OS threads using an atomic
//! work-stealing index — no allocation per item, results land in-place, and
//! panics in workers propagate to the caller.
//!
//! One dispatch = one pool: workers (named `dse-worker-<n>` for
//! debuggers and thread profilers) live exactly as long as their work
//! list. The campaign layer exploits this by submitting the *entire*
//! suite × sweep cross-product as a single `parallel_map_with` call, so
//! spawn cost and per-worker state (one `SimArena` each) are amortized
//! across the whole campaign instead of per benchmark.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default (`AMM_DSE_THREADS` overrides).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("AMM_DSE_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Map `f` over `items` in parallel on `threads` OS threads, preserving
/// order. `f` must be `Sync`; items are taken by shared reference.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send + Default + Clone,
    F: Fn(&T) -> R + Sync,
{
    parallel_map_with(items, threads, || (), |_, t| f(t))
}

/// [`parallel_map`] with per-worker owned state: each worker thread
/// builds one `S` via `init` and hands it mutably to `f` for every item
/// it processes. This is how sweep workers own one reusable
/// `sched::SimArena` for their whole slice instead of allocating
/// scheduler state per design point.
pub fn parallel_map_with<T, S, R, FI, F>(items: &[T], threads: usize, init: FI, f: F) -> Vec<R>
where
    T: Sync,
    R: Send + Default + Clone,
    FI: Fn() -> S + Sync,
    F: Fn(&mut S, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        let mut state = init();
        return items.iter().map(|t| f(&mut state, t)).collect();
    }
    let mut results: Vec<R> = vec![R::default(); n];
    let next = AtomicUsize::new(0);
    // SAFETY-free approach: hand out disjoint &mut cells via raw parts is
    // avoidable — use a Vec of Mutexes? Too slow. Instead: split results
    // into per-index cells with `as_mut_ptr` wrapped in a Sync holder.
    struct Cells<R>(*mut R);
    unsafe impl<R> Sync for Cells<R> {}
    let cells = Cells(results.as_mut_ptr());
    // Edition-2021 closures capture fields disjointly, which would pull
    // the raw `*mut R` (not `Sync`) into the closure — capture the whole
    // wrapper by reference instead.
    let cells = &cells;
    let (f, init, next) = (&f, &init, &next);
    std::thread::scope(|s| {
        for w in 0..threads {
            std::thread::Builder::new()
                .name(format!("dse-worker-{w}"))
                .spawn_scoped(s, move || {
                    // Worker-owned state: created on this thread, never
                    // shared, dropped when the worker's slice drains.
                    let mut state = init();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let r = f(&mut state, &items[i]);
                        // SAFETY: each index i is claimed exactly once via the
                        // atomic counter, so writes to cells are disjoint; the
                        // scope guarantees `results` outlives all workers.
                        unsafe {
                            *cells.0.add(i) = r;
                        }
                    }
                })
                .expect("spawn pool worker");
        }
    });
    results
}

/// Chunked variant: processes `items` in `chunk`-sized blocks to amortize
/// the atomic increment for very cheap work items.
pub fn parallel_map_chunked<T, R, F>(items: &[T], threads: usize, chunk: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send + Default + Clone,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let chunk = chunk.max(1);
    let threads = threads.max(1).min(n.div_ceil(chunk));
    if threads == 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let mut results: Vec<R> = vec![R::default(); n];
    let next = AtomicUsize::new(0);
    struct Cells<R>(*mut R);
    unsafe impl<R> Sync for Cells<R> {}
    let cells = Cells(results.as_mut_ptr());
    let cells = &cells; // see parallel_map: avoid disjoint field capture
    let (f, next) = (&f, &next);
    std::thread::scope(|s| {
        for w in 0..threads {
            std::thread::Builder::new()
                .name(format!("dse-worker-{w}"))
                .spawn_scoped(s, move || loop {
                    let start = next.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    for i in start..end {
                        let r = f(&items[i]);
                        // SAFETY: chunks [start, end) are disjoint across claims.
                        unsafe {
                            *cells.0.add(i) = r;
                        }
                    }
                })
                .expect("spawn pool worker");
        }
    });
    results
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn chunked_matches_plain() {
        let items: Vec<u64> = (0..777).collect();
        let a = parallel_map(&items, 4, |&x| x + 1);
        let b = parallel_map_chunked(&items, 4, 32, |&x| x + 1);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_and_single() {
        let none: Vec<u32> = vec![];
        assert!(parallel_map(&none, 4, |&x| x).is_empty());
        assert_eq!(parallel_map(&[42u32], 4, |&x| x), vec![42]);
    }

    #[test]
    fn single_thread_path() {
        let items: Vec<u32> = (0..10).collect();
        assert_eq!(parallel_map(&items, 1, |&x| x * x)[9], 81);
    }

    #[test]
    fn with_state_matches_plain_and_reuses_state() {
        let items: Vec<u64> = (0..500).collect();
        let plain = parallel_map(&items, 4, |&x| x + 7);
        // State is a scratch Vec each worker keeps across its items; the
        // result must not depend on how dirty it is.
        let with = parallel_map_with(
            &items,
            4,
            Vec::<u64>::new,
            |scratch, &x| {
                scratch.push(x); // deliberately dirty the state
                x + 7
            },
        );
        assert_eq!(plain, with);
    }

    #[test]
    fn with_state_single_thread_uses_one_state() {
        let inits = AtomicUsize::new(0);
        let items: Vec<u32> = (0..10).collect();
        let out = parallel_map_with(
            &items,
            1,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
                0u32
            },
            |acc, &x| {
                *acc += x;
                *acc
            },
        );
        assert_eq!(inits.load(Ordering::Relaxed), 1, "one worker, one init");
        assert_eq!(out[9], (0..10).sum::<u32>(), "state accumulates across items");
    }
}
