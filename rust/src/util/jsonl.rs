//! Shared helpers for the crate's flat single-line JSON record formats
//! (the campaign result sink, the cost store, the status sidecar).
//!
//! These are deliberately **not** a general JSON parser: every emitter
//! in this crate writes one flat object per line with no nesting, and
//! the only free-form string it embeds is escaped with [`escape`].
//! Keeping the extractor in one place stops the sink and the cost
//! store from drifting apart (both pin the prefixed-key pitfall in
//! their tests).

use std::path::{Path, PathBuf};

/// Extract one scalar field from a flat single-line JSON object. The
/// quote in the `"key":` pattern anchors the match to the real key, so
/// `"id"` is not fooled by `"mem_id"`. Relies on the emitters never
/// nesting objects or leaving `"`/`,`/`}` unescaped inside string
/// values.
pub fn field<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":");
    let at = line.find(&pat)? + pat.len();
    let rest = &line[at..];
    if let Some(s) = rest.strip_prefix('"') {
        s.split('"').next()
    } else {
        let end = rest.find([',', '}'])?;
        Some(rest[..end].trim())
    }
}

/// Escape a free-form string for embedding in a JSON string value:
/// backslashes, double quotes, and control characters (the latter as
/// `\u00XX`). Everything this crate emits besides user-supplied paths
/// is already from a constrained alphabet.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// `<path><suffix>` as a new path: the sidecar-naming idiom shared by
/// the campaign sink (`<sink>.status.json`, `<sink>.cost.jsonl`) and
/// the stores' atomic-rewrite tmp files (`<file>.tmp`).
pub fn path_with_suffix(path: &Path, suffix: &str) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(suffix);
    PathBuf::from(os)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_extracts_strings_and_scalars() {
        let line = "{\"schema\":\"x/v1\",\"id\":\"a/b\",\"mem_id\":\"zzz\",\"n\":42,\"f\":1.5}";
        assert_eq!(field(line, "schema"), Some("x/v1"));
        assert_eq!(field(line, "id"), Some("a/b"), "not fooled by the mem_id key");
        assert_eq!(field(line, "n"), Some("42"));
        assert_eq!(field(line, "f"), Some("1.5"));
        assert_eq!(field(line, "missing"), None);
    }

    #[test]
    fn escape_handles_quotes_backslashes_and_controls() {
        assert_eq!(escape("plain/path.jsonl"), "plain/path.jsonl");
        assert_eq!(escape("a\\b"), "a\\\\b");
        assert_eq!(escape("say \"hi\""), "say \\\"hi\\\"");
        assert_eq!(escape("tab\there"), "tab\\u0009here");
    }

    #[test]
    fn path_with_suffix_appends_to_the_full_name() {
        let p = path_with_suffix(Path::new("results/s0.jsonl"), ".status.json");
        assert_eq!(p, Path::new("results/s0.jsonl.status.json"));
    }
}
