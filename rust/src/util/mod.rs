//! In-tree replacements for the crates this (fully offline) environment
//! cannot provide: a PRNG (`rng`), summary statistics (`stats`), a scoped
//! thread pool (`pool`), a minimal TOML-subset parser (`tomlmini`), a
//! property-based-testing kit (`propkit`, proptest-style shrink-on-failure),
//! and a criterion-style benchmark harness (`benchkit`).

pub mod benchkit;
pub mod hash;
pub mod jsonl;
pub mod log;
pub mod pool;
pub mod propkit;
pub mod rng;
pub mod stats;
pub mod tomlmini;
