//! FNV-1a (64-bit): the crate's one stable content hash. Both the
//! shard assignment ([`crate::spec::shard_of`]) and the cost-store
//! keying ([`crate::cost::key_hash`], [`crate::runtime::artifact_fingerprint`])
//! are *pinned on-disk/cross-host contracts* built on these constants —
//! keeping the fold in one place means a typo can't silently fork one
//! of them.

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold `bytes` into a running FNV-1a hash (seed with [`FNV_OFFSET`]).
pub fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_matches_published_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a(FNV_OFFSET, b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(FNV_OFFSET, b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(FNV_OFFSET, b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn fnv1a_folds_incrementally() {
        let whole = fnv1a(FNV_OFFSET, b"hello world");
        let split = fnv1a(fnv1a(FNV_OFFSET, b"hello "), b"world");
        assert_eq!(whole, split);
    }
}
