//! Summary statistics used by the bench harness and the DSE reports.

/// Arithmetic mean. Empty input → 0.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean of strictly-positive values (the paper's §IV-C metric).
/// Computed in log space to avoid overflow over long products.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    debug_assert!(xs.iter().all(|&x| x > 0.0), "geomean needs positive inputs");
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Sample standard deviation (n-1 denominator). n < 2 → 0.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Median (of a copy; input order preserved).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Pearson correlation coefficient. Returns 0 when either side is constant.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// Spearman rank correlation (robust to the non-linear locality/ratio
/// relationship in Fig 5).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    let rx = ranks(xs);
    let ry = ranks(ys);
    pearson(&rx, &ry)
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut r = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        // average ranks over ties
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0;
        for k in i..=j {
            r[idx[k]] = avg;
        }
        i = j + 1;
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn geomean_matches_hand_calc() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        let g = geomean(&[2.0, 2.0, 2.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_no_overflow() {
        let xs = vec![1e300; 100];
        let g = geomean(&xs);
        assert!((g / 1e300 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn pearson_perfect() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yneg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_zero() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y = [1.0, 8.0, 27.0, 64.0, 125.0]; // monotone, nonlinear
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_bounds() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn stddev_known() {
        let s = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s - 2.138089935).abs() < 1e-6);
    }
}
