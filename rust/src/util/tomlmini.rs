//! Minimal TOML-subset parser for the framework's config files.
//!
//! Supports what `configs/*.toml` use: `[section]` and `[[array-of-table]]`
//! headers, `key = value` with string / integer / float / boolean / array
//! values, `#` comments, and basic inline whitespace. Unsupported TOML
//! (dates, inline tables, dotted keys, multiline strings) is a parse error,
//! not silent misbehaviour.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Integer (i64).
    Int(i64),
    /// Float (f64).
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Homogeneous-or-not array.
    Array(Vec<Value>),
}

impl Value {
    /// As string, if `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// As i64, if `Int`.
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// As f64 (accepts `Int` too).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// As bool, if `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// As array slice, if `Array`.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// One table (section) of key → value.
pub type Table = BTreeMap<String, Value>;

/// A parsed document: the root table, named tables, and arrays-of-tables.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    /// Keys before any `[section]` header.
    pub root: Table,
    /// `[name]` sections in file order.
    pub tables: Vec<(String, Table)>,
    /// `[[name]]` array-of-tables entries in file order.
    pub table_arrays: Vec<(String, Table)>,
}

impl Doc {
    /// First `[name]` table.
    pub fn table(&self, name: &str) -> Option<&Table> {
        self.tables.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }
    /// All `[[name]]` entries.
    pub fn array_of(&self, name: &str) -> Vec<&Table> {
        self.table_arrays.iter().filter(|(n, _)| n == name).map(|(_, t)| t).collect()
    }
    /// Root-or-section lookup: `get("a.b")` finds key `b` in table `a`.
    pub fn get(&self, path: &str) -> Option<&Value> {
        match path.split_once('.') {
            None => self.root.get(path),
            Some((t, k)) => self.table(t)?.get(k),
        }
    }
}

/// Parse error with line number.
#[derive(Debug)]
pub struct ParseError {
    /// 1-based line.
    pub line: usize,
    /// Description.
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a TOML-subset document.
pub fn parse(src: &str) -> Result<Doc, ParseError> {
    let mut doc = Doc::default();
    // (is_array, name) of the currently-open section; None = root.
    let mut current: Option<(bool, String)> = None;

    for (lineno, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| ParseError { line: lineno + 1, msg: msg.to_string() };

        if let Some(rest) = line.strip_prefix("[[") {
            let name = rest.strip_suffix("]]").ok_or_else(|| err("unterminated [[table]]"))?.trim();
            if name.is_empty() {
                return Err(err("empty table name"));
            }
            doc.table_arrays.push((name.to_string(), Table::new()));
            current = Some((true, name.to_string()));
        } else if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| err("unterminated [table]"))?.trim();
            if name.is_empty() {
                return Err(err("empty table name"));
            }
            doc.tables.push((name.to_string(), Table::new()));
            current = Some((false, name.to_string()));
        } else {
            let (key, val) = line.split_once('=').ok_or_else(|| err("expected key = value"))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let value = parse_value(val.trim()).map_err(|m| err(&m))?;
            let table = match &current {
                None => &mut doc.root,
                Some((true, _)) => &mut doc.table_arrays.last_mut().unwrap().1,
                Some((false, _)) => &mut doc.tables.last_mut().unwrap().1,
            };
            table.insert(key.to_string(), value);
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // A `#` inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quote in string (escapes unsupported)".into());
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(Value::Array(items));
    }
    let cleaned = s.replace('_', "");
    if cleaned.contains('.') || cleaned.contains('e') || cleaned.contains('E') {
        if let Ok(f) = cleaned.parse::<f64>() {
            return Ok(Value::Float(f));
        }
    }
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    Err(format!("cannot parse value: {s:?}"))
}

/// Split on commas not nested inside brackets or strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_doc() {
        let doc = parse(
            r#"
            # comment
            name = "gemm"   # trailing comment
            n = 32
            scale = 1.5
            verbose = true

            [sweep]
            unroll = [1, 2, 4]
            kinds = ["banked", "xor"]

            [[mem]]
            kind = "lvt"
            read_ports = 2

            [[mem]]
            kind = "xor"
            read_ports = 4
            "#,
        )
        .unwrap();
        assert_eq!(doc.root["name"], Value::Str("gemm".into()));
        assert_eq!(doc.root["n"], Value::Int(32));
        assert_eq!(doc.root["scale"], Value::Float(1.5));
        assert_eq!(doc.root["verbose"], Value::Bool(true));
        let sweep = doc.table("sweep").unwrap();
        assert_eq!(sweep["unroll"].as_array().unwrap().len(), 3);
        let mems = doc.array_of("mem");
        assert_eq!(mems.len(), 2);
        assert_eq!(mems[1]["read_ports"], Value::Int(4));
    }

    #[test]
    fn dotted_get() {
        let doc = parse("[a]\nb = 7\n").unwrap();
        assert_eq!(doc.get("a.b").unwrap().as_int(), Some(7));
        assert!(doc.get("a.c").is_none());
    }

    #[test]
    fn hash_inside_string() {
        let doc = parse("s = \"a#b\"\n").unwrap();
        assert_eq!(doc.root["s"].as_str(), Some("a#b"));
    }

    #[test]
    fn underscored_int() {
        let doc = parse("n = 1_000_000\n").unwrap();
        assert_eq!(doc.root["n"].as_int(), Some(1_000_000));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn nested_arrays() {
        let doc = parse("m = [[1, 2], [3, 4]]\n").unwrap();
        let outer = doc.root["m"].as_array().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[1].as_array().unwrap()[0].as_int(), Some(3));
    }

    #[test]
    fn rejects_unterminated() {
        assert!(parse("s = \"oops\n").is_err());
        assert!(parse("[sec\n").is_err());
    }
}
