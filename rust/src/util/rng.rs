//! Deterministic PRNG — `xoshiro256**` (Blackman & Vigna).
//!
//! Every stochastic component in the framework (workload generators,
//! property tests, sweep subsampling) takes an explicit [`Rng`] so runs are
//! reproducible from a seed recorded in EXPERIMENTS.md.

/// `xoshiro256**` pseudo-random generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that similar seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32-bit output (upper half — better-distributed bits).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` using Lemire's unbiased multiply-shift.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (bound as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return hi;
            }
        }
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        lo + self.below((hi - lo + 1) as u64) as u32
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below_usize(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(3);
        for bound in [1u64, 2, 3, 7, 100, 1 << 40] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
