//! Minimal property-based-testing kit (proptest-substitute).
//!
//! `check` runs a property over `cases` random inputs drawn from a
//! generator; on failure it greedily shrinks the input via the
//! user-supplied `shrink` function and reports the minimal counterexample.
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath in this offline image)
//! use amm_dse::util::propkit::{check, Config};
//! check(Config::default().cases(64), |rng| {
//!     let n = rng.below(1000) as u32;
//!     (n, ())
//! }, |(n, _)| *n < 1000, |_| vec![]);
//! ```

use super::rng::Rng;

/// Property-run configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; case `i` uses `seed + i` so failures are reproducible.
    pub seed: u64,
    /// Maximum shrink steps before giving up on minimization.
    pub max_shrink: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0xA11ADD1, max_shrink: 2000 }
    }
}

impl Config {
    /// Override the number of cases.
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Override the seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Run `prop` on `cases` inputs drawn by `gen`. On failure, repeatedly
/// apply `shrink` (which returns candidate smaller inputs) while the
/// property still fails, then panic with the minimal counterexample.
pub fn check<T, G, P, S>(cfg: Config, gen: G, prop: P, shrink: S)
where
    T: std::fmt::Debug + Clone,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> bool,
    S: Fn(&T) -> Vec<T>,
{
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed.wrapping_add(case as u64));
        let input = gen(&mut rng);
        if prop(&input) {
            continue;
        }
        // Shrink.
        let mut minimal = input.clone();
        let mut steps = 0;
        'outer: while steps < cfg.max_shrink {
            for cand in shrink(&minimal) {
                steps += 1;
                if !prop(&cand) {
                    minimal = cand;
                    continue 'outer;
                }
                if steps >= cfg.max_shrink {
                    break;
                }
            }
            break;
        }
        panic!(
            "property failed (case {case}, seed {})\n  original: {:?}\n  minimal:  {:?}",
            cfg.seed.wrapping_add(case as u64),
            input,
            minimal
        );
    }
}

/// Shrinker for a `Vec<T>`: tries removing halves, then single elements.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    let n = v.len();
    if n == 0 {
        return out;
    }
    out.push(v[..n / 2].to_vec());
    out.push(v[n / 2..].to_vec());
    if n <= 16 {
        for i in 0..n {
            let mut w = v.to_vec();
            w.remove(i);
            out.push(w);
        }
    }
    out
}

/// Shrinker for an integer: tries 0, half, and decrement.
pub fn shrink_u32(x: u32) -> Vec<u32> {
    let mut out = Vec::new();
    if x > 0 {
        out.push(0);
        if x > 1 {
            out.push(x / 2);
        }
        out.push(x - 1);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_silent() {
        check(
            Config::default().cases(64),
            |rng| rng.below(100) as u32,
            |&x| x < 100,
            |&x| shrink_u32(x),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(
            Config::default().cases(64),
            |rng| rng.below(100) as u32,
            |&x| x < 50,
            |&x| shrink_u32(x),
        );
    }

    #[test]
    fn shrinks_to_minimal() {
        // Capture the panic message and check the minimal counterexample
        // for `x < 50` is exactly 50.
        let result = std::panic::catch_unwind(|| {
            check(
                Config::default().cases(64),
                |rng| rng.below(100) as u32,
                |&x| x < 50,
                |&x| shrink_u32(x),
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("minimal:  50"), "msg: {msg}");
    }

    #[test]
    fn shrink_vec_halves() {
        let cands = shrink_vec(&[1, 2, 3, 4]);
        assert!(cands.contains(&vec![1, 2]));
        assert!(cands.contains(&vec![3, 4]));
        assert!(cands.contains(&vec![2, 3, 4]));
    }
}
