//! Minimal stderr logging shim (replacement for the `log` crate facade).
//!
//! Warnings always print; info lines only when `AMM_DSE_VERBOSE` is set.
//! Deliberately tiny — the crate's long-running paths report progress
//! through their own return values, not logs.

use std::fmt::Display;

/// Is verbose (info-level) logging enabled?
pub fn verbose() -> bool {
    std::env::var_os("AMM_DSE_VERBOSE").is_some()
}

/// Print a warning to stderr.
pub fn warn(msg: impl Display) {
    eprintln!("[amm-dse warn] {msg}");
}

/// Print an info line to stderr when `AMM_DSE_VERBOSE` is set.
pub fn info(msg: impl Display) {
    if verbose() {
        eprintln!("[amm-dse] {msg}");
    }
}
