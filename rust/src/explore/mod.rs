//! The `Explorer` facade — the crate's front door.
//!
//! One builder replaces the `suite::generate` → `Coordinator::run_sweep`
//! → `dse::*` → `report::*` free-function choreography:
//!
//! ```no_run
//! use amm_dse::{Explorer, dse::Sweep, suite::Scale};
//!
//! let ex = Explorer::new()
//!     .workload("gemm", Scale::Paper)
//!     .sweep(Sweep::default())
//!     .threads(8)
//!     .run()
//!     .expect("exploration failed");
//! println!("{} points, ratio {:?}", ex.points().len(), ex.performance_ratio());
//! ex.write_csv("results/gemm.csv").unwrap();
//! ```
//!
//! `run()` validates everything up front (benchmark name, registry
//! model ids) and returns a single [`Exploration`] handle carrying the
//! evaluated design points plus locality, Pareto, ratio and report
//! accessors. Cost scoring goes through the [`Coordinator`]'s batched
//! cost service (PJRT when artifacts + the `pjrt` feature are present,
//! the pure-Rust mirror otherwise) unless [`Explorer::offline`]
//! disables it.
//!
//! Since the campaign refactor, `Explorer` is a thin veneer over
//! [`crate::campaign::Campaign`]: `run`/`run_with` build a
//! single-benchmark campaign and unwrap its one exploration, so the
//! facade rides the same engine as suite-scale runs — memoized workload
//! generation, [`Coordinator::score_designs`] cost batching, one
//! [`crate::sched::CompiledTrace`] per word-size group, one reusable
//! [`crate::sched::SimArena`] per worker thread (see [`crate::dse`] and
//! [`crate::campaign`]).

use crate::campaign::{Campaign, CampaignOutcome};
use crate::coordinator::{Coordinator, CostBackend};
use crate::dse::{self, BenchSummary, DesignPoint, Sweep};
use crate::error::{Error, Result};
use crate::report;
use crate::spec::CampaignSpec;
use crate::suite::Scale;
use std::path::{Path, PathBuf};

/// Builder for one design-space exploration run.
#[derive(Clone, Debug)]
pub struct Explorer {
    benchmark: Option<String>,
    scale: Scale,
    sweep: Sweep,
    /// Models added via [`Explorer::model`] — kept separate from the
    /// sweep so [`Explorer::sweep`] can truly replace it.
    models: Vec<String>,
    threads: usize,
    artifacts: Option<PathBuf>,
    cost_store: Option<PathBuf>,
    sim_store: Option<PathBuf>,
    offline: bool,
}

impl Default for Explorer {
    fn default() -> Self {
        Self::new()
    }
}

impl Explorer {
    /// Start a new exploration (defaults: paper scale, default sweep,
    /// auto threads, batched cost service on).
    pub fn new() -> Self {
        Explorer {
            benchmark: None,
            scale: Scale::Paper,
            sweep: Sweep::default(),
            models: Vec::new(),
            threads: 0,
            artifacts: None,
            cost_store: None,
            sim_store: None,
            offline: false,
        }
    }

    /// Select the benchmark and scale to explore (required).
    pub fn workload(mut self, name: impl Into<String>, scale: Scale) -> Self {
        self.benchmark = Some(name.into());
        self.scale = scale;
        self
    }

    /// Replace the sweep definition. Models added with
    /// [`Explorer::model`] are tracked separately and survive the
    /// replacement, so builder order doesn't matter.
    pub fn sweep(mut self, sweep: Sweep) -> Self {
        self.sweep = sweep;
        self
    }

    /// Add one memory model by registry id (on top of the sweep's axes).
    pub fn model(mut self, id: impl Into<String>) -> Self {
        self.models.push(id.into());
        self
    }

    /// Scheduler worker threads (0 = auto).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    /// Artifacts directory for the PJRT cost model (default:
    /// [`crate::runtime::artifacts_dir`]).
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = Some(dir.into());
        self
    }

    /// Persist (and warm-start from) the macro-cost store at `path` —
    /// the exploration rides the campaign engine, so it inherits the
    /// tiered cost stack (see [`crate::cost`]) for free.
    pub fn cost_store(mut self, path: impl Into<PathBuf>) -> Self {
        self.cost_store = Some(path.into());
        self
    }

    /// Persist (and warm-start from) the simulation-result store at
    /// `path` — a warm store lets a repeat exploration skip the
    /// cycle-accurate kernel entirely (see [`crate::sim`]).
    pub fn sim_store(mut self, path: impl Into<PathBuf>) -> Self {
        self.sim_store = Some(path.into());
        self
    }

    /// Skip the coordinator/cost-service entirely and evaluate in-process
    /// with the pure-Rust cost model (useful for tests and doctests).
    pub fn offline(mut self) -> Self {
        self.offline = true;
        self
    }

    /// Validate, run the sweep, and hand back the results. Brings up a
    /// private [`Coordinator`] (unless [`Explorer::offline`]); to share
    /// one cost service across several explorations, use
    /// [`Explorer::run_with`].
    pub fn run(self) -> Result<Exploration> {
        if self.offline {
            return single(self.campaign()?.offline().run()?);
        }
        let dir = self.artifacts.clone().unwrap_or_else(crate::runtime::artifacts_dir);
        let threads = if self.threads != 0 { self.threads } else { self.sweep.threads };
        let coord = Coordinator::with_artifacts(dir).threads(threads);
        self.run_with(&coord)
    }

    /// Validate and run the sweep through a caller-provided coordinator,
    /// so several explorations share one cost service (and one compiled
    /// PJRT cost artifact).
    pub fn run_with(self, coord: &Coordinator) -> Result<Exploration> {
        single(self.campaign()?.run_with(coord)?)
    }

    /// Lower this explorer to the serializable [`CampaignSpec`] it
    /// describes — the one-benchmark plan that [`Explorer::run`] hands
    /// to the campaign engine. Useful for shipping the run elsewhere
    /// (`spec.to_toml()`), sharding it, or diffing two builders.
    pub fn spec(self) -> Result<CampaignSpec> {
        self.campaign().map(Campaign::into_spec)
    }

    /// Lower this explorer to the single-benchmark [`Campaign`] it
    /// describes — `Explorer` is a veneer; the campaign engine does the
    /// work, including benchmark-name and model-id validation (only the
    /// "no workload selected" check is facade-specific).
    fn campaign(self) -> Result<Campaign> {
        let benchmark = self
            .benchmark
            .ok_or_else(|| Error::config("no workload selected: call .workload(name, scale)"))?;
        let mut sweep = self.sweep;
        sweep.extra_models.extend(self.models);
        if self.threads != 0 {
            sweep.threads = self.threads;
        }
        let mut campaign = Campaign::new().benchmark(benchmark).scale(self.scale).sweep(sweep);
        if let Some(store) = self.cost_store {
            campaign = campaign.cost_store(store);
        }
        if let Some(store) = self.sim_store {
            campaign = campaign.sim_store(store);
        }
        Ok(campaign)
    }
}

/// Unwrap a single-benchmark campaign's one exploration.
fn single(outcome: CampaignOutcome) -> Result<Exploration> {
    outcome
        .explorations
        .into_iter()
        .next()
        .ok_or_else(|| Error::msg("single-benchmark campaign produced no exploration"))
}

/// Results of one exploration run: evaluated design points plus the
/// post-processing the paper's figures need.
#[derive(Clone, Debug)]
pub struct Exploration {
    /// Benchmark name.
    pub benchmark: String,
    /// Workload scale.
    pub scale: Scale,
    /// Weinberg spatial locality of the trace.
    pub locality: f64,
    /// Cost backend used (`None` for [`Explorer::offline`] runs).
    pub backend: Option<CostBackend>,
    /// Number of trace nodes scheduled per design point.
    pub trace_nodes: usize,
    /// Functional checksum of the traced execution.
    pub checksum: f64,
    /// Every evaluated design point.
    pub points: Vec<DesignPoint>,
}

impl Exploration {
    /// The evaluated design points.
    pub fn points(&self) -> &[DesignPoint] {
        &self.points
    }

    /// Human label for the cost backend (`"Pjrt"`, `"RustFallback"`, or
    /// `"Offline"` for [`Explorer::offline`] runs).
    pub fn backend_label(&self) -> &'static str {
        match self.backend {
            Some(CostBackend::Pjrt) => "Pjrt",
            Some(CostBackend::RustFallback) => "RustFallback",
            None => "Offline",
        }
    }

    /// Pareto frontier minimizing (time, area) — one Fig-4 panel.
    pub fn pareto_area(&self) -> Vec<&DesignPoint> {
        dse::pareto_front(&self.points, |p| p.time_ns(), |p| p.area())
            .into_iter()
            .map(|i| &self.points[i])
            .collect()
    }

    /// Pareto frontier minimizing (time, power).
    pub fn pareto_power(&self) -> Vec<&DesignPoint> {
        dse::pareto_front(&self.points, |p| p.time_ns(), |p| p.power())
            .into_iter()
            .map(|i| &self.points[i])
            .collect()
    }

    /// §IV-C geometric-mean area ratio (banking / AMM) at 10% matched
    /// time, if both families produced frontier points.
    pub fn performance_ratio(&self) -> Option<f64> {
        self.performance_ratio_tol(0.10)
    }

    /// [`Exploration::performance_ratio`] with an explicit relative
    /// time-matching tolerance.
    pub fn performance_ratio_tol(&self, tol: f64) -> Option<f64> {
        dse::performance_ratio(&self.points, tol)
    }

    /// Fastest banking (non-AMM) execution time, ns.
    pub fn best_banking_ns(&self) -> f64 {
        dse::best_time(&self.points, |p| !p.is_amm)
    }

    /// Fastest AMM execution time, ns.
    pub fn best_amm_ns(&self) -> f64 {
        dse::best_time(&self.points, |p| p.is_amm)
    }

    /// Fig-5 row for this benchmark.
    pub fn summary(&self) -> BenchSummary {
        BenchSummary {
            name: self.benchmark.clone(),
            locality: self.locality,
            perf_ratio: self.performance_ratio(),
            best_banking_ns: self.best_banking_ns(),
            best_amm_ns: self.best_amm_ns(),
            n_points: self.points.len(),
        }
    }

    /// The Fig-4 CSV (one row per design point).
    pub fn to_csv(&self) -> String {
        report::fig4_csv(&self.points)
    }

    /// Write the Fig-4 CSV to `path`, creating parent directories.
    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        report::write_file(path, &self.to_csv())
            .map_err(|e| Error::io(format!("write {}", path.display()), e))
    }

    /// ASCII scatter of area vs time (the terminal Fig-4 panel).
    pub fn scatter_area(&self, width: usize, height: usize) -> String {
        report::ascii_scatter(
            &self.points,
            |p| p.area(),
            &format!("{}: area vs time", self.benchmark),
            width,
            height,
        )
    }

    /// ASCII scatter of power vs time.
    pub fn scatter_power(&self, width: usize, height: usize) -> String {
        report::ascii_scatter(
            &self.points,
            |p| p.power(),
            &format!("{}: power vs time", self.benchmark),
            width,
            height,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suite;

    #[test]
    fn run_requires_a_workload() {
        let err = Explorer::new().run().unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
    }

    #[test]
    fn run_rejects_unknown_benchmark() {
        let err = Explorer::new().workload("nope", Scale::Tiny).run().unwrap_err();
        assert!(matches!(err, Error::UnknownBenchmark { .. }), "{err}");
    }

    #[test]
    fn run_rejects_unknown_model_id() {
        let err = Explorer::new()
            .workload("gemm", Scale::Tiny)
            .sweep(Sweep::quick())
            .model("nonsense42")
            .run()
            .unwrap_err();
        assert!(matches!(err, Error::UnknownModel { .. }), "{err}");
    }

    #[test]
    fn offline_exploration_produces_points_and_summaries() {
        let ex = Explorer::new()
            .workload("stencil2d", Scale::Tiny)
            .sweep(Sweep::quick())
            .offline()
            .run()
            .unwrap();
        assert!(!ex.points().is_empty());
        assert!(ex.locality > 0.0);
        assert!(ex.backend.is_none());
        assert!(!ex.pareto_area().is_empty());
        assert!(!ex.pareto_power().is_empty());
        let s = ex.summary();
        assert_eq!(s.n_points, ex.points().len());
        assert!(ex.to_csv().lines().count() == ex.points().len() + 1);
    }

    #[test]
    fn facade_matches_the_free_function_path() {
        // Golden equivalence: the facade must reproduce exactly what the
        // scattered free-function choreography produced.
        let ex = Explorer::new()
            .workload("gemm", Scale::Tiny)
            .sweep(Sweep::quick())
            .offline()
            .run()
            .unwrap();
        let wl = suite::generate("gemm", Scale::Tiny);
        let direct = Sweep::quick().run(&wl.trace);
        assert_eq!(ex.points().len(), direct.len());
        for (a, b) in ex.points().iter().zip(&direct) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.out.cycles, b.out.cycles, "{}", a.id);
            assert_eq!(a.out.area_um2, b.out.area_um2, "{}", a.id);
        }
    }

    #[test]
    fn coordinator_backed_run_reports_a_backend() {
        let tmp = std::env::temp_dir().join("amm_dse_explorer_test");
        let _ = std::fs::create_dir_all(&tmp);
        let ex = Explorer::new()
            .workload("stencil2d", Scale::Tiny)
            .sweep(Sweep::quick())
            .artifacts(&tmp)
            .run()
            .unwrap();
        assert_eq!(ex.backend, Some(CostBackend::RustFallback));
        assert_eq!(ex.backend_label(), "RustFallback");
        assert!(!ex.points().is_empty());
    }

    #[test]
    fn model_calls_survive_a_later_sweep_replacement() {
        // Builder order must not matter: .model() before .sweep() sticks.
        let ex = Explorer::new()
            .workload("stencil2d", Scale::Tiny)
            .model("cmp2r2w")
            .sweep(Sweep::quick())
            .offline()
            .run()
            .unwrap();
        assert!(ex.points().iter().any(|p| p.mem_id == "cmp2r2w"));
    }

    #[test]
    fn run_with_shares_one_coordinator_across_explorations() {
        let tmp = std::env::temp_dir().join("amm_dse_explorer_shared");
        let _ = std::fs::create_dir_all(&tmp);
        let coord = Coordinator::with_artifacts(tmp);
        for bench in ["stencil2d", "gemm"] {
            let ex = Explorer::new()
                .workload(bench, Scale::Tiny)
                .sweep(Sweep::quick())
                .run_with(&coord)
                .unwrap();
            assert_eq!(ex.backend, Some(CostBackend::RustFallback));
            assert!(!ex.points().is_empty());
        }
    }
}
