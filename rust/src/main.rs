//! `repro` — the amm-dse launcher.
//!
//! Subcommands (hand-rolled arg parsing; no CLI crates are available in
//! this offline environment):
//!
//! ```text
//! repro list                              list benchmarks + artifacts
//! repro models                            list registered memory models
//! repro trace <bench> [--scale s]         trace stats for one benchmark
//! repro locality [bench...] [--scale s]   Fig-5 locality table
//! repro locality-sweep [...]              AMM-benefit-vs-locality dial sweep
//! repro simulate <bench> --mem <id> [...] one design point
//! repro run <config.toml> [...]           spec-driven campaign (the canonical verb)
//! repro merge <sinks...> [--config c]     merge shard sinks -> reports
//! repro cost-store <stat|gc|export> <f>   inspect/compact/export a cost store
//! repro sim-store <stat|gc|export> <f>    inspect/compact/export a sim store
//! repro sweep --config <file.toml>        config-driven sweep -> CSV
//! repro figure fig4 [--bench b] [...]     regenerate Fig 4 CSV + plots
//! repro figure fig5 [--scale s]           regenerate Fig 5 + correlation
//! repro synth-table                       §III-A AMM synthesis table
//! repro port-scaling                      Fig-2 HB-NTX port-scaling table
//! ```
//!
//! Flags accept both `--name value` and `--name=value`; unknown flags
//! are a config error (a typo like `--sclae` fails loudly instead of
//! being silently ignored). `simulate`, `sweep`, `run` and `figure`
//! resolve memory organizations through the model registry — they work
//! unchanged for any registered [`amm_dse::mem::MemModel`].

use amm_dse::cost::CostStore;
use amm_dse::dse::{self, Sweep};
use amm_dse::mem;
use amm_dse::sched::Knobs;
use amm_dse::spec::{Shard, ShardStrategy};
use amm_dse::serve;
use amm_dse::sim::SimStore;
use amm_dse::suite::{self, Scale};
use amm_dse::{campaign, config, locality, report, Campaign, Error, Explorer, Result};
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &[String]) -> Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "list" => cmd_list(),
        "models" => cmd_models(),
        "trace" => cmd_trace(&args[1..]),
        "locality" => cmd_locality(&args[1..]),
        "locality-sweep" => cmd_locality_sweep(&args[1..]),
        "simulate" => cmd_simulate(&args[1..]),
        "run" => cmd_run(&args[1..]),
        "merge" => cmd_merge(&args[1..]),
        "serve" => cmd_serve(&args[1..]),
        "cost-store" => cmd_cost_store(&args[1..]),
        "sim-store" => cmd_sim_store(&args[1..]),
        "sweep" => cmd_sweep(&args[1..]),
        "figure" => cmd_figure(&args[1..]),
        "synth-table" => cmd_synth_table(),
        "port-scaling" => cmd_port_scaling(),
        "perf-smoke" => cmd_perf_smoke(&args[1..]),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(Error::msg(format!("unknown command {other:?}; see `repro help`"))),
    }
}

const HELP: &str = r#"repro — Design Space Exploration of Algorithmic Multi-Port Memories

USAGE:
  repro list
  repro models
  repro trace <benchmark> [--scale tiny|paper|large]
  repro locality [<benchmark>...] [--scale tiny|paper|large]
  repro locality-sweep [--config configs/locality.toml] [--scale s]
            [--sink f.jsonl] [--cost-store f.cost.jsonl]
            [--threads N] [--out-dir results] [--quiet]
  repro simulate <benchmark> --mem <id> [--unroll N] [--word N] [--alus N] [--scale s]
  repro run <config.toml> [--shard i/n] [--shard-strategy hash|weighted]
            [--sink f.jsonl] [--cost-store f.cost.jsonl] [--sim-store f.sim.jsonl]
            [--scale s] [--weights w.jsonl] [--status-history N]
            [--threads N] [--out-dir results] [--quiet]
  repro merge <sink.jsonl>... [--config <config.toml>] [--scale s]
            [--out-dir results] [--partial]
  repro merge --pool-stores <store.jsonl>... --out pooled.jsonl
  repro merge --pool-sim-stores <store.jsonl>... --out pooled.jsonl
  repro serve [--addr host:port] [--workers N] [--data-dir serve-data]
            [--artifacts dir] [--status-history N]
  repro cost-store <stat|gc|export> <store.jsonl> [--out f.csv]
  repro sim-store <stat|gc|export> <store.jsonl> [--out f.csv]
  repro sweep --config configs/<file>.toml [--out results/out.csv]
  repro figure fig4 [--bench <name>|all] [--scale s] [--out-dir results] [--sink f.jsonl]
  repro figure fig5 [--scale s] [--out-dir results] [--sink f.jsonl]
  repro synth-table
  repro port-scaling
  repro perf-smoke [--out BENCH_sweep.json] [--campaign-out BENCH_campaign.json]
                   [--batch-out BENCH_batch.json] [--simstore-out BENCH_simstore.json]
                   [--iters N] [--repeats N] [--min-speedup X]
                   [--min-campaign-speedup X] [--min-batch-speedup X]
                   [--min-warm-speedup X]

`run` is the canonical campaign verb: the config file (single-benchmark
or `[campaign]`-table form, see configs/suite.toml) lowers to one
declarative CampaignSpec, and the whole benchmark x sweep cross-product
executes as one work stream over one worker pool, scored by one
deduplicated cost batch, with stderr progress/ETA (silence: --quiet).
With --sink, results stream to an append-only JSONL file as points
complete; re-running with the same --sink resumes, skipping every
already-scored point, and a `<sink>.status.json` sidecar is rewritten
atomically as the run progresses (done/total, ETA, shard, cost
counters) so fleet tooling polls health without parsing stderr. Macro
costs persist to a cost store (`--cost-store`, `[campaign]
cost_store`, default `<sink>.cost.jsonl`): any later run sharing the
store skips the runtime cost batch for every shape already scored
under the same backend fingerprint. Simulation results persist the
same way to a sim store (`--sim-store`, `[campaign] sim_store`,
default `<sink>.sim.jsonl`): any later run sharing the store skips
the cycle-accurate scheduler itself for every design point already
simulated under the same fingerprint + engine version — a warm
re-run against a fresh sink reports `simulated: 0` with byte-identical
results. With --shard i/n, this process
runs only its deterministic 1/n bucket of the plan — run the other
shards anywhere (any host: a spec is data), then reconcile with `repro
merge`; `--shard-strategy weighted` balances shards by benchmark trace
size instead of the uniform hash (a `--weights` table answers trace
sizes from disk so hosts don't trace benchmarks they don't own).
`merge --pool-stores` reconciles shard-fleet cost stores into one
warm store (first-wins on conflicting fingerprint rows), and
`merge --pool-sim-stores` does the same for simulation stores.

`serve` runs the campaign engine as a daemon: POST the same TOML spec
to /campaigns, poll /campaigns/<id>/status, tail
/campaigns/<id>/results?after=N, query /query/pareto and
/cost-store/stat. Every job shares one coordinator, one cost store
and one sim store under --data-dir, so re-submitting a finished spec
issues zero backend batches and simulates zero points. See README
"Serving" for the endpoint table.

Flags take `--name value` or `--name=value`; unknown flags are errors.

BENCHMARK NAMES: everywhere a benchmark is named (trace, locality,
simulate, config files, serve submissions) either a MachSuite name
(`repro list`) or a parametric synthetic spec works, e.g.
`synth:stride=rand,rw=0.7,reuse=64` — dials: stride=unit|s<K>|rand,
mix=0..1, rw=0..1, reuse=32..1048576, conflict=0..1, seed=<u64>,
n=64..16777216 (any order; omitted dials take defaults). See README
"Synthetic workloads". `locality-sweep` runs the configs/locality.toml
dial x port-model campaign and writes locality_amm.csv — AMM benefit
(banked best time / AMM best time) against measured locality.

MEMORY IDS: any id resolvable by the model registry (`repro models`),
e.g. banked<N>, banked2p<N>, bankedblk<N>, pump<K>, lvt<R>r<W>w,
xor<R>r<W>w (HB-NTX), xorflat<R>r<W>w (LaForest), cmp<R>r<W>w
"#;

/// Parsed command-line tail: positionals plus validated flags.
///
/// `--name value` and `--name=value` are both accepted; a flag not in
/// the command's allow-list is a config error (so `--sclae tiny` fails
/// loudly instead of silently running at the default scale).
struct Args {
    positional: Vec<String>,
    values: Vec<(String, String)>,
    bools: Vec<String>,
}

fn parse_args(raw: &[String], value_flags: &[&str], bool_flags: &[&str]) -> Result<Args> {
    let mut args = Args { positional: Vec::new(), values: Vec::new(), bools: Vec::new() };
    let mut i = 0;
    while i < raw.len() {
        let tok = &raw[i];
        if let Some(body) = tok.strip_prefix("--") {
            let (name, inline) = match body.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (body, None),
            };
            let dashed = format!("--{name}");
            if bool_flags.contains(&dashed.as_str()) {
                if inline.is_some() {
                    return Err(Error::config(format!("{dashed} takes no value")));
                }
                args.bools.push(dashed);
            } else if value_flags.contains(&dashed.as_str()) {
                let value = match inline {
                    Some(v) => v,
                    None => {
                        i += 1;
                        let next = raw
                            .get(i)
                            .ok_or_else(|| Error::config(format!("{dashed} needs a value")))?;
                        // don't let a flag swallow the next flag as its
                        // value (`--sink --quiet`); the `--name=value`
                        // form exists for values that really start with
                        // dashes
                        if next.starts_with("--") {
                            return Err(Error::config(format!(
                                "{dashed} needs a value, found flag {next} (use {dashed}=... for dashed values)"
                            )));
                        }
                        next.clone()
                    }
                };
                args.values.push((dashed, value));
            } else {
                return Err(Error::config(format!(
                    "unknown flag {dashed} (see `repro help`)"
                )));
            }
        } else {
            args.positional.push(tok.clone());
        }
        i += 1;
    }
    Ok(args)
}

impl Args {
    fn get(&self, name: &str) -> Option<&str> {
        self.values.iter().rev().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }

    fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|n| n == name)
    }

    fn scale_or(&self, default: Scale) -> Result<Scale> {
        match self.get("--scale") {
            None => Ok(default),
            Some(s) => {
                Scale::parse(s).ok_or_else(|| Error::config(format!("bad --scale {s:?}")))
            }
        }
    }

    fn u32_or(&self, name: &str, default: u32) -> Result<u32> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| Error::config(format!("bad {name} {s:?}"))),
        }
    }

    fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| Error::config(format!("bad {name} {s:?}"))),
        }
    }
}

fn cmd_list() -> Result<()> {
    println!("benchmarks (paper's Fig-4 DSE set marked *):");
    for name in suite::ALL_BENCHMARKS {
        let star = if suite::DSE_BENCHMARKS.contains(&name) { "*" } else { " " };
        println!("  {star} {name}");
    }
    let dir = amm_dse::runtime::artifacts_dir();
    let missing = amm_dse::runtime::missing_artifacts(&dir);
    if missing.is_empty() {
        println!("artifacts: all present in {}", dir.display());
    } else {
        println!("artifacts missing from {}: {missing:?} (run `make artifacts`)", dir.display());
    }
    Ok(())
}

fn cmd_models() -> Result<()> {
    println!("{:<12} {:<14} description", "prefix", "example");
    for e in mem::registry() {
        println!("{:<12} {:<14} {}", e.prefix, e.example, e.synopsis);
    }
    Ok(())
}

fn cmd_trace(rest: &[String]) -> Result<()> {
    let args = parse_args(rest, &["--scale"], &[])?;
    let name = args
        .positional
        .first()
        .cloned()
        .ok_or_else(|| Error::config("usage: repro trace <benchmark>"))?;
    // MachSuite or parametric `synth:` name; bad synth dials error with
    // the known-dial listing
    suite::validate_name(&name)?;
    let scale = args.scale_or(Scale::Paper)?;
    // one-shot path: plain generate, so the trace drops on exit instead
    // of pinning in the workload cache
    let wl = suite::generate(&name, scale);
    let t = &wl.trace;
    println!("benchmark {name} ({scale:?})");
    println!("  nodes          {}", t.len());
    println!("  mem ops        {}", t.mem_ops());
    println!("  alu ops        {}", t.alu_ops());
    println!("  arrays         {}", t.arrays.len());
    for a in &t.arrays {
        println!("    {:<16} {:>8} elems x {}B", a.name, a.length, a.elem_bytes);
    }
    println!("  footprint      {} bytes", t.footprint_bytes());
    println!("  critical path  {}", t.critical_path_len());
    println!("  checksum       {:.6}", wl.checksum);
    let rep = locality::analyze(t);
    println!("  L_spatial      {:.4}", rep.spatial_locality());
    println!("  stride-1 frac  {:.4}", rep.stride1_fraction());
    Ok(())
}

fn cmd_locality(rest: &[String]) -> Result<()> {
    let args = parse_args(rest, &["--scale"], &[])?;
    let scale = args.scale_or(Scale::Paper)?;
    // Positional names (MachSuite or `synth:` specs) restrict the table;
    // default stays the full Fig-5 suite.
    let names: Vec<String> = if args.positional.is_empty() {
        suite::ALL_BENCHMARKS.iter().map(|s| s.to_string()).collect()
    } else {
        for name in &args.positional {
            suite::validate_name(name)?;
        }
        args.positional.clone()
    };
    let width = names.iter().map(|n| n.len()).max().unwrap_or(12).max(12);
    println!("{:<width$} {:>10} {:>12}", "benchmark", "L_spatial", "stride1");
    for name in &names {
        // each benchmark is generated exactly once here: plain generate
        // keeps peak memory at one trace, not thirteen
        let wl = suite::generate(name, scale);
        let rep = locality::analyze(&wl.trace);
        println!(
            "{:<width$} {:>10.4} {:>12.4}",
            name,
            rep.spatial_locality(),
            rep.stride1_fraction()
        );
    }
    Ok(())
}

/// The locality-dial campaign preset: run `configs/locality.toml` (a
/// synthetic dial sweep × the banked + AMM port models), then plot AMM
/// benefit — fastest banked time / fastest AMM time — against the
/// locality measured back from each generated trace. The sink/cost-store
/// machinery is the ordinary campaign engine, so the sweep is resumable
/// and warm-startable like any `repro run`.
fn cmd_locality_sweep(rest: &[String]) -> Result<()> {
    let args = parse_args(
        rest,
        &["--config", "--scale", "--sink", "--cost-store", "--threads", "--out-dir"],
        &["--quiet"],
    )?;
    let cfg_path = args.get("--config").unwrap_or("configs/locality.toml").to_string();
    let rc = config::load(Path::new(&cfg_path))?;
    let mut spec = rc.campaign.clone();
    spec.scale = args.scale_or(spec.scale)?;
    if let Some(s) = args.get("--sink") {
        spec.sink = Some(s.into());
    }
    if let Some(s) = args.get("--cost-store") {
        spec.cost_store = Some(s.into());
    }
    if let Some(s) = args.get("--threads") {
        spec.threads =
            s.parse().map_err(|_| Error::config(format!("bad --threads {s:?}")))?;
    }
    let quiet = args.has("--quiet");
    let out_dir = PathBuf::from(args.get("--out-dir").unwrap_or("results"));
    if !quiet {
        eprintln!(
            "locality-sweep {}: {} dial point(s), {} planned unit(s)",
            cfg_path,
            spec.swept().len(),
            spec.plan_keys().len()
        );
    }
    let opts = campaign::ExecOptions { progress: !quiet, ..Default::default() };
    let outcome = campaign::run(&spec, &opts)?;
    let summaries = outcome.summaries();
    let csv = report::locality_csv(&summaries);
    let csv_path = out_dir.join("locality_amm.csv");
    report::write_file(&csv_path, &csv)
        .map_err(|e| Error::io(format!("write {}", csv_path.display()), e))?;
    println!("{}", report::locality_ascii(&summaries));
    if let Some(rho) = report::locality_benefit_spearman(&summaries) {
        println!(
            "spearman(locality, AMM benefit) = {rho:.3} (paper thesis: negative — \
             the lower the spatial locality, the more true multi-porting buys)"
        );
    }
    println!("wrote {}", csv_path.display());
    Ok(())
}

fn cmd_simulate(rest: &[String]) -> Result<()> {
    let args = parse_args(rest, &["--mem", "--unroll", "--word", "--alus", "--scale"], &[])?;
    let name = args
        .positional
        .first()
        .cloned()
        .ok_or_else(|| Error::config("usage: repro simulate <benchmark> --mem <id>"))?;
    suite::validate_name(&name)?;
    let scale = args.scale_or(Scale::Paper)?;
    let mem_id = args.get("--mem").unwrap_or("banked1").to_string();
    // Registry resolution: any registered model id works, not just the
    // built-in MemKind variants.
    let model = mem::parse_model(&mem_id).ok_or(Error::UnknownModel { id: mem_id.clone() })?;
    let knobs = Knobs {
        unroll: args.u32_or("--unroll", 1)?,
        word_bytes: args.u32_or("--word", 8)?,
        alus: args.u32_or("--alus", 4)?,
    };
    let wl = suite::generate(&name, scale);
    let p = dse::evaluate_model(&wl.trace, &*model, &knobs);
    let out = &p.out;
    println!(
        "benchmark {name} ({scale:?}), mem={} ({}) unroll={} word={}B alus={}",
        model.id(),
        model.describe(),
        knobs.unroll,
        knobs.word_bytes,
        knobs.alus
    );
    println!("  cycles      {}", out.cycles);
    println!("  period      {:.3} ns", out.period_ns);
    println!("  time        {:.1} ns", out.time_ns);
    println!(
        "  area        {:.1} um^2 (mem {:.1} + fu {:.1})",
        out.area_um2, out.mem_area_um2, out.fu_area_um2
    );
    println!("  power       {:.3} mW", out.power_mw);
    println!("  mem access  {}", out.mem_accesses);
    println!("  port stalls {}", out.port_stalls);
    Ok(())
}

/// The canonical campaign verb: `<config.toml>` lowers to a
/// [`amm_dse::CampaignSpec`], CLI flags override the spec's sink /
/// shard / scale / threads, and the campaign engine does the rest.
fn cmd_run(rest: &[String]) -> Result<()> {
    let args = parse_args(
        rest,
        &[
            "--shard",
            "--shard-strategy",
            "--sink",
            "--cost-store",
            "--sim-store",
            "--scale",
            "--weights",
            "--status-history",
            "--threads",
            "--out-dir",
        ],
        &["--quiet"],
    )?;
    let cfg_path = args
        .positional
        .first()
        .cloned()
        .ok_or_else(|| Error::config("usage: repro run <config.toml> [--shard i/n] [--sink f.jsonl]"))?;
    let rc = config::load(Path::new(&cfg_path))?;
    let mut spec = rc.campaign.clone();
    spec.scale = args.scale_or(spec.scale)?;
    if let Some(s) = args.get("--sink") {
        spec.sink = Some(s.into());
    }
    if let Some(s) = args.get("--cost-store") {
        spec.cost_store = Some(s.into());
    }
    if let Some(s) = args.get("--sim-store") {
        spec.sim_store = Some(s.into());
    }
    if let Some(s) = args.get("--shard") {
        spec.shard = Some(Shard::parse(s)?);
    }
    if let Some(s) = args.get("--weights") {
        spec.weights = Some(s.into());
    }
    if let Some(s) = args.get("--shard-strategy") {
        spec.shard_strategy = ShardStrategy::parse(s)
            .ok_or_else(|| Error::config(format!("bad --shard-strategy {s:?} (hash|weighted)")))?;
    }
    if let Some(s) = args.get("--threads") {
        spec.threads = s
            .parse()
            .map_err(|_| Error::config(format!("bad --threads {s:?}")))?;
    }
    let quiet = args.has("--quiet");
    let out_dir = PathBuf::from(args.get("--out-dir").unwrap_or("results"));
    if !quiet {
        let shard_note = spec
            .shard
            .map(|sh| format!(", shard {sh}"))
            .unwrap_or_default();
        eprintln!(
            "run {}: {} swept + {} locality-only benchmark(s), {} planned unit(s){shard_note}",
            cfg_path,
            spec.swept().len(),
            spec.locality_names().len(),
            spec.plan_keys().len(),
        );
    }
    let mut opts = campaign::ExecOptions { progress: !quiet, ..Default::default() };
    if let Some(s) = args.get("--status-history") {
        opts.status_history = s
            .parse()
            .map_err(|_| Error::config(format!("bad --status-history {s:?}")))?;
    }
    let t0 = std::time::Instant::now();
    let outcome = campaign::run(&spec, &opts)?;
    if !quiet {
        eprintln!(
            "campaign: {} points ({} simulated, {} memoized, {} restored) in {:.2?} ({:.0} points/s sustained, cost backend {}, {} cost batch(es), {} hit(s), {} miss(es))",
            outcome.total_points(),
            outcome.simulated,
            outcome.memoized,
            outcome.resumed,
            t0.elapsed(),
            outcome.points_per_s,
            outcome.backend_label(),
            outcome.cost.batches,
            outcome.cost.hits(),
            outcome.cost.misses
        );
    }
    // always on stdout (CI's warm-store jobs grep it even with
    // --quiet): a warm sim store makes this "simulated: 0"
    println!(
        "sim: simulated: {}, memoized: {}, restored: {}",
        outcome.simulated, outcome.memoized, outcome.resumed
    );
    if let Some(sh) = spec.shard {
        // a shard owns a partial result set: reports come from `merge`
        println!(
            "shard {sh}: {} point(s) ({} simulated, {} memoized, {} restored){}",
            outcome.total_points(),
            outcome.simulated,
            outcome.memoized,
            outcome.resumed,
            spec.sink
                .as_ref()
                .map(|s| format!(" -> {}", s.display()))
                .unwrap_or_else(|| " (no --sink: results discarded!)".into()),
        );
        // always on stdout (CI's shared-store job greps it even with
        // --quiet): a warm store makes this "0 backend batch(es)"
        println!(
            "cost: {} backend batch(es), {} hit(s), {} miss(es)",
            outcome.cost.batches,
            outcome.cost.hits(),
            outcome.cost.misses
        );
        println!("reconcile with: repro merge <all shard sinks> --config {cfg_path}");
        return Ok(());
    }
    let multi = outcome.explorations().len() > 1;
    for ex in outcome.explorations() {
        if ex.points().is_empty() {
            continue;
        }
        let csv = if multi {
            out_dir.join(format!("fig4_{}.csv", ex.benchmark))
        } else {
            rc.out_csv
                .clone()
                .map(PathBuf::from)
                .unwrap_or_else(|| out_dir.join(format!("{}.csv", ex.benchmark)))
        };
        ex.write_csv(&csv)?;
        println!("wrote {}", csv.display());
        if !multi {
            println!("{}", ex.scatter_area(72, 18));
            if let Some(r) = ex.performance_ratio() {
                println!("performance ratio (banking area / AMM area, geomean): {r:.3}");
            }
        }
    }
    if multi {
        report::write_file(&out_dir.join("fig5.csv"), &outcome.fig5_csv())
            .map_err(|e| Error::io("write fig5.csv", e))?;
        println!("{}", outcome.fig5_ascii());
        println!("wrote {}/fig5.csv", out_dir.display());
    }
    Ok(())
}

/// Reconcile shard sinks: with `--config` the merge is checked against
/// the plan (missing/duplicate/foreign accounting, enumeration-order
/// output); without it the records speak for themselves.
fn cmd_merge(rest: &[String]) -> Result<()> {
    let args = parse_args(
        rest,
        &["--config", "--scale", "--out-dir", "--out"],
        &["--partial", "--pool-stores", "--pool-sim-stores"],
    )?;
    if args.has("--pool-stores") && args.has("--pool-sim-stores") {
        return Err(Error::config(
            "--pool-stores and --pool-sim-stores are exclusive (pool one store kind at a time)",
        ));
    }
    if args.has("--pool-stores") {
        return cmd_pool_stores(&args);
    }
    if args.has("--pool-sim-stores") {
        return cmd_pool_sim_stores(&args);
    }
    if args.get("--out").is_some() {
        return Err(Error::config(
            "--out is a --pool-stores/--pool-sim-stores flag (sinks use --out-dir)",
        ));
    }
    if args.positional.is_empty() {
        return Err(Error::config(
            "usage: repro merge <sink.jsonl>... [--config <config.toml>]",
        ));
    }
    let sinks: Vec<&Path> = args.positional.iter().map(Path::new).collect();
    let out_dir = PathBuf::from(args.get("--out-dir").unwrap_or("results"));
    let merged = match args.get("--config") {
        Some(cfg) => {
            let mut spec = config::load(Path::new(cfg))?.campaign;
            spec.shard = None; // a merge spans all shards
            spec.scale = args.scale_or(spec.scale)?;
            campaign::merge::merge(&spec, &sinks)?
        }
        None => {
            if args.get("--scale").is_some() {
                return Err(Error::config(
                    "--scale needs --config (loose merges take the scale from the records)",
                ));
            }
            campaign::merge::merge_loose(&sinks)?
        }
    };
    eprintln!(
        "merge: {} record(s) from {} sink(s) -> {} point(s) ({} duplicate(s), {} conflict(s), {} foreign, {} torn tail(s))",
        merged.records,
        sinks.len(),
        merged.outcome.total_points(),
        merged.duplicates,
        merged.conflicts,
        merged.foreign,
        merged.torn_tails,
    );
    if !merged.missing.is_empty() {
        let (b, id) = &merged.missing[0];
        let msg = format!(
            "merge: {} planned point(s) missing from the sinks (e.g. {b}/{id}) — a shard is absent or died mid-run",
            merged.missing.len()
        );
        if args.has("--partial") {
            eprintln!("warning: {msg}; rendering the partial set (--partial)");
        } else {
            return Err(Error::msg(format!("{msg}; pass --partial to render anyway")));
        }
    }
    let outcome = &merged.outcome;
    for ex in outcome.explorations() {
        if ex.points().is_empty() {
            continue;
        }
        let csv = out_dir.join(format!("fig4_{}.csv", ex.benchmark));
        ex.write_csv(&csv)?;
        let pareto = out_dir.join(format!("fig4_{}_pareto.csv", ex.benchmark));
        report::write_file(&pareto, &report::pareto_csv(ex.points()))
            .map_err(|e| Error::io(format!("write {}", pareto.display()), e))?;
    }
    report::write_file(&out_dir.join("fig5.csv"), &outcome.fig5_csv())
        .map_err(|e| Error::io("write fig5.csv", e))?;
    println!("{}", outcome.fig5_ascii());
    println!(
        "wrote {dir}/fig5.csv, {dir}/fig4_*.csv, {dir}/fig4_*_pareto.csv",
        dir = out_dir.display()
    );
    Ok(())
}

/// `repro merge --pool-stores`: reconcile N shard-fleet cost stores
/// into one warm store. First-wins on conflicting fingerprint rows —
/// the `--out` store's own rows beat every input, earlier inputs beat
/// later ones — and the accounting is printed so a fleet operator can
/// see what the pool actually absorbed.
fn cmd_pool_stores(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get("--out").ok_or_else(|| {
        Error::config("usage: repro merge --pool-stores <store.jsonl>... --out pooled.jsonl")
    })?);
    if args.positional.is_empty() {
        return Err(Error::config("--pool-stores needs at least one input store"));
    }
    if args.get("--config").is_some() || args.get("--scale").is_some() || args.has("--partial") {
        return Err(Error::config(
            "--pool-stores takes store files only (--config/--scale/--partial are sink-merge flags)",
        ));
    }
    let inputs: Vec<&Path> = args.positional.iter().map(Path::new).collect();
    let (store, rep) = amm_dse::cost::store::pool(&inputs, &out)?;
    println!(
        "pooled {} store(s) -> {}: {} row(s) ({} added, {} already held, {} conflict(s) kept-first, {} malformed skipped)",
        rep.inputs,
        out.display(),
        store.len(),
        rep.added,
        rep.already_held,
        rep.conflicts,
        rep.malformed,
    );
    for (fp, rows) in store.per_fingerprint() {
        println!("  {fp}: {rows} row(s)");
    }
    Ok(())
}

/// `repro merge --pool-sim-stores`: the simulation-store twin of
/// `--pool-stores`. Reconciles N shard-fleet sim stores into one warm
/// store with the same first-wins contract, so a fleet's next campaign
/// simulates only points no shard has seen.
fn cmd_pool_sim_stores(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get("--out").ok_or_else(|| {
        Error::config("usage: repro merge --pool-sim-stores <store.jsonl>... --out pooled.jsonl")
    })?);
    if args.positional.is_empty() {
        return Err(Error::config("--pool-sim-stores needs at least one input store"));
    }
    if args.get("--config").is_some() || args.get("--scale").is_some() || args.has("--partial") {
        return Err(Error::config(
            "--pool-sim-stores takes store files only (--config/--scale/--partial are sink-merge flags)",
        ));
    }
    let inputs: Vec<&Path> = args.positional.iter().map(Path::new).collect();
    let (store, rep) = amm_dse::sim::store::pool(&inputs, &out)?;
    println!(
        "pooled {} sim store(s) -> {}: {} row(s) ({} added, {} already held, {} conflict(s) kept-first, {} malformed skipped)",
        rep.inputs,
        out.display(),
        store.len(),
        rep.added,
        rep.already_held,
        rep.conflicts,
        rep.malformed,
    );
    for (fp, rows) in store.per_fingerprint() {
        println!("  {fp}: {rows} row(s)");
    }
    Ok(())
}

/// `repro serve`: the DSE-as-a-service daemon. Binds, prints the
/// resolved address (stdout, so scripts can scrape an ephemeral-port
/// bind), then serves until `POST /shutdown`.
fn cmd_serve(rest: &[String]) -> Result<()> {
    let args = parse_args(
        rest,
        &["--addr", "--workers", "--data-dir", "--artifacts", "--status-history"],
        &[],
    )?;
    let mut opts = serve::ServeOptions::default();
    if let Some(a) = args.get("--addr") {
        opts.addr = a.to_string();
    }
    if let Some(w) = args.get("--workers") {
        opts.workers = w
            .parse()
            .map_err(|_| Error::config(format!("bad --workers {w:?}")))?;
    }
    if let Some(d) = args.get("--data-dir") {
        opts.data_dir = d.into();
    }
    if let Some(d) = args.get("--artifacts") {
        opts.artifacts = Some(d.into());
    }
    if let Some(s) = args.get("--status-history") {
        opts.status_history = s
            .parse()
            .map_err(|_| Error::config(format!("bad --status-history {s:?}")))?;
    }
    let server = serve::Server::bind(&opts)?;
    println!("serving on http://{} (data dir {})", server.addr(), opts.data_dir.display());
    server.run()
}

/// Operate on a persistent macro-cost store (`cost-store/v1`, see the
/// `cost` module): `stat` prints row/fingerprint accounting, `gc`
/// compacts the file (drops malformed/duplicate/conflicting lines via
/// an atomic rewrite), `export` renders the rows as CSV.
fn cmd_cost_store(rest: &[String]) -> Result<()> {
    let args = parse_args(rest, &["--out"], &[])?;
    let usage = || {
        Error::config("usage: repro cost-store <stat|gc|export> <store.jsonl> [--out f.csv]")
    };
    let verb = args.positional.first().cloned().ok_or_else(usage)?;
    let path = args.positional.get(1).cloned().ok_or_else(usage)?;
    let path = Path::new(&path);
    match verb.as_str() {
        "stat" => {
            let store = CostStore::open(path)?;
            let rep = store.report();
            println!("cost store {}", path.display());
            println!("  rows        {}", store.len());
            println!(
                "  skipped     {} malformed, {} duplicate(s), {} conflict(s){}",
                rep.malformed,
                rep.duplicates,
                rep.conflicts,
                if rep.torn_tail { ", torn tail" } else { "" }
            );
            for (fp, n) in store.per_fingerprint() {
                println!("  {n:>6} x {fp}");
            }
            if rep.malformed + rep.duplicates + rep.conflicts > 0 || rep.torn_tail {
                println!("  (run `repro cost-store gc {}` to compact)", path.display());
            }
        }
        "gc" => {
            let mut store = CostStore::open(path)?;
            let before = store.len();
            let dropped = store.gc()?;
            println!(
                "cost store {}: kept {} row(s), dropped {} line(s)",
                path.display(),
                before,
                dropped
            );
        }
        "export" => {
            let csv = CostStore::open(path)?.export_csv();
            match args.get("--out") {
                Some(out) => {
                    report::write_file(Path::new(out), &csv)
                        .map_err(|e| Error::io(format!("write {out}"), e))?;
                    println!("wrote {out}");
                }
                None => print!("{csv}"),
            }
        }
        other => {
            return Err(Error::config(format!(
                "unknown cost-store verb {other:?} (stat|gc|export)"
            )))
        }
    }
    Ok(())
}

/// Operate on a persistent simulation store (`sim-store/v1`, see the
/// `sim` module): the same stat/gc/export verbs as `cost-store`, over
/// the store that lets warm campaigns skip the cycle-accurate kernel.
fn cmd_sim_store(rest: &[String]) -> Result<()> {
    let args = parse_args(rest, &["--out"], &[])?;
    let usage = || {
        Error::config("usage: repro sim-store <stat|gc|export> <store.jsonl> [--out f.csv]")
    };
    let verb = args.positional.first().cloned().ok_or_else(usage)?;
    let path = args.positional.get(1).cloned().ok_or_else(usage)?;
    let path = Path::new(&path);
    match verb.as_str() {
        "stat" => {
            let store = SimStore::open(path)?;
            let rep = store.report();
            println!("sim store {}", path.display());
            println!("  rows        {}", store.len());
            println!(
                "  skipped     {} malformed, {} duplicate(s), {} conflict(s){}",
                rep.malformed,
                rep.duplicates,
                rep.conflicts,
                if rep.torn_tail { ", torn tail" } else { "" }
            );
            for (fp, n) in store.per_fingerprint() {
                println!("  {n:>6} x {fp}");
            }
            if rep.malformed + rep.duplicates + rep.conflicts > 0 || rep.torn_tail {
                println!("  (run `repro sim-store gc {}` to compact)", path.display());
            }
        }
        "gc" => {
            let mut store = SimStore::open(path)?;
            let before = store.len();
            let dropped = store.gc()?;
            println!(
                "sim store {}: kept {} row(s), dropped {} line(s)",
                path.display(),
                before,
                dropped
            );
        }
        "export" => {
            let csv = SimStore::open(path)?.export_csv();
            match args.get("--out") {
                Some(out) => {
                    report::write_file(Path::new(out), &csv)
                        .map_err(|e| Error::io(format!("write {out}"), e))?;
                    println!("wrote {out}");
                }
                None => print!("{csv}"),
            }
        }
        other => {
            return Err(Error::config(format!(
                "unknown sim-store verb {other:?} (stat|gc|export)"
            )))
        }
    }
    Ok(())
}

fn cmd_sweep(rest: &[String]) -> Result<()> {
    let args = parse_args(rest, &["--config", "--out"], &[])?;
    let cfg_path = args
        .get("--config")
        .map(str::to_string)
        .ok_or_else(|| Error::config("usage: repro sweep --config <file.toml>"))?;
    let rc = config::load(Path::new(&cfg_path))?;
    if rc.campaign.plan.len() > 1 {
        return Err(Error::config(format!(
            "{cfg_path} describes a {}-benchmark campaign; `sweep` runs exactly one — use `repro run {cfg_path}`",
            rc.campaign.plan.len()
        )));
    }
    let out_csv = args
        .get("--out")
        .map(str::to_string)
        .or(rc.out_csv.clone())
        .unwrap_or_else(|| format!("results/{}.csv", rc.benchmark));
    eprintln!(
        "sweep {} ({:?}): {} design points",
        rc.benchmark,
        rc.scale,
        rc.sweep.points().len(),
    );
    let t0 = std::time::Instant::now();
    let ex = rc.explorer().run()?;
    eprintln!(
        "evaluated {} points in {:.2?} (cost backend {})",
        ex.points().len(),
        t0.elapsed(),
        ex.backend_label()
    );
    ex.write_csv(&out_csv)?;
    println!("{}", ex.scatter_area(72, 20));
    if let Some(r) = ex.performance_ratio() {
        println!("performance ratio (banking area / AMM area, geomean): {r:.3}");
    }
    println!("wrote {out_csv}");
    Ok(())
}

fn cmd_figure(rest: &[String]) -> Result<()> {
    let args = parse_args(rest, &["--bench", "--scale", "--out-dir", "--sink"], &[])?;
    let which = args.positional.first().map(String::as_str).unwrap_or("");
    let scale = args.scale_or(Scale::Paper)?;
    let out_dir = PathBuf::from(args.get("--out-dir").unwrap_or("results"));
    match which {
        "fig4" => {
            let bench = args.get("--bench").unwrap_or("all").to_string();
            let benches: Vec<&str> = if bench == "all" {
                suite::DSE_BENCHMARKS.to_vec()
            } else {
                vec![suite::ALL_BENCHMARKS
                    .iter()
                    .find(|&&b| b == bench)
                    .copied()
                    .ok_or(Error::UnknownBenchmark { name: bench })?]
            };
            // one campaign for the whole figure: all benchmarks' sweep
            // points form one work stream, scored by one cost batch
            let mut campaign =
                Campaign::new().benchmarks(benches).scale(scale).sweep(Sweep::default());
            if let Some(sink) = args.get("--sink") {
                campaign = campaign.sink(sink);
            }
            let t0 = std::time::Instant::now();
            let outcome = campaign.run()?;
            eprintln!(
                "fig4 campaign: {} benchmark(s), {} points ({} simulated, {} memoized, {} restored) in {:.2?} (cost backend {}, {} cost batch(es), {} hit(s))",
                outcome.explorations().len(),
                outcome.total_points(),
                outcome.simulated,
                outcome.memoized,
                outcome.resumed,
                t0.elapsed(),
                outcome.backend_label(),
                outcome.cost.batches,
                outcome.cost.hits()
            );
            for ex in outcome.explorations() {
                ex.write_csv(out_dir.join(format!("fig4_{}.csv", ex.benchmark)))?;
                println!("{}", ex.scatter_area(72, 18));
                println!("{}", ex.scatter_power(72, 18));
            }
            println!("wrote {}/fig4_*.csv", out_dir.display());
        }
        "fig5" => {
            // one campaign over the whole suite: the DSE set is swept,
            // the rest contribute locality only
            let mut campaign = Campaign::new().scale(scale).sweep(Sweep::default());
            for name in suite::ALL_BENCHMARKS {
                campaign = if suite::DSE_BENCHMARKS.contains(&name) {
                    campaign.benchmark(name)
                } else {
                    campaign.locality_only(name)
                };
            }
            if let Some(sink) = args.get("--sink") {
                campaign = campaign.sink(sink);
            }
            let t0 = std::time::Instant::now();
            let outcome = campaign.run()?;
            eprintln!(
                "fig5 campaign: {} points ({} simulated, {} memoized, {} restored) in {:.2?} (cost backend {}, {} cost batch(es), {} hit(s))",
                outcome.total_points(),
                outcome.simulated,
                outcome.memoized,
                outcome.resumed,
                t0.elapsed(),
                outcome.backend_label(),
                outcome.cost.batches,
                outcome.cost.hits()
            );
            let summaries = outcome.summaries();
            report::write_file(&out_dir.join("fig5.csv"), &outcome.fig5_csv())
                .map_err(|e| Error::io("write fig5.csv", e))?;
            println!("{}", outcome.fig5_ascii());
            // the paper's claim: ratio correlates negatively with locality
            let with_ratio: Vec<&dse::BenchSummary> =
                summaries.iter().filter(|s| s.perf_ratio.is_some()).collect();
            if with_ratio.len() >= 3 {
                let xs: Vec<f64> = with_ratio.iter().map(|s| s.locality).collect();
                let ys: Vec<f64> = with_ratio.iter().map(|s| s.perf_ratio.unwrap()).collect();
                println!(
                    "locality/ratio correlation: pearson {:.3}, spearman {:.3}",
                    amm_dse::util::stats::pearson(&xs, &ys),
                    amm_dse::util::stats::spearman(&xs, &ys)
                );
                for s in &with_ratio {
                    let wins = s.perf_ratio.unwrap() > 1.0;
                    let low = s.locality < 0.3;
                    println!(
                        "  {:<10} L={:.3} ratio={:.3}  low-locality={} amm-wins={}  {}",
                        s.name,
                        s.locality,
                        s.perf_ratio.unwrap(),
                        low,
                        wins,
                        if low == wins { "consistent with paper" } else { "INCONSISTENT" }
                    );
                }
            }
            println!("wrote {}/fig5.csv", out_dir.display());
        }
        other => return Err(Error::config(format!("unknown figure {other:?} (fig4|fig5)"))),
    }
    Ok(())
}

fn cmd_synth_table() -> Result<()> {
    // §III-A: synthesized AMM designs across depth × ports — resolved
    // through the registry so new models can be added to the table by id.
    println!(
        "{:<12} {:>7} {:>6} {:>12} {:>10} {:>10} {:>10} {:>8}",
        "design", "depth", "width", "area_um2", "e_rd_pJ", "e_wr_pJ", "leak_uW", "t_ns"
    );
    for depth in [256u32, 1024, 4096, 16384] {
        for id in [
            "banked1", "lvt2r1w", "lvt2r2w", "lvt4r2w", "xor2r1w", "xor2r2w", "xor4r2w",
            "cmp2r2w", "cmp4r2w",
        ] {
            let model = mem::parse_model(id).ok_or(Error::UnknownModel { id: id.into() })?;
            let d = model.build(depth, 32);
            println!(
                "{:<12} {:>7} {:>6} {:>12.1} {:>10.3} {:>10.3} {:>10.2} {:>8.3}",
                d.id,
                depth,
                32,
                d.area_um2(),
                d.e_read_pj(),
                d.e_write_pj(),
                d.leak_uw(),
                d.t_access_ns()
            );
        }
        println!();
    }
    Ok(())
}

/// CI perf smoke (no `cargo bench` needed), three sections:
///
/// 1. **sweep engine** — time the quick sweep on gemm/fft through the
///    per-point compat path (fresh `CompiledTrace` + `SimArena` per
///    design point) and through the grouped lane-batched engine; write
///    points/sec + wall ms to `BENCH_sweep.json`. Single-threaded on
///    both sides so the ratio measures the engine, not the pool.
/// 2. **batch lanes** — the full default model set at one knob
///    combination (wide compatible groups, the shape the v2 kernel is
///    built for) through the grouped dispatcher with `lanes = 1`
///    (scalar engine per point) and `lanes = auto`; write lanes used,
///    points/sec and the batch-vs-scalar-engine speedup to
///    `BENCH_batch.json`. This isolates the lane kernel's contribution
///    from the grouping wins section 1 already had.
/// 3. **campaign** — run the whole 13-benchmark suite × quick sweep as
///    sequential per-benchmark `Explorer` runs and as one `Campaign`
///    (shared coordinator on both sides), and write suite points/sec +
///    campaign-vs-sequential speedup to `BENCH_campaign.json`.
/// 4. **simstore** — seed a simulation store once (untimed), then time
///    the same two-benchmark campaign cold (`sim_memo` off: every point
///    through the scheduler) against warm (fresh coordinator per
///    iteration, so every hit is an honest store hit including the
///    JSONL parse). Asserts the warm side simulates zero points and
///    writes warm-vs-cold speedup to `BENCH_simstore.json`
///    (`bench_simstore/v1`, gated by `--min-warm-speedup`).
///
/// `--repeats N` runs every timed side N times and reports the median
/// of the per-run medians, so one noisy run cannot flip a CI gate; each
/// JSON also records a host fingerprint (CPU model, logical cores,
/// thread count) so trajectories are comparable across runners.
fn cmd_perf_smoke(rest: &[String]) -> Result<()> {
    use amm_dse::util::benchkit::{self, Bench};
    let args = parse_args(
        rest,
        &[
            "--out",
            "--campaign-out",
            "--batch-out",
            "--simstore-out",
            "--iters",
            "--repeats",
            "--min-speedup",
            "--min-campaign-speedup",
            "--min-batch-speedup",
            "--min-warm-speedup",
        ],
        &[],
    )?;
    let out_path = args.get("--out").unwrap_or("BENCH_sweep.json").to_string();
    let campaign_out = args.get("--campaign-out").unwrap_or("BENCH_campaign.json").to_string();
    let batch_out = args.get("--batch-out").unwrap_or("BENCH_batch.json").to_string();
    let simstore_out = args.get("--simstore-out").unwrap_or("BENCH_simstore.json").to_string();
    let iters = args.u32_or("--iters", 7)? as usize;
    // De-flake knob: each section's timed pair runs `repeats` times and
    // the reported statistic is the median over per-run medians.
    let repeats = (args.u32_or("--repeats", 1)? as usize).max(1);
    let (host_cpu, host_cores) = benchkit::host_fingerprint();
    let host_json = format!(
        "{{\"cpu\": \"{}\", \"logical_cores\": {}, \"threads\": {}}}",
        amm_dse::util::jsonl::escape(&host_cpu),
        host_cores,
        amm_dse::util::pool::default_threads()
    );
    // Regression gate: fail if any benchmark's engine speedup drops
    // below this (0 = report only). With the lane-batched kernel on the
    // engine side the observed floor is well above the old 0.8x noise
    // gate, so CI now holds 1.2x (the >= 2x points/sec target stays
    // visible in the JSON trajectory).
    let min_speedup = args.f64_or("--min-speedup", 0.0)?;
    // Same shape for the campaign section (0 = report only): campaign
    // wall time includes workload/locality planning, so the gate exists
    // for local use while CI keeps it advisory.
    let min_campaign_speedup = args.f64_or("--min-campaign-speedup", 0.0)?;
    // Gate for the batch-vs-scalar-engine section (0 = report only):
    // both sides share grouping/arena wins, so this is a pure kernel
    // ratio — with the v2 event-wheel kernel on wide default-model
    // groups, CI ratchets this to 1.5x.
    let min_batch_speedup = args.f64_or("--min-batch-speedup", 0.0)?;
    // Gate for the warm-vs-cold sim-store section (0 = report only):
    // the warm side skips simulation entirely, so the ratio tracks
    // store probe + parse overhead against real scheduler work.
    let min_warm_speedup = args.f64_or("--min-warm-speedup", 0.0)?;
    let sweep = Sweep::quick();
    let mut rows = Vec::new();
    let mut worst = f64::INFINITY;
    for name in ["gemm", "fft"] {
        let wl = suite::generate_cached(name, Scale::Tiny);
        let points = sweep.points();
        let n_points = points.len() as u64;
        let mut bench = Bench::new(iters, 2);
        for _ in 0..repeats {
            bench.run(&format!("sweep/{name}/per-point"), Some(n_points), || {
                points
                    .iter()
                    .map(|p| dse::evaluate_model(&wl.trace, &*p.model, &p.knobs).out.cycles)
                    .fold(0u64, u64::wrapping_add)
            });
            // Engine side runs with auto lanes — this row carries the
            // lane-batched kernel, so its points/sec step vs the
            // per-point baseline is the headline the CI gate ratchets.
            bench.run(&format!("sweep/{name}/engine"), Some(n_points), || {
                dse::run_points(&wl.trace, &points, 1, 0)
                    .iter()
                    .map(|p| p.out.cycles)
                    .fold(0u64, u64::wrapping_add)
            });
        }
        let base_ns =
            benchkit::median_median_ns(bench.results(), &format!("sweep/{name}/per-point"));
        let eng_ns = benchkit::median_median_ns(bench.results(), &format!("sweep/{name}/engine"));
        let speedup = base_ns / eng_ns;
        let pps = |ns: f64| n_points as f64 / (ns / 1e9);
        rows.push(format!(
            concat!(
                "    {{\"benchmark\": \"{}\", \"points\": {}, ",
                "\"baseline_wall_ms\": {:.4}, \"engine_wall_ms\": {:.4}, ",
                "\"baseline_points_per_s\": {:.1}, \"engine_points_per_s\": {:.1}, ",
                "\"speedup\": {:.3}}}"
            ),
            name,
            n_points,
            base_ns / 1e6,
            eng_ns / 1e6,
            pps(base_ns),
            pps(eng_ns),
            speedup,
        ));
        println!("perf-smoke {name}: engine {speedup:.2}x points/sec vs per-point baseline");
        worst = worst.min(speedup);
    }
    // Streaming-generation throughput: one Paper-equivalent synthetic
    // trace (2^16 accesses = 131072 nodes), generated fresh each repeat
    // (the `n` dial puts it past the cache-admission ceiling, so this
    // times the generator, not the workload cache). Advisory — the
    // number rides BENCH_sweep.json so generator regressions are
    // visible in the artifact trail.
    let synth_name = "synth:stride=rand,rw=0.7,reuse=256,seed=1,n=65536";
    let mut synth_wall_ns = f64::INFINITY;
    let mut synth_nodes = 0u64;
    for _ in 0..repeats.max(1) {
        let t0 = std::time::Instant::now();
        let wl = suite::generate(synth_name, Scale::Tiny);
        let ns = t0.elapsed().as_nanos() as f64;
        synth_nodes = wl.trace.len() as u64;
        synth_wall_ns = synth_wall_ns.min(ns);
    }
    let synth_nodes_per_s = synth_nodes as f64 / (synth_wall_ns / 1e9);
    println!(
        "perf-smoke synth: generated {synth_nodes} nodes in {:.2} ms ({:.0} nodes/s)",
        synth_wall_ns / 1e6,
        synth_nodes_per_s
    );
    let json = format!(
        concat!(
            "{{\n  \"schema\": \"bench_sweep/v1\",\n  \"sweep\": \"quick\",\n",
            "  \"scale\": \"tiny\",\n  \"threads\": 1,\n  \"iters\": {},\n",
            "  \"repeats\": {},\n  \"host\": {},\n",
            "  \"synth_generation\": {{\"name\": \"{}\", \"nodes\": {}, ",
            "\"wall_ms\": {:.4}, \"nodes_per_s\": {:.1}}},\n",
            "  \"results\": [\n{}\n  ]\n}}\n"
        ),
        iters,
        repeats,
        host_json,
        synth_name,
        synth_nodes,
        synth_wall_ns / 1e6,
        synth_nodes_per_s,
        rows.join(",\n")
    );
    report::write_file(Path::new(&out_path), &json)
        .map_err(|e| Error::io(format!("write {out_path}"), e))?;
    println!("wrote {out_path}");

    // --- batch lanes: lane kernel vs scalar engine, same dispatcher ---
    // Both sides go through the grouped dispatcher (shared trace
    // compile, shared arenas), so the only variable is lanes=1 (scalar
    // oracle per point) vs lanes=auto (lane-batched kernel). The ratio
    // is therefore the kernel's own contribution, independent of the
    // grouping wins the sweep section measures. The sweep here is the
    // full default model set at one knob combination — wide compatible
    // groups, the shape the v2 event-wheel kernel is built for — so the
    // ratio reflects the kernel at its real campaign width rather than
    // the 4-wide groups of `Sweep::quick()`.
    let bsweep = {
        let mut s = Sweep::default();
        s.unrolls = vec![1, 4];
        s.word_bytes = vec![8];
        s.alus = vec![4];
        s
    };
    let bmodels = bsweep.models().len();
    let mut brows = Vec::new();
    let mut bworst = f64::INFINITY;
    for name in ["gemm", "fft"] {
        let wl = suite::generate_cached(name, Scale::Tiny);
        let points = bsweep.points();
        let n_points = points.len() as u64;
        let lanes = dse::resolve_lanes(0, bmodels, wl.trace.len());
        let mut bench = Bench::new(iters, 2);
        for _ in 0..repeats {
            bench.run(&format!("batch/{name}/scalar"), Some(n_points), || {
                dse::run_points(&wl.trace, &points, 1, 1)
                    .iter()
                    .map(|p| p.out.cycles)
                    .fold(0u64, u64::wrapping_add)
            });
            bench.run(&format!("batch/{name}/lanes"), Some(n_points), || {
                dse::run_points(&wl.trace, &points, 1, 0)
                    .iter()
                    .map(|p| p.out.cycles)
                    .fold(0u64, u64::wrapping_add)
            });
        }
        let scalar_ns =
            benchkit::median_median_ns(bench.results(), &format!("batch/{name}/scalar"));
        let batch_ns = benchkit::median_median_ns(bench.results(), &format!("batch/{name}/lanes"));
        let speedup = scalar_ns / batch_ns;
        let pps = |ns: f64| n_points as f64 / (ns / 1e9);
        brows.push(format!(
            concat!(
                "    {{\"benchmark\": \"{}\", \"points\": {}, \"lanes\": {}, ",
                "\"scalar_wall_ms\": {:.4}, \"batch_wall_ms\": {:.4}, ",
                "\"scalar_points_per_s\": {:.1}, \"batch_points_per_s\": {:.1}, ",
                "\"speedup\": {:.3}}}"
            ),
            name,
            n_points,
            lanes,
            scalar_ns / 1e6,
            batch_ns / 1e6,
            pps(scalar_ns),
            pps(batch_ns),
            speedup,
        ));
        println!(
            "perf-smoke {name}: batch kernel {speedup:.2}x points/sec vs scalar engine ({lanes} lanes)"
        );
        bworst = bworst.min(speedup);
    }
    let bjson = format!(
        concat!(
            "{{\n  \"schema\": \"bench_batch/v2\",\n  \"sweep\": \"default-models\",\n",
            "  \"scale\": \"tiny\",\n  \"threads\": 1,\n  \"models\": {},\n",
            "  \"iters\": {},\n  \"repeats\": {},\n  \"host\": {},\n",
            "  \"results\": [\n{}\n  ]\n}}\n"
        ),
        bmodels,
        iters,
        repeats,
        host_json,
        brows.join(",\n")
    );
    report::write_file(Path::new(&batch_out), &bjson)
        .map_err(|e| Error::io(format!("write {batch_out}"), e))?;
    println!("wrote {batch_out}");

    // --- campaign throughput: suite × quick sweep, one work stream ----
    // Sequential baseline = per-benchmark Explorer runs; campaign = one
    // flat unit stream. Both share one coordinator (and its cost
    // service) and the same thread count, so the ratio measures barrier
    // removal + global cost batching, not pool sizing. Workloads are
    // memoized in `suite`, so generation costs neither side after the
    // warmup iteration.
    let threads = amm_dse::util::pool::default_threads();
    let coord = amm_dse::coordinator::Coordinator::new();
    let n_benchmarks = suite::ALL_BENCHMARKS.len();
    let suite_points = (sweep.points().len() * n_benchmarks) as u64;
    let citers = iters.clamp(1, 5);
    let mut cbench = Bench::new(citers, 1);
    for _ in 0..repeats {
        cbench.run("campaign/suite/sequential", Some(suite_points), || {
            let mut cycles = 0u64;
            for name in suite::ALL_BENCHMARKS {
                let ex = Explorer::new()
                    .workload(name, Scale::Tiny)
                    .sweep(sweep.clone())
                    .threads(threads)
                    .run_with(&coord)
                    .expect("sequential explorer run");
                cycles =
                    ex.points().iter().map(|p| p.out.cycles).fold(cycles, u64::wrapping_add);
            }
            cycles
        });
        cbench.run("campaign/suite/campaign", Some(suite_points), || {
            let outcome = Campaign::new()
                .benchmarks(suite::ALL_BENCHMARKS)
                .scale(Scale::Tiny)
                .sweep(sweep.clone())
                .threads(threads)
                .run_with(&coord)
                .expect("campaign run");
            outcome
                .explorations()
                .iter()
                .flat_map(|e| e.points().iter().map(|p| p.out.cycles))
                .fold(0u64, u64::wrapping_add)
        });
    }
    let seq_ns = benchkit::median_median_ns(cbench.results(), "campaign/suite/sequential");
    let camp_ns = benchkit::median_median_ns(cbench.results(), "campaign/suite/campaign");
    let campaign_speedup = seq_ns / camp_ns;
    let cpps = |ns: f64| suite_points as f64 / (ns / 1e9);
    println!(
        "perf-smoke campaign: {campaign_speedup:.2}x suite points/sec vs sequential explorer runs"
    );
    let cjson = format!(
        concat!(
            "{{\n  \"schema\": \"bench_campaign/v1\",\n  \"sweep\": \"quick\",\n",
            "  \"scale\": \"tiny\",\n  \"benchmarks\": {},\n  \"threads\": {},\n",
            "  \"iters\": {},\n  \"repeats\": {},\n  \"host\": {},\n  \"suite_points\": {},\n",
            "  \"sequential_wall_ms\": {:.4},\n  \"campaign_wall_ms\": {:.4},\n",
            "  \"sequential_points_per_s\": {:.1},\n  \"campaign_points_per_s\": {:.1},\n",
            "  \"speedup\": {:.3}\n}}\n"
        ),
        n_benchmarks,
        threads,
        citers,
        repeats,
        host_json,
        suite_points,
        seq_ns / 1e6,
        camp_ns / 1e6,
        cpps(seq_ns),
        cpps(camp_ns),
        campaign_speedup,
    );
    report::write_file(Path::new(&campaign_out), &cjson)
        .map_err(|e| Error::io(format!("write {campaign_out}"), e))?;
    println!("wrote {campaign_out}");

    // --- sim store: warm campaign vs cold re-simulation ---------------
    // Same two-benchmark spec on both sides, no sink. The store is
    // seeded once, untimed; the cold side disables the sim stack
    // (`sim_memo: false`) so every point goes through the scheduler,
    // and the warm side opens a fresh coordinator per iteration so the
    // in-process memo tier starts empty — every hit is an honest store
    // hit, JSONL parse included. The warm side must simulate zero
    // points: that is the store's contract, so it is asserted here,
    // not just reported.
    let sdir = std::env::temp_dir().join("amm_dse_perf_simstore");
    let _ = std::fs::remove_dir_all(&sdir);
    std::fs::create_dir_all(&sdir)
        .map_err(|e| Error::io(format!("create {}", sdir.display()), e))?;
    let store_path = sdir.join("sim.jsonl");
    let sspec = Campaign::new()
        .benchmark("gemm")
        .benchmark("fft")
        .scale(Scale::Tiny)
        .sweep(sweep.clone())
        .threads(1)
        .sim_store(&store_path)
        .into_spec();
    let sim_points = (sweep.points().len() * 2) as u64;
    let cold_opts = campaign::ExecOptions { sim_memo: false, ..Default::default() };
    let warm_opts = campaign::ExecOptions::default();
    let seed_coord = amm_dse::coordinator::Coordinator::new();
    let seeded = campaign::run_with(&sspec, &seed_coord, &warm_opts)?;
    drop(seed_coord);
    if seeded.simulated as u64 != sim_points {
        return Err(Error::msg(format!(
            "perf-smoke: seed campaign simulated {} of {sim_points} point(s) against an empty store",
            seeded.simulated
        )));
    }
    let siters = iters.clamp(1, 5);
    let mut sbench = Bench::new(siters, 1);
    let mut warm_simulated = usize::MAX;
    let mut warm_memoized = 0usize;
    for _ in 0..repeats {
        sbench.run("simstore/pair/cold", Some(sim_points), || {
            let coord = amm_dse::coordinator::Coordinator::new();
            let o = campaign::run_with(&sspec, &coord, &cold_opts).expect("cold campaign");
            o.total_points() as u64
        });
        sbench.run("simstore/pair/warm", Some(sim_points), || {
            let coord = amm_dse::coordinator::Coordinator::new();
            let o = campaign::run_with(&sspec, &coord, &warm_opts).expect("warm campaign");
            warm_simulated = o.simulated;
            warm_memoized = o.memoized;
            o.total_points() as u64
        });
    }
    let cold_ns = benchkit::median_median_ns(sbench.results(), "simstore/pair/cold");
    let warm_ns = benchkit::median_median_ns(sbench.results(), "simstore/pair/warm");
    let warm_speedup = cold_ns / warm_ns;
    let spps = |ns: f64| sim_points as f64 / (ns / 1e9);
    println!(
        "perf-smoke simstore: warm campaign {warm_speedup:.2}x vs cold ({warm_memoized} memoized, {warm_simulated} simulated)"
    );
    if warm_simulated != 0 {
        return Err(Error::msg(format!(
            "perf-smoke: warm campaign simulated {warm_simulated} point(s); the sim store must satisfy all of them"
        )));
    }
    let sjson = format!(
        concat!(
            "{{\n  \"schema\": \"bench_simstore/v1\",\n  \"sweep\": \"quick\",\n",
            "  \"scale\": \"tiny\",\n  \"benchmarks\": 2,\n  \"threads\": 1,\n",
            "  \"iters\": {},\n  \"repeats\": {},\n  \"host\": {},\n  \"points\": {},\n",
            "  \"warm_memoized\": {},\n  \"warm_simulated\": {},\n",
            "  \"cold_wall_ms\": {:.4},\n  \"warm_wall_ms\": {:.4},\n",
            "  \"cold_points_per_s\": {:.1},\n  \"warm_points_per_s\": {:.1},\n",
            "  \"speedup\": {:.3}\n}}\n"
        ),
        siters,
        repeats,
        host_json,
        sim_points,
        warm_memoized,
        warm_simulated,
        cold_ns / 1e6,
        warm_ns / 1e6,
        spps(cold_ns),
        spps(warm_ns),
        warm_speedup,
    );
    report::write_file(Path::new(&simstore_out), &sjson)
        .map_err(|e| Error::io(format!("write {simstore_out}"), e))?;
    println!("wrote {simstore_out}");
    let _ = std::fs::remove_dir_all(&sdir);

    if min_speedup > 0.0 && worst < min_speedup {
        return Err(Error::msg(format!(
            "perf-smoke: worst engine speedup {worst:.3}x is below the required {min_speedup}x"
        )));
    }
    if min_batch_speedup > 0.0 && bworst < min_batch_speedup {
        return Err(Error::msg(format!(
            "perf-smoke: worst batch speedup {bworst:.3}x is below the required {min_batch_speedup}x"
        )));
    }
    if min_campaign_speedup > 0.0 && campaign_speedup < min_campaign_speedup {
        return Err(Error::msg(format!(
            "perf-smoke: campaign speedup {campaign_speedup:.3}x is below the required {min_campaign_speedup}x"
        )));
    }
    if min_warm_speedup > 0.0 && warm_speedup < min_warm_speedup {
        return Err(Error::msg(format!(
            "perf-smoke: warm sim-store speedup {warm_speedup:.3}x is below the required {min_warm_speedup}x"
        )));
    }
    Ok(())
}

fn cmd_port_scaling() -> Result<()> {
    // Fig 2: the HB-NTX-RdWr flow — how banks/capacity/logic scale as
    // ports are added.
    println!(
        "{:<10} {:>6} {:>8} {:>10} {:>12} {:>12} {:>8}",
        "config", "banks", "macros", "cap_factor", "sram_um2", "logic_um2", "t_ns"
    );
    let base = mem::MemKind::Banked { banks: 1 }.build(4096, 32);
    for (r, w) in [(1u32, 1u32), (2, 1), (4, 1), (2, 2), (4, 2), (4, 4), (8, 4)] {
        let kind = mem::MemKind::XorAmm { read_ports: r, write_ports: w };
        let d = kind.build(4096, 32);
        println!(
            "{:<10} {:>6} {:>8} {:>10.2} {:>12.1} {:>12.1} {:>8.3}",
            format!("{r}R{w}W"),
            d.macros,
            d.macros,
            d.sram.area_um2 / base.sram.area_um2,
            d.sram.area_um2,
            d.logic.area_um2,
            d.t_access_ns()
        );
    }
    Ok(())
}
