//! Design-space exploration engine (paper §IV).
//!
//! Enumerates the sweep (unrolling × word size × memory organization ×
//! port configuration), evaluates every point with the scheduler, and
//! post-processes into the paper's artifacts:
//!
//! * Pareto frontiers over (cycles, area) and (cycles, power) — Fig 4;
//! * the geometric-mean **performance ratio** of banking-vs-AMM area at
//!   matched execution times — Fig 5 / §IV-C;
//! * the locality-vs-ratio correlation behind the paper's
//!   "AMMs win below L_spatial ≈ 0.3" claim.

use crate::mem::{self, MemDesign, MemKind, MemModel};
use crate::sched::{self, BatchArena, CompiledTrace, DesignConfig, Knobs, SimArena, SimOutput};
use crate::trace::Trace;
use crate::util::{pool, stats};
use std::sync::Arc;

/// One evaluated design point.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DesignPoint {
    /// Sweep configuration id (e.g. `xor2r2w/u8/w8/a8`).
    pub id: String,
    /// Memory kind id.
    pub mem_id: String,
    /// True if an algorithmic multi-port design (blue in Fig 4).
    pub is_amm: bool,
    /// Unroll factor.
    pub unroll: u32,
    /// Word bytes.
    pub word_bytes: u32,
    /// ALU slots.
    pub alus: u32,
    /// Scheduling + cost result.
    pub out: SimOutput,
}

impl DesignPoint {
    /// Execution time in ns.
    pub fn time_ns(&self) -> f64 {
        self.out.time_ns
    }
    /// Area in µm².
    pub fn area(&self) -> f64 {
        self.out.area_um2 as f64
    }
    /// Power in mW.
    pub fn power(&self) -> f64 {
        self.out.power_mw as f64
    }
    /// Energy-delay product, pJ·ns — the paper's §I "EDP maximization"
    /// objective (total energy including leakage, times execution time).
    pub fn edp(&self) -> f64 {
        let leak_energy_pj = self.out.power_mw as f64 * self.out.time_ns; // mW·ns = pJ (incl. dynamic)
        leak_energy_pj * self.out.time_ns
    }
}

/// The sweep definition (defaults reproduce Fig 4's axes).
///
/// `PartialEq` covers every axis: two sweeps compare equal iff they
/// enumerate the identical point stream, which is what the
/// [`crate::spec::CampaignSpec`] TOML round-trip golden relies on.
#[derive(Clone, Debug, PartialEq)]
pub struct Sweep {
    /// Unroll factors.
    pub unrolls: Vec<u32>,
    /// Word sizes in bytes.
    pub word_bytes: Vec<u32>,
    /// ALU slot counts.
    pub alus: Vec<u32>,
    /// Banked partition counts (the baseline red points).
    pub bank_counts: Vec<u32>,
    /// Also sweep dual-port (1R1W-macro) banked designs. Off by default:
    /// the paper's red baseline is single-port array partitioning.
    pub include_dual_port: bool,
    /// Also sweep block (contiguous-range) partitionings (§IV-A's
    /// cyclic-vs-block axis). Off by default.
    pub include_block: bool,
    /// Also sweep the flat LaForest XOR baseline (ablation comparator).
    pub include_flat_xor: bool,
    /// AMM (read, write) port configurations (the blue points).
    pub amm_ports: Vec<(u32, u32)>,
    /// Include multipumping designs.
    pub include_multipump: bool,
    /// Include LVT table-based AMMs (as well as XOR).
    pub include_lvt: bool,
    /// Additional memory-model ids resolved through the registry
    /// ([`crate::mem::parse_model`]) — the hook that sweeps organizations
    /// the built-in axes don't know about (registry extensions included).
    /// Unknown ids are skipped here; [`crate::Explorer`] validates them
    /// up front.
    pub extra_models: Vec<String>,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// Simulation lanes per batched engine call (0 = auto-calibrated
    /// per group by [`auto_lanes`], 1 = force the scalar engine,
    /// explicit values clamped to [`MAX_LANES`]). Compatible points —
    /// same word size, unroll and ALU count, memory designs varying —
    /// are scored together through [`CompiledTrace::simulate_batch`]
    /// in groups of up to this many lanes. Purely a scheduling knob:
    /// results are bit-identical for every value.
    pub lanes: usize,
}

impl Default for Sweep {
    fn default() -> Self {
        Sweep {
            unrolls: vec![1, 2, 4, 8, 16],
            word_bytes: vec![1, 2, 4, 8],
            alus: vec![2, 4, 8, 16],
            bank_counts: vec![1, 2, 4, 8, 16, 32],
            include_dual_port: false,
            include_block: false,
            include_flat_xor: false,
            amm_ports: vec![(2, 1), (2, 2), (4, 2), (4, 4), (8, 4)],
            include_multipump: true,
            include_lvt: true,
            extra_models: Vec::new(),
            threads: 0,
            lanes: 0,
        }
    }
}

/// One enumerated sweep point: a memory model plus the non-memory knobs.
///
/// The model is `Arc`-shared across every knob combination it appears
/// in, so enumerating a Cartesian sweep costs O(models) allocations,
/// not O(points).
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// The memory organization (trait object — built-in or registered),
    /// shared across all knob variants of this model.
    pub model: Arc<dyn MemModel>,
    /// Unroll / word size / ALU knobs.
    pub knobs: Knobs,
}

impl Sweep {
    /// Quick sweep for unit tests.
    pub fn quick() -> Self {
        Sweep {
            unrolls: vec![1, 4],
            word_bytes: vec![8],
            alus: vec![4],
            bank_counts: vec![1, 4],
            amm_ports: vec![(2, 1), (2, 2)],
            include_multipump: false,
            include_lvt: false,
            ..Sweep::default()
        }
    }

    /// The memory organizations of this sweep, as trait objects.
    pub fn models(&self) -> Vec<Box<dyn MemModel>> {
        let mut kinds: Vec<MemKind> = Vec::new();
        for &b in &self.bank_counts {
            kinds.push(MemKind::Banked { banks: b });
            if self.include_dual_port && b > 1 {
                kinds.push(MemKind::BankedDualPort { banks: b });
            }
            if self.include_block && b > 1 {
                kinds.push(MemKind::BankedBlock { banks: b });
            }
        }
        if self.include_multipump {
            kinds.push(MemKind::MultiPump { factor: 2 });
            kinds.push(MemKind::MultiPump { factor: 4 });
        }
        for &(r, w) in &self.amm_ports {
            kinds.push(MemKind::XorAmm { read_ports: r, write_ports: w });
            if self.include_lvt {
                kinds.push(MemKind::LvtAmm { read_ports: r, write_ports: w });
            }
            if self.include_flat_xor {
                kinds.push(MemKind::XorFlat { read_ports: r, write_ports: w });
            }
        }
        let mut models: Vec<Box<dyn MemModel>> = kinds.iter().map(MemKind::model).collect();
        for id in &self.extra_models {
            if let Some(m) = mem::parse_model(id) {
                // dedupe against axis-produced models (and repeated
                // extras) so e.g. flat_xor + models=["xorflat4r2w"]
                // doesn't enumerate the same design twice
                if !models.iter().any(|e| e.id() == m.id()) {
                    models.push(m);
                }
            }
        }
        models
    }

    /// Enumerate every sweep point (word × models × unroll × alus).
    ///
    /// `word_bytes` is the **outermost** axis: points sharing a word
    /// size are contiguous, so the engine runners ([`run_points`],
    /// [`evaluate_designs`]) compile the trace once per group and serve
    /// every (model, unroll, alus) variant in it from that one
    /// [`CompiledTrace`]. Each model trait object is boxed once and
    /// `Arc`-shared across all its knob combinations.
    pub fn points(&self) -> Vec<SweepPoint> {
        let models: Vec<Arc<dyn MemModel>> = self
            .models()
            .into_iter()
            .map(|m| -> Arc<dyn MemModel> { Arc::from(m) })
            .collect();
        let mut out = Vec::with_capacity(
            models.len() * self.unrolls.len() * self.word_bytes.len() * self.alus.len(),
        );
        for &word_bytes in &self.word_bytes {
            for model in &models {
                for &unroll in &self.unrolls {
                    for &alus in &self.alus {
                        out.push(SweepPoint {
                            model: Arc::clone(model),
                            knobs: Knobs { unroll, word_bytes, alus },
                        });
                    }
                }
            }
        }
        out
    }

    /// Compat enumeration as [`DesignConfig`]s (built-in organizations
    /// only — `extra_models` need the trait-object path of [`points`]).
    pub fn configs(&self) -> Vec<DesignConfig> {
        self.points()
            .into_iter()
            .filter_map(|p| {
                MemKind::parse(&p.model.id()).map(|mem| DesignConfig {
                    mem,
                    unroll: p.knobs.unroll,
                    word_bytes: p.knobs.word_bytes,
                    alus: p.knobs.alus,
                })
            })
            .collect()
    }

    /// Run the sweep over a trace: word-size groups share one
    /// [`CompiledTrace`], compatible points run lane-batched, workers
    /// reuse their arenas, results in enumeration order.
    pub fn run(&self, trace: &Trace) -> Vec<DesignPoint> {
        let threads = if self.threads == 0 { pool::default_threads() } else { self.threads };
        run_points(trace, &self.points(), threads, self.lanes)
    }
}

/// Hard lane cap for batched dispatch. The v2 kernel tracks lanes in
/// `u64` bitmasks (event wheel, active set) so it physically supports
/// 64, but past ~32 lanes the lane-major counter arena outgrows a
/// per-core cache slice for typical traces — the dispatchers stop here.
pub const MAX_LANES: usize = 32;

/// Per-worker budget for the lane-major hot state backing the
/// auto-calibration in [`auto_lanes`]: ~8 B of counters per (lane,
/// node), kept within ~1 MiB so a worker's working set stays inside
/// its L2 slice.
const LANE_CACHE_BUDGET_BYTES: usize = 1 << 20;

/// Auto-calibrated lane width for `lanes = 0`: as wide as the
/// compatible group allows, clamped so `trace_nodes` lanes of counters
/// fit the cache budget (big traces narrow the batch, small traces run
/// the full [`MAX_LANES`]). Always at least 2 — a 1-wide batch would
/// pay lane setup for zero sharing.
pub fn auto_lanes(group: usize, trace_nodes: usize) -> usize {
    let per_lane_bytes = trace_nodes.max(1) * 8;
    (LANE_CACHE_BUDGET_BYTES / per_lane_bytes.max(1)).clamp(2, MAX_LANES).min(group.max(1))
}

/// Resolve the `lanes` knob for one compatible group: 0 = auto
/// ([`auto_lanes`] from the group size and trace footprint), explicit
/// values clamped to [`MAX_LANES`] (1 still forces the scalar engine).
/// Purely a scheduling decision — results are bit-identical regardless.
pub fn resolve_lanes(lanes: usize, group: usize, trace_nodes: usize) -> usize {
    if lanes == 0 {
        auto_lanes(group, trace_nodes)
    } else {
        lanes.min(MAX_LANES)
    }
}

/// Build one sized [`MemDesign`] per enumerated point.
///
/// A memory design depends only on `(model, word_bytes)`, so each is
/// built **once per contiguous (model, word-size) run** — for
/// [`Sweep::points`] enumeration that is once per model per word group —
/// and cloned across the (unroll, alus) knob variants; the clone skips
/// the macro-sizing math `build` redoes. The single home of this
/// build-or-clone rule: [`run_points`], the [`crate::coordinator`] and
/// the campaign planner all feed from it. Output order matches `points`.
pub fn build_designs(trace: &Trace, points: &[SweepPoint]) -> Vec<MemDesign> {
    let mut builder = sched::DesignBuilder::new(trace);
    let mut out: Vec<MemDesign> = Vec::with_capacity(points.len());
    for (i, p) in points.iter().enumerate() {
        let fresh = match i.checked_sub(1) {
            Some(j) => {
                points[j].knobs.word_bytes != p.knobs.word_bytes
                    || !Arc::ptr_eq(&points[j].model, &p.model)
            }
            None => true,
        };
        if fresh {
            out.push(builder.build(&*p.model, p.knobs.word_bytes));
        } else {
            let prev = out.last().unwrap().clone();
            out.push(prev);
        }
    }
    out
}

/// Evaluate enumerated sweep points with the compiled-trace engine:
/// designs from [`build_designs`], scheduling through
/// [`evaluate_designs`]. Output order matches `points`.
pub fn run_points(
    trace: &Trace,
    points: &[SweepPoint],
    threads: usize,
    lanes: usize,
) -> Vec<DesignPoint> {
    let designs = build_designs(trace, points);
    let work: Vec<(SweepPoint, MemDesign)> = points.iter().cloned().zip(designs).collect();
    evaluate_designs(trace, &work, threads, lanes)
}

/// Partition one word-size group into lane chunks: indices (into the
/// group) of points sharing `(unroll, alus)`, bucketed in first-seen
/// order and split to at most `lanes` per chunk. [`Sweep::points`] puts
/// the model axis *outside* the knob axes, so one knob combination
/// recurs once per model at a fixed stride — the buckets gather those
/// recurrences into maximal compatible lane sets. Scattering results
/// back through the indices restores exact enumeration order.
fn lane_chunks(group: &[(SweepPoint, MemDesign)], lanes: usize) -> Vec<Vec<usize>> {
    let lanes = lanes.max(1);
    let mut buckets: Vec<((u32, u32), Vec<usize>)> = Vec::new();
    for (i, (p, _)) in group.iter().enumerate() {
        let key = (p.knobs.unroll, p.knobs.alus);
        match buckets.iter_mut().find(|(k, _)| *k == key) {
            Some((_, v)) => v.push(i),
            None => buckets.push((key, vec![i])),
        }
    }
    let mut chunks = Vec::new();
    for (_, idxs) in buckets {
        for c in idxs.chunks(lanes) {
            chunks.push(c.to_vec());
        }
    }
    chunks
}

/// Score one lane chunk: the batched engine for real lane groups, the
/// scalar engine for singletons (a one-lane batch would pay lane-arena
/// setup for zero sharing). `scratch` holds the chunk's design clones
/// in a buffer reused across every chunk a worker scores — no per-chunk
/// `Vec` on the dispatch path. Returns points in chunk order.
fn evaluate_chunk(
    compiled: &CompiledTrace<'_>,
    group: &[(SweepPoint, MemDesign)],
    chunk: &[usize],
    arena: &mut SimArena,
    batch: &mut BatchArena,
    scratch: &mut Vec<MemDesign>,
) -> Vec<DesignPoint> {
    let knobs = group[chunk[0]].0.knobs;
    if chunk.len() == 1 {
        let (p, design) = &group[chunk[0]];
        let sim = compiled.simulate(arena, &p.knobs, design);
        return vec![point_from(&design.id, design.is_amm, &p.knobs, sim)];
    }
    scratch.clear();
    scratch.extend(chunk.iter().map(|&i| group[i].1.clone()));
    let sims = compiled.simulate_batch(batch, &knobs, scratch);
    chunk
        .iter()
        .zip(sims)
        .map(|(&i, sim)| {
            let (p, design) = &group[i];
            point_from(&design.id, design.is_amm, &p.knobs, sim)
        })
        .collect()
}

/// Evaluate pre-built `(point, design)` pairs with the compiled-trace
/// engines: consecutive pairs sharing a `word_bytes` form one group,
/// the trace compiles once per group (word size is [`Sweep::points`]'
/// outermost axis, so each size compiles exactly once), the group is
/// split into compatible lane chunks ([`lane_chunks`]) scored through
/// [`CompiledTrace::simulate_batch`] — scalar for singletons — and
/// every [`crate::util::pool::parallel_map_with`] worker reuses one
/// [`SimArena`] + [`BatchArena`] across its whole slice of the group.
/// This is the single grouped dispatcher — [`run_points`] feeds it
/// freshly built designs, the [`crate::coordinator`] feeds it
/// cost-patched ones. Output order matches the input for every `lanes`
/// value, and so do the output bytes (the engines are bit-identical).
pub fn evaluate_designs(
    trace: &Trace,
    work: &[(SweepPoint, MemDesign)],
    threads: usize,
    lanes: usize,
) -> Vec<DesignPoint> {
    let mut out: Vec<Option<DesignPoint>> = Vec::with_capacity(work.len());
    out.resize_with(work.len(), || None);
    let mut start = 0;
    while start < work.len() {
        let wb = work[start].0.knobs.word_bytes;
        let end = start
            + work[start..].iter().take_while(|(p, _)| p.knobs.word_bytes == wb).count();
        let group = &work[start..end];
        let compiled = CompiledTrace::new(trace, wb);
        let width = resolve_lanes(lanes, group.len(), trace.len());
        let chunks = lane_chunks(group, width);
        let scored = pool::parallel_map_with(
            &chunks,
            threads,
            || (SimArena::new(), BatchArena::new(), Vec::new()),
            |(arena, batch, scratch), chunk| {
                let points = evaluate_chunk(&compiled, group, chunk, arena, batch, scratch);
                chunk.iter().copied().zip(points).collect::<Vec<(usize, DesignPoint)>>()
            },
        );
        for (i, p) in scored.into_iter().flatten() {
            out[start + i] = Some(p);
        }
        start = end;
    }
    out.into_iter().map(|p| p.expect("every sweep point scored exactly once")).collect()
}

/// Evaluate a single design point (compat wrapper over the model path).
pub fn evaluate(trace: &Trace, cfg: &DesignConfig) -> DesignPoint {
    evaluate_model(trace, &*cfg.mem.model(), &cfg.knobs())
}

/// Evaluate one (model, knobs) sweep point: size + build the memory,
/// schedule, and label the result.
pub fn evaluate_model(trace: &Trace, model: &dyn MemModel, knobs: &Knobs) -> DesignPoint {
    let design = sched::build_memory_model(trace, model, knobs.word_bytes);
    let out = sched::simulate_design(trace, knobs, &design);
    point_from(&design.id, design.is_amm, knobs, out)
}

/// Canonical design-point id: `<mem>/u<unroll>/w<word>/a<alus>`. The
/// campaign resume path keys its JSONL sink on `(benchmark, point_id)`,
/// so this format is part of the sink schema — change it and old sinks
/// stop resuming.
pub fn point_id(mem_id: &str, knobs: &Knobs) -> String {
    format!("{}/u{}/w{}/a{}", mem_id, knobs.unroll, knobs.word_bytes, knobs.alus)
}

/// Assemble a [`DesignPoint`] from its labels + scheduling result.
pub fn point_from(mem_id: &str, is_amm: bool, knobs: &Knobs, out: SimOutput) -> DesignPoint {
    DesignPoint {
        id: point_id(mem_id, knobs),
        mem_id: mem_id.to_string(),
        is_amm,
        unroll: knobs.unroll,
        word_bytes: knobs.word_bytes,
        alus: knobs.alus,
        out,
    }
}

/// Indices of the Pareto-optimal entries of pre-extracted `(x, y)`
/// pairs, minimizing both. The generic frontier kernel: callers extract
/// their keys once, so the sweep runs over plain floats — no cloning or
/// repeated accessor calls per comparison.
pub fn pareto_front_xy(xy: &[(f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xy.len()).collect();
    // sort by x asc, then y asc; sweep keeping strictly-improving y
    idx.sort_by(|&a, &b| {
        xy[a].0
            .partial_cmp(&xy[b].0)
            .unwrap()
            .then(xy[a].1.partial_cmp(&xy[b].1).unwrap())
    });
    let mut front = Vec::new();
    let mut best_y = f64::INFINITY;
    for i in idx {
        if xy[i].1 < best_y {
            best_y = xy[i].1;
            front.push(i);
        }
    }
    front
}

/// Indices of the Pareto-optimal points minimizing `(x, y)`.
pub fn pareto_front<F, G>(points: &[DesignPoint], x: F, y: G) -> Vec<usize>
where
    F: Fn(&DesignPoint) -> f64,
    G: Fn(&DesignPoint) -> f64,
{
    let xy: Vec<(f64, f64)> = points.iter().map(|p| (x(p), y(p))).collect();
    pareto_front_xy(&xy)
}

/// The paper's §IV-C metric: geometric mean over matched-time pairs of
/// `area(banking) / area(AMM)`. For each banking point on the banking
/// (time, area) Pareto front, find the AMM point on the AMM front with
/// the closest execution time within `tol` (relative); pair their areas.
/// Ratio > 1 ⇒ AMM reaches the same performance with less area.
pub fn performance_ratio(points: &[DesignPoint], tol: f64) -> Option<f64> {
    let banking: Vec<&DesignPoint> = points.iter().filter(|p| !p.is_amm).collect();
    let amm: Vec<&DesignPoint> = points.iter().filter(|p| p.is_amm).collect();
    if banking.is_empty() || amm.is_empty() {
        return None;
    }
    let bidx = pareto_front_ref(&banking);
    let aidx = pareto_front_ref(&amm);
    let mut ratios = Vec::new();
    for &bi in &bidx {
        let b = banking[bi];
        // closest-time AMM frontier point
        let mut best: Option<(f64, f64)> = None; // (dt, area)
        for &ai in &aidx {
            let a = amm[ai];
            let dt = (a.time_ns() - b.time_ns()).abs() / b.time_ns();
            if dt <= tol {
                match best {
                    Some((bd, _)) if bd <= dt => {}
                    _ => best = Some((dt, a.area())),
                }
            }
        }
        if let Some((_, a_area)) = best {
            ratios.push(b.area() / a_area);
        }
    }
    if ratios.is_empty() {
        None
    } else {
        Some(stats::geomean(&ratios))
    }
}

/// (time, area) frontier over borrowed points — key extraction only, no
/// `DesignPoint` clones (`performance_ratio` calls this per family).
fn pareto_front_ref(points: &[&DesignPoint]) -> Vec<usize> {
    let xy: Vec<(f64, f64)> = points.iter().map(|p| (p.time_ns(), p.area())).collect();
    pareto_front_xy(&xy)
}

/// Fastest achievable time among a filtered subset (∞ if none).
pub fn best_time<F: Fn(&DesignPoint) -> bool>(points: &[DesignPoint], f: F) -> f64 {
    points.iter().filter(|p| f(p)).map(|p| p.time_ns()).fold(f64::INFINITY, f64::min)
}

/// Summary of one benchmark's DSE (one Fig 4 panel + one Fig 5 bar).
#[derive(Clone, Debug)]
pub struct BenchSummary {
    /// Benchmark name.
    pub name: String,
    /// Weinberg locality.
    pub locality: f64,
    /// §IV-C geometric-mean area ratio (banking / AMM), if computable.
    pub perf_ratio: Option<f64>,
    /// Fastest banking time (ns).
    pub best_banking_ns: f64,
    /// Fastest AMM time (ns).
    pub best_amm_ns: f64,
    /// Number of evaluated points.
    pub n_points: usize,
}

/// Run the full per-benchmark analysis (sweep + locality + ratio).
pub fn analyze_benchmark(
    name: &str,
    scale: crate::suite::Scale,
    sweep: &Sweep,
) -> (BenchSummary, Vec<DesignPoint>) {
    let wl = crate::suite::generate(name, scale);
    let points = sweep.run(&wl.trace);
    let locality = crate::locality::analyze(&wl.trace).spatial_locality();
    let summary = BenchSummary {
        name: name.to_string(),
        locality,
        perf_ratio: performance_ratio(&points, 0.10),
        best_banking_ns: best_time(&points, |p| !p.is_amm),
        best_amm_ns: best_time(&points, |p| p.is_amm),
        n_points: points.len(),
    };
    (summary, points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suite::{self, Scale};

    #[test]
    fn sweep_enumerates_cartesian_product() {
        let s = Sweep::quick();
        let configs = s.configs();
        // mems: banked1, banked4, xor2r1w, xor2r2w = 4
        assert_eq!(configs.len(), 4 * 2 * 1 * 1);
        let mut dual = Sweep::quick();
        dual.include_dual_port = true;
        assert_eq!(dual.configs().len(), 5 * 2);
    }

    #[test]
    fn points_group_by_word_bytes_and_share_models() {
        let mut s = Sweep::quick();
        s.word_bytes = vec![4, 8];
        let pts = s.points();
        // word size is the outermost axis: one contiguous run per size
        let runs = 1 + pts
            .windows(2)
            .filter(|w| w[0].knobs.word_bytes != w[1].knobs.word_bytes)
            .count();
        assert_eq!(runs, s.word_bytes.len());
        // models are Arc-shared: O(models) distinct allocations, not
        // O(points)
        let distinct: std::collections::HashSet<*const ()> =
            pts.iter().map(|p| Arc::as_ptr(&p.model) as *const ()).collect();
        assert_eq!(distinct.len(), s.models().len());
    }

    #[test]
    fn build_designs_matches_per_point_builds() {
        let wl = suite::generate("stencil2d", Scale::Tiny);
        let mut s = Sweep::quick();
        s.word_bytes = vec![4, 8];
        let pts = s.points();
        let designs = build_designs(&wl.trace, &pts);
        assert_eq!(designs.len(), pts.len());
        for (p, d) in pts.iter().zip(&designs) {
            let fresh = sched::build_memory_model(&wl.trace, &*p.model, p.knobs.word_bytes);
            assert_eq!(d.id, fresh.id);
            assert_eq!(d.depth, fresh.depth);
            assert_eq!(d.macro_depth, fresh.macro_depth);
            assert_eq!(d.sram.area_um2, fresh.sram.area_um2, "{}", d.id);
        }
    }

    #[test]
    fn grouped_run_matches_per_point_compat_path() {
        let wl = suite::generate("stencil2d", Scale::Tiny);
        let mut s = Sweep::quick();
        s.word_bytes = vec![4, 8];
        let run = s.run(&wl.trace);
        let per_point: Vec<DesignPoint> = s
            .points()
            .iter()
            .map(|p| evaluate_model(&wl.trace, &*p.model, &p.knobs))
            .collect();
        assert_eq!(run.len(), per_point.len());
        for (a, b) in run.iter().zip(&per_point) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.out, b.out, "{}", a.id);
        }
    }

    #[test]
    fn pareto_front_xy_matches_closure_front() {
        let wl = suite::generate("gemm", Scale::Tiny);
        let points = Sweep::quick().run(&wl.trace);
        let via_closures = pareto_front(&points, |p| p.time_ns(), |p| p.area());
        let xy: Vec<(f64, f64)> = points.iter().map(|p| (p.time_ns(), p.area())).collect();
        assert_eq!(via_closures, pareto_front_xy(&xy));
    }

    #[test]
    fn pareto_front_is_minimal_and_sorted() {
        let wl = suite::generate("gemm", Scale::Tiny);
        let points = Sweep::quick().run(&wl.trace);
        let front = pareto_front(&points, |p| p.time_ns(), |p| p.area());
        assert!(!front.is_empty());
        // no frontier point dominates another
        for (k, &i) in front.iter().enumerate() {
            for &j in &front[k + 1..] {
                let (a, b) = (&points[i], &points[j]);
                let dominates = a.time_ns() <= b.time_ns() && a.area() <= b.area();
                assert!(!dominates, "{} dominates {}", a.id, b.id);
            }
        }
        // every non-front point is dominated by some front point
        for (i, p) in points.iter().enumerate() {
            if front.contains(&i) {
                continue;
            }
            let dominated = front.iter().any(|&f| {
                points[f].time_ns() <= p.time_ns() && points[f].area() <= p.area()
            });
            assert!(dominated, "{} not dominated", p.id);
        }
    }

    #[test]
    fn amm_extends_the_fast_end_for_gemm() {
        // The paper's Fig 4(b) shape: AMM points reach cycle counts the
        // banked designs cannot.
        let wl = suite::generate("gemm", Scale::Tiny);
        let sweep = Sweep {
            unrolls: vec![8],
            word_bytes: vec![8],
            alus: vec![8],
            bank_counts: vec![1, 2, 4],
            amm_ports: vec![(4, 2)],
            include_multipump: false,
            include_lvt: false,
            ..Sweep::default()
        };
        let points = sweep.run(&wl.trace);
        let best_banked = best_time(&points, |p| !p.is_amm);
        let best_amm = best_time(&points, |p| p.is_amm);
        assert!(
            best_amm < best_banked,
            "amm {best_amm} should beat banked {best_banked} on gemm"
        );
    }

    #[test]
    fn performance_ratio_none_without_amm() {
        let wl = suite::generate("gemm", Scale::Tiny);
        let sweep = Sweep { amm_ports: vec![], ..Sweep::quick() };
        let points = sweep.run(&wl.trace);
        assert!(performance_ratio(&points, 0.1).is_none());
    }

    #[test]
    fn edp_is_positive_and_scales_with_time() {
        let wl = suite::generate("stencil2d", Scale::Tiny);
        let points = Sweep::quick().run(&wl.trace);
        for p in &points {
            assert!(p.edp() > 0.0, "{}", p.id);
        }
        // the slowest point has a larger EDP than the fastest (same
        // workload, comparable power scale)
        let fastest = points.iter().min_by(|a, b| a.time_ns().partial_cmp(&b.time_ns()).unwrap()).unwrap();
        let slowest = points.iter().max_by(|a, b| a.time_ns().partial_cmp(&b.time_ns()).unwrap()).unwrap();
        assert!(slowest.edp() > fastest.edp() * 0.5);
    }

    #[test]
    fn block_and_flat_xor_flags_extend_the_sweep() {
        let mut s = Sweep::quick();
        let base = s.configs().len();
        s.include_block = true;
        s.include_flat_xor = true;
        // +1 bankedblk4 (banks>1 only), +2 xorflat
        assert_eq!(s.configs().len(), base + (1 + 2) * 2);
    }

    #[test]
    fn extra_models_extend_the_sweep_via_the_registry() {
        let mut s = Sweep::quick();
        let base = s.points().len();
        s.extra_models = vec!["cmp2r2w".into(), "not-a-model".into()];
        // unknown ids are skipped; cmp2r2w adds unrolls × words × alus
        assert_eq!(s.points().len(), base + 2);
        assert!(s.points().iter().any(|p| p.model.id() == "cmp2r2w"));
        // the compat DesignConfig view still resolves built-ins
        assert!(s.configs().iter().any(|c| c.mem == MemKind::CircuitMp { read_ports: 2, write_ports: 2 }));
    }

    #[test]
    fn analyze_benchmark_produces_summary() {
        let (summary, points) = analyze_benchmark("stencil2d", Scale::Tiny, &Sweep::quick());
        assert_eq!(summary.n_points, points.len());
        assert!(summary.locality > 0.0);
        assert!(summary.best_amm_ns.is_finite());
        assert!(summary.best_banking_ns.is_finite());
    }
}
