//! Unified error type for the crate (in-tree `anyhow` replacement).
//!
//! Every fallible public API returns [`Result`]. Variants are coarse on
//! purpose: callers branch on *category* (bad config vs missing runtime
//! support), and the payload carries the human-readable detail.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified error for config parsing, exploration, reporting and the
/// (optional) PJRT runtime.
#[derive(Debug)]
pub enum Error {
    /// Filesystem failure, with the path or operation that failed.
    Io {
        /// What was being done (e.g. `read config configs/gemm.toml`).
        what: String,
        /// Underlying I/O error.
        source: std::io::Error,
    },
    /// Malformed config file or option value.
    Config(String),
    /// Benchmark name neither in [`crate::suite::ALL_BENCHMARKS`] nor a
    /// parametric `synth:` spec (see [`crate::suite::validate_name`]).
    UnknownBenchmark {
        /// The offending name.
        name: String,
    },
    /// Memory-model id not resolvable through [`crate::mem::parse_model`].
    UnknownModel {
        /// The offending id.
        id: String,
    },
    /// PJRT / cost-service failure (backend died, artifact mismatch, or
    /// PJRT support not compiled in).
    Runtime(String),
    /// Anything else.
    Msg(String),
}

impl Error {
    /// Free-form error (the `anyhow::anyhow!` replacement).
    pub fn msg(m: impl Into<String>) -> Error {
        Error::Msg(m.into())
    }

    /// Config-category error.
    pub fn config(m: impl Into<String>) -> Error {
        Error::Config(m.into())
    }

    /// Runtime-category error.
    pub fn runtime(m: impl Into<String>) -> Error {
        Error::Runtime(m.into())
    }

    /// Wrap an I/O error with context.
    pub fn io(what: impl Into<String>, source: std::io::Error) -> Error {
        Error::Io { what: what.into(), source }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io { what, source } => write!(f, "{what}: {source}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::UnknownBenchmark { name } => write!(
                f,
                "unknown benchmark {name:?} (known: {:?}; or a parametric synthetic name \
                 like \"synth:stride=rand,rw=0.7,reuse=64\" — {})",
                crate::suite::ALL_BENCHMARKS,
                crate::suite::synthetic::DIAL_HELP
            ),
            Error::UnknownModel { id } => write!(
                f,
                "unknown memory model {id:?}; registered prefixes: {}",
                crate::mem::registry()
                    .iter()
                    .map(|e| e.prefix)
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Msg(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io { what: "io".into(), source: e }
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Error::Config(format!("bad integer: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category_and_detail() {
        assert!(Error::config("bad key").to_string().contains("bad key"));
        assert!(Error::runtime("no pjrt").to_string().contains("no pjrt"));
        let e = Error::UnknownBenchmark { name: "nope".into() };
        assert!(e.to_string().contains("nope"));
        assert!(e.to_string().contains("gemm"));
        // the synthetic namespace and its dials are advertised too
        assert!(e.to_string().contains("synth:"));
        assert!(e.to_string().contains("known dials"));
    }

    #[test]
    fn unknown_model_lists_registry_prefixes() {
        let e = Error::UnknownModel { id: "weird9".into() };
        let s = e.to_string();
        assert!(s.contains("weird9"));
        assert!(s.contains("banked"), "{s}");
        assert!(s.contains("xor"), "{s}");
    }

    #[test]
    fn io_errors_chain_a_source() {
        use std::error::Error as _;
        let e = Error::io("read x", std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        assert!(e.source().is_some());
        assert!(e.to_string().contains("read x"));
    }
}
