//! CACTI-lite: analytical SRAM macro model at 45 nm.
//!
//! The paper estimates SRAM banks with CACTI and folds the numbers into
//! Aladdin's power/area/latency tables (§III-A). CACTI itself is not
//! available here, so this module implements the same *functional forms*
//! CACTI uses — cell array + √depth periphery area, √(bits) wire/sense
//! energy, bit-proportional leakage, and log-decoder + bitline access
//! time — with constants calibrated to published 45 nm SRAM data
//! (see DESIGN.md "Reproduction stance"). Only relative cost between
//! configurations matters for the paper's Pareto shapes.
//!
//! **This model is mirrored bit-for-bit (f32 arithmetic, same formulas,
//! same constants) by the Pallas kernel in
//! `python/compile/kernels/cost_eval.py`.** `rust/tests/pjrt_cost.rs`
//! asserts the two agree to 1e-4 relative. Change one side → change both.

/// Calibration constants (45 nm). Shared verbatim with the L1 kernel.
pub mod cal {
    /// 6T SRAM cell area, µm² per bit (45 nm bulk, published compilers).
    pub const CELL_UM2: f32 = 0.65;
    /// Extra cell-area factor per port beyond the first 1RW port
    /// (extra wordline + bitline pair pitch growth, per axis — the
    /// quadratic blow-up that motivates AMMs; 0.5/port reflects the
    /// wire-congestion-dominated layouts reported for ≥4-port cells).
    pub const PORT_PITCH: f32 = 0.5;
    /// Periphery area coefficient: decoder/sense µm² per (width · √depth).
    pub const PERIPH_A: f32 = 1.9;
    /// Fixed macro overhead, µm² (control, timing, well taps).
    pub const PERIPH_B: f32 = 520.0;
    /// Read energy: pJ fixed per access (decode + control).
    pub const E_READ_0: f32 = 0.45;
    /// Read energy: pJ per bit · √depth term (bitline + sense).
    pub const E_READ_BIT: f32 = 0.0021;
    /// Write energy multiplier over read (full-swing bitlines).
    pub const WRITE_FACTOR: f32 = 1.18;
    /// Leakage, µW per bit at 45 nm HVT-ish array.
    pub const LEAK_BIT: f32 = 0.00082;
    /// Leakage fixed periphery, µW.
    pub const LEAK_0: f32 = 3.1;
    /// Access time: fixed ns (clk-to-q + control).
    pub const T_0: f32 = 0.28;
    /// Access time: ns per log2(depth) (decoder levels).
    pub const T_DEC: f32 = 0.042;
    /// Access time: ns per √depth (bitline RC).
    pub const T_BL: f32 = 0.0095;
    /// Access-time port penalty per extra port (loading on cell).
    pub const T_PORT: f32 = 0.06;
}

/// A physical SRAM macro configuration (one bank as the memory compiler
/// would generate it).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MacroCfg {
    /// Number of words.
    pub depth: u32,
    /// Word width in bits.
    pub width: u32,
    /// Read ports (≥1).
    pub read_ports: u32,
    /// Write ports (≥1). `read_ports + write_ports ≤ 2` is what real
    /// memory compilers provide; more is a *circuit-level* multiport and
    /// is costed with the quadratic pitch penalty below (that penalty is
    /// exactly why the paper builds AMMs instead).
    pub write_ports: u32,
}

impl MacroCfg {
    /// Simple 1RW macro.
    pub fn rw1(depth: u32, width: u32) -> Self {
        MacroCfg { depth, width, read_ports: 1, write_ports: 1 }
    }
    /// Dual-port 1R1W macro (the largest config EDA flows hand out).
    pub fn r1w1(depth: u32, width: u32) -> Self {
        MacroCfg { depth, width, read_ports: 1, write_ports: 1 }
    }
    /// Total ports.
    pub fn ports(&self) -> u32 {
        self.read_ports + self.write_ports
    }
}

/// Cost estimate for one macro.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MacroCost {
    /// Layout area, µm².
    pub area_um2: f32,
    /// Energy per read access, pJ.
    pub e_read_pj: f32,
    /// Energy per write access, pJ.
    pub e_write_pj: f32,
    /// Leakage power, µW.
    pub leak_uw: f32,
    /// Access (read) time, ns.
    pub t_access_ns: f32,
}

impl MacroCost {
    /// Sum of two cost structs (areas/leakage add; energies add — used
    /// when a logical access touches several macros; access time takes
    /// the max).
    pub fn stack(self, other: MacroCost) -> MacroCost {
        MacroCost {
            area_um2: self.area_um2 + other.area_um2,
            e_read_pj: self.e_read_pj + other.e_read_pj,
            e_write_pj: self.e_write_pj + other.e_write_pj,
            leak_uw: self.leak_uw + other.leak_uw,
            t_access_ns: self.t_access_ns.max(other.t_access_ns),
        }
    }
}

/// Evaluate the CACTI-lite model for one macro.
///
/// Functional form (all f32, mirrored by the Pallas kernel):
/// ```text
/// pitch     = 1 + PORT_PITCH · (ports − 2)        (ports > 2, else 1)
/// area      = depth·width·CELL·pitch² + PERIPH_A·width·√depth·pitch + PERIPH_B
/// e_read    = E_READ_0 + E_READ_BIT · width · √depth · pitch
/// e_write   = e_read · WRITE_FACTOR
/// leak      = LEAK_0 + LEAK_BIT · depth · width · pitch²
/// t_access  = T_0 + T_DEC·log2(depth) + T_BL·√depth·pitch
///             + T_PORT·(ports − 2 if ports > 2 else 0)
/// ```
pub fn macro_cost(cfg: MacroCfg) -> MacroCost {
    let depth = cfg.depth.max(1) as f32;
    let width = cfg.width.max(1) as f32;
    let ports = cfg.ports() as f32;
    let extra = (ports - 2.0).max(0.0);
    let pitch = 1.0 + cal::PORT_PITCH * extra;
    let sqrt_d = depth.sqrt();
    let area = depth * width * cal::CELL_UM2 * pitch * pitch
        + cal::PERIPH_A * width * sqrt_d * pitch
        + cal::PERIPH_B;
    let e_read = cal::E_READ_0 + cal::E_READ_BIT * width * sqrt_d * pitch;
    let e_write = e_read * cal::WRITE_FACTOR;
    let leak = cal::LEAK_0 + cal::LEAK_BIT * depth * width * pitch * pitch;
    let t = cal::T_0 + cal::T_DEC * depth.log2() + cal::T_BL * sqrt_d * pitch + cal::T_PORT * extra;
    MacroCost { area_um2: area, e_read_pj: e_read, e_write_pj: e_write, leak_uw: leak, t_access_ns: t }
}

/// Batched evaluation over a design matrix — the exact computation the
/// AOT Pallas kernel performs. Input rows are
/// `[depth, width, read_ports, write_ports]`; output rows are
/// `[area, e_read, e_write, leak, t_access]`. Used as the pure-Rust
/// fallback / cross-check for the PJRT path.
pub fn macro_cost_batch(rows: &[[f32; 4]]) -> Vec<[f32; 5]> {
    rows.iter()
        .map(|r| {
            let c = macro_cost(MacroCfg {
                depth: r[0] as u32,
                width: r[1] as u32,
                read_ports: r[2] as u32,
                write_ports: r[3] as u32,
            });
            [c.area_um2, c.e_read_pj, c.e_write_pj, c.leak_uw, c.t_access_ns]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_in_depth() {
        let a = macro_cost(MacroCfg::rw1(256, 32));
        let b = macro_cost(MacroCfg::rw1(1024, 32));
        let c = macro_cost(MacroCfg::rw1(4096, 32));
        assert!(a.area_um2 < b.area_um2 && b.area_um2 < c.area_um2);
        assert!(a.t_access_ns < b.t_access_ns && b.t_access_ns < c.t_access_ns);
        assert!(a.e_read_pj < b.e_read_pj && b.e_read_pj < c.e_read_pj);
        assert!(a.leak_uw < b.leak_uw && b.leak_uw < c.leak_uw);
    }

    #[test]
    fn monotone_in_width() {
        let a = macro_cost(MacroCfg::rw1(1024, 8));
        let b = macro_cost(MacroCfg::rw1(1024, 64));
        assert!(a.area_um2 < b.area_um2);
        assert!(a.e_read_pj < b.e_read_pj);
    }

    #[test]
    fn circuit_multiport_is_quadratically_expensive() {
        // The motivation for AMMs: a circuit-level 4R2W macro blows up.
        let dp = macro_cost(MacroCfg { depth: 1024, width: 32, read_ports: 1, write_ports: 1 });
        let mp = macro_cost(MacroCfg { depth: 1024, width: 32, read_ports: 4, write_ports: 2 });
        // 6 ports → pitch = 1 + 0.35·4 = 2.4 → cell array ≈ 5.76×
        assert!(mp.area_um2 > 4.0 * dp.area_um2, "mp={} dp={}", mp.area_um2, dp.area_um2);
        assert!(mp.t_access_ns > dp.t_access_ns);
    }

    #[test]
    fn write_costs_more_than_read() {
        let c = macro_cost(MacroCfg::rw1(2048, 64));
        assert!(c.e_write_pj > c.e_read_pj);
        assert!((c.e_write_pj / c.e_read_pj - cal::WRITE_FACTOR).abs() < 1e-6);
    }

    #[test]
    fn splitting_into_banks_costs_area_overhead() {
        // One 4096-word macro vs 4×1024: banking pays periphery 4 times.
        let whole = macro_cost(MacroCfg::rw1(4096, 32));
        let quarter = macro_cost(MacroCfg::rw1(1024, 32));
        assert!(4.0 * quarter.area_um2 > whole.area_um2);
        // ...but each bank is faster.
        assert!(quarter.t_access_ns < whole.t_access_ns);
    }

    #[test]
    fn batch_matches_scalar() {
        let rows = [[1024.0, 32.0, 1.0, 1.0], [256.0, 64.0, 2.0, 2.0], [8192.0, 8.0, 1.0, 1.0]];
        let out = macro_cost_batch(&rows);
        for (r, o) in rows.iter().zip(&out) {
            let c = macro_cost(MacroCfg {
                depth: r[0] as u32,
                width: r[1] as u32,
                read_ports: r[2] as u32,
                write_ports: r[3] as u32,
            });
            assert_eq!(o[0], c.area_um2);
            assert_eq!(o[4], c.t_access_ns);
        }
    }

    #[test]
    fn degenerate_inputs_do_not_nan() {
        let c = macro_cost(MacroCfg { depth: 0, width: 0, read_ports: 1, write_ports: 0 });
        assert!(c.area_um2.is_finite());
        assert!(c.t_access_ns.is_finite());
    }
}
