//! Persistent per-`(benchmark, scale)` trace node-count table
//! (`weight-table/v1` JSONL).
//!
//! Weighted (LPT) sharding needs every swept benchmark's trace size to
//! compute the global assignment — which used to force each shard host
//! to *trace the whole swept set*, including benchmarks it owns no
//! units of. Trace generation is deterministic, so the node counts are
//! a pure function of `(benchmark, scale)`; this table caches them in
//! one small JSONL file that hosts can share (ship it with the spec, or
//! point every host at a common data dir). A host with a warm table
//! computes the identical assignment without tracing anything it does
//! not own.
//!
//! Format, in idiom with the sink and cost store: one flat JSON object
//! per line, append-only, first-wins on duplicate keys (the counts are
//! deterministic, so duplicates can only agree), malformed/torn lines
//! skipped with a warning. Missing file = empty table.

use crate::error::{Error, Result};
use crate::suite::{self, Scale};
use crate::util::jsonl;
use crate::util::log;
use std::collections::BTreeMap;
use std::fs::OpenOptions;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Schema tag stamped on every row.
pub const SCHEMA: &str = "weight-table/v1";

/// A cached map from `(benchmark, scale)` to trace node count, with an
/// optional JSONL file backing it.
#[derive(Debug, Default)]
pub struct WeightTable {
    path: Option<PathBuf>,
    rows: BTreeMap<(String, Scale), u64>,
    warned: bool,
}

impl WeightTable {
    /// A table with no backing file: lookups miss, recordings stay
    /// in-process. The behaviour before this table existed.
    pub fn in_memory() -> WeightTable {
        WeightTable::default()
    }

    /// Open (or start) the table at `path`. A missing file is an empty
    /// table; unreadable or malformed lines are skipped.
    pub fn open(path: impl Into<PathBuf>) -> Result<WeightTable> {
        let path = path.into();
        let mut table = WeightTable { path: Some(path.clone()), ..WeightTable::default() };
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(table),
            Err(e) => return Err(Error::io(format!("read weight table {}", path.display()), e)),
        };
        let mut malformed = 0usize;
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            match parse_line(line) {
                // First-wins: counts are deterministic, so a duplicate
                // can only repeat the held value; keep the oldest.
                Some((bench, scale, nodes)) => {
                    table.rows.entry((bench, scale)).or_insert(nodes);
                }
                None => malformed += 1,
            }
        }
        if malformed > 0 {
            log::warn(format!(
                "weight table {}: skipped {malformed} malformed line(s)",
                path.display()
            ));
        }
        Ok(table)
    }

    /// Backing file, if any.
    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Number of cached entries.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Cached node count, if present.
    pub fn get(&self, benchmark: &str, scale: Scale) -> Option<u64> {
        self.rows.get(&(benchmark.to_string(), scale)).copied()
    }

    /// Cache a count, appending to the backing file (best-effort: an
    /// unwritable table still works in-process, with one warning).
    pub fn record(&mut self, benchmark: &str, scale: Scale, nodes: u64) {
        let key = (benchmark.to_string(), scale);
        if self.rows.contains_key(&key) {
            return;
        }
        self.rows.insert(key, nodes);
        let Some(path) = &self.path else { return };
        let line = record_line(benchmark, scale, nodes);
        let appended = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .and_then(|mut f| f.write_all(line.as_bytes()).and_then(|()| f.flush()));
        if let Err(e) = appended {
            if !self.warned {
                self.warned = true;
                log::warn(format!("weight table {} not updatable: {e}", path.display()));
            }
        }
    }

    /// The weighted-sharding lookup: cached count, or trace the
    /// benchmark once (memoized per process) and cache the result.
    /// Synthetic (`synth:`) node counts are closed-form — exactly 2
    /// nodes per access — so they are *computed*, never traced, and
    /// recorded in the table like any other row.
    pub fn nodes_or_trace(&mut self, benchmark: &str, scale: Scale) -> u64 {
        if let Some(n) = self.get(benchmark, scale) {
            return n;
        }
        let nodes = match suite::synthetic::try_node_count(benchmark, scale) {
            Some(n) => n,
            None => suite::generate_cached(benchmark, scale).trace.len() as u64,
        };
        self.record(benchmark, scale, nodes);
        nodes
    }
}

/// One table row, newline-terminated.
pub fn record_line(benchmark: &str, scale: Scale, nodes: u64) -> String {
    format!(
        "{{\"schema\":\"{SCHEMA}\",\"benchmark\":\"{}\",\"scale\":\"{}\",\"trace_nodes\":{nodes}}}\n",
        jsonl::escape(benchmark),
        scale.as_str()
    )
}

/// Parse one table row; `None` on schema mismatch or malformed/torn
/// lines.
pub fn parse_line(line: &str) -> Option<(String, Scale, u64)> {
    if !line.ends_with('}') || jsonl::field(line, "schema") != Some(SCHEMA) {
        return None;
    }
    let bench = jsonl::field(line, "benchmark")?.to_string();
    let scale = Scale::parse(jsonl::field(line, "scale")?)?;
    let nodes = jsonl::field(line, "trace_nodes")?.parse::<u64>().ok()?;
    Some((bench, scale, nodes))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir();
        dir.join(format!("amm-weights-{tag}-{}.jsonl", std::process::id()))
    }

    #[test]
    fn rows_round_trip() {
        let line = record_line("gemm", Scale::Tiny, 12345);
        assert!(line.ends_with('\n'));
        assert_eq!(parse_line(line.trim_end()), Some(("gemm".into(), Scale::Tiny, 12345)));
        assert_eq!(parse_line("{\"schema\":\"other/v1\"}"), None, "schema gate");
        let torn = &line[..line.len() - 3];
        assert_eq!(parse_line(torn), None, "torn tail rejected");
    }

    #[test]
    fn open_record_reopen_persists_first_wins() {
        let path = tmpfile("persist");
        let _ = std::fs::remove_file(&path);
        let mut t = WeightTable::open(&path).unwrap();
        assert!(t.is_empty(), "missing file is an empty table");
        t.record("gemm", Scale::Tiny, 100);
        t.record("gemm", Scale::Tiny, 999); // ignored: first-wins
        t.record("fft", Scale::Paper, 5000);
        assert_eq!(t.get("gemm", Scale::Tiny), Some(100));
        assert_eq!(t.len(), 2);
        let t2 = WeightTable::open(&path).unwrap();
        assert_eq!(t2.get("gemm", Scale::Tiny), Some(100));
        assert_eq!(t2.get("fft", Scale::Paper), Some(5000));
        assert_eq!(t2.get("gemm", Scale::Paper), None, "scales are distinct keys");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn malformed_and_torn_lines_are_skipped() {
        let path = tmpfile("torn");
        let good = record_line("kmp", Scale::Tiny, 77);
        let torn = &good[..good.len() - 4];
        std::fs::write(&path, format!("{good}not json\n{torn}")).unwrap();
        let t = WeightTable::open(&path).unwrap();
        assert_eq!(t.len(), 1, "only the intact row survives");
        assert_eq!(t.get("kmp", Scale::Tiny), Some(77));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn nodes_or_trace_fills_the_table_and_matches_the_real_trace() {
        let path = tmpfile("trace");
        let _ = std::fs::remove_file(&path);
        let mut t = WeightTable::open(&path).unwrap();
        let real = suite::generate_cached("gemm", Scale::Tiny).trace.len() as u64;
        assert_eq!(t.nodes_or_trace("gemm", Scale::Tiny), real);
        // warm path: the table now answers without tracing
        assert_eq!(t.get("gemm", Scale::Tiny), Some(real));
        let t2 = WeightTable::open(&path).unwrap();
        assert_eq!(t2.get("gemm", Scale::Tiny), Some(real), "persisted across reopen");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn synthetic_weights_are_computed_not_traced() {
        let name = "synth:stride=rand,rw=0.6,reuse=64,seed=5";
        let mut t = WeightTable::in_memory();
        let expect = suite::synthetic::parse(name).unwrap().node_count(Scale::Large);
        // Large-scale synthetic: closed form answers instantly; actually
        // tracing 2^20 nodes here would be a test-time smell.
        assert_eq!(t.nodes_or_trace(name, Scale::Large), expect);
        assert_eq!(t.get(name, Scale::Large), Some(expect), "recorded like any row");
        // and the closed form is honest: at Tiny it matches a real trace
        assert_eq!(
            suite::generate(name, Scale::Tiny).trace.len() as u64,
            t.nodes_or_trace(name, Scale::Tiny)
        );
        // names with '=' ',' ':' survive the JSONL round trip
        let line = record_line(name, Scale::Tiny, 42);
        assert_eq!(parse_line(line.trim_end()), Some((name.into(), Scale::Tiny, 42)));
    }

    #[test]
    fn in_memory_table_works_without_a_file() {
        let mut t = WeightTable::in_memory();
        assert_eq!(t.path(), None);
        t.record("gemm", Scale::Tiny, 42);
        assert_eq!(t.get("gemm", Scale::Tiny), Some(42));
    }
}
