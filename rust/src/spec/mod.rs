//! Declarative campaign plans: one serializable artifact for every run.
//!
//! A [`CampaignSpec`] is the crate's single description of "a run" —
//! the benchmark plan (swept + locality-only rows), workload scale,
//! sweep axes, result sink, thread count, and an optional shard
//! assignment. Every other way of describing a run lowers to it:
//!
//! * [`crate::config::RunConfig`] parses `*.toml` files (including the
//!   `[campaign]` table) into a spec;
//! * the [`crate::Campaign`] and [`crate::Explorer`] builders are thin
//!   front-ends that assemble a spec;
//! * the campaign engine ([`crate::campaign::run`]) consumes **only**
//!   specs.
//!
//! Because a spec is a plain serializable value ([`CampaignSpec::to_toml`]
//! / [`CampaignSpec::parse`] round-trip), a run can be shipped to another
//! process or host as data. Combined with deterministic **sharding** —
//! [`Shard`] filters the planned `(benchmark, point id)` unit stream by a
//! stable FNV-1a hash, so `n` shards partition the cross-product exactly
//! — the same spec file drives a whole multi-host campaign:
//!
//! ```text
//! host0$ repro run suite.toml --shard 0/2 --sink s0.jsonl
//! host1$ repro run suite.toml --shard 1/2 --sink s1.jsonl
//! any $ repro merge s0.jsonl s1.jsonl --config suite.toml
//! ```

pub mod weights;

use crate::coordinator::Coordinator;
use crate::dse::{self, Sweep};
use crate::error::{Error, Result};
use crate::suite::{self, Scale};
use std::collections::BTreeMap;
use std::fmt;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// The canonical TOML document's schema tag. Parsing accepts a missing
/// tag as v1 (every pre-tag document *is* v1); any other value is
/// rejected up front, so a future v2 can change the grammar without
/// old binaries silently mis-reading it.
pub const SCHEMA: &str = "campaign-spec/v1";

/// One row of the campaign plan, in display (Fig-5) order.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanEntry {
    /// Benchmark name: a [`suite::ALL_BENCHMARKS`] entry or a parametric
    /// `synth:` name (validated by [`suite::validate_name`]).
    pub name: String,
    /// Swept benchmarks run the full sweep; non-swept rows contribute
    /// locality only (the grey rows of Fig 5).
    pub swept: bool,
}

/// A deterministic shard assignment: this run executes the planned
/// units whose stable hash lands in bucket `index` of `count`.
///
/// The hash is a function of `(benchmark, point id)` only — not of the
/// plan order, thread count, or host — so for any `count`, the `count`
/// shards are pairwise disjoint and their union is exactly the full
/// cross-product (pinned by `tests/spec_shard.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    /// Zero-based bucket this run owns.
    pub index: u32,
    /// Total bucket count (≥ 1).
    pub count: u32,
}

impl Shard {
    /// Parse the CLI/TOML form `i/n` (e.g. `0/4`).
    pub fn parse(s: &str) -> Result<Shard> {
        let err = || Error::config(format!("bad shard {s:?} (expected i/n, e.g. 0/4)"));
        let (i, n) = s.split_once('/').ok_or_else(err)?;
        let shard = Shard {
            index: i.trim().parse().map_err(|_| err())?,
            count: n.trim().parse().map_err(|_| err())?,
        };
        shard.validate()?;
        Ok(shard)
    }

    /// Reject empty or out-of-range assignments.
    pub fn validate(&self) -> Result<()> {
        if self.count == 0 {
            return Err(Error::config("shard count must be >= 1"));
        }
        if self.index >= self.count {
            return Err(Error::config(format!(
                "shard index {} out of range for {} shard(s)",
                self.index, self.count
            )));
        }
        Ok(())
    }

    /// Does this shard own the planned unit `(benchmark, point_id)`?
    pub fn contains(&self, benchmark: &str, point_id: &str) -> bool {
        shard_of(benchmark, point_id, self.count) == self.index
    }
}

impl fmt::Display for Shard {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

/// The stable shard bucket of one planned unit: FNV-1a (64-bit) over
/// `benchmark \0 point_id`, reduced mod `count`. This function is part
/// of the sink/spec contract — change it and mixed-version shard fleets
/// stop partitioning.
pub fn shard_of(benchmark: &str, point_id: &str, count: u32) -> u32 {
    use crate::util::hash::{fnv1a, FNV_OFFSET};
    let h = fnv1a(fnv1a(fnv1a(FNV_OFFSET, benchmark.as_bytes()), &[0u8]), point_id.as_bytes());
    (h % u64::from(count.max(1))) as u32
}

/// How a sharded run decides which planned units it owns.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShardStrategy {
    /// Per-unit [`shard_of`] hash (the default): uniform, stateless,
    /// and a shard host never traces a benchmark it owns no units of.
    #[default]
    Hash,
    /// [`weighted_shard_assignment`]: LPT over per-benchmark trace node
    /// counts, so heterogeneous suites split into shards of comparable
    /// *simulation work*, not just comparable unit counts. Needs every
    /// swept benchmark's trace size; a warm [`weights`] table answers
    /// those from disk, otherwise each host traces the whole swept set
    /// (memoized) before filtering.
    Weighted,
}

impl ShardStrategy {
    /// Stable lowercase name (TOML/CLI).
    pub fn as_str(&self) -> &'static str {
        match self {
            ShardStrategy::Hash => "hash",
            ShardStrategy::Weighted => "weighted",
        }
    }

    /// Parse the name produced by [`ShardStrategy::as_str`].
    pub fn parse(s: &str) -> Option<ShardStrategy> {
        match s {
            "hash" => Some(ShardStrategy::Hash),
            "weighted" => Some(ShardStrategy::Weighted),
            _ => None,
        }
    }
}

/// The weighted variant of [`shard_of`]: assign every planned unit a
/// shard via LPT (longest-processing-time-first) over per-benchmark
/// weights, returning one bucket per `keys` entry (same order).
///
/// Balance is a *global* property, so unlike the per-unit hash this
/// needs the whole key stream at once: units are visited heaviest
/// benchmark first (ties broken by the `(benchmark, point id)` key
/// itself), each going to the currently least-loaded shard (ties to
/// the lowest index). The result is a deterministic function of
/// `(keys, weights, count)` alone — every host computes the identical
/// assignment — and trivially partitions the cross-product exactly:
/// each unit lands in exactly one bucket (pinned by
/// `tests/spec_shard.rs`).
///
/// `weight_of` is consulted once per distinct benchmark (the campaign
/// passes trace node counts); weights are clamped to ≥ 1.
pub fn weighted_shard_assignment<F>(
    keys: &[(String, String)],
    mut weight_of: F,
    count: u32,
) -> Vec<u32>
where
    F: FnMut(&str) -> u64,
{
    let count = count.max(1) as usize;
    let mut weights: BTreeMap<&str, u64> = BTreeMap::new();
    for (bench, _) in keys {
        if !weights.contains_key(bench.as_str()) {
            let w = weight_of(bench.as_str()).max(1);
            weights.insert(bench.as_str(), w);
        }
    }
    let mut order: Vec<usize> = (0..keys.len()).collect();
    order.sort_by(|&a, &b| {
        weights[keys[b].0.as_str()]
            .cmp(&weights[keys[a].0.as_str()])
            .then_with(|| keys[a].cmp(&keys[b]))
    });
    let mut load = vec![0u64; count];
    let mut out = vec![0u32; keys.len()];
    for i in order {
        let mut best = 0usize;
        for s in 1..count {
            if load[s] < load[best] {
                best = s;
            }
        }
        load[best] += weights[keys[i].0.as_str()];
        out[i] = best as u32;
    }
    out
}

/// A validated, serializable campaign plan — the single lowering target
/// for every way a run is described (see the module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct CampaignSpec {
    /// Benchmarks in display order (swept and locality-only rows).
    pub plan: Vec<PlanEntry>,
    /// Workload scale for every benchmark.
    pub scale: Scale,
    /// The sweep applied to every swept benchmark.
    pub sweep: Sweep,
    /// Streaming/resume JSONL sink path, if any.
    pub sink: Option<PathBuf>,
    /// Persistent macro-cost store path (`cost-store/v1`, see
    /// [`crate::cost`]). `None` derives `<sink>.cost.jsonl` when a sink
    /// is set; coordinator-less (offline) runs never open one.
    pub cost_store: Option<PathBuf>,
    /// Persistent simulation-result store path (`sim-store/v1`, see
    /// [`crate::sim`]). `None` derives `<sink>.sim.jsonl` when a sink
    /// is set; coordinator-less (offline) runs never open one.
    pub sim_store: Option<PathBuf>,
    /// Campaign-level worker threads (0 = fall through to
    /// `sweep.threads`, then the coordinator's count, then auto).
    pub threads: usize,
    /// Optional shard assignment: run only this bucket of the plan.
    pub shard: Option<Shard>,
    /// How shard ownership is decided (ignored without a shard).
    pub shard_strategy: ShardStrategy,
    /// Persistent trace-weight table (`weight-table/v1`, see
    /// [`weights`]): caches per-`(benchmark, scale)` node counts so
    /// weighted sharding stops tracing benchmarks this host owns no
    /// units of. `None` falls back to tracing (memoized in-process).
    pub weights: Option<PathBuf>,
}

impl Default for CampaignSpec {
    fn default() -> Self {
        CampaignSpec {
            plan: Vec::new(),
            scale: Scale::Paper,
            sweep: Sweep::default(),
            sink: None,
            cost_store: None,
            sim_store: None,
            threads: 0,
            shard: None,
            shard_strategy: ShardStrategy::Hash,
            weights: None,
        }
    }
}

impl CampaignSpec {
    /// An empty spec (paper scale, default sweep, no sink, no shard).
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one swept benchmark.
    pub fn benchmark(mut self, name: impl Into<String>) -> Self {
        self.plan.push(PlanEntry { name: name.into(), swept: true });
        self
    }

    /// Add one locality-only benchmark.
    pub fn locality_only(mut self, name: impl Into<String>) -> Self {
        self.plan.push(PlanEntry { name: name.into(), swept: false });
        self
    }

    /// Set the shard assignment (validated by [`CampaignSpec::validate`]).
    pub fn with_shard(mut self, index: u32, count: u32) -> Self {
        self.shard = Some(Shard { index, count });
        self
    }

    /// Set the shard-ownership strategy.
    pub fn with_shard_strategy(mut self, strategy: ShardStrategy) -> Self {
        self.shard_strategy = strategy;
        self
    }

    /// Set the persistent macro-cost store path.
    pub fn with_cost_store(mut self, path: impl Into<PathBuf>) -> Self {
        self.cost_store = Some(path.into());
        self
    }

    /// Set the persistent simulation-result store path.
    pub fn with_sim_store(mut self, path: impl Into<PathBuf>) -> Self {
        self.sim_store = Some(path.into());
        self
    }

    /// Set the persistent trace-weight table path (see [`weights`]).
    pub fn with_weights(mut self, path: impl Into<PathBuf>) -> Self {
        self.weights = Some(path.into());
        self
    }

    /// Everything the engine assumes, checked up front: non-empty plan,
    /// known benchmark names, no duplicate plan entries (a benchmark
    /// planned twice would make the `(benchmark, scale, point id)` sink
    /// keys ambiguous — resume would never converge and merge would
    /// report false missing points), known extra-model ids, sane shard.
    pub fn validate(&self) -> Result<()> {
        if self.plan.is_empty() {
            return Err(Error::config(
                "empty campaign spec: add benchmarks / locality_only entries",
            ));
        }
        let mut seen = std::collections::HashSet::new();
        for e in &self.plan {
            // MachSuite names or parametric `synth:` specs (dial errors
            // surface with the known-dial listing).
            suite::validate_name(&e.name)?;
            if !seen.insert(e.name.as_str()) {
                return Err(Error::config(format!(
                    "benchmark {:?} appears twice in the campaign plan",
                    e.name
                )));
            }
        }
        for id in &self.sweep.extra_models {
            if crate::mem::parse_model(id).is_none() {
                return Err(Error::UnknownModel { id: id.clone() });
            }
        }
        if let Some(sh) = &self.shard {
            sh.validate()?;
        }
        Ok(())
    }

    /// Swept benchmark names, in plan order.
    pub fn swept(&self) -> Vec<&str> {
        self.plan.iter().filter(|e| e.swept).map(|e| e.name.as_str()).collect()
    }

    /// Locality-only benchmark names, in plan order.
    pub fn locality_names(&self) -> Vec<&str> {
        self.plan.iter().filter(|e| !e.swept).map(|e| e.name.as_str()).collect()
    }

    /// Every planned swept unit as `(benchmark, point id)`, in
    /// enumeration order, **before** shard filtering — the key stream
    /// that [`Shard::contains`] partitions and `repro merge` reconciles.
    pub fn plan_keys(&self) -> Vec<(String, String)> {
        let points = self.sweep.points();
        let mut keys = Vec::with_capacity(points.len() * self.plan.len());
        for e in &self.plan {
            if !e.swept {
                continue;
            }
            for p in &points {
                keys.push((e.name.clone(), dse::point_id(&p.model.id(), &p.knobs)));
            }
        }
        keys
    }

    /// Serialize to the canonical TOML form (tagged
    /// `schema = "campaign-spec/v1"`). Canonicalization notes: swept
    /// benchmarks are listed before locality-only rows (relative
    /// order within each group is preserved), defaults that parsing
    /// restores (`threads = 0`, `lanes = 0`, absent
    /// sink/cost-store/sim-store/shard, `hash` shard strategy, empty
    /// model list) are omitted.
    /// `parse(to_toml(spec)) == spec` for specs already in
    /// canonical plan order, and `to_toml(parse(text)) == text` for
    /// canonical documents (pinned by `tests/spec_shard.rs`).
    pub fn to_toml(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "# amm-dse campaign spec");
        let _ = writeln!(s, "schema = \"{SCHEMA}\"");
        let _ = writeln!(s, "scale = \"{}\"", self.scale.as_str());
        let _ = writeln!(s);
        let _ = writeln!(s, "[campaign]");
        let _ = writeln!(s, "benchmarks = {}", str_array(&self.swept()));
        let loc = self.locality_names();
        if !loc.is_empty() {
            let _ = writeln!(s, "locality_only = {}", str_array(&loc));
        }
        if let Some(sink) = &self.sink {
            let _ = writeln!(s, "sink = \"{}\"", sink.display());
        }
        if let Some(store) = &self.cost_store {
            let _ = writeln!(s, "cost_store = \"{}\"", store.display());
        }
        if let Some(store) = &self.sim_store {
            let _ = writeln!(s, "sim_store = \"{}\"", store.display());
        }
        if let Some(w) = &self.weights {
            let _ = writeln!(s, "weights = \"{}\"", w.display());
        }
        if self.threads != 0 {
            let _ = writeln!(s, "threads = {}", self.threads);
        }
        if let Some(sh) = &self.shard {
            let _ = writeln!(s, "shard = \"{sh}\"");
        }
        if self.shard_strategy != ShardStrategy::Hash {
            let _ = writeln!(s, "shard_strategy = \"{}\"", self.shard_strategy.as_str());
        }
        let _ = writeln!(s);
        let _ = writeln!(s, "[sweep]");
        let sw = &self.sweep;
        let _ = writeln!(s, "unrolls = {}", int_array(&sw.unrolls));
        let _ = writeln!(s, "word_bytes = {}", int_array(&sw.word_bytes));
        let _ = writeln!(s, "alus = {}", int_array(&sw.alus));
        let _ = writeln!(s, "bank_counts = {}", int_array(&sw.bank_counts));
        let _ = writeln!(s, "multipump = {}", sw.include_multipump);
        let _ = writeln!(s, "lvt = {}", sw.include_lvt);
        let _ = writeln!(s, "dual_port = {}", sw.include_dual_port);
        let _ = writeln!(s, "block_partitioning = {}", sw.include_block);
        let _ = writeln!(s, "flat_xor = {}", sw.include_flat_xor);
        if !sw.extra_models.is_empty() {
            let ids: Vec<&str> = sw.extra_models.iter().map(String::as_str).collect();
            let _ = writeln!(s, "models = {}", str_array(&ids));
        }
        if sw.threads != 0 {
            let _ = writeln!(s, "threads = {}", sw.threads);
        }
        // `lanes = 0` (auto-calibrated batch width, the default) is
        // canonical-by-omission, mirroring `threads` above.
        if sw.lanes != 0 {
            let _ = writeln!(s, "lanes = {}", sw.lanes);
        }
        for (r, w) in &sw.amm_ports {
            let _ = writeln!(s);
            let _ = writeln!(s, "[[amm]]");
            let _ = writeln!(s, "read_ports = {r}");
            let _ = writeln!(s, "write_ports = {w}");
        }
        s
    }

    /// Parse a spec from TOML text (the same grammar as
    /// [`crate::config::parse`]; the `[campaign]` table is optional when
    /// a top-level `benchmark` key names a single-benchmark run).
    pub fn parse(text: &str) -> Result<CampaignSpec> {
        crate::config::parse(text).map(|rc| rc.campaign)
    }

    /// Load a spec from a TOML file.
    pub fn load(path: &Path) -> Result<CampaignSpec> {
        crate::config::load(path).map(|rc| rc.campaign)
    }

    /// Run this spec with a private coordinator (see
    /// [`crate::campaign::run`]).
    pub fn run(&self) -> Result<crate::campaign::CampaignOutcome> {
        crate::campaign::run(self, &crate::campaign::ExecOptions::default())
    }

    /// Run this spec offline (pure-Rust cost model, no coordinator).
    pub fn run_offline(&self) -> Result<crate::campaign::CampaignOutcome> {
        let opts = crate::campaign::ExecOptions { offline: true, ..Default::default() };
        crate::campaign::run(self, &opts)
    }

    /// Run this spec through a caller-provided coordinator.
    pub fn run_with(&self, coord: &Coordinator) -> Result<crate::campaign::CampaignOutcome> {
        crate::campaign::run_with(self, coord, &crate::campaign::ExecOptions::default())
    }
}

fn str_array(items: &[&str]) -> String {
    let quoted: Vec<String> = items.iter().map(|s| format!("\"{s}\"")).collect();
    format!("[{}]", quoted.join(", "))
}

fn int_array(items: &[u32]) -> String {
    let nums: Vec<String> = items.iter().map(u32::to_string).collect();
    format!("[{}]", nums.join(", "))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_parse_accepts_i_slash_n_and_rejects_nonsense() {
        assert_eq!(Shard::parse("0/4").unwrap(), Shard { index: 0, count: 4 });
        assert_eq!(Shard::parse("3/4").unwrap().to_string(), "3/4");
        assert!(Shard::parse("4/4").is_err(), "index must be < count");
        assert!(Shard::parse("0/0").is_err(), "count must be >= 1");
        assert!(Shard::parse("1").is_err());
        assert!(Shard::parse("a/b").is_err());
    }

    #[test]
    fn shard_of_is_stable_and_in_range() {
        // Pinned values: the hash is part of the cross-host contract.
        let h1 = shard_of("gemm", "banked1/u1/w8/a4", 7);
        let h2 = shard_of("gemm", "banked1/u1/w8/a4", 7);
        assert_eq!(h1, h2);
        for n in [1u32, 2, 3, 7, 64] {
            for b in ["gemm", "fft", "kmp"] {
                for id in ["banked1/u1/w8/a4", "xor2r2w/u4/w8/a4"] {
                    assert!(shard_of(b, id, n) < n);
                }
            }
        }
        // the benchmark is part of the key: same point id, different
        // benchmark must be free to land in different buckets
        let spread: std::collections::HashSet<u32> = (0..64)
            .map(|i| shard_of(&format!("b{i}"), "banked1/u1/w8/a4", 8))
            .collect();
        assert!(spread.len() > 1, "hash must depend on the benchmark");
    }

    #[test]
    fn validate_rejects_bad_specs() {
        assert!(CampaignSpec::new().validate().is_err(), "empty plan");
        assert!(CampaignSpec::new().benchmark("nope").validate().is_err());
        let mut s = CampaignSpec::new().benchmark("gemm");
        s.sweep.extra_models = vec!["warp9".into()];
        assert!(matches!(s.validate().unwrap_err(), Error::UnknownModel { .. }));
        let s = CampaignSpec::new().benchmark("gemm").with_shard(2, 2);
        assert!(s.validate().is_err(), "shard index out of range");
        assert!(CampaignSpec::new().benchmark("gemm").with_shard(1, 2).validate().is_ok());
        // duplicates corrupt the (benchmark, scale, point id) key space
        let dup = CampaignSpec::new().benchmark("gemm").benchmark("gemm");
        assert!(dup.validate().is_err(), "swept twice");
        let dup = CampaignSpec::new().benchmark("gemm").locality_only("gemm");
        assert!(dup.validate().is_err(), "swept + locality-only");
    }

    #[test]
    fn shard_strategy_names_round_trip() {
        for s in [ShardStrategy::Hash, ShardStrategy::Weighted] {
            assert_eq!(ShardStrategy::parse(s.as_str()), Some(s));
        }
        assert_eq!(ShardStrategy::parse("round-robin"), None);
        assert_eq!(ShardStrategy::default(), ShardStrategy::Hash);
    }

    #[test]
    fn weighted_assignment_partitions_and_balances() {
        // synthetic suite: one heavy benchmark, two light ones
        let mut keys: Vec<(String, String)> = Vec::new();
        for bench in ["heavy", "light-a", "light-b"] {
            for u in 0..8 {
                keys.push((bench.to_string(), format!("m/u{u}/w8/a4")));
            }
        }
        let weight = |b: &str| if b == "heavy" { 1000u64 } else { 10 };
        for n in [2u32, 3, 7] {
            let assign = weighted_shard_assignment(&keys, weight, n);
            assert_eq!(assign.len(), keys.len());
            assert!(assign.iter().all(|&s| s < n), "buckets in range (n={n})");
            // determinism: same inputs, same assignment
            assert_eq!(assign, weighted_shard_assignment(&keys, weight, n));
        }
        // 2-way: the heavy units must spread across BOTH shards (a
        // whole-benchmark split would leave one shard with 100x the
        // work), and total weight per shard must be near-balanced
        let assign = weighted_shard_assignment(&keys, weight, 2);
        let heavy: Vec<u32> = keys
            .iter()
            .zip(&assign)
            .filter(|((b, _), _)| b == "heavy")
            .map(|(_, &s)| s)
            .collect();
        assert!(heavy.contains(&0) && heavy.contains(&1), "{heavy:?}");
        let mut load = [0u64; 2];
        for ((b, _), &s) in keys.iter().zip(&assign) {
            load[s as usize] += weight(b);
        }
        let (hi, lo) = (load[0].max(load[1]), load[0].min(load[1]));
        assert!(hi - lo <= 1000, "LPT must balance within one heavy unit: {load:?}");
    }

    #[test]
    fn weighted_assignment_consults_each_benchmark_once() {
        let keys: Vec<(String, String)> = (0..6)
            .map(|i| ("gemm".to_string(), format!("m/u{i}/w8/a4")))
            .collect();
        let mut calls = 0usize;
        let assign = weighted_shard_assignment(
            &keys,
            |_| {
                calls += 1;
                7
            },
            3,
        );
        assert_eq!(calls, 1, "weights are memoized per benchmark");
        assert_eq!(assign.len(), 6);
    }

    #[test]
    fn plan_keys_cover_the_swept_cross_product() {
        let mut spec = CampaignSpec::new()
            .benchmark("gemm")
            .locality_only("kmp")
            .benchmark("fft");
        spec.sweep = Sweep::quick();
        let keys = spec.plan_keys();
        let per_bench = spec.sweep.points().len();
        assert_eq!(keys.len(), 2 * per_bench, "locality-only rows carry no units");
        assert!(keys.iter().all(|(b, _)| b == "gemm" || b == "fft"));
        assert!(keys[0].1.contains("/u"), "{:?}", keys[0]);
    }
}
