//! Tiered macro-cost provider subsystem.
//!
//! The DSE loop is dominated by re-scoring the same SRAM macro shapes
//! (depth × ports × banking) across sweeps, campaigns, shard hosts and
//! resumes. The shapes are deterministic, so cost characterization is
//! treated as an **artifact**, not a per-run side effect: every query
//! flows through one [`CostStack`] of three tiers, each a cheaper cache
//! in front of the next:
//!
//! 1. **memo** — an in-process map; repeated scoring inside one process
//!    (sequential sweeps, perf probes, resumed campaigns sharing a
//!    coordinator) never re-batches a shape it has already seen;
//! 2. **store** — the persistent on-disk [`CostStore`]
//!    (`cost-store/v1` append-only JSONL, see [`store`]): a campaign
//!    opens it next to its sink and flushes newly scored rows after
//!    each batch, so a *new process* — a resumed campaign, another
//!    shard host, the next accelerator generation's sweep — starts
//!    warm. Rows are keyed by a stable hash of the canonical macro key
//!    plus a scoring-context **fingerprint** (see [`key`]), so stub-
//!    and pjrt-scored rows can never cross-contaminate;
//! 3. **backend** — any [`CostProvider`]: the PJRT/stub
//!    [`CostService`] batch runtime in production, the in-process
//!    [`MirrorProvider`] in tests. Only misses reach it, in one
//!    deduplicated batch per scoring call, preserving first-seen order.
//!
//! The stack itself implements [`CostProvider`], so tiers compose and
//! the [`crate::coordinator::Coordinator`]'s `score_designs` /
//! `run_sweep` fronts are behavior-identical to the pre-stack code on a
//! cold stack: same queries, same order, same backend, same f32 bits.
//! [`CostCounters`] exposes hit/miss/batch accounting — the campaign
//! reports it and tests pin the "warm run issues zero batches"
//! contract.

pub mod key;
pub mod service;
pub mod store;

pub use key::{backend_fingerprint, key_hash, macro_key, MacroKey};
pub use service::{CostBackend, CostService, MacroQuery, ServiceGuard, COST_BATCH};
pub use store::{CostRow, CostStore};

use crate::error::{Error, Result};
use crate::mem::MemDesign;
use crate::sram::MacroCost;
use crate::util::log;
use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Anything that can score a batch of macro-cost queries. Implemented
/// by the runtime batch backend ([`CostService`]), the in-process
/// mirror ([`MirrorProvider`]), and [`CostStack`] itself (tiers
/// compose). `Sync` is part of the contract: one provider may be
/// scored through concurrently (the serve daemon shares a single
/// coordinator across its whole worker fleet).
pub trait CostProvider: Send + Sync {
    /// Short human label (diagnostics, summaries).
    fn label(&self) -> &'static str;

    /// Evaluate a batch of macro queries, one
    /// `[area, e_read, e_write, leak, t_access]` row per query, in
    /// query order.
    fn cost_batch(&self, queries: &[MacroQuery]) -> Result<Vec<[f32; 5]>>;
}

/// In-process pure-Rust mirror backend (no service thread). The
/// offline twin of [`CostService`]: tests build stacks over it without
/// spawning anything.
#[derive(Clone, Copy, Debug, Default)]
pub struct MirrorProvider;

impl CostProvider for MirrorProvider {
    fn label(&self) -> &'static str {
        "rust-mirror"
    }

    fn cost_batch(&self, queries: &[MacroQuery]) -> Result<Vec<[f32; 5]>> {
        Ok(crate::sram::macro_cost_batch(queries))
    }
}

/// Unpack one cost row into a [`MacroCost`].
pub fn macro_cost_row(row: [f32; 5]) -> MacroCost {
    MacroCost {
        area_um2: row[0],
        e_read_pj: row[1],
        e_write_pj: row[2],
        leak_uw: row[3],
        t_access_ns: row[4],
    }
}

/// Deduplicating accumulator for macro-cost queries.
///
/// Designs register their macro shape with [`CostBatcher::add`] and get
/// back a slot into the batch; identical shapes share a slot. The batch
/// is laid out in **first-seen order** and the key index is a
/// `BTreeMap`, so the layout is identical run to run — campaign JSONL
/// sinks and the resume golden test depend on byte-stable batches, and
/// hash-seeded layouts would also defeat PJRT input caching.
#[derive(Debug, Default)]
pub struct CostBatcher {
    unique: Vec<MacroQuery>,
    index: BTreeMap<MacroKey, usize>,
}

impl CostBatcher {
    /// An empty batch.
    pub fn new() -> Self {
        CostBatcher::default()
    }

    /// Register a design's macro query; returns its slot in the batch.
    pub fn add(&mut self, d: &MemDesign) -> usize {
        let key = macro_key(d);
        match self.index.get(&key) {
            Some(&slot) => slot,
            None => {
                let slot = self.unique.len();
                self.unique
                    .push([key[0] as f32, key[1] as f32, key[2] as f32, key[3] as f32]);
                self.index.insert(key, slot);
                slot
            }
        }
    }

    /// Number of distinct macro configurations batched so far.
    pub fn len(&self) -> usize {
        self.unique.len()
    }

    /// True if nothing has been batched.
    pub fn is_empty(&self) -> bool {
        self.unique.is_empty()
    }

    /// The deduplicated queries, in first-seen order.
    pub fn into_queries(self) -> Vec<MacroQuery> {
        self.unique
    }
}

/// Snapshot of a [`CostStack`]'s accounting. Campaigns diff two
/// snapshots ([`CostCounters::since`]) to report their own share of a
/// long-lived coordinator's traffic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CostCounters {
    /// Queries answered by the in-process memo tier.
    pub memo_hits: usize,
    /// Queries answered by the persistent store tier.
    pub store_hits: usize,
    /// Queries that reached the runtime backend.
    pub misses: usize,
    /// Backend batches issued (≤ 1 per scoring call; 0 when every
    /// query hit a cache tier).
    pub batches: usize,
}

impl CostCounters {
    /// Total cache hits (memo + store).
    pub fn hits(&self) -> usize {
        self.memo_hits + self.store_hits
    }

    /// The delta between this snapshot and an earlier one.
    pub fn since(&self, earlier: &CostCounters) -> CostCounters {
        CostCounters {
            memo_hits: self.memo_hits.saturating_sub(earlier.memo_hits),
            store_hits: self.store_hits.saturating_sub(earlier.store_hits),
            misses: self.misses.saturating_sub(earlier.misses),
            batches: self.batches.saturating_sub(earlier.batches),
        }
    }
}

/// The three-tier provider: memo → store → backend (see the module
/// docs). Interior-mutable so a shared `&Coordinator` can score and a
/// campaign can attach a store without exclusive access.
pub struct CostStack {
    fingerprint: String,
    memo: Mutex<HashMap<MacroKey, [f32; 5]>>,
    store: Mutex<Option<CostStore>>,
    backend: Box<dyn CostProvider>,
    memo_hits: AtomicUsize,
    store_hits: AtomicUsize,
    misses: AtomicUsize,
    batches: AtomicUsize,
}

impl std::fmt::Debug for CostStack {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CostStack")
            .field("fingerprint", &self.fingerprint)
            .field("backend", &self.backend.label())
            .field("counters", &self.counters())
            .finish()
    }
}

impl CostStack {
    /// A stack over `backend`, scoring under `fingerprint` (see
    /// [`key::backend_fingerprint`]). Starts with an empty memo and no
    /// store attached.
    pub fn new(backend: Box<dyn CostProvider>, fingerprint: String) -> Self {
        CostStack {
            fingerprint,
            memo: Mutex::new(HashMap::new()),
            store: Mutex::new(None),
            backend,
            memo_hits: AtomicUsize::new(0),
            store_hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            batches: AtomicUsize::new(0),
        }
    }

    /// The scoring-context fingerprint rows are persisted under.
    pub fn fingerprint(&self) -> &str {
        &self.fingerprint
    }

    /// Attach (open or create) the persistent store at `path`. A store
    /// already open at the same path is kept; a different path replaces
    /// it (with a warning — one stack persists to one store at a time).
    pub fn open_store(&self, path: &Path) -> Result<()> {
        let mut slot = self.store.lock().expect("cost store slot poisoned");
        if let Some(open) = slot.as_ref() {
            if open.path() == path {
                return Ok(());
            }
            log::warn(format!(
                "cost stack: replacing open store {} with {}",
                open.path().display(),
                path.display()
            ));
        }
        *slot = Some(CostStore::open(path)?);
        Ok(())
    }

    /// Path of the attached store, if any.
    pub fn store_path(&self) -> Option<PathBuf> {
        self.store
            .lock()
            .expect("cost store slot poisoned")
            .as_ref()
            .map(|s| s.path().to_path_buf())
    }

    /// Hit/miss/batch accounting since construction.
    pub fn counters(&self) -> CostCounters {
        CostCounters {
            memo_hits: self.memo_hits.load(Ordering::Relaxed),
            store_hits: self.store_hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
        }
    }
}

/// A query's integral macro key (queries are built from u32 fields by
/// [`CostBatcher`] / [`macro_key`], so the f32 round trip is exact).
fn query_key(q: &MacroQuery) -> MacroKey {
    [q[0] as u32, q[1] as u32, q[2] as u32, q[3] as u32]
}

impl CostProvider for CostStack {
    fn label(&self) -> &'static str {
        "tiered-stack"
    }

    fn cost_batch(&self, queries: &[MacroQuery]) -> Result<Vec<[f32; 5]>> {
        let mut out: Vec<Option<[f32; 5]>> = vec![None; queries.len()];
        let mut miss_at: Vec<usize> = Vec::new();
        let mut miss_q: Vec<MacroQuery> = Vec::new();
        let mut memo_hits = 0usize;
        let mut store_hits = 0usize;
        // Rows the attached store is missing: backend misses, plus
        // memo hits the store never saw (it may have been attached — or
        // swapped — after they were scored; the store's content must
        // not depend on attach order).
        let mut persist: Vec<(MacroKey, [f32; 5])> = Vec::new();
        {
            // one lock scope per batch, memo before store (every site
            // that holds both acquires in this order)
            let mut memo = self.memo.lock().expect("cost memo poisoned");
            let store = self.store.lock().expect("cost store slot poisoned");
            for (i, q) in queries.iter().enumerate() {
                let key = query_key(q);
                if let Some(row) = memo.get(&key) {
                    out[i] = Some(*row);
                    memo_hits += 1;
                    if let Some(s) = store.as_ref() {
                        if s.get(&self.fingerprint, key).is_none() {
                            persist.push((key, *row));
                        }
                    }
                    continue;
                }
                if let Some(row) =
                    store.as_ref().and_then(|s| s.get(&self.fingerprint, key))
                {
                    memo.insert(key, row);
                    out[i] = Some(row);
                    store_hits += 1;
                    continue;
                }
                miss_at.push(i);
                miss_q.push(*q);
            }
        }
        self.memo_hits.fetch_add(memo_hits, Ordering::Relaxed);
        self.store_hits.fetch_add(store_hits, Ordering::Relaxed);

        if !miss_q.is_empty() {
            // the miss path: ONE backend batch, first-seen order
            let rows = self.backend.cost_batch(&miss_q)?;
            if rows.len() != miss_q.len() {
                return Err(Error::runtime(format!(
                    "cost backend {} returned {} rows for {} queries",
                    self.backend.label(),
                    rows.len(),
                    miss_q.len()
                )));
            }
            self.misses.fetch_add(miss_q.len(), Ordering::Relaxed);
            self.batches.fetch_add(1, Ordering::Relaxed);
            let mut memo = self.memo.lock().expect("cost memo poisoned");
            for ((&at, q), row) in miss_at.iter().zip(&miss_q).zip(&rows) {
                let key = query_key(q);
                out[at] = Some(*row);
                // a shape batched twice in one call persists once
                if memo.insert(key, *row).is_none() {
                    persist.push((key, *row));
                }
            }
        }
        if !persist.is_empty() {
            // Flush after every batch, so a killed run still warms the
            // next one — but persistence is a cache, not a result: an
            // unwritable store must not fail a fully scored campaign.
            let mut store = self.store.lock().expect("cost store slot poisoned");
            if let Some(s) = store.as_mut() {
                if let Err(e) = s.append(&self.fingerprint, &persist) {
                    log::warn(format!(
                        "cost store {}: {e} (rows stay memoized; persistence skipped)",
                        s.path().display()
                    ));
                }
            }
        }
        Ok(out.into_iter().map(|r| r.expect("every query answered")).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn queries() -> Vec<MacroQuery> {
        vec![[1024.0, 32.0, 2.0, 1.0], [2048.0, 64.0, 1.0, 1.0], [1024.0, 32.0, 2.0, 1.0]]
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("amm_dse_cost_stack_unit");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join(name);
        let _ = std::fs::remove_file(&path);
        path
    }

    #[test]
    fn cold_stack_matches_the_backend_bit_for_bit() {
        let stack = CostStack::new(Box::new(MirrorProvider), "fp-test".into());
        let q = queries();
        let via_stack = stack.cost_batch(&q).unwrap();
        let direct = MirrorProvider.cost_batch(&q).unwrap();
        assert_eq!(via_stack.len(), direct.len());
        for (a, b) in via_stack.iter().zip(&direct) {
            for k in 0..5 {
                assert_eq!(a[k].to_bits(), b[k].to_bits());
            }
        }
        let c = stack.counters();
        // the duplicate query memo-hits within the batch? No: dedupe is
        // the batcher's job — here all 3 miss (the dup scores twice in
        // one backend batch but persists once)
        assert_eq!((c.memo_hits, c.store_hits, c.misses, c.batches), (0, 0, 3, 1));
    }

    #[test]
    fn memo_tier_absorbs_repeat_batches() {
        let stack = CostStack::new(Box::new(MirrorProvider), "fp-test".into());
        let q = queries();
        let first = stack.cost_batch(&q).unwrap();
        let second = stack.cost_batch(&q).unwrap();
        assert_eq!(first, second);
        let c = stack.counters();
        assert_eq!(c.batches, 1, "repeat batch must not reach the backend");
        assert_eq!(c.memo_hits, 3);
    }

    #[test]
    fn store_tier_warms_a_fresh_stack_to_zero_batches() {
        let path = tmp("warm.jsonl");
        let q = queries();
        let cold = CostStack::new(Box::new(MirrorProvider), "fp-test".into());
        cold.open_store(&path).unwrap();
        let cold_rows = cold.cost_batch(&q).unwrap();
        assert_eq!(cold.counters().batches, 1);

        // a fresh stack (new process) over the same store: zero batches
        let warm = CostStack::new(Box::new(MirrorProvider), "fp-test".into());
        warm.open_store(&path).unwrap();
        let warm_rows = warm.cost_batch(&q).unwrap();
        let c = warm.counters();
        assert_eq!(c.batches, 0, "a warm store must absorb every query");
        assert_eq!(c.misses, 0);
        assert_eq!(c.store_hits + c.memo_hits, 3);
        for (a, b) in cold_rows.iter().zip(&warm_rows) {
            for k in 0..5 {
                assert_eq!(a[k].to_bits(), b[k].to_bits(), "stored rows must be bit-exact");
            }
        }
    }

    #[test]
    fn fingerprints_keep_scoring_contexts_cold_for_each_other() {
        let path = tmp("fp_cold.jsonl");
        let q = queries();
        let a = CostStack::new(Box::new(MirrorProvider), "fp-a".into());
        a.open_store(&path).unwrap();
        a.cost_batch(&q).unwrap();
        // same store, different fingerprint: everything misses
        let b = CostStack::new(Box::new(MirrorProvider), "fp-b".into());
        b.open_store(&path).unwrap();
        b.cost_batch(&q).unwrap();
        assert_eq!(b.counters().batches, 1, "foreign-fingerprint rows must not satisfy");
        assert_eq!(b.counters().store_hits, 0);
    }

    #[test]
    fn memo_hits_backfill_a_store_attached_after_scoring() {
        // Scored with no store, then a store is attached: the next
        // scoring call must persist the memoized rows, so the store's
        // content does not depend on when it was attached.
        let path = tmp("backfill.jsonl");
        let q = queries();
        let stack = CostStack::new(Box::new(MirrorProvider), "fp-test".into());
        stack.cost_batch(&q).unwrap();
        assert_eq!(stack.counters().batches, 1);
        stack.open_store(&path).unwrap();
        stack.cost_batch(&q).unwrap();
        assert_eq!(stack.counters().batches, 1, "memo still absorbs the repeat");
        // a fresh stack over the backfilled store is fully warm
        let fresh = CostStack::new(Box::new(MirrorProvider), "fp-test".into());
        fresh.open_store(&path).unwrap();
        fresh.cost_batch(&q).unwrap();
        assert_eq!(fresh.counters().batches, 0, "backfilled store must warm a new process");
        assert_eq!(fresh.counters().store_hits + fresh.counters().memo_hits, 3);
    }

    #[test]
    fn counters_diff_with_since() {
        let stack = CostStack::new(Box::new(MirrorProvider), "fp".into());
        let q = queries();
        stack.cost_batch(&q).unwrap();
        let mid = stack.counters();
        stack.cost_batch(&q).unwrap();
        let delta = stack.counters().since(&mid);
        assert_eq!(delta.batches, 0);
        assert_eq!(delta.memo_hits, 3);
        assert_eq!(delta.hits(), 3);
    }

    #[test]
    fn open_store_is_idempotent_per_path() {
        let path = tmp("idem.jsonl");
        let stack = CostStack::new(Box::new(MirrorProvider), "fp".into());
        stack.open_store(&path).unwrap();
        stack.cost_batch(&queries()).unwrap();
        // reopening the same path must keep the loaded/written rows
        stack.open_store(&path).unwrap();
        let again = stack.cost_batch(&queries()).unwrap();
        assert_eq!(again.len(), 3);
        assert_eq!(stack.counters().batches, 1);
        assert_eq!(stack.store_path().as_deref(), Some(path.as_path()));
    }
}
