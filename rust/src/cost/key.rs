//! Canonical macro-cost keys and scoring-context fingerprints.
//!
//! A persisted cost row is only reusable when two things match:
//!
//! * the **macro key** — the `(depth, width, read_ports, write_ports)`
//!   tuple the memory compiler (and the AOT cost model) is asked for;
//! * the **fingerprint** — a stable string identifying *what produced
//!   the numbers*: the pure-Rust mirror keyed by its calibration
//!   constants, or the PJRT backend keyed by the compiled cost-model
//!   artifact's content hash. Stub- and pjrt-scored rows therefore can
//!   never cross-contaminate: a store warmed by one backend is simply
//!   cold for the other, and a recalibration of [`crate::sram::cal`] (or
//!   a rebuilt artifact) invalidates every previously persisted row.
//!
//! [`key_hash`] combines both into the 64-bit FNV-1a id each store row
//! carries; the store recomputes it on load, so a hand-edited or
//! corrupted row is detected and dropped instead of silently served.

use crate::mem::MemDesign;
use crate::runtime;
use crate::util::hash::{fnv1a, FNV_OFFSET};
use std::path::Path;

/// The canonical macro shape: `[depth, width, read_ports, write_ports]`
/// of the design's base macro — identical to what
/// [`crate::cost::CostBatcher`] deduplicates on.
pub type MacroKey = [u32; 4];

/// The macro key of one built design (what the cost service is asked
/// for). The single home of this projection: batcher, stack and store
/// all key on it.
pub fn macro_key(d: &MemDesign) -> MacroKey {
    [d.macro_depth, d.width, d.macro_ports.0, d.macro_ports.1]
}

/// Stable 64-bit id of one `(fingerprint, macro key)` pair: FNV-1a over
/// the fingerprint bytes, a NUL separator, then the four key fields as
/// little-endian u32s. Part of the `cost-store/v1` on-disk contract —
/// change it and every existing store reads as corrupt.
pub fn key_hash(fingerprint: &str, key: MacroKey) -> u64 {
    let mut h = fnv1a(FNV_OFFSET, fingerprint.as_bytes());
    h = fnv1a(h, &[0u8]);
    for field in key {
        h = fnv1a(h, &field.to_le_bytes());
    }
    h
}

/// Fingerprint of the pure-Rust CACTI-lite mirror: the calibration
/// constants' exact f32 bit patterns, hashed. Recalibrating
/// [`crate::sram::cal`] changes the fingerprint, so stale rows stop
/// resolving instead of mis-scoring new runs.
pub fn mirror_fingerprint() -> String {
    use crate::sram::cal;
    let consts = [
        cal::CELL_UM2,
        cal::PORT_PITCH,
        cal::PERIPH_A,
        cal::PERIPH_B,
        cal::E_READ_0,
        cal::E_READ_BIT,
        cal::WRITE_FACTOR,
        cal::LEAK_BIT,
        cal::LEAK_0,
        cal::T_0,
        cal::T_DEC,
        cal::T_BL,
        cal::T_PORT,
    ];
    let mut h = FNV_OFFSET;
    for c in consts {
        h = fnv1a(h, &c.to_bits().to_le_bytes());
    }
    format!("rust-mirror/45nm/{h:016x}")
}

/// Fingerprint of the PJRT backend: the compiled cost-model artifact's
/// content hash ([`runtime::artifact_fingerprint`]), so rows are keyed
/// to the exact HLO the numbers came from. `unknown` only when the
/// artifact vanished between service spawn and fingerprinting.
pub fn pjrt_fingerprint(artifacts_dir: &Path) -> String {
    match runtime::artifact_fingerprint(artifacts_dir, runtime::names::COST_MODEL) {
        Some(h) => format!("pjrt/cost_model/{h:016x}"),
        None => "pjrt/cost_model/unknown".to_string(),
    }
}

/// The fingerprint for one live backend (what the coordinator installs
/// in its [`crate::cost::CostStack`]).
pub fn backend_fingerprint(backend: super::CostBackend, artifacts_dir: &Path) -> String {
    match backend {
        super::CostBackend::Pjrt => pjrt_fingerprint(artifacts_dir),
        super::CostBackend::RustFallback => mirror_fingerprint(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_hash_is_stable_and_separates_fingerprints() {
        let k: MacroKey = [1024, 32, 2, 1];
        assert_eq!(key_hash("a", k), key_hash("a", k), "deterministic");
        assert_ne!(key_hash("a", k), key_hash("b", k), "fingerprint is part of the key");
        assert_ne!(key_hash("a", k), key_hash("a", [1024, 32, 1, 2]), "field order matters");
        // the NUL separator keeps (fp, key) unambiguous against fp
        // prefixes
        assert_ne!(key_hash("ab", [0, 0, 0, 0]), key_hash("a", [b'b' as u32, 0, 0, 0]));
    }

    #[test]
    fn mirror_fingerprint_is_stable_and_named() {
        let a = mirror_fingerprint();
        assert_eq!(a, mirror_fingerprint());
        assert!(a.starts_with("rust-mirror/45nm/"), "{a}");
    }

    #[test]
    fn pjrt_fingerprint_tracks_artifact_content() {
        let dir = std::env::temp_dir().join("amm_dse_cost_key_fp");
        let _ = std::fs::create_dir_all(&dir);
        let file = dir.join(format!("{}.hlo.txt", runtime::names::COST_MODEL));
        let _ = std::fs::remove_file(&file);
        assert_eq!(pjrt_fingerprint(&dir), "pjrt/cost_model/unknown");
        std::fs::write(&file, "HloModule cost_model_v1").unwrap();
        let fp1 = pjrt_fingerprint(&dir);
        assert!(fp1.starts_with("pjrt/cost_model/") && !fp1.ends_with("unknown"), "{fp1}");
        std::fs::write(&file, "HloModule cost_model_v2").unwrap();
        assert_ne!(pjrt_fingerprint(&dir), fp1, "content change must change the fingerprint");
    }

    #[test]
    fn macro_key_matches_the_design_fields() {
        let d = crate::mem::MemKind::Banked { banks: 4 }.build(4096, 32);
        let k = macro_key(&d);
        assert_eq!(k, [d.macro_depth, d.width, d.macro_ports.0, d.macro_ports.1]);
    }
}
