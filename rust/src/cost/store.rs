//! The persistent macro-cost store: `cost-store/v1` append-only JSONL.
//!
//! Macro-cost characterization is deterministic for a given scoring
//! context (see [`super::key`]), so it should be an **artifact**, not a
//! per-run side effect: one flat JSON object per line, one line per
//! scored `(fingerprint, macro key)` pair. A store written by one
//! campaign warms every later campaign, shard host or resume that
//! shares it — the miss path (the runtime batch backend) is only paid
//! once per macro shape per scoring context, ever.
//!
//! Properties, mirroring the campaign result sink:
//!
//! * **self-contained rows** — every line carries the fingerprint, the
//!   explicit macro fields and the five cost numbers, plus the
//!   [`super::key::key_hash`] id recomputed on load, so corrupt or
//!   hand-edited rows are detected and skipped rather than served;
//! * **bit-exact round trip** — floats use Rust's shortest round-trip
//!   formatting, so a warm run restacks the *identical* f32 bits a cold
//!   run computed (the warm-vs-cold fig5 byte-equality golden depends
//!   on this);
//! * **kill-safe appends** — rows are appended in one buffered write and
//!   flushed per batch; a torn (newline-less) tail left by a kill is
//!   detected on open and terminated before the next append, exactly
//!   like the campaign sink;
//! * **first record wins** — duplicate keys collapse, conflicting
//!   payloads keep the first and are counted; [`CostStore::gc`]
//!   compacts the file (drops malformed/duplicate/conflicting lines)
//!   with an atomic tmp-file + rename rewrite.
//!
//! Rows scored under different fingerprints coexist in one file (a
//! fleet can share a single store across stub and pjrt hosts); lookups
//! are always fingerprint-filtered.

use super::key::{key_hash, MacroKey};
use crate::error::{Error, Result};
use crate::util::jsonl::{field, path_with_suffix};
use crate::util::log;
use std::collections::btree_map::Entry;
use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// Schema tag carried by every row.
pub const SCHEMA: &str = "cost-store/v1";

/// One scored cost row: `[area_um2, e_read_pj, e_write_pj, leak_uw,
/// t_access_ns]` — the cost service's output shape.
pub type CostRow = [f32; 5];

/// Accounting from opening (or gc-ing) a store file.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LoadReport {
    /// Parseable, hash-valid rows read.
    pub records: usize,
    /// Lines that failed to parse or failed the key-hash check.
    pub malformed: usize,
    /// Identical repeats of an already-loaded key, collapsed.
    pub duplicates: usize,
    /// Same-key rows with differing payloads (first wins).
    pub conflicts: usize,
    /// Whether the file ended in a torn (newline-less) tail.
    pub torn_tail: bool,
}

/// A loaded cost store: the full on-disk row set indexed by
/// fingerprint, then macro key (nested so the per-query lookup on the
/// scoring path is allocation-free), plus the append path.
#[derive(Debug)]
pub struct CostStore {
    path: PathBuf,
    rows: BTreeMap<String, BTreeMap<MacroKey, CostRow>>,
    report: LoadReport,
    /// True while the on-disk file still ends in a torn tail (repaired
    /// lazily by the next append).
    torn_tail: bool,
}

impl CostStore {
    /// Open a store, loading every valid row. A missing file is an
    /// empty store (created on first append); unreadable files and
    /// malformed *rows* are not fatal — rows are skipped and counted —
    /// but a real read error on an existing file is.
    pub fn open(path: impl Into<PathBuf>) -> Result<CostStore> {
        let path = path.into();
        let mut store = CostStore {
            path,
            rows: BTreeMap::new(),
            report: LoadReport::default(),
            torn_tail: false,
        };
        if !store.path.exists() {
            return Ok(store);
        }
        let text = std::fs::read_to_string(&store.path)
            .map_err(|e| Error::io(format!("read cost store {}", store.path.display()), e))?;
        store.report.torn_tail = !text.is_empty() && !text.ends_with('\n');
        store.torn_tail = store.report.torn_tail;
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let Some((fp, key, row)) = parse_line(line) else {
                store.report.malformed += 1;
                continue;
            };
            match store.rows.entry(fp).or_default().entry(key) {
                Entry::Occupied(prev) => {
                    if bits(prev.get()) == bits(&row) {
                        store.report.duplicates += 1;
                    } else {
                        store.report.conflicts += 1;
                    }
                }
                Entry::Vacant(slot) => {
                    slot.insert(row);
                    store.report.records += 1;
                }
            }
        }
        if store.report.malformed > 0 || store.report.conflicts > 0 {
            log::warn(format!(
                "cost store {}: skipped {} malformed line(s), kept first of {} conflict(s)",
                store.path.display(),
                store.report.malformed,
                store.report.conflicts
            ));
        }
        Ok(store)
    }

    /// The file this store persists to.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Load-time accounting (what `repro cost-store stat` prints).
    pub fn report(&self) -> LoadReport {
        self.report
    }

    /// Distinct `(fingerprint, key)` rows held.
    pub fn len(&self) -> usize {
        self.rows.values().map(BTreeMap::len).sum()
    }

    /// True when no rows are held.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look one row up within a scoring context (allocation-free: this
    /// runs once per memo-missed query on the scoring path).
    pub fn get(&self, fingerprint: &str, key: MacroKey) -> Option<CostRow> {
        self.rows.get(fingerprint)?.get(&key).copied()
    }

    /// Row counts per fingerprint, sorted (for `stat`).
    pub fn per_fingerprint(&self) -> Vec<(String, usize)> {
        self.rows.iter().map(|(fp, m)| (fp.clone(), m.len())).collect()
    }

    /// Append freshly scored rows (skipping keys already held) and
    /// flush, creating the file/parents on first use and terminating a
    /// torn tail so it can never merge with a fresh row. One buffered
    /// write per call: the campaign flushes after each backend batch,
    /// so a killed campaign still warms the next one.
    pub fn append(&mut self, fingerprint: &str, fresh: &[(MacroKey, CostRow)]) -> Result<()> {
        let mut buf = String::new();
        if self.torn_tail {
            buf.push('\n');
        }
        if !fresh.is_empty() {
            let held = self.rows.entry(fingerprint.to_string()).or_default();
            for (key, row) in fresh {
                if held.contains_key(key) {
                    continue;
                }
                buf.push_str(&record_line(fingerprint, *key, *row));
                buf.push('\n');
                held.insert(*key, *row);
            }
        }
        if buf.is_empty() {
            return Ok(());
        }
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .map_err(|e| Error::io(format!("create {}", dir.display()), e))?;
            }
        }
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .map_err(|e| Error::io(format!("open cost store {}", self.path.display()), e))?;
        f.write_all(buf.as_bytes())
            .map_err(|e| Error::io(format!("append cost store {}", self.path.display()), e))?;
        f.flush()
            .map_err(|e| Error::io(format!("flush cost store {}", self.path.display()), e))?;
        self.torn_tail = false;
        Ok(())
    }

    /// Compact the file: rewrite the held row set (sorted by
    /// fingerprint, then key — byte-stable) through a tmp file + atomic
    /// rename, dropping every malformed/duplicate/conflicting line the
    /// load skipped. Returns how many lines the rewrite shed.
    pub fn gc(&mut self) -> Result<usize> {
        let dropped = self.report.malformed
            + self.report.duplicates
            + self.report.conflicts
            + usize::from(self.report.torn_tail);
        let mut buf = String::new();
        for (fp, held) in &self.rows {
            for (key, row) in held {
                buf.push_str(&record_line(fp, *key, *row));
                buf.push('\n');
            }
        }
        let tmp = path_with_suffix(&self.path, ".tmp");
        std::fs::write(&tmp, buf.as_bytes())
            .map_err(|e| Error::io(format!("write {}", tmp.display()), e))?;
        std::fs::rename(&tmp, &self.path)
            .map_err(|e| Error::io(format!("rename {} over store", tmp.display()), e))?;
        self.torn_tail = false;
        self.report = LoadReport { records: self.len(), ..LoadReport::default() };
        Ok(dropped)
    }

    /// The whole row set as a CSV document (for `export`), sorted like
    /// [`CostStore::gc`] writes.
    pub fn export_csv(&self) -> String {
        let mut s = String::from(
            "fingerprint,depth,width,read_ports,write_ports,area_um2,e_read_pj,e_write_pj,leak_uw,t_access_ns\n",
        );
        for (fp, held) in &self.rows {
            for (k, r) in held {
                s.push_str(&format!(
                    "{fp},{},{},{},{},{},{},{},{},{}\n",
                    k[0], k[1], k[2], k[3], r[0], r[1], r[2], r[3], r[4]
                ));
            }
        }
        s
    }
}

/// Accounting from one [`pool`] call (what `repro merge --pool-stores`
/// prints).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolReport {
    /// Input store files read.
    pub inputs: usize,
    /// Distinct rows held across the inputs (after each input's own
    /// dedupe).
    pub rows_seen: usize,
    /// Rows appended to the output store.
    pub added: usize,
    /// Rows the output already held with the identical payload.
    pub already_held: usize,
    /// Rows whose key was already held with a *different* payload —
    /// the earlier row wins (pre-existing output rows beat inputs,
    /// earlier inputs beat later ones).
    pub conflicts: usize,
    /// Malformed/corrupt lines skipped across the inputs.
    pub malformed: usize,
}

/// Reconcile N shard-fleet stores into one: open (or create) `out`,
/// absorb every input's rows with first-wins semantics, and append the
/// genuinely new rows in one sorted batch per `(input, fingerprint)` —
/// the multi-host closing move of a sharded campaign, where each host
/// accumulated its own store and the fleet wants one warm artifact.
///
/// First-wins ordering: rows already in `out` beat every input, and an
/// earlier input beats a later one (matching the sink-merge and
/// load-time conflict rules). Conflicts can only arise across
/// *different* scoring contexts mis-sharing a fingerprint — counted and
/// kept-first, never merged.
pub fn pool<P: AsRef<Path>>(inputs: &[P], out: &Path) -> Result<(CostStore, PoolReport)> {
    let mut store = CostStore::open(out)?;
    let mut report = PoolReport { inputs: inputs.len(), ..PoolReport::default() };
    for input in inputs {
        let src = CostStore::open(input.as_ref())?;
        report.malformed += src.report().malformed;
        for (fp, held) in &src.rows {
            let mut fresh: Vec<(MacroKey, CostRow)> = Vec::new();
            for (key, row) in held {
                report.rows_seen += 1;
                match store.get(fp, *key) {
                    Some(prev) if bits(&prev) == bits(row) => report.already_held += 1,
                    Some(_) => report.conflicts += 1,
                    None => fresh.push((*key, *row)),
                }
            }
            report.added += fresh.len();
            store.append(fp, &fresh)?;
        }
    }
    Ok((store, report))
}

/// The f32 bit patterns of a row (exact comparison: duplicate vs
/// conflict must not be fooled by NaN or -0.0 semantics).
fn bits(r: &CostRow) -> [u32; 5] {
    [r[0].to_bits(), r[1].to_bits(), r[2].to_bits(), r[3].to_bits(), r[4].to_bits()]
}

/// Emit one store row. Floats use shortest round-trip formatting, so
/// `parse_line(record_line(..))` reproduces the identical f32 bits.
pub fn record_line(fingerprint: &str, key: MacroKey, row: CostRow) -> String {
    format!(
        concat!(
            "{{\"schema\":\"{}\",\"k\":\"{:016x}\",\"fp\":\"{}\",",
            "\"depth\":{},\"width\":{},\"rp\":{},\"wp\":{},",
            "\"area_um2\":{},\"e_read_pj\":{},\"e_write_pj\":{},",
            "\"leak_uw\":{},\"t_access_ns\":{}}}"
        ),
        SCHEMA,
        key_hash(fingerprint, key),
        fingerprint,
        key[0],
        key[1],
        key[2],
        key[3],
        row[0],
        row[1],
        row[2],
        row[3],
        row[4],
    )
}

/// Parse one row back. `None` for malformed lines, foreign schemas, or
/// rows whose recorded key hash does not match the recomputed one
/// (corruption / hand edits) — the store treats all of those as absent.
pub fn parse_line(line: &str) -> Option<(String, MacroKey, CostRow)> {
    if field(line, "schema")? != SCHEMA {
        return None;
    }
    let fp = field(line, "fp")?.to_string();
    let key: MacroKey = [
        field(line, "depth")?.parse().ok()?,
        field(line, "width")?.parse().ok()?,
        field(line, "rp")?.parse().ok()?,
        field(line, "wp")?.parse().ok()?,
    ];
    let recorded = u64::from_str_radix(field(line, "k")?, 16).ok()?;
    if recorded != key_hash(&fp, key) {
        return None;
    }
    let row: CostRow = [
        field(line, "area_um2")?.parse().ok()?,
        field(line, "e_read_pj")?.parse().ok()?,
        field(line, "e_write_pj")?.parse().ok()?,
        field(line, "leak_uw")?.parse().ok()?,
        field(line, "t_access_ns")?.parse().ok()?,
    ];
    Some((fp, key, row))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("amm_dse_cost_store_unit");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join(name);
        let _ = std::fs::remove_file(&path);
        path
    }

    fn sample_row() -> CostRow {
        [98765.4, 0.512345, 0.61234567, 3.1415927, 0.4242424]
    }

    #[test]
    fn rows_round_trip_bit_for_bit() {
        let key: MacroKey = [1024, 32, 2, 1];
        let row = sample_row();
        let line = record_line("rust-mirror/45nm/abc", key, row);
        let (fp, k, r) = parse_line(&line).expect("must parse");
        assert_eq!(fp, "rust-mirror/45nm/abc");
        assert_eq!(k, key);
        assert_eq!(bits(&r), bits(&row), "shortest float reprs reparse to identical bits");
    }

    #[test]
    fn corrupt_rows_and_foreign_schemas_parse_to_none() {
        let key: MacroKey = [1024, 32, 2, 1];
        let line = record_line("fp", key, sample_row());
        assert!(parse_line("").is_none());
        assert!(parse_line("{\"schema\":\"other/v9\"}").is_none());
        assert!(parse_line(&line[..line.len() / 2]).is_none(), "torn tail must not parse");
        // flipping a field invalidates the recorded key hash
        let tampered = line.replace("\"depth\":1024", "\"depth\":2048");
        assert_ne!(line, tampered);
        assert!(parse_line(&tampered).is_none(), "hash check must catch edits");
    }

    #[test]
    fn store_appends_persist_and_reload() {
        let path = tmp("roundtrip.jsonl");
        let mut store = CostStore::open(&path).unwrap();
        assert!(store.is_empty());
        let rows = vec![([1024u32, 32, 2, 1], sample_row()), ([2048, 64, 1, 1], sample_row())];
        store.append("fp-a", &rows).unwrap();
        assert_eq!(store.len(), 2);
        // re-appending held keys writes nothing new
        store.append("fp-a", &rows).unwrap();
        let reloaded = CostStore::open(&path).unwrap();
        assert_eq!(reloaded.len(), 2);
        assert_eq!(reloaded.report().records, 2);
        assert_eq!(reloaded.report().duplicates, 0, "held keys must not re-append");
        assert_eq!(
            bits(&reloaded.get("fp-a", [1024, 32, 2, 1]).unwrap()),
            bits(&sample_row())
        );
    }

    #[test]
    fn fingerprints_isolate_rows() {
        let path = tmp("fp_isolation.jsonl");
        let mut store = CostStore::open(&path).unwrap();
        let key: MacroKey = [4096, 32, 4, 2];
        store.append("rust-mirror/45nm/aaaa", &[(key, sample_row())]).unwrap();
        // stub-scored rows are invisible to a pjrt-fingerprinted lookup
        assert!(store.get("pjrt/cost_model/bbbb", key).is_none());
        assert!(store.get("rust-mirror/45nm/aaaa", key).is_some());
        // both contexts can coexist in one file
        let other = [key[0], key[1], key[2], key[3]];
        store.append("pjrt/cost_model/bbbb", &[(other, [1.0, 2.0, 3.0, 4.0, 5.0])]).unwrap();
        let reloaded = CostStore::open(&path).unwrap();
        assert_eq!(reloaded.len(), 2);
        assert_eq!(reloaded.get("rust-mirror/45nm/aaaa", key).unwrap()[0], sample_row()[0]);
        assert_eq!(reloaded.get("pjrt/cost_model/bbbb", key).unwrap()[0], 1.0);
        let per_fp = reloaded.per_fingerprint();
        assert_eq!(per_fp.len(), 2);
        assert!(per_fp.iter().all(|(_, n)| *n == 1), "{per_fp:?}");
    }

    #[test]
    fn torn_tails_are_detected_and_repaired_by_the_next_append() {
        let path = tmp("torn.jsonl");
        let mut store = CostStore::open(&path).unwrap();
        store.append("fp", &[([512, 32, 1, 1], sample_row())]).unwrap();
        // simulate a kill mid-append: a newline-less fragment
        let full = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, format!("{full}{}", &full[..30])).unwrap();
        let mut reopened = CostStore::open(&path).unwrap();
        assert!(reopened.report().torn_tail);
        assert_eq!(reopened.len(), 1, "the torn fragment must not parse");
        reopened.append("fp", &[([640, 32, 1, 1], sample_row())]).unwrap();
        // the repair newline keeps the fresh row parseable
        let repaired = CostStore::open(&path).unwrap();
        assert!(!repaired.report().torn_tail);
        assert_eq!(repaired.len(), 2);
        assert_eq!(repaired.report().malformed, 1, "the terminated fragment is skipped");
    }

    #[test]
    fn gc_compacts_duplicates_conflicts_and_garbage() {
        let path = tmp("gc.jsonl");
        let key: MacroKey = [1024, 32, 2, 1];
        let good = record_line("fp", key, sample_row());
        let mut conflicted = sample_row();
        conflicted[0] += 1.0;
        let conflict = record_line("fp", key, conflicted);
        std::fs::write(&path, format!("{good}\ngarbage line\n{good}\n{conflict}\n")).unwrap();
        let mut store = CostStore::open(&path).unwrap();
        let rep = store.report();
        assert_eq!((rep.records, rep.malformed, rep.duplicates, rep.conflicts), (1, 1, 1, 1));
        // first record wins the conflict
        assert_eq!(bits(&store.get("fp", key).unwrap()), bits(&sample_row()));
        let dropped = store.gc().unwrap();
        assert_eq!(dropped, 3);
        let clean = CostStore::open(&path).unwrap();
        let rep = clean.report();
        assert_eq!((rep.records, rep.malformed, rep.duplicates, rep.conflicts), (1, 0, 0, 0));
        // gc output is byte-stable
        let once = std::fs::read_to_string(&path).unwrap();
        CostStore::open(&path).unwrap().gc().unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), once);
    }

    #[test]
    fn pool_reconciles_shard_stores_first_wins() {
        let a_path = tmp("pool_a.jsonl");
        let b_path = tmp("pool_b.jsonl");
        let out_path = tmp("pool_out.jsonl");
        let shared: MacroKey = [1024, 32, 2, 1];
        let only_a: MacroKey = [2048, 32, 2, 1];
        let only_b: MacroKey = [4096, 64, 1, 1];
        let mut a = CostStore::open(&a_path).unwrap();
        a.append("fp", &[(shared, sample_row()), (only_a, sample_row())]).unwrap();
        let mut b = CostStore::open(&b_path).unwrap();
        let mut divergent = sample_row();
        divergent[0] += 1.0;
        b.append("fp", &[(shared, divergent), (only_b, sample_row())]).unwrap();
        let (pooled, rep) = pool(&[&a_path, &b_path], &out_path).unwrap();
        assert_eq!(rep.inputs, 2);
        assert_eq!(rep.rows_seen, 4);
        assert_eq!(rep.added, 3, "shared key pools once");
        assert_eq!(rep.conflicts, 1, "divergent payload for the shared key");
        assert_eq!(rep.already_held, 0);
        assert_eq!(pooled.len(), 3);
        // first input wins the conflict
        assert_eq!(bits(&pooled.get("fp", shared).unwrap()), bits(&sample_row()));
        // the output is a normal store: reload agrees
        let reloaded = CostStore::open(&out_path).unwrap();
        assert_eq!(reloaded.len(), 3);
        assert_eq!(reloaded.report().records, 3);
        // pooling again is a no-op: everything already held
        let (_, again) = pool(&[&a_path, &b_path], &out_path).unwrap();
        assert_eq!(again.added, 0);
        assert_eq!(again.already_held, 3);
        assert_eq!(again.conflicts, 1, "the divergent row still conflicts");
        assert_eq!(CostStore::open(&out_path).unwrap().len(), 3);
    }

    #[test]
    fn pool_preserves_fingerprint_isolation_and_skips_garbage() {
        let a_path = tmp("pool_fp_a.jsonl");
        let out_path = tmp("pool_fp_out.jsonl");
        let key: MacroKey = [512, 32, 1, 1];
        let mut a = CostStore::open(&a_path).unwrap();
        a.append("fp-one", &[(key, sample_row())]).unwrap();
        a.append("fp-two", &[(key, [1.0, 2.0, 3.0, 4.0, 5.0])]).unwrap();
        // corrupt line rides along in the input file
        let mut text = std::fs::read_to_string(&a_path).unwrap();
        text.push_str("garbage\n");
        std::fs::write(&a_path, text).unwrap();
        let (pooled, rep) = pool(&[&a_path], &out_path).unwrap();
        assert_eq!(rep.malformed, 1, "input garbage is counted, not copied");
        assert_eq!(pooled.len(), 2);
        assert_eq!(pooled.get("fp-one", key).unwrap()[0], sample_row()[0]);
        assert_eq!(pooled.get("fp-two", key).unwrap()[0], 1.0);
        let text = std::fs::read_to_string(&out_path).unwrap();
        assert!(!text.contains("garbage"));
    }

    #[test]
    fn export_csv_lists_every_row() {
        let path = tmp("export.jsonl");
        let mut store = CostStore::open(&path).unwrap();
        store.append("fp-b", &[([1024, 32, 2, 1], sample_row())]).unwrap();
        store.append("fp-a", &[([64, 16, 1, 1], sample_row())]).unwrap();
        let csv = store.export_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3, "{csv}");
        assert!(lines[0].starts_with("fingerprint,depth,width"));
        // sorted by fingerprint then key
        assert!(lines[1].starts_with("fp-a,64,16,1,1,"));
        assert!(lines[2].starts_with("fp-b,1024,32,2,1,"));
    }
}
