//! The runtime cost backend: a service thread hosting the (non-`Send`)
//! PJRT runtime, answering batched macro-cost queries with the AOT cost
//! model's outputs — design points are scored by the *same compiled
//! artifact* the Python build produced, never by ad-hoc
//! reimplementation (the pure-Rust mirror in [`crate::sram`] exists
//! only as a fallback and cross-check). Extracted verbatim from the
//! coordinator when the tiered cost stack landed; this is the **miss
//! path** of [`super::CostStack`], tier 3 of 3.

use crate::error::{Error, Result};
use crate::runtime::{names, Runtime};
use crate::util::log;
use std::sync::mpsc;

/// A macro-cost query: `[depth, width, read_ports, write_ports]`.
pub type MacroQuery = [f32; 4];

/// Requests accepted by the PJRT service thread.
enum Request {
    /// Evaluate a batch of macro queries; respond with one
    /// `[area, e_read, e_write, leak, t_access]` row per query.
    CostBatch(Vec<MacroQuery>, mpsc::Sender<Result<Vec<[f32; 5]>>>),
    /// Shut the service down.
    Stop,
}

/// Handle to the PJRT cost service. Clone-able across worker threads.
#[derive(Clone)]
pub struct CostService {
    tx: mpsc::Sender<Request>,
}

/// Where the cost numbers came from (reported in run summaries).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostBackend {
    /// AOT Pallas/JAX cost model via PJRT (the production path).
    Pjrt,
    /// Pure-Rust mirror (artifacts not built).
    RustFallback,
}

impl CostService {
    /// Spawn the service thread. Returns the handle, a join guard, and
    /// which backend is live. Falls back to the Rust mirror when the
    /// artifact is missing or PJRT fails to initialize.
    pub fn spawn(artifacts_dir: std::path::PathBuf) -> (CostService, ServiceGuard, CostBackend) {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<CostBackend>();
        let join = std::thread::Builder::new()
            .name("pjrt-cost-service".into())
            .spawn(move || service_main(artifacts_dir, rx, ready_tx))
            .expect("spawn pjrt service thread");
        let backend = ready_rx.recv().unwrap_or(CostBackend::RustFallback);
        (CostService { tx }, ServiceGuard { tx2: None, join: Some(join) }, backend)
    }

    /// Evaluate a batch of macro queries (blocking).
    pub fn cost_batch(&self, queries: Vec<MacroQuery>) -> Result<Vec<[f32; 5]>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Request::CostBatch(queries, rtx))
            .map_err(|_| Error::runtime("cost service stopped"))?;
        rrx.recv().map_err(|_| Error::runtime("cost service dropped reply"))?
    }

    /// Ask the service to stop (the guard also does this on drop).
    pub fn stop(&self) {
        let _ = self.tx.send(Request::Stop);
    }
}

impl super::CostProvider for CostService {
    fn label(&self) -> &'static str {
        "runtime-batch"
    }

    fn cost_batch(&self, queries: &[MacroQuery]) -> Result<Vec<[f32; 5]>> {
        CostService::cost_batch(self, queries.to_vec())
    }
}

/// Joins the service thread on drop.
pub struct ServiceGuard {
    tx2: Option<mpsc::Sender<Request>>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Drop for ServiceGuard {
    fn drop(&mut self) {
        if let Some(tx) = self.tx2.take() {
            let _ = tx.send(Request::Stop);
        }
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn service_main(
    dir: std::path::PathBuf,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<CostBackend>,
) {
    // Try to bring up PJRT + the cost artifact; otherwise run the mirror.
    let exe = match Runtime::with_dir(&dir) {
        Ok(rt) if rt.has_artifact(names::COST_MODEL) => match rt.load(names::COST_MODEL) {
            Ok(exe) => Some((rt, exe)),
            Err(e) => {
                log::warn(format!("cost model failed to compile ({e}); using Rust mirror"));
                None
            }
        },
        Ok(_) => {
            log::info("artifacts not built; cost service using Rust mirror");
            None
        }
        Err(e) => {
            // With the pjrt feature on, a client that fails to come up
            // is a real problem worth a warning; the stub build errors
            // here by design, so only whisper.
            let msg = format!("PJRT unavailable ({e}); cost service using Rust mirror");
            if cfg!(feature = "pjrt") {
                log::warn(msg);
            } else {
                log::info(msg);
            }
            None
        }
    };
    let backend = if exe.is_some() { CostBackend::Pjrt } else { CostBackend::RustFallback };
    let _ = ready.send(backend);

    while let Ok(req) = rx.recv() {
        match req {
            Request::Stop => break,
            Request::CostBatch(queries, reply) => {
                let result = match &exe {
                    Some((_rt, exe)) => pjrt_cost_batch(exe, &queries),
                    None => Ok(crate::sram::macro_cost_batch(&queries)),
                };
                let _ = reply.send(result);
            }
        }
    }
}

/// The artifact's batch size (must match `python/compile/aot.py`).
pub const COST_BATCH: usize = 1024;

fn pjrt_cost_batch(
    exe: &crate::runtime::Executable,
    queries: &[MacroQuery],
) -> Result<Vec<[f32; 5]>> {
    let mut out = Vec::with_capacity(queries.len());
    // Pad to the fixed batch the artifact was lowered for.
    for chunk in queries.chunks(COST_BATCH) {
        let mut flat = vec![0f32; COST_BATCH * 4];
        for (i, q) in chunk.iter().enumerate() {
            flat[i * 4..i * 4 + 4].copy_from_slice(q);
        }
        // Padding rows use a benign config (depth 4, width 1, 1R1W).
        for i in chunk.len()..COST_BATCH {
            flat[i * 4..i * 4 + 4].copy_from_slice(&[4.0, 1.0, 1.0, 1.0]);
        }
        let results = exe.run_f32(&[(&flat, &[COST_BATCH, 4])])?;
        let rows = &results[0]; // [COST_BATCH, 5] flattened
        if rows.len() != COST_BATCH * 5 {
            return Err(Error::runtime(format!("unexpected cost output size {}", rows.len())));
        }
        for i in 0..chunk.len() {
            out.push([
                rows[i * 5],
                rows[i * 5 + 1],
                rows[i * 5 + 2],
                rows[i * 5 + 3],
                rows[i * 5 + 4],
            ]);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_service_survives_multiple_batches() {
        let tmp = std::env::temp_dir().join("amm_dse_cost_service_test");
        let _ = std::fs::create_dir_all(&tmp);
        let (svc, _guard, backend) = CostService::spawn(tmp);
        assert_eq!(backend, CostBackend::RustFallback);
        for _ in 0..3 {
            let out = svc.cost_batch(vec![[1024.0, 32.0, 1.0, 1.0]; 10]).unwrap();
            assert_eq!(out.len(), 10);
            assert!(out[0][0] > 0.0);
        }
        svc.stop();
    }
}
