//! Cache-memory AMM designs (paper abstract / §V: "scratchpad **and
//! cache-memory** AMM designs ... in different memory cells, port
//! configurations and memory depth").
//!
//! A set-associative cache is two SRAM structures — a tag array and a
//! data array — plus comparators and way muxes. Multi-porting a cache
//! multi-ports *both* arrays, so every organization of [`super::MemKind`]
//! composes here: an AMM-ported cache gives N conflict-free lookups per
//! cycle at the AMM's capacity overhead on both arrays, while a banked
//! cache serializes same-bank lookups exactly like a banked scratchpad.
//!
//! This module provides the *cost composition* used by the §III-A
//! synthesis table (the trace-driven benchmarks in this paper run on
//! scratchpads, as in Aladdin; cache timing simulation is out of the
//! paper's scope).

use super::{MemDesign, MemKind};
use crate::synth;

/// A cache organization to cost.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CacheCfg {
    /// Total data capacity in bytes.
    pub capacity_bytes: u32,
    /// Line size in bytes.
    pub line_bytes: u32,
    /// Associativity (ways).
    pub ways: u32,
    /// Physical address width the tags cover.
    pub addr_bits: u32,
    /// Memory organization for both the tag and data arrays.
    pub ports: MemKind,
}

impl CacheCfg {
    /// Number of sets.
    pub fn sets(&self) -> u32 {
        (self.capacity_bytes / self.line_bytes / self.ways).max(1)
    }
    /// Tag width in bits (addr − index − offset, + valid/dirty).
    pub fn tag_bits(&self) -> u32 {
        let index_bits = 32 - (self.sets().max(2) - 1).leading_zeros();
        let offset_bits = 32 - (self.line_bytes.max(2) - 1).leading_zeros();
        self.addr_bits.saturating_sub(index_bits + offset_bits) + 2
    }
}

/// Fully-costed cache design.
#[derive(Clone, Debug)]
pub struct CacheDesign {
    /// Configuration.
    pub cfg: CacheCfg,
    /// Data-array design (depth = sets, width = line·8, per way).
    pub data: MemDesign,
    /// Tag-array design (depth = sets, width = tag_bits, per way).
    pub tags: MemDesign,
    /// Comparator + way-mux logic cost.
    pub lookup: synth::LogicCost,
}

impl CacheDesign {
    /// Total area, µm².
    pub fn area_um2(&self) -> f32 {
        let w = self.cfg.ways as f32;
        self.data.area_um2() * w + self.tags.area_um2() * w + self.lookup.area_um2
    }
    /// Energy per lookup (all ways probed in parallel), pJ.
    pub fn e_lookup_pj(&self) -> f32 {
        let w = self.cfg.ways as f32;
        w * (self.data.e_read_pj() + self.tags.e_read_pj()) + self.lookup.e_access_pj
    }
    /// Leakage, µW.
    pub fn leak_uw(&self) -> f32 {
        let w = self.cfg.ways as f32;
        self.data.leak_uw() * w + self.tags.leak_uw() * w + self.lookup.leak_uw
    }
    /// Lookup (hit) time, ns: slower of tag path (tag read + compare +
    /// way mux) and data path.
    pub fn t_lookup_ns(&self) -> f32 {
        let tag_path = self.tags.t_access_ns() + self.lookup.delay_ns;
        tag_path.max(self.data.t_access_ns())
    }
}

/// Build a cache design.
pub fn build(cfg: CacheCfg) -> CacheDesign {
    let sets = cfg.sets();
    let data = cfg.ports.build(sets, cfg.line_bytes * 8);
    let tags = cfg.ports.build(sets, cfg.tag_bits());
    // Per-way comparators + way-select mux for each lookup port. The
    // port count comes from the built design's PortModel, so any
    // registered organization composes here without a per-kind match.
    let lookup_ports = match data.ports {
        super::PortModel::TruePorts { reads, .. } => reads,
        super::PortModel::PerBank { .. } => 1,
    };
    let cmp = synth::conflict_comparators(2, cfg.tag_bits()).times((cfg.ways * lookup_ports) as f32);
    let way_mux = synth::mux_tree(cfg.ways, cfg.line_bytes * 8).times(lookup_ports as f32);
    CacheDesign { cfg, data, tags, lookup: cmp.beside(way_mux).cost() }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg(ports: MemKind) -> CacheCfg {
        CacheCfg { capacity_bytes: 16384, line_bytes: 32, ways: 4, addr_bits: 32, ports }
    }

    #[test]
    fn geometry_is_consistent() {
        let c = base_cfg(MemKind::Banked { banks: 1 });
        assert_eq!(c.sets(), 128);
        // 32-bit addr, 7 index bits, 5 offset bits → 20 tag bits + v/d
        assert_eq!(c.tag_bits(), 22);
    }

    #[test]
    fn amm_cache_cheaper_than_circuit_multiport_cache() {
        let xor = build(base_cfg(MemKind::XorAmm { read_ports: 4, write_ports: 2 }));
        let cmp = build(base_cfg(MemKind::CircuitMp { read_ports: 4, write_ports: 2 }));
        assert!(xor.area_um2() < cmp.area_um2());
        assert!(xor.e_lookup_pj() > 0.0 && xor.t_lookup_ns() > 0.0);
    }

    #[test]
    fn associativity_multiplies_arrays() {
        let w2 = build(CacheCfg { ways: 2, ..base_cfg(MemKind::Banked { banks: 1 }) });
        let w8 = build(CacheCfg { ways: 8, ..base_cfg(MemKind::Banked { banks: 1 }) });
        // same capacity: more ways → fewer sets per way but more periphery
        // + comparators → more area and lookup energy
        assert!(w8.e_lookup_pj() > w2.e_lookup_pj());
        assert!(w8.lookup.area_um2 > w2.lookup.area_um2);
    }

    #[test]
    fn tag_path_contributes_to_lookup_time() {
        let c = build(base_cfg(MemKind::LvtAmm { read_ports: 2, write_ports: 1 }));
        assert!(c.t_lookup_ns() >= c.tags.t_access_ns());
    }

    #[test]
    fn bigger_caches_cost_more() {
        let small = build(CacheCfg { capacity_bytes: 4096, ..base_cfg(MemKind::Banked { banks: 1 }) });
        let big = build(CacheCfg { capacity_bytes: 65536, ..base_cfg(MemKind::Banked { banks: 1 }) });
        assert!(big.area_um2() > 4.0 * small.area_um2());
        assert!(big.leak_uw() > small.leak_uw());
    }
}
