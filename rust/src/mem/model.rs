//! The memory-model trait and registry — the crate's extension seam.
//!
//! A memory organization is anything that can (a) describe itself with a
//! stable id, (b) build a fully-costed [`MemDesign`] for a logical
//! depth × width, and (c) tell the scheduler its per-cycle port
//! semantics. The eight organizations of the paper live in
//! [`super::models`]; new schemes (e.g. the coding-based designs of
//! arXiv:2001.09599) implement [`MemModel`], register a [`ModelEntry`],
//! and immediately work everywhere — config files, sweeps, the
//! `Explorer` facade, CSV reports — without touching `sched`, `dse` or
//! `config`.

use super::{MemDesign, PortModel};
use std::sync::{OnceLock, RwLock};

/// An explorable memory organization.
///
/// Object-safe: the DSE layers hold `Box<dyn MemModel>` and never match
/// on concrete types. All cost/arbitration knowledge a downstream layer
/// needs must be baked into the returned [`MemDesign`] / [`PortModel`].
pub trait MemModel: std::fmt::Debug + Send + Sync {
    /// Stable short id used in CSV output, configs and CLI flags
    /// (e.g. `xor4r2w`). Must round-trip through the registry's parser.
    fn id(&self) -> String;

    /// One-line human description (CLI `repro models`, reports).
    fn describe(&self) -> String;

    /// Is this one of the algorithmic multi-port organizations (the blue
    /// points of the paper's Fig 4)?
    fn is_amm(&self) -> bool {
        false
    }

    /// Per-cycle port semantics the scheduler enforces.
    fn port_model(&self) -> PortModel;

    /// Build the fully-costed physical design for a logical memory of
    /// `depth` words × `width` bits.
    fn build(&self, depth: u32, width: u32) -> MemDesign;

    /// The built-in [`MemKind`](super::MemKind) this model corresponds
    /// to, if any — the compat-shim hook that lets `MemKind::parse`
    /// reuse the registry's single id grammar. Registry extensions keep
    /// the default `None`.
    fn compat_kind(&self) -> Option<super::MemKind> {
        None
    }

    /// Object-safe clone.
    fn boxed_clone(&self) -> Box<dyn MemModel>;
}

impl Clone for Box<dyn MemModel> {
    fn clone(&self) -> Self {
        self.boxed_clone()
    }
}

/// Registry entry: how to recognize and construct one family of models
/// from its id string.
#[derive(Clone, Copy)]
pub struct ModelEntry {
    /// Id prefix this family owns (diagnostics; parsing is exact, so
    /// overlapping prefixes like `banked`/`banked2p` are fine).
    pub prefix: &'static str,
    /// One-line description of the family.
    pub synopsis: &'static str,
    /// An example id that must parse (doubles as registry self-test).
    pub example: &'static str,
    /// Parse a *full* id into a model; `None` if the id is not this
    /// family's (wrong prefix or malformed parameters).
    pub parse: fn(&str) -> Option<Box<dyn MemModel>>,
}

impl std::fmt::Debug for ModelEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelEntry")
            .field("prefix", &self.prefix)
            .field("example", &self.example)
            .finish()
    }
}

/// Extension entries registered at run time (tests, downstream crates).
fn extensions() -> &'static RwLock<Vec<ModelEntry>> {
    static EXT: OnceLock<RwLock<Vec<ModelEntry>>> = OnceLock::new();
    EXT.get_or_init(|| RwLock::new(Vec::new()))
}

/// Register an additional memory-model family. Extensions take priority
/// over built-ins with the same prefix, and the registration is
/// process-global (intended for tests and downstream crates adding new
/// AMM schemes).
pub fn register_model(entry: ModelEntry) {
    extensions().write().expect("model registry poisoned").push(entry);
}

/// All registered model families: extensions first (newest first), then
/// the eight built-ins.
pub fn registry() -> Vec<ModelEntry> {
    let mut all: Vec<ModelEntry> =
        extensions().read().expect("model registry poisoned").iter().rev().copied().collect();
    all.extend_from_slice(super::models::BUILTIN_MODELS);
    all
}

/// Resolve an id (e.g. `"xor4r2w"`) to a model through the registry.
pub fn parse_model(id: &str) -> Option<Box<dyn MemModel>> {
    registry().iter().find_map(|e| (e.parse)(id))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_builtin_example_round_trips() {
        for e in registry() {
            let m = (e.parse)(e.example)
                .unwrap_or_else(|| panic!("example {:?} does not parse", e.example));
            assert_eq!(m.id(), e.example, "prefix {}", e.prefix);
            assert!(m.id().starts_with(e.prefix), "{} !~ {}", m.id(), e.prefix);
        }
    }

    #[test]
    fn parse_model_rejects_garbage() {
        assert!(parse_model("bogus").is_none());
        assert!(parse_model("banked").is_none(), "missing bank count");
        assert!(parse_model("xor2r").is_none(), "missing write ports");
        assert!(parse_model("").is_none());
    }

    #[test]
    fn registered_extension_is_found_and_prioritized() {
        // A toy single-entry family; prefix deliberately exotic so this
        // test cannot interfere with others sharing the process.
        #[derive(Debug, Clone)]
        struct Toy;
        impl MemModel for Toy {
            fn id(&self) -> String {
                "toy0".into()
            }
            fn describe(&self) -> String {
                "toy model".into()
            }
            fn port_model(&self) -> PortModel {
                PortModel::TruePorts { reads: 1, writes: 1 }
            }
            fn build(&self, depth: u32, width: u32) -> MemDesign {
                crate::mem::MemKind::Banked { banks: 1 }.build(depth, width)
            }
            fn boxed_clone(&self) -> Box<dyn MemModel> {
                Box::new(self.clone())
            }
        }
        fn parse_toy(s: &str) -> Option<Box<dyn MemModel>> {
            (s == "toy0").then(|| Box::new(Toy) as Box<dyn MemModel>)
        }
        register_model(ModelEntry {
            prefix: "toy",
            synopsis: "test-only toy model",
            example: "toy0",
            parse: parse_toy,
        });
        let m = parse_model("toy0").expect("extension must resolve");
        assert_eq!(m.id(), "toy0");
        assert!(registry().iter().any(|e| e.prefix == "toy"));
    }
}
